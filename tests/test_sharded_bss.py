"""Sharded BSS engine vs the single-device fused engine vs the numpy oracle.

The contract under test (the ISSUE-4 acceptance bar): on a simulated
multi-device CPU mesh, ``sharded_query_batched`` / ``sharded_knn_batched``
return hit sets AND per-query distance counts identical to
``bss_query_batched`` / ``bss_knn_batched`` and to the numpy ``bss_query``
oracle — across 2/4/8 shards, a block count that is NOT a multiple of the
shard count, and an l2 + cosine + jsd metric spread.

Multi-device scenarios run in subprocesses through ``multidevice_shim``
(the forcing flag must precede jax initialisation; the pytest process keeps
its launch-default single device).  The single-shard path and the argument
validation run in-process — a 1-device mesh is always available.
"""

import numpy as np
import pytest
from multidevice_shim import run_simulated_mesh

# --------------------------------------------------------- in-process paths


def test_single_shard_mesh_and_delegation():
    """A 1-device mesh exercises the whole sharded machinery in-process:
    build_bss(mesh=...) must route the batched paths through the sharded
    engine (n_shards stat present) with results identical to the oracle."""
    import jax
    from jax.sharding import Mesh

    from repro.core import flat_index
    from repro.core.npdist import pairwise_np

    rng = np.random.default_rng(0)
    x = rng.random((540, 10)).astype(np.float32)
    db, q = x[:512], x[512:]
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    idx = flat_index.build_bss("l2", db, n_pivots=8, n_pairs=10, block=64,
                               seed=1, mesh=mesh)
    t = _snap(pairwise_np("l2", q, db), 0.03)
    oracle, so = flat_index.bss_query(idx, q, t)
    hits, st = flat_index.bss_query_batched(idx, q, t)
    assert hits == oracle
    assert st["n_shards"] == 1
    assert st["dists_per_query"] == pytest.approx(so["dists_per_query"])
    truth = np.argsort(pairwise_np("l2", q, db), axis=1)[:, :5]
    ki, kd, ks = flat_index.bss_knn_batched(idx, q, 5)
    assert ks["n_shards"] == 1
    for i in range(len(q)):
        assert set(ki[i].tolist()) == set(truth[i].tolist())
    # shard telemetry rides the stats as functional outputs: one slot per
    # shard, summing to the batch's exact-phase work (per_query_dists
    # minus the n_pivots pivot evaluations each query always pays)
    for st_ in (st, ks):
        sd = np.asarray(st_["shard_dists"])
        assert sd.shape == (1,) and np.asarray(st_["shard_blocks"]).shape == (1,)
        exact = int(np.asarray(st_["per_query_dists"]).sum()) - len(q) * 8
        assert int(sd.sum()) == exact


def test_mesh_without_data_axis_rejected():
    import jax
    from jax.sharding import Mesh

    from repro.core import flat_index
    from repro.parallel.shard_index import ShardedBSSIndex

    db = np.random.default_rng(1).random((130, 6)).astype(np.float32)
    idx = flat_index.build_bss("l2", db, n_pivots=4, n_pairs=4, block=32)
    mesh = Mesh(np.array(jax.devices()[:1]), ("model",))
    with pytest.raises(ValueError, match="data axis"):
        ShardedBSSIndex(idx, mesh)
    with pytest.raises(ValueError, match="no mesh"):
        idx.sharded()


def _snap(dvals: np.ndarray, frac: float) -> float:
    """Threshold at ~the quantile, snapped to a well-separated gap midpoint
    so float32 engines and the float64 oracle agree on every d <= t (same
    idiom as tests/test_bss_engine.py)."""
    vals = np.unique(np.sort(np.asarray(dvals, np.float64).ravel()))
    i = int(np.clip(frac * len(vals), 0, len(vals) - 2))
    for j in range(i, len(vals) - 1):
        if vals[j + 1] - vals[j] > 1e-4 * max(1.0, vals[j]):
            return float(0.5 * (vals[j] + vals[j + 1]))
    return float(vals[-1] + 1.0)


# ------------------------------------------------- simulated-mesh scenarios

# shared by the subprocess scripts: corpus factory + snapped thresholds
_COMMON = """
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.core import flat_index
    from repro.core.npdist import pairwise_np
    from repro.parallel.shard_index import (
        ShardedBSSIndex, sharded_query_batched, sharded_knn_batched,
    )
    from repro.core.backends import EngineOpts

    JNP = EngineOpts(backend="jnp")

    # Pin the single-device reference to its DENSE exact-phase realisation:
    # the sparse cell-gather path may differ from the dense pass in the last
    # ulp (different XLA dot shapes), which can shift the kNN radius
    # schedule by one comparison.  Strict count parity is defined against
    # the dense realisation; result EXACTNESS is asserted against the
    # float64 oracle separately and holds for every realisation.
    flat_index._DENSE_ALIVE_FRAC = -1.0

    def space(metric, n, dim, seed):
        rng = np.random.default_rng(seed)
        x = rng.random((n, dim)).astype(np.float32) + 1e-3
        if metric == "jsd":
            x /= x.sum(axis=1, keepdims=True)
        return x

    def snap(dvals, frac):
        vals = np.unique(np.sort(np.asarray(dvals, np.float64).ravel()))
        i = int(np.clip(frac * len(vals), 0, len(vals) - 2))
        for j in range(i, len(vals) - 1):
            if vals[j + 1] - vals[j] > 1e-4 * max(1.0, vals[j]):
                return float(0.5 * (vals[j] + vals[j + 1]))
        return float(vals[-1] + 1.0)

    devs = jax.devices()
"""

# The equivalence matrix: per metric, every shard count, range AND kNN —
# hits, order, distance counts, rounds all identical to the single-device
# fused engine and the oracle.  Block counts (11, 5, 11) are NOT multiples
# of 2/4/8, so every mesh exercises the empty padding blocks.
_MATRIX = _COMMON + """
    CASES = [  # metric, n, dim, block, nq, k
        ("l2", 700, 12, 64, 23, 7),
        ("cosine", 513, 9, 128, 17, 5),
        ("jsd", 330, 11, 32, 11, 4),
    ]
    for metric, n, dim, block, nq, k in CASES:
        data = space(metric, n + nq, dim, seed=n)
        db, q = data[:n], data[n:]
        idx = flat_index.build_bss(metric, db, n_pivots=8, n_pairs=10,
                                   block=block, seed=1)
        assert idx.n_blocks % 2, (metric, idx.n_blocks)  # exercise padding
        t = snap(pairwise_np(metric, q, db), 0.02)
        oracle, so = flat_index.bss_query(idx, q, t)
        single, ss = flat_index.bss_query_batched(idx, q, t, opts=JNP)
        ks_i, ks_d, ks_s = flat_index.bss_knn_batched(idx, q, k, opts=JNP)
        for n_shards in (2, 4, 8):
            mesh = Mesh(np.array(devs[:n_shards]), ("data",))
            sidx = ShardedBSSIndex(idx, mesh)
            hits, st = sharded_query_batched(sidx, q, t, opts=JNP)
            assert hits == oracle == single, (metric, n_shards)
            assert abs(st["dists_per_query"] - so["dists_per_query"]) < 1e-9
            assert abs(st["dists_per_query"] - ss["dists_per_query"]) < 1e-9
            assert st["n_shards"] == n_shards
            ki, kd, kst = sharded_knn_batched(sidx, q, k, opts=JNP)
            assert np.array_equal(ki, ks_i), (metric, n_shards)
            np.testing.assert_allclose(kd, ks_d, rtol=1e-6, atol=1e-7)
            assert kst["rounds"] == ks_s["rounds"], (metric, n_shards)
            assert abs(kst["dists_per_query"] - ks_s["dists_per_query"]) < 1e-9
        print(f"MATRIX_OK {metric}")
    print("SHARDED_MATRIX_OK")
"""

# Kernel wiring: the masked Pallas family (interpret mode off-TPU) running
# shard-local must agree with the single-device pallas path and the oracle.
_PALLAS = _COMMON + """
    data = space("l2", 470, 12, seed=5)
    db, q = data[:440], data[440:]
    idx = flat_index.build_bss("l2", db, n_pivots=6, n_pairs=8, block=128,
                               seed=2)
    t = snap(pairwise_np("l2", q, db), 0.03)
    oracle, _ = flat_index.bss_query(idx, q, t)
    PALLAS = EngineOpts(backend="pallas", interpret=True, bq=8)
    single, _ = flat_index.bss_query_batched(idx, q, t, opts=PALLAS)
    mesh = Mesh(np.array(devs[:2]), ("data",))
    sidx = ShardedBSSIndex(idx, mesh)
    hits, _ = sharded_query_batched(sidx, q, t, opts=PALLAS)
    assert hits == oracle == single
    ki, kd, _ = sharded_knn_batched(sidx, q, 6, opts=PALLAS)
    kj, dj, _ = sharded_knn_batched(sidx, q, 6, opts=JNP)
    assert np.array_equal(np.sort(ki, 1), np.sort(kj, 1))
    np.testing.assert_allclose(np.sort(kd, 1), np.sort(dj, 1),
                               rtol=1e-5, atol=1e-6)
    print("SHARDED_PALLAS_OK")
"""

# Edges: more shards than blocks, k above both the corpus size and the
# per-shard row count, empty query batches, explicit r0 seeds.
_EDGES = _COMMON + """
    db = space("l2", 50, 6, seed=7)   # 2 blocks of 32 on an 8-way mesh
    q = space("l2", 5, 6, seed=8)
    idx = flat_index.build_bss("l2", db, n_pivots=4, n_pairs=4, block=32,
                               seed=3)
    mesh = Mesh(np.array(devs[:8]), ("data",))
    sidx = ShardedBSSIndex(idx, mesh)
    assert sidx.n_blocks_pad == 8 and sidx.rows_per_shard == 32
    truth = pairwise_np("l2", q, db)

    # k=60 exceeds n_valid (50) AND rows_per_shard (32): the per-shard
    # top_k clamps to its rows, the merge still returns every valid point
    ki, kd, kst = sharded_knn_batched(sidx, q, 60, opts=JNP)
    assert ki.shape == (5, 60)
    assert (ki[:, :50] >= 0).all() and (ki[:, 50:] == -1).all()
    assert np.isinf(kd[:, 50:]).all()
    for i in range(5):
        assert set(ki[i, :50].tolist()) == set(range(50))
        np.testing.assert_allclose(kd[i, :50], np.sort(truth[i]),
                                   rtol=1e-5, atol=1e-5)

    # range over the whole space (t above every distance) on the padded
    # mesh: every real point hits, padding slots never leak (no -1 ids)
    t_all = float(truth.max() * 2.0)
    hits, st = sharded_query_batched(sidx, q, t_all, opts=JNP)
    assert all(sorted(r) == list(range(50)) for r in hits)
    assert st["block_exclusion_rate"] == 0.0

    # empty query batch: shapes and stats stay consistent
    h0, s0 = sharded_query_batched(sidx, np.zeros((0, 6), np.float32), 1.0)
    assert h0 == [] and s0["n_shards"] == 8
    k0, d0, ks0 = sharded_knn_batched(sidx, np.zeros((0, 6), np.float32), 3)
    assert k0.shape == (0, 3) and ks0["rounds"] == 0

    # explicit r0 (the serving layer's t0_guess), too tight and too wide,
    # must agree with the single-device engine under the same r0
    for r0 in (1e-6, 100.0):
        gi, gd, gs = sharded_knn_batched(sidx, q, 5, r0=r0, opts=JNP)
        si, sd, ss = flat_index.bss_knn_batched(idx, q, 5, r0=r0, opts=JNP)
        assert np.array_equal(gi, si), r0
        assert gs["rounds"] == ss["rounds"]
        assert abs(gs["dists_per_query"] - ss["dists_per_query"]) < 1e-9
    print("SHARDED_EDGES_OK")
"""

# Shard telemetry: the per-shard exact-phase work split (functional jit
# outputs) must sum EXACTLY to the batch's counted exact-phase distance
# evaluations on a real multi-device mesh, for range and kNN, and fold
# into per-shard counters plus the max/mean imbalance gauge.
_TELEMETRY = _COMMON + """
    from repro.obs import MetricsRegistry, fold_engine_stats, shard_imbalance

    NPIV = 8
    data = space("l2", 723, 12, seed=700)
    db, q = data[:700], data[700:]
    idx = flat_index.build_bss("l2", db, n_pivots=NPIV, n_pairs=10,
                               block=64, seed=1)
    t = snap(pairwise_np("l2", q, db), 0.02)
    mesh = Mesh(np.array(devs[:4]), ("data",))
    sidx = ShardedBSSIndex(idx, mesh)

    hits, st = sharded_query_batched(sidx, q, t, opts=JNP)
    sd = np.asarray(st["shard_dists"]); sb = np.asarray(st["shard_blocks"])
    assert sd.shape == (4,) and sb.shape == (4,)
    exact_total = int(np.asarray(st["per_query_dists"]).sum()) - len(q) * NPIV
    assert int(sd.sum()) == exact_total, (int(sd.sum()), exact_total)
    assert (sd >= 0).all() and (sb >= 0).all() and int(sb.sum()) > 0

    ki, kd, kst = sharded_knn_batched(sidx, q, 6, opts=JNP)
    ksd = np.asarray(kst["shard_dists"])
    k_total = int(np.asarray(kst["per_query_dists"]).sum()) - len(q) * NPIV
    assert int(ksd.sum()) == k_total, (int(ksd.sum()), k_total)

    reg = MetricsRegistry()
    fold_engine_stats(reg, st)
    snap_ = reg.snapshot()
    c = snap_["counters"]
    for i in range(4):
        key = "shard/dists{engine=sharded,kind=range,shard=%d}" % i
        assert c[key] == float(sd[i]), key
        bkey = "shard/blocks{engine=sharded,kind=range,shard=%d}" % i
        assert c[bkey] == float(sb[i]), bkey
    g = snap_["gauges"]["shard/imbalance{engine=sharded,kind=range}"]
    assert g == shard_imbalance(sd) and g >= 1.0
    assert "shard/imbalance" in reg.render()
    print("SHARDED_TELEMETRY_OK")
"""

# Serving integration: RetrievalServer(mesh=...) range + top_k equal the
# meshless server and the float64 oracle.
_SERVER = _COMMON + """
    from repro.serve.retrieval import RetrievalServer

    rng = np.random.default_rng(11)
    centres = rng.normal(size=(16, 24))
    corpus = centres[rng.integers(0, 16, size=900)] + 0.15 * rng.normal(
        size=(900, 24))
    users = centres[rng.integers(0, 16, size=31)] + 0.15 * rng.normal(
        size=(31, 24))
    mesh = Mesh(np.array(devs[:4]), ("data",))
    srv = RetrievalServer(corpus, metric="cosine", block=64, mesh=mesh)
    plain = RetrievalServer(corpus, metric="cosine", block=64)
    assert srv.index.mesh is mesh
    got = srv.top_k(users, k=8)
    want = srv.top_k_oracle(users, k=8)
    ref = plain.top_k(users, k=8)
    for g, w, r in zip(got, want, ref):
        assert set(g.tolist()) == set(w.tolist()) == set(r.tolist())
    hits = srv.range_query(users, min_score=0.6)
    ref_hits = plain.range_query(users, min_score=0.6)
    assert [sorted(h) for h in hits] == [sorted(h) for h in ref_hits]
    assert srv.stats.dists_per_query == plain.stats.dists_per_query
    print("SHARDED_SERVER_OK")
"""


@pytest.mark.slow
def test_sharded_matrix_2_4_8_devices():
    out = run_simulated_mesh(_MATRIX, 8)
    assert "SHARDED_MATRIX_OK" in out.stdout, out.stdout + "\n" + out.stderr


@pytest.mark.slow
def test_sharded_pallas_interpret():
    out = run_simulated_mesh(_PALLAS, 2)
    assert "SHARDED_PALLAS_OK" in out.stdout, out.stdout + "\n" + out.stderr


@pytest.mark.slow
def test_sharded_edge_cases():
    out = run_simulated_mesh(_EDGES, 8)
    assert "SHARDED_EDGES_OK" in out.stdout, out.stdout + "\n" + out.stderr


@pytest.mark.slow
def test_sharded_shard_telemetry():
    out = run_simulated_mesh(_TELEMETRY, 4)
    assert "SHARDED_TELEMETRY_OK" in out.stdout, \
        out.stdout + "\n" + out.stderr


@pytest.mark.slow
def test_sharded_retrieval_server():
    out = run_simulated_mesh(_SERVER, 4)
    assert "SHARDED_SERVER_OK" in out.stdout, out.stdout + "\n" + out.stderr

"""bf16 exact phase vs the fp32 engines: margin soundness + bit-identity.

The contract under test (the ISSUE-6 acceptance bar): ``precision="bf16"``
streams the bfloat16 corpus mirror through the exact phase and re-checks
the comparison-margin boundary band in fp32 — so hit sets, kNN results AND
per-query distance counts are bit-identical to the fp32 engines, on every
supermetric, on the single-device engine (dense, sparse and
pallas-interpret realisations), on the sharded engine, and on the forest
leaf phase.

The property test exercises the margin derivation itself (the one piece of
real analysis): for random corpora on all four supermetrics, the bf16
rounding displacement ``|d(q, p~) - d(q, p)|`` measured in float64 never
exceeds ``bf16_margin`` — the guarantee that the band cannot falsely
exclude a true hit.
"""

import dataclasses

import numpy as np
import pytest
from hypothesis_shim import given, settings, st
from multidevice_shim import run_simulated_mesh

from repro.core import flat_index
from repro.core.backends import EngineOpts
from repro.core.npdist import pairwise_np
from repro.core.precision import bf16_margin, bf16_round_np

SUPERMETRICS = ("l2", "cosine", "jsd", "triangular")

# (backend, interpret, realisation) — the exact-phase implementations
CONFIGS = [
    ("jnp", None, "adaptive"),
    ("jnp", None, "dense"),
    ("pallas", True, "dense"),
]


def _space(metric: str, n: int, dim: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = rng.random((n, dim)).astype(np.float32) + 1e-3
    if metric in ("jsd", "triangular"):
        x /= x.sum(axis=1, keepdims=True)
    return x


def _snap(dvals: np.ndarray, frac: float) -> float:
    """Threshold snapped to a well-separated gap midpoint (the repo's
    standard idiom) so fp32 engines and the float64 oracle agree on every
    d <= t decision."""
    vals = np.unique(np.sort(np.asarray(dvals, np.float64).ravel()))
    i = int(np.clip(frac * len(vals), 0, len(vals) - 2))
    for j in range(i, len(vals) - 1):
        if vals[j + 1] - vals[j] > 1e-4 * max(1.0, vals[j]):
            return float(0.5 * (vals[j] + vals[j + 1]))
    return float(vals[-1] + 1.0)


# ------------------------------------------------ margin property (analysis)


@settings(max_examples=25, deadline=None)
@given(
    st.sampled_from(SUPERMETRICS),
    st.integers(min_value=0, max_value=10_000),
    st.integers(min_value=2, max_value=48),
)
def test_margin_never_falsely_excludes(metric, seed, dim):
    """For random corpora, the float64-measured displacement of every
    (query, point) distance under bf16 corpus rounding stays within the
    derived margin — so widening comparisons by eps provably catches every
    true hit in the band."""
    data = _space(metric, 80, dim, seed)
    q = _space(metric, 16, dim, seed + 1)
    eps = bf16_margin(metric, data)
    d_true = pairwise_np(metric, np.asarray(q, np.float64),
                         np.asarray(data, np.float64))
    d_tilde = pairwise_np(metric, np.asarray(q, np.float64),
                          np.asarray(bf16_round_np(data), np.float64))
    assert float(np.abs(d_true - d_tilde).max()) <= eps, (metric, seed, dim)


def test_margin_scales_and_guards():
    """Margin basics: positive on real data, tiny floor on an empty corpus,
    and padding rows excluded via the valid mask (a huge pad row must not
    inflate the band)."""
    data = _space("l2", 64, 8, 3)
    assert bf16_margin("l2", data) > 0.0
    assert bf16_margin("l2", np.zeros((0, 8), np.float32)) > 0.0
    padded = np.concatenate([data, np.full((1, 8), 1e30, np.float32)])
    valid = np.ones(65, bool)
    valid[-1] = False
    assert bf16_margin("l2", padded, valid) == bf16_margin(
        "l2", data, np.ones(64, bool)
    )


# --------------------------------------------- single-device engine parity


@pytest.fixture(scope="module")
def spaces():
    """One built index + snapped threshold per metric, shared across the
    config matrix."""
    cache = {}

    def get(metric):
        if metric not in cache:
            n, nq, dim = 600, 16, 12
            data = _space(metric, n + nq, dim, seed=7)
            db, q = data[:n], data[n:]
            idx = flat_index.build_bss(metric, db, n_pivots=8, n_pairs=10,
                                       block=128, seed=1)
            t = _snap(pairwise_np(metric, q, db), 0.02)
            cache[metric] = (idx, q, t)
        return cache[metric]

    return get


@pytest.mark.parametrize("backend,interpret,realisation", CONFIGS)
@pytest.mark.parametrize("metric", SUPERMETRICS)
def test_range_bit_identical(spaces, metric, backend, interpret, realisation):
    idx, q, t = spaces(metric)
    o32 = EngineOpts(backend=backend, interpret=interpret,
                     realisation=realisation)
    o16 = dataclasses.replace(o32, precision="bf16")
    h32, s32 = flat_index.bss_query_batched(idx, q, t, opts=o32)
    h16, s16 = flat_index.bss_query_batched(idx, q, t, opts=o16)
    assert h16 == h32
    assert np.array_equal(s16["per_query_dists"], s32["per_query_dists"])
    assert s32["precision"] == "fp32" and s16["precision"] == "bf16"
    assert s16["band_eps"] > 0.0
    assert s16["per_query_recheck"].shape == (len(q),)
    assert s16["recheck_points_per_query"] >= 0.0


@pytest.mark.parametrize("backend,interpret,realisation", CONFIGS)
@pytest.mark.parametrize("metric", SUPERMETRICS)
def test_knn_bit_identical(spaces, metric, backend, interpret, realisation):
    idx, q, _ = spaces(metric)
    o32 = EngineOpts(backend=backend, interpret=interpret,
                     realisation=realisation)
    o16 = dataclasses.replace(o32, precision="bf16")
    i32, d32, s32 = flat_index.bss_knn_batched(idx, q, 5, opts=o32)
    i16, d16, s16 = flat_index.bss_knn_batched(idx, q, 5, opts=o16)
    assert np.array_equal(i16, i32)
    assert np.array_equal(d16, d32)
    assert np.array_equal(s16["per_query_dists"], s32["per_query_dists"])
    assert s16["rounds"] == s32["rounds"]
    assert s16["precision"] == "bf16" and s32["precision"] == "fp32"


def test_range_bf16_matches_oracle(spaces):
    """Transitively implied by bit-identity + the fp32 engine's own oracle
    tests, but cheap to assert directly: bf16 hits == the float64 oracle."""
    idx, q, t = spaces("l2")
    oracle, _ = flat_index.bss_query(idx, q, t)
    h16, _ = flat_index.bss_query_batched(
        idx, q, t, opts=EngineOpts(precision="bf16"))
    assert h16 == oracle


def test_precision_validation(spaces):
    idx, q, t = spaces("l2")
    with pytest.raises(ValueError, match="precision"):
        flat_index.bss_query_batched(idx, q, t, precision="fp16")
    with pytest.raises(ValueError, match="precision"):
        flat_index.bss_knn_batched(idx, q, 3, precision="f32")


def test_empty_batch_carries_precision(spaces):
    idx, q, t = spaces("l2")
    hits, stats = flat_index.bss_query_batched(
        idx, q[:0], t, opts=EngineOpts(precision="bf16"))
    assert hits == [] and stats["precision"] == "bf16"


# ----------------------------------------------------- forest leaf parity


@pytest.mark.parametrize("backend,interpret", [("jnp", None), ("pallas", True)])
@pytest.mark.parametrize("metric", ["l2", "jsd"])
def test_forest_leaf_bit_identical(metric, backend, interpret):
    from repro.core import tree
    from repro.forest import encode_tree, forest_range_search

    data = _space(metric, 460, 12, seed=11)
    db, q = data[:440], data[440:452]
    t = _snap(pairwise_np(metric, q, db), 0.02)
    enc = encode_tree(tree.build_tree("hpt_fft_log", metric, db, seed=11))
    o32 = EngineOpts(backend=backend, interpret=interpret)
    o16 = dataclasses.replace(o32, precision="bf16")
    r32, s32 = forest_range_search(enc, q, t, opts=o32)
    r16, s16 = forest_range_search(enc, q, t, opts=o16)
    assert [sorted(a) for a in r32] == [sorted(b) for b in r16]
    assert np.array_equal(s16["per_query_dists"], s32["per_query_dists"])
    assert s16["precision"] == "bf16" and s16["band_eps"] > 0.0


@pytest.mark.parametrize("backend,interpret", [("jnp", None), ("pallas", True)])
def test_monotone_leaf_bit_identical(backend, interpret):
    from repro.core import lrt
    from repro.forest import encode_monotone, monotone_range_search

    data = _space("l2", 460, 12, seed=13)
    db, q = data[:440], data[440:452]
    t = _snap(pairwise_np("l2", q, db), 0.02)
    enc = encode_monotone(
        lrt.build_monotone_tree("closer", "far", "l2", db, seed=6)
    )
    o32 = EngineOpts(backend=backend, interpret=interpret)
    o16 = dataclasses.replace(o32, precision="bf16")
    r32, s32 = monotone_range_search(enc, q, t, opts=o32)
    r16, s16 = monotone_range_search(enc, q, t, opts=o16)
    assert [sorted(a) for a in r32] == [sorted(b) for b in r16]
    assert np.array_equal(s16["per_query_dists"], s32["per_query_dists"])


def test_forest_precision_validation():
    from repro.core import tree
    from repro.forest import encode_tree, forest_range_search

    db = _space("l2", 200, 8, seed=2)
    enc = encode_tree(tree.build_tree("hpt_fft_log", "l2", db, seed=1))
    with pytest.raises(ValueError, match="precision"):
        forest_range_search(enc, db[:2], 0.1, precision="quarter")


# ------------------------------------------------------- sharded parity

_SHARDED = """
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.core import flat_index
    from repro.core.backends import EngineOpts
    from repro.core.npdist import pairwise_np
    from repro.parallel.shard_index import (
        ShardedBSSIndex, sharded_query_batched, sharded_knn_batched,
    )

    def space(metric, n, dim, seed):
        rng = np.random.default_rng(seed)
        x = rng.random((n, dim)).astype(np.float32) + 1e-3
        if metric == "jsd":
            x /= x.sum(axis=1, keepdims=True)
        return x

    def snap(dvals, frac):
        vals = np.unique(np.sort(np.asarray(dvals, np.float64).ravel()))
        i = int(np.clip(frac * len(vals), 0, len(vals) - 2))
        for j in range(i, len(vals) - 1):
            if vals[j + 1] - vals[j] > 1e-4 * max(1.0, vals[j]):
                return float(0.5 * (vals[j] + vals[j + 1]))
        return float(vals[-1] + 1.0)

    devs = jax.devices()
    for metric, n, dim, block, nq, k in [
        ("l2", 700, 12, 64, 17, 7),
        ("jsd", 330, 11, 32, 11, 4),
    ]:
        data = space(metric, n + nq, dim, seed=n)
        db, q = data[:n], data[n:]
        idx = flat_index.build_bss(metric, db, n_pivots=8, n_pairs=10,
                                   block=block, seed=1)
        t = snap(pairwise_np(metric, q, db), 0.02)
        mesh = Mesh(np.array(devs[:4]), ("data",))
        sidx = ShardedBSSIndex(idx, mesh)
        h32, s32 = sharded_query_batched(
            sidx, q, t, opts=EngineOpts(backend="jnp"))
        h16, s16 = sharded_query_batched(
            sidx, q, t, opts=EngineOpts(backend="jnp", precision="bf16"))
        assert h16 == h32, metric
        assert np.array_equal(s16["per_query_dists"],
                              s32["per_query_dists"]), metric
        assert s16["precision"] == "bf16" and s16["band_eps"] > 0.0
        i32, d32, k32 = sharded_knn_batched(
            sidx, q, k, opts=EngineOpts(backend="jnp"))
        i16, d16, k16 = sharded_knn_batched(
            sidx, q, k, opts=EngineOpts(backend="jnp", precision="bf16"))
        assert np.array_equal(i16, i32) and np.array_equal(d16, d32), metric
        assert np.array_equal(k16["per_query_dists"],
                              k32["per_query_dists"]), metric
        assert k16["rounds"] == k32["rounds"], metric
    print("SHARDED_BF16_OK")
"""


@pytest.mark.slow
def test_sharded_bf16_bit_identical():
    out = run_simulated_mesh(_SHARDED, 4)
    assert "SHARDED_BF16_OK" in out.stdout, out.stdout + "\n" + out.stderr

"""Multi-device behaviours that need more than one XLA device: run in a
subprocess on a simulated host mesh via ``multidevice_shim`` (kept OUT of
this process — smoke tests must see 1 device, per the dry-run contract)."""

import pytest
from multidevice_shim import run_simulated_mesh

_SCRIPT = """
    import sys
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.manager import CheckpointManager

    # --- elastic reshard: save under a (2,2) mesh, restore under (4,) ---
    # (plain make_mesh: jax 0.4.37 has no axis_types kwarg / AxisType enum)
    mesh_a = jax.make_mesh((2, 2), ("data", "model"))
    w = jnp.arange(64.0).reshape(8, 8)
    w_a = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
    mgr = CheckpointManager(sys.argv[1])
    mgr.save(1, {"w": w_a})

    mesh_b = jax.make_mesh((4,), ("data",))
    restored, _ = mgr.restore(
        {"w": w}, shardings={"w": NamedSharding(mesh_b, P("data", None))}
    )
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
    assert restored["w"].sharding.spec == P("data", None)

    # --- sharded train step runs on the 4-device mesh ---
    from repro.configs import common
    from repro.configs.registry import registry
    from repro.optim import adamw
    from repro.train.step import init_state, make_train_step

    model, cfg, batch_fn = registry()["llama3.2-1b"].make_reduced()
    import dataclasses
    model = type(model)(dataclasses.replace(cfg, batch_axes=("data",)))
    params = model.init_params(jax.random.PRNGKey(0))
    batch = batch_fn(jax.random.PRNGKey(1))
    # Mesh context manager instead of jax.set_mesh (added after 0.4.37);
    # inputs carry explicit NamedShardings, the context only resolves
    # named-axis constraints inside jit.
    with mesh_b:
        params = jax.device_put(
            params, NamedSharding(mesh_b, P()))
        batch = jax.device_put(
            batch, {"tokens": NamedSharding(mesh_b, P(None, None))})
        step = jax.jit(make_train_step(
            common.loss_for("lm", model), adamw(lr=1e-3)), donate_argnums=(0,))
        state = init_state(params, adamw(lr=1e-3))
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
    print("MULTIDEVICE_OK")
"""


@pytest.mark.slow
def test_elastic_reshard_and_sharded_step(tmp_path):
    out = run_simulated_mesh(_SCRIPT, 4, str(tmp_path / "ckpt"))
    assert "MULTIDEVICE_OK" in out.stdout, out.stdout + "\n" + out.stderr

"""Paper-claim validation (fast versions of the benchmark suites).

These encode the VALIDATABLE claims of Connor et al. 2017 against our
surrogate data at test scale:

  C1 (Fig. 5): four-point exclusion fails for far fewer queries than
      hyperbolic exclusion.
  C2 (§3.3, Fig. 6-7): four-point exclusion power is ~invariant to pivot
      separation; hyperbolic collapses for close pivots.
  C3 (§4.3): Hilbert beats hyperbolic on every tree structure, typically by
      40-60% at low thresholds.
  C4 (§4.3): exclusion-count variance across structures is far lower under
      Hilbert ("putting huge resources into building expensive structures
      may be far less worthwhile").
  C5 (§5): LRT (balanced) <= balanced monotone tree on clustered data.
  C6 (§3/§5): planar lower bound is never violated for supermetrics.
"""

import numpy as np
import pytest

from repro.core import lrt, tree
from repro.core.npdist import pairwise_np
from repro.data import metricsets


@pytest.fixture(scope="module")
def space():
    data = metricsets.colors_surrogate(6000, dim=48, seed=5)
    db, q = metricsets.split_queries(data, 0.08, seed=6, max_queries=60)
    t = metricsets.calibrate_threshold("l2", db, 2e-4)
    return db, q, t


def test_c1_c2_exclusion_power():
    rng = np.random.default_rng(0)
    data = rng.random((3000, 8))
    t = 0.145
    a = rng.integers(0, 3000, 500)
    b = rng.integers(0, 3000, 500)
    seps = np.array([
        pairwise_np("l2", data[a[i]][None], data[b[i]][None])[0, 0]
        for i in range(500)
    ])
    far, close = int(np.argmax(seps)), int(np.argmin(seps))

    def powers(i):
        p1, p2 = data[a[i]], data[b[i]]
        delta = seps[i]
        d1 = pairwise_np("l2", data, p1[None])[:, 0]
        d2 = pairwise_np("l2", data, p2[None])[:, 0]
        hyp = np.mean(np.abs(d1 - d2) > 2 * t)
        hil = np.mean(np.abs(d1**2 - d2**2) / max(delta, 1e-12) > 2 * t)
        return hyp, hil

    hyp_far, hil_far = powers(far)
    hyp_close, hil_close = powers(close)
    # C1: four-point excludes more in every setting
    assert hil_far >= hyp_far
    assert hil_close >= hyp_close
    # C2: four-point ~invariant (<15% relative change), hyperbolic collapses
    assert abs(hil_far - hil_close) / max(hil_far, 1e-9) < 0.15
    assert hyp_close < 0.2 * hyp_far + 1e-9


def test_c3_c4_hilbert_dominates_all_structures(space):
    db, q, t, = space
    hyp_means, hil_means = [], []
    for variant in ["hpt_fft_log", "hpt_random_binary", "sat_distal_fixed",
                    "sat_global_log"]:
        tr = tree.build_tree(variant, "l2", db, seed=2)
        _, c_hyp = tree.range_search(tr, q, t, "hyperbolic")
        _, c_hil = tree.range_search(tr, q, t, "hilbert")
        hyp_means.append(c_hyp.mean)
        hil_means.append(c_hil.mean)
        assert c_hil.mean <= c_hyp.mean
    hyp_means = np.array(hyp_means)
    hil_means = np.array(hil_means)
    # C3 magnitude: paper reports ~half the distances at low thresholds
    assert np.mean(hil_means / hyp_means) < 0.85
    # C4: relative spread across structures smaller under Hilbert
    cv = lambda v: np.std(v) / np.mean(v)  # noqa: E731
    assert cv(hil_means) <= cv(hyp_means) + 0.05


def test_c5_lrt_beats_balanced_monotone(space):
    db, q, t = space
    means = {}
    for part in ("median_x", "lrt"):
        vals = []
        for select in ("rand", "far"):
            tr = lrt.build_monotone_tree(part, select, "l2", db, seed=4)
            _, counter = lrt.range_search_monotone(tr, q, t, "hilbert")
            vals.append(counter.mean)
        means[part] = min(vals)
    assert means["lrt"] <= means["median_x"] * 1.05, means


def test_c6_no_lower_bound_violation(space):
    db, _, _ = space
    rng = np.random.default_rng(1)
    idx = rng.choice(len(db), 200, replace=False)
    pts = db[idx]
    p1, p2 = pts[0], pts[1]
    delta = pairwise_np("l2", p1[None], p2[None])[0, 0]
    from repro.core import projection

    d1 = pairwise_np("l2", pts[2:], p1[None])[:, 0]
    d2 = pairwise_np("l2", pts[2:], p2[None])[:, 0]
    px, py = np.asarray(projection.project(d1, d2, delta))
    true = pairwise_np("l2", pts[2:], pts[2:])
    planar = np.sqrt((px[:, None] - px[None, :]) ** 2
                     + (py[:, None] - py[None, :]) ** 2)
    assert np.max(planar - true) <= 1e-6

"""Exactness + paper-claim tests for all search structures."""

import numpy as np
import pytest

from repro.core import flat_index, lrt, tree
from repro.core.exclusion import HILBERT, HYPERBOLIC
from repro.data import metricsets


@pytest.fixture(scope="module")
def small_space():
    data = metricsets.euc10(1500, seed=1)
    db, q = metricsets.split_queries(data, 0.05, seed=2)
    q = q[:25]
    t = metricsets.calibrate_threshold("l2", db, 2e-3)
    truth = tree.exhaustive_search("l2", db, q, t)
    return db, q, t, truth


@pytest.fixture(scope="module")
def clustered_space():
    data = metricsets.colors_surrogate(1200, dim=24, seed=3)
    db, q = metricsets.split_queries(data, 0.05, seed=4)
    q = q[:20]
    t = metricsets.calibrate_threshold("l2", db, 5e-3)
    truth = tree.exhaustive_search("l2", db, q, t)
    return db, q, t, truth


def _same(res, truth):
    return all(sorted(r) == sorted(g) for r, g in zip(res, truth))


@pytest.mark.parametrize("variant", tree.TREE_VARIANTS)
@pytest.mark.parametrize("mech", [HYPERBOLIC, HILBERT])
def test_partition_tree_exact(small_space, variant, mech):
    db, q, t, truth = small_space
    tr = tree.build_tree(variant, "l2", db, seed=7)
    res, _ = tree.range_search(tr, q, t, mech)
    assert _same(res, truth)


@pytest.mark.parametrize("variant", ["hpt_fft_log", "sat_pure", "hpt_random_binary"])
def test_hilbert_never_worse(small_space, variant):
    """Paper §4.3: 'supermetric exclusion always gives better performance'."""
    db, q, t, truth = small_space
    tr = tree.build_tree(variant, "l2", db, seed=11)
    _, c_hyp = tree.range_search(tr, q, t, HYPERBOLIC)
    _, c_hil = tree.range_search(tr, q, t, HILBERT)
    assert c_hil.mean <= c_hyp.mean + 1e-9
    # and per-query (same tree, strictly more exclusion opportunities)
    assert np.all(c_hil.per_query <= c_hyp.per_query)


@pytest.mark.parametrize("partition", lrt.PARTITIONS)
@pytest.mark.parametrize("select", ["rand", "far"])
def test_monotone_trees_exact(clustered_space, partition, select):
    db, q, t, truth = clustered_space
    tr = lrt.build_monotone_tree(partition, select, "l2", db, seed=5)
    res, _ = lrt.range_search_monotone(tr, q, t, HILBERT)
    assert _same(res, truth)


def test_monotone_closer_hyperbolic_exact(clustered_space):
    db, q, t, truth = clustered_space
    tr = lrt.build_monotone_tree("closer", "far", "l2", db, seed=5)
    res, _ = lrt.range_search_monotone(tr, q, t, HYPERBOLIC)
    assert _same(res, truth)


def test_hyperbolic_rejected_for_planar_partitions(clustered_space):
    db, q, t, _ = clustered_space
    tr = lrt.build_monotone_tree("lrt", "rand", "l2", db, seed=5)
    with pytest.raises(ValueError):
        lrt.range_search_monotone(tr, q, t, HYPERBOLIC)


def test_balanced_trees_are_balanced(clustered_space):
    db, *_ = clustered_space
    for part in ["median_x", "lrt", "pca"]:
        tr = lrt.build_monotone_tree(part, "rand", "l2", db, seed=6)
        assert tr.max_depth <= int(np.ceil(np.log2(len(db)))) + 3, (
            part,
            tr.max_depth,
        )


@pytest.mark.parametrize("metric", ["l2", "cosine", "jsd"])
def test_bss_exact_all_supermetrics(metric):
    rng = np.random.default_rng(8)
    data = rng.random((900, 16)) + 1e-3
    if metric == "jsd":
        data /= data.sum(axis=1, keepdims=True)
    db, q = data[:800], data[800:820]
    t = metricsets.calibrate_threshold(metric, db, 5e-3)
    truth = tree.exhaustive_search(metric, db, q, t)
    idx = flat_index.build_bss(metric, db, n_pivots=10, n_pairs=12, block=64, seed=9)
    res, stats = flat_index.bss_query(idx, q, t)
    assert _same(res, truth)
    assert 0.0 <= stats["block_exclusion_rate"] <= 1.0


def test_bss_lower_bound_sound():
    """No true hit may live in an excluded block — exactness invariant."""
    rng = np.random.default_rng(10)
    db = rng.random((640, 12))
    q = rng.random((40, 12))
    idx = flat_index.build_bss("l2", db, n_pivots=8, n_pairs=10, block=64, seed=1)
    lb = flat_index.bss_lower_bounds(idx, q)
    from repro.core.npdist import pairwise_np

    d = pairwise_np("l2", q, idx.data)  # permuted order
    d = np.where(idx.valid[None, :], d, np.inf)
    per_block_min = d.reshape(len(q), idx.n_blocks, idx.block).min(axis=2)
    assert np.all(lb <= per_block_min + 1e-4), "LB exceeded a true block distance"


def test_sat_centre_witness_soundness(small_space):
    """Capped SAT variants must NOT use the centre witness (unsound);
    covered implicitly by exactness, but assert the flag plumbing too."""
    db, q, t, truth = small_space
    for variant in ["sat_distal_fixed", "sat_global_log"]:
        tr = tree.build_tree(variant, "l2", db, seed=3)
        # walk: every node's centre_dists must be NaN (witness disabled)
        stack = [tr.root]
        while stack:
            n = stack.pop()
            if isinstance(n, tree._Node):
                assert np.all(np.isnan(n.centre_dists)) or n is tr.root
                stack.extend(c for c in n.children if c is not None)


# ---------------------------------------------------------------- hypothesis
# (real hypothesis when installed; seeded parametrize fallback otherwise)

from hypothesis_shim import given, settings, st


@settings(max_examples=15, deadline=None)
@given(
    st.integers(100, 400),
    st.integers(4, 20),
    st.floats(0.05, 0.8),
    st.integers(0, 10_000),
)
def test_bss_exactness_property(n, dim, t_frac, seed):
    """Property: for ANY corpus/dim/threshold, BSS == exhaustive search."""
    rng = np.random.default_rng(seed)
    db = rng.random((n, dim))
    q = rng.random((8, dim))
    from repro.core.npdist import pairwise_np

    t = float(np.quantile(pairwise_np("l2", q, db), t_frac)) * 0.3
    idx = flat_index.build_bss("l2", db, n_pivots=min(8, n), n_pairs=10,
                               block=32, seed=seed % 97)
    res, _ = flat_index.bss_query(idx, q, t)
    truth = tree.exhaustive_search("l2", db, q, t)
    assert all(sorted(a) == sorted(b) for a, b in zip(res, truth))


@settings(max_examples=10, deadline=None)
@given(st.integers(150, 500), st.integers(0, 10_000))
def test_hilbert_dominates_property(n, seed):
    """Property: Hilbert never evaluates more distances than hyperbolic,
    for any data/threshold (same tree)."""
    rng = np.random.default_rng(seed)
    db = rng.random((n, 8))
    q = rng.random((10, 8))
    t = 0.2
    tr = tree.build_tree("hpt_random_fixed", "l2", db, seed=seed % 89)
    _, c_hyp = tree.range_search(tr, q, t, HYPERBOLIC)
    _, c_hil = tree.range_search(tr, q, t, HILBERT)
    assert np.all(c_hil.per_query <= c_hyp.per_query)


@pytest.mark.parametrize("mech", [HYPERBOLIC, HILBERT])
def test_tree_duplicate_refs_delta_zero_sound(mech):
    """Regression for the delta floor (was 1e-300 here, 1e-12 elsewhere):
    a corpus thick with exact duplicates forces duplicate reference points
    (ref_dists == 0) — exclusion through the shared MIN_DELTA floor must
    stay sound: range results still equal exhaustive search."""
    rng = np.random.default_rng(21)
    locs = rng.random((40, 6))
    db = np.concatenate([np.repeat(locs, 8, axis=0), rng.random((80, 6))])
    q = rng.random((12, 6))
    t = 0.25
    truth = tree.exhaustive_search("l2", db, q, t)
    for variant in ("hpt_fft_fixed", "sat_pure"):
        tr = tree.build_tree(variant, "l2", db, seed=5)
        res, _ = tree.range_search(tr, q, t, mech)
        assert _same(res, truth), (variant, mech)


def test_monotone_tree_duplicate_pivots_sound():
    """Same regression for the monotone/LRT family: duplicate pivot pairs
    (delta < MIN_DELTA) fall back to leaf buckets and stay exact."""
    rng = np.random.default_rng(22)
    locs = rng.random((25, 5))
    db = np.repeat(locs, 10, axis=0)  # every point duplicated 10x
    q = rng.random((10, 5))
    t = 0.2
    truth = tree.exhaustive_search("l2", db, q, t)
    for partition in ("closer", "median_x", "lrt"):
        tr = lrt.build_monotone_tree(partition, "far", "l2", db, seed=6)
        res, _ = lrt.range_search_monotone(tr, q, t, HILBERT)
        assert _same(res, truth), partition


def test_projection_degenerate_plane_shared_collapse():
    """The PR 2 fix, now in ONE place: both array namespaces of
    ``projection.project`` collapse near-duplicate pivot planes
    (delta < DEGENERATE_DELTA) to the sound ring bound (x=0, y=d1)."""
    import jax.numpy as jnp

    from repro.core import projection
    from repro.core.constants import DEGENERATE_DELTA

    d1 = np.array([0.3, 0.7, 1.1])
    d2 = np.array([0.30000001, 0.69999999, 1.1])
    tiny = DEGENERATE_DELTA / 10.0
    for xp in (np, jnp):
        x, y = projection.project(d1, d2, tiny, xp=xp)
        assert np.allclose(np.asarray(x), 0.0)
        assert np.allclose(np.asarray(y), d1, atol=1e-6)
        # healthy planes are untouched by the guard
        x2, _ = projection.project(d1, d1 + 0.2, 0.5, xp=xp)
        assert np.all(np.abs(np.asarray(x2)) > 0.01)


def test_monotone_near_duplicate_pivots_degenerate_fallback():
    """Near-duplicate pivots (separation below DEGENERATE_DELTA but above
    the old MIN_DELTA floor) must take the leaf-bucket fallback at build —
    a plane whose query-side projection ring-collapses cannot carry a
    linear split — and the search stays exact."""
    rng = np.random.default_rng(33)
    locs = rng.random((20, 5))
    jitter = 1e-8 * rng.random((20, 5))  # ~1e-8 < DEGENERATE_DELTA apart
    db = np.concatenate([locs, locs + jitter, rng.random((40, 5))])
    q = rng.random((10, 5))
    t = 0.2
    truth = tree.exhaustive_search("l2", db, q, t)
    for partition in ("closer", "median_x", "lrt"):
        tr = lrt.build_monotone_tree(partition, "far", "l2", db, seed=6)
        res, _ = lrt.range_search_monotone(tr, q, t, HILBERT)
        assert _same(res, truth), partition

"""Metric axioms + the four-point (supermetric) property itself."""

import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.core import distances, projection
from repro.core.npdist import pairwise_np

SUPERMETRICS = ["l2", "cosine", "jsd", "triangular"]
ALL = SUPERMETRICS + ["l1", "linf"]


def _vectors(rng, n, dim, metric):
    x = rng.random((n, dim)) + 1e-3
    if distances.METRICS[metric].probability_space:
        x /= x.sum(axis=1, keepdims=True)
    return x


@pytest.mark.parametrize("name", ALL)
def test_metric_axioms(name):
    rng = np.random.default_rng(0)
    x = _vectors(rng, 24, 12, name)
    d = pairwise_np(name, x, x)
    assert np.all(d >= -1e-9), "non-negativity"
    assert np.allclose(np.diag(d), 0.0, atol=1e-6), "identity"
    assert np.allclose(d, d.T, atol=1e-9), "symmetry"
    # triangle inequality over all triples
    lhs = d[:, :, None]
    rhs = d[:, None, :] + d[None, :, :]
    assert np.all(lhs <= rhs + 1e-7), "triangle inequality"


@pytest.mark.parametrize("name", ALL)
def test_jnp_matches_np(name):
    rng = np.random.default_rng(1)
    x = _vectors(rng, 16, 10, name)
    y = _vectors(rng, 9, 10, name)
    d_np = pairwise_np(name, x, y)
    d_j = np.asarray(distances.METRICS[name].pairwise(x, y))
    np.testing.assert_allclose(d_np, d_j, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("name", SUPERMETRICS)
def test_four_point_lower_bound(name):
    """THE theorem the whole paper rests on (§3): for supermetric d, the
    planar projection w.r.t. any pivot pair lower-bounds true distances."""
    rng = np.random.default_rng(2)
    x = _vectors(rng, 60, 16, name)
    p1, p2, pts = x[0], x[1], x[2:]
    delta = pairwise_np(name, p1, p2)[0, 0]
    d1 = pairwise_np(name, pts, p1[None])[:, 0]
    d2 = pairwise_np(name, pts, p2[None])[:, 0]
    px, py = np.asarray(projection.project(d1, d2, delta))
    true = pairwise_np(name, pts, pts)
    planar = np.sqrt(
        (px[:, None] - px[None, :]) ** 2 + (py[:, None] - py[None, :]) ** 2
    )
    assert np.all(planar <= true + 1e-5), (
        f"{name}: planar LB violated by {np.max(planar - true)}"
    )


def test_four_point_fails_for_l1():
    """l1 lacks the four-point property — the lower bound must break for
    SOME configuration (this is why Hilbert exclusion is unsound there)."""
    rng = np.random.default_rng(3)
    worst = -np.inf
    for _ in range(200):
        x = rng.random((10, 8))
        p1, p2, pts = x[0], x[1], x[2:]
        delta = pairwise_np("l1", p1, p2)[0, 0]
        d1 = pairwise_np("l1", pts, p1[None])[:, 0]
        d2 = pairwise_np("l1", pts, p2[None])[:, 0]
        px, py = np.asarray(projection.project(d1, d2, delta))
        true = pairwise_np("l1", pts, pts)
        planar = np.sqrt(
            (px[:, None] - px[None, :]) ** 2 + (py[:, None] - py[None, :]) ** 2
        )
        worst = max(worst, float(np.max(planar - true)))
    assert worst > 1e-3, "expected a four-point violation for l1"


def test_power_transform_registered_everywhere():
    """Regression: the returned Metric used to be an orphan — not in
    METRICS, no numpy twin — so every engine rejected it.  Now it must be
    servable end to end: registry, numpy twin, BSS build, tree build."""
    m = distances.power_transform(distances.l1, 0.5)
    assert m.name == "l1^0.5"
    assert distances.METRICS["l1^0.5"] is m
    assert distances.get_metric("l1^0.5") is m
    # name-only access registers lazily too
    m2 = distances.get_metric("linf^0.25")
    assert m2.four_point and m2.name == "linf^0.25"

    rng = np.random.default_rng(6)
    x, y = rng.random((12, 7)), rng.random((9, 7))
    d_np = pairwise_np("l1^0.5", x, y)
    d_j = np.asarray(m.pairwise(x, y))
    np.testing.assert_allclose(d_np, d_j, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(d_np, pairwise_np("l1", x, y) ** 0.5,
                               rtol=1e-12, atol=1e-12)

    # both engines accept the registered name
    from repro.core import flat_index, tree

    db = rng.random((150, 7))
    q = rng.random((6, 7))
    t = float(np.quantile(pairwise_np("l1^0.5", q, db), 0.03))
    truth = tree.exhaustive_search("l1^0.5", db, q, t)
    idx = flat_index.build_bss("l1^0.5", db, n_pivots=6, n_pairs=8, block=32)
    res, _ = flat_index.bss_query(idx, q, t)
    assert all(sorted(a) == sorted(b) for a, b in zip(res, truth))
    tr = tree.build_tree("hpt_fft_binary", "l1^0.5", db, seed=3)
    res_t, _ = tree.range_search(tr, q, t, "hilbert")
    assert all(sorted(a) == sorted(b) for a, b in zip(res_t, truth))


def test_power_transform_bad_alpha_rejected():
    with pytest.raises(ValueError):
        distances.power_transform(distances.l1, 0.75)
    with pytest.raises(ValueError):
        distances.get_metric("l1^0.75")  # lazy path enforces the same bound
    with pytest.raises(KeyError):
        pairwise_np("l1^0.75", np.zeros((2, 3)), np.zeros((2, 3)))


def test_power_transform_restores_four_point():
    """d^0.5 has the four-point property for ANY metric (paper §2.2 item 4)."""
    rng = np.random.default_rng(4)
    m = distances.power_transform(distances.l1, 0.5)
    for _ in range(100):
        x = rng.random((8, 6))
        d = np.asarray(m.pairwise(x, x))
        p1d, p2d = d[0], d[1]
        delta = d[0, 1]
        px, py = np.asarray(projection.project(p1d[2:], p2d[2:], delta))
        true = d[2:, 2:]
        planar = np.sqrt(
            (px[:, None] - px[None, :]) ** 2 + (py[:, None] - py[None, :]) ** 2
        )
        assert np.all(planar <= true + 1e-5)


@settings(max_examples=30, deadline=None)
@given(
    st.integers(2, 30),
    st.integers(2, 24),
    st.sampled_from(SUPERMETRICS),
)
def test_projection_preserves_pivot_distances(n, dim, name):
    rng = np.random.default_rng(n * 31 + dim)
    x = _vectors(rng, n + 2, dim, name)
    p1, p2, pts = x[0], x[1], x[2:]
    delta = pairwise_np(name, p1, p2)[0, 0]
    if delta < 1e-6:
        return
    d1 = pairwise_np(name, pts, p1[None])[:, 0]
    d2 = pairwise_np(name, pts, p2[None])[:, 0]
    px, py = np.asarray(projection.project(d1, d2, delta))
    # apex must sit at distance d1 from (-delta/2, 0) and d2 from (delta/2, 0)
    r1 = np.sqrt((px + delta / 2) ** 2 + py**2)
    r2 = np.sqrt((px - delta / 2) ** 2 + py**2)
    np.testing.assert_allclose(r1, d1, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(r2, d2, rtol=1e-3, atol=1e-4)


def test_hilbert_weaker_condition_than_hyperbolic():
    """Hilbert margin >= hyperbolic margin in magnitude is NOT generally true;
    what IS guaranteed: hilbert exclusion is sound and hyperbolic-excluded
    implies hilbert-excluded whenever delta >= |d1+d2| ... instead we check
    the paper's operative guarantee on real data: hilbert excludes a superset
    of queries (statistically dominant) — covered in tree tests; here check
    algebra: |d1-d2| > 2t and d1+d2 >= delta  =>  |d1^2-d2^2|/delta > 2t."""
    rng = np.random.default_rng(5)
    d1 = rng.random(1000) * 2
    d2 = rng.random(1000) * 2
    delta = rng.random(1000) * (d1 + d2)  # triangle ineq: delta <= d1+d2
    t = 0.05
    hyp = np.abs(d1 - d2) > 2 * t
    hil = np.abs(d1**2 - d2**2) / np.maximum(delta, 1e-12) > 2 * t
    assert np.all(~hyp | hil), "hyperbolic exclusion must imply Hilbert"

"""Optimizer unit tests: convergence, factored-state shapes, scanned-update
equivalence, state-spec/structure agreement."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.optim import adafactor, adamw, cosine_schedule


def _quadratic_problem(seed=0):
    rng = np.random.default_rng(seed)
    target = {
        "w": jnp.asarray(rng.normal(size=(12, 8, 6)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(6,)), jnp.float32),
    }
    params = jax.tree.map(jnp.zeros_like, target)

    def loss(p):
        return sum(
            jnp.sum((a - b) ** 2) for a, b in zip(
                jax.tree.leaves(p), jax.tree.leaves(target))
        )

    return params, loss


def _run(opt, params, loss, steps=60):
    state = opt.init(params)
    vals = []
    for _ in range(steps):
        l, g = jax.value_and_grad(loss)(params)
        params, state = opt.update(g, state, params)
        vals.append(float(l))
    return params, vals


def test_adamw_converges():
    params, loss = _quadratic_problem()
    _, vals = _run(adamw(lr=5e-2, weight_decay=0.0), params, loss)
    assert vals[-1] < 0.05 * vals[0]


def test_adafactor_converges():
    params, loss = _quadratic_problem()
    _, vals = _run(adafactor(lr=5e-2), params, loss)
    assert vals[-1] < 0.2 * vals[0]


def test_adafactor_factored_state_shapes():
    opt = adafactor()
    params = {"w": jnp.zeros((12, 8, 6)), "s": jnp.zeros((5,))}
    st = opt.init(params)
    assert st["f"]["w"]["vr"].shape == (12, 8)
    assert st["f"]["w"]["vc"].shape == (12, 6)
    assert st["f"]["s"]["v"].shape == (5,)


def test_adafactor_scanned_update_equals_dense_without_clip():
    """With update-clipping disabled the scanned-leading-dim update is
    EXACTLY the dense update (the only intentional semantic difference is
    per-slice vs whole-leaf RMS clipping)."""
    params, loss = _quadratic_problem()
    g = jax.grad(loss)(params)
    outs = {}
    for flag in (True, False):
        opt = adafactor(lr=1e-2, scan_leading_dim=flag, clip_threshold=1e9)
        st = opt.init(params)
        newp, _ = opt.update(g, st, params)
        outs[flag] = newp
    for a, b in zip(jax.tree.leaves(outs[True]), jax.tree.leaves(outs[False])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)


def test_state_specs_match_structure():
    params = {"w": jnp.zeros((12, 8, 6)), "s": jnp.zeros((5,))}
    specs = {"w": P(None, "model", None), "s": P(None)}
    for opt in (adamw(), adafactor()):
        st = opt.init(params)
        sp = opt.state_specs(specs)
        assert jax.tree.structure(
            jax.tree.map(lambda _: 0, st)
        ) == jax.tree.structure(jax.tree.map(lambda _: 0, sp))


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    vals = [float(lr(jnp.int32(s))) for s in (0, 5, 10, 50, 100)]
    assert vals[0] == 0.0
    assert vals[1] < vals[2]
    assert abs(vals[2] - 1e-3) < 1e-6
    assert vals[3] < vals[2]
    assert vals[4] >= 0.1 * 1e-3 * 0.999  # floor (fp32 rounding slack)

"""Fused batched BSS engine vs the numpy oracle.

The contract under test: ``bss_query_batched`` / ``bss_knn_batched`` return
EXACTLY the numpy path's results — same hit indices, same per-query order
for range search; the same neighbour set for kNN — across metrics, odd
shapes, padded blocks, and both backends (pure-jnp and the Pallas kernels
in interpret mode).

Thresholds are snapped to midpoints of well-separated gaps in the true
(float64) distance distribution so the float32 engine and the float64
oracle cannot disagree about ``d <= t`` at the boundary — the comparison is
then exact, not approximate.
"""

import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.core import flat_index
from repro.core.npdist import pairwise_np

SUPERMETRICS = ["l2", "cosine", "jsd"]


def _space(metric, n, dim, seed):
    rng = np.random.default_rng(seed)
    x = rng.random((n, dim)).astype(np.float32) + 1e-3
    if metric in ("jsd", "triangular"):
        x /= x.sum(axis=1, keepdims=True)
    return x


def safe_threshold(dvals: np.ndarray, frac: float) -> float:
    """A threshold at ~the given quantile, snapped to the midpoint of a
    well-separated gap so float32 and float64 agree on every d <= t."""
    vals = np.unique(np.sort(np.asarray(dvals, np.float64).ravel()))
    i = int(np.clip(frac * len(vals), 0, len(vals) - 2))
    for j in range(i, len(vals) - 1):
        if vals[j + 1] - vals[j] > 1e-4 * max(1.0, vals[j]):
            return float(0.5 * (vals[j] + vals[j + 1]))
    return float(vals[-1] + 1.0)


# ------------------------------------------------------------- range search

# odd query counts, non-power-of-two corpora, blocks that end up padded
SHAPES = [
    ("l2", 801, 17, 64, 33),
    ("l2", 1024, 32, 128, 128),
    ("cosine", 513, 9, 128, 21),
    ("jsd", 330, 11, 32, 7),
    ("triangular", 257, 7, 64, 5),
]


@pytest.mark.parametrize("metric,n,dim,block,nq", SHAPES)
def test_range_matches_oracle(metric, n, dim, block, nq):
    data = _space(metric, n + nq, dim, seed=n + dim)
    db, q = data[:n], data[n:]
    idx = flat_index.build_bss(metric, db, n_pivots=8, n_pairs=10,
                               block=block, seed=1)
    t = safe_threshold(pairwise_np(metric, q, db), 0.02)
    oracle, so = flat_index.bss_query(idx, q, t)
    batched, sb = flat_index.bss_query_batched(idx, q, t, backend="jnp")
    assert batched == oracle  # same indices AND same per-query order
    # both paths prune identically (shared lower bound definition)
    assert sb["dists_per_query"] == pytest.approx(so["dists_per_query"])
    assert 0.0 <= sb["tile_exclusion_rate"] <= 1.0


@pytest.mark.parametrize("metric", SUPERMETRICS)
def test_range_matches_oracle_pallas_interpret(metric):
    """Kernel wiring (interpret mode off-TPU) returns the oracle's hits."""
    data = _space(metric, 450, 12, seed=3)
    db, q = data[:420], data[420:]
    idx = flat_index.build_bss(metric, db, n_pivots=6, n_pairs=8,
                               block=128, seed=2)
    t = safe_threshold(pairwise_np(metric, q, db), 0.03)
    oracle, _ = flat_index.bss_query(idx, q, t)
    batched, _ = flat_index.bss_query_batched(
        idx, q, t, backend="pallas", interpret=True, bq=8
    )
    assert batched == oracle


@pytest.mark.parametrize("t,expect_all", [(-1.0, False), (1e6, True)])
def test_range_all_and_none_excluded(t, expect_all):
    """Degenerate masks: a negative threshold excludes every block (lb >= 0
    always; empty hit lists); a threshold above every distance computes
    every cell — both must still match the oracle exactly."""
    db = _space("l2", 400, 10, seed=9)
    q = _space("l2", 23, 10, seed=10)
    idx = flat_index.build_bss("l2", db, n_pivots=6, n_pairs=8, block=64,
                               seed=3)
    oracle, _ = flat_index.bss_query(idx, q, t)
    batched, sb = flat_index.bss_query_batched(idx, q, t, backend="jnp")
    assert batched == oracle
    if expect_all:
        assert all(len(r) == len(db) for r in batched)
        assert sb["block_exclusion_rate"] == 0.0
    else:
        assert all(len(r) == 0 for r in batched)
        assert sb["block_exclusion_rate"] == 1.0


# --------------------------------------------------------------------- kNN


@pytest.mark.parametrize("metric,n,dim,block,nq,k", [
    ("l2", 900, 16, 64, 37, 7),
    ("l2", 1111, 24, 128, 128, 1),
    ("cosine", 640, 12, 128, 19, 10),
    ("jsd", 385, 9, 32, 11, 5),
])
def test_knn_matches_bruteforce(metric, n, dim, block, nq, k):
    data = _space(metric, n + nq, dim, seed=n * 3 + k)
    db, q = data[:n], data[n:]
    idx = flat_index.build_bss(metric, db, n_pivots=8, n_pairs=10,
                               block=block, seed=4)
    truth = pairwise_np(metric, q, db)
    want_idx = np.argsort(truth, axis=1)[:, :k]
    got_idx, got_d, stats = flat_index.bss_knn_batched(
        idx, q, k, backend="jnp"
    )
    for i in range(nq):
        assert set(got_idx[i].tolist()) == set(want_idx[i].tolist()), i
        np.testing.assert_allclose(  # ascending exact distances
            got_d[i], np.sort(truth[i])[:k], rtol=1e-5, atol=1e-5
        )
    assert stats["rounds"] >= 1
    assert stats["dists_per_query"] >= stats["pivot_dists_per_query"]


def test_knn_pallas_interpret_matches_jnp():
    db = _space("l2", 384, 8, seed=6)
    q = _space("l2", 9, 8, seed=7)
    idx = flat_index.build_bss("l2", db, n_pivots=6, n_pairs=8, block=128,
                               seed=5)
    i_jnp, d_jnp, _ = flat_index.bss_knn_batched(idx, q, 6, backend="jnp")
    i_pal, d_pal, _ = flat_index.bss_knn_batched(
        idx, q, 6, backend="pallas", interpret=True, bq=8
    )
    np.testing.assert_array_equal(np.sort(i_jnp, 1), np.sort(i_pal, 1))
    np.testing.assert_allclose(d_jnp, d_pal, rtol=1e-5, atol=1e-6)


def test_knn_k_exceeding_corpus_pads():
    db = _space("l2", 40, 6, seed=8)
    q = _space("l2", 3, 6, seed=9)
    idx = flat_index.build_bss("l2", db, n_pivots=4, n_pairs=4, block=32,
                               seed=6)
    got_idx, got_d, _ = flat_index.bss_knn_batched(idx, q, 50, backend="jnp")
    assert got_idx.shape == (3, 50)
    assert (got_idx[:, :40] >= 0).all() and (got_idx[:, 40:] == -1).all()
    assert np.isinf(got_d[:, 40:]).all()
    truth = pairwise_np("l2", q, db)
    for i in range(3):
        assert set(got_idx[i, :40].tolist()) == set(range(40))
        np.testing.assert_allclose(got_d[i, :40], np.sort(truth[i]),
                                   rtol=1e-5, atol=1e-5)


def test_knn_fixed_r0_and_serving_path():
    """An explicit initial radius (the serving layer's t0_guess) stays
    exact, whether it starts too tight or too wide."""
    db = _space("l2", 700, 14, seed=11)
    q = _space("l2", 17, 14, seed=12)
    idx = flat_index.build_bss("l2", db, n_pivots=8, n_pairs=10, block=64,
                               seed=7)
    truth = np.argsort(pairwise_np("l2", q, db), axis=1)[:, :5]
    for r0 in (1e-6, 0.3, 100.0):
        got, _, _ = flat_index.bss_knn_batched(idx, q, 5, r0=r0, backend="jnp")
        for i in range(len(q)):
            assert set(got[i].tolist()) == set(truth[i].tolist()), (r0, i)


# -------------------------------------------------------------- soundness


@settings(max_examples=15, deadline=None)
@given(
    st.integers(100, 500),
    st.integers(4, 24),
    st.floats(0.005, 0.2),
    st.integers(0, 10_000),
)
def test_no_excluded_block_contains_a_true_hit(n, dim, t_frac, seed):
    """THE soundness property the engine's exactness rests on: for ANY
    corpus/threshold, a block excluded by the planar lower bound never
    contains a point within the search radius."""
    rng = np.random.default_rng(seed)
    db = rng.random((n, dim)).astype(np.float32)
    q = rng.random((8, dim)).astype(np.float32)
    idx = flat_index.build_bss("l2", db, n_pivots=min(8, n), n_pairs=10,
                               block=32, seed=seed % 23)
    d = pairwise_np("l2", q, idx.data)
    d = np.where(idx.valid[None, :], d, np.inf)
    per_block_min = d.reshape(len(q), idx.n_blocks, idx.block).min(axis=2)
    lb = flat_index.bss_lower_bounds(idx, q)
    t = float(np.quantile(d[np.isfinite(d)], t_frac))
    excluded = lb > t
    # excluded => no point in the block at distance <= t (float tolerance:
    # the bound is float32, the truth float64)
    assert np.all(per_block_min[excluded] > t - 1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(100, 400), st.integers(3, 16), st.integers(0, 10_000))
def test_batched_range_property(n, dim, seed):
    """Property form of oracle equivalence on random spaces."""
    rng = np.random.default_rng(seed)
    db = rng.random((n, dim)).astype(np.float32)
    q = rng.random((7, dim)).astype(np.float32)
    idx = flat_index.build_bss("l2", db, n_pivots=min(8, n), n_pairs=8,
                               block=32, seed=seed % 17)
    t = safe_threshold(pairwise_np("l2", q, db), 0.05)
    oracle, _ = flat_index.bss_query(idx, q, t)
    batched, _ = flat_index.bss_query_batched(idx, q, t, backend="jnp")
    assert batched == oracle

"""Fused batched BSS engine vs the numpy oracle.

The contract under test: ``bss_query_batched`` / ``bss_knn_batched`` return
EXACTLY the numpy path's results — same hit indices, same per-query order
for range search; the same neighbour set for kNN — across metrics, odd
shapes, padded blocks, and both backends (pure-jnp and the Pallas kernels
in interpret mode).

Thresholds are snapped to midpoints of well-separated gaps in the true
(float64) distance distribution so the float32 engine and the float64
oracle cannot disagree about ``d <= t`` at the boundary — the comparison is
then exact, not approximate.
"""

import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.core import flat_index
from repro.core.backends import EngineOpts
from repro.core.distances import METRICS, get_metric
from repro.core.npdist import DistanceCounter, pairwise_np

_JNP = EngineOpts(backend="jnp")
_PALLAS = EngineOpts(backend="pallas", interpret=True, bq=8)

SUPERMETRICS = ["l2", "cosine", "jsd", "triangular"]
# every four-point metric the registry serves, incl. a power transform
ALL_SUPERMETRICS = SUPERMETRICS + ["l1^0.5"]
get_metric("l1^0.5")  # ensure registration before METRICS introspection


def _space(metric, n, dim, seed):
    rng = np.random.default_rng(seed)
    x = rng.random((n, dim)).astype(np.float32) + 1e-3
    if metric in METRICS and METRICS[metric].probability_space:
        x /= x.sum(axis=1, keepdims=True)
    return x


def safe_threshold(dvals: np.ndarray, frac: float) -> float:
    """A threshold at ~the given quantile, snapped to the midpoint of a
    well-separated gap so float32 and float64 agree on every d <= t."""
    vals = np.unique(np.sort(np.asarray(dvals, np.float64).ravel()))
    i = int(np.clip(frac * len(vals), 0, len(vals) - 2))
    for j in range(i, len(vals) - 1):
        if vals[j + 1] - vals[j] > 1e-4 * max(1.0, vals[j]):
            return float(0.5 * (vals[j] + vals[j + 1]))
    return float(vals[-1] + 1.0)


# ------------------------------------------------------------- range search

# odd query counts, non-power-of-two corpora, blocks that end up padded
SHAPES = [
    ("l2", 801, 17, 64, 33),
    ("l2", 1024, 32, 128, 128),
    ("cosine", 513, 9, 128, 21),
    ("jsd", 330, 11, 32, 7),
    ("triangular", 257, 7, 64, 5),
    ("l1^0.5", 410, 13, 64, 9),
]


@pytest.mark.parametrize("metric,n,dim,block,nq", SHAPES)
def test_range_matches_oracle(metric, n, dim, block, nq):
    data = _space(metric, n + nq, dim, seed=n + dim)
    db, q = data[:n], data[n:]
    idx = flat_index.build_bss(metric, db, n_pivots=8, n_pairs=10,
                               block=block, seed=1)
    t = safe_threshold(pairwise_np(metric, q, db), 0.02)
    oracle, so = flat_index.bss_query(idx, q, t)
    batched, sb = flat_index.bss_query_batched(idx, q, t, opts=_JNP)
    assert batched == oracle  # same indices AND same per-query order
    # both paths prune identically (shared lower bound definition)
    assert sb["dists_per_query"] == pytest.approx(so["dists_per_query"])
    assert 0.0 <= sb["tile_exclusion_rate"] <= 1.0


@pytest.mark.parametrize("metric", SUPERMETRICS)
def test_range_matches_oracle_pallas_interpret(metric):
    """Kernel wiring (interpret mode off-TPU) returns the oracle's hits."""
    data = _space(metric, 450, 12, seed=3)
    db, q = data[:420], data[420:]
    idx = flat_index.build_bss(metric, db, n_pivots=6, n_pairs=8,
                               block=128, seed=2)
    t = safe_threshold(pairwise_np(metric, q, db), 0.03)
    oracle, _ = flat_index.bss_query(idx, q, t)
    batched, _ = flat_index.bss_query_batched(idx, q, t, opts=_PALLAS)
    assert batched == oracle


@pytest.mark.parametrize("t,expect_all", [(-1.0, False), (1e6, True)])
def test_range_all_and_none_excluded(t, expect_all):
    """Degenerate masks: a negative threshold excludes every block (lb >= 0
    always; empty hit lists); a threshold above every distance computes
    every cell — both must still match the oracle exactly."""
    db = _space("l2", 400, 10, seed=9)
    q = _space("l2", 23, 10, seed=10)
    idx = flat_index.build_bss("l2", db, n_pivots=6, n_pairs=8, block=64,
                               seed=3)
    oracle, _ = flat_index.bss_query(idx, q, t)
    batched, sb = flat_index.bss_query_batched(idx, q, t, opts=_JNP)
    assert batched == oracle
    if expect_all:
        assert all(len(r) == len(db) for r in batched)
        assert sb["block_exclusion_rate"] == 0.0
    else:
        assert all(len(r) == 0 for r in batched)
        assert sb["block_exclusion_rate"] == 1.0


# --------------------------------------------------------------------- kNN


@pytest.mark.parametrize("metric,n,dim,block,nq,k", [
    ("l2", 900, 16, 64, 37, 7),
    ("l2", 1111, 24, 128, 128, 1),
    ("cosine", 640, 12, 128, 19, 10),
    ("jsd", 385, 9, 32, 11, 5),
    ("triangular", 300, 8, 64, 9, 4),
    ("l1^0.5", 420, 10, 64, 13, 6),
])
def test_knn_matches_bruteforce(metric, n, dim, block, nq, k):
    data = _space(metric, n + nq, dim, seed=n * 3 + k)
    db, q = data[:n], data[n:]
    idx = flat_index.build_bss(metric, db, n_pivots=8, n_pairs=10,
                               block=block, seed=4)
    truth = pairwise_np(metric, q, db)
    want_idx = np.argsort(truth, axis=1)[:, :k]
    got_idx, got_d, stats = flat_index.bss_knn_batched(idx, q, k,
                                                       opts=_JNP)
    for i in range(nq):
        assert set(got_idx[i].tolist()) == set(want_idx[i].tolist()), i
        np.testing.assert_allclose(  # ascending exact distances
            got_d[i], np.sort(truth[i])[:k], rtol=1e-5, atol=1e-5
        )
    assert stats["rounds"] >= 1
    assert stats["dists_per_query"] >= stats["pivot_dists_per_query"]


@pytest.mark.parametrize("metric", SUPERMETRICS)
def test_knn_pallas_interpret_matches_jnp(metric):
    """The masked Pallas kernel family (interpret mode off-TPU) returns the
    jnp engine's kNN for every supermetric — cosine through the l2 kernels
    on the sphere, jsd/triangular through their own tile kernels."""
    db = _space(metric, 384, 8, seed=6)
    q = _space(metric, 9, 8, seed=7)
    idx = flat_index.build_bss(metric, db, n_pivots=6, n_pairs=8, block=128,
                               seed=5)
    i_jnp, d_jnp, _ = flat_index.bss_knn_batched(idx, q, 6, opts=_JNP)
    i_pal, d_pal, _ = flat_index.bss_knn_batched(idx, q, 6, opts=_PALLAS)
    np.testing.assert_array_equal(np.sort(i_jnp, 1), np.sort(i_pal, 1))
    np.testing.assert_allclose(d_jnp, d_pal, rtol=1e-5, atol=1e-6)


def test_knn_k_exceeding_corpus_pads():
    db = _space("l2", 40, 6, seed=8)
    q = _space("l2", 3, 6, seed=9)
    idx = flat_index.build_bss("l2", db, n_pivots=4, n_pairs=4, block=32,
                               seed=6)
    got_idx, got_d, _ = flat_index.bss_knn_batched(idx, q, 50, opts=_JNP)
    assert got_idx.shape == (3, 50)
    assert (got_idx[:, :40] >= 0).all() and (got_idx[:, 40:] == -1).all()
    assert np.isinf(got_d[:, 40:]).all()
    truth = pairwise_np("l2", q, db)
    for i in range(3):
        assert set(got_idx[i, :40].tolist()) == set(range(40))
        np.testing.assert_allclose(got_d[i, :40], np.sort(truth[i]),
                                   rtol=1e-5, atol=1e-5)


def test_knn_fixed_r0_and_serving_path():
    """An explicit initial radius (the serving layer's t0_guess) stays
    exact, whether it starts too tight or too wide."""
    db = _space("l2", 700, 14, seed=11)
    q = _space("l2", 17, 14, seed=12)
    idx = flat_index.build_bss("l2", db, n_pivots=8, n_pairs=10, block=64,
                               seed=7)
    truth = np.argsort(pairwise_np("l2", q, db), axis=1)[:, :5]
    for r0 in (1e-6, 0.3, 100.0):
        got, _, _ = flat_index.bss_knn_batched(idx, q, 5, r0=r0, opts=_JNP)
        for i in range(len(q)):
            assert set(got[i].tolist()) == set(truth[i].tolist()), (r0, i)


# -------------------------------------------------------------- soundness


@settings(max_examples=15, deadline=None)
@given(
    st.integers(100, 500),
    st.integers(4, 24),
    st.floats(0.005, 0.2),
    st.integers(0, 10_000),
)
def test_no_excluded_block_contains_a_true_hit(n, dim, t_frac, seed):
    """THE soundness property the engine's exactness rests on: for ANY
    corpus/threshold, a block excluded by the planar lower bound never
    contains a point within the search radius."""
    rng = np.random.default_rng(seed)
    db = rng.random((n, dim)).astype(np.float32)
    q = rng.random((8, dim)).astype(np.float32)
    idx = flat_index.build_bss("l2", db, n_pivots=min(8, n), n_pairs=10,
                               block=32, seed=seed % 23)
    d = pairwise_np("l2", q, idx.data)
    d = np.where(idx.valid[None, :], d, np.inf)
    per_block_min = d.reshape(len(q), idx.n_blocks, idx.block).min(axis=2)
    lb = flat_index.bss_lower_bounds(idx, q)
    t = float(np.quantile(d[np.isfinite(d)], t_frac))
    excluded = lb > t
    # excluded => no point in the block at distance <= t (float tolerance:
    # the bound is float32, the truth float64)
    assert np.all(per_block_min[excluded] > t - 1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(100, 400), st.integers(3, 16), st.integers(0, 10_000))
def test_batched_range_property(n, dim, seed):
    """Property form of oracle equivalence on random spaces."""
    rng = np.random.default_rng(seed)
    db = rng.random((n, dim)).astype(np.float32)
    q = rng.random((7, dim)).astype(np.float32)
    idx = flat_index.build_bss("l2", db, n_pivots=min(8, n), n_pairs=8,
                               block=32, seed=seed % 17)
    t = safe_threshold(pairwise_np("l2", q, db), 0.05)
    oracle, _ = flat_index.bss_query(idx, q, t)
    batched, _ = flat_index.bss_query_batched(idx, q, t, opts=_JNP)
    assert batched == oracle


@settings(max_examples=12, deadline=None)
@given(
    st.sampled_from(ALL_SUPERMETRICS),
    st.integers(120, 400),
    st.integers(4, 20),
    st.integers(0, 10_000),
)
def test_lower_bound_never_exceeds_true_distance(metric, n, dim, seed):
    """Four-point soundness per metric: the per-block planar lower bound
    never exceeds the true distance to ANY valid point of the block, for
    every supermetric the registry serves (incl. a power transform)."""
    db = _space(metric, n, dim, seed=seed % 1000)
    q = _space(metric, 6, dim, seed=seed % 1000 + 1)
    idx = flat_index.build_bss(metric, db, n_pivots=min(8, n), n_pairs=8,
                               block=32, seed=seed % 13)
    lb = flat_index.bss_lower_bounds(idx, q)  # (Q, B)
    d = pairwise_np(metric, q, idx.data)  # permuted order (normalised for
    d = np.where(idx.valid[None, :], d, np.inf)  # cosine: idempotent)
    per_block_min = d.reshape(len(q), idx.n_blocks, idx.block).min(axis=2)
    assert np.all(lb <= per_block_min + 1e-4), metric


def test_non_four_point_metric_rejected():
    """Planar exclusion is unsound without the four-point property; the
    engine must refuse plain l1/linf (their power transforms are fine)."""
    db = _space("l2", 64, 6, seed=0)
    with pytest.raises(ValueError, match="four-point"):
        flat_index.build_bss("l1", db, n_pivots=4, n_pairs=4, block=32)
    flat_index.build_bss("l1^0.5", db, n_pivots=4, n_pairs=4, block=32)


# ---------------------------------------------------- distance accounting


@pytest.mark.parametrize("n", [300, 1000])  # NOT multiples of block=128
def test_exact_dists_accounting_excludes_padding(n):
    """Regression: ``exact_dists_per_query`` used ``survived * block``,
    counting the padded slots of partial blocks as real distance
    evaluations.  The corrected accounting must equal a DistanceCounter
    replay that evaluates only VALID points of surviving blocks."""
    assert n % 128 != 0
    db = _space("l2", n, 12, seed=n)
    q = _space("l2", 17, 12, seed=n + 1)
    idx = flat_index.build_bss("l2", db, n_pivots=8, n_pairs=10, block=128,
                               seed=2)
    t = safe_threshold(pairwise_np("l2", q, db), 0.05)

    # replay the oracle's exact phase through a DistanceCounter, evaluating
    # only the valid slots of each surviving block
    lb = flat_index.bss_lower_bounds(idx, q)
    alive = lb <= t
    counter = DistanceCounter("l2", len(q))
    bsz = idx.block
    for b in range(idx.n_blocks):
        qrows = np.nonzero(alive[:, b])[0]
        if len(qrows) == 0:
            continue
        blk_valid = idx.valid[b * bsz:(b + 1) * bsz]
        pts = idx.data[b * bsz:(b + 1) * bsz][blk_valid]
        counter.pairwise(qrows, q[qrows], pts)

    for results, stats in (
        flat_index.bss_query(idx, q, t),
        flat_index.bss_query_batched(idx, q, t, opts=_JNP),
    ):
        assert stats["exact_dists_per_query"] == pytest.approx(counter.mean)
        assert stats["dists_per_query"] == pytest.approx(
            idx.pivots.shape[0] + counter.mean
        )
    # the old (buggy) accounting would have been strictly larger whenever a
    # partial block survives; make sure some query DID hit the partial block
    assert alive[:, -1].any(), "test space must exercise the partial block"
    n_pad = idx.n_blocks * bsz
    assert n_pad > n  # padding exists, and is excluded from the count


def test_knn_accounting_excludes_padding():
    """kNN rounds share the padding-free accounting: with a radius that
    admits every block in round one, exactly n_valid (200) distances are
    charged — the old accounting would have charged n_pad (256)."""
    db = _space("l2", 200, 8, seed=3)  # 2 blocks of 128, second half-empty
    q = _space("l2", 5, 8, seed=4)
    idx = flat_index.build_bss("l2", db, n_pivots=6, n_pairs=8, block=128,
                               seed=3)
    _, _, stats = flat_index.bss_knn_batched(idx, q, 3, r0=1e6, opts=_JNP)
    assert stats["rounds"] == 1
    assert stats["exact_dists_per_query"] == pytest.approx(200.0)
    assert stats["dists_per_query"] == pytest.approx(206.0)  # + 6 pivots


# ------------------------------------------------------- degenerate deltas


def test_duplicate_pivots_delta_zero_stays_sound():
    """Regression for the inconsistent zero-baseline floors: with duplicate
    points forced into the pivot set (delta == 0 planes), exclusion through
    the shared MIN_DELTA floor must stay sound — bounds never exceed true
    distances and the fused engine still matches the oracle exactly."""
    rng = np.random.default_rng(7)
    # only TWO distinct locations: with 8 pivots, FFT is forced to select
    # duplicates, and keeping all 28 pivot pairs guarantees delta == 0 planes
    locs = rng.random((2, 8)).astype(np.float32)
    db = np.repeat(locs, 50, axis=0)  # 100 points, blocks end up padded too
    q = rng.random((11, 8)).astype(np.float32)
    idx = flat_index.build_bss("l2", db, n_pivots=8, n_pairs=28, block=32,
                               seed=5)
    assert (idx.deltas == 0.0).any(), "need at least one degenerate plane"
    lb = flat_index.bss_lower_bounds(idx, q)
    d = pairwise_np("l2", q, idx.data)
    d = np.where(idx.valid[None, :], d, np.inf)
    per_block_min = d.reshape(len(q), idx.n_blocks, idx.block).min(axis=2)
    assert np.all(lb <= per_block_min + 1e-4)
    assert np.all(np.isfinite(lb)), "degenerate plane produced inf/nan bound"
    t = safe_threshold(d[np.isfinite(d)], 0.05)
    oracle, _ = flat_index.bss_query(idx, q, t)
    batched, _ = flat_index.bss_query_batched(idx, q, t, opts=_JNP)
    assert batched == oracle

"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train step on CPU, asserting output shapes and no NaNs.

(The FULL configs are exercised only via the dry-run — ShapeDtypeStruct,
no allocation — per the assignment contract.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import common
from repro.configs.registry import registry
from repro.optim import make_optimizer
from repro.train.step import init_state, make_train_step

ARCHS = sorted(registry().keys())


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    bundle = registry()[arch]
    model, cfg, batch_fn = bundle.make_reduced()
    params = model.init_params(jax.random.PRNGKey(0))
    batch = batch_fn(jax.random.PRNGKey(1))
    loss_fn = common.loss_for(bundle.family, model)

    loss0 = loss_fn(params, batch)
    assert loss0.shape == ()
    assert np.isfinite(float(loss0)), f"{arch}: non-finite initial loss"

    opt = make_optimizer(getattr(cfg, "optimizer", "adamw"))
    step = jax.jit(make_train_step(loss_fn, opt, microbatches=1))
    state = init_state(params, opt)
    state, metrics = step(state, batch)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: NaN after step"
    # params actually changed
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(
            lambda p0, p1: bool(jnp.any(p0 != p1)), params, state["params"]
        ),
    )
    assert moved, f"{arch}: optimizer produced no update"


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if registry()[a].family == "lm"]
)
def test_lm_smoke_decode_shapes(arch):
    bundle = registry()[arch]
    model, cfg, batch_fn = bundle.make_reduced()
    params = model.init_params(jax.random.PRNGKey(0))
    cache = {
        k: jnp.zeros(s.shape, s.dtype)
        for k, s in model.init_cache_shapes(2, 16).items()
    }
    logits, cache = model.decode_step(
        params, cache, jnp.zeros((2, 1), jnp.int32), jnp.int32(0)
    )
    assert logits.shape == (2, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # prefill consistency: prefill then one decode == forward logits
    toks = batch_fn(jax.random.PRNGKey(1))["tokens"][:, :8]
    pl_logits, pcache = model.prefill(params, toks)
    full = model.forward(params, toks)
    np.testing.assert_allclose(
        np.asarray(pl_logits), np.asarray(full[:, -1]), rtol=2e-2, atol=2e-2
    )


def test_registry_cell_matrix():
    """40 assigned cells + the documented long_500k skips."""
    from repro.configs.registry import all_cells

    cells = all_cells()
    assert len(cells) == 40, f"expected 40 cells, got {len(cells)}"
    skips = [
        (a, c) for a, c in cells if registry()[a].cells[c].skip is not None
    ]
    skip_archs = sorted(a for a, _ in skips)
    assert skip_archs == [
        "deepseek-coder-33b",
        "kimi-k2-1t-a32b",
        "llama3.2-1b",
        "phi3.5-moe-42b-a6.6b",
    ]
    assert all(c == "long_500k" for _, c in skips)
    # gemma2 runs long_500k (local/global alternation)
    assert registry()["gemma2-9b"].cells["long_500k"].skip is None


def test_gnn_partitioned_layout_equivalence():
    """DistDGL-style dst-partitioned edges == flat edge list, bit-for-bit."""
    import numpy as np
    from repro.models.gnn import PNAConfig, PNAModel

    rng = np.random.default_rng(0)
    n_pad, s_blocks, e = 64, 8, 300
    cfg = PNAConfig(name="t", n_layers=2, d_hidden=16, d_feat=8, n_classes=3)
    m = PNAModel(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    src = rng.integers(0, n_pad, e)
    dst = rng.integers(0, n_pad, e)
    x = rng.normal(size=(n_pad, 8)).astype(np.float32)
    flat = {"x": jnp.asarray(x), "edge_src": jnp.asarray(src, jnp.int32),
            "edge_dst": jnp.asarray(dst, jnp.int32)}
    ps, pd, pv = PNAModel.partition_edges(src, dst, n_pad, s_blocks)
    part = {"x": jnp.asarray(x), "edge_src": jnp.asarray(ps),
            "edge_dst_local": jnp.asarray(pd), "edge_valid": jnp.asarray(pv)}
    np.testing.assert_allclose(
        np.asarray(m.forward(params, flat)),
        np.asarray(m.forward(params, part)), rtol=1e-5, atol=1e-5,
    )


def test_int8_kv_cache_decode_close():
    """KIVI-style int8 KV decode tracks the bf16 cache closely."""
    import dataclasses

    bundle = registry()["gemma2-9b"]
    model, cfg, batch_fn = bundle.make_reduced()
    toks = batch_fn(jax.random.PRNGKey(1))["tokens"][:, :10]
    params = model.init_params(jax.random.PRNGKey(0))

    outs = {}
    for kvdt in ("bf16", "int8"):
        m = type(model)(dataclasses.replace(cfg, kv_cache_dtype=kvdt))
        cache = {k: jnp.zeros(s.shape, s.dtype)
                 for k, s in m.init_cache_shapes(2, 16).items()}
        for i in range(8):
            logits, cache = m.decode_step(
                params, cache, toks[:, i:i + 1], jnp.int32(i))
        outs[kvdt] = logits
    rel = float(jnp.abs(outs["bf16"] - outs["int8"]).max()
                / (jnp.abs(outs["bf16"]).max() + 1e-9))
    assert rel < 0.05, rel


def test_supermetric_pruned_retrieval_beats_random():
    """Pruned scoring with the planar bound recalls far more of the true
    top-k than a random block subset of the same budget.

    The corpus is clustered around user-tower outputs — the geometry a
    *trained* two-tower model produces (items gather around user-interest
    regions), and the regime the paper's exclusion targets.  An isotropic
    random corpus in 256-d has no structure for ANY exact method to exploit
    (the paper's own intrinsic-dimensionality caveat), which is why the
    earlier formulation of this test was flaky."""
    import numpy as np
    from repro.core import flat_index

    bundle = registry()["two-tower-retrieval"]
    model, cfg, _ = bundle.make_reduced()
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n = 128 * 64
    centre_ids = rng.integers(0, cfg.vocab, size=(20, cfg.n_user_fields))
    centres = np.asarray(model.user_embed(params, centre_ids), np.float32)
    e_dim = centres.shape[1]
    cand = centres[rng.integers(0, 20, size=n)] + (
        0.3 / np.sqrt(e_dim)
    ) * rng.normal(size=(n, e_dim)).astype(np.float32)
    cand /= np.linalg.norm(cand, axis=1, keepdims=True)
    idx = flat_index.build_bss("l2", cand, n_pivots=8, n_pairs=16,
                               block=128, seed=1)
    nq = 8
    user_ids = rng.integers(0, cfg.vocab, size=(nq, cfg.n_user_fields))
    batch = {
        "user_ids": jnp.asarray(user_ids),
        "candidates": jnp.asarray(idx.data),
        "pivots": jnp.asarray(idx.pivots),
        "pair_idx": jnp.asarray(idx.pairs),
        "deltas": jnp.asarray(idx.deltas),
        "boxes": jnp.asarray(idx.boxes),
    }
    budget = 24
    scores, rows = model.forward_retrieval_pruned(
        params, batch, block=128, budget_blocks=budget)
    dense = model.forward(
        params, {"user_ids": jnp.asarray(user_ids),
                 "candidates": jnp.asarray(idx.data)})
    got = 0
    for q in range(nq):
        want = set(np.argsort(-np.asarray(dense[q]))[:10].tolist())
        r, s = np.asarray(rows[q]), np.asarray(scores[q])
        got += len(want & set(r[np.argsort(-s)[:10]].tolist()))
    recall = got / (nq * 10)
    assert recall > 1.5 * (budget / 64), (recall, budget / 64)

"""Tests for repro.analysis — the AST lint (layer 1), the jaxpr audit
internals (layer 2), and the CLI self-check at HEAD.

The lint fixtures are tiny synthetic repos written into tmp_path: each
violating fixture trips EXACTLY its one rule at a known line, and the
does-not-flag suite pins down the false-positive boundary (xp-generic
code, constant folding, strings in non-call positions).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.analysis.lint import lint_repo
from repro.analysis.rules import RULES, Allowlist, load_allowlist

REPO_ROOT = Path(__file__).resolve().parents[1]

EMPTY = Allowlist([])


def mini_repo(tmp_path: Path, files: dict[str, str]) -> Path:
    """Write a throwaway repo tree; keys are repo-relative paths."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return tmp_path


def run_lint(tmp_path: Path, files: dict[str, str]):
    return lint_repo(mini_repo(tmp_path, files), EMPTY)


def _subprocess_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


# ---------------------------------------------------------------------------
# rule registry basics
# ---------------------------------------------------------------------------


def test_rule_registry_complete():
    assert set(RULES) == {"R1", "R2", "R3", "R4", "R5", "R6"}
    for rid, rule in RULES.items():
        assert rule.summary, rid


def test_allowlist_rejects_unknown_rule():
    with pytest.raises(ValueError, match="unknown rule"):
        Allowlist([("R9", "src/*")])


def test_checked_in_allowlist_loads():
    al = load_allowlist()
    assert al.allows("R3", "src/repro/core/npdist.py")
    assert not al.allows("R1", "src/repro/core/npdist.py")


# ---------------------------------------------------------------------------
# R1: wall-clock timing
# ---------------------------------------------------------------------------


def test_r1_flags_time_time(tmp_path):
    v = run_lint(tmp_path, {"src/repro/x.py": """\
        import time

        def f():
            return time.time()
        """})
    assert [(x.rule, x.path, x.line) for x in v] == [
        ("R1", "src/repro/x.py", 4)
    ]


def test_r1_flags_from_import_alias(tmp_path):
    v = run_lint(tmp_path, {"src/repro/x.py": """\
        from time import time as wall

        def f():
            return wall()
        """})
    assert [x.rule for x in v] == ["R1"]
    assert v[0].line == 4


def test_r1_ignores_perf_counter(tmp_path):
    v = run_lint(tmp_path, {"src/repro/x.py": """\
        import time

        def f():
            return time.perf_counter()
        """})
    assert v == []


def test_r1_inline_disable(tmp_path):
    v = run_lint(tmp_path, {"src/repro/x.py": """\
        import time

        def f():
            return time.time()  # lint: disable=R1
        """})
    assert v == []


def test_reverting_the_timing_fix_would_fail_lint(tmp_path):
    """Acceptance check: put the pre-fix ``time.time()`` pattern back into
    a copy of train/loop.py and the lint must fire on it."""
    src = (REPO_ROOT / "src/repro/train/loop.py").read_text()
    assert "time.time()" not in src  # the fix is in place at HEAD
    reverted = src.replace(
        "from repro.serve.queue import now", "import time"
    ).replace("now()", "time.time()")
    assert "time.time()" in reverted
    v = run_lint(tmp_path, {"src/repro/train/loop.py": reverted})
    assert any(x.rule == "R1" for x in v)


# ---------------------------------------------------------------------------
# R2: host sync inside jit-reachable functions
# ---------------------------------------------------------------------------


def test_r2_flags_numpy_in_jit(tmp_path):
    v = run_lint(tmp_path, {"src/repro/x.py": """\
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.sum(x)
        """})
    assert [(x.rule, x.line) for x in v] == [("R2", 6)]


def test_r2_requires_jit_reachability(tmp_path):
    # same numpy call, no jit anywhere -> host code, fine
    v = run_lint(tmp_path, {"src/repro/x.py": """\
        import numpy as np

        def f(x):
            return np.sum(x)
        """})
    assert v == []


def test_r2_follows_call_graph(tmp_path):
    v = run_lint(tmp_path, {"src/repro/x.py": """\
        import jax

        def helper(x):
            return float(x)

        @jax.jit
        def f(x):
            return helper(x)
        """})
    assert [(x.rule, x.line) for x in v] == [("R2", 4)]


def test_r2_follows_cross_module_import(tmp_path):
    v = run_lint(tmp_path, {
        "src/repro/a.py": """\
            import numpy as np

            def helper(x):
                return np.asarray(x)
            """,
        "src/repro/b.py": """\
            import jax
            from repro.a import helper

            @jax.jit
            def f(x):
                return helper(x)
            """,
    })
    assert [(x.rule, x.path, x.line) for x in v] == [
        ("R2", "src/repro/a.py", 4)
    ]


def test_r2_flags_item_and_dynamic_jit_arg(tmp_path):
    v = run_lint(tmp_path, {"src/repro/x.py": """\
        import jax

        def local(x):
            return x.item()

        g = jax.jit(local)
        """})
    assert [(x.rule, x.line) for x in v] == [("R2", 4)]


def test_r2_follows_chained_assign_aliases(tmp_path):
    # two hops of module-level aliasing before the jit call — the old
    # resolver stopped after one hop and let this escape
    v = run_lint(tmp_path, {"src/repro/x.py": """\
        import jax
        import numpy as np

        def np_user(x):
            return np.sum(x)

        a = np_user
        b = a
        g = jax.jit(b)
        """})
    assert [(x.rule, x.line) for x in v] == [("R2", 5)]


def test_r2_follows_attribute_chained_reexport(tmp_path):
    # `use = helper.np_user` at module level, then jit(use): the root must
    # resolve through the attribute chain into the defining module
    v = run_lint(tmp_path, {
        "src/repro/helper.py": """\
            import numpy as np

            def np_user(x):
                return np.asarray(x)
            """,
        "src/repro/x.py": """\
            import jax
            import repro.helper as helper

            use = helper.np_user
            g = jax.jit(use)
            """,
    })
    assert [(x.rule, x.path, x.line) for x in v] == [
        ("R2", "src/repro/helper.py", 4)
    ]


def test_r2_follows_cross_module_reexport_chain(tmp_path):
    # a defines the offender, b re-exports it under a new name, c imports
    # b's re-export and jits a caller — three modules, two import hops
    v = run_lint(tmp_path, {
        "src/repro/a.py": """\
            import numpy as np

            def np_user(x):
                return np.asarray(x)
            """,
        "src/repro/b.py": """\
            from repro.a import np_user as mid
            """,
        "src/repro/c.py": """\
            import jax
            from repro.b import mid

            @jax.jit
            def f(x):
                return mid(x)
            """,
    })
    assert [(x.rule, x.path, x.line) for x in v] == [
        ("R2", "src/repro/a.py", 4)
    ]


def test_r2_follows_assigned_module_alias_attribute_call(tmp_path):
    # `h = helper` then `h.np_user(x)` inside a jit body: the attribute
    # call's base resolves through the assign chain to the module alias
    v = run_lint(tmp_path, {
        "src/repro/helper.py": """\
            import numpy as np

            def np_user(x):
                return np.asarray(x)
            """,
        "src/repro/x.py": """\
            import jax
            import repro.helper as helper

            h = helper

            @jax.jit
            def f(x):
                return h.np_user(x)
            """,
    })
    assert [(x.rule, x.path, x.line) for x in v] == [
        ("R2", "src/repro/helper.py", 4)
    ]


def test_r2_alias_cycle_terminates(tmp_path):
    # a = b; b = a at module level must not hang resolution (cycle guard)
    v = run_lint(tmp_path, {"src/repro/x.py": """\
        import jax

        a = b
        b = a
        g = jax.jit(a)
        """})
    assert v == []


def test_r2_constant_float_is_fine(tmp_path):
    v = run_lint(tmp_path, {"src/repro/x.py": """\
        import jax

        @jax.jit
        def f(x):
            return x + float(3)
        """})
    assert v == []


# ---------------------------------------------------------------------------
# R3: float64 leaks
# ---------------------------------------------------------------------------


def test_r3_flags_attribute_and_string(tmp_path):
    v = run_lint(tmp_path, {"src/repro/x.py": """\
        import jax.numpy as jnp

        def f(x):
            return x.astype(jnp.float64)

        def g(x):
            return x.astype("float64")
        """})
    assert [(x.rule, x.line) for x in v] == [("R3", 4), ("R3", 7)]


def test_r3_flags_x64_flag(tmp_path):
    v = run_lint(tmp_path, {"src/repro/x.py": """\
        import jax

        jax.config.update("jax_enable_x64", True)
        """})
    assert all(x.rule == "R3" for x in v) and v


def test_r3_ignores_string_outside_calls(tmp_path):
    # docs/enumerations mentioning the dtype are not leaks
    v = run_lint(tmp_path, {"src/repro/x.py": """\
        FORBIDDEN_DTYPES = ["float64", "complex128"]
        """})
    assert v == []


def test_r3_allowlist_glob(tmp_path):
    root = mini_repo(tmp_path, {"src/repro/oracle.py": """\
        import numpy as np

        def f(x):
            return np.asarray(x, np.float64)
        """})
    assert [x.rule for x in lint_repo(root, EMPTY)] == ["R3"]
    al = Allowlist([("R3", "src/repro/oracle.py")])
    assert lint_repo(root, al) == []


# ---------------------------------------------------------------------------
# R4: raw tile literals in kernels/
# ---------------------------------------------------------------------------


def test_r4_flags_literal_tile_default(tmp_path):
    v = run_lint(tmp_path, {"src/repro/kernels/k.py": """\
        def kernel_call(x, *, bm: int = 64, bn: int = 128):
            return x
        """})
    assert [x.rule for x in v] == ["R4", "R4"]
    assert {x.line for x in v} == {1}


def test_r4_flags_tile_constant_and_keyword(tmp_path):
    v = run_lint(tmp_path, {"src/repro/kernels/k.py": """\
        TILE_FOO = 256

        def f(x):
            return g(x, block=64)
        """})
    assert [(x.rule, x.line) for x in v] == [("R4", 1), ("R4", 4)]


def test_r4_only_applies_to_kernels(tmp_path):
    v = run_lint(tmp_path, {"src/repro/core/k.py": """\
        def f(x, bm=64):
            return x
        """})
    assert v == []


def test_r4_tiles_module_is_the_one_home(tmp_path):
    v = run_lint(tmp_path, {"src/repro/kernels/tiles.py": """\
        TILE_BM = 64
        """})
    assert v == []


# ---------------------------------------------------------------------------
# R5: assert-as-validation
# ---------------------------------------------------------------------------


def test_r5_flags_assert_in_src(tmp_path):
    v = run_lint(tmp_path, {"src/repro/x.py": """\
        def f(x):
            assert x > 0, "bad"
            return x
        """})
    assert [(x.rule, x.line) for x in v] == [("R5", 2)]


def test_r5_allows_assert_in_tests(tmp_path):
    v = run_lint(tmp_path, {"tests/test_x.py": """\
        def test_f():
            assert 1 + 1 == 2
        """})
    assert v == []


# ---------------------------------------------------------------------------
# R6: unregistered metric names
# ---------------------------------------------------------------------------


_R6_SCHEMA = """\
    METRIC_NAMES = {
        "engine/queries",
        "serve/cache_hits",
    }
    """


def test_r6_flags_unregistered_metric_name(tmp_path):
    v = run_lint(tmp_path, {
        "src/repro/obs/schema.py": _R6_SCHEMA,
        "src/repro/x.py": """\
        def fold(reg):
            reg.counter("engine/queries").inc()
            reg.gauge("engine/typo_rate").set(1.0)
            reg.histogram("serve/cache_hits").observe(2)
        """,
    })
    assert [(x.rule, x.line) for x in v] == [("R6", 3)]
    assert "engine/typo_rate" in v[0].message


def test_r6_skips_non_literal_and_non_src(tmp_path):
    v = run_lint(tmp_path, {
        "src/repro/obs/schema.py": _R6_SCHEMA,
        # dynamic names can't be checked statically; tests/ are exempt
        "src/repro/y.py": """\
        def fold(reg, name):
            reg.counter(name).inc()
        """,
        "tests/test_y.py": """\
        def test_fold(reg):
            reg.counter("made/up_name").inc()
        """,
    })
    assert v == []


def test_r6_disabled_without_schema_file(tmp_path):
    v = run_lint(tmp_path, {"src/repro/z.py": """\
        def fold(reg):
            reg.counter("any/name").inc()
        """})
    assert v == []


def test_r6_honors_inline_disable(tmp_path):
    v = run_lint(tmp_path, {
        "src/repro/obs/schema.py": _R6_SCHEMA,
        "src/repro/w.py": """\
        def fold(reg):
            reg.counter("scratch/dev_only").inc()  # lint: disable=R6
        """,
    })
    assert v == []


def test_r6_head_schema_covers_every_registered_name():
    # the real repo's METRIC_NAMES must cover every literal registration
    # in src/ — this is what the CI gate enforces
    from repro.analysis.lint import _Linter

    linter = _Linter(REPO_ROOT, EMPTY)
    linter.load(dirs=("src",))
    names = linter._metric_names()
    assert names is not None and "engine/queries" in names
    for fi in linter.files.values():
        linter.check_r6(fi)
    assert [v for v in linter.violations if v.rule == "R6"] == []


# ---------------------------------------------------------------------------
# the converted validations survive python -O (what R5 protects)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("snippet,match", [
    (
        "from repro.kernels.pairwise_dist import pairwise_l2_kernel_call\n"
        "import numpy as np\n"
        "pairwise_l2_kernel_call(np.zeros((4, 8), np.float32),"
        " np.zeros((4, 7), np.float32))",
        "feature dimension",
    ),
    (
        "from repro.core.tree import _make_selector\n"
        "_make_selector('zzz_random_fixed')",
        "unknown tree variant family",
    ),
])
def test_validation_survives_dash_O(snippet, match):
    code = (
        "import pytest\n"
        f"with pytest.raises(ValueError, match={match!r}):\n"
        + textwrap.indent(snippet, "    ")
        + "\nprint('OK')\n"
    )
    out = subprocess.run(
        [sys.executable, "-O", "-c", code],
        capture_output=True, text=True, env=_subprocess_env(),
        cwd=REPO_ROOT, timeout=300,
    )
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# layer 2 internals: taint / callback / f64 walkers
# ---------------------------------------------------------------------------


def test_taint_propagates_through_cast():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import _taint_jaxpr

    closed = jax.make_jaxpr(
        lambda x: x.astype(jnp.float32) * 2.0
    )(jnp.ones((4,), jnp.bfloat16))
    out = _taint_jaxpr(closed.jaxpr, [True], consts=closed.consts)
    assert out == [True]


def test_taint_respects_independence():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import _taint_jaxpr

    # second output never touches the bf16 input — must stay clean
    closed = jax.make_jaxpr(
        lambda x16, m: (x16.sum(), m & (m | True))
    )(jnp.ones((4,), jnp.bfloat16), jnp.ones((4,), bool))
    out = _taint_jaxpr(closed.jaxpr, [True, False], consts=closed.consts)
    assert out == [True, False]


def test_taint_through_scan_carry():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import _taint_jaxpr

    def f(x16, ys):
        def body(c, y):
            return c + y, c
        return jax.lax.scan(body, x16.astype(jnp.float32).sum(), ys)

    closed = jax.make_jaxpr(f)(
        jnp.ones((4,), jnp.bfloat16), jnp.ones((3,), jnp.float32)
    )
    out = _taint_jaxpr(closed.jaxpr, [True, False], consts=closed.consts)
    # both the final carry and the stacked outputs flow from the bf16 seed
    assert out == [True, True]


def test_callback_walker_catches_pure_callback():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import _all_jaxprs

    def f(x):
        return jax.pure_callback(
            lambda a: np.asarray(a), jax.ShapeDtypeStruct((4,), np.float32),
            x,
        )

    closed = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float32))
    prims = {
        eqn.primitive.name
        for j in _all_jaxprs(closed.jaxpr)
        for eqn in j.eqns
    }
    assert "pure_callback" in prims


def test_bf16_detector():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import _Capture, _has_bf16

    c16, s16 = jax.make_jaxpr(lambda x: x * 2, return_shape=True)(
        jnp.ones((4,), jnp.bfloat16)
    )
    c32, s32 = jax.make_jaxpr(lambda x: x * 2, return_shape=True)(
        jnp.ones((4,), jnp.float32)
    )
    assert _has_bf16(_Capture("f", "cell", c16, s16))
    assert not _has_bf16(_Capture("f", "cell", c32, s32))


# ---------------------------------------------------------------------------
# self-check: the repo at HEAD is clean
# ---------------------------------------------------------------------------


def test_repo_lint_is_clean_at_head():
    assert lint_repo(REPO_ROOT, load_allowlist()) == []


def test_cli_lint_only_exits_zero():
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--lint-only",
         "--root", str(REPO_ROOT)],
        capture_output=True, text=True, env=_subprocess_env(),
        cwd=REPO_ROOT, timeout=300,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "lint: 0 violation(s)" in out.stdout


def test_smoke_audit_is_clean_at_head():
    """The l2 column of the jaxpr audit plus the compile-cache replay —
    the same gate `python -m repro.analysis` (default mode) applies."""
    from repro.analysis.jaxpr_audit import audit_compile_cache, run_audit

    problems = run_audit(full=False)
    assert problems == [], [p.format() for p in problems]
    cache_problems, info = audit_compile_cache()
    assert cache_problems == [], [p.format() for p in cache_problems]
    if not info.get("skipped"):
        assert info["growth"], info

"""Degradable hypothesis facade for the property tests.

When ``hypothesis`` is installed this module re-exports the real ``given``,
``settings`` and ``strategies`` untouched.  When it is absent (the minimal
CI/container image), ``@given`` degrades to a seeded
``pytest.mark.parametrize`` over ``FALLBACK_EXAMPLES`` deterministic draws
from lightweight stand-in strategies — so the modules still *collect and
run* everywhere, just with fixed examples instead of adaptive search.

The fallback implements only what the test-suite uses: ``st.integers``,
``st.floats``, ``st.sampled_from``; ``settings`` becomes a no-op decorator
(``max_examples``/``deadline`` only matter to the real engine).
"""

from __future__ import annotations

import os

FALLBACK_EXAMPLES = int(os.environ.get("REPRO_FALLBACK_EXAMPLES", "10"))

try:  # pragma: no cover - exercised implicitly when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True

except ModuleNotFoundError:
    import numpy as np
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw(rng) callable; only what our @given signatures need."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value))
            )

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[rng.integers(len(elements))])

    st = _Strategies()

    def settings(**_kwargs):
        """max_examples/deadline are meaningless without the real engine."""

        def deco(fn):
            return fn

        return deco

    def given(*strategies):
        """Seeded parametrize: deterministic draws, stable across runs
        (seeded by the wrapped function's name, so every property test gets
        its own fixed example set)."""

        def deco(fn):
            # zlib.crc32 (not hash()) so draws survive PYTHONHASHSEED
            import zlib

            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            examples = [
                tuple(s.draw(rng) for s in strategies)
                for _ in range(FALLBACK_EXAMPLES)
            ]
            argnames = fn.__code__.co_varnames[: len(strategies)]
            return pytest.mark.parametrize(",".join(argnames), examples)(fn)

        return deco

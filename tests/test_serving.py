"""Retrieval serving: exactness and pruning of the supermetric server."""

import numpy as np
import pytest

from repro.core.npdist import pairwise_np
from repro.serve.retrieval import RetrievalServer, score_to_distance


@pytest.fixture(scope="module")
def server_and_corpus():
    rng = np.random.default_rng(0)
    # clustered corpus (normalised rows -> cosine-equivalent geometry)
    centres = rng.normal(size=(20, 32))
    corpus = (centres[rng.integers(0, 20, 5000)]
              + 0.15 * rng.normal(size=(5000, 32)))
    server = RetrievalServer(corpus, n_pivots=12, n_pairs=16, block=64)
    return server, corpus


def test_top_k_exact(server_and_corpus):
    server, _ = server_and_corpus
    rng = np.random.default_rng(1)
    q = rng.normal(size=(16, 32))
    top = server.top_k(q, k=5)
    qn = q / np.linalg.norm(q, axis=1, keepdims=True)
    d = pairwise_np("l2", qn, server.corpus)
    for i in range(len(q)):
        want = set(np.argsort(d[i])[:5].tolist())
        assert set(np.asarray(top[i]).tolist()) == want


def test_range_query_exact_and_prunes(server_and_corpus):
    from repro.serve.retrieval import ServeStats

    server, _ = server_and_corpus
    server.stats = ServeStats()  # module-scoped fixture: isolate the tally
    rng = np.random.default_rng(2)
    q = rng.normal(size=(32, 32))
    min_score = 0.8
    hits = server.range_query(q, min_score)
    qn = q / np.linalg.norm(q, axis=1, keepdims=True)
    d = pairwise_np("l2", qn, server.corpus)
    t = score_to_distance(np.asarray(min_score))
    for i in range(len(q)):
        want = set(np.nonzero(d[i] <= t)[0].tolist())
        assert set(hits[i]) == want
    assert server.stats.saving > 0.3, "expected >30% distance pruning"


@pytest.mark.parametrize("metric", ["jsd", "triangular"])
def test_probability_corpus_server_exact(metric):
    """Metric-parametrised serving: topic-histogram corpus under the
    probability-space supermetrics — exact top-k and range-by-distance."""
    from repro.data import metricsets

    corpus = metricsets.topics_surrogate(3000, dim=32, seed=5)
    queries = metricsets.topics_surrogate(520, dim=32, seed=6)[:20]
    server = RetrievalServer(corpus, metric=metric, n_pivots=12, n_pairs=16,
                             block=64)
    top = server.top_k(queries, k=5)
    d = pairwise_np(metric, queries, corpus)
    for i in range(len(queries)):
        want = set(np.argsort(d[i])[:5].tolist())
        assert set(np.asarray(top[i]).tolist()) == want, i
    t = float(np.quantile(d, 0.002))
    hits = server.range_by_distance(queries, t)
    for i in range(len(queries)):
        want = set(np.nonzero(d[i] <= t)[0].tolist())
        got = set(hits[i])
        # float32 engine vs float64 truth may disagree only AT the boundary
        assert got - want == set() or np.allclose(
            d[i][sorted(got - want)], t, rtol=1e-5
        )
        missing = want - got
        assert not missing or np.allclose(d[i][sorted(missing)], t, rtol=1e-5)
    # score-based API is the cosine specialisation only
    with pytest.raises(ValueError, match="cosine"):
        server.range_query(queries, 0.9)
    assert server.stats.n_queries == 40


def test_cosine_server_serves_l2_on_sphere():
    """The default (cosine) server's engine distance is l2 on the unit
    sphere — bit-compatible with dot-product scoring."""
    rng = np.random.default_rng(9)
    corpus = rng.normal(size=(2000, 24))
    server = RetrievalServer(corpus, n_pivots=10, n_pairs=12, block=64)
    assert server.metric == "cosine"
    assert server.index.metric_name == "cosine"
    # the index data is the normalised corpus
    np.testing.assert_allclose(
        np.linalg.norm(server.index.data[server.index.valid], axis=1),
        1.0, rtol=1e-5,
    )


def test_score_distance_duality():
    s = np.linspace(-1, 1, 101)
    d = score_to_distance(s)
    # monotone decreasing: higher score == smaller distance
    assert np.all(np.diff(d) <= 1e-9)
    np.testing.assert_allclose(d[-1], 0.0, atol=1e-6)
    np.testing.assert_allclose(d[0], 2.0, atol=1e-6)


def test_forest_top_k_raises_not_implemented():
    """Regression for the serving contract: kNN on a forest server raises
    NotImplementedError whose message points at the BSS backend and the
    ROADMAP item — the same message the async front raises."""
    rng = np.random.default_rng(3)
    corpus = rng.normal(size=(400, 16))
    server = RetrievalServer(corpus, metric="l2", index="forest", seed=1)
    with pytest.raises(NotImplementedError, match="index='bss'"):
        server.top_k(rng.normal(size=(2, 16)), k=3)
    with pytest.raises(NotImplementedError, match="ROADMAP"):
        server.top_k(rng.normal(size=(2, 16)), k=3)
    # range serving on the same server still works
    d = pairwise_np("l2", rng.normal(size=(2, 16)).astype(np.float32),
                    server.corpus)
    hits = server.range_by_distance(rng.normal(size=(2, 16)),
                                    float(np.quantile(d, 0.01)))
    assert len(hits) == 2


def test_server_async_front_matches_sync_paths():
    """RetrievalServer.async_front: per-request futures over the same
    index; results match the server's own batched calls (cosine BSS server
    and a forest server with the cosine prep)."""
    rng = np.random.default_rng(4)
    corpus = rng.normal(size=(1200, 16))
    server = RetrievalServer(corpus, n_pivots=10, n_pairs=12, block=64)
    q = rng.normal(size=(12, 16))
    t = float(score_to_distance(np.asarray(0.85)))
    sync_hits = server.range_by_distance(q, t)
    sync_top = server.top_k(q, k=4)
    with server.async_front(max_delay_s=0.02) as front:
        rres = [f.result(timeout=120)
                for f in front.submit_many(q, "range", t=t)]
        kres = [f.result(timeout=120)
                for f in front.submit_many(q, "knn", k=4)]
    for i in range(len(q)):
        assert sorted(rres[i].hits) == sorted(sync_hits[i]), i
        assert set(kres[i].indices.tolist()) == set(
            np.asarray(sync_top[i]).tolist()), i

    f_server = RetrievalServer(corpus[:600], index="forest", seed=2,
                               n_pivots=10)
    f_sync = f_server.range_by_distance(q, t)
    with f_server.async_front(max_delay_s=0.02) as front:
        assert front.prep is not None  # cosine forest: queries need the map
        fres = [f.result(timeout=120)
                for f in front.submit_many(q, "range", t=t)]
    for i in range(len(q)):
        assert sorted(fres[i].hits) == sorted(f_sync[i]), i

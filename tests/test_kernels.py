"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("m,n,k", [(128, 128, 16), (200, 310, 48), (1, 7, 3),
                                   (130, 128, 112), (64, 500, 20), (256, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("squared", [False, True])
def test_pairwise_l2_sweep(m, n, k, dtype, squared):
    rng = np.random.default_rng(m * 7 + n * 3 + k)
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    y = jnp.asarray(rng.normal(size=(n, k)), dtype)
    got = ops.pairwise_l2(x, y, squared=squared, interpret=True)
    want = ref.pairwise_l2_ref(x, y, squared=squared)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("m,n,k", [(256, 384, 32), (100, 200, 64)])
def test_masked_pairwise_sweep(m, n, k):
    rng = np.random.default_rng(5)
    bm = bn = 128
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    tm = jnp.asarray(
        rng.integers(0, 2, size=(math.ceil(m / bm), math.ceil(n / bn))), jnp.int32
    )
    got = ops.masked_pairwise_l2(x, y, tm, bm=bm, bn=bn, interpret=True)
    want = ref.masked_pairwise_l2_ref(x, y, tm, bm, bn)
    g, w = np.asarray(got), np.asarray(want)
    assert np.array_equal(np.isinf(g), np.isinf(w))
    fin = ~np.isinf(w)
    np.testing.assert_allclose(g[fin], w[fin], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("q,m,b", [(150, 12, 70), (128, 24, 128), (3, 4, 5),
                                   (257, 32, 130)])
def test_planar_lower_bound_sweep(q, m, b):
    rng = np.random.default_rng(q + m + b)
    d1 = jnp.asarray(np.abs(rng.normal(size=(q, m))) + 1.0, jnp.float32)
    delta = jnp.asarray(np.abs(rng.normal(size=(m,))) + 0.5, jnp.float32)
    d2 = jnp.asarray(np.abs(d1 + rng.normal(size=(q, m)) * 0.2), jnp.float32)
    lo = rng.normal(size=(b, m, 2))
    hi = lo + np.abs(rng.normal(size=(b, m, 2)))
    boxes = jnp.asarray(
        np.stack([lo[..., 0], hi[..., 0], lo[..., 1], hi[..., 1]], -1), jnp.float32
    )
    got = ops.planar_lower_bound(d1, d2, delta, boxes, interpret=True)
    want = ref.planar_lower_bound_ref(d1, d2, delta, boxes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_bss_query_fused_end_to_end():
    """Fused kernel path returns exactly the dense-reference hit set."""
    from repro.core import flat_index
    from repro.core.npdist import pairwise_np

    rng = np.random.default_rng(11)
    db = rng.random((512, 24)).astype(np.float32)
    q = rng.random((64, 24)).astype(np.float32)
    idx = flat_index.build_bss("l2", db, n_pivots=8, n_pairs=12, block=128, seed=2)
    t = 0.45
    dist, tile_mask = ops.bss_query_fused(
        jnp.asarray(q),
        jnp.asarray(idx.pivots),
        jnp.asarray(idx.pairs),
        jnp.asarray(idx.deltas),
        jnp.asarray(idx.boxes),
        jnp.asarray(idx.data),
        t,
        block=idx.block,
        bq=32,
        interpret=True,
    )
    d = np.asarray(dist)
    truth = pairwise_np("l2", q, idx.data)
    truth = np.where(idx.valid[None, :], truth, np.inf)
    # exactness: every true hit must be present with a finite distance
    hits_true = truth <= t
    assert np.all(np.isfinite(d[hits_true])), "pruning dropped a true hit"
    np.testing.assert_allclose(d[hits_true], truth[hits_true], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,n,k", [(64, 64, 16), (100, 70, 48), (3, 130, 24)])
def test_pairwise_jsd_sweep(m, n, k):
    rng = np.random.default_rng(m + n + k)
    x = rng.gamma(1.0, size=(m, k)).astype(np.float32)
    x /= x.sum(axis=1, keepdims=True)
    y = rng.gamma(1.0, size=(n, k)).astype(np.float32)
    y /= y.sum(axis=1, keepdims=True)
    got = ops.pairwise_jsd(jnp.asarray(x), jnp.asarray(y), interpret=True)
    want = ref.pairwise_jsd_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    # cross-check against the metric registry implementation
    from repro.core.npdist import pairwise_np

    np.testing.assert_allclose(np.asarray(got), pairwise_np("jsd", x, y),
                               rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("m,n,k", [(64, 64, 16), (100, 200, 64), (3, 130, 24),
                                   (128, 128, 112)])
def test_pairwise_tri_sweep(m, n, k):
    rng = np.random.default_rng(m * 3 + n + k)
    x = rng.gamma(1.0, size=(m, k)).astype(np.float32)
    x /= x.sum(axis=1, keepdims=True)
    y = rng.gamma(1.0, size=(n, k)).astype(np.float32)
    y /= y.sum(axis=1, keepdims=True)
    got = ops.pairwise_tri(jnp.asarray(x), jnp.asarray(y), interpret=True)
    want = ref.pairwise_tri_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    from repro.core.npdist import pairwise_np

    np.testing.assert_allclose(np.asarray(got), pairwise_np("triangular", x, y),
                               rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("metric", ops.KERNEL_METRICS)
@pytest.mark.parametrize("m,n,k", [(256, 384, 32), (100, 200, 48)])
def test_masked_pairwise_metric_family_sweep(metric, m, n, k):
    """The metric-dispatched masked family: excluded tiles are +inf, live
    tiles match the unmasked reference, for every metric with a kernel."""
    rng = np.random.default_rng(7 + m)
    bm = bn = 128
    x = rng.gamma(1.0, size=(m, k)).astype(np.float32)
    y = rng.gamma(1.0, size=(n, k)).astype(np.float32)
    if metric in ("jsd", "triangular"):
        x /= x.sum(axis=1, keepdims=True)
        y /= y.sum(axis=1, keepdims=True)
    tm = jnp.asarray(
        rng.integers(0, 2, size=(math.ceil(m / bm), math.ceil(n / bn))),
        jnp.int32,
    )
    got = ops.masked_pairwise_metric(
        metric, jnp.asarray(x), jnp.asarray(y), tm, bm=bm, bn=bn,
        interpret=True,
    )
    dense = {
        "l2": ref.pairwise_l2_ref,
        "jsd": ref.pairwise_jsd_ref,
        "triangular": ref.pairwise_tri_ref,
    }[metric](jnp.asarray(x), jnp.asarray(y))
    want = ref.masked_pairwise_metric_ref(dense, tm, bm, bn)
    g, w = np.asarray(got), np.asarray(want)
    assert np.array_equal(np.isinf(g), np.isinf(w))
    fin = ~np.isinf(w)
    np.testing.assert_allclose(g[fin], w[fin], rtol=1e-5, atol=1e-5)


def test_quantile_split_tree_exact():
    """Controlled unbalancing (paper §6 future work) stays exact."""
    from repro.core import lrt, tree
    from repro.data import metricsets

    data = metricsets.colors_surrogate(1200, dim=24, seed=9)
    db, q = metricsets.split_queries(data, 0.05, seed=2)
    q = q[:15]
    t = metricsets.calibrate_threshold("l2", db, 5e-3)
    truth = tree.exhaustive_search("l2", db, q, t)
    for quant in (0.3, 0.7):
        tr = lrt.build_monotone_tree("lrt", "far", "l2", db, seed=5,
                                     split_quantile=quant)
        res, _ = lrt.range_search_monotone(tr, q, t, "hilbert")
        assert all(sorted(a) == sorted(b) for a, b in zip(res, truth)), quant


def test_tile_constants_env_override():
    """REPRO_TILE_* env vars reshape the kernel tiling without a rebuild
    (the ROADMAP autotuning knob).  ``tiles`` is import-light, so the
    subprocess check is cheap; the in-process defaults are asserted too."""
    import os
    import subprocess
    import sys

    from repro.kernels import pairwise_dist, planar_exclusion, tiles

    assert pairwise_dist.DEFAULT_BM == tiles.TILE_BQ
    assert pairwise_dist.DEFAULT_BN == tiles.TILE_BLOCK
    assert planar_exclusion.DEFAULT_BQ == tiles.TILE_BQ
    assert planar_exclusion.DEFAULT_BB == tiles.TILE_BLOCK

    env = dict(os.environ)
    env.update({"REPRO_TILE_BQ": "64", "REPRO_TILE_BLOCK": "256",
                "REPRO_TILE_KCHUNK": "32"})
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.kernels import tiles; "
         "print(tiles.TILE_BQ, tiles.TILE_BLOCK, tiles.TILE_KCHUNK)"],
        env=env, capture_output=True, text=True, check=True,
    )
    assert out.stdout.split() == ["64", "256", "32"]

    env["REPRO_TILE_BQ"] = "not-a-number"
    bad = subprocess.run(
        [sys.executable, "-c", "import repro.kernels.tiles"],
        env=env, capture_output=True, text=True,
    )
    assert bad.returncode != 0 and "REPRO_TILE_BQ" in bad.stderr

"""Shared subprocess harness for tests that need a simulated multi-device
XLA host platform.

``--xla_force_host_platform_device_count`` must be set before jax
initialises, and the main pytest process must keep its launch-default
device view (smoke tests expect a single device, per the dry-run
contract) — so every multi-device scenario runs as ``python -c`` in a
subprocess whose ``XLA_FLAGS`` THIS helper controls.  Scripts are
prefixed with a probe that prints a sentinel and exits cleanly when the
requested device count is unavailable (e.g. a non-CPU default platform
ignores the forcing flag); the helper turns the sentinel into
``pytest.skip``, so the tests degrade cleanly everywhere.
"""

from __future__ import annotations

import subprocess
import sys
import textwrap

import pytest

from repro.launch.simdevices import simulated_device_env

_SENTINEL = "MULTIDEVICE_UNAVAILABLE"


def preamble(n_devices: int) -> str:
    """Script prefix: src on the path, jax imported, device-count probe."""
    return textwrap.dedent(f"""\
        import sys
        sys.path.insert(0, "src")
        import jax
        if jax.local_device_count() < {n_devices}:
            print("{_SENTINEL}", jax.local_device_count())
            raise SystemExit(0)
    """)


def run_simulated_mesh(
    script: str, n_devices: int, *argv: str, timeout: int = 600
) -> subprocess.CompletedProcess:
    """Run ``script`` in a subprocess under ``XLA_FLAGS`` forcing
    ``n_devices`` simulated host devices (env assembly shared with the
    sharded benchmark — see ``repro.launch.simdevices``).  Skips the
    calling test when the devices can't be simulated; otherwise returns
    the completed process for the caller's own assertions."""
    env = simulated_device_env(n_devices)
    out = subprocess.run(
        [sys.executable, "-c",
         preamble(n_devices) + textwrap.dedent(script), *argv],
        capture_output=True, text=True, timeout=timeout, cwd=".", env=env,
    )
    if _SENTINEL in out.stdout:
        pytest.skip(f"cannot simulate {n_devices} XLA host devices")
    return out

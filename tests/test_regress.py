"""Regression-sentinel semantics (benchmarks/regress.py).

The sentinel's one job: fail CI on a real slowdown, never on timer noise.
These tests pin the comparison semantics on synthetic trajectories — a 2x
slowdown fails, a vanished row fails, ordinary jitter passes, cross-host
baselines get relaxed wall-clock slack — and check the normalisers
against miniature BENCH payloads plus the committed baseline itself.
"""

from __future__ import annotations

import copy
import json

import pytest

from benchmarks.regress import (
    BASELINE_PATH,
    TRAJECTORY_SCHEMA,
    collect,
    compare,
    delta_table,
    failures,
    median_of,
    normalise_payload,
    _row,
)

HOST = {"platform": "test", "cpu_count": 4}


def _traj(rows, host=HOST):
    return {"schema": TRAJECTORY_SCHEMA, "host": dict(host),
            "sources": ["synthetic"], "rows": rows}


def _base():
    return _traj({
        "a/us_per_query": _row(1000.0, "us", "time"),
        "a/dists_per_query": _row(2000.0, "count", "work"),
        "a/exact": _row(True, "bool", "flag", better="higher"),
        "b/goodput_rps": _row(1000.0, "rps", "throughput", better="higher"),
        "c/bytes_ratio": _row(0.55, "ratio", "ratio"),
    })


def test_identical_trajectories_pass():
    t = _base()
    deltas = compare(t, t)
    assert failures(deltas) == []
    assert all(d["status"] == "ok" for d in deltas)


def test_two_x_slowdown_fails():
    base, cur = _base(), _base()
    cur["rows"]["a/us_per_query"]["value"] *= 2.0
    bad = failures(compare(base, cur))
    assert [d["name"] for d in bad] == ["a/us_per_query"]
    assert bad[0]["status"] == "REGRESSION"
    assert "REGRESSION" in delta_table(compare(base, cur))


def test_jitter_within_slack_passes():
    base, cur = _base(), _base()
    cur["rows"]["a/us_per_query"]["value"] *= 1.4       # < 1.75x rel slack
    cur["rows"]["b/goodput_rps"]["value"] /= 1.3
    cur["rows"]["c/bytes_ratio"]["value"] *= 1.1
    assert failures(compare(base, cur)) == []


def test_absolute_floor_protects_tiny_times():
    # 3x on a 20us row is under the 100us absolute floor: noise, not a
    # regression; the same ratio at 1000us is real
    base = _traj({"t": _row(20.0, "us", "time")})
    cur = _traj({"t": _row(60.0, "us", "time")})
    assert failures(compare(base, cur)) == []
    big_b = _traj({"t": _row(1000.0, "us", "time")})
    big_c = _traj({"t": _row(3000.0, "us", "time")})
    assert failures(compare(big_b, big_c))


def test_work_counts_are_tight():
    base, cur = _base(), _base()
    cur["rows"]["a/dists_per_query"]["value"] *= 1.10   # >5% more work
    assert failures(compare(base, cur))


def test_flag_regression_fails():
    base, cur = _base(), _base()
    cur["rows"]["a/exact"]["value"] = 0.0
    bad = failures(compare(base, cur))
    assert [d["name"] for d in bad] == ["a/exact"]


def test_missing_row_fails_new_row_passes():
    base, cur = _base(), _base()
    del cur["rows"]["c/bytes_ratio"]
    cur["rows"]["d/new_metric"] = _row(1.0, "count", "work")
    deltas = compare(base, cur)
    by = {d["name"]: d["status"] for d in deltas}
    assert by["c/bytes_ratio"] == "MISSING"
    assert by["d/new_metric"] == "new"
    assert len(failures(deltas)) == 1


def test_cross_host_relaxes_wall_clock_only():
    base = _base()
    cur = copy.deepcopy(_base())
    cur["host"] = {"platform": "other", "cpu_count": 96}
    cur["rows"]["a/us_per_query"]["value"] *= 2.5   # < 1.75*2 cross-host
    assert failures(compare(base, cur)) == []
    # work counts stay tight across hosts (deterministic given the seed)
    cur["rows"]["a/dists_per_query"]["value"] *= 1.10
    assert failures(compare(base, cur))


def test_schema_mismatch_rejected():
    base = _base()
    base["schema"] = 999
    with pytest.raises(ValueError, match="rebase"):
        compare(base, _base())


def test_median_of_runs():
    runs = []
    for v in (100.0, 500.0, 110.0):
        t = _base()
        t["rows"]["a/us_per_query"]["value"] = v
        runs.append(t)
    med = median_of(runs)
    assert med["rows"]["a/us_per_query"]["value"] == 110.0  # outlier gone
    assert med["runs"] == 3


def test_normalise_bss_metrics_payload():
    payload = {
        "bench": "bss_metrics",
        "metrics": {"l2": {
            "range": {"exact": True, "dists_per_query": 2911.0,
                      "us_per_query": 40.2, "tile_exclusion_rate": 0.0},
            "knn": {"k": 10, "exact": True, "rounds": 5,
                    "dists_per_query": 7127.0, "us_per_query": 132.8},
        }},
    }
    rows = normalise_payload(payload)
    assert rows["bss/l2/range/us_per_query"]["class"] == "time"
    assert rows["bss/l2/knn/rounds"]["class"] == "work"
    assert rows["bss/l2/range/exact"]["better"] == "higher"


def test_normalise_serving_payload_positional_rates():
    payload = {
        "workload": {"sync_service_ms": 1.3},
        "rates": [
            {"async": {"p95_ms": 10.0, "goodput_rps": 400.0}},
            {"async": {"p95_ms": 35.0, "goodput_rps": 1100.0}},
            {"async": {"p95_ms": 15.0, "goodput_rps": 2200.0}},
        ],
    }
    rows = normalise_payload(payload)
    assert rows["serving/under/async_p95_ms"]["value"] == 10.0
    assert rows["serving/overload/async_goodput_rps"]["better"] == "higher"
    assert normalise_payload({"bench": "unknown_thing"}) == {}


def test_collect_rejects_duplicate_rows(tmp_path):
    payload = {"bench": "bss_incremental", "append": {"rows_per_s": 1.0}}
    for name in ("BENCH_a.json", "BENCH_b.json"):
        (tmp_path / name).write_text(json.dumps(payload))
    with pytest.raises(ValueError, match="duplicate"):
        collect(sorted(tmp_path.glob("BENCH_*.json")))


def test_committed_baseline_is_a_valid_trajectory():
    baseline = json.loads(BASELINE_PATH.read_text())
    assert baseline["schema"] == TRAJECTORY_SCHEMA
    assert baseline["rows"], "baseline must not be empty"
    for name, r in baseline["rows"].items():
        assert set(r) == {"value", "unit", "class", "better"}, name
    # comparing the baseline to itself is clean by construction
    assert failures(compare(baseline, baseline)) == []

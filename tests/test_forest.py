"""Oracle-equivalence tests for the device forest (array-encoded jitted
batched walks) against the host numpy walks.

The contract under test is the strongest the subsystem makes: for every
tree variant, exclusion mechanism and backend, the walker returns the SAME
result sets and the SAME per-query distance counts as the distance-counted
host walk (``tree.range_search`` / ``lrt.range_search_monotone``).  The
pallas backend runs in interpret mode off-TPU, exercising the real masked
kernel wiring everywhere.
"""

import numpy as np
import pytest

from repro.core import lrt, tree
from repro.core.backends import EngineOpts
from repro.core.exclusion import HILBERT, HYPERBOLIC
from repro.data import metricsets
from repro.forest import (
    encode_monotone,
    encode_tree,
    forest_range_search,
    monotone_range_search,
)

BACKENDS = ("jnp", "pallas")


def _kw(backend):
    # interpret=True exercises the Pallas kernels off-TPU
    return {"backend": backend, "interpret": True if backend == "pallas" else None}


def _same_results(res, oracle):
    return all(sorted(a) == sorted(b) for a, b in zip(res, oracle))


@pytest.fixture(scope="module")
def space():
    data = metricsets.colors_surrogate(650, dim=16, seed=3)
    db, q = metricsets.split_queries(data, 0.05, seed=4)
    q = q[:12]
    t = metricsets.calibrate_threshold("l2", db, 5e-3)
    return db, q, t


@pytest.fixture(scope="module")
def tree_cache(space):
    """Build + encode each variant once for the whole matrix."""
    db, _, _ = space
    cache = {}

    def get(variant):
        if variant not in cache:
            tr = tree.build_tree(variant, "l2", db, seed=7)
            cache[variant] = (tr, encode_tree(tr))
        return cache[variant]

    return get


@pytest.fixture(scope="module")
def oracle_cache(space, tree_cache):
    db, q, t = space
    cache = {}

    def get(variant, mech):
        if (variant, mech) not in cache:
            tr, _ = tree_cache(variant)
            cache[(variant, mech)] = tree.range_search(tr, q, t, mech)
        return cache[(variant, mech)]

    return get


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mech", [HYPERBOLIC, HILBERT])
@pytest.mark.parametrize("variant", tree.TREE_VARIANTS)
def test_forest_matches_numpy_walk(space, tree_cache, oracle_cache,
                                   variant, mech, backend):
    """Result sets AND per-query distance counts identical to the host walk
    — all 12 variants x both mechanisms x both backends."""
    db, q, t = space
    _, enc = tree_cache(variant)
    res_np, counter = oracle_cache(variant, mech)
    res, stats = forest_range_search(enc, q, t, mech, **_kw(backend))
    assert _same_results(res, res_np), (variant, mech, backend)
    assert np.array_equal(stats["per_query_dists"], counter.per_query), (
        variant, mech, backend,
    )


@pytest.fixture(scope="module")
def monotone_cache(space):
    db, _, _ = space
    cache = {}

    def get(partition, select):
        if (partition, select) not in cache:
            tr = lrt.build_monotone_tree(partition, select, "l2", db, seed=5)
            cache[(partition, select)] = (tr, encode_monotone(tr))
        return cache[(partition, select)]

    return get


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("select", ["rand", "far"])
@pytest.mark.parametrize("partition", lrt.PARTITIONS)
def test_monotone_forest_matches_numpy_walk(space, monotone_cache,
                                            partition, select, backend):
    db, q, t = space
    tr, enc = monotone_cache(partition, select)
    res_np, counter = lrt.range_search_monotone(tr, q, t, HILBERT)
    res, stats = monotone_range_search(enc, q, t, HILBERT, **_kw(backend))
    assert _same_results(res, res_np), (partition, select, backend)
    assert np.array_equal(stats["per_query_dists"], counter.per_query)


@pytest.mark.parametrize("backend", BACKENDS)
def test_monotone_forest_hyperbolic_closer(space, monotone_cache, backend):
    db, q, t = space
    tr, enc = monotone_cache("closer", "far")
    res_np, counter = lrt.range_search_monotone(tr, q, t, HYPERBOLIC)
    res, stats = monotone_range_search(enc, q, t, HYPERBOLIC, **_kw(backend))
    assert _same_results(res, res_np)
    assert np.array_equal(stats["per_query_dists"], counter.per_query)


def test_monotone_forest_rejects_hyperbolic_planar(space, monotone_cache):
    db, q, t = space
    _, enc = monotone_cache("lrt", "rand")
    with pytest.raises(ValueError):
        monotone_range_search(enc, q, t, HYPERBOLIC)


def test_forest_rejects_unknown_mechanism(space, tree_cache):
    db, q, t = space
    _, enc = tree_cache("hpt_fft_fixed")
    with pytest.raises(ValueError):
        forest_range_search(enc, q, t, "euclid")


# ------------------------------------------------------------- edge shapes


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("nq", [1, 5])
def test_forest_non_multiple_frontier_widths(space, tree_cache,
                                             oracle_cache, nq, backend):
    """Query batches far from the 128-row tile width (and a corpus whose
    per-level node counts don't divide the kernel block) — padding paths."""
    db, q, t = space
    _, enc = tree_cache("hpt_fft_log")
    res_np, counter = tree.range_search(
        tree_cache("hpt_fft_log")[0], q[:nq], t, HILBERT
    )
    res, stats = forest_range_search(enc, q[:nq], t, HILBERT, **_kw(backend))
    assert _same_results(res, res_np)
    assert np.array_equal(stats["per_query_dists"], counter.per_query)


def test_forest_empty_query_batch(space, tree_cache):
    db, q, t = space
    _, enc = tree_cache("hpt_fft_log")
    res, stats = forest_range_search(enc, q[:0], t, HILBERT,
                                     opts=EngineOpts(backend="jnp"))
    assert res == []
    assert stats["per_query_dists"].shape == (0,)


# -------------------------------------------------- degenerate geometries


@pytest.fixture(scope="module")
def duplicate_space():
    """A corpus thick with exact duplicates: duplicate reference points at
    inner nodes (ref_dists == 0), oversized fallback leaf buckets in the
    monotone family — the PR 2 delta-floor regression surface."""
    rng = np.random.default_rng(21)
    locs = rng.random((30, 6))
    db = np.concatenate([np.repeat(locs, 8, axis=0), rng.random((60, 6))])
    q = rng.random((10, 6))
    t = 0.25
    truth = tree.exhaustive_search("l2", db, q, t)
    return db, q, t, truth


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mech", [HYPERBOLIC, HILBERT])
@pytest.mark.parametrize("variant", ["hpt_fft_fixed", "sat_pure"])
def test_forest_duplicate_refs_sound(duplicate_space, variant, mech, backend):
    db, q, t, truth = duplicate_space
    tr = tree.build_tree(variant, "l2", db, seed=5)
    enc = encode_tree(tr)
    res_np, counter = tree.range_search(tr, q, t, mech)
    res, stats = forest_range_search(enc, q, t, mech, **_kw(backend))
    assert _same_results(res, truth), (variant, mech, backend)
    assert _same_results(res, res_np)
    assert np.array_equal(stats["per_query_dists"], counter.per_query)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("partition", ["closer", "median_x", "lrt"])
def test_monotone_forest_duplicate_pivots_sound(duplicate_space, partition,
                                                backend):
    """Duplicate pivot pairs force the degenerate leaf-bucket fallback at
    build — buckets larger than leaf_cap, exercising the padded leaf table."""
    db, q, t, truth = duplicate_space
    tr = lrt.build_monotone_tree(partition, "far", "l2", db, seed=6)
    enc = encode_monotone(tr)
    res_np, counter = lrt.range_search_monotone(tr, q, t, HILBERT)
    res, stats = monotone_range_search(enc, q, t, HILBERT, **_kw(backend))
    assert _same_results(res, truth), (partition, backend)
    assert _same_results(res, res_np)
    assert np.array_equal(stats["per_query_dists"], counter.per_query)


def test_forest_tiny_dataset_root_leaf():
    """Datasets at/below leaf_cap produce the k==0 wrapper root (partition)
    or a bare leaf root (monotone) — root-attached always-alive buckets."""
    rng = np.random.default_rng(9)
    db = rng.random((6, 4))
    q = rng.random((3, 4))
    t = 0.4
    truth = tree.exhaustive_search("l2", db, q, t)
    tr = tree.build_tree("hpt_random_fixed", "l2", db, seed=1)
    res, stats = forest_range_search(encode_tree(tr), q, t, HILBERT,
                                     opts=EngineOpts(backend="jnp"))
    assert _same_results(res, truth)
    _, counter = tree.range_search(tr, q, t, HILBERT)
    assert np.array_equal(stats["per_query_dists"], counter.per_query)
    mtr = lrt.build_monotone_tree("closer", "far", "l2", db, seed=1)
    mres, mstats = monotone_range_search(encode_monotone(mtr), q, t, HILBERT,
                                         opts=EngineOpts(backend="jnp"))
    assert _same_results(mres, truth)
    _, mcounter = lrt.range_search_monotone(mtr, q, t, HILBERT)
    assert np.array_equal(mstats["per_query_dists"], mcounter.per_query)


# ------------------------------------------------------- other supermetrics


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("metric", ["cosine", "jsd"])
def test_forest_other_metrics(metric, backend):
    """The walker is metric-dispatched: probability-space JSD rides its VPU
    kernel on the pallas backend, cosine the jnp formula."""
    rng = np.random.default_rng(8)
    data = rng.random((500, 12)) + 1e-3
    if metric == "jsd":
        data /= data.sum(axis=1, keepdims=True)
    db, q = data[:440], data[440:452]
    t = metricsets.calibrate_threshold(metric, db, 5e-3)
    tr = tree.build_tree("hpt_fft_log", metric, db, seed=11)
    enc = encode_tree(tr)
    res_np, counter = tree.range_search(tr, q, t, HILBERT)
    res, stats = forest_range_search(enc, q, t, HILBERT, **_kw(backend))
    assert _same_results(res, res_np), (metric, backend)
    assert np.array_equal(stats["per_query_dists"], counter.per_query)


# ------------------------------------------------------------ serving wire


def test_retrieval_server_forest_backend():
    from repro.serve.retrieval import RetrievalServer

    rng = np.random.default_rng(13)
    centres = rng.normal(size=(8, 24))
    corpus = centres[rng.integers(0, 8, size=400)] + 0.15 * rng.normal(
        size=(400, 24)
    )
    qs = corpus[:16] + 0.01 * rng.normal(size=(16, 24))
    bss = RetrievalServer(corpus, metric="cosine", seed=3)
    forest = RetrievalServer(corpus, metric="cosine", seed=3, index="forest")
    t = 0.35
    hits_bss = bss.range_by_distance(qs, t)
    hits_f = forest.range_by_distance(qs, t)
    assert all(set(a) == set(b) for a, b in zip(hits_f, hits_bss))
    assert forest.stats.n_queries == 16
    assert forest.stats.dists_per_query > 0
    with pytest.raises(NotImplementedError):
        forest.top_k(qs, 5)

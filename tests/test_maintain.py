"""Living-corpus maintenance: the ISSUE-9 exactness contract.

After any mutation sequence (append / delete / compact), range hits, kNN
results AND per-query distance counts must be bit-identical to what a
fresh ``build_bss`` over the same live rows would serve — on the fused,
oracle, sharded and bf16 paths alike.  Compaction with pivot refresh goes
further: the compacted index must equal the fresh build FIELD FOR FIELD
(same seed, same permutation, ids mapped through the live-id table).

Append must also be cheap by construction: its host-side distance work is
the new-rows x pivots table extension only (``table_dists == m * P``),
never a rebuild.

The serving-side contract rides the same file: the front's micro-batches
each finish on ONE index snapshot (``ServeResult.generation`` names it,
and the hits must match a direct engine call on that snapshot even while
a mutator thread swaps generations under live traffic), and the exact-hit
LRU keys on generation, so a mutation orphans every stale entry.

Multi-device scenarios run in subprocesses through ``multidevice_shim``
(same convention as ``test_sharded_bss``).
"""

import threading

import numpy as np
import pytest
from multidevice_shim import run_simulated_mesh

from repro.core import flat_index
from repro.core.backends import EngineOpts
from repro.core.npdist import pairwise_np
from repro.index import append, compact, delete, maybe_compact

METRICS = ("l2", "cosine", "jsd", "triangular")


def _space(metric: str, n: int, dim: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = rng.random((n, dim)).astype(np.float32) + 1e-3
    if metric in ("jsd", "triangular"):
        x /= x.sum(axis=1, keepdims=True)
    return x


def _snap(dvals: np.ndarray, frac: float) -> float:
    """A threshold snapped into a wide gap of the distance distribution, so
    fp32/fp64 rounding cannot flip a hit across it."""
    vals = np.unique(np.sort(np.asarray(dvals, np.float64).ravel()))
    i = int(np.clip(frac * len(vals), 0, len(vals) - 2))
    for j in range(i, len(vals) - 1):
        if vals[j + 1] - vals[j] > 1e-4 * max(1.0, vals[j]):
            return float(0.5 * (vals[j] + vals[j + 1]))
    return float(vals[-1] + 1.0)


def _live_rows_by_id(index):
    """(ids ascending, raw engine-space rows) of the live corpus."""
    live_pos = np.nonzero(index.valid)[0]
    ids = index.perm[live_pos]
    order = np.argsort(ids)
    return ids[order], index.data[live_pos[order]]


def _check_all_paths(index, q, t, k, oracle_hits, oracle_stats, truth_knn):
    """Fused fp32 + bf16 + kNN on ``index`` against the given oracle."""
    hits, st = flat_index.bss_query_batched(index, q, t)
    assert hits == oracle_hits
    assert np.array_equal(
        np.asarray(st["per_query_dists"]),
        np.asarray(oracle_stats["per_query_dists"]),
    )
    h16, st16 = flat_index.bss_query_batched(
        index, q, t, opts=EngineOpts(precision="bf16")
    )
    assert h16 == oracle_hits
    ki, kd, ks = flat_index.bss_knn_batched(index, q, k)
    for i in range(len(q)):
        got = [j for j in ki[i].tolist() if j >= 0]
        assert got == truth_knn[i], (i, got, truth_knn[i])
    return st


def _truth_knn(metric, q, ids, rows, k):
    """float64 oracle top-k over the live rows, as original corpus ids."""
    d = pairwise_np(metric, q, rows)
    out = []
    for i in range(len(q)):
        kk = min(k, rows.shape[0])
        out.append([int(ids[j]) for j in np.argsort(d[i])[:kk]])
    return out


# --------------------------------------------------------------- bit-identity


@pytest.mark.parametrize("metric", METRICS)
def test_mutation_vs_fresh_build_bit_identity(metric):
    """append -> delete -> compact, every generation checked on every path
    against the oracle over its OWN live rows; the compacted index equals a
    fresh seeded build over the live rows field for field."""
    dim, k = 9, 5
    base = _space(metric, 460, dim, seed=11)
    extra = _space(metric, 70, dim, seed=12)
    q = _space(metric, 13, dim, seed=13)
    idx0 = flat_index.build_bss(
        metric, base, n_pivots=7, n_pairs=9, block=32, seed=4
    )
    t = _snap(pairwise_np(metric, q, base), 0.03)

    def oracle_on(index):
        ids, rows = _live_rows_by_id(index)
        # the oracle serves the ORIGINAL metric space: engine-space rows
        # are the raw rows for every metric except cosine, whose stored
        # unit vectors represent the same points for the cosine distance
        hits, so = flat_index.bss_query(index, q, t)
        return ids, rows, hits, so

    # generation 0
    ids, rows, oh, so = oracle_on(idx0)
    _check_all_paths(idx0, q, t, k, oh, so, _truth_knn(metric, q, ids, rows, k))
    assert idx0.generation == 0

    # append: fresh blocks, no rebuild
    idx1, ms = append(idx0, extra)
    assert idx1.generation == 1
    assert ms.op == "append" and ms.rows == len(extra)
    assert ms.table_dists == len(extra) * idx0.pivots.shape[0]
    ids, rows, oh, so = oracle_on(idx1)
    _check_all_paths(idx1, q, t, k, oh, so, _truth_knn(metric, q, ids, rows, k))
    # the appended ids are dense and the old index is untouched
    assert idx1.next_id == idx0.next_id + len(extra)
    assert idx0.generation == 0 and idx0.n_blocks < idx1.n_blocks

    # delete a spread of ids, old and new
    dead = [0, 17, 461, idx1.next_id - 1]
    idx2, ms = delete(idx1, dead)
    assert idx2.generation == 2 and ms.op == "delete"
    assert idx2.tombstones == len(dead)
    ids, rows, oh, so = oracle_on(idx2)
    assert not set(dead) & set(ids.tolist())
    _check_all_paths(idx2, q, t, k, oh, so, _truth_knn(metric, q, ids, rows, k))
    # deleted ids are gone from range hits too
    hits, _ = flat_index.bss_query_batched(idx2, q, t)
    assert not set(dead) & {h for row in hits for h in row}

    # compact == fresh build over the live rows, field for field
    ids, rows = _live_rows_by_id(idx2)
    idx3, ms = compact(idx2)
    assert idx3.generation == 3 and ms.op == "compact"
    assert ms.refreshed_pivots and idx3.tombstones == 0
    fresh = flat_index._build_engine_index(
        idx2.metric_name, rows, n_pivots=idx2.pivots.shape[0],
        n_pairs=idx2.pairs.shape[0], block=idx2.block, seed=idx2.seed,
        mesh=None,
    )
    assert np.array_equal(idx3.data, fresh.data)
    assert np.array_equal(idx3.pivots, fresh.pivots)
    assert np.array_equal(idx3.pairs, fresh.pairs)
    assert np.array_equal(idx3.deltas, fresh.deltas)
    assert np.array_equal(idx3.boxes, fresh.boxes)
    assert np.array_equal(idx3.valid, fresh.valid)
    # idx3.perm carries ORIGINAL ids; mapping fresh's dense positions
    # through the live-id table must reproduce it exactly
    mapped = np.where(
        fresh.perm >= 0,
        ids[np.clip(fresh.perm, 0, len(ids) - 1)],
        -1,
    )
    assert np.array_equal(idx3.perm, mapped)
    ids3, rows3, oh, so = oracle_on(idx3)
    _check_all_paths(
        idx3, q, t, k, oh, so, _truth_knn(metric, q, ids3, rows3, k)
    )


def test_append_accounting_and_validation():
    db = _space("l2", 300, 8, seed=1)
    idx = flat_index.build_bss("l2", db, n_pivots=6, n_pairs=8, block=64,
                               seed=2)
    more = _space("l2", 33, 8, seed=3)
    idx1, ms = append(idx, more)
    # no-rebuild accounting: the host table build is m x P distances only
    assert ms.table_dists == 33 * 6
    assert ms.new_blocks == idx1.n_blocks - idx.n_blocks
    with pytest.raises(ValueError):
        append(idx, np.zeros((0, 8), np.float32))
    with pytest.raises(ValueError):
        append(idx, _space("l2", 4, 9, seed=4))  # wrong dim


def test_delete_validation():
    db = _space("l2", 200, 8, seed=5)
    idx = flat_index.build_bss("l2", db, n_pivots=6, n_pairs=8, block=64)
    with pytest.raises(ValueError):
        delete(idx, [])
    with pytest.raises(ValueError):
        delete(idx, [3, 3])
    with pytest.raises(ValueError):
        delete(idx, [200])  # never existed
    idx1, _ = delete(idx, [7])
    with pytest.raises(ValueError):
        delete(idx1, [7])  # already dead


def test_maybe_compact_thresholds():
    db = _space("l2", 256, 8, seed=6)
    idx = flat_index.build_bss("l2", db, n_pivots=6, n_pairs=8, block=32)
    same, ms = maybe_compact(idx)
    assert same is idx and ms is None  # healthy index: no-op, same object
    # push tombstones over the default 25% threshold
    idx1, _ = delete(idx, list(range(80)))
    idx2, ms = maybe_compact(idx1)
    assert ms is not None and ms.op == "compact"
    assert idx2.tombstones == 0 and idx2.generation == idx1.generation + 1
    # degraded exclusion power forces a pivot refresh; healthy skips it
    idx3, ms = maybe_compact(
        idx1, block_exclusion_rate=0.1, refresh_pivots=None
    )
    assert ms.refreshed_pivots
    idx4, ms = maybe_compact(
        idx1, block_exclusion_rate=0.9, refresh_pivots=None
    )
    assert not ms.refreshed_pivots


def test_generation_stamped_in_engine_stats():
    db = _space("jsd", 200, 6, seed=7)
    q = _space("jsd", 5, 6, seed=8)
    idx = flat_index.build_bss("jsd", db, n_pivots=6, n_pairs=8, block=32)
    idx1, _ = append(idx, _space("jsd", 20, 6, seed=9))
    _, st = flat_index.bss_query_batched(idx1, q, 0.1)
    assert st["generation"] == 1
    _, _, ks = flat_index.bss_knn_batched(idx1, q, 3)
    assert ks["generation"] == 1
    _, so = flat_index.bss_query(idx1, q, 0.1)
    assert so["generation"] == 1


# ------------------------------------------------------------ EngineOpts API


def test_engine_opts_equivalence_and_strict_shim(monkeypatch):
    db = _space("l2", 300, 8, seed=10)
    q = _space("l2", 7, 8, seed=11)
    idx = flat_index.build_bss("l2", db, n_pivots=6, n_pairs=8, block=64)
    t = _snap(pairwise_np("l2", q, db), 0.03)
    h_legacy, s_legacy = flat_index.bss_query_batched(
        idx, q, t, backend="jnp", realisation="dense"
    )
    h_opts, s_opts = flat_index.bss_query_batched(
        idx, q, t, opts=EngineOpts(backend="jnp", realisation="dense")
    )
    assert h_legacy == h_opts
    assert s_legacy["dists_per_query"] == s_opts["dists_per_query"]
    # opts= and legacy kwargs are exclusive
    with pytest.raises(ValueError):
        flat_index.bss_query_batched(
            idx, q, t, opts=EngineOpts(), backend="jnp"
        )
    # invalid knob values fail in EngineOpts itself
    with pytest.raises(ValueError):
        EngineOpts(precision="fp16")
    with pytest.raises(ValueError):
        EngineOpts(realisation="sparse")
    # strict-API mode: legacy kwargs warn, opts= stays silent
    monkeypatch.setenv("REPRO_STRICT_API", "1")
    with pytest.warns(DeprecationWarning, match="legacy engine kwargs"):
        flat_index.bss_query_batched(idx, q, t, backend="jnp")
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        flat_index.bss_query_batched(
            idx, q, t, opts=EngineOpts(backend="jnp")
        )


# --------------------------------------------------------------- serving side


def test_front_cache_invalidated_by_generation():
    from repro.serve.front import ServingFront

    db = _space("l2", 256, 8, seed=20)
    idx = flat_index.build_bss("l2", db, n_pivots=6, n_pairs=8, block=64)
    q = _space("l2", 1, 8, seed=21)[0]
    with ServingFront(idx, cache_size=16, max_delay_s=0.001) as front:
        r1 = front.submit(q, "range", t=1.0).result(30)
        r2 = front.submit(q, "range", t=1.0).result(30)
        assert r2.cache_hit and r2.generation == 0
        ms = front.append(_space("l2", 10, 8, seed=22))
        assert ms.generation == 1
        r3 = front.submit(q, "range", t=1.0).result(30)
        # the pre-mutation entry is keyed to generation 0: unreachable now
        assert not r3.cache_hit and r3.generation == 1
        r4 = front.submit(q, "range", t=1.0).result(30)
        assert r4.cache_hit and r4.generation == 1
        assert sorted(r1.hits) != sorted(r3.hits) or True  # hits may differ
        snap = front.metrics().snapshot()
        assert snap["gauges"]["index/generation"] == 1.0


def test_front_generation_swap_under_live_traffic():
    """A mutator thread swaps generations while queries stream; every
    result's hits must equal a direct engine call on the snapshot its
    ``generation`` names — no torn batch ever mixes two generations."""
    from repro.serve.front import ServingFront

    db = _space("l2", 300, 8, seed=30)
    idx = flat_index.build_bss("l2", db, n_pivots=6, n_pairs=8, block=64,
                               seed=3)
    queries = _space("l2", 120, 8, seed=31)
    t = 1.1
    snapshots = {0: idx}
    with ServingFront(idx, max_delay_s=0.001) as front:
        stop = threading.Event()

        def mutate():
            g = np.random.default_rng(32)
            while not stop.is_set():
                ms = front.append(
                    g.random((5, 8), dtype=np.float32) + 1e-3
                )
                snapshots[ms.generation] = front.index
                stop.wait(0.002)

        th = threading.Thread(target=mutate)
        th.start()
        try:
            futs = [front.submit(q, "range", t=t) for q in queries]
            results = [f.result(60) for f in futs]
        finally:
            stop.set()
            th.join()
    assert {r.generation for r in results} , "no result resolved"
    for q, r in zip(queries, results):
        ref_hits, _ = flat_index.bss_query_batched(
            snapshots[r.generation], q[None], t
        )
        assert sorted(r.hits) == sorted(ref_hits[0]), r.generation
    # with a 2ms mutation cadence and 120 queries, traffic should span
    # more than one generation (not a correctness property, a smoke check
    # that the race was actually exercised)
    assert len(snapshots) > 1


def test_retrieval_server_search_and_mutations():
    from repro.serve.retrieval import RetrievalServer

    rng = np.random.default_rng(40)
    corpus = rng.normal(size=(400, 12)).astype(np.float32)
    srv = RetrievalServer(corpus, metric="cosine", n_pivots=8, n_pairs=10,
                          seed=3)
    q = rng.normal(size=(6, 12)).astype(np.float32)

    res = srv.search(q, "knn", k=5)
    legacy = srv.top_k(q, 5)
    assert all(np.array_equal(res.indices[i], legacy[i]) for i in range(6))
    assert res.generation == 0 and res.stats["kind"] == "knn"
    with pytest.raises(ValueError):
        srv.search(q, "range")  # t missing
    with pytest.raises(ValueError):
        srv.search(q, "knn")  # k missing
    with pytest.raises(ValueError):
        srv.search(q, "nearest")

    ms = srv.append(rng.normal(size=(30, 12)).astype(np.float32))
    assert ms.generation == 1 and srv.corpus.shape[0] == 430
    dead = [int(srv.search(q, "knn", k=1).indices[0][0]), 5]
    srv.delete(dead)
    res = srv.search(q, "knn", k=5)
    oracle = srv.top_k_oracle(q, 5)
    for i in range(6):
        assert np.array_equal(res.indices[i], oracle[i])
    assert not set(dead) & set(res.indices.ravel().tolist())
    srv.compact()
    res2 = srv.search(q, "knn", k=5)
    assert res2.generation == 3
    for i in range(6):
        assert np.array_equal(res2.indices[i], res.indices[i])


# -------------------------------------------------------------- sharded mesh

_SHARDED = """
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.core import flat_index
    from repro.index import append, compact, delete
    from repro.core.backends import EngineOpts

    rng = np.random.default_rng(0)
    db = rng.random((700, 10)).astype(np.float32) + 1e-3
    q = rng.random((11, 10)).astype(np.float32) + 1e-3
    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    idx = flat_index.build_bss("l2", db, n_pivots=8, n_pairs=10, block=64,
                               seed=1, mesh=mesh)
    fns_before = idx.sharded()._fns
    t = 0.9

    # small append fits the trailing padding blocks: spliced IN PLACE on
    # the mesh (shapes frozen, jit cache shared -> zero recompiles)
    idx1, ms = append(idx, rng.random((20, 10)).astype(np.float32) + 1e-3)
    assert ms.sharded_in_place, ms
    assert idx1.sharded()._fns is fns_before, "jit cache not shared"
    oracle, so = flat_index.bss_query(idx1, q, t)
    hits, st = flat_index.bss_query_batched(idx1, q, t)
    assert st["n_shards"] == 4
    assert hits == oracle
    h16, _ = flat_index.bss_query_batched(
        idx1, q, t, opts=EngineOpts(precision="bf16"))
    assert h16 == oracle

    # oversized append overflows the free blocks: falls back to a lazy
    # full re-layout, same results
    idx2, ms = append(idx1, rng.random((300, 10)).astype(np.float32) + 1e-3)
    assert not ms.sharded_in_place
    oracle, _ = flat_index.bss_query(idx2, q, t)
    hits, st = flat_index.bss_query_batched(idx2, q, t)
    assert st["n_shards"] == 4 and hits == oracle

    # delete + compact keep serving through the mesh
    idx3, _ = delete(idx2, [0, 5, 700, 1019])
    oracle, _ = flat_index.bss_query(idx3, q, t)
    hits, st = flat_index.bss_query_batched(idx3, q, t)
    assert st["n_shards"] == 4 and hits == oracle
    ki, kd, ks = flat_index.bss_knn_batched(idx3, q, 5)
    assert ks["n_shards"] == 4
    idx4, _ = compact(idx3)
    assert idx4.mesh is mesh
    hits2, st2 = flat_index.bss_query_batched(idx4, q, t)
    assert st2["n_shards"] == 4
    # hit ORDER follows the block layout, which compaction re-permutes;
    # the hit SETS are the exactness contract
    assert [sorted(h) for h in hits2] == [sorted(h) for h in hits]
    oracle4, _ = flat_index.bss_query(idx4, q, t)
    assert hits2 == oracle4
    ki2, kd2, _ = flat_index.bss_knn_batched(idx4, q, 5)
    assert np.array_equal(ki, ki2) and np.array_equal(kd, kd2)
    print("SHARDED-MAINTAIN-OK")
"""


def test_sharded_living_corpus_4dev():
    out = run_simulated_mesh(_SHARDED, 4)
    assert "SHARDED-MAINTAIN-OK" in out.stdout, out.stdout + out.stderr

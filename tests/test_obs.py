"""Observability layer tests (repro.obs + the serving wiring).

The load-bearing guarantee is the ISSUE-8 acceptance bar: collecting
metrics must change NOTHING — a metrics-on front and a metrics-off front
return bit-identical results on all four supermetrics, and the
instrumented engine jits still contain zero callback primitives (the
device-side counters are functional outputs, not debug hooks).  Around
that: registry/histogram unit semantics, the shared stats schema on real
engine output, exclusion-attribution cross-checks, spans/explain, the
exposition round-trip, and the recompile counter.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro.core import flat_index, tree
from repro.core.backends import EngineOpts, jit_cache_size
from repro.core.npdist import pairwise_np
from repro.forest import encode_tree, forest_range_search
from repro.obs import (
    DEFAULT_LADDER,
    MECHANISMS,
    METRIC_NAMES,
    MetricsRegistry,
    Span,
    TraceBuffer,
    check_stats,
    complete_event,
    fold_engine_stats,
    instant_event,
    ladder_for,
    load_trace,
    log_ladder,
    metadata_event,
    metric_key,
    new_trace_id,
    parse_prometheus,
    poll_compile,
    shard_imbalance,
    validate_exposition,
    validate_stats,
    validate_trace,
    write_snapshot,
    write_trace,
)
from repro.serve.front import ServingFront

_DENSE = EngineOpts(realisation="dense")

DIM = 12


def _space(metric: str, n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = rng.random((n, DIM)).astype(np.float32) + 1e-3
    if metric in ("jsd", "triangular"):
        x /= x.sum(axis=1, keepdims=True)
    return x


def _snap(dvals: np.ndarray, frac: float) -> float:
    vals = np.unique(np.sort(np.asarray(dvals, np.float64).ravel()))
    i = int(np.clip(frac * len(vals), 0, len(vals) - 2))
    for j in range(i, len(vals) - 1):
        if vals[j + 1] - vals[j] > 1e-4 * max(1.0, vals[j]):
            return float(0.5 * (vals[j] + vals[j + 1]))
    return float(vals[-1] + 1.0)


# ---------------------------------------------------------------- registry


def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("engine/dists", engine="bss", kind="range")
    c.inc(5)
    c.inc()
    assert c.value == 6.0
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)
    g = reg.gauge("compile/cache_size", fn="lb")
    g.set(3)
    g.set(2)  # gauges go down
    assert g.value == 2.0
    # same (name, labels) -> the same live series
    assert reg.counter("engine/dists", kind="range", engine="bss") is c


def test_metric_key_is_canonical():
    assert metric_key("m", {}) == "m"
    assert metric_key("m", {"b": 1, "a": "x"}) == "m{a=x,b=1}"
    assert metric_key("m", {"a": "x", "b": 1}) == metric_key(
        "m", {"b": 1, "a": "x"}
    )


def test_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError, match="already registered as counter"):
        reg.gauge("x")


def test_histogram_ring_units():
    """Percentiles are a WINDOW statistic over the bounded ring; count/sum
    are lifetime tallies that survive ring eviction."""
    reg = MetricsRegistry()
    h = reg.histogram("serve/span_s", window=4, stage="queue")
    for v in range(1, 11):
        h.observe(float(v))
    assert h.count == 10 and h.sum == 55.0
    assert list(h.ring) == [7.0, 8.0, 9.0, 10.0]
    assert h.percentile(0.5) == 8.0  # nearest-rank over the window
    assert h.percentile(0.99) == 10.0
    s = h.summary()
    assert s["count"] == 10 and s["window"] == 4 and s["max"] == 10.0
    with pytest.raises(ValueError, match="window"):
        reg.histogram("serve/span_s", window=8, stage="queue")
    with pytest.raises(ValueError, match="window"):
        MetricsRegistry().histogram("h", window=0)


def test_snapshot_and_prometheus_round_trip():
    reg = MetricsRegistry()
    reg.counter("engine/dists", engine="bss", kind="range").inc(100)
    reg.gauge("compile/ladder_buckets").set(4)
    h = reg.histogram("serve/engine_s", kind="range")
    h.observe(0.25)
    h.observe(0.75)
    snap = reg.snapshot()
    assert snap["counters"]["engine/dists{engine=bss,kind=range}"] == 100.0
    assert snap["gauges"]["compile/ladder_buckets"] == 4.0
    assert snap["histograms"]["serve/engine_s{kind=range}"]["count"] == 2
    json.loads(reg.to_json())  # JSON-serialisable as claimed

    text = reg.to_prometheus()
    assert validate_exposition(text) == []
    samples = parse_prometheus(text)
    by_name = {}
    for name, labels, value in samples:
        by_name.setdefault(name, []).append((labels, value))
    assert by_name["engine_dists"] == [
        ({"engine": "bss", "kind": "range"}, 100.0)
    ]
    assert by_name["serve_engine_s_count"][0][1] == 2.0
    assert by_name["serve_engine_s_sum"][0][1] == 1.0
    # real cumulative buckets: monotone counts over the le ladder ending
    # at +Inf == _count (0.25 and 0.75 land in adjacent seconds buckets)
    buckets = by_name["serve_engine_s_bucket"]
    counts = [v for _, v in buckets]
    assert counts == sorted(counts) and counts[-1] == 2.0
    by_le = {lbl["le"]: v for lbl, v in buckets}
    assert by_le["+Inf"] == 2.0
    assert by_le["0.1"] == 0.0
    assert by_le["0.316227766"] == 1.0 and by_le["1"] == 2.0
    assert "# TYPE engine_dists counter" in text
    assert "# TYPE serve_engine_s histogram" in text


def test_prometheus_label_escaping_parses_back():
    reg = MetricsRegistry()
    reg.counter("m", path='a"b\\c').inc(1)
    samples = parse_prometheus(reg.to_prometheus())
    assert samples[0][1] == {"path": 'a"b\\c'}


def test_prometheus_malformed_label_values_round_trip():
    """Text-format spec escapes: backslash, double-quote AND newline must
    survive exposition -> parse, including the adversarial ``\\n``
    (escaped backslash followed by a literal n), which a sequential
    str.replace unescaper corrupts into a newline."""
    nasty = {
        "newline": "a\nb",
        "backslash_n": "a\\nb",   # literal backslash + 'n', NOT a newline
        "mixed": 'q"\\\n"end',
    }
    reg = MetricsRegistry()
    for key, val in nasty.items():
        reg.counter("m", which=key, v=val).inc(1)
    text = reg.to_prometheus()
    assert validate_exposition(text) == []
    got = {lbl["which"]: lbl["v"] for _, lbl, _ in parse_prometheus(text)}
    assert got == nasty
    # every exposition line is a single sample line (newlines escaped)
    assert all(
        line.startswith(("#", "m{")) for line in text.strip().splitlines()
    )


def test_parse_prometheus_rejects_malformed():
    with pytest.raises(ValueError, match="malformed"):
        parse_prometheus("this is not a sample line{")
    with pytest.raises(ValueError, match="non-numeric"):
        parse_prometheus("ok_name notanumber")


def test_render_groups_by_prefix():
    reg = MetricsRegistry()
    reg.counter("engine/dists").inc(7)
    reg.histogram("serve/engine_s").observe(0.5)
    out = reg.render()
    assert "== engine " in out and "== serve " in out
    assert "engine/dists" in out and "p95=" in out
    assert MetricsRegistry().render() == "(no metrics recorded)"


# ------------------------------------------------------------------- spans


def test_span_marks_and_durations():
    sp = Span()
    for i, stage in enumerate(("admit", "batch", "dispatch", "engine",
                               "demux")):
        sp.mark(stage, t=10.0 + i)
    d = sp.durations()
    assert d == {"queue": 1.0, "batch": 1.0, "engine": 1.0, "demux": 1.0,
                 "total": 4.0}
    with pytest.raises(ValueError, match="unknown stage"):
        sp.mark("teleport")


def test_span_partial_marks():
    sp = Span()
    sp.mark("admit", t=1.0)
    assert sp.durations() == {}  # one mark, no interval
    sp.mark("engine", t=3.0)  # batch/dispatch never marked
    d = sp.durations()
    assert d == {"admit_to_engine": 2.0, "total": 2.0}


def test_trace_ids_unique_and_sortable():
    ids = [new_trace_id() for _ in range(5)]
    assert len(set(ids)) == 5
    assert ids == sorted(ids)  # zero-padded -> lexicographic == numeric


# ----------------------------------------------- schema on real engine stats


def _bss_built(metric="l2"):
    data = _space(metric, 660, seed=3)
    db, q = data[:640], data[640:]
    idx = flat_index.build_bss(metric, db, n_pivots=8, n_pairs=10,
                               block=64, seed=5)
    t = _snap(pairwise_np(metric, q, db), 0.04)
    return idx, db, q, t


def test_bss_stats_conform_and_cross_check():
    idx, db, q, t = _bss_built()
    hits, stats = flat_index.bss_query_batched(idx, q, t, opts=_DENSE)
    check_stats(stats)
    assert stats["engine"] == "bss" and stats["kind"] == "range"
    # attribution cross-check: the scan's only mechanism is the Hilbert
    # four-point bound, so excluded blocks == blocks whose lower bound
    # clears the radius
    lb = flat_index.bss_lower_bounds(idx, q)
    expect = (np.asarray(lb) > t).sum(axis=1)
    assert (stats["excluded"]["hilbert"] == expect).all()

    _, _, ks = flat_index.bss_knn_batched(idx, q, 4, opts=_DENSE)
    check_stats(ks)
    assert ks["kind"] == "knn" and ks["rounds"] >= 1
    assert set(ks["excluded"]) == {"hilbert"}

    # empty batch still conforms
    _, es = flat_index.bss_query_batched(idx, q[:0], t)
    check_stats(es)
    _, _, eks = flat_index.bss_knn_batched(idx, q[:0], 4)
    check_stats(eks)


def test_bss_bf16_stats_conform():
    idx, db, q, t = _bss_built()
    _, stats = flat_index.bss_query_batched(
        idx, q, t, opts=EngineOpts(realisation="dense", precision="bf16"))
    check_stats(stats)
    assert stats["precision"] == "bf16"
    assert "band_eps" in stats and "recheck_points_per_query" in stats


def test_forest_stats_attribution_and_frontier():
    db = _space("l2", 600, seed=21)
    q = _space("l2", 8, seed=22)
    tr = tree.build_tree("hpt_fft_log", "l2", db, seed=23)
    enc = encode_tree(tr)
    t = _snap(pairwise_np("l2", q, db), 0.04)
    hits, stats = forest_range_search(enc, q, t)
    check_stats(stats)
    assert stats["engine"] == "forest"
    excl = stats["excluded"]
    assert set(excl) <= set(MECHANISMS) and "cover" in excl
    # the walker attributes disjointly (priority cover > hyperplane >
    # centre), so per-mechanism counts are individually sane and the
    # batch pruned *something* at this selective radius
    assert all((v >= 0).all() for v in excl.values())
    assert sum(int(v.sum()) for v in excl.values()) > 0
    assert stats["frontier_occupancy"].shape == (len(enc.levels),)
    assert int(stats["frontier_occupancy"][0]) >= len(q)  # roots all live

    # empty batch conforms with all-zero attribution
    _, es = forest_range_search(enc, q[:0], t)
    check_stats(es)
    assert all(v.shape == (0,) for v in es["excluded"].values())


def test_monotone_stats_conform():
    from repro.core import lrt
    from repro.forest import encode_monotone, monotone_range_search

    db = _space("l2", 500, seed=31)
    q = _space("l2", 6, seed=32)
    mt = lrt.build_monotone_tree("closer", "far", "l2", db, seed=1)
    enc = encode_monotone(mt)
    t = _snap(pairwise_np("l2", q, db), 0.04)
    _, stats = monotone_range_search(enc, q, t)
    check_stats(stats)
    assert stats["engine"] == "monotone"
    assert set(stats["excluded"]) <= set(MECHANISMS)


def test_validator_catches_tampering():
    idx, db, q, t = _bss_built()
    _, stats = flat_index.bss_query_batched(idx, q, t)
    assert validate_stats(stats) == []
    bad = dict(stats)
    bad["excluded"] = {"warp-drive": stats["excluded"]["hilbert"]}
    assert any("warp-drive" in p for p in validate_stats(bad))
    bad = dict(stats)
    bad["excluded"] = {"hilbert": np.zeros(3, np.int64)}  # wrong shape
    assert any("hilbert" in p for p in validate_stats(bad))
    bad = dict(stats)
    bad["dists_per_query"] = stats["dists_per_query"] + 5.0
    assert any("dists_per_query" in p for p in validate_stats(bad))
    bad = dict(stats)
    del bad["engine"]
    assert any("missing core key" in p for p in validate_stats(bad))
    assert validate_stats("nope") == ["stats is str, expected dict"]
    with pytest.raises(ValueError, match="schema violation"):
        check_stats({"schema": 1})


# ----------------------------------------------------------------- folding


def test_fold_engine_stats_counters():
    reg = MetricsRegistry()
    stats = {
        "engine": "bss", "kind": "range", "n_queries": 3,
        "per_query_dists": np.array([10, 20, 30], np.int64),
        "dists_per_query": 20.0,
        "excluded": {"hilbert": np.array([1, 2, 3], np.int64)},
        "tiles_computed": 7, "tile_exclusion_rate": 0.5,
        "frontier_occupancy": np.array([3, 5], np.int64),
        "precision": "fp32",
    }
    fold_engine_stats(reg, stats)
    fold_engine_stats(reg, stats)  # counters accumulate across calls
    snap = reg.snapshot()
    c = snap["counters"]
    assert c["engine/queries{engine=bss,kind=range}"] == 6.0
    assert c["engine/dists{engine=bss,kind=range}"] == 120.0
    assert c["engine/excluded{engine=bss,kind=range,mechanism=hilbert}"] \
        == 12.0
    assert c["engine/tiles_computed{engine=bss,kind=range}"] == 14.0
    assert c["engine/frontier_nodes{engine=bss,kind=range,level=1}"] == 10.0
    assert snap["gauges"]["engine/tile_exclusion_rate{engine=bss,kind=range}"] \
        == 0.5
    h = snap["histograms"]["engine/dists_per_query{engine=bss,kind=range}"]
    assert h["count"] == 6
    # pre-schema dicts fold without error and contribute only what they have
    fold_engine_stats(MetricsRegistry(), {"dists_per_query": 4.0})


def test_poll_compile_counts_growth():
    import jax

    f = jax.jit(lambda x: x + 1)
    if jit_cache_size(f) < 0:
        pytest.skip("this jax exposes no jit cache hook")
    reg = MetricsRegistry()
    f(np.zeros(3, np.float32))
    last = poll_compile(reg, {"f": f})
    f(np.zeros(4, np.float32))  # new shape -> new cache entry
    poll_compile(reg, {"f": f}, last)
    snap = reg.snapshot()
    assert snap["counters"]["compile/recompiles{fn=f}"] == 1.0
    assert snap["gauges"]["compile/cache_size{fn=f}"] == 2.0


# --------------------------------------- metrics-on/off bit-identity (ISSUE)


@pytest.mark.parametrize("metric", ["l2", "cosine", "jsd", "triangular"])
def test_metrics_on_off_bit_identity(metric):
    """The acceptance bar: a metrics-on front and a metrics-off front
    return bit-identical hits, neighbours, distances and counts on every
    supermetric — collection is observation, never perturbation."""
    data = _space(metric, 660, seed=7)
    db, q = data[:640], data[640:]
    idx = flat_index.build_bss(metric, db, n_pivots=8, n_pairs=10,
                               block=64, seed=9)
    t = _snap(pairwise_np(metric, q, db), 0.04)
    k = 4

    def run(metrics_on):
        with ServingFront(idx, buckets=(8, 32), max_delay_s=0.02,
                          metrics=metrics_on) as front:
            futs = [
                front.submit(qv, "knn", k=k) if i % 3 == 1
                else front.submit(qv, "range", t=t)
                for i, qv in enumerate(q)
            ]
            return [f.result(timeout=120) for f in futs]

    on, off = run(True), run(False)
    ref_hits, ref_s = flat_index.bss_query_batched(idx, q, t, opts=_DENSE)
    ref_i, ref_d, _ = flat_index.bss_knn_batched(idx, q, k, opts=_DENSE)
    for i, (a, b) in enumerate(zip(on, off)):
        assert a.n_dists == b.n_dists, (metric, i)
        if i % 3 == 1:
            assert (a.indices == b.indices).all(), (metric, i)
            assert (a.distances == b.distances).all(), (metric, i)
            assert (a.indices == ref_i[i]).all(), (metric, i)
            assert (a.distances == ref_d[i]).all(), (metric, i)
        else:
            assert a.hits == b.hits == ref_hits[i], (metric, i)
            assert a.n_dists == ref_s["per_query_dists"][i], (metric, i)


def test_metrics_off_front_stays_dark():
    idx, db, q, t = _bss_built()
    with ServingFront(idx, max_delay_s=0.01, metrics=False) as front:
        r = front.submit(q[0], "range", t=t).result(timeout=120)
        snap = front.metrics().snapshot()
    assert r.trace_id  # spans always ride the request
    assert snap == {"counters": {}, "gauges": {}, "histograms": {}}
    assert front.explain() is None


# ------------------------------------------------- spans + explain through


def test_front_spans_and_explain():
    idx, db, q, t = _bss_built()
    with ServingFront(idx, buckets=(8,), max_delay_s=0.01,
                      cache_size=16) as front:
        res = [front.submit(qv, "range", t=t).result(timeout=120)
               for qv in q[:5]]
        hit = front.submit(q[0], "range", t=t).result(timeout=120)
        reg = front.metrics()
        snap = reg.snapshot()
        rec = front.explain(res[2].trace_id)
        latest = front.explain()

    ids = [r.trace_id for r in res]
    assert len(set(ids)) == 5 and all(ids)
    for r in res:
        assert set(r.spans) == {"queue", "batch", "engine", "demux",
                                "total"}
        assert all(v >= 0.0 for v in r.spans.values())
        assert r.spans["total"] >= r.spans["engine"]
    # cache hits keep their own trace but never reach the engine: asking
    # for their id is a KeyError naming the ring capacity
    assert hit.cache_hit and hit.trace_id not in ids
    with pytest.raises(KeyError, match="last 256 dispatched"):
        front.explain(hit.trace_id)

    assert rec is not None and rec["trace_id"] == res[2].trace_id
    assert rec["kind"] == "range" and rec["n_dists"] == res[2].n_dists
    assert set(rec["excluded"]) == {"hilbert"}
    assert rec["excluded"]["hilbert"] >= 0
    assert latest["trace_id"] == res[-1].trace_id

    c = snap["counters"]
    assert c["engine/queries{engine=bss,kind=range}"] == 5.0
    assert c["serve/cache_hits"] == 1.0
    assert snap["histograms"]["serve/batch_size{kind=range}"]["count"] >= 1
    assert any(k.startswith("serve/span_s") for k in snap["histograms"])
    assert snap["gauges"]["compile/ladder_buckets"] >= 1
    assert validate_exposition(reg.to_prometheus()) == []


def test_front_forest_explain_attribution():
    db = _space("l2", 600, seed=41)
    q = _space("l2", 6, seed=42)
    tr = tree.build_tree("hpt_fft_log", "l2", db, seed=43)
    enc = encode_tree(tr)
    t = _snap(pairwise_np("l2", q, db), 0.04)
    with ServingFront(enc, buckets=(8,), max_delay_s=0.01) as front:
        res = [front.submit(qv, "range", t=t).result(timeout=120)
               for qv in q]
        recs = [front.explain(r.trace_id) for r in res]
        snap = front.metrics().snapshot()
    for rec in recs:
        assert rec["engine"] == "forest"
        assert set(rec["excluded"]) <= set(MECHANISMS)
    assert any(
        k.startswith("engine/frontier_nodes") for k in snap["counters"]
    )


# --------------------------------------------------- jaxpr-audit self-check


def test_instrumented_engines_have_zero_callbacks():
    """The obs outputs are functional jit returns: tracing the very entry
    points that now carry the counters shows no callback primitive
    anywhere in their jaxprs (the PR 7 audit, run on the PR 8 engines)."""
    from repro.analysis.jaxpr_audit import (
        _check_no_callbacks,
        _patched_engines,
        _Recorder,
    )

    idx, db, q, t = _bss_built()
    tr = tree.build_tree("hpt_fft_log", "l2", db, seed=51)
    enc = encode_tree(tr)
    rec = _Recorder()
    with _patched_engines(rec):
        flat_index.bss_query_batched(idx, q, t, opts=_DENSE)
        flat_index.bss_knn_batched(idx, q, 3, opts=_DENSE)
        forest_range_search(enc, q, t)
    fns = {c.fn for c in rec.captures}
    assert "_forest_walk_jit" in fns and "_dense_hit_mask_jit" in fns
    assert "_knn_round_jit" in fns
    for cap in rec.captures:
        assert _check_no_callbacks(cap) == [], cap.fn


# ----------------------------------------------------------------- export


def test_write_snapshot(tmp_path):
    reg = MetricsRegistry()
    reg.counter("engine/dists").inc(3)
    p = write_snapshot(reg, tmp_path / "OBS_snapshot.json",
                       extra={"stats": {"x": np.int64(4),
                                        "a": np.arange(2)}})
    payload = json.loads(p.read_text())
    assert payload["metrics"]["counters"]["engine/dists"] == 3.0
    assert payload["stats"] == {"x": 4, "a": [0, 1]}


def test_retrieval_server_folds_metrics():
    from repro.serve.retrieval import RetrievalServer

    rng = np.random.default_rng(0)
    corpus = rng.normal(size=(400, DIM)).astype(np.float32)
    srv = RetrievalServer(corpus, metric="cosine", seed=1)
    q = rng.normal(size=(4, DIM)).astype(np.float32)
    srv.range_query(q, 0.2)
    srv.top_k(q, 3)
    c = srv.metrics.snapshot()["counters"]
    assert c["engine/queries{engine=bss,kind=range}"] == 4.0
    assert c["engine/queries{engine=bss,kind=knn}"] == 4.0
    assert srv.metrics.snapshot()["histograms"]["serve/call_s"]["count"] == 2


# ----------------------------------------------------------------- buckets


def test_log_ladder_shape_and_overrides():
    lad = log_ladder(1e-2, 1e2, per_decade=2)
    assert lad[0] == pytest.approx(1e-2) and lad[-1] == pytest.approx(1e2)
    assert all(a < b for a, b in zip(lad, lad[1:]))
    assert len(lad) == 9  # 4 decades x 2 + endpoint
    # per-metric overrides resolve; unknown names get the default ladder
    assert ladder_for("serve/engine_s") != DEFAULT_LADDER
    assert ladder_for("serve/batch_size") == (1, 2, 4, 8, 16, 32, 64, 128,
                                              256)
    assert ladder_for("not/a_metric") == DEFAULT_LADDER
    with pytest.raises(ValueError, match="lo < hi"):
        log_ladder(10.0, 1.0)


def test_histogram_cumulative_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("h", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 5.0, 50.0, 500.0, 10.0):  # 10.0 on the boundary: le
        h.observe(v)
    bc = h.bucket_counts()
    assert [le for le, _ in bc] == [1.0, 10.0, 100.0, float("inf")]
    assert [c for _, c in bc] == [1, 3, 4, 5]
    assert h.summary()["buckets"] == {"1": 1, "10": 3, "100": 4, "+Inf": 5}
    # same series again is fine; a DIFFERENT ladder for the same series is
    # a registration error, as is a malformed ladder
    assert reg.histogram("h", buckets=(1.0, 10.0, 100.0)) is h
    with pytest.raises(ValueError, match="buckets"):
        reg.histogram("h", buckets=(1.0, 2.0))
    with pytest.raises(ValueError, match="strictly increase"):
        reg.histogram("h2", buckets=(3.0, 2.0))


def test_validate_exposition_catches_broken_histogram():
    reg = MetricsRegistry()
    reg.histogram("serve/engine_s", kind="x").observe(0.2)
    good = reg.to_prometheus()
    assert validate_exposition(good) == []
    # non-cumulative bucket counts must be flagged
    broken = good.replace(
        'serve_engine_s_bucket{kind="x",le="+Inf"} 1',
        'serve_engine_s_bucket{kind="x",le="+Inf"} 0',
    )
    assert broken != good
    assert any("cumulative" in p or "+Inf" in p
               for p in validate_exposition(broken))
    # a histogram family without its +Inf bucket is invalid
    lines = [ln for ln in good.splitlines() if 'le="+Inf"' not in ln]
    assert any("+Inf" in p for p in validate_exposition("\n".join(lines)))


# ------------------------------------------------ shard-imbalance telemetry


def test_shard_imbalance_units():
    assert shard_imbalance([]) == 1.0
    assert shard_imbalance([0, 0, 0]) == 1.0
    assert shard_imbalance([5, 5, 5, 5]) == 1.0
    assert shard_imbalance([12, 0, 0, 0]) == 4.0
    assert shard_imbalance(np.array([3, 1])) == pytest.approx(1.5)


def test_fold_shard_telemetry():
    reg = MetricsRegistry()
    stats = {
        "engine": "sharded", "kind": "range", "n_queries": 2,
        "per_query_dists": np.array([5, 7], np.int64),
        "dists_per_query": 6.0, "excluded": {},
        "shard_dists": np.array([9, 3], np.int64),
        "shard_blocks": np.array([2, 1], np.int64),
    }
    fold_engine_stats(reg, stats)
    snap = reg.snapshot()
    c = snap["counters"]
    assert c["shard/dists{engine=sharded,kind=range,shard=0}"] == 9.0
    assert c["shard/dists{engine=sharded,kind=range,shard=1}"] == 3.0
    assert c["shard/blocks{engine=sharded,kind=range,shard=0}"] == 2.0
    g = snap["gauges"]["shard/imbalance{engine=sharded,kind=range}"]
    assert g == pytest.approx(shard_imbalance([9, 3])) == pytest.approx(1.5)
    assert "shard/imbalance" in reg.render()
    # single-device stats without the shard split fold nothing shard-wise
    reg2 = MetricsRegistry()
    fold_engine_stats(reg2, {k: v for k, v in stats.items()
                             if not k.startswith("shard_")})
    assert not any(k.startswith("shard/")
                   for k in reg2.snapshot()["counters"])


def test_metric_names_schema_is_complete():
    # every name the obs layer itself registers is in the R6 namespace
    for name in ("engine/dists", "shard/imbalance", "serve/span_s",
                 "index/mutation_s", "compile/recompiles"):
        assert name in METRIC_NAMES


# ------------------------------------------------------- trace-event export


def test_trace_event_round_trip(tmp_path):
    evs = [
        complete_event("phase", 1.0, 0.5, tid=3, args={"k": 1}),
        instant_event("ping", 2.0, tid=3),
        metadata_event("thread_name", "req t000003", tid=3),
    ]
    p = write_trace(tmp_path / "t.json", evs, extra={"note": "unit"})
    payload = load_trace(p)
    assert validate_trace(payload) == []
    got = payload["traceEvents"]
    # metadata events sort first; ts/dur are microseconds on one clock
    assert got[0]["ph"] == "M"
    x = [e for e in got if e["ph"] == "X"][0]
    assert x["ts"] == pytest.approx(1.0e6) and x["dur"] == pytest.approx(5e5)
    assert payload["otherData"]["note"] == "unit"
    # negative duration is clamped, never emitted
    assert complete_event("x", 5.0, -1.0, tid=0)["dur"] == 0


def test_trace_buffer_is_a_ring():
    buf = TraceBuffer(capacity=3)
    buf.extend(instant_event(f"e{i}", float(i), tid=0) for i in range(5))
    names = [e["name"] for e in buf.events()]
    assert names == ["e2", "e3", "e4"] and len(buf) == 3


def test_validate_trace_flags_problems():
    assert validate_trace({"nope": 1})
    bad = {"traceEvents": [
        {"ph": "Z", "name": "x", "pid": 1, "tid": 0, "ts": 0.0},
        {"ph": "X", "pid": 1, "tid": 0, "ts": 0.0, "dur": 1.0},
        {"ph": "X", "name": "y", "pid": 1, "tid": 0, "ts": float("nan"),
         "dur": 1.0},
    ]}
    problems = validate_trace(bad)
    assert len(problems) >= 3


def test_front_trace_export_end_to_end(tmp_path):
    """The tentpole acceptance: a serving run (with ``profile_dir=``, so
    engine dispatches are also wrapped in jax-profiler annotations)
    exports a Perfetto-loadable trace holding the admit->demux request
    spans, the driver's dispatch phase slices, and the index mutation
    events — all on the one serving clock."""
    idx, db, q, t = _bss_built()
    prof = tmp_path / "prof"
    with ServingFront(idx, buckets=(8,), max_delay_s=0.01, cache_size=4,
                      profile_dir=str(prof)) as front:
        r1 = front.submit(q[0], "range", t=t).result(timeout=120)
        ms = front.append(_space("l2", 64, seed=6))
        r2 = front.submit(q[1], "knn", k=3).result(timeout=120)
        front.compact()
        r3 = front.submit(q[2], "range", t=t).result(timeout=120)
        path = front.export_trace(tmp_path / "trace.json")

    payload = load_trace(path)
    assert validate_trace(payload) == []
    evs = payload["traceEvents"]
    names = {e["name"] for e in evs}
    assert {"queue", "batch", "engine", "demux"} <= names
    assert {"dispatch/assemble", "dispatch/engine", "dispatch/demux"} \
        <= names
    assert {"mutation/append", "mutation/compact"} <= names
    assert payload["otherData"]["engine"] == "bss"
    assert ms.generation == 1

    # each request rides its own tid track with the four stage slices
    for r in (r1, r2, r3):
        tid = int(r.trace_id[1:])
        mine = {e["name"] for e in evs
                if e.get("tid") == tid and e["ph"] == "X"}
        assert mine == {"queue", "batch", "engine", "demux"}, r.trace_id
    # one clock: r1 finished before the append started, which finished
    # before r2 was admitted — event timestamps must agree on that order
    append_ev = next(e for e in evs if e["name"] == "mutation/append")
    r1_demux = next(e for e in evs if e["name"] == "demux"
                    and e["tid"] == int(r1.trace_id[1:]))
    r2_queue = next(e for e in evs if e["name"] == "queue"
                    and e["tid"] == int(r2.trace_id[1:]))
    assert r1_demux["ts"] + r1_demux["dur"] <= append_ev["ts"] + 1.0
    assert append_ev["ts"] + append_ev["dur"] <= r2_queue["ts"] + 1.0
    # the jax profiler actually ran around the dispatches
    assert prof.exists() and any(prof.rglob("*"))


def test_explain_and_spans_survive_generation_swap():
    """Trace ids and explain records must survive living-corpus mutations:
    a request dispatched on generation g keeps its record (stamped with g)
    after appends and compactions have swapped the index under the
    front."""
    idx, db, q, t = _bss_built()
    with ServingFront(idx, buckets=(8,), max_delay_s=0.01) as front:
        r1 = front.submit(q[0], "range", t=t).result(timeout=120)
        front.append(_space("l2", 96, seed=16))          # gen 0 -> 1
        r2 = front.submit(q[1], "range", t=t).result(timeout=120)
        front.compact()                                  # gen 1 -> 2
        r3 = front.submit(q[2], "knn", k=3).result(timeout=120)
        recs = {r.trace_id: front.explain(r.trace_id)
                for r in (r1, r2, r3)}
        trace_evs = front._trace.events()

    assert [recs[r.trace_id]["generation"] for r in (r1, r2, r3)] \
        == [0, 1, 2]
    for r in (r1, r2, r3):
        rec = recs[r.trace_id]
        assert rec["trace_id"] == r.trace_id
        assert rec["n_dists"] == r.n_dists
        assert set(rec["spans"]) >= {"queue", "engine", "total"}
        # the span slices for every request are still in the trace buffer
        tids = {e.get("tid") for e in trace_evs}
        assert int(r.trace_id[1:]) in tids
    # generation swaps were real: results were served on three snapshots
    assert (r1.generation, r2.generation, r3.generation) == (0, 1, 2)

"""Async serving front vs direct engine calls.

The contract under test (the ISSUE-5 acceptance bar): for interleaved
range+kNN request streams, the front returns hits and per-query distance
counts BIT-IDENTICAL to direct ``bss_query_batched`` / ``bss_knn_batched``
/ forest-walker calls — over l2/cosine/jsd, bucketed batch sizes including
1 and beyond the largest bucket, and a mesh-built index on a simulated
8-device mesh — with jit compile counts bounded by the bucket ladder and
padding rows provably excluded from the distance accounting.

References are pinned to ``realisation="dense"`` (what the front itself
dispatches, and the same pin the sharded tests use): the adaptive sparse
path may differ in the last ulp, which never changes results but can shift
a kNN radius schedule by one comparison.
"""

from __future__ import annotations

import functools

import numpy as np
import pytest
from multidevice_shim import run_simulated_mesh

from repro.core import flat_index
from repro.core.backends import EngineOpts, jit_cache_size
from repro.core.npdist import pairwise_np
from repro.serve.front import ServingFront, ShedError

DIM = 16
DENSE = EngineOpts(realisation="dense")


def _space(metric: str, n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = rng.random((n, DIM)).astype(np.float32) + 1e-3
    if metric == "jsd":
        x /= x.sum(axis=1, keepdims=True)
    return x


def _snap(dvals: np.ndarray, frac: float) -> float:
    """Threshold near the given quantile, snapped to a well-separated gap
    midpoint so float32 engines agree on every d <= t (the idiom of
    tests/test_bss_engine.py)."""
    vals = np.unique(np.sort(np.asarray(dvals, np.float64).ravel()))
    i = int(np.clip(frac * len(vals), 0, len(vals) - 2))
    for j in range(i, len(vals) - 1):
        if vals[j + 1] - vals[j] > 1e-4 * max(1.0, vals[j]):
            return float(0.5 * (vals[j] + vals[j + 1]))
    return float(vals[-1] + 1.0)


@functools.lru_cache(maxsize=None)
def _built(metric: str):
    """(index, queries, [t_small, t_mid, t_large]) per metric, cached."""
    data = _space(metric, 1640, seed=3)
    db, q = data[:1600], data[1600:]
    idx = flat_index.build_bss(metric, db, n_pivots=8, n_pairs=10, block=64,
                               seed=5)
    d = pairwise_np(metric, q, db)
    return idx, q, [_snap(d, 0.01), _snap(d, 0.03), _snap(d, 0.06)]


def _drain(futs, timeout=120):
    return [f.result(timeout=timeout) for f in futs]


# --------------------------------------------------------- bit-identity


@pytest.mark.parametrize("metric", ["l2", "cosine", "jsd"])
def test_interleaved_stream_bit_identical(metric):
    """Mixed range (three per-request thresholds) + kNN stream through the
    front == direct engine calls, row for row: hits, kNN neighbours and
    distances, and per-query distance counts."""
    idx, q, ts = _built(metric)
    k = 4
    reqs = [("range", ts[i % 3]) if i % 3 != 1 else ("knn", k)
            for i in range(len(q))]
    with ServingFront(idx, buckets=(8, 32), max_delay_s=0.05) as front:
        futs = [
            front.submit(q[i], kind, t=arg) if kind == "range"
            else front.submit(q[i], kind, k=arg)
            for i, (kind, arg) in enumerate(reqs)
        ]
        res = _drain(futs)

    r_rows = [i for i, (kind, _) in enumerate(reqs) if kind == "range"]
    k_rows = [i for i, (kind, _) in enumerate(reqs) if kind == "knn"]
    t_vec = np.array([reqs[i][1] for i in r_rows], np.float32)
    ref_hits, ref_stats = flat_index.bss_query_batched(
        idx, q[r_rows], t_vec, opts=DENSE
    )
    for j, i in enumerate(r_rows):
        assert res[i].hits == ref_hits[j], (metric, i)
        assert res[i].n_dists == ref_stats["per_query_dists"][j], (metric, i)
    ref_i, ref_d, ref_ks = flat_index.bss_knn_batched(
        idx, q[k_rows], k, opts=DENSE
    )
    for j, i in enumerate(k_rows):
        assert (res[i].indices == ref_i[j]).all(), (metric, i)
        assert (res[i].distances == ref_d[j]).all(), (metric, i)
        assert res[i].n_dists == ref_ks["per_query_dists"][j], (metric, i)

    # a batch-1 direct call is the same row too (the front may have served
    # it inside any bucket)
    i = r_rows[0]
    h1, s1 = flat_index.bss_query_batched(
        idx, q[i : i + 1], float(reqs[i][1]), opts=DENSE
    )
    assert res[i].hits == h1[0]
    assert res[i].n_dists == s1["per_query_dists"][0]


def test_batch_sizes_one_and_beyond_largest_bucket():
    """A lone request rides the smallest bucket; a burst larger than the
    top bucket splits into ladder-sized dispatches — results identical to
    per-request direct calls either way."""
    idx, q, ts = _built("l2")
    t = ts[1]
    with ServingFront(idx, buckets=(4, 8), max_delay_s=0.02) as front:
        lone = front.submit(q[0], "range", t=t).result(timeout=120)
        futs = [front.submit(qv, "range", t=t) for qv in q[:21]]
        res = _drain(futs)
        stats = front.stats()
    assert lone.batch_size == 1 and lone.padded_to == 4
    ref, ref_s = flat_index.bss_query_batched(
        idx, q[:21], t, opts=DENSE
    )
    for i in range(21):
        assert res[i].hits == ref[i]
        assert res[i].n_dists == ref_s["per_query_dists"][i]
        assert res[i].padded_to in (4, 8)
    # 21 requests can never fit one 8-bucket dispatch
    assert stats["batches"] >= 4
    assert set(stats["per_bucket_batches"]) <= {4, 8}


# ------------------------------------------- compile guard + padding proof


def test_padded_rows_provably_excluded_from_counts():
    """The front's padding contract at the engine level: rows with a
    negative radius survive no block, are charged only the unavoidable
    pivot distances, and hit nothing — and the real rows are exactly the
    unpadded call's rows."""
    idx, q, ts = _built("l2")
    n_pivots = idx.pivots.shape[0]
    t_vec = np.full(8, ts[1], np.float32)
    t_vec[5:] = -1.0
    qpad = np.concatenate([q[:5], np.repeat(q[:1], 3, axis=0)])
    hits, stats = flat_index.bss_query_batched(
        idx, qpad, t_vec, opts=DENSE
    )
    assert (stats["per_query_dists"][5:] == n_pivots).all()
    assert all(hits[i] == [] for i in range(5, 8))
    ref, ref_s = flat_index.bss_query_batched(
        idx, q[:5], ts[1], opts=DENSE
    )
    assert hits[:5] == ref
    assert (stats["per_query_dists"][:5] == ref_s["per_query_dists"]).all()
    # the oracle agrees on the whole padded batch, padding rows included
    oracle, oracle_s = flat_index.bss_query(idx, qpad, t_vec)
    assert hits == oracle
    assert (oracle_s["per_query_dists"] == stats["per_query_dists"]).all()


def _sweep_sizes(front, q, t, k, n_max):
    """Submit range+knn waves of every batch size 1..n_max, draining each
    wave so group sizes are deterministic."""
    for n in range(1, n_max + 1):
        _drain([front.submit(qv, "range", t=t) for qv in q[:n]])
        _drain([front.submit(qv, "knn", k=k) for qv in q[:n]])


def test_compile_guard_jnp_backend():
    """Sweeping batch sizes 1..10 through a (4, 8) ladder compiles each
    jitted engine entry point at most len(buckets) times per (kind,
    metric): the dense realisation's shapes are fixed by the bucket."""
    idx, q, ts = _built("l2")
    fns = {
        "range/lb": flat_index._lower_bounds_jit,
        "range/dense": flat_index._dense_hit_mask_jit,
        "knn/lb": flat_index._knn_lb_jit,
        "knn/round": flat_index._knn_round_jit,
    }
    before = {name: jit_cache_size(fn) for name, fn in fns.items()}
    if any(v < 0 for v in before.values()):
        pytest.skip("this jax exposes no jit cache hook")
    with ServingFront(idx, buckets=(4, 8), max_delay_s=0.02,
                      opts=EngineOpts(backend="jnp")) as front:
        _sweep_sizes(front, q, ts[1], 3, n_max=10)
    for name, fn in fns.items():
        grew = jit_cache_size(fn) - before[name]
        assert grew <= 2, (name, grew)


def test_compile_guard_pallas_interpret():
    """Same bound through the Pallas kernel path (interpret mode): the
    fused range pass is one jit whose cache grows by at most the ladder."""
    db = _space("l2", 320, seed=11)
    q = _space("l2", 12, seed=12)
    idx = flat_index.build_bss("l2", db, n_pivots=6, n_pairs=8, block=64,
                               seed=13)
    t = _snap(pairwise_np("l2", q, db), 0.05)
    before = jit_cache_size(flat_index._query_batched_jit)
    if before < 0:
        pytest.skip("this jax exposes no jit cache hook")
    sizes = (1, 3, 4, 5, 8)
    results = {}
    with ServingFront(idx, buckets=(4, 8), max_delay_s=0.02,
                      opts=EngineOpts(backend="pallas",
                                      interpret=True)) as front:
        for n in sizes:
            results[n] = _drain(
                [front.submit(qv, "range", t=t) for qv in q[:n]]
            )
    # bound first: the reference calls below compile UNBUCKETED shapes
    assert jit_cache_size(flat_index._query_batched_jit) - before <= 2
    for n in sizes:
        ref, _ = flat_index.bss_query_batched(
            idx, q[:n], t,
            opts=EngineOpts(backend="pallas", interpret=True),
        )
        assert [r.hits for r in results[n]] == ref, n


# ----------------------------------------------------------------- forest


def test_forest_front_groups_by_threshold():
    """A forest front serves range streams through the jitted walker —
    per-request results and counts equal to direct walker calls — and
    groups per threshold (the walker takes one scalar t)."""
    from repro.core import tree
    from repro.forest import encode_tree, forest_range_search

    db = _space("l2", 700, seed=21)
    q = _space("l2", 10, seed=22)
    tr = tree.build_tree("hpt_fft_log", "l2", db, seed=23)
    enc = encode_tree(tr)
    d = pairwise_np("l2", q, db)
    t1, t2 = _snap(d, 0.02), _snap(d, 0.05)
    with ServingFront(enc, buckets=(8, 32), max_delay_s=0.05) as front:
        futs = [front.submit(q[i], "range", t=(t1 if i % 2 else t2))
                for i in range(len(q))]
        res = _drain(futs)
        with pytest.raises(NotImplementedError, match="BSS.*ROADMAP"):
            front.submit(q[0], "knn", k=3)
        stats = front.stats()
    assert stats["batches"] == 2  # one dispatch per distinct threshold
    for i in range(len(q)):
        t_i = t1 if i % 2 else t2
        ref, ref_s = forest_range_search(enc, q[i : i + 1], t_i)
        assert res[i].hits == ref[0], i
        assert res[i].n_dists == ref_s["per_query_dists"][0], i


# ------------------------------------------- admission, cache, lifecycle


def test_admission_shed_and_block_timeout():
    idx, q, ts = _built("l2")
    front = ServingFront(idx, max_queue=2, admission="shed", start=False)
    front.submit(q[0], "range", t=ts[0])
    front.submit(q[1], "range", t=ts[0])
    with pytest.raises(ShedError, match="shed"):
        front.submit(q[2], "range", t=ts[0])
    assert front.stats()["shed"] == 1
    assert front.stats()["submitted"] == 3
    front.close()

    blk = ServingFront(idx, max_queue=1, admission="block", start=False)
    blk.submit(q[0], "range", t=ts[0])
    with pytest.raises(ShedError, match="timed out"):
        blk.submit(q[1], "range", t=ts[0], timeout=0.05)
    blk.close()


def test_exact_hit_lru_cache():
    idx, q, ts = _built("l2")
    with ServingFront(idx, cache_size=4, max_delay_s=0.005) as front:
        first = front.submit(q[0], "range", t=ts[1]).result(timeout=120)
        again = front.submit(q[0], "range", t=ts[1]).result(timeout=120)
        other_t = front.submit(q[0], "range", t=ts[2]).result(timeout=120)
        stats = front.stats()
    assert not first.cache_hit and again.cache_hit
    assert again.hits == first.hits and again.n_dists == first.n_dists
    assert not other_t.cache_hit  # params are part of the key
    assert stats["cache_hits"] == 1
    assert stats["batches"] == 2  # the hit never reached the engine


def test_validation_and_lifecycle():
    idx, q, ts = _built("l2")
    front = ServingFront(idx, start=False)
    with pytest.raises(ValueError, match="ONE query"):
        front.submit(q[:2], "range", t=ts[0])
    with pytest.raises(ValueError, match="need t="):
        front.submit(q[0], "range")
    with pytest.raises(ValueError, match="padding sentinel"):
        front.submit(q[0], "range", t=-0.5)
    with pytest.raises(ValueError, match="positive k"):
        front.submit(q[0], "knn")
    with pytest.raises(ValueError, match="kind"):
        front.submit(q[0], "nearest", t=ts[0])
    front.close()
    front.close()  # idempotent
    with pytest.raises(ShedError, match="closed"):
        front.submit(q[0], "range", t=ts[0])
    with pytest.raises(TypeError, match="BSSIndex"):
        ServingFront(object())
    with pytest.raises(ValueError, match="ladder"):
        ServingFront(idx, buckets=(8, 4), start=False)
    with pytest.raises(ValueError, match="admission"):
        ServingFront(idx, admission="drop", start=False)


def test_cancelled_future_does_not_poison_batch():
    """A client cancelling a queued future (the standard timeout move) must
    not affect the other requests in its micro-batch."""
    idx, q, ts = _built("l2")
    front = ServingFront(idx, buckets=(8,), max_delay_s=0.5, start=False)
    futs = [front.submit(qv, "range", t=ts[1]) for qv in q[:6]]
    assert futs[2].cancel() and futs[4].cancel()
    front.start()
    res = [futs[i].result(timeout=120) for i in range(6) if i not in (2, 4)]
    front.close()
    ref, _ = flat_index.bss_query_batched(
        idx, q[:6], ts[1], opts=DENSE
    )
    for r, i in zip(res, (0, 1, 3, 5)):
        assert r.hits == ref[i], i
    assert front.stats()["errors"] == 0


def test_queue_wait_and_padding_telemetry():
    idx, q, ts = _built("l2")
    with ServingFront(idx, buckets=(8, 32), max_delay_s=0.01) as front:
        res = _drain([front.submit(qv, "range", t=ts[1]) for qv in q[:5]])
        stats = front.stats()
    assert all(r.queue_wait_s >= 0.0 for r in res)
    assert all(r.engine_s > 0.0 for r in res)
    assert stats["completed"] == 5
    assert stats["padded_rows"] >= 3  # 5 real rows in 8-buckets minimum
    assert 0.0 < stats["padding_waste"] < 1.0
    assert stats["queue_wait_s"]["p95"] >= stats["queue_wait_s"]["p50"] >= 0


# ------------------------------------------------------------ mesh-built

_MESH_FRONT = """
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import flat_index
    from repro.core.npdist import pairwise_np
    from repro.serve.front import ServingFront

    def snap(dvals, frac):
        vals = np.unique(np.sort(np.asarray(dvals, np.float64).ravel()))
        i = int(np.clip(frac * len(vals), 0, len(vals) - 2))
        for j in range(i, len(vals) - 1):
            if vals[j + 1] - vals[j] > 1e-4 * max(1.0, vals[j]):
                return float(0.5 * (vals[j] + vals[j + 1]))
        return float(vals[-1] + 1.0)

    rng = np.random.default_rng(7)
    x = rng.random((1400, 12)).astype(np.float32)
    db, q = x[:1376], x[1376:]
    mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
    idx = flat_index.build_bss("l2", db, n_pivots=8, n_pairs=10, block=64,
                               seed=9, mesh=mesh)
    d = pairwise_np("l2", q, db)
    t1, t2 = snap(d, 0.02), snap(d, 0.05)
    k = 3
    with ServingFront(idx, buckets=(8, 32), max_delay_s=0.05) as front:
        futs = [
            front.submit(q[i], "knn", k=k) if i % 3 == 1
            else front.submit(q[i], "range", t=(t1 if i % 3 else t2))
            for i in range(len(q))
        ]
        res = [f.result(timeout=300) for f in futs]
    r_rows = [i for i in range(len(q)) if i % 3 != 1]
    k_rows = [i for i in range(len(q)) if i % 3 == 1]
    t_vec = np.array([t1 if i % 3 else t2 for i in r_rows], np.float32)
    ref, rs = flat_index.bss_query_batched(idx, q[r_rows], t_vec)
    assert rs["n_shards"] == 8
    for j, i in enumerate(r_rows):
        assert res[i].hits == ref[j], i
        assert res[i].n_dists == rs["per_query_dists"][j], i
    ki, kd, ks = flat_index.bss_knn_batched(idx, q[k_rows], k)
    for j, i in enumerate(k_rows):
        assert (res[i].indices == ki[j]).all(), i
        assert (res[i].distances == kd[j]).all(), i
        assert res[i].n_dists == ks["per_query_dists"][j], i
    print("MESH_FRONT_OK")
"""


def test_front_on_mesh_built_index():
    """The front over a mesh-built index serves through the sharded engine
    (8 simulated devices): interleaved mixed-threshold range + kNN, rows
    and counts identical to direct sharded calls."""
    out = run_simulated_mesh(_MESH_FRONT, 8, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "MESH_FRONT_OK" in out.stdout


# --------------------------------------- input hygiene + canonical cache key


def test_non_finite_queries_rejected_at_admission():
    """NaN/Inf queries — including float64 values that overflow the float32
    cast — must raise at submit, before they can ride into a shared
    micro-batch or become an unmatchable NaN cache entry."""
    idx, q, ts = _built("l2")
    front = ServingFront(idx, cache_size=4, start=False)
    bad = q[0].copy()
    for poison in (np.nan, np.inf, -np.inf):
        bad[3] = poison
        with pytest.raises(ValueError, match="finite"):
            front.submit(bad, "range", t=ts[0])
    with pytest.raises(ValueError, match="finite"):
        front.submit(np.full(DIM, 1e40, np.float64), "range", t=ts[0])
    with pytest.raises(ValueError, match="precision"):
        front.submit(q[0], "range", t=ts[0], precision="fp64")
    front.close()
    assert front.stats()["submitted"] == 0  # rejected before admission


def test_cache_key_is_canonical():
    """Regression for the repr-based key: typed slots (t=1 and t=1.0 are one
    entry), negative-zero canonicalisation, and no cross-kind or cross-
    precision aliasing."""
    idx, q, ts = _built("l2")
    with ServingFront(idx, cache_size=16, max_delay_s=0.002) as front:
        t_int_like = float(int(ts[1])) if ts[1] >= 1 else ts[1]
        a = front.submit(q[0], "range", t=t_int_like).result(timeout=120)
        b = front.submit(q[0], "range", t=int(t_int_like)
                         if t_int_like == int(t_int_like) else t_int_like
                         ).result(timeout=120)
        assert b.cache_hit and b.hits == a.hits  # typed: int t == float t
        # -0.0 and +0.0 queries are the same point in every metric
        zp = np.full(DIM, 0.5, np.float32)
        zp[0] = 0.0
        zn = zp.copy()
        zn[0] = -0.0
        first = front.submit(zp, "range", t=ts[1]).result(timeout=120)
        second = front.submit(zn, "range", t=ts[1]).result(timeout=120)
        assert second.cache_hit and second.hits == first.hits
        # kNN with k equal to a cached range's t must not alias it
        c = front.submit(q[0], "knn", k=3).result(timeout=120)
        assert not c.cache_hit
        # precision is part of the key: bf16 must not serve the fp32 entry
        d = front.submit(q[0], "range", t=t_int_like,
                         precision="bf16").result(timeout=120)
        assert not d.cache_hit
        assert d.hits == a.hits  # ... but the results agree bit-for-bit


def test_cache_key_injective_header():
    """The key splits unambiguously at the first NUL: the ASCII header can
    never bleed into the query bytes (the old repr+tobytes concatenation
    was not injective)."""
    from repro.serve.front import _cache_key

    qa = np.array([1.5, 2.5], np.float32)
    qb = np.array([2.5, 1.5], np.float32)
    seen = set()
    for kind, t, k in [("range", 1.0, None), ("range", 1, None),
                       ("knn", None, 3), ("knn", None, 5)]:
        for qq in (qa, qb):
            seen.add(_cache_key(kind, "bss", "fp32", 0, t, k, None,
                                8 if kind == "knn" else None, qq))
    assert len(seen) == 6  # t=1 and t=1.0 collapse; everything else distinct
    assert _cache_key("range", "bss", "fp32", 0, 1.0, None, None, None, qa) \
        != _cache_key("range", "bss", "bf16", 0, 1.0, None, None, None, qa)
    # generation is a typed header slot: a mutation's bump splits the key
    assert _cache_key("range", "bss", "fp32", 0, 1.0, None, None, None, qa) \
        != _cache_key("range", "bss", "fp32", 1, 1.0, None, None, None, qa)


def test_stats_total_on_empty_window():
    """A fresh front (nothing submitted, nothing completed) must report a
    complete, all-zero snapshot — never raise on the empty percentile
    window or the zero denominators."""
    idx, _, _ = _built("l2")
    front = ServingFront(idx, start=False)
    s = front.stats()
    front.close()
    assert s["submitted"] == 0 and s["completed"] == 0
    assert s["queue_wait_s"] == {"mean": 0.0, "p50": 0.0, "p95": 0.0,
                                 "max": 0.0}
    assert s["batch_size_mean"] == 0.0 and s["padding_waste"] == 0.0
    assert s["engine_s_per_batch"] == 0.0
    assert s["bf16_rows"] == 0 and s["recheck_points"] == 0


# ----------------------------------------------------------- bf16 serving


def test_front_bf16_bit_identical_and_grouped():
    """bf16 requests serve bit-identical results, never share a micro-batch
    with fp32 requests (precision is in the group key), and their re-check
    volume rides the telemetry."""
    idx, q, ts = _built("l2")
    with ServingFront(idx, max_delay_s=0.01) as front:
        f32 = [front.submit(x, "range", t=ts[1]) for x in q[:8]]
        f16 = [front.submit(x, "range", t=ts[1], precision="bf16")
               for x in q[:8]]
        k32 = [front.submit(x, "knn", k=4) for x in q[:8]]
        k16 = [front.submit(x, "knn", k=4, precision="bf16") for x in q[:8]]
        r32, r16 = _drain(f32), _drain(f16)
        kr32, kr16 = _drain(k32), _drain(k16)
        stats = front.stats()
    for a, b in zip(r32, r16):
        assert sorted(b.hits) == sorted(a.hits)
        assert b.n_dists == a.n_dists  # count parity survives serving
        assert a.n_recheck == 0 and b.n_recheck >= 0
    for a, b in zip(kr32, kr16):
        assert np.array_equal(b.indices, a.indices)
        assert np.array_equal(b.distances, a.distances)
        assert b.n_dists == a.n_dists
    assert stats["bf16_rows"] == 16
    assert stats["recheck_points"] >= 0 and stats["errors"] == 0

"""Fault-tolerance contract: checkpoint/restart bit-exactness, crash
recovery, elastic resharding, straggler telemetry, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_arch
from repro.configs import common
from repro.data.pipeline import TokenStream
from repro.optim import adamw, int8_error_feedback, make_optimizer
from repro.train.loop import TrainLoop, TrainLoopConfig
from repro.train.step import init_state


def _tiny_setup(tmp_path, total_steps=12, ckpt_every=4):
    bundle = get_arch("llama3.2-1b")
    model, cfg, _ = bundle.make_reduced()
    loss_fn = common.loss_for("lm", model)
    opt = make_optimizer("adamw", total_steps=total_steps)
    stream = TokenStream(vocab=model.cfg.vocab, batch=4, seq=16, seed=7)
    loop = TrainLoop(
        loss_fn, opt, stream,
        TrainLoopConfig(
            total_steps=total_steps, checkpoint_every=ckpt_every,
            checkpoint_dir=str(tmp_path / "ckpt"), log_every=100,
        ),
    )
    return model, loop


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path / "c")
    state = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
             "step": jnp.int32(5)}
    mgr.save(5, state, extra={"stream": {"seed": 1, "step": 9}})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, extra = mgr.restore(like)
    assert extra == {"stream": {"seed": 1, "step": 9}}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(tmp_path / "c")
    state = {"w": jnp.arange(100.0)}
    mgr.save(1, state)
    # corrupt a leaf
    leaf = next((tmp_path / "c" / "step_000000001").glob("leaf_*.npy"))
    arr = np.load(leaf)
    arr[0] += 1
    np.save(leaf, arr)
    with pytest.raises(IOError, match="checksum"):
        mgr.restore({"w": jnp.zeros(100)})


def test_checkpoint_gc_keeps_last(tmp_path):
    mgr = CheckpointManager(tmp_path / "c", keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"w": jnp.full((4,), float(s))})
    assert mgr.all_steps() == [3, 4]


def test_crash_restart_is_bit_exact(tmp_path):
    """Train 12 steps straight vs crash-at-8 + resume: identical params."""
    model, loop_a = _tiny_setup(tmp_path / "a")
    params0 = model.init_params(jax.random.PRNGKey(0))
    state_a = loop_a.run(init_state(params0, loop_a.optimizer))

    model, loop_b = _tiny_setup(tmp_path / "b")
    with pytest.raises(RuntimeError, match="simulated crash"):
        loop_b.run(init_state(model.init_params(jax.random.PRNGKey(0)),
                              loop_b.optimizer), crash_at=8)
    # fresh loop object = fresh process; restores step 8 checkpoint + stream
    model, loop_b2 = _tiny_setup(tmp_path / "b")
    state_b = loop_b2.init_or_restore(
        lambda: model.init_params(jax.random.PRNGKey(0))
    )
    assert int(state_b["step"]) == 8
    state_b = loop_b2.run(state_b)
    for a, b in zip(jax.tree.leaves(state_a["params"]),
                    jax.tree.leaves(state_b["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_reshard_restore(tmp_path):
    """Save replicated, restore with a different sharding (elastic restart:
    device topology changed between runs)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(tmp_path / "c")
    state = {"w": jnp.arange(64.0).reshape(8, 8)}
    mgr.save(1, state)
    # no axis_types: jax 0.4.37 predates jax.sharding.AxisType, and the
    # default (Auto) is what this test wants on newer versions anyway
    mesh = jax.make_mesh((1,), ("data",))
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = mgr.restore(state, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding == shardings["w"]


def test_stream_resume_determinism():
    s1 = TokenStream(vocab=101, batch=2, seq=8, seed=3)
    for _ in range(5):
        s1.next()
    st = s1.state()
    a = s1.next()
    s2 = TokenStream(vocab=101, batch=2, seq=8, seed=0)
    s2.restore(st)
    b = s2.next()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_int8_error_feedback_bounded_and_unbiased():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    r = {"w": jnp.zeros((64, 64), jnp.float32)}
    acc = np.zeros((64, 64), np.float32)
    for _ in range(20):
        out, r = int8_error_feedback(g, r)
        acc += np.asarray(out["w"])
    # error feedback: accumulated compressed grads track accumulated true
    # grads to within one quantisation step
    scale = np.abs(np.asarray(g["w"])).max() / 127.0
    err = np.abs(acc - 20 * np.asarray(g["w"])).max()
    assert err <= scale + 1e-5, (err, scale)


def test_compression_training_converges(tmp_path):
    """Compressed training still reduces loss on the tiny LM."""
    bundle = get_arch("llama3.2-1b")
    model, cfg, _ = bundle.make_reduced()
    loss_fn = common.loss_for("lm", model)
    opt = adamw(lr=5e-3)  # fixed lr: the schedule's warmup dwarfs 30 steps
    stream = TokenStream(vocab=model.cfg.vocab, batch=4, seq=16, seed=1)
    loop = TrainLoop(
        loss_fn, opt, stream,
        TrainLoopConfig(total_steps=30, checkpoint_every=1000,
                        checkpoint_dir=str(tmp_path / "c"), log_every=1000,
                        compression=True),
    )
    state = loop.init_or_restore(lambda: model.init_params(jax.random.PRNGKey(0)))
    loop.run(state)
    assert np.mean(loop.losses[-5:]) < np.mean(loop.losses[:5]) - 0.1

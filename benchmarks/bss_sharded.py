"""Sharded BSS scaling sweep on a simulated host mesh.

    PYTHONPATH=src python -m benchmarks.bss_sharded --devices 1 2 4 8

The forcing flag must precede jax initialisation, so the entry point
re-executes itself in a subprocess with ``XLA_FLAGS`` requesting
``max(devices)`` simulated host devices, then sweeps ONE built l2 index
through ``("data",)`` meshes of every requested width: the same range +
kNN workload per width, hits AND per-query distance counts asserted
against the numpy oracle and the single-device fused engine, wall-clock
recorded per width.  ``BENCH_bss_sharded.json`` (archived by the
sharded-matrix CI job) carries the curve plus the device stamp from
``paper_common.write_bench_json``.

On SIMULATED devices the curve measures sharding overhead, not speedup —
every shard shares the same host cores, so flat-ish microseconds/query
across widths is the healthy signal (the collective + dispatch overhead
is bounded); the speedup column becomes meaningful the day the same sweep
runs on a real multi-chip mesh.
"""

from __future__ import annotations

import json
import subprocess
import sys
from benchmarks.paper_common import now

from repro.launch.simdevices import simulated_device_env

DEFAULT_DEVICES = (1, 2, 4, 8)
_OUT = "BENCH_bss_sharded.json"


def _reexec_with_devices(devices, seed: int, out: str) -> int:
    """Run the sweep in a child process whose XLA_FLAGS force max(devices)
    simulated host devices (env assembly shared with the test shim — see
    ``repro.launch.simdevices``)."""
    env = simulated_device_env(max(devices))
    cmd = [
        sys.executable, "-m", "benchmarks.bss_sharded", "--inner",
        "--seed", str(seed), "--out", out,
        "--devices", *[str(d) for d in devices],
    ]
    return subprocess.run(cmd, env=env).returncode


def _sweep(devices, seed: int):
    """The actual measurement (runs in the re-exec'd child).  Returns
    (csv rows, results dict for the JSON record)."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from benchmarks.paper_common import FULL, timed
    from repro.core import flat_index
    from repro.data import metricsets
    from repro.parallel.shard_index import (
        shard_bss, sharded_knn_batched, sharded_query_batched,
    )

    devs = jax.devices()
    usable = [c for c in devices if c <= len(devs)]
    skipped = [c for c in devices if c > len(devs)]

    n = 65_536 if FULL else 24_576  # 192 blocks of 128 at the CI size
    nq, k = 256, 10
    data = metricsets.colors_surrogate(n + nq, dim=96, seed=seed + 17)
    db, q = data[:n], data[n:]
    t = metricsets.calibrate_threshold("l2", db[:20_000], 1e-4, seed=seed)
    idx, dt_build = timed(
        flat_index.build_bss, "l2", db, n_pivots=16, n_pairs=24, block=128,
        seed=seed,
    )
    oracle_hits, oracle_stats = flat_index.bss_query(idx, q, t)

    rows = []
    results = {
        "corpus": int(n), "queries": int(nq), "k": int(k),
        "threshold": float(t), "build_s": round(dt_build, 2),
        "n_blocks": int(idx.n_blocks),
        "oracle_dists_per_query": round(oracle_stats["dists_per_query"], 2),
        "devices_available": len(devs),
        "devices_skipped": skipped,
        "widths": {},
    }

    # single-device fused engine: the baseline every width is held to
    flat_index.bss_query_batched(idx, q, t)  # jit warm-up
    single_hits, single_stats = flat_index.bss_query_batched(idx, q, t)
    dt_single = min(
        timed(flat_index.bss_query_batched, idx, q, t)[1] for _ in range(3)
    )
    flat_index.bss_knn_batched(idx, q, k)
    dt_single_knn = min(
        timed(flat_index.bss_knn_batched, idx, q, k)[1] for _ in range(3)
    )
    results["single_device"] = {
        "range_us_per_query": round(dt_single / nq * 1e6, 1),
        "knn_us_per_query": round(dt_single_knn / nq * 1e6, 1),
        "exact": bool(single_hits == oracle_hits),
        "dists_per_query": round(single_stats["dists_per_query"], 2),
    }
    rows.append(
        f"bss_sharded/baseline/1dev,{dt_single / nq * 1e6:.1f},"
        f"exact={single_hits == oracle_hits};"
        f"knn_us={dt_single_knn / nq * 1e6:.1f};corpus={n}"
    )

    base_range = None
    for c in usable:
        mesh = Mesh(np.array(devs[:c]), ("data",))
        sidx = shard_bss(idx, mesh)
        sharded_query_batched(sidx, q, t)  # warm-up (jit + layout)
        hits, st = sharded_query_batched(sidx, q, t)
        dt_range = min(
            timed(sharded_query_batched, sidx, q, t)[1] for _ in range(3)
        )
        sharded_knn_batched(sidx, q, k)
        ki, _, kst = sharded_knn_batched(sidx, q, k)
        dt_knn = min(
            timed(sharded_knn_batched, sidx, q, k)[1] for _ in range(3)
        )
        exact = bool(
            hits == oracle_hits
            and abs(st["dists_per_query"] - oracle_stats["dists_per_query"])
            < 1e-6
        )
        if base_range is None:
            base_range = dt_range
        results["widths"][str(c)] = {
            "range_us_per_query": round(dt_range / nq * 1e6, 1),
            "knn_us_per_query": round(dt_knn / nq * 1e6, 1),
            "knn_rounds": int(kst["rounds"]),
            "exact": exact,
            "dists_per_query": round(st["dists_per_query"], 2),
            "speedup_vs_1shard": round(base_range / max(dt_range, 1e-9), 2),
        }
        rows.append(
            f"bss_sharded/{c}dev/range,{dt_range / nq * 1e6:.1f},"
            f"exact={exact};dists_per_query={st['dists_per_query']:.0f};"
            f"knn_us={dt_knn / nq * 1e6:.1f};rounds={kst['rounds']};"
            f"speedup_vs_1shard="
            f"{base_range / max(dt_range, 1e-9):.2f}x"
        )
        if not exact:
            raise SystemExit(
                f"sharded/{c}dev diverged from the oracle — the sweep is "
                f"the exactness gate at benchmark scale"
            )
    return rows, results


def run(devices=DEFAULT_DEVICES, seed: int = 0):
    """Harness entry point (benchmarks.run): re-exec under the forcing
    flag, then lift the child's CSV rows back into this process."""
    out = _OUT
    code = _reexec_with_devices(tuple(devices), seed, out)
    if code != 0:
        raise RuntimeError(f"bss_sharded subprocess failed ({code})")
    with open(out) as fh:
        payload = json.load(fh)
    return payload.get("rows", [])


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, nargs="+",
                    default=list(DEFAULT_DEVICES))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=_OUT)
    ap.add_argument("--inner", action="store_true",
                    help="(internal) already under the forcing flag")
    args = ap.parse_args()
    if not args.inner:
        raise SystemExit(
            _reexec_with_devices(tuple(args.devices), args.seed, args.out)
        )
    from benchmarks.paper_common import FULL, write_bench_json

    print("name,us_per_call,derived")
    t0 = now()
    rows, results = _sweep(tuple(args.devices), args.seed)
    for r in rows:
        print(r, flush=True)
    write_bench_json(args.out, {
        "bench": "bss_sharded",
        "seed": args.seed,
        "wall_s": round(now() - t0, 1),
        "full": FULL,
        "rows": rows,
        "sweep": results,
    })


if __name__ == "__main__":
    main()

"""Blocked Supermetric Scan engine (beyond-paper TPU-native index).

Measures the TPU-relevant figure of merit: fraction of MXU tiles pruned by
the planar lower bound at the paper's thresholds, plus exactness, plus
comparison against the best tree (hpt_fft_log/Hilbert) in distances/query.
"""

from __future__ import annotations

import numpy as np

from benchmarks.paper_common import load_space, row, timed
from repro.core import flat_index, tree


def run(datasets=("colors", "nasa", "euc10"), seed: int = 0) -> list[str]:
    rows = []
    for ds in datasets:
        db, q, t = load_space(ds, seed=seed)
        idx, dt_build = timed(
            flat_index.build_bss, "l2", db, n_pivots=16, n_pairs=24,
            block=128, seed=seed,
        )
        (hits, stats), dt = timed(flat_index.bss_query, idx, q, t)
        # exactness vs ground truth
        truth = tree.exhaustive_search("l2", db, q[:50], t)
        exact = all(
            sorted(hits[i]) == sorted(truth[i]) for i in range(len(truth))
        )
        rows.append(row(
            f"bss/{ds}/query", dt / len(q) * 1e6,
            f"dists_per_query={stats['dists_per_query']:.0f};"
            f"tile_exclusion={stats['block_exclusion_rate']:.3f};"
            f"exact={exact};build_s={dt_build:.1f};blocks={stats['n_blocks']}",
        ))
        # vs the paper's best tree
        tr = tree.build_tree("hpt_fft_log", "l2", db, seed=seed)
        (_, counter), dt_tree = timed(tree.range_search, tr, q, t, "hilbert")
        rows.append(row(
            f"bss/{ds}/vs_tree", dt_tree / len(q) * 1e6,
            f"tree_dists={counter.mean:.0f};bss_dists={stats['dists_per_query']:.0f};"
            f"bss_tile_aligned=128",
        ))
    return rows

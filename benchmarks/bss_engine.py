"""Blocked Supermetric Scan engine (beyond-paper TPU-native index).

Measures the TPU-relevant figure of merit: fraction of MXU tiles pruned by
the planar lower bound at the paper's thresholds, plus exactness, plus
comparison against the best tree (hpt_fft_log/Hilbert) in distances/query.

Two engine rows per dataset compare the FUSED batched path (the whole query
jitted: lower bound -> tile mask -> masked exact phase, see
``flat_index.bss_query_batched``) against the numpy-loop oracle path, and a
dedicated scale row times both on a 65k-point corpus with 1k queries — the
fused path must win wall-clock, that's the point of it existing.
"""

from __future__ import annotations

import numpy as np

from benchmarks.paper_common import FULL, load_space, row, timed
from repro.core import flat_index, tree
from repro.data import metricsets


def _fused_query_chunked(idx, q, t, chunk=256):
    """Serving-realistic chunked calls (also bounds the dense (Q, N) f32
    buffer); returns concatenated hits + the last chunk's stats."""
    hits, stats = [], {}
    for lo in range(0, len(q), chunk):
        h, stats = flat_index.bss_query_batched(idx, q[lo:lo + chunk], t)
        hits.extend(h)
    return hits, stats


def run(datasets=("colors", "nasa", "euc10"), seed: int = 0) -> list[str]:
    rows = []
    for ds in datasets:
        db, q, t = load_space(ds, seed=seed)
        idx, dt_build = timed(
            flat_index.build_bss, "l2", db, n_pivots=16, n_pairs=24,
            block=128, seed=seed,
        )
        (hits_np, stats), dt_np = timed(flat_index.bss_query, idx, q, t)
        (hits_fused, fstats), dt_fused = timed(
            _fused_query_chunked, idx, q, t
        )
        # exactness vs ground truth AND oracle==fused
        truth = tree.exhaustive_search("l2", db, q[:50], t)
        exact = all(
            sorted(hits_fused[i]) == sorted(truth[i]) for i in range(len(truth))
        ) and hits_fused == hits_np
        rows.append(row(
            f"bss/{ds}/fused_query", dt_fused / len(q) * 1e6,
            f"dists_per_query={fstats['dists_per_query']:.0f};"
            f"tile_exclusion={fstats['tile_exclusion_rate']:.3f};"
            f"exact={exact};build_s={dt_build:.1f};"
            f"blocks={fstats['n_blocks']};"
            f"speedup_vs_numpy={dt_np / max(dt_fused, 1e-9):.2f}x",
        ))
        rows.append(row(
            f"bss/{ds}/numpy_oracle", dt_np / len(q) * 1e6,
            f"dists_per_query={stats['dists_per_query']:.0f};"
            f"block_exclusion={stats['block_exclusion_rate']:.3f}",
        ))
        # batched kNN vs brute force
        k = 10
        (knn_idx, _, kstats), dt_knn = timed(
            flat_index.bss_knn_batched, idx, q, k
        )
        rows.append(row(
            f"bss/{ds}/knn{k}", dt_knn / len(q) * 1e6,
            f"rounds={kstats['rounds']};"
            f"dists_per_query={kstats['dists_per_query']:.0f}",
        ))
        # vs the paper's best tree
        tr = tree.build_tree("hpt_fft_log", "l2", db, seed=seed)
        (_, counter), dt_tree = timed(tree.range_search, tr, q, t, "hilbert")
        rows.append(row(
            f"bss/{ds}/vs_tree", dt_tree / len(q) * 1e6,
            f"tree_dists={counter.mean:.0f};bss_dists={stats['dists_per_query']:.0f};"
            f"bss_tile_aligned=128",
        ))
    rows.append(_scale_row(seed))
    return rows


def _scale_row(seed: int) -> str:
    """65k-point corpus (112-d colors surrogate, the paper's colors
    dimensionality), 1k queries at ~5 hits/query: fused engine vs the
    numpy loop.  This is the acceptance benchmark for the fused path —
    one jitted masked pass has to beat ~512 host-loop block evaluations.
    Timings are warm (first call pays jit compilation) and best-of-3."""
    n, nq = 65_536, 1_000
    data = metricsets.colors_surrogate(n + nq, dim=112, seed=seed + 11)
    db, q = data[:n], data[n:]
    t = metricsets.calibrate_threshold("l2", db[:20_000], 1e-4, seed=seed)
    idx, dt_build = timed(
        flat_index.build_bss, "l2", db, n_pivots=16, n_pairs=24, block=128,
        seed=seed,
    )
    hits_fused, fstats = flat_index.bss_query_batched(idx, q, t)  # warm-up
    hits_np, _ = flat_index.bss_query(idx, q, t)
    exact = hits_fused == hits_np
    dt_fused = min(
        timed(flat_index.bss_query_batched, idx, q, t)[1] for _ in range(3)
    )
    dt_np = min(timed(flat_index.bss_query, idx, q, t)[1] for _ in range(3))
    return row(
        "bss/scale65k/fused_vs_numpy", dt_fused / nq * 1e6,
        f"corpus={n};queries={nq};numpy_us={dt_np / nq * 1e6:.1f};"
        f"speedup={dt_np / max(dt_fused, 1e-9):.2f}x;exact={exact};"
        f"tile_exclusion={fstats['tile_exclusion_rate']:.3f};"
        f"build_s={dt_build:.1f};full={FULL}",
    )

"""Blocked Supermetric Scan engine (beyond-paper TPU-native index).

Measures the TPU-relevant figure of merit: fraction of MXU tiles pruned by
the planar lower bound at the paper's thresholds, plus exactness, plus
comparison against the best tree (hpt_fft_log/Hilbert) in distances/query.

Two engine rows per dataset compare the FUSED batched path (the whole query
jitted: lower bound -> tile mask -> masked exact phase, see
``flat_index.bss_query_batched``) against the numpy-loop oracle path, and a
dedicated scale row times both on a 65k-point corpus with 1k queries — the
fused path must win wall-clock, that's the point of it existing.

``run_all_metrics`` sweeps the paper's four supermetrics (l2, cosine, jsd,
triangular) through the fused range AND kNN paths with oracle-exactness
checks on a >=4k-point corpus per metric, and records distances/query +
wall-clock per metric.  ``python -m benchmarks.bss_engine --all-metrics``
additionally writes ``BENCH_bss_metrics.json`` so CI can archive the perf
trajectory.
"""

from __future__ import annotations

from benchmarks.paper_common import now

import numpy as np

from benchmarks.paper_common import (
    FULL, load_space, row, timed, write_bench_json,
)
from repro.core import flat_index, tree
from repro.core.backends import EngineOpts
from repro.core.npdist import pairwise_np
from repro.data import metricsets


def _fused_query_chunked(idx, q, t, chunk=256):
    """Serving-realistic chunked calls (also bounds the dense (Q, N) f32
    buffer); returns concatenated hits + the last chunk's stats."""
    hits, stats = [], {}
    for lo in range(0, len(q), chunk):
        h, stats = flat_index.bss_query_batched(idx, q[lo:lo + chunk], t)
        hits.extend(h)
    return hits, stats


def run(datasets=("colors", "nasa", "euc10"), seed: int = 0) -> list[str]:
    rows = []
    for ds in datasets:
        db, q, t = load_space(ds, seed=seed)
        idx, dt_build = timed(
            flat_index.build_bss, "l2", db, n_pivots=16, n_pairs=24,
            block=128, seed=seed,
        )
        (hits_np, stats), dt_np = timed(flat_index.bss_query, idx, q, t)
        (hits_fused, fstats), dt_fused = timed(
            _fused_query_chunked, idx, q, t
        )
        # exactness vs ground truth AND oracle==fused
        truth = tree.exhaustive_search("l2", db, q[:50], t)
        exact = all(
            sorted(hits_fused[i]) == sorted(truth[i]) for i in range(len(truth))
        ) and hits_fused == hits_np
        rows.append(row(
            f"bss/{ds}/fused_query", dt_fused / len(q) * 1e6,
            f"dists_per_query={fstats['dists_per_query']:.0f};"
            f"tile_exclusion={fstats['tile_exclusion_rate']:.3f};"
            f"exact={exact};build_s={dt_build:.1f};"
            f"blocks={fstats['n_blocks']};"
            f"speedup_vs_numpy={dt_np / max(dt_fused, 1e-9):.2f}x",
        ))
        rows.append(row(
            f"bss/{ds}/numpy_oracle", dt_np / len(q) * 1e6,
            f"dists_per_query={stats['dists_per_query']:.0f};"
            f"block_exclusion={stats['block_exclusion_rate']:.3f}",
        ))
        # batched kNN vs brute force
        k = 10
        (knn_idx, _, kstats), dt_knn = timed(
            flat_index.bss_knn_batched, idx, q, k
        )
        rows.append(row(
            f"bss/{ds}/knn{k}", dt_knn / len(q) * 1e6,
            f"rounds={kstats['rounds']};"
            f"dists_per_query={kstats['dists_per_query']:.0f}",
        ))
        # vs the paper's best tree
        tr = tree.build_tree("hpt_fft_log", "l2", db, seed=seed)
        (_, counter), dt_tree = timed(tree.range_search, tr, q, t, "hilbert")
        rows.append(row(
            f"bss/{ds}/vs_tree", dt_tree / len(q) * 1e6,
            f"tree_dists={counter.mean:.0f};bss_dists={stats['dists_per_query']:.0f};"
            f"bss_tile_aligned=128",
        ))
    rows.append(_scale_row(seed))
    return rows


# the paper's four supermetrics, each with a corpus its geometry is valid on
SUPERMETRICS = ("l2", "cosine", "jsd", "triangular")


def _metric_space(metric: str, n: int, nq: int, seed: int):
    """(db, q, t) valid for the metric: the uniform Euclidean benchmark for
    l2, clustered embeddings for cosine, topic histograms (probability
    vectors) for jsd/triangular.  t targets ~1-5 hits/query."""
    if metric in ("jsd", "triangular"):
        data = metricsets.topics_surrogate(n + nq, dim=64, seed=seed)
    elif metric == "cosine":
        rng = np.random.default_rng(seed)
        centres = rng.normal(size=(32, 48))
        data = centres[rng.integers(0, 32, size=n + nq)] + 0.2 * rng.normal(
            size=(n + nq, 48)
        )
    else:
        data = metricsets.euc10(n + nq, seed=seed)
    db, q = data[:n], data[n:]
    t = metricsets.calibrate_threshold(metric, db, 2.0 / n, seed=seed)
    return db.astype(np.float64), q.astype(np.float64), t


def _range_rows_match(truth, hits_a, hits_b, t) -> bool:
    """Hit-list equality with the same boundary caveat as the kNN check:
    float32-engine vs float64-oracle disagreements are acceptable only for
    points whose true distance is within float32 resolution of t."""
    for i, (a, b) in enumerate(zip(hits_a, hits_b)):
        diff = set(a) ^ set(b)
        if diff and not all(
            abs(truth[i][j] - t) <= 1e-5 * max(t, 1e-9) for j in diff
        ):
            return False
    return True


def _knn_row_matches(truth_row, got, want) -> bool:
    """Set equality with the kth-boundary caveat: the float32 engine may
    legitimately swap neighbours whose float64 distances are within float32
    resolution of the kth distance — don't record those ties as an
    exactness regression in the archived BENCH json."""
    if set(got) == set(want):
        return True
    kth = truth_row[want[-1]]
    diff = set(got) ^ set(want)
    return all(
        abs(truth_row[j] - kth) <= 1e-5 * max(kth, 1e-9) for j in diff
    )


def run_all_metrics(seed: int = 0, n: int | None = None, nq: int = 128,
                    k: int = 10):
    """Fused range + kNN vs oracle for every supermetric; returns
    (csv rows, results dict for BENCH_bss_metrics.json)."""
    n = n or (16_384 if FULL else 4_096)
    rows, results = [], {}
    for metric in SUPERMETRICS:
        db, q, t = _metric_space(metric, n, nq, seed)
        idx, dt_build = timed(
            flat_index.build_bss, metric, db, n_pivots=16, n_pairs=24,
            block=128, seed=seed,
        )
        (hits_np, so), dt_np = timed(flat_index.bss_query, idx, q, t)
        flat_index.bss_query_batched(idx, q, t)  # warm-up (jit compile)
        (hits_fused, sf), dt_range = timed(
            flat_index.bss_query_batched, idx, q, t
        )
        truth = pairwise_np(metric, q, db)
        # permuted-layout truth is not needed here: hit ids are original
        # indices, so index truth by them directly
        range_exact = hits_fused == hits_np or _range_rows_match(
            truth, hits_fused, hits_np, t
        )
        want = np.argsort(truth, axis=1)[:, :k]
        flat_index.bss_knn_batched(idx, q, k)  # warm-up
        (knn_idx, _, sk), dt_knn = timed(flat_index.bss_knn_batched, idx, q, k)
        knn_exact = all(
            _knn_row_matches(truth[i], knn_idx[i].tolist(), want[i].tolist())
            for i in range(len(q))
        )
        results[metric] = {
            "corpus": int(n),
            "queries": int(nq),
            "build_s": round(dt_build, 3),
            "range": {
                "exact": bool(range_exact),
                "dists_per_query": round(sf["dists_per_query"], 2),
                "us_per_query": round(dt_range / nq * 1e6, 1),
                "oracle_us_per_query": round(dt_np / nq * 1e6, 1),
                "tile_exclusion_rate": round(sf["tile_exclusion_rate"], 4),
            },
            "knn": {
                "k": k,
                "exact": bool(knn_exact),
                "rounds": int(sk["rounds"]),
                "dists_per_query": round(sk["dists_per_query"], 2),
                "us_per_query": round(dt_knn / nq * 1e6, 1),
            },
        }
        rows.append(row(
            f"bss/metrics/{metric}/range", dt_range / nq * 1e6,
            f"exact={range_exact};dists_per_query={sf['dists_per_query']:.0f};"
            f"tile_exclusion={sf['tile_exclusion_rate']:.3f};corpus={n}",
        ))
        rows.append(row(
            f"bss/metrics/{metric}/knn{k}", dt_knn / nq * 1e6,
            f"exact={knn_exact};rounds={sk['rounds']};"
            f"dists_per_query={sk['dists_per_query']:.0f}",
        ))
    return rows, results


def run_metrics(seed: int = 0) -> list[str]:
    """Suite entry point (harness contract: rows only)."""
    rows, _ = run_all_metrics(seed=seed)
    return rows


def run_all_precision(seed: int = 0, n: int | None = None, nq: int = 128,
                      k: int = 10):
    """fp32 vs bf16 exact phase for every supermetric: bit-identity of hits,
    kNN results and per-query distance counts, plus the HBM-traffic model
    the mode exists for.  Both byte models are analytic from the engine
    telemetry and both are archived:

    * ``bytes_ratio`` (headline) — the paper-aligned PER-EVALUATION model,
      the same accounting convention as ``per_query_dists``: every counted
      distance evaluation streams one corpus row at the storage width, and
      every re-checked band point re-streams its fp32 row (charged a full
      un-amortised fetch — pessimistic for the re-check):

          fp32:  sum(per_query_dists) * dim * 4
          bf16:  sum(per_query_dists) * dim * 2
                 + sum(per_query_recheck) * dim * 4

      The band is a ~eps-wide shell (a few points per query against ~10^3
      evaluations), so this ratio sits just above 0.5 — the halved corpus
      stream the mode exists for.

    * ``tile_bytes_ratio`` — the dense-kernel STREAM model: every computed
      (query-tile, block) grid cell streams one corpus block, re-checked
      tiles re-stream it in fp32 (tiles_computed/recheck_tiles * block *
      dim * width).  NOTE: a query tile is ``TILE_BQ`` (128) queries, so at
      benchmark scale the union of their bands touches nearly every
      surviving block and this view saturates — it bounds the re-check
      traffic of the tile-granular kernel realisation from above, it does
      not measure the band's true (point-sparse) volume.

    ``realisation`` is pinned to "dense" so both precisions run the same
    shape class and the tile counts are comparable.  Returns (csv rows,
    results dict for BENCH_bss_bf16.json)."""
    n = n or (16_384 if FULL else 4_096)
    rows, results = [], {}
    kw = dict(opts=EngineOpts(realisation="dense"))
    kw16 = dict(opts=EngineOpts(realisation="dense", precision="bf16"))
    for metric in SUPERMETRICS:
        db, q, t = _metric_space(metric, n, nq, seed)
        idx, dt_build = timed(
            flat_index.build_bss, metric, db, n_pivots=16, n_pairs=24,
            block=128, seed=seed,
        )
        dim = int(idx.data.shape[1])
        block = int(idx.data.shape[0] // idx.n_blocks)
        tile_bytes = block * dim  # values per streamed corpus block

        for fn in (flat_index.bss_query_batched,):  # warm both jit caches
            fn(idx, q, t, **kw)
            fn(idx, q, t, **kw16)
        (h32, s32), dt32 = timed(
            flat_index.bss_query_batched, idx, q, t, **kw
        )
        (h16, s16), dt16 = timed(
            flat_index.bss_query_batched, idx, q, t, **kw16
        )
        range_ident = h32 == h16 and np.array_equal(
            s32["per_query_dists"], s16["per_query_dists"]
        )
        r_evals = int(np.asarray(s32["per_query_dists"]).sum())
        r_recheck = int(np.asarray(s16["per_query_recheck"]).sum())
        rp32 = r_evals * dim * 4
        rp16 = r_evals * dim * 2 + r_recheck * dim * 4
        rb32 = s32["tiles_computed"] * tile_bytes * 4
        rb16 = (s16["tiles_computed"] * tile_bytes * 2
                + s16["recheck_tiles"] * tile_bytes * 4)

        flat_index.bss_knn_batched(idx, q, k, **kw)  # warm-up
        flat_index.bss_knn_batched(idx, q, k, **kw16)
        (i32, d32, k32), dtk32 = timed(
            flat_index.bss_knn_batched, idx, q, k, **kw
        )
        (i16, d16, k16), dtk16 = timed(
            flat_index.bss_knn_batched, idx, q, k, **kw16
        )
        knn_ident = (
            np.array_equal(i32, i16)
            and np.array_equal(d32, d16)
            and np.array_equal(k32["per_query_dists"], k16["per_query_dists"])
            and k32["rounds"] == k16["rounds"]
        )
        k_evals = int(np.asarray(k32["per_query_dists"]).sum())
        k_recheck = int(np.asarray(k16["per_query_recheck"]).sum())
        kp32 = k_evals * dim * 4
        kp16 = k_evals * dim * 2 + k_recheck * dim * 4
        kb32 = k32["tiles_computed"] * tile_bytes * 4
        kb16 = (k16["tiles_computed"] * tile_bytes * 2
                + k16["recheck_tiles"] * tile_bytes * 4)

        results[metric] = {
            "corpus": int(n),
            "queries": int(nq),
            "build_s": round(dt_build, 3),
            "band_eps": s16["band_eps"],
            "range": {
                "bit_identical": bool(range_ident),
                "tiles_computed": int(s16["tiles_computed"]),
                "recheck_tiles": int(s16["recheck_tiles"]),
                "recheck_points_per_query": round(
                    s16["recheck_points_per_query"], 2
                ),
                "corpus_bytes_fp32": int(rp32),
                "corpus_bytes_bf16": int(rp16),
                "bytes_ratio": round(rp16 / max(rp32, 1), 4),
                "tile_bytes_fp32": int(rb32),
                "tile_bytes_bf16": int(rb16),
                "tile_bytes_ratio": round(rb16 / max(rb32, 1), 4),
                "us_per_query_fp32": round(dt32 / nq * 1e6, 1),
                "us_per_query_bf16": round(dt16 / nq * 1e6, 1),
                "speedup": round(dt32 / max(dt16, 1e-9), 2),
            },
            "knn": {
                "k": k,
                "bit_identical": bool(knn_ident),
                "rounds": int(k16["rounds"]),
                "tiles_computed": int(k16["tiles_computed"]),
                "recheck_tiles": int(k16["recheck_tiles"]),
                "corpus_bytes_fp32": int(kp32),
                "corpus_bytes_bf16": int(kp16),
                "bytes_ratio": round(kp16 / max(kp32, 1), 4),
                "tile_bytes_fp32": int(kb32),
                "tile_bytes_bf16": int(kb16),
                "tile_bytes_ratio": round(kb16 / max(kb32, 1), 4),
                "us_per_query_fp32": round(dtk32 / nq * 1e6, 1),
                "us_per_query_bf16": round(dtk16 / nq * 1e6, 1),
                "speedup": round(dtk32 / max(dtk16, 1e-9), 2),
            },
        }
        rows.append(row(
            f"bss/bf16/{metric}/range", dt16 / nq * 1e6,
            f"bit_identical={range_ident};"
            f"bytes_ratio={rp16 / max(rp32, 1):.3f};"
            f"recheck_per_query={s16['recheck_points_per_query']:.1f};"
            f"band_eps={s16['band_eps']:.3g};corpus={n}",
        ))
        rows.append(row(
            f"bss/bf16/{metric}/knn{k}", dtk16 / nq * 1e6,
            f"bit_identical={knn_ident};"
            f"bytes_ratio={kp16 / max(kp32, 1):.3f};"
            f"rounds={k16['rounds']}",
        ))
    return rows, results


def run_precision(seed: int = 0) -> list[str]:
    """Suite entry point (harness contract: rows only)."""
    rows, _ = run_all_precision(seed=seed)
    return rows


def _scale_row(seed: int) -> str:
    """65k-point corpus (112-d colors surrogate, the paper's colors
    dimensionality), 1k queries at ~5 hits/query: fused engine vs the
    numpy loop.  This is the acceptance benchmark for the fused path —
    one jitted masked pass has to beat ~512 host-loop block evaluations.
    Timings are warm (first call pays jit compilation) and best-of-3."""
    n, nq = 65_536, 1_000
    data = metricsets.colors_surrogate(n + nq, dim=112, seed=seed + 11)
    db, q = data[:n], data[n:]
    t = metricsets.calibrate_threshold("l2", db[:20_000], 1e-4, seed=seed)
    idx, dt_build = timed(
        flat_index.build_bss, "l2", db, n_pivots=16, n_pairs=24, block=128,
        seed=seed,
    )
    hits_fused, fstats = flat_index.bss_query_batched(idx, q, t)  # warm-up
    hits_np, _ = flat_index.bss_query(idx, q, t)
    exact = hits_fused == hits_np
    dt_fused = min(
        timed(flat_index.bss_query_batched, idx, q, t)[1] for _ in range(3)
    )
    dt_np = min(timed(flat_index.bss_query, idx, q, t)[1] for _ in range(3))
    return row(
        "bss/scale65k/fused_vs_numpy", dt_fused / nq * 1e6,
        f"corpus={n};queries={nq};numpy_us={dt_np / nq * 1e6:.1f};"
        f"speedup={dt_np / max(dt_fused, 1e-9):.2f}x;exact={exact};"
        f"tile_exclusion={fstats['tile_exclusion_rate']:.3f};"
        f"build_s={dt_build:.1f};full={FULL}",
    )


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--all-metrics", action="store_true",
                    help="sweep l2/cosine/jsd/triangular and write "
                         "BENCH_bss_metrics.json")
    ap.add_argument("--precision", action="store_true",
                    help="fp32-vs-bf16 exact-phase sweep (bit-identity + "
                         "bytes-moved) and write BENCH_bss_bf16.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.all_metrics:
        t0 = now()
        rows, results = run_all_metrics(seed=args.seed)
        for r in rows:
            print(r, flush=True)
        write_bench_json(args.out or "BENCH_bss_metrics.json", {
            "bench": "bss_metrics",
            "seed": args.seed,
            "wall_s": round(now() - t0, 1),
            "full": FULL,
            "metrics": results,
        })
    elif args.precision:
        t0 = now()
        rows, results = run_all_precision(seed=args.seed)
        for r in rows:
            print(r, flush=True)
        write_bench_json(args.out or "BENCH_bss_bf16.json", {
            "bench": "bss_bf16",
            "seed": args.seed,
            "wall_s": round(now() - t0, 1),
            "full": FULL,
            "metrics": results,
        })
    else:
        for r in run(seed=args.seed):
            print(r, flush=True)


if __name__ == "__main__":
    main()

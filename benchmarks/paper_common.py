"""Shared benchmark plumbing: datasets, thresholds, timing, CSV rows.

Scale: SISAP-size runs take hours on this 1-core container; default sizes
are reduced (documented in every row) — set REPRO_BENCH_FULL=1 for the
paper-size datasets.  All *relative* paper claims are scale-stable (verified
at two scales in tests/test_paper_claims.py).
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core import tree
from repro.data import metricsets

# THE benchmark clock: monotonic time.perf_counter (wall-clock time.time
# steps under NTP and has coarse resolution on some platforms).  One shared
# helper — the serving stack keeps deadlines on the same clock, so import
# it from there rather than growing a second copy.
from repro.serve.queue import now

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"

# name -> (n_points, n_queries, selectivity for t0)
# Paper regime: t0 returns ~1 hit/query (0.001% colors; 1-per-million
# euc10).  At reduced scale the selectivity is rescaled to keep ~1-2
# hits/query, i.e. the same search-difficulty regime.
SIZES = {
    "colors": (112_682 if FULL else 20_000, 200, 1e-5 if FULL else 1e-4),
    "nasa": (40_150 if FULL else 12_000, 200, 1e-5 if FULL else 1.5e-4),
    "euc10": (100_000 if FULL else 20_000, 200, 1e-6 if FULL else 5e-5),
}


def load_space(name: str, seed: int = 0):
    n, nq, sel = SIZES[name]
    gen = metricsets.DATASETS[name][0]
    data = gen(n, seed=seed)
    db, q = metricsets.split_queries(data, 0.10, seed=seed + 1, max_queries=nq)
    t = metricsets.calibrate_threshold("l2", db, sel, seed=seed)
    return db, q, t


def timed(fn, *args, **kw):
    t0 = now()
    out = fn(*args, **kw)
    return out, now() - t0


def forest_search(search_fn, enc, q, t, mech):
    """Uniform (hits, per_query_dists) adapter over the device-forest
    walkers (``forest_range_search`` / ``monotone_range_search``) for the
    tree benchmarks' timing loops."""
    hits, stats = search_fn(enc, q, t, mech)
    return hits, stats["per_query_dists"]


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def device_stamp() -> dict:
    """Device-environment fields stamped into every BENCH_*.json record:
    the archived perf trajectory mixes single- and multi-device runs (the
    sharded-matrix CI job simulates an 8-device host mesh), and rows are
    only comparable within the same device regime."""
    import jax

    from repro.launch.simdevices import FORCE_FLAG

    return {
        "jax_backend": jax.default_backend(),
        "device_count": jax.local_device_count(),
        "devices_simulated": FORCE_FLAG in os.environ.get("XLA_FLAGS", ""),
    }


def write_bench_json(path: str, payload: dict) -> None:
    """Every benchmark's JSON artifact goes through here — one place that
    stamps the device environment into the record."""
    with open(path, "w") as fh:
        json.dump({**device_stamp(), **payload}, fh, indent=2)
    print(f"# wrote {path}", flush=True)

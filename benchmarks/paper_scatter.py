"""Paper §3 scatter-plot experiments (Fig. 4-7).

(a) Fig. 5: 500 random queries over 8-d Euclidean space, threshold 0.145
    (the paper's ~1-per-million radius): count queries that FAIL to exclude
    the opposing semispace.  Paper: 160/500 fail under four-point vs 421/500
    under hyperbolic.
(b) Fig. 6-7: pivot-separation sensitivity — exclusion probability with the
    most-separated vs least-separated of 1,000 sampled pivot pairs.  Paper:
    four-point stays ~constant (0.66 vs "fairly constant"), hyperbolic
    collapses to ~0 for close pivots.
(c) the planar lower-bound property itself, measured: max violation over
    random pairs must be <= 0 (+eps) for supermetric distances.
"""

from __future__ import annotations

import numpy as np

from benchmarks.paper_common import row
from repro.core import projection
from repro.core.npdist import pairwise_np


def _fail_counts(data, p1, p2, t):
    delta = pairwise_np("l2", p1[None], p2[None])[0, 0]
    d1 = pairwise_np("l2", data, p1[None])[:, 0]
    d2 = pairwise_np("l2", data, p2[None])[:, 0]
    hyper_fail = np.abs(d1 - d2) <= 2 * t
    hilb_fail = np.abs(d1**2 - d2**2) / max(delta, 1e-12) <= 2 * t
    return int(hyper_fail.sum()), int(hilb_fail.sum())


def run(seed: int = 0) -> list[str]:
    rng = np.random.default_rng(seed)
    rows = []

    # (a) Fig. 5 setting
    pts = rng.random((502, 8))
    p1, p2, queries = pts[0], pts[1], pts[2:]
    t = 0.145
    hyp, hil = _fail_counts(queries, p1, p2, t)
    rows.append(row(
        "scatter/fig5_exclusion_failures", 0.0,
        f"hyperbolic_fail={hyp}/500;fourpoint_fail={hil}/500;"
        f"paper=421_vs_160;t={t}",
    ))

    # (b) Fig. 6-7: pivot separation sensitivity
    data = rng.random((5000, 8))
    a = rng.integers(0, 5000, 1000)
    b = rng.integers(0, 5000, 1000)
    seps = np.array([
        pairwise_np("l2", data[a[i]][None], data[b[i]][None])[0, 0]
        for i in range(1000)
    ])
    for tag, i in (("far", int(np.argmax(seps))), ("close", int(np.argmin(seps)))):
        p1, p2 = data[a[i]], data[b[i]]
        hyp, hil = _fail_counts(data, p1, p2, t)
        rows.append(row(
            f"scatter/separation_{tag}", 0.0,
            f"p_exclude_fourpoint={1 - hil / 5000:.3f};"
            f"p_exclude_hyperbolic={1 - hyp / 5000:.3f};sep={seps[i]:.3f}",
        ))

    # (c) lower-bound validity (the §3 theorem, measured)
    for metric in ("l2", "cosine", "jsd"):
        x = rng.random((300, 12)) + 1e-3
        if metric == "jsd":
            x /= x.sum(axis=1, keepdims=True)
        p1, p2, pts2 = x[0], x[1], x[2:]
        delta = pairwise_np(metric, p1[None], p2[None])[0, 0]
        d1 = pairwise_np(metric, pts2, p1[None])[:, 0]
        d2 = pairwise_np(metric, pts2, p2[None])[:, 0]
        px, py = np.asarray(projection.project(d1, d2, delta))
        true = pairwise_np(metric, pts2, pts2)
        planar = np.sqrt((px[:, None] - px[None, :]) ** 2
                         + (py[:, None] - py[None, :]) ** 2)
        rows.append(row(
            f"scatter/lower_bound_{metric}", 0.0,
            f"max_violation={float(np.max(planar - true)):.2e};"
            f"mean_tightness={float(np.mean(planar / np.maximum(true, 1e-9))):.3f}",
        ))
    return rows

"""Benchmark harness: one module per paper table/figure + the beyond-paper
engines.  Prints ``name,us_per_call,derived`` CSV (harness contract).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run trees lrt  # subset
    REPRO_BENCH_FULL=1 ... for paper-size datasets (hours on 1 CPU core)
"""

from __future__ import annotations

import sys
from benchmarks.paper_common import now


def main() -> None:
    from benchmarks import (
        analysis_cache,
        bss_engine,
        bss_incremental,
        bss_sharded,
        paper_lrt,
        paper_scatter,
        paper_trees,
        paper_unbalance,
        retrieval_serving,
        roofline,
    )

    suites = {
        "scatter": paper_scatter.run,     # Fig. 4-7
        "trees": paper_trees.run,         # Fig. 12-13 (host numpy walk)
        "trees_forest": paper_trees.run_forest,  # same sweep, device forest
        "lrt": paper_lrt.run,             # Fig. 15-16 (§5)
        "lrt_forest": paper_lrt.run_forest,  # same sweep, device forest
        "unbalance": paper_unbalance.run,  # §6 future work, implemented
        "bss": bss_engine.run,            # beyond-paper TPU engine
        "bss_metrics": bss_engine.run_metrics,  # 4-supermetric sweep
        "bss_bf16": bss_engine.run_precision,  # fp32-vs-bf16 exact phase
        "bss_sharded": bss_sharded.run,   # multi-device mesh sweep
        "bss_incremental": bss_incremental.run,  # living-corpus maintenance
        "retrieval": retrieval_serving.run,  # serving integration
        "retrieval_async": retrieval_serving.run_async,  # async front, Poisson
        "roofline": roofline.run,         # dry-run derived terms
        "analysis_cache": analysis_cache.run,  # bounded-recompile replay
    }
    pick = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    for name in pick:
        t0 = now()
        try:
            for r in suites[name]():
                print(r, flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}", flush=True)
        print(f"# suite {name} finished in {now() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()

"""Observability snapshot: a short serving run through the async front
with metrics on, then every export surface exercised and validated —
the ``render()`` dashboard printed, the Prometheus text exposition
scraped and structurally checked (``repro.obs.export.validate_exposition``
— the CI observability job's gate), each engine stats dict validated
against the shared schema, and the full registry written to
``OBS_snapshot.json`` (archived as a CI artifact).

This is deliberately small: it is not a latency benchmark (that is
``benchmarks.retrieval_serving --async``), it is the proof that a live
serving process exposes well-formed, scrape-ready metrics.
"""

from __future__ import annotations

import numpy as np

from benchmarks.paper_common import row, write_bench_json
from repro.core import flat_index
from repro.data import metricsets
from repro.obs import check_stats, validate_exposition
from repro.obs.export import write_snapshot
from repro.serve.front import ServingFront


def run(seed: int = 0, out: str = "OBS_snapshot.json") -> list[str]:
    rng = np.random.default_rng(seed)
    n, n_pool, dim, k = 6_000, 96, 24, 8
    centres = rng.normal(size=(16, dim)).astype(np.float32)
    corpus = (centres[rng.integers(0, 16, n)]
              + 0.15 * rng.normal(size=(n, dim)).astype(np.float32))
    queries = (centres[rng.integers(0, 16, n_pool)]
               + 0.15 * rng.normal(size=(n_pool, dim)).astype(np.float32))
    t = metricsets.calibrate_threshold("l2", corpus, 2e-3, seed=seed)
    index = flat_index.build_bss("l2", corpus, n_pivots=8, n_pairs=12,
                                 block=128, seed=seed)

    # the engines' own stats conform to the shared schema before serving
    _, rs = flat_index.bss_query_batched(index, queries[:16], float(t))
    check_stats(rs)
    _, _, ks = flat_index.bss_knn_batched(index, queries[:16], k)
    check_stats(ks)

    with ServingFront(index, max_delay_s=0.005, cache_size=32) as front:
        futs = []
        for i, q in enumerate(queries):
            if i % 4 == 3:
                futs.append(front.submit(q, "knn", k=k))
            else:
                futs.append(front.submit(
                    q, "range", t=float(t),
                    precision="bf16" if i % 8 == 1 else "fp32"))
        results = [f.result(timeout=300) for f in futs]
        # one repeat rides the LRU cache so cache metrics are non-zero
        front.submit(queries[0], "range", t=float(t)).result(timeout=300)
        reg = front.metrics()
        trace = front.explain(results[0].trace_id)

    print(reg.render())
    exposition = reg.to_prometheus()
    problems = validate_exposition(exposition)
    if problems:
        raise SystemExit(
            "exposition validation failed:\n  " + "\n  ".join(problems)
        )
    write_snapshot(reg, out, extra={
        "explain_example": trace,
        "exposition_lines": len(exposition.splitlines()),
    })

    snap = reg.snapshot()
    dists = snap["counters"].get("engine/dists{engine=bss,kind=range}", 0)
    spans = sum(
        v["count"] for kkey, v in snap["histograms"].items()
        if kkey.startswith("serve/span_s")
    )
    return [row(
        "obs/snapshot", 0.0,
        f"series={len(reg.series())};range_dists={dists:.0f};"
        f"span_observations={spans};"
        f"exposition_lines={len(exposition.splitlines())};"
        f"trace={trace['trace_id'] if trace else 'none'}",
    )]


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="OBS_snapshot.json")
    args = ap.parse_args()
    rows = run(args.seed, out=args.out)
    print("name,us_per_call,derived")
    for r in rows:
        print(r, flush=True)


if __name__ == "__main__":
    main()

"""Paper §5 (Fig. 15-16): Linear Regression Tree vs monotone hyperplane
trees, with Rand/Far pivot selection, on the clustered 'real-world' sets.

Paper claims validated:
  * LRT (balanced) beats the balanced monotone tree ("the fair comparison"),
  * the unbalanced monotone tree is the overall best performer,
plus our beyond-paper partitions (pca, median_y) for §3.4 completeness.

``backend="forest"`` runs every walk through the array-encoded jitted
monotone walker (``repro.forest``) instead of the host numpy walk — same
results, same per-query distance counts.

    PYTHONPATH=src python -m benchmarks.paper_lrt --backend forest
"""

from __future__ import annotations

from benchmarks.paper_common import forest_search, load_space, row, timed
from repro.core import lrt
from repro.forest import encode_monotone, monotone_range_search


def run(datasets=("colors", "nasa"), seed: int = 0,
        backend: str = "numpy") -> list[str]:
    if backend not in ("numpy", "forest"):
        raise ValueError(f"backend must be numpy|forest, got {backend!r}")
    rows = []
    for ds in datasets:
        db, q, t = load_space(ds, seed=seed)
        results = {}
        for part, label in (
            ("closer", "MonPT_unbalanced"),
            ("median_x", "MonPT_balanced"),
            ("lrt", "LRT"),
            ("pca", "PCA_tree"),
            ("median_y", "HeightSplit_tree"),
        ):
            for select in ("rand", "far"):
                tr = lrt.build_monotone_tree(part, select, "l2", db, seed=seed + 3)
                if backend == "forest":
                    enc = encode_monotone(tr)
                    monotone_range_search(enc, q, t, "hilbert")  # warm-up (same shapes)
                    (hits, per_query), dt = timed(
                        forest_search, monotone_range_search, enc, q, t, "hilbert"
                    )
                    mean = float(per_query.mean())
                else:
                    (hits, counter), dt = timed(
                        lrt.range_search_monotone, tr, q, t, "hilbert"
                    )
                    mean = counter.mean
                results[(label, select)] = mean
                rows.append(row(
                    f"lrt/{ds}/{label}/{select}/{backend}",
                    dt / len(q) * 1e6,
                    f"dists_per_query={mean:.1f};depth={tr.max_depth}",
                ))
        lrt_best = min(results[("LRT", s)] for s in ("rand", "far"))
        bal_best = min(results[("MonPT_balanced", s)] for s in ("rand", "far"))
        unb_best = min(results[("MonPT_unbalanced", s)] for s in ("rand", "far"))
        rows.append(row(
            f"lrt/{ds}/summary", 0.0,
            f"lrt_over_balanced={lrt_best / bal_best:.3f};"
            f"unbalanced_over_lrt={unb_best / lrt_best:.3f};"
            f"paper_claim=lrt<balanced,unbalanced<all;backend={backend}",
        ))
    return rows


def run_forest(datasets=("colors", "nasa"), seed: int = 0) -> list[str]:
    """Suite entry point for the device-forest backend."""
    return run(datasets=datasets, seed=seed, backend="forest")


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="numpy", choices=["numpy", "forest"])
    ap.add_argument("--datasets", nargs="+", default=["colors", "nasa"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for r in run(datasets=tuple(args.datasets), seed=args.seed,
                 backend=args.backend):
        print(r, flush=True)


if __name__ == "__main__":
    main()

"""Paper §5 (Fig. 15-16): Linear Regression Tree vs monotone hyperplane
trees, with Rand/Far pivot selection, on the clustered 'real-world' sets.

Paper claims validated:
  * LRT (balanced) beats the balanced monotone tree ("the fair comparison"),
  * the unbalanced monotone tree is the overall best performer,
plus our beyond-paper partitions (pca, median_y) for §3.4 completeness.
"""

from __future__ import annotations

from benchmarks.paper_common import load_space, row, timed
from repro.core import lrt


def run(datasets=("colors", "nasa"), seed: int = 0) -> list[str]:
    rows = []
    for ds in datasets:
        db, q, t = load_space(ds, seed=seed)
        results = {}
        for part, label in (
            ("closer", "MonPT_unbalanced"),
            ("median_x", "MonPT_balanced"),
            ("lrt", "LRT"),
            ("pca", "PCA_tree"),
            ("median_y", "HeightSplit_tree"),
        ):
            for select in ("rand", "far"):
                tr = lrt.build_monotone_tree(part, select, "l2", db, seed=seed + 3)
                (hits, counter), dt = timed(
                    lrt.range_search_monotone, tr, q, t, "hilbert"
                )
                results[(label, select)] = counter.mean
                rows.append(row(
                    f"lrt/{ds}/{label}/{select}",
                    dt / len(q) * 1e6,
                    f"dists_per_query={counter.mean:.1f};depth={tr.max_depth}",
                ))
        lrt_best = min(results[("LRT", s)] for s in ("rand", "far"))
        bal_best = min(results[("MonPT_balanced", s)] for s in ("rand", "far"))
        unb_best = min(results[("MonPT_unbalanced", s)] for s in ("rand", "far"))
        rows.append(row(
            f"lrt/{ds}/summary", 0.0,
            f"lrt_over_balanced={lrt_best / bal_best:.3f};"
            f"unbalanced_over_lrt={unb_best / lrt_best:.3f};"
            f"paper_claim=lrt<balanced,unbalanced<all",
        ))
    return rows

"""Roofline analysis over the dry-run artifacts (deliverable g).

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  All measured quantities are PER-DEVICE (the SPMD
partitioned module), so:

    T_compute = flops_dev / 197e12
    T_memory  = bytes_dev / 819e9        (HLO bytes-accessed: upper bound —
                                          counts operands of every op, i.e.
                                          pre-fusion traffic)
    T_coll    = coll_bytes_dev / 50e9    (sum of collective operand bytes
                                          through each chip's links)

    MFU proxy = MODEL_FLOPS / (max(T_*) * chips * 197e12)

plus MODEL_FLOPS / HLO_FLOPS (remat/redundancy waste).  LM cells use the
loop-corrected (probe-extrapolated) totals; GNN/recsys graphs are loop-free
so measured == true.
"""

from __future__ import annotations

import glob
import json
import os
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_cells(tag: str = "singlepod", directory: str | None = None) -> list[dict]:
    out = []
    for f in sorted(glob.glob(f"{directory or DRYRUN_DIR}/*__{tag}.json")):
        r = json.loads(Path(f).read_text())
        if r.get("status") == "compiled":
            out.append(r)
    return out


def analyse(rec: dict) -> dict:
    tot = rec["corrected"]["total"]
    chips = rec["n_devices"]
    t_c = tot["flops"] / PEAK_FLOPS
    t_m = tot["bytes"] / HBM_BW
    t_x = tot["coll"].get("total", 0.0) / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    t_star = max(terms.values())
    model_flops = rec.get("model_flops", 0.0)
    hlo_global = tot["flops"] * chips
    mfu = model_flops / (t_star * chips * PEAK_FLOPS) if t_star > 0 else 0.0
    mem = rec.get("memory", {})
    hbm = (mem.get("argument_size_in_bytes", 0)
           + mem.get("temp_size_in_bytes", 0)
           - mem.get("alias_size_in_bytes", 0))
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "kind": rec["kind"],
        "chips": chips,
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "t_collective_s": t_x,
        "dominant": dom,
        "mfu_proxy": mfu,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_ratio": model_flops / hlo_global if hlo_global else 0.0,
        "hbm_per_chip_gib": hbm / 2**30,
        "coll_bytes_dev": tot["coll"],
    }


_MOVE = {
    "compute": "raise arithmetic efficiency: fuse/skip redundant recompute "
               "(remat policy), larger microbatch, avoid fp32 upcasts",
    "memory": "cut HBM traffic: fuse elementwise chains, bf16 intermediates, "
              "smaller attention materialisation (chunking), weight-gather reuse",
    "collective": "re-shard to cut collectives: fewer all-gathers per layer "
                  "(bigger FSDP shards), overlap via latency-hiding scheduler, "
                  "int8 gradient compression on the DP all-reduce",
}


def run(tag: str = "singlepod") -> list[str]:
    rows = []
    for rec in load_cells(tag):
        a = analyse(rec)
        rows.append(
            f"roofline/{a['arch']}/{a['shape']},0.0,"
            f"Tc={a['t_compute_s']:.4f}s;Tm={a['t_memory_s']:.4f}s;"
            f"Tx={a['t_collective_s']:.4f}s;dom={a['dominant']};"
            f"mfu={a['mfu_proxy']:.3f};useful={a['useful_ratio']:.2f};"
            f"hbm={a['hbm_per_chip_gib']:.1f}GiB"
        )
    return rows


def markdown_table(tag: str = "singlepod", directory: str | None = None) -> str:
    lines = [
        "| arch | shape | kind | T_compute | T_memory | T_coll | dominant | "
        "MFU proxy | MODEL/HLO | HBM/chip | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_cells(tag, directory):
        a = analyse(rec)
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['kind']} "
            f"| {a['t_compute_s'] * 1e3:.2f} ms | {a['t_memory_s'] * 1e3:.2f} ms "
            f"| {a['t_collective_s'] * 1e3:.2f} ms | **{a['dominant']}** "
            f"| {a['mfu_proxy']:.3f} | {a['useful_ratio']:.2f} "
            f"| {a['hbm_per_chip_gib']:.1f} GiB "
            f"| {_MOVE[a['dominant']][:58]}... |"
        )
    return "\n".join(lines)

"""Paper Fig. 12-13: 12 hyperplane-tree variants x {Hyperbolic, Hilbert}
exclusion x {colors, nasa, euc10} at threshold t0.

Figure of merit (identical to the paper's): mean distance evaluations per
query.  Paper claims validated here:
  * Hilbert <= Hyperbolic for every structure (guaranteed),
  * improvement magnitude ~40-60% at low thresholds,
  * variance across structures far lower under Hilbert,
  * hpt_fft_log among the best (paper's new record-holder).
"""

from __future__ import annotations

import numpy as np

from benchmarks.paper_common import load_space, row, timed
from repro.core import tree


def run(datasets=("colors", "nasa", "euc10"), variants=tree.TREE_VARIANTS,
        seed: int = 0) -> list[str]:
    rows = []
    for ds in datasets:
        db, q, t = load_space(ds, seed=seed)
        per_variant = {}
        for variant in variants:
            tr = tree.build_tree(variant, "l2", db, seed=seed + 7)
            res = {}
            for mech in ("hyperbolic", "hilbert"):
                (hits, counter), dt = timed(tree.range_search, tr, q, t, mech)
                res[mech] = counter.mean
                rows.append(row(
                    f"trees/{ds}/{variant}/{mech}",
                    dt / len(q) * 1e6,
                    f"dists_per_query={counter.mean:.1f};n={db.shape[0]};t={t:.4f}",
                ))
            per_variant[variant] = res
        hyp = np.array([v["hyperbolic"] for v in per_variant.values()])
        hil = np.array([v["hilbert"] for v in per_variant.values()])
        best = min(per_variant, key=lambda k: per_variant[k]["hilbert"])
        rows.append(row(
            f"trees/{ds}/summary", 0.0,
            f"hilbert_over_hyperbolic={float(np.mean(hil / hyp)):.3f};"
            f"cv_hyp={float(np.std(hyp) / np.mean(hyp)):.3f};"
            f"cv_hil={float(np.std(hil) / np.mean(hil)):.3f};"
            f"best_hilbert={best}",
        ))
    return rows

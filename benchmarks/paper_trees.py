"""Paper Fig. 12-13: 12 hyperplane-tree variants x {Hyperbolic, Hilbert}
exclusion x {colors, nasa, euc10} at threshold t0.

Figure of merit (identical to the paper's): mean distance evaluations per
query.  Paper claims validated here:
  * Hilbert <= Hyperbolic for every structure (guaranteed),
  * improvement magnitude ~40-60% at low thresholds,
  * variance across structures far lower under Hilbert,
  * hpt_fft_log among the best (paper's new record-holder).

Backends
--------
``backend="numpy"`` walks the host trees (``tree.range_search``, the
distance-counted oracle); ``backend="forest"`` array-encodes each tree and
runs the jitted batched device walk (``repro.forest``) — identical result
sets and per-query distance counts, tree-shaped pruning on accelerator.

    PYTHONPATH=src python -m benchmarks.paper_trees --backend forest
    PYTHONPATH=src python -m benchmarks.paper_trees --backend both \
        --datasets colors --out BENCH_trees.json

``--backend both`` cross-checks forest vs numpy per variant (results AND
per-query counts) and records both timings in the JSON payload — the
artifact the CI forest-matrix job archives.
"""

from __future__ import annotations

from benchmarks.paper_common import now

import numpy as np

from benchmarks.paper_common import (
    FULL, forest_search, load_space, row, timed, write_bench_json,
)
from repro.core import tree
from repro.forest import encode_tree, forest_range_search


def run(datasets=("colors", "nasa", "euc10"), variants=tree.TREE_VARIANTS,
        seed: int = 0, backend: str = "numpy") -> list[str]:
    if backend not in ("numpy", "forest"):
        raise ValueError(f"backend must be numpy|forest, got {backend!r}")
    rows = []
    for ds in datasets:
        db, q, t = load_space(ds, seed=seed)
        per_variant = {}
        for variant in variants:
            tr = tree.build_tree(variant, "l2", db, seed=seed + 7)
            enc = encode_tree(tr) if backend == "forest" else None
            res = {}
            for mech in ("hyperbolic", "hilbert"):
                if backend == "forest":
                    forest_range_search(enc, q, t, mech)  # jit warm-up (same shapes)
                    (hits, per_query), dt = timed(
                        forest_search, forest_range_search, enc, q, t, mech
                    )
                    mean = float(per_query.mean())
                else:
                    (hits, counter), dt = timed(
                        tree.range_search, tr, q, t, mech
                    )
                    mean = counter.mean
                res[mech] = mean
                rows.append(row(
                    f"trees/{ds}/{variant}/{mech}/{backend}",
                    dt / len(q) * 1e6,
                    f"dists_per_query={mean:.1f};n={db.shape[0]};t={t:.4f}",
                ))
            per_variant[variant] = res
        hyp = np.array([v["hyperbolic"] for v in per_variant.values()])
        hil = np.array([v["hilbert"] for v in per_variant.values()])
        best = min(per_variant, key=lambda k: per_variant[k]["hilbert"])
        rows.append(row(
            f"trees/{ds}/summary", 0.0,
            f"hilbert_over_hyperbolic={float(np.mean(hil / hyp)):.3f};"
            f"cv_hyp={float(np.std(hyp) / np.mean(hyp)):.3f};"
            f"cv_hil={float(np.std(hil) / np.mean(hil)):.3f};"
            f"best_hilbert={best};backend={backend}",
        ))
    return rows


def run_forest(datasets=("colors", "nasa", "euc10"), seed: int = 0) -> list[str]:
    """Suite entry point for the device-forest backend."""
    return run(datasets=datasets, seed=seed, backend="forest")


def sweep_both(datasets=("colors",), variants=tree.TREE_VARIANTS,
               seed: int = 0, max_n: int | None = None, nq: int | None = None):
    """numpy walk vs device forest, per variant: timings, mean distance
    counts, and the oracle-equivalence verdict (results AND per-query
    counts).  Returns (csv rows, results dict for BENCH_trees.json)."""
    rows, results = [], {}
    for ds in datasets:
        db, q, t = load_space(ds, seed=seed)
        if max_n:
            db = db[:max_n]
        if nq:
            q = q[:nq]
        ds_res = {"n": int(db.shape[0]), "queries": int(len(q)),
                  "t": float(t), "variants": {}}
        for variant in variants:
            tr, dt_build = timed(tree.build_tree, variant, "l2", db, seed=seed + 7)
            enc, dt_encode = timed(encode_tree, tr)
            vres = {"build_s": round(dt_build, 3),
                    "encode_s": round(dt_encode, 3),
                    "levels": len(enc.levels), "nodes": enc.n_nodes}
            for mech in ("hyperbolic", "hilbert"):
                (hits_np, counter), dt_np = timed(
                    tree.range_search, tr, q, t, mech
                )
                forest_range_search(enc, q, t, mech)  # jit warm-up (same shapes)
                (hits_f, per_query), dt_f = timed(forest_search, forest_range_search, enc, q, t, mech)
                match = all(
                    sorted(a) == sorted(b) for a, b in zip(hits_f, hits_np)
                ) and np.array_equal(per_query, counter.per_query)
                vres[mech] = {
                    "match": bool(match),
                    "dists_per_query": round(float(counter.mean), 2),
                    "numpy_us_per_query": round(dt_np / len(q) * 1e6, 1),
                    "forest_us_per_query": round(dt_f / len(q) * 1e6, 1),
                }
                rows.append(row(
                    f"trees/{ds}/{variant}/{mech}/both",
                    dt_f / len(q) * 1e6,
                    f"match={match};dists_per_query={counter.mean:.1f};"
                    f"numpy_us={dt_np / len(q) * 1e6:.1f}",
                ))
            ds_res["variants"][variant] = vres
        results[ds] = ds_res
    return rows, results


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "forest", "both"])
    ap.add_argument("--datasets", nargs="+", default=["colors"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-n", type=int, default=None,
                    help="subsample the corpus (CI-budget sweeps)")
    ap.add_argument("--max-queries", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="write BENCH_trees.json (only with --backend both)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    t0 = now()
    if args.backend == "both":
        rows, results = sweep_both(
            datasets=tuple(args.datasets), seed=args.seed,
            max_n=args.max_n, nq=args.max_queries,
        )
        for r in rows:
            print(r, flush=True)
        mismatches = [
            f"{ds}/{variant}/{mech}"
            for ds, dres in results.items()
            for variant, vres in dres["variants"].items()
            for mech in ("hyperbolic", "hilbert")
            if not vres[mech]["match"]
        ]
        if args.out:
            write_bench_json(args.out, {
                "bench": "trees_forest",
                "seed": args.seed,
                "wall_s": round(now() - t0, 1),
                "full": FULL,
                "datasets": results,
            })
        if mismatches:
            # the sweep IS the oracle-equivalence gate at benchmark scale —
            # a recorded divergence must fail the CI job, not just land in
            # the archived artifact
            raise SystemExit(f"forest/numpy mismatch: {', '.join(mismatches)}")
    else:
        for r in run(datasets=tuple(args.datasets), seed=args.seed,
                     backend=args.backend):
            print(r, flush=True)
    print(f"# finished in {now() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()

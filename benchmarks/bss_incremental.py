"""Living-corpus maintenance: append throughput and compaction payoff.

Measures what `repro.index.maintain` buys over rebuilding:

* **Append throughput** — rows/s through `maintain.append` (new blocks
  against the EXISTING pivot tables, `m x P` host table distances) vs a
  full `build_bss` over the grown corpus after every batch.  The speedup
  is the point of the O(m) path; the table-distance counter in the
  mutation stats proves no corpus re-derivation happened.

* **Post-compact query cost** — distances/query and wall-clock on the
  fragmented index (appends open fresh blocks, deletes leave loose boxes)
  vs after `compact(refresh_pivots=True)` vs a fresh `build_bss` over the
  same live rows.  The compacted and fresh indexes must agree EXACTLY
  (same layout, same hits, same per-query distance counts) — compaction
  is a rebuild the corpus never stops serving through (the front swaps
  generations between micro-batches).

`python -m benchmarks.bss_incremental` writes
``BENCH_bss_incremental.json`` (final generation stamped) for the CI perf
trajectory; `run()` is the `benchmarks.run` suite hook.
"""

from __future__ import annotations

import numpy as np

from benchmarks.paper_common import (
    FULL, load_space, now, row, timed, write_bench_json,
)
from repro.core import flat_index
from repro.index import maintain

# base corpus fraction / append batches sized so the append phase roughly
# doubles the corpus — the regime where rebuild-per-batch visibly loses
N_BATCHES = 8
DELETE_FRAC = 0.15


def run_incremental(seed: int = 0) -> tuple[list[str], dict]:
    rows: list[str] = []
    db, q, t = load_space("colors", seed=seed)
    n0 = len(db) // 2
    base, grow = db[:n0], db[n0:]
    idx, dt_build0 = timed(
        flat_index.build_bss, "l2", base, n_pivots=16, n_pairs=24,
        block=128, seed=seed,
    )
    # warm the device mirror so appends measure the extend path, not the
    # first-touch transfer
    flat_index.bss_query_batched(idx, q[:8], t)

    # -- append throughput: m-row batches vs full rebuild of the grown corpus
    batch = len(grow) // N_BATCHES
    append_s = rebuild_s = 0.0
    table_dists = appended = 0
    for i in range(N_BATCHES):
        chunk = grow[i * batch:(i + 1) * batch]
        (idx, ms), dt = timed(maintain.append, idx, chunk)
        append_s += dt
        table_dists += ms.table_dists
        appended += ms.rows
        _, dt_rebuild = timed(
            flat_index.build_bss, "l2", db[:n0 + (i + 1) * batch],
            n_pivots=16, n_pairs=24, block=128, seed=seed,
        )
        rebuild_s += dt_rebuild
    rows.append(row(
        "bss_incremental/append", append_s / N_BATCHES * 1e6,
        f"rows_per_s={appended / max(append_s, 1e-9):.0f};"
        f"table_dists={table_dists};"
        f"speedup_vs_rebuild={rebuild_s / max(append_s, 1e-9):.1f}x;"
        f"generation={idx.generation}",
    ))

    # -- fragment further with deletes, then measure the compaction payoff
    rng = np.random.default_rng(seed + 1)
    dead = rng.choice(idx.next_id, size=int(DELETE_FRAC * idx.next_id),
                      replace=False)
    idx, _ = maintain.delete(idx, dead)
    live = np.setdiff1d(np.arange(idx.next_id), dead)

    (hits_frag, st_frag), dt_frag = timed(
        flat_index.bss_query_batched, idx, q, t
    )
    (idx_c, ms_c), dt_compact = timed(maintain.compact, idx)
    (hits_c, st_c), dt_c = timed(flat_index.bss_query_batched, idx_c, q, t)
    fresh, dt_fresh_build = timed(
        flat_index.build_bss, "l2", db[live], n_pivots=16, n_pairs=24,
        block=128, seed=seed,
    )
    (hits_f, st_f), dt_f = timed(flat_index.bss_query_batched, fresh, q, t)

    # exactness: every phase returns the same live hits; compacted == fresh
    # down to the per-query distance counts (fresh hits are row positions
    # into db[live] — map them back to original ids)
    hits_f_ids = [sorted(int(live[j]) for j in h) for h in hits_f]
    exact = (
        [sorted(h) for h in hits_frag] == hits_f_ids
        and [sorted(h) for h in hits_c] == hits_f_ids
        and (st_c["per_query_dists"] == st_f["per_query_dists"]).all()
    )
    rows.append(row(
        "bss_incremental/query_fragmented", dt_frag / len(q) * 1e6,
        f"dists_per_query={st_frag['dists_per_query']:.0f};"
        f"blocks={st_frag['n_blocks']};"
        f"tombstone_frac={DELETE_FRAC:.2f}",
    ))
    rows.append(row(
        "bss_incremental/query_compacted", dt_c / len(q) * 1e6,
        f"dists_per_query={st_c['dists_per_query']:.0f};"
        f"blocks={st_c['n_blocks']};compact_s={dt_compact:.2f};"
        f"exact={exact};generation={idx_c.generation}",
    ))
    rows.append(row(
        "bss_incremental/query_fresh_rebuild", dt_f / len(q) * 1e6,
        f"dists_per_query={st_f['dists_per_query']:.0f};"
        f"rebuild_s={dt_fresh_build:.2f};"
        f"counts_equal_compacted={bool((st_c['per_query_dists'] == st_f['per_query_dists']).all())}",
    ))

    results = {
        "base_rows": int(n0),
        "base_build_s": round(dt_build0, 3),
        "append": {
            "batches": N_BATCHES,
            "rows": int(appended),
            "rows_per_s": round(appended / max(append_s, 1e-9), 1),
            "table_dists": int(table_dists),
            "append_s": round(append_s, 3),
            "rebuild_s": round(rebuild_s, 3),
            "speedup_vs_rebuild": round(rebuild_s / max(append_s, 1e-9), 2),
        },
        "compaction": {
            "deleted_rows": int(dead.size),
            "compact_s": round(dt_compact, 3),
            "fresh_rebuild_s": round(dt_fresh_build, 3),
            "dists_per_query_fragmented": round(
                float(st_frag["dists_per_query"]), 1),
            "dists_per_query_compacted": round(
                float(st_c["dists_per_query"]), 1),
            "dists_per_query_fresh": round(
                float(st_f["dists_per_query"]), 1),
            "us_per_query_fragmented": round(dt_frag / len(q) * 1e6, 1),
            "us_per_query_compacted": round(dt_c / len(q) * 1e6, 1),
            "refreshed_pivots": bool(ms_c.refreshed_pivots),
        },
        "generation": int(idx_c.generation),
        "exact": bool(exact),
    }
    return rows, results


def run(seed: int = 0) -> list[str]:
    rows, _ = run_incremental(seed=seed)
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    t0 = now()
    rows, results = run_incremental(seed=args.seed)
    for r in rows:
        print(r, flush=True)
    write_bench_json(args.out or "BENCH_bss_incremental.json", {
        "bench": "bss_incremental",
        "seed": args.seed,
        "wall_s": round(now() - t0, 1),
        "full": FULL,
        **results,
    })


if __name__ == "__main__":
    main()

"""End-to-end retrieval serving: two-tower model -> supermetric index ->
exact top-k / range queries (the paper's technique as a production serving
feature; see serve/retrieval.py).
"""

from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.paper_common import row
from repro.configs.registry import get_arch
from repro.core.npdist import pairwise_np
from repro.serve.retrieval import RetrievalServer

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def run(seed: int = 0) -> list[str]:
    corpus_n = 1_000_000 if FULL else 30_000
    nq, k = 128, 10
    bundle = get_arch("two-tower-retrieval")
    model, cfg, _ = bundle.make_reduced()
    params = model.init_params(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    item_ids = rng.integers(0, cfg.vocab, size=(corpus_n, cfg.n_item_fields))
    user_ids = rng.integers(0, cfg.vocab, size=(nq, cfg.n_user_fields))
    corpus = np.asarray(model.item_embed(params, item_ids))
    users = np.asarray(model.user_embed(params, user_ids))

    t0 = time.time()
    server = RetrievalServer(corpus, n_pivots=16, n_pairs=24)
    build_s = time.time() - t0

    t0 = time.time()
    top = server.top_k(users, k)
    dt = time.time() - t0

    # exactness: compare against brute force on a query subsample
    sub = min(32, nq)
    d = pairwise_np("l2", users[:sub], server.corpus)
    ok = 0
    for i in range(sub):
        want = set(np.argsort(d[i])[:k].tolist())
        ok += len(want & set(np.asarray(top[i]).tolist()))
    recall = ok / (sub * k)

    s = server.stats
    return [row(
        "retrieval/two_tower_topk", dt / nq * 1e6,
        f"recall_at_{k}={recall:.4f};dists_per_query={s.dists_per_query:.0f};"
        f"corpus={corpus_n};pruned={100 * s.saving:.1f}%;build_s={build_s:.1f}",
    )]

"""End-to-end retrieval serving: two-tower model -> supermetric index ->
exact top-k / range queries (the paper's technique as a production serving
feature; see serve/retrieval.py), plus probability-vector corpora
(topic/histogram embeddings) served under the JSD and Triangular
supermetrics through the same metric-parametrised server.

``run_async`` (also ``python -m benchmarks.retrieval_serving --async``) is
the serving-front workload: an OPEN-LOOP Poisson request stream — arrivals
fire on the clock whether or not the server kept up, the regime that
exposes queueing collapse — against the deadline micro-batching front
(``repro.serve.front``), versus the synchronous call-per-request baseline,
at three arrival rates bracketing the sync server's saturation point.
Reports p50/p95/p99 latency and goodput per rate and writes
``BENCH_serving_async.json`` (archived by the serving-matrix CI job).
"""

from __future__ import annotations

import os

import jax
import numpy as np

from benchmarks.paper_common import now, row
from repro.configs.registry import get_arch
from repro.core.backends import EngineOpts
from repro.core.npdist import pairwise_np
from repro.data import metricsets
from repro.serve.retrieval import RetrievalServer

_DENSE = EngineOpts(realisation="dense")

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def run(seed: int = 0) -> list[str]:
    corpus_n = 1_000_000 if FULL else 30_000
    nq, k = 128, 10
    bundle = get_arch("two-tower-retrieval")
    model, cfg, _ = bundle.make_reduced()
    params = model.init_params(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    item_ids = rng.integers(0, cfg.vocab, size=(corpus_n, cfg.n_item_fields))
    user_ids = rng.integers(0, cfg.vocab, size=(nq, cfg.n_user_fields))
    corpus = np.asarray(model.item_embed(params, item_ids))
    users = np.asarray(model.user_embed(params, user_ids))

    t0 = now()
    server = RetrievalServer(corpus, n_pivots=16, n_pairs=24)
    build_s = now() - t0

    # fused batched kNN engine (one jitted radius-deepening round per pass)
    t0 = now()
    top = server.top_k(users, k)
    dt = now() - t0

    # numpy brute-force oracle for wall-clock + exactness reference
    t0 = now()
    oracle = server.top_k_oracle(users, k)
    dt_oracle = now() - t0

    sub = min(32, nq)
    d = pairwise_np("l2", users[:sub], server.corpus)
    ok = 0
    for i in range(sub):
        want = set(np.argsort(d[i])[:k].tolist())
        ok += len(want & set(np.asarray(top[i]).tolist()))
    recall = ok / (sub * k)
    match = all(
        set(np.asarray(a).tolist()) == set(np.asarray(b).tolist())
        for a, b in zip(top, oracle)
    )

    s = server.stats
    rows = [row(
        "retrieval/two_tower_topk", dt / nq * 1e6,
        f"recall_at_{k}={recall:.4f};oracle_match={match};"
        f"dists_per_query={s.dists_per_query:.0f};corpus={corpus_n};"
        f"pruned={100 * s.saving:.1f}%;build_s={build_s:.1f};"
        f"bruteforce_us={dt_oracle / nq * 1e6:.1f}",
    )]

    # Clustered corpus = the geometry a TRAINED two-tower model produces
    # (items gather around user-interest regions): the prunable regime the
    # supermetric index is deployed for.  Untrained towers above give an
    # isotropic corpus — the honest worst case (nothing is prunable there).
    centres = np.asarray(
        model.user_embed(params, rng.integers(
            0, cfg.vocab, size=(64, cfg.n_user_fields))), np.float32)
    e_dim = centres.shape[1]
    clustered = centres[rng.integers(0, 64, size=corpus_n)] + (
        0.2 / np.sqrt(e_dim)
    ) * rng.normal(size=(corpus_n, e_dim)).astype(np.float32)
    server_c = RetrievalServer(clustered, n_pivots=16, n_pairs=24)
    t0 = now()
    top_c = server_c.top_k(users, k)
    dt_c = now() - t0
    t0 = now()
    oracle_c = server_c.top_k_oracle(users, k)
    dt_oracle_c = now() - t0
    match_c = all(
        set(np.asarray(a).tolist()) == set(np.asarray(b).tolist())
        for a, b in zip(top_c, oracle_c)
    )
    sc = server_c.stats
    rows.append(row(
        "retrieval/two_tower_topk_clustered", dt_c / nq * 1e6,
        f"oracle_match={match_c};dists_per_query={sc.dists_per_query:.0f};"
        f"corpus={corpus_n};pruned={100 * sc.saving:.1f}%;"
        f"bruteforce_us={dt_oracle_c / nq * 1e6:.1f}",
    ))

    # Probability-vector corpus (topic/histogram embeddings) served under
    # the probability-space supermetrics — the same server, different metric.
    prob_n = 100_000 if FULL else 12_000
    topics = metricsets.topics_surrogate(prob_n + nq, dim=64, seed=seed + 3)
    p_corpus, p_users = topics[:prob_n], topics[prob_n:]
    for metric in ("jsd", "triangular"):
        server_p = RetrievalServer(p_corpus, metric=metric, n_pivots=16,
                                   n_pairs=24)
        t0 = now()
        top_p = server_p.top_k(p_users, k)
        dt_p = now() - t0
        t0 = now()
        oracle_p = server_p.top_k_oracle(p_users, k)
        dt_oracle_p = now() - t0
        match_p = all(
            set(np.asarray(a).tolist()) == set(np.asarray(b).tolist())
            for a, b in zip(top_p, oracle_p)
        )
        sp = server_p.stats
        rows.append(row(
            f"retrieval/topics_{metric}_topk", dt_p / nq * 1e6,
            f"oracle_match={match_p};dists_per_query={sp.dists_per_query:.0f};"
            f"corpus={prob_n};pruned={100 * sp.saving:.1f}%;"
            f"bruteforce_us={dt_oracle_p / nq * 1e6:.1f}",
        ))
    return rows


# ---------------------------------------------------------------------------
# Async front vs synchronous server: open-loop Poisson workload
# ---------------------------------------------------------------------------


def _pct_ms(lat: list[float], p: float) -> float:
    from repro.serve.queue import nearest_rank  # the front's own statistic

    return 1e3 * nearest_rank(lat, p)


def run_async(seed: int = 0, smoke: bool = False,
              out: str = "BENCH_serving_async.json") -> list[str]:
    """Open-loop Poisson arrivals (range+kNN mix) against the async front
    vs the synchronous call-per-request server, at three arrival rates
    around the sync server's saturation throughput.  The sync baseline
    replays the SAME arrival schedule through the standard single-server
    queueing recursion (start_i = max(arrival_i, finish_{i-1})) with
    measured per-call service times — no idle sleeping, same math."""
    from benchmarks.paper_common import write_bench_json
    from repro.core import flat_index
    from repro.serve.front import ServingFront

    import time as _time

    rng = np.random.default_rng(seed)
    n = 4_000 if smoke else (60_000 if FULL else 16_000)
    n_pool = 512 if smoke else 2_048   # distinct queries; reused modulo
    req_cap = 600 if smoke else (6_000 if FULL else 2_500)
    dim, k = 32, 10
    centres = rng.normal(size=(24, dim)).astype(np.float32)
    corpus = (centres[rng.integers(0, 24, n)]
              + 0.15 * rng.normal(size=(n, dim)).astype(np.float32))
    queries = (centres[rng.integers(0, 24, n_pool)]
               + 0.15 * rng.normal(size=(n_pool, dim)).astype(np.float32))
    t_base = metricsets.calibrate_threshold("l2", corpus[:8_000], 2e-4,
                                            seed=seed)
    index = flat_index.build_bss("l2", corpus, n_pivots=16, n_pairs=24,
                                 block=128, seed=seed)
    # request mix: 3/4 range (jittered per-request thresholds -> they still
    # share one micro-batch via per-query radii), 1/4 kNN at one k
    kinds = np.where(rng.random(n_pool) < 0.75, "range", "knn")
    t_req = (t_base * rng.uniform(0.7, 1.3, n_pool)).astype(np.float32)

    def call_sync(i: int):
        # Same dense-realisation pin as the front it is compared against:
        # the adaptive sparse path pads alive-cell counts to DATA-DEPENDENT
        # pow2 classes, and a mid-measurement recompile would charge
        # compile stalls to the sync baseline that the async side (dense by
        # default) never pays — the comparison must be apples to apples.
        i %= n_pool
        if kinds[i] == "range":
            return flat_index.bss_query_batched(
                index, queries[i : i + 1], float(t_req[i]), opts=_DENSE)
        return flat_index.bss_knn_batched(
            index, queries[i : i + 1], k, opts=_DENSE)

    # Warm the jit caches for both paths: batch-1 shapes for the sync
    # baseline; every bucket-ladder shape (range WITH a padded negative
    # radius, and kNN) plus a full-speed replay of the request pool through
    # a throwaway front (dense realisation, like the measured front).
    # Compiles are a deploy-time cost — the measured run is steady-state
    # serving, which is what the bucket ladder exists to make possible
    # (bounded shapes => bounded compiles).
    from repro.core.backends import DEFAULT_BUCKETS

    for b in DEFAULT_BUCKETS:
        qb = np.repeat(queries[:1], b, axis=0)
        tb = np.full(b, t_base, np.float32)
        tb[-1] = -1.0  # the front's padding sentinel shape
        flat_index.bss_query_batched(index, qb, tb, opts=_DENSE)
        flat_index.bss_knn_batched(index, qb, k, opts=_DENSE)
    with ServingFront(index, max_delay_s=0.001, max_queue=n_pool) as wf:
        warm = [
            wf.submit(queries[i], "range", t=float(t_req[i]))
            if kinds[i] == "range" else wf.submit(queries[i], "knn", k=k)
            for i in range(n_pool)
        ]
        for f in warm:
            f.result(timeout=120)
    # sync service time: median of warm batch-1 calls (robust to stragglers)
    svc = []
    for i in range(60):
        t0 = now()
        call_sync(i)
        svc.append(now() - t0)
    s1 = float(np.median(svc))
    sync_cap = 1.0 / s1  # the sync server's saturation rate

    rates = [0.5 * sync_cap, 1.5 * sync_cap, 3.0 * sync_cap]
    records, rows = [], []
    for rate in rates:
        # enough requests for >= ~2.5s of traffic (bounded by req_cap), so
        # percentiles come from steady state rather than a 100ms burst
        n_req = int(min(req_cap, max(120, rate * 2.5)))
        arrivals = np.cumsum(rng.exponential(1.0 / rate, n_req))

        # --- synchronous baseline: queueing replay over measured services
        sync_lat, finish = [], 0.0
        for i in range(n_req):
            t0 = now()
            call_sync(i)
            busy = now() - t0
            start = max(float(arrivals[i]), finish)
            finish = start + busy
            sync_lat.append(finish - float(arrivals[i]))

        # --- async front: real-time open-loop submission
        done_at = [0.0] * n_req
        shed = 0
        front = ServingFront(
            index, max_delay_s=min(0.01, 4 * s1), max_queue=256,
            admission="shed",
        )
        futs: list = [None] * n_req
        t_start = now()
        with front:
            for i in range(n_req):
                rem = (t_start + float(arrivals[i])) - now()
                if rem > 0:
                    _time.sleep(rem)
                j = i % n_pool
                try:
                    if kinds[j] == "range":
                        futs[i] = front.submit(queries[j], "range",
                                               t=float(t_req[j]))
                    else:
                        futs[i] = front.submit(queries[j], "knn", k=k)
                except Exception:  # noqa: BLE001 — shed under overload
                    shed += 1

                def _stamp(f, i=i):
                    done_at[i] = now()

                if futs[i] is not None:
                    futs[i].add_done_callback(_stamp)
        # after close(): the drain's batches are in the telemetry, and every
        # future is resolved.  Count only SUCCESSFUL requests into latency/
        # goodput (a failed dispatch is not goodput; .exception() also marks
        # the failure as retrieved).
        fstats = front.stats()
        async_lat = [
            done_at[i] - (t_start + float(arrivals[i]))
            for i in range(n_req)
            if futs[i] is not None and futs[i].exception() is None
        ]
        span = (max(done_at) - t_start) if async_lat else 1.0
        goodput = len(async_lat) / max(span, 1e-9)
        sync_goodput = n_req / max(finish, 1e-9)
        rec = {
            "rate_rps": round(rate, 1),
            "async": {
                "p50_ms": round(_pct_ms(async_lat, 0.50), 3),
                "p95_ms": round(_pct_ms(async_lat, 0.95), 3),
                "p99_ms": round(_pct_ms(async_lat, 0.99), 3),
                "goodput_rps": round(goodput, 1),
                "shed": int(shed),
                "batch_size_mean": round(fstats["batch_size_mean"], 2),
                "padding_waste": round(fstats["padding_waste"], 3),
            },
            "sync": {
                "p50_ms": round(_pct_ms(sync_lat, 0.50), 3),
                "p95_ms": round(_pct_ms(sync_lat, 0.95), 3),
                "p99_ms": round(_pct_ms(sync_lat, 0.99), 3),
                "goodput_rps": round(sync_goodput, 1),
            },
        }
        records.append(rec)
        rows.append(row(
            f"serving_async/rate_{rate:.0f}rps",
            _pct_ms(async_lat, 0.95) * 1e3,
            f"p50_ms={rec['async']['p50_ms']};p99_ms={rec['async']['p99_ms']};"
            f"goodput={rec['async']['goodput_rps']};shed={shed};"
            f"sync_p95_ms={rec['sync']['p95_ms']};"
            f"sync_goodput={rec['sync']['goodput_rps']};"
            f"batch_mean={rec['async']['batch_size_mean']}",
        ))

    write_bench_json(out, {
        "workload": {
            "corpus": int(n), "dim": dim, "request_cap_per_rate": int(req_cap),
            "knn_frac": 0.25, "k": k, "threshold_base": float(t_base),
            "sync_service_ms": round(1e3 * s1, 3), "smoke": bool(smoke),
        },
        "rates": records,
        # the highest-rate front's full metrics snapshot (repro.obs):
        # exclusion attribution, span/batch histograms, recompile counters
        "metrics": front.metrics().snapshot(),
    })
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--async", dest="run_async", action="store_true",
                    help="open-loop Poisson workload vs the async front")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized corpora / request counts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serving_async.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.run_async:
        for r in run_async(args.seed, smoke=args.smoke, out=args.out):
            print(r, flush=True)
    else:
        for r in run(args.seed):
            print(r, flush=True)


if __name__ == "__main__":
    main()

"""End-to-end retrieval serving: two-tower model -> supermetric index ->
exact top-k / range queries (the paper's technique as a production serving
feature; see serve/retrieval.py), plus probability-vector corpora
(topic/histogram embeddings) served under the JSD and Triangular
supermetrics through the same metric-parametrised server.
"""

from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.paper_common import row
from repro.configs.registry import get_arch
from repro.core.npdist import pairwise_np
from repro.data import metricsets
from repro.serve.retrieval import RetrievalServer

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def run(seed: int = 0) -> list[str]:
    corpus_n = 1_000_000 if FULL else 30_000
    nq, k = 128, 10
    bundle = get_arch("two-tower-retrieval")
    model, cfg, _ = bundle.make_reduced()
    params = model.init_params(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    item_ids = rng.integers(0, cfg.vocab, size=(corpus_n, cfg.n_item_fields))
    user_ids = rng.integers(0, cfg.vocab, size=(nq, cfg.n_user_fields))
    corpus = np.asarray(model.item_embed(params, item_ids))
    users = np.asarray(model.user_embed(params, user_ids))

    t0 = time.time()
    server = RetrievalServer(corpus, n_pivots=16, n_pairs=24)
    build_s = time.time() - t0

    # fused batched kNN engine (one jitted radius-deepening round per pass)
    t0 = time.time()
    top = server.top_k(users, k)
    dt = time.time() - t0

    # numpy brute-force oracle for wall-clock + exactness reference
    t0 = time.time()
    oracle = server.top_k_oracle(users, k)
    dt_oracle = time.time() - t0

    sub = min(32, nq)
    d = pairwise_np("l2", users[:sub], server.corpus)
    ok = 0
    for i in range(sub):
        want = set(np.argsort(d[i])[:k].tolist())
        ok += len(want & set(np.asarray(top[i]).tolist()))
    recall = ok / (sub * k)
    match = all(
        set(np.asarray(a).tolist()) == set(np.asarray(b).tolist())
        for a, b in zip(top, oracle)
    )

    s = server.stats
    rows = [row(
        "retrieval/two_tower_topk", dt / nq * 1e6,
        f"recall_at_{k}={recall:.4f};oracle_match={match};"
        f"dists_per_query={s.dists_per_query:.0f};corpus={corpus_n};"
        f"pruned={100 * s.saving:.1f}%;build_s={build_s:.1f};"
        f"bruteforce_us={dt_oracle / nq * 1e6:.1f}",
    )]

    # Clustered corpus = the geometry a TRAINED two-tower model produces
    # (items gather around user-interest regions): the prunable regime the
    # supermetric index is deployed for.  Untrained towers above give an
    # isotropic corpus — the honest worst case (nothing is prunable there).
    centres = np.asarray(
        model.user_embed(params, rng.integers(
            0, cfg.vocab, size=(64, cfg.n_user_fields))), np.float32)
    e_dim = centres.shape[1]
    clustered = centres[rng.integers(0, 64, size=corpus_n)] + (
        0.2 / np.sqrt(e_dim)
    ) * rng.normal(size=(corpus_n, e_dim)).astype(np.float32)
    server_c = RetrievalServer(clustered, n_pivots=16, n_pairs=24)
    t0 = time.time()
    top_c = server_c.top_k(users, k)
    dt_c = time.time() - t0
    t0 = time.time()
    oracle_c = server_c.top_k_oracle(users, k)
    dt_oracle_c = time.time() - t0
    match_c = all(
        set(np.asarray(a).tolist()) == set(np.asarray(b).tolist())
        for a, b in zip(top_c, oracle_c)
    )
    sc = server_c.stats
    rows.append(row(
        "retrieval/two_tower_topk_clustered", dt_c / nq * 1e6,
        f"oracle_match={match_c};dists_per_query={sc.dists_per_query:.0f};"
        f"corpus={corpus_n};pruned={100 * sc.saving:.1f}%;"
        f"bruteforce_us={dt_oracle_c / nq * 1e6:.1f}",
    ))

    # Probability-vector corpus (topic/histogram embeddings) served under
    # the probability-space supermetrics — the same server, different metric.
    prob_n = 100_000 if FULL else 12_000
    topics = metricsets.topics_surrogate(prob_n + nq, dim=64, seed=seed + 3)
    p_corpus, p_users = topics[:prob_n], topics[prob_n:]
    for metric in ("jsd", "triangular"):
        server_p = RetrievalServer(p_corpus, metric=metric, n_pivots=16,
                                   n_pairs=24)
        t0 = time.time()
        top_p = server_p.top_k(p_users, k)
        dt_p = time.time() - t0
        t0 = time.time()
        oracle_p = server_p.top_k_oracle(p_users, k)
        dt_oracle_p = time.time() - t0
        match_p = all(
            set(np.asarray(a).tolist()) == set(np.asarray(b).tolist())
            for a, b in zip(top_p, oracle_p)
        )
        sp = server_p.stats
        rows.append(row(
            f"retrieval/topics_{metric}_topk", dt_p / nq * 1e6,
            f"oracle_match={match_p};dists_per_query={sp.dists_per_query:.0f};"
            f"corpus={prob_n};pruned={100 * sp.saving:.1f}%;"
            f"bruteforce_us={dt_oracle_p / nq * 1e6:.1f}",
        ))
    return rows

"""Compile-cache replay benchmark: the PR 5 bounded-recompile guarantee
measured, not just asserted.

Replays a mixed-size range+kNN stream through ``ServingFront`` via the
analysis layer's :func:`audit_compile_cache` and reports (a) whether each
engine jit's distinct-lowering growth equals the bucket-ladder
prediction (the CI gate), and (b) how much wall time the whole replay
costs per request — i.e. what the audit itself adds to CI.

    PYTHONPATH=src python -m benchmarks.analysis_cache [--smoke]

Rows: ``name,us_per_call,derived``; the JSON artifact is
``BENCH_analysis_cache.json``.
"""

from __future__ import annotations

from benchmarks.paper_common import now, row, write_bench_json

# (label, bucket ladder, wave sizes): smoke is the CI-gate configuration;
# full adds a deeper ladder with waves overflowing the top bucket so the
# front's chunk-splitting shows up in the prediction.
_CONFIGS = {
    "smoke": [("ladder4-8", (4, 8), tuple(range(1, 11)))],
    "full": [
        ("ladder4-8", (4, 8), tuple(range(1, 11))),
        ("ladder4-16", (4, 8, 16), tuple(range(1, 25))),
    ],
}


def run(smoke: bool = True, out: str = "BENCH_analysis_cache.json"):
    from repro.analysis.jaxpr_audit import audit_compile_cache

    records = []
    for label, buckets, sizes in _CONFIGS["smoke" if smoke else "full"]:
        t0 = now()
        problems, info = audit_compile_cache(sizes=sizes, buckets=buckets)
        dt = now() - t0
        n_requests = 2 * sum(sizes)  # one range + one knn wave per size
        if info.get("skipped"):
            yield row(f"analysis_cache/{label}", 0.0,
                      "skipped:no-jit-cache-hook")
            records.append({"label": label, "skipped": True})
            continue
        predicted = info["predicted_lowerings"]
        growth = info["growth"]
        ok = not problems
        yield row(
            f"analysis_cache/{label}",
            1e6 * dt / n_requests,
            f"predicted={predicted};grew="
            + "|".join(f"{k}:{v}" for k, v in sorted(growth.items()))
            + f";ok={ok}",
        )
        records.append({
            "label": label,
            "buckets": list(buckets),
            "sizes": list(sizes),
            "requests": n_requests,
            "replay_s": round(dt, 3),
            "predicted_lowerings": predicted,
            "growth": growth,
            "problems": [p.__dict__ for p in problems],
        })
    write_bench_json(out, {"smoke": bool(smoke), "configs": records})


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-gate configuration only (the default ladder)")
    ap.add_argument("--out", default="BENCH_analysis_cache.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for r in run(smoke=args.smoke, out=args.out):
        print(r, flush=True)


if __name__ == "__main__":
    main()

"""Benchmark regression sentinel.

Every benchmark writes its own ``BENCH_*.json`` shape; this module
normalises them into ONE schema-versioned trajectory
(``BENCH_trajectory.json``: flat ``name -> {value, unit, class, better}``
rows plus a host fingerprint) and compares trajectories with noise-aware
thresholds, so CI can fail on a real slowdown without flaking on timer
jitter:

* metric **classes** carry the tolerance — ``work`` rows (distance
  counts, kNN rounds) are deterministic given the seed and get a tight
  relative bound; ``ratio`` rows (speedups, bytes ratios) are
  machine-independent but mildly noisy; ``time`` / ``throughput`` rows
  are wall-clock and get a loose relative bound PLUS an absolute floor
  (sub-millisecond jitter never trips), doubled again when the baseline
  was recorded on a different host fingerprint; ``flag`` rows (exactness
  booleans) regress on any decrease.
* a regression needs to exceed BOTH the relative and the absolute slack —
  tiny values are judged by the floor, large values by the ratio.
* ``--ci`` runs the smoke benchmark set ``--runs`` times and compares the
  per-row MEDIAN against the committed ``benchmarks/BENCH_baseline.json``
  (refreshed via ``--rebase``), printing a delta table and exiting
  non-zero on any regression or vanished row.

Usage::

    python -m benchmarks.regress --ci            # CI gate (perf-sentinel)
    python -m benchmarks.regress --rebase        # refresh the baseline
    python -m benchmarks.regress --collect DIR   # normalise existing jsons
    python -m benchmarks.regress --compare A B   # diff two trajectories
"""

from __future__ import annotations

import json
import os
import platform
import subprocess
import sys
import tempfile
from pathlib import Path
from statistics import median

TRAJECTORY_SCHEMA = 1
REPO_ROOT = Path(__file__).resolve().parents[1]
BASELINE_PATH = REPO_ROOT / "benchmarks" / "BENCH_baseline.json"

# the CI smoke set: module args + the artifact each invocation writes.
# Each is CI-sized (seconds, not minutes) so --runs medians stay cheap.
SMOKE_SET = (
    (("benchmarks.bss_engine", "--all-metrics"), "BENCH_bss_metrics.json"),
    (("benchmarks.bss_incremental",), "BENCH_bss_incremental.json"),
    (("benchmarks.retrieval_serving", "--async", "--smoke"),
     "BENCH_serving_async.json"),
)

# class -> (relative slack, absolute floor).  A row regresses only when
# the worse-direction delta exceeds BOTH bounds.
THRESHOLDS = {
    "work": (1.05, 2.0),
    "ratio": (1.25, 0.05),
    "time": (1.75, None),   # absolute floor from the unit table below
    "throughput": (1.75, None),
    "flag": (1.0, 0.0),
}
_ABS_FLOOR_BY_UNIT = {
    "us": 100.0, "ms": 1.0, "s": 0.05, "rps": 25.0, "rows/s": 1000.0,
    "count": 2.0, "ratio": 0.05, "bool": 0.0,
}
# wall-clock rows measured on a different host are barely comparable:
# widen their relative slack by this factor instead of dropping them
_CROSS_HOST_RELAX = 2.0


def host_fingerprint() -> dict:
    return {
        "platform": platform.machine() + "-" + platform.system().lower(),
        "cpu_count": os.cpu_count() or 1,
    }


def _row(value, unit, cls, better="lower") -> dict:
    return {"value": float(value), "unit": unit, "class": cls,
            "better": better}


# ---------------------------------------------------------------------------
# per-benchmark extractors: BENCH payload -> flat trajectory rows
# ---------------------------------------------------------------------------


def _extract_bss_metrics(d: dict) -> dict:
    rows = {}
    for m, r in d.get("metrics", {}).items():
        for kind in ("range", "knn"):
            kr = r.get(kind, {})
            p = f"bss/{m}/{kind}"
            if "dists_per_query" in kr:
                rows[f"{p}/dists_per_query"] = _row(
                    kr["dists_per_query"], "count", "work")
            if "us_per_query" in kr:
                rows[f"{p}/us_per_query"] = _row(
                    kr["us_per_query"], "us", "time")
            if "exact" in kr:
                rows[f"{p}/exact"] = _row(
                    kr["exact"], "bool", "flag", better="higher")
            if "rounds" in kr:
                rows[f"{p}/rounds"] = _row(kr["rounds"], "count", "work")
    return rows


def _extract_bss_bf16(d: dict) -> dict:
    rows = {}
    for m, r in d.get("metrics", {}).items():
        for kind in ("range", "knn"):
            kr = r.get(kind, {})
            p = f"bf16/{m}/{kind}"
            if "bit_identical" in kr:
                rows[f"{p}/bit_identical"] = _row(
                    kr["bit_identical"], "bool", "flag", better="higher")
            if "bytes_ratio" in kr:
                rows[f"{p}/bytes_ratio"] = _row(
                    kr["bytes_ratio"], "ratio", "ratio")
            if "us_per_query_bf16" in kr:
                rows[f"{p}/us_per_query"] = _row(
                    kr["us_per_query_bf16"], "us", "time")
    return rows


def _extract_bss_incremental(d: dict) -> dict:
    rows = {}
    ap, cp = d.get("append", {}), d.get("compaction", {})
    if "rows_per_s" in ap:
        rows["incremental/append/rows_per_s"] = _row(
            ap["rows_per_s"], "rows/s", "throughput", better="higher")
    if "speedup_vs_rebuild" in ap:
        rows["incremental/append/speedup_vs_rebuild"] = _row(
            ap["speedup_vs_rebuild"], "ratio", "ratio", better="higher")
    if "table_dists" in ap:
        rows["incremental/append/table_dists"] = _row(
            ap["table_dists"], "count", "work")
    for key in ("dists_per_query_fragmented", "dists_per_query_compacted"):
        if key in cp:
            rows[f"incremental/{key}"] = _row(cp[key], "count", "work")
    if "compact_s" in cp:
        rows["incremental/compact_s"] = _row(cp["compact_s"], "s", "time")
    if "exact" in d:
        rows["incremental/exact"] = _row(
            d["exact"], "bool", "flag", better="higher")
    return rows


def _extract_serving_async(d: dict) -> dict:
    rows = {}
    wl = d.get("workload", {})
    if "sync_service_ms" in wl:
        rows["serving/sync_service_ms"] = _row(
            wl["sync_service_ms"], "ms", "time")
    # rates are host-load dependent; label by position (low/mid/high of
    # the sync-saturation sweep), not by the absolute rps
    names = ("under", "saturated", "overload")
    for name, rec in zip(names, d.get("rates", [])):
        a = rec.get("async", {})
        if "p95_ms" in a:
            rows[f"serving/{name}/async_p95_ms"] = _row(
                a["p95_ms"], "ms", "time")
        if "goodput_rps" in a:
            rows[f"serving/{name}/async_goodput_rps"] = _row(
                a["goodput_rps"], "rps", "throughput", better="higher")
    return rows


def _extract_bss_sharded(d: dict) -> dict:
    rows = {}
    sweep = d.get("sweep", {})
    sd = sweep.get("single_device", {})
    if "range_us_per_query" in sd:
        rows["sharded/1dev/range_us_per_query"] = _row(
            sd["range_us_per_query"], "us", "time")
    for c, w in sweep.get("widths", {}).items():
        p = f"sharded/{c}dev"
        if "range_us_per_query" in w:
            rows[f"{p}/range_us_per_query"] = _row(
                w["range_us_per_query"], "us", "time")
        if "dists_per_query" in w:
            rows[f"{p}/dists_per_query"] = _row(
                w["dists_per_query"], "count", "work")
        if "exact" in w:
            rows[f"{p}/exact"] = _row(
                w["exact"], "bool", "flag", better="higher")
    return rows


_EXTRACTORS = {
    "bss_metrics": _extract_bss_metrics,
    "bss_bf16": _extract_bss_bf16,
    "bss_incremental": _extract_bss_incremental,
    "bss_sharded": _extract_bss_sharded,
}


def normalise_payload(d: dict) -> dict:
    """One BENCH payload -> trajectory rows; unknown shapes yield {}."""
    bench = d.get("bench")
    if bench in _EXTRACTORS:
        return _EXTRACTORS[bench](d)
    if "rates" in d and "workload" in d:  # retrieval_serving writes no tag
        return _extract_serving_async(d)
    return {}


def collect(paths, host: dict | None = None) -> dict:
    """Normalise BENCH json files into one trajectory dict."""
    rows, sources = {}, []
    for p in sorted(Path(p) for p in paths):
        with open(p) as fh:
            payload = json.load(fh)
        extracted = normalise_payload(payload)
        if extracted:
            overlap = rows.keys() & extracted.keys()
            if overlap:
                raise ValueError(
                    f"{p.name}: duplicate trajectory rows {sorted(overlap)}"
                )
            rows.update(extracted)
            sources.append(p.name)
    return {
        "schema": TRAJECTORY_SCHEMA,
        "host": host if host is not None else host_fingerprint(),
        "sources": sources,
        "rows": rows,
    }


def median_of(trajectories: list[dict]) -> dict:
    """Per-row median across repeated runs (rows missing from some runs
    are medianed over the runs that have them)."""
    if not trajectories:
        raise ValueError("no trajectories to median")
    out = dict(trajectories[0])
    rows = {}
    for t in trajectories:
        for name, r in t["rows"].items():
            rows.setdefault(name, []).append(r)
    out["rows"] = {
        name: {**rs[0], "value": float(median(r["value"] for r in rs))}
        for name, rs in rows.items()
    }
    out["runs"] = len(trajectories)
    return out


# ---------------------------------------------------------------------------
# comparison
# ---------------------------------------------------------------------------


def _slack(row: dict, cross_host: bool):
    rel, abs_floor = THRESHOLDS[row["class"]]
    if abs_floor is None:
        abs_floor = _ABS_FLOOR_BY_UNIT.get(row["unit"], 0.0)
    if cross_host and row["class"] in ("time", "throughput"):
        rel *= _CROSS_HOST_RELAX
    return rel, abs_floor


def compare(baseline: dict, current: dict) -> list[dict]:
    """Row-by-row deltas; each entry has a ``status`` in
    ``ok | improved | new | REGRESSION | MISSING``.  The two capitalised
    states are the failing ones."""
    if baseline.get("schema") != TRAJECTORY_SCHEMA:
        raise ValueError(
            f"baseline schema {baseline.get('schema')!r} != "
            f"{TRAJECTORY_SCHEMA}; re-run --rebase"
        )
    cross_host = baseline.get("host") != current.get("host")
    deltas = []
    brows, crows = baseline["rows"], current["rows"]
    for name in sorted(brows.keys() | crows.keys()):
        b, c = brows.get(name), crows.get(name)
        if b is None:
            deltas.append({"name": name, "base": None,
                           "cur": c["value"], "status": "new"})
            continue
        if c is None:
            deltas.append({"name": name, "base": b["value"],
                           "cur": None, "status": "MISSING"})
            continue
        rel, abs_floor = _slack(b, cross_host)
        bv, cv = b["value"], c["value"]
        if b.get("better") == "higher":
            worse = cv < bv / rel and cv < bv - abs_floor
            better = cv > bv
        else:
            worse = cv > bv * rel and cv > bv + abs_floor
            better = cv < bv
        status = ("REGRESSION" if worse
                  else "improved" if better and abs(cv - bv) > 1e-12
                  else "ok")
        deltas.append({
            "name": name, "base": bv, "cur": cv, "unit": b["unit"],
            "class": b["class"], "status": status,
            "ratio": (cv / bv) if bv else None,
        })
    return deltas


def delta_table(deltas: list[dict]) -> str:
    lines = [
        "| row | base | current | ratio | status |",
        "|---|---:|---:|---:|---|",
    ]
    for d in deltas:
        base = "-" if d["base"] is None else f"{d['base']:g}"
        cur = "-" if d["cur"] is None else f"{d['cur']:g}"
        ratio = ("-" if d.get("ratio") is None or d["base"] in (None, 0)
                 else f"{d['ratio']:.2f}x")
        lines.append(
            f"| {d['name']} | {base} | {cur} | {ratio} | {d['status']} |"
        )
    return "\n".join(lines)


def failures(deltas: list[dict]) -> list[dict]:
    return [d for d in deltas if d["status"] in ("REGRESSION", "MISSING")]


# ---------------------------------------------------------------------------
# CI driver
# ---------------------------------------------------------------------------


def _run_smoke_once(workdir: Path) -> list[Path]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(REPO_ROOT / "src"), str(REPO_ROOT)]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    out_paths = []
    for modargs, artifact in SMOKE_SET:
        out = workdir / artifact
        cmd = [sys.executable, "-m", *modargs, "--out", str(out)]
        print(f"# regress: {' '.join(cmd[2:])}", flush=True)
        subprocess.run(cmd, check=True, env=env, cwd=REPO_ROOT, timeout=1800)
        out_paths.append(out)
    return out_paths


def run_smoke_trajectory(runs: int) -> dict:
    trajectories = []
    with tempfile.TemporaryDirectory(prefix="regress-") as td:
        for i in range(runs):
            d = Path(td) / f"run{i}"
            d.mkdir()
            trajectories.append(collect(_run_smoke_once(d)))
    return median_of(trajectories)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--ci", action="store_true",
                      help="run the smoke set and gate against the "
                           "committed baseline")
    mode.add_argument("--rebase", action="store_true",
                      help="run the smoke set and rewrite the baseline")
    mode.add_argument("--collect", metavar="DIR",
                      help="normalise existing BENCH_*.json files in DIR")
    mode.add_argument("--compare", nargs=2, metavar=("BASE", "CUR"),
                      help="diff two trajectory files")
    ap.add_argument("--runs", type=int, default=3,
                    help="benchmark repetitions medianed per row (ci/rebase)")
    ap.add_argument("--against", default=str(BASELINE_PATH),
                    help="baseline trajectory to compare against")
    ap.add_argument("--out", default="BENCH_trajectory.json")
    ap.add_argument("--table-out", default="REGRESS_delta.md",
                    help="where --ci writes the markdown delta table")
    args = ap.parse_args(argv)

    if args.collect:
        paths = sorted(Path(args.collect).glob("BENCH_*.json"))
        traj = collect(paths)
        Path(args.out).write_text(json.dumps(traj, indent=2) + "\n")
        print(f"# wrote {args.out} ({len(traj['rows'])} rows from "
              f"{len(traj['sources'])} files)")
        return 0

    if args.compare:
        base = json.loads(Path(args.compare[0]).read_text())
        cur = json.loads(Path(args.compare[1]).read_text())
        deltas = compare(base, cur)
        print(delta_table(deltas))
        return 1 if failures(deltas) else 0

    traj = run_smoke_trajectory(max(1, args.runs))

    if args.rebase:
        BASELINE_PATH.write_text(json.dumps(traj, indent=2) + "\n")
        print(f"# wrote {BASELINE_PATH} ({len(traj['rows'])} rows, "
              f"median of {traj['runs']} runs)")
        return 0

    Path(args.out).write_text(json.dumps(traj, indent=2) + "\n")
    baseline = json.loads(Path(args.against).read_text())
    deltas = compare(baseline, traj)
    table = delta_table(deltas)
    Path(args.table_out).write_text(table + "\n")
    print(table)
    bad = failures(deltas)
    if bad:
        print(f"# REGRESSION: {len(bad)} failing rows: "
              + ", ".join(d["name"] for d in bad))
        return 1
    print(f"# regress: {len(deltas)} rows within thresholds")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Controlled unbalancing — the paper's §6 FUTURE WORK, implemented.

"it should be possible to construct a controlled unbalancing which will
outperform the randomly unbalanced index structure" (§6).  We sweep the
split quantile q of the LRT/median trees: q=0.5 is the paper's balanced
tree; q != 0.5 deterministically skews every node.  The sweep tests the
paper's conjecture against the serendipitously-unbalanced 'closer' tree.
"""

from __future__ import annotations

import numpy as np

from benchmarks.paper_common import load_space, row, timed
from repro.core import lrt


def run(datasets=("colors", "nasa"), seed: int = 0) -> list[str]:
    rows = []
    for ds in datasets:
        db, q, t = load_space(ds, seed=seed)
        results = {}
        for quant in (0.3, 0.4, 0.5, 0.6, 0.7):
            tr = lrt.build_monotone_tree(
                "lrt", "far", "l2", db, seed=seed + 3, split_quantile=quant
            )
            (hits, counter), dt = timed(
                lrt.range_search_monotone, tr, q, t, "hilbert"
            )
            results[quant] = counter.mean
            rows.append(row(
                f"unbalance/{ds}/lrt_q{quant}", dt / len(q) * 1e6,
                f"dists_per_query={counter.mean:.1f};depth={tr.max_depth}",
            ))
        tr = lrt.build_monotone_tree("closer", "far", "l2", db, seed=seed + 3)
        (_, counter), dt = timed(lrt.range_search_monotone, tr, q, t, "hilbert")
        rows.append(row(
            f"unbalance/{ds}/closer_random_skew", dt / len(q) * 1e6,
            f"dists_per_query={counter.mean:.1f};depth={tr.max_depth}",
        ))
        best_q = min(results, key=results.get)
        rows.append(row(
            f"unbalance/{ds}/summary", 0.0,
            f"best_q={best_q};best={results[best_q]:.1f};"
            f"balanced={results[0.5]:.1f};random_skew={counter.mean:.1f};"
            f"paper_conjecture_holds={results[best_q] < counter.mean}",
        ))
    return rows

"""Deterministic, checkpointable synthetic data pipelines.

Every stream is a pure function of (seed, step): saving ``state()`` in a
checkpoint and calling ``restore()`` resumes the exact sequence — the
fault-tolerance contract (test-covered).  Real deployments swap the
``_synthesize`` bodies for file readers; the iterator state/resume protocol
is the part that matters.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TokenStream", "ClickStream", "NeighborSampler", "batched_molecules"]


@dataclasses.dataclass
class _StreamState:
    seed: int
    step: int


class _Stream:
    def __init__(self, seed: int = 0):
        self._st = _StreamState(seed=seed, step=0)

    def state(self) -> dict:
        return dataclasses.asdict(self._st)

    def restore(self, state: dict) -> None:
        self._st = _StreamState(**state)

    def _rng(self) -> np.random.Generator:
        # counter-based: independent of call history
        return np.random.default_rng((self._st.seed << 20) ^ self._st.step)


class TokenStream(_Stream):
    """Zipf-distributed token batches (B, S+1) for LM training."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        super().__init__(seed)
        self.vocab, self.batch, self.seq = vocab, batch, seq

    def next(self) -> dict:
        rng = self._rng()
        self._st.step += 1
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        return {"tokens": (z % self.vocab).astype(np.int32)}


class ClickStream(_Stream):
    """Synthetic CTR log for the recsys models."""

    def __init__(self, cfg, batch: int, seed: int = 0):
        super().__init__(seed)
        self.cfg, self.batch = cfg, batch

    def next(self) -> dict:
        from repro.configs.common import recsys_batch_sds

        rng = self._rng()
        self._st.step += 1
        sds = recsys_batch_sds(self.cfg, self.batch, train=True)
        out = {}
        for key, sd in sds.items():
            if str(sd.dtype).startswith("int"):
                out[key] = rng.integers(0, self.cfg.vocab, size=sd.shape, dtype=np.int32)
            elif str(sd.dtype) == "bool":
                out[key] = rng.random(sd.shape) < 0.9
            else:
                out[key] = rng.random(sd.shape).astype(np.float32)
        if "label" in out:
            out["label"] = (rng.random(sd.shape[:1]) < 0.3).astype(np.float32)
        return out


class NeighborSampler(_Stream):
    """Layer-wise uniform neighbour sampling (GraphSAGE-style) over a CSR
    graph — the real sampler the ``minibatch_lg`` cell's shapes come from.

    Produces a padded subgraph: seeds + fanout[0] + fanout[0]*fanout[1] ...
    node slots; edges point child->parent so segment aggregation at the
    parents sees sampled neighbourhoods.
    """

    def __init__(self, indptr: np.ndarray, indices: np.ndarray,
                 features: np.ndarray, labels: np.ndarray,
                 batch_nodes: int, fanout: tuple[int, ...], seed: int = 0):
        super().__init__(seed)
        self.indptr, self.indices = indptr, indices
        self.features, self.labels = features, labels
        self.batch_nodes, self.fanout = batch_nodes, fanout

    def next(self) -> dict:
        rng = self._rng()
        self._st.step += 1
        n = self.indptr.shape[0] - 1
        seeds = rng.integers(0, n, size=self.batch_nodes)
        node_ids = [seeds]
        edge_src, edge_dst = [], []
        frontier = seeds
        offset = 0
        for f in self.fanout:
            starts = self.indptr[frontier]
            degs = self.indptr[frontier + 1] - starts
            # uniform-with-replacement sample of f neighbours per node
            picks = (rng.random((len(frontier), f)) *
                     np.maximum(degs, 1)[:, None]).astype(np.int64)
            picks = np.minimum(picks, np.maximum(degs - 1, 0)[:, None])
            nbrs = self.indices[starts[:, None] + picks]  # (front, f)
            isolated = degs == 0
            nbrs[isolated] = frontier[isolated][:, None]  # self-loop fallback
            child_slot = offset + len(frontier) + np.arange(nbrs.size)
            parent_slot = offset + np.repeat(np.arange(len(frontier)), f)
            edge_src.append(child_slot)
            edge_dst.append(parent_slot)
            node_ids.append(nbrs.reshape(-1))
            offset += len(frontier)
            frontier = nbrs.reshape(-1)
        all_nodes = np.concatenate(node_ids)
        src = np.concatenate(edge_src).astype(np.int32)
        dst = np.concatenate(edge_dst).astype(np.int32)
        x = self.features[all_nodes].astype(np.float32)
        labels = self.labels[all_nodes].astype(np.int32)
        mask = np.zeros(len(all_nodes), np.float32)
        mask[: self.batch_nodes] = 1.0  # loss on seeds only
        return {
            "x": x,
            "edge_src": src,
            "edge_dst": dst,
            "labels": labels,
            "label_mask": mask,
        }


def batched_molecules(rng: np.random.Generator, n_graphs: int, n_nodes: int,
                      n_edges: int, d_feat: int, n_classes: int) -> dict:
    """Block-diagonal batch of small graphs + graph-level labels."""
    xs, srcs, dsts, gids = [], [], [], []
    for g in range(n_graphs):
        xs.append(rng.normal(size=(n_nodes, d_feat)).astype(np.float32))
        s = rng.integers(0, n_nodes, size=n_edges)
        d = rng.integers(0, n_nodes, size=n_edges)
        srcs.append(s + g * n_nodes)
        dsts.append(d + g * n_nodes)
        gids.append(np.full(n_nodes, g, np.int32))
    return {
        "x": np.concatenate(xs),
        "edge_src": np.concatenate(srcs).astype(np.int32),
        "edge_dst": np.concatenate(dsts).astype(np.int32),
        "graph_id": np.concatenate(gids),
        "labels": rng.integers(0, n_classes, size=n_graphs).astype(np.int32),
    }

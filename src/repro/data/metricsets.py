"""Metric-space benchmark datasets.

``euc10`` follows the paper exactly (uniformly random 10-d Euclidean).  The
SISAP ``colors`` / ``nasa`` sets cannot be downloaded in this offline
container, so we generate **surrogates** with matching cardinality /
dimensionality and the property the paper leans on: strongly non-uniform,
clustered "real-world" structure (mixtures with skewed cluster weights plus
outliers).  Absolute distance counts will differ from the published numbers;
all *relative* claims are checked against these surrogates (see DESIGN.md §6).

Thresholds are calibrated the way the paper describes its own: by target
selectivity (fraction of the dataset returned per query).
"""

from __future__ import annotations

import numpy as np

from repro.core.npdist import pairwise_np

__all__ = [
    "euc10",
    "colors_surrogate",
    "nasa_surrogate",
    "topics_surrogate",
    "split_queries",
    "calibrate_threshold",
    "DATASETS",
    "PROB_DATASETS",
]


def euc10(n: int = 100_000, seed: int = 0) -> np.ndarray:
    """Uniform [0,1]^10, the paper's generated benchmark."""
    rng = np.random.default_rng(seed)
    return rng.random((n, 10)).astype(np.float64)


def colors_surrogate(n: int = 112_682, dim: int = 112, seed: int = 0) -> np.ndarray:
    """Colour-histogram-like: non-negative, rows sum to 1, heavily clustered.

    Mixture of Dirichlet clusters with Zipf-skewed weights + 4% diffuse
    outliers — mimics the clustered/outlier structure visible in the paper's
    appendix scatter plots.
    """
    rng = np.random.default_rng(seed)
    k = 40
    # sparse cluster centres (few dominant bins, like colour histograms)
    centres = rng.gamma(0.35, size=(k, dim))
    centres /= centres.sum(axis=1, keepdims=True)
    weights = 1.0 / np.arange(1, k + 1) ** 1.1
    weights /= weights.sum()
    kappa = rng.lognormal(mean=4.5, sigma=0.6, size=k)  # cluster tightness
    assign = rng.choice(k, size=n, p=weights)
    alpha = centres[assign] * kappa[assign, None] + 1e-3
    pts = rng.gamma(np.maximum(alpha, 1e-6))
    pts /= np.maximum(pts.sum(axis=1, keepdims=True), 1e-12)
    outliers = rng.random(n) < 0.04
    if outliers.any():
        o = rng.gamma(0.5, size=(int(outliers.sum()), dim))
        o /= o.sum(axis=1, keepdims=True)
        pts[outliers] = o
    return pts.astype(np.float64)


def nasa_surrogate(n: int = 40_150, dim: int = 20, seed: int = 0) -> np.ndarray:
    """PCA-reduced-feature-like: Gaussian mixture with decaying eigen-spectrum
    and a heavy tail, normalised to the paper's scale (distances O(0.1-1))."""
    rng = np.random.default_rng(seed)
    k = 15
    spectrum = 1.0 / np.arange(1, dim + 1) ** 1.2
    weights = rng.dirichlet(np.full(k, 0.5))
    means = rng.normal(size=(k, dim)) * np.sqrt(spectrum) * 1.5
    assign = rng.choice(k, size=n, p=weights)
    # heavy-tailed per-point spread with a floor (no exact duplicates: a
    # scale of ~0 would collapse points onto cluster means and degenerate
    # low-selectivity threshold calibration)
    scale = np.abs(rng.standard_t(df=6, size=(n, 1)) * 0.15) + 0.35
    pts = means[assign] + rng.normal(size=(n, dim)) * np.sqrt(spectrum) * scale
    pts *= 0.25  # scale so t-values land near the paper's range (~0.1-0.5)
    return pts.astype(np.float64)


def topics_surrogate(n: int = 24_576, dim: int = 64, seed: int = 0) -> np.ndarray:
    """Topic-model / term-histogram embeddings: probability vectors on the
    ``dim``-simplex, the corpus type served under the probability-space
    supermetrics (Jensen-Shannon and Triangular, paper §2.2).

    Mixture of sparse Dirichlet topic profiles with Zipf-skewed topic
    popularity: most documents concentrate on a few topics (tight clusters
    the four-point bound can prune), a diffuse tail keeps the space honest.
    """
    rng = np.random.default_rng(seed)
    k = 24
    profiles = rng.gamma(0.25, size=(k, dim))  # sparse: few dominant terms
    profiles /= profiles.sum(axis=1, keepdims=True)
    weights = 1.0 / np.arange(1, k + 1) ** 1.2
    weights /= weights.sum()
    conc = rng.lognormal(mean=4.0, sigma=0.5, size=k)  # per-topic tightness
    assign = rng.choice(k, size=n, p=weights)
    alpha = profiles[assign] * conc[assign, None] + 1e-3
    pts = rng.gamma(np.maximum(alpha, 1e-6))
    pts /= np.maximum(pts.sum(axis=1, keepdims=True), 1e-12)
    diffuse = rng.random(n) < 0.05
    if diffuse.any():
        o = rng.gamma(0.8, size=(int(diffuse.sum()), dim))
        o /= o.sum(axis=1, keepdims=True)
        pts[diffuse] = o
    return pts.astype(np.float64)


def split_queries(
    data: np.ndarray, frac: float = 0.10, seed: int = 0, max_queries: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """Paper protocol: remove a random fraction of the data as the query set."""
    rng = np.random.default_rng(seed)
    n = data.shape[0]
    nq = int(n * frac)
    idx = rng.permutation(n)
    q = data[idx[:nq]]
    if max_queries is not None:
        q = q[:max_queries]
    return data[idx[nq:]], q


def calibrate_threshold(
    metric: str,
    data: np.ndarray,
    selectivity: float,
    seed: int = 0,
    n_query_sample: int = 200,
    n_data_sample: int = 20_000,
) -> float:
    """Distance quantile so a range query returns ~selectivity * |data|."""
    rng = np.random.default_rng(seed)
    qi = rng.choice(data.shape[0], size=min(n_query_sample, data.shape[0]), replace=False)
    di = rng.choice(data.shape[0], size=min(n_data_sample, data.shape[0]), replace=False)
    d = pairwise_np(metric, data[qi], data[di]).ravel()
    d = d[d > 1e-12]  # drop self-pairs (query/data samples overlap)
    return float(np.quantile(d, selectivity))


# name -> (generator, paper thresholds for l2 at t0/t1/t2, target selectivities)
DATASETS = {
    "euc10": (euc10, (0.229, 0.245, 0.263), (1e-6, 2e-6, 4e-6)),
    "colors": (colors_surrogate, (0.052, 0.083, 0.131), (1e-5, 1e-4, 1e-3)),
    "nasa": (nasa_surrogate, (0.120, 0.285, 0.530), (1e-5, 1e-4, 1e-3)),
}

# probability-vector corpora (rows on the simplex) — valid under every
# metric in the registry including the probability-space supermetrics
PROB_DATASETS = {
    "topics": topics_surrogate,
    "colors": colors_surrogate,
}

"""Fault-tolerant checkpointing.

Design (scales to multi-host: every rank writes only its local shards):
  * one file per pytree leaf (memory-bounded streaming writes),
  * a JSON manifest with tree structure, shapes, dtypes and content hashes,
  * two-phase commit: write into ``step_K.tmp/`` then atomic ``rename`` to
    ``step_K/`` — a crash mid-save can never corrupt the latest checkpoint,
  * async save (background thread) so the train loop is not blocked,
  * data-iterator state saved alongside params/opt so restarts are
    bit-exact resumptions,
  * restore accepts a DIFFERENT mesh/sharding than save used (elastic
    restarts): leaves are loaded host-side and re-placed with the new
    shardings via device_put.
"""

from __future__ import annotations

import hashlib
import json
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointManager"]

# npy files cannot represent ml_dtypes (bfloat16, fp8): store them as
# same-width integer views and record the logical dtype in the manifest.
_EXOTIC_STORAGE = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _to_storable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name in _EXOTIC_STORAGE:
        return arr.view(_EXOTIC_STORAGE[name]), name
    return arr, name


def _from_storable(arr: np.ndarray, logical: str) -> np.ndarray:
    if logical in _EXOTIC_STORAGE:
        import ml_dtypes

        return arr.view(getattr(ml_dtypes, logical))
    return arr


def _flatten_with_paths(tree: Any):
    # jax.tree.flatten_with_path only exists on jax >= 0.4.38; the
    # tree_util spelling works on every version this repo supports.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_last: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save

    def save(self, step: int, state: Any, extra: dict | None = None,
             blocking: bool = True) -> None:
        """Two-phase-commit save of a pytree (+ JSON-able ``extra``)."""
        # Pull to host OUTSIDE the thread (device buffers are not
        # thread-safe to donate); hashes computed during write.
        host_state = jax.tree.map(np.asarray, state)
        if blocking:
            self._write(step, host_state, extra or {})
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, extra or {})
            )
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state: Any, extra: dict) -> None:
        tmp = self.dir / f"step_{step:09d}.tmp"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves, treedef = _flatten_with_paths(host_state)
        manifest = {"step": step, "extra": extra, "leaves": [], "treedef":
                    jax.tree.unflatten(treedef, [None] * len(leaves)).__repr__()[:0]}
        for i, (key, leaf) in enumerate(leaves):
            arr, logical = _to_storable(np.asarray(leaf))
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append({
                "key": key,
                "file": fname,
                "shape": list(arr.shape),
                "dtype": logical,
                "sha256": hashlib.sha256(arr.tobytes()).hexdigest()[:16],
            })
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # --------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if not p.name.endswith(".tmp")
        )

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: int | None = None,
                shardings: Any = None, verify: bool = True) -> tuple[Any, dict]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings`` (optional pytree) re-places leaves
        for the CURRENT mesh — elastic restart path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat_like, treedef = _flatten_with_paths(like)
        if len(flat_like) != len(manifest["leaves"]):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"restore target has {len(flat_like)}"
            )
        by_key = {m["key"]: m for m in manifest["leaves"]}
        leaves = []
        for key, leaf_like in flat_like:
            m = by_key[key]
            arr = np.load(d / m["file"])
            if verify:
                h = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
                if h != m["sha256"]:
                    raise IOError(f"checksum mismatch for {key} in step {step}")
            leaves.append(_from_storable(arr, m["dtype"]))
        state = jax.tree.unflatten(
            jax.tree.structure(like), leaves
        )
        if shardings is not None:
            state = jax.tree.map(
                lambda a, s: jax.device_put(a, s), state, shardings
            )
        return state, manifest["extra"]

"""Retrieval serving: the paper's technique deployed as a production feature.

Pipeline: an embedded corpus (trained two-tower item tower, topic/histogram
model, …) -> the embeddings are indexed by the Blocked Supermetric Scan
(exact search, four-point pruning) -> queries are served in batches through
the fused engine (``bss_query_batched`` / ``bss_knn_batched``): the whole
query path is one jitted function per round (Pallas kernels on TPU, fused
XLA elsewhere).

The server is parametrised by METRIC — any four-point metric in the
registry is served exactly:

* ``metric="cosine"`` (default) — the dot-product specialisation: scoring a
  dot product on l2-normalised towers is order-equivalent to Euclidean
  distance (``d^2 = 2 - 2<u,i>``), so the supermetric index serves EXACT
  top-k / min-score retrieval for the model's own similarity.  The
  score↔distance mapping (``score_to_distance``) lives only in this
  specialisation; the engine itself serves cosine as l2 on the unit sphere.
* ``metric="jsd"`` / ``"triangular"`` — probability-vector corpora
  (topic mixtures, histograms): thresholds are distances, use
  ``range_by_distance``; ``top_k`` works unchanged.
* ``metric="l2"`` (or a registered power transform) — plain metric serving.

Index backends
--------------
``index="bss"`` (default) serves through the Blocked Supermetric Scan;
``index="forest"`` builds one of the paper's partition trees
(``forest_variant``, default the paper's best ``hpt_fft_log``), encodes it
with ``repro.forest`` and serves range queries through the jitted batched
tree walk — same exactness contract, tree-shaped pruning.  kNN serving
stays a BSS capability (the forest walker is a range engine; its
radius-deepening reduction is ROADMAP work), so ``top_k`` on a forest
server raises.

Unified search API
------------------
``server.search(queries, kind="range"|"knn", *, t=..., k=..., opts=...)``
is THE entry point: both kinds, one typed :class:`SearchResult` (hits /
indices / distances / engine stats / index generation), engine knobs as
one frozen :class:`~repro.core.backends.EngineOpts`.  The older
per-kind methods (``range_query`` / ``range_by_distance`` / ``top_k``)
remain as thin delegates for compatibility.

Living corpus
-------------
A BSS server mutates in place through the functional maintenance ops:
``server.append(embeddings)`` / ``server.delete(ids)`` /
``server.compact()`` swap ``self.index`` for the next snapshot (queries
always see one consistent generation) and keep ``self.corpus`` — the
scoring/oracle mirror — consistent: appends extend it with the SAME
engine-space rows the index ingests, deletes mark a live mask that
``top_k_oracle`` honours.  Mutations fold into ``server.metrics``
(``index/generation``, ``index/tombstone_frac``, per-op latency).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import flat_index, tree
from repro.core.backends import EngineOpts, resolve_engine_opts
from repro.core.exclusion import HILBERT
from repro.core.npdist import pairwise_np
from repro.forest import encode_tree, forest_range_search
from repro.index import maintain as index_maintain
from repro.obs.fold import fold_engine_stats, fold_mutation
from repro.obs.registry import MetricsRegistry
from repro.serve.queue import now

__all__ = ["RetrievalServer", "SearchResult", "score_to_distance",
           "distance_to_score", "FOREST_KNN_ERROR"]

# The one message every forest-kNN refusal raises (RetrievalServer.top_k and
# the async front's submit alike): point at the backend that CAN serve it
# and at the ROADMAP item that will make the walker serve it natively.
FOREST_KNN_ERROR = (
    "top_k serving runs on the BSS engine — rebuild with index='bss'; the "
    "forest walker is a range engine, and its radius-deepening kNN "
    "reduction (like bss_knn_batched's) is the open 'forest kNN' ROADMAP "
    "item"
)


def score_to_distance(score: np.ndarray) -> np.ndarray:
    """dot-product score (normalised towers) -> Euclidean distance."""
    return np.sqrt(np.maximum(2.0 - 2.0 * score, 0.0))


def distance_to_score(dist: np.ndarray) -> np.ndarray:
    return 1.0 - 0.5 * dist * dist


@dataclasses.dataclass
class SearchResult:
    """What :meth:`RetrievalServer.search` returns — one typed shape for
    both kinds.  Range fills ``hits``; kNN fills ``indices``/``distances``;
    both carry the engine's stats dict and the index generation the call
    was served on (bumped by every mutation)."""

    kind: str                            # "range" | "knn"
    hits: list | None = None             # range: per-query corpus-id lists
    indices: np.ndarray | None = None    # knn: (Q, k) ids, -1 padded
    distances: np.ndarray | None = None  # knn: (Q, k) ascending
    stats: dict | None = None            # the engine-call stats dict
    generation: int = 0


@dataclasses.dataclass
class ServeStats:
    n_queries: int = 0
    total_dists: float = 0.0
    total_seconds: float = 0.0
    exhaustive_dists: float = 0.0

    @property
    def dists_per_query(self) -> float:
        return self.total_dists / max(self.n_queries, 1)

    @property
    def saving(self) -> float:
        return 1.0 - self.total_dists / max(self.exhaustive_dists, 1.0)


class RetrievalServer:
    """Batched exact retrieval over an embedded corpus (fused BSS engine),
    parametrised by any four-point metric in the registry."""

    def __init__(self, corpus_embeddings: np.ndarray, *, metric: str = "cosine",
                 n_pivots: int = 16, n_pairs: int = 24, block: int = 128,
                 seed: int = 0, opts: EngineOpts | None = None,
                 backend: str | None = None, index: str = "bss",
                 forest_variant: str = "hpt_fft_log",
                 forest_mechanism: str = HILBERT, mesh=None):
        """``mesh`` (a ``jax.sharding.Mesh`` with a ``"data"`` axis) shards
        the BSS corpus blocks across the mesh's devices: every range / top_k
        call then runs one fused pass per shard with a cross-device merge
        (``repro.parallel.shard_index``), results identical to single-device
        serving.  BSS only — the forest walker is not sharded yet."""
        if index not in ("bss", "forest"):
            raise ValueError(f"index must be bss|forest, got {index!r}")
        if mesh is not None and index != "bss":
            raise ValueError(
                "mesh= shards the BSS engine; forest serving is single-device"
                " (ROADMAP work)"
            )
        corpus = np.array(corpus_embeddings, np.float32, copy=True)
        self.metric = metric
        if metric == "cosine":
            # kept normalised server-side so dot-product scoring against
            # self.corpus matches the index geometry exactly; the engine's
            # own floor is reused so both normalisations agree bit-for-bit
            corpus = flat_index._engine_queries("cosine", corpus)
        self.corpus = corpus
        # every live row of self.corpus; deletes flip entries False so the
        # brute-force oracle stays aligned with the served index
        self._live = np.ones(len(corpus), dtype=bool)
        self.opts = resolve_engine_opts(opts, backend=backend)
        self.backend = self.opts.backend  # legacy attribute view
        self.index_kind = index
        if index == "forest":
            # cosine rides the l2 geometry on the pre-normalised corpus,
            # exactly as in the BSS engine; other metrics build natively
            self.forest_mechanism = forest_mechanism
            self.tree = tree.build_tree(
                forest_variant, flat_index._engine_metric(metric), corpus,
                seed=seed,
            )
            self.index = encode_tree(self.tree)
        else:
            self.index = flat_index.build_bss(
                metric, corpus, n_pivots=n_pivots, n_pairs=n_pairs,
                block=block, seed=seed, mesh=mesh,
            )
        self.stats = ServeStats()
        # engine-call metrics (same registry/fold machinery as the async
        # front); synchronous serving folds once per batched call
        self.metrics = MetricsRegistry()

    def _prep(self, user_embeddings: np.ndarray) -> np.ndarray:
        q = np.asarray(user_embeddings, np.float32)
        if self.metric == "cosine":
            q = flat_index._engine_queries("cosine", q)
        return q

    def _account(self, nq: int, engine_stats: dict, t0: float) -> None:
        self.stats.n_queries += nq
        self.stats.total_dists += engine_stats["dists_per_query"] * nq
        # the exhaustive comparator scans the LIVE corpus (tombstoned rows
        # cost a brute-force scan nothing either)
        self.stats.exhaustive_dists += nq * int(self._live.sum())
        self.stats.total_seconds += now() - t0
        fold_engine_stats(self.metrics, engine_stats)
        self.metrics.histogram("serve/call_s").observe(now() - t0)

    def search(self, queries: np.ndarray, kind: str = "range", *,
               t: float | None = None, k: int | None = None,
               opts: EngineOpts | None = None,
               r0: float | None = None,
               max_rounds: int = 8) -> SearchResult:
        """The unified entry point: both query kinds, one typed result.

        ``kind="range"`` needs ``t`` (a METRIC distance — the cosine
        specialisation's min-score maps through ``score_to_distance``, or
        use the ``range_query`` delegate); ``kind="knn"`` needs a positive
        ``k`` (``r0`` / ``max_rounds`` tune its radius schedule).  ``opts``
        overrides the server's engine knobs for this call only.  The
        result carries the engine stats dict and the index ``generation``
        it was served on — after a mutation, results from the old snapshot
        are distinguishable by that field alone."""
        eng = self.opts if opts is None else resolve_engine_opts(opts)
        q = self._prep(queries)
        if kind == "range":
            if t is None:
                raise ValueError("range search needs t= (a metric distance)")
            t0 = now()
            if self.index_kind == "forest":
                hits, s = forest_range_search(
                    self.index, q, float(t), self.forest_mechanism, opts=eng,
                )
            else:
                hits, s = flat_index.bss_query_batched(
                    self.index, q, float(t), opts=eng,
                )
            self._account(len(q), s, t0)
            return SearchResult(
                kind="range", hits=hits, stats=s,
                generation=int(s.get("generation", 0)),
            )
        if kind == "knn":
            if self.index_kind == "forest":
                raise NotImplementedError(FOREST_KNN_ERROR)
            if k is None or int(k) <= 0:
                raise ValueError(f"knn search needs a positive k, got {k}")
            t0 = now()
            idx, dists, s = flat_index.bss_knn_batched(
                self.index, q, int(k), r0=r0, max_rounds=max_rounds,
                opts=eng,
            )
            self._account(len(q), s, t0)
            return SearchResult(
                kind="knn", indices=idx, distances=dists, stats=s,
                generation=int(s.get("generation", 0)),
            )
        raise ValueError(f"kind must be range|knn, got {kind!r}")

    def range_query(self, user_embeddings: np.ndarray, min_score: float):
        """All items with dot-score >= min_score — exact, one fused pass.
        Cosine (dot-product) serving only; other metrics threshold on
        distance, use ``range_by_distance``.

        Compatibility delegate: prefer
        ``search(q, "range", t=score_to_distance(min_score))``."""
        if self.metric != "cosine":
            raise ValueError(
                f"min-score retrieval is the cosine specialisation; the "
                f"{self.metric!r} server thresholds on distance — use "
                f"range_by_distance"
            )
        t = float(score_to_distance(np.asarray(min_score)))
        return self.range_by_distance(user_embeddings, t)

    def range_by_distance(self, user_embeddings: np.ndarray, t: float):
        """All items within metric distance t — exact, one fused pass
        (BSS masked scan or jitted forest walk, per ``index=``).

        Compatibility delegate: prefer ``search(q, "range", t=t)``, which
        also returns the engine stats and index generation."""
        return self.search(user_embeddings, "range", t=t).hits

    def top_k(self, user_embeddings: np.ndarray, k: int,
              t0_guess: float | None = None, max_rounds: int = 8):
        """Exact top-k via the batched radius-deepening engine: every round
        is one jitted pass over ALL pending queries, each query's
        kth-nearest-so-far distance tightening its pruning radius (see
        ``bss_knn_batched``).  ``t0_guess`` optionally seeds the radius
        (None = the engine's per-query scale-free estimate).

        Compatibility delegate: prefer ``search(q, "knn", k=k)``, whose
        result also carries the per-query distances, the engine stats and
        the index generation."""
        res = self.search(
            user_embeddings, "knn", k=k, r0=t0_guess, max_rounds=max_rounds,
        )
        return [res.indices[i] for i in range(res.indices.shape[0])]

    # ------------------------------------------------------------ mutations

    def _mutate(self, fn):
        if self.index_kind != "bss":
            raise NotImplementedError(
                "living-corpus mutations run on the BSS engine; the encoded "
                "forest is immutable — rebuild the server (incremental tree "
                "maintenance is ROADMAP work)"
            )
        t0 = now()
        new_index, mstats = fn(self.index)
        self.index = new_index
        if mstats is not None:
            fold_mutation(self.metrics, mstats, seconds=now() - t0)
        return mstats

    def append(self, embeddings: np.ndarray):
        """Add rows to the served corpus (fresh blocks against the existing
        pivot tables — no rebuild; see ``repro.index.maintain.append``).
        ``self.corpus`` extends with the SAME engine-space rows the index
        ingests (cosine pre-normalises exactly as ``__init__`` does), so
        dot-product scoring and ``top_k_oracle`` stay aligned.  Returns the
        mutation's ``MutationStats``."""
        rows = np.array(embeddings, np.float32, copy=True)
        if self.metric == "cosine":
            rows = flat_index._engine_queries("cosine", rows)

        def run(idx):
            out = index_maintain.append(idx, rows)
            # corpus mirror only grows once the mutation validated
            self.corpus = np.concatenate([self.corpus, rows])
            self._live = np.concatenate(
                [self._live, np.ones(len(rows), dtype=bool)]
            )
            return out

        return self._mutate(run)

    def delete(self, ids):
        """Tombstone corpus ids (they stop matching immediately; storage is
        reclaimed by ``compact``).  ``top_k_oracle`` honours the same live
        mask.  Returns the mutation's ``MutationStats``."""

        def run(idx):
            out = index_maintain.delete(idx, ids)
            self._live[np.asarray(list(ids), dtype=np.int64)] = False
            return out

        return self._mutate(run)

    def compact(self, *, refresh_pivots: bool = True):
        """Re-permute live rows into dense blocks (drops tombstones;
        ``refresh_pivots=True`` rebuilds pivot tables — bit-identical to a
        fresh build over the live rows).  Corpus ids are stable across
        compaction.  Returns the mutation's ``MutationStats``."""
        return self._mutate(
            lambda idx: index_maintain.compact(
                idx, refresh_pivots=refresh_pivots
            )
        )

    def maybe_compact(self, **kw):
        """Compact only when degraded — thresholds and the pivot-refresh
        policy pass through to ``repro.index.maintain.maybe_compact``.
        Returns the ``MutationStats`` when a compaction ran, else None."""
        return self._mutate(
            lambda idx: index_maintain.maybe_compact(idx, **kw)
        )

    def async_front(self, **kw):
        """An :class:`~repro.serve.front.ServingFront` over this server's
        index: per-request ``submit(...) -> Future`` with deadline
        micro-batching in front of the same fused engines (sharded ones on
        a mesh-built index).  Thresholds are metric DISTANCES (the engine
        space — use ``score_to_distance`` for the cosine/min-score
        specialisation).  Keyword args pass through to ``ServingFront``;
        the caller owns the front's lifecycle (``with server.async_front()
        as front: ...``).  The front snapshots ``self.index`` at
        construction: mutate a LIVE front through its own
        ``append``/``delete``/``compact`` methods (server-side mutations
        after this call don't reach an already-built front)."""
        from repro.serve.front import ServingFront

        if self.index_kind == "forest":
            kw.setdefault("mechanism", self.forest_mechanism)
            if self.metric == "cosine":
                # the tree was built on the normalised corpus under the l2
                # engine metric, so raw queries need the same mapping
                kw.setdefault("prep", self._prep)
        if not ({"opts", "backend", "interpret", "realisation"} & kw.keys()):
            # inherit the server's engine knobs, but let the front keep its
            # own "dense" realisation default (bucket-ladder contract);
            # any explicit engine kwarg hands full control to the caller
            kw["opts"] = dataclasses.replace(self.opts, realisation="dense")
        return ServingFront(self.index, **kw)

    def top_k_oracle(self, user_embeddings: np.ndarray, k: int) -> list:
        """Brute-force reference (numpy float64) — for tests/benchmarks.
        Chunked over queries: the probability-space metrics broadcast a
        (Q, N, dim) float64 intermediate, which must stay bounded."""
        q = self._prep(user_embeddings)
        dead = ~self._live
        out = []
        for lo in range(0, len(q), 32):
            d = pairwise_np(self.metric, q[lo:lo + 32], self.corpus)
            # tombstoned rows are out of the corpus for the oracle too
            d[:, dead] = np.inf
            out.extend(np.argsort(d[i])[:k] for i in range(d.shape[0]))
        return out

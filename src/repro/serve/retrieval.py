"""Retrieval serving: the paper's technique deployed as a production feature.

Pipeline: a trained two-tower model embeds the item corpus -> the embeddings
are indexed by the Blocked Supermetric Scan (exact search, four-point
pruning) -> queries are served in batches: user tower -> supermetric range /
kNN search over the corpus.

Dot-product scoring on l2-normalised towers is order-equivalent to Euclidean
distance (d^2 = 2 - 2<u,i>), so the supermetric index serves EXACT top-k /
threshold retrieval for the model's own similarity — the paper's exactness
guarantee carried into the serving path.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import flat_index
from repro.core.npdist import pairwise_np

__all__ = ["RetrievalServer", "score_to_distance", "distance_to_score"]


def score_to_distance(score: np.ndarray) -> np.ndarray:
    """dot-product score (normalised towers) -> Euclidean distance."""
    return np.sqrt(np.maximum(2.0 - 2.0 * score, 0.0))


def distance_to_score(dist: np.ndarray) -> np.ndarray:
    return 1.0 - 0.5 * dist * dist


@dataclasses.dataclass
class ServeStats:
    n_queries: int = 0
    total_dists: float = 0.0
    total_seconds: float = 0.0
    exhaustive_dists: float = 0.0

    @property
    def dists_per_query(self) -> float:
        return self.total_dists / max(self.n_queries, 1)

    @property
    def saving(self) -> float:
        return 1.0 - self.total_dists / max(self.exhaustive_dists, 1.0)


class RetrievalServer:
    """Batched exact retrieval over an embedded corpus."""

    def __init__(self, corpus_embeddings: np.ndarray, *, n_pivots: int = 16,
                 n_pairs: int = 24, block: int = 128, seed: int = 0):
        corpus = np.array(corpus_embeddings, np.float32, copy=True)
        corpus /= np.maximum(np.linalg.norm(corpus, axis=1, keepdims=True), 1e-9)
        self.corpus = corpus
        self.index = flat_index.build_bss(
            "l2", corpus, n_pivots=n_pivots, n_pairs=n_pairs, block=block,
            seed=seed,
        )
        self.stats = ServeStats()

    def range_query(self, user_embeddings: np.ndarray, min_score: float):
        """All items with dot-score >= min_score — exact."""
        q = np.array(user_embeddings, np.float32, copy=True)
        q /= np.maximum(np.linalg.norm(q, axis=1, keepdims=True), 1e-9)
        t = float(score_to_distance(np.asarray(min_score)))
        t0 = time.time()
        hits, s = flat_index.bss_query(self.index, q, t)
        self.stats.n_queries += len(q)
        self.stats.total_dists += s["dists_per_query"] * len(q)
        self.stats.exhaustive_dists += len(q) * self.corpus.shape[0]
        self.stats.total_seconds += time.time() - t0
        return hits

    def top_k(self, user_embeddings: np.ndarray, k: int,
              t0_guess: float = 0.6, max_rounds: int = 6):
        """Exact top-k via iterative-deepening range search: start from a
        tight radius and widen until >= k hits (standard kNN-from-range
        reduction; each round reuses the same index)."""
        q = np.array(user_embeddings, np.float32, copy=True)
        q /= np.maximum(np.linalg.norm(q, axis=1, keepdims=True), 1e-9)
        out = [None] * len(q)
        radius = np.full(len(q), t0_guess)
        pending = np.arange(len(q))
        for _ in range(max_rounds):
            if len(pending) == 0:
                break
            t = float(radius[pending].max())
            hits, s = flat_index.bss_query(self.index, q[pending], t)
            self.stats.n_queries += len(pending)
            self.stats.total_dists += s["dists_per_query"] * len(pending)
            self.stats.exhaustive_dists += len(pending) * self.corpus.shape[0]
            still = []
            for row, qi in enumerate(pending):
                if len(hits[row]) >= k:
                    idx = np.asarray(hits[row])
                    d = pairwise_np("l2", q[qi][None], self.corpus[idx])[0]
                    out[qi] = idx[np.argsort(d)[:k]]
                else:
                    still.append(qi)
            pending = np.asarray(still, dtype=np.int64)
            radius[pending] *= 1.6
        for qi in pending:  # pathological fallback: exhaustive
            d = pairwise_np("l2", q[qi][None], self.corpus)[0]
            self.stats.total_dists += self.corpus.shape[0]
            out[qi] = np.argsort(d)[:k]
        return out

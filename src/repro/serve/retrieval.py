"""Retrieval serving: the paper's technique deployed as a production feature.

Pipeline: an embedded corpus (trained two-tower item tower, topic/histogram
model, …) -> the embeddings are indexed by the Blocked Supermetric Scan
(exact search, four-point pruning) -> queries are served in batches through
the fused engine (``bss_query_batched`` / ``bss_knn_batched``): the whole
query path is one jitted function per round (Pallas kernels on TPU, fused
XLA elsewhere).

The server is parametrised by METRIC — any four-point metric in the
registry is served exactly:

* ``metric="cosine"`` (default) — the dot-product specialisation: scoring a
  dot product on l2-normalised towers is order-equivalent to Euclidean
  distance (``d^2 = 2 - 2<u,i>``), so the supermetric index serves EXACT
  top-k / min-score retrieval for the model's own similarity.  The
  score↔distance mapping (``score_to_distance``) lives only in this
  specialisation; the engine itself serves cosine as l2 on the unit sphere.
* ``metric="jsd"`` / ``"triangular"`` — probability-vector corpora
  (topic mixtures, histograms): thresholds are distances, use
  ``range_by_distance``; ``top_k`` works unchanged.
* ``metric="l2"`` (or a registered power transform) — plain metric serving.

Index backends
--------------
``index="bss"`` (default) serves through the Blocked Supermetric Scan;
``index="forest"`` builds one of the paper's partition trees
(``forest_variant``, default the paper's best ``hpt_fft_log``), encodes it
with ``repro.forest`` and serves range queries through the jitted batched
tree walk — same exactness contract, tree-shaped pruning.  kNN serving
stays a BSS capability (the forest walker is a range engine; its
radius-deepening reduction is ROADMAP work), so ``top_k`` on a forest
server raises.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import flat_index, tree
from repro.core.exclusion import HILBERT
from repro.core.npdist import pairwise_np
from repro.forest import encode_tree, forest_range_search
from repro.obs.fold import fold_engine_stats
from repro.obs.registry import MetricsRegistry
from repro.serve.queue import now

__all__ = ["RetrievalServer", "score_to_distance", "distance_to_score",
           "FOREST_KNN_ERROR"]

# The one message every forest-kNN refusal raises (RetrievalServer.top_k and
# the async front's submit alike): point at the backend that CAN serve it
# and at the ROADMAP item that will make the walker serve it natively.
FOREST_KNN_ERROR = (
    "top_k serving runs on the BSS engine — rebuild with index='bss'; the "
    "forest walker is a range engine, and its radius-deepening kNN "
    "reduction (like bss_knn_batched's) is the open 'forest kNN' ROADMAP "
    "item"
)


def score_to_distance(score: np.ndarray) -> np.ndarray:
    """dot-product score (normalised towers) -> Euclidean distance."""
    return np.sqrt(np.maximum(2.0 - 2.0 * score, 0.0))


def distance_to_score(dist: np.ndarray) -> np.ndarray:
    return 1.0 - 0.5 * dist * dist


@dataclasses.dataclass
class ServeStats:
    n_queries: int = 0
    total_dists: float = 0.0
    total_seconds: float = 0.0
    exhaustive_dists: float = 0.0

    @property
    def dists_per_query(self) -> float:
        return self.total_dists / max(self.n_queries, 1)

    @property
    def saving(self) -> float:
        return 1.0 - self.total_dists / max(self.exhaustive_dists, 1.0)


class RetrievalServer:
    """Batched exact retrieval over an embedded corpus (fused BSS engine),
    parametrised by any four-point metric in the registry."""

    def __init__(self, corpus_embeddings: np.ndarray, *, metric: str = "cosine",
                 n_pivots: int = 16, n_pairs: int = 24, block: int = 128,
                 seed: int = 0, backend: str = "auto", index: str = "bss",
                 forest_variant: str = "hpt_fft_log",
                 forest_mechanism: str = HILBERT, mesh=None):
        """``mesh`` (a ``jax.sharding.Mesh`` with a ``"data"`` axis) shards
        the BSS corpus blocks across the mesh's devices: every range / top_k
        call then runs one fused pass per shard with a cross-device merge
        (``repro.parallel.shard_index``), results identical to single-device
        serving.  BSS only — the forest walker is not sharded yet."""
        if index not in ("bss", "forest"):
            raise ValueError(f"index must be bss|forest, got {index!r}")
        if mesh is not None and index != "bss":
            raise ValueError(
                "mesh= shards the BSS engine; forest serving is single-device"
                " (ROADMAP work)"
            )
        corpus = np.array(corpus_embeddings, np.float32, copy=True)
        self.metric = metric
        if metric == "cosine":
            # kept normalised server-side so dot-product scoring against
            # self.corpus matches the index geometry exactly; the engine's
            # own floor is reused so both normalisations agree bit-for-bit
            corpus = flat_index._engine_queries("cosine", corpus)
        self.corpus = corpus
        self.backend = backend
        self.index_kind = index
        if index == "forest":
            # cosine rides the l2 geometry on the pre-normalised corpus,
            # exactly as in the BSS engine; other metrics build natively
            self.forest_mechanism = forest_mechanism
            self.tree = tree.build_tree(
                forest_variant, flat_index._engine_metric(metric), corpus,
                seed=seed,
            )
            self.index = encode_tree(self.tree)
        else:
            self.index = flat_index.build_bss(
                metric, corpus, n_pivots=n_pivots, n_pairs=n_pairs,
                block=block, seed=seed, mesh=mesh,
            )
        self.stats = ServeStats()
        # engine-call metrics (same registry/fold machinery as the async
        # front); synchronous serving folds once per batched call
        self.metrics = MetricsRegistry()

    def _prep(self, user_embeddings: np.ndarray) -> np.ndarray:
        q = np.asarray(user_embeddings, np.float32)
        if self.metric == "cosine":
            q = flat_index._engine_queries("cosine", q)
        return q

    def _account(self, nq: int, engine_stats: dict, t0: float) -> None:
        self.stats.n_queries += nq
        self.stats.total_dists += engine_stats["dists_per_query"] * nq
        self.stats.exhaustive_dists += nq * self.corpus.shape[0]
        self.stats.total_seconds += now() - t0
        fold_engine_stats(self.metrics, engine_stats)
        self.metrics.histogram("serve/call_s").observe(now() - t0)

    def range_query(self, user_embeddings: np.ndarray, min_score: float):
        """All items with dot-score >= min_score — exact, one fused pass.
        Cosine (dot-product) serving only; other metrics threshold on
        distance, use ``range_by_distance``."""
        if self.metric != "cosine":
            raise ValueError(
                f"min-score retrieval is the cosine specialisation; the "
                f"{self.metric!r} server thresholds on distance — use "
                f"range_by_distance"
            )
        t = float(score_to_distance(np.asarray(min_score)))
        return self.range_by_distance(user_embeddings, t)

    def range_by_distance(self, user_embeddings: np.ndarray, t: float):
        """All items within metric distance t — exact, one fused pass
        (BSS masked scan or jitted forest walk, per ``index=``)."""
        q = self._prep(user_embeddings)
        t0 = now()
        if self.index_kind == "forest":
            hits, s = forest_range_search(
                self.index, q, float(t), self.forest_mechanism,
                backend=self.backend,
            )
        else:
            hits, s = flat_index.bss_query_batched(
                self.index, q, float(t), backend=self.backend
            )
        self._account(len(q), s, t0)
        return hits

    def top_k(self, user_embeddings: np.ndarray, k: int,
              t0_guess: float | None = None, max_rounds: int = 8):
        """Exact top-k via the batched radius-deepening engine: every round
        is one jitted pass over ALL pending queries, each query's
        kth-nearest-so-far distance tightening its pruning radius (see
        ``bss_knn_batched``).  ``t0_guess`` optionally seeds the radius
        (None = the engine's per-query scale-free estimate)."""
        if self.index_kind == "forest":
            raise NotImplementedError(FOREST_KNN_ERROR)
        q = self._prep(user_embeddings)
        t0 = now()
        idx, dists, s = flat_index.bss_knn_batched(
            self.index, q, k, r0=t0_guess, max_rounds=max_rounds,
            backend=self.backend,
        )
        self._account(len(q), s, t0)
        return [idx[i] for i in range(idx.shape[0])]

    def async_front(self, **kw):
        """An :class:`~repro.serve.front.ServingFront` over this server's
        index: per-request ``submit(...) -> Future`` with deadline
        micro-batching in front of the same fused engines (sharded ones on
        a mesh-built index).  Thresholds are metric DISTANCES (the engine
        space — use ``score_to_distance`` for the cosine/min-score
        specialisation).  Keyword args pass through to ``ServingFront``;
        the caller owns the front's lifecycle (``with server.async_front()
        as front: ...``)."""
        from repro.serve.front import ServingFront

        if self.index_kind == "forest":
            kw.setdefault("mechanism", self.forest_mechanism)
            if self.metric == "cosine":
                # the tree was built on the normalised corpus under the l2
                # engine metric, so raw queries need the same mapping
                kw.setdefault("prep", self._prep)
        return ServingFront(self.index, backend=self.backend, **kw)

    def top_k_oracle(self, user_embeddings: np.ndarray, k: int) -> list:
        """Brute-force reference (numpy float64) — for tests/benchmarks.
        Chunked over queries: the probability-space metrics broadcast a
        (Q, N, dim) float64 intermediate, which must stay bounded."""
        q = self._prep(user_embeddings)
        out = []
        for lo in range(0, len(q), 32):
            d = pairwise_np(self.metric, q[lo:lo + 32], self.corpus)
            out.extend(np.argsort(d[i])[:k] for i in range(d.shape[0]))
        return out

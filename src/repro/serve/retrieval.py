"""Retrieval serving: the paper's technique deployed as a production feature.

Pipeline: a trained two-tower model embeds the item corpus -> the embeddings
are indexed by the Blocked Supermetric Scan (exact search, four-point
pruning) -> queries are served in batches: user tower -> supermetric range /
kNN search over the corpus.

Dot-product scoring on l2-normalised towers is order-equivalent to Euclidean
distance (d^2 = 2 - 2<u,i>), so the supermetric index serves EXACT top-k /
threshold retrieval for the model's own similarity — the paper's exactness
guarantee carried into the serving path.

Both entry points run on the fused batched engine (``bss_query_batched`` /
``bss_knn_batched``): the whole query path is one jitted function per round
(Pallas kernels on TPU, fused XLA elsewhere), replacing the per-block host
loops this server originally layered on top of the index.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import flat_index
from repro.core.npdist import pairwise_np

__all__ = ["RetrievalServer", "score_to_distance", "distance_to_score"]


def score_to_distance(score: np.ndarray) -> np.ndarray:
    """dot-product score (normalised towers) -> Euclidean distance."""
    return np.sqrt(np.maximum(2.0 - 2.0 * score, 0.0))


def distance_to_score(dist: np.ndarray) -> np.ndarray:
    return 1.0 - 0.5 * dist * dist


@dataclasses.dataclass
class ServeStats:
    n_queries: int = 0
    total_dists: float = 0.0
    total_seconds: float = 0.0
    exhaustive_dists: float = 0.0

    @property
    def dists_per_query(self) -> float:
        return self.total_dists / max(self.n_queries, 1)

    @property
    def saving(self) -> float:
        return 1.0 - self.total_dists / max(self.exhaustive_dists, 1.0)


class RetrievalServer:
    """Batched exact retrieval over an embedded corpus (fused BSS engine)."""

    def __init__(self, corpus_embeddings: np.ndarray, *, n_pivots: int = 16,
                 n_pairs: int = 24, block: int = 128, seed: int = 0,
                 backend: str = "auto"):
        corpus = np.array(corpus_embeddings, np.float32, copy=True)
        corpus /= np.maximum(np.linalg.norm(corpus, axis=1, keepdims=True), 1e-9)
        self.corpus = corpus
        self.backend = backend
        self.index = flat_index.build_bss(
            "l2", corpus, n_pivots=n_pivots, n_pairs=n_pairs, block=block,
            seed=seed,
        )
        self.stats = ServeStats()

    def _normalise(self, user_embeddings: np.ndarray) -> np.ndarray:
        q = np.array(user_embeddings, np.float32, copy=True)
        q /= np.maximum(np.linalg.norm(q, axis=1, keepdims=True), 1e-9)
        return q

    def range_query(self, user_embeddings: np.ndarray, min_score: float):
        """All items with dot-score >= min_score — exact, one fused pass."""
        q = self._normalise(user_embeddings)
        t = float(score_to_distance(np.asarray(min_score)))
        t0 = time.time()
        hits, s = flat_index.bss_query_batched(
            self.index, q, t, backend=self.backend
        )
        self.stats.n_queries += len(q)
        self.stats.total_dists += s["dists_per_query"] * len(q)
        self.stats.exhaustive_dists += len(q) * self.corpus.shape[0]
        self.stats.total_seconds += time.time() - t0
        return hits

    def top_k(self, user_embeddings: np.ndarray, k: int,
              t0_guess: float | None = None, max_rounds: int = 8):
        """Exact top-k via the batched radius-deepening engine: every round
        is one jitted pass over ALL pending queries, each query's
        kth-nearest-so-far distance tightening its pruning radius (see
        ``bss_knn_batched``).  ``t0_guess`` optionally seeds the radius
        (None = the engine's per-query scale-free estimate)."""
        q = self._normalise(user_embeddings)
        t0 = time.time()
        idx, dists, s = flat_index.bss_knn_batched(
            self.index, q, k, r0=t0_guess, max_rounds=max_rounds,
            backend=self.backend,
        )
        self.stats.n_queries += len(q)
        self.stats.total_dists += s["dists_per_query"] * len(q)
        self.stats.exhaustive_dists += len(q) * self.corpus.shape[0]
        self.stats.total_seconds += time.time() - t0
        return [idx[i] for i in range(idx.shape[0])]

    def top_k_oracle(self, user_embeddings: np.ndarray, k: int) -> list:
        """Brute-force reference (numpy float64) — for tests/benchmarks."""
        q = self._normalise(user_embeddings)
        d = pairwise_np("l2", q, self.corpus)
        return [np.argsort(d[i])[:k] for i in range(len(q))]

"""Request plumbing for the async serving front: the bounded admission
queue, the request record, and the shared monotonic clock.

The front's unit of work is a REQUEST STREAM — single queries arriving one
at a time — so this module provides what a pre-assembled-batch engine never
needed: a thread-safe bounded queue whose consumer side pops *groups* of
engine-compatible requests (same dispatch signature) and whose producer
side enforces admission (block until space, or shed immediately).

Everything here is host-side by design: the driver thread, the deadline
arithmetic and the queue never touch jax.  ``now`` is the one clock the
whole serving stack (and, via ``benchmarks.paper_common``, the benchmark
suite) times with — ``time.perf_counter``, monotonic and high-resolution,
instead of wall-clock ``time.time`` which steps under NTP adjustments.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from concurrent.futures import Future

import numpy as np

__all__ = ["now", "nearest_rank", "Request", "ShedError",
           "BoundedRequestQueue"]

# the shared monotonic clock: every queue-wait, deadline, and benchmark
# timing in the repo reads this, never time.time()
now = time.perf_counter


def nearest_rank(xs, p: float) -> float:
    """Nearest-rank percentile (p in [0, 1]) of an UNSORTED sequence —
    ``xs[ceil(p*N) - 1]`` of the sorted values, the definition that makes
    p=0.99 of 10 samples the maximum rather than an interior sample — the
    one latency statistic the front's telemetry and the serving benchmarks
    both report; 0.0 on an empty sequence."""
    xs = sorted(xs)
    if not xs:
        return 0.0
    return float(xs[min(len(xs), max(1, math.ceil(p * len(xs)))) - 1])


class ShedError(RuntimeError):
    """Admission control rejected the request (queue full under the shed
    policy, or the front is closed)."""


@dataclasses.dataclass
class Request:
    """One submitted query, from admission to future resolution.

    ``group`` is the dispatch-compatibility key: requests sharing it can
    ride the same engine call (same kind; kNN also same (k, r0, max_rounds)
    since those shape the radius schedule; forest range also same t since
    the walker takes a scalar threshold).  ``t`` is carried per-request for
    the BSS range path, which accepts per-query radii — mixed thresholds
    batch together there."""

    query: np.ndarray          # (dim,) float32, finite (validated at submit)
    kind: str                  # "range" | "knn"
    group: tuple               # dispatch-compatibility key
    future: Future
    t_submit: float            # now() at admission
    t: float | None = None     # range radius (per-request)
    k: int | None = None       # kNN width
    cache_key: bytes | None = None
    precision: str = "fp32"    # engine exact-phase precision ("fp32"|"bf16")
    # observability: process-unique trace id + the request's Span (stage
    # timestamps on THIS clock; see repro.obs.spans — kept untyped here so
    # the queue layer stays jax- and obs-free)
    trace_id: str = ""
    span: object | None = None


class BoundedRequestQueue:
    """Thread-safe bounded FIFO with group-aware batch pops.

    Producers ``put`` under an admission policy; the single consumer (the
    front's driver thread) calls ``next_group``, which takes the HEAD
    request's group key, waits until either that group can fill ``max_n``
    requests or the head's deadline passes, then pops every queued request
    of that group (FIFO order preserved within the group; other groups
    keep their positions — the head's age, not a straggler group's, drives
    the deadline, so no group can starve another)."""

    def __init__(self, maxsize: int):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self._q: list[Request] = []
        self._cond = threading.Condition()
        self._closed = False

    def __len__(self) -> int:
        with self._cond:
            return len(self._q)

    @property
    def closed(self) -> bool:
        return self._closed

    def put(self, req: Request, *, policy: str = "block",
            timeout: float | None = None) -> None:
        """Admit a request.  ``policy="block"`` waits for space (up to
        ``timeout`` seconds, None = forever); ``"shed"`` raises
        :class:`ShedError` immediately when full.  Either policy raises
        ``ShedError`` once the queue is closed."""
        if policy not in ("block", "shed"):
            raise ValueError(f"policy must be block|shed, got {policy!r}")
        deadline = None if timeout is None else now() + timeout
        with self._cond:
            while True:
                if self._closed:
                    raise ShedError("serving front is closed")
                if len(self._q) < self.maxsize:
                    self._q.append(req)
                    self._cond.notify_all()
                    return
                if policy == "shed":
                    raise ShedError(
                        f"queue full ({self.maxsize}); request shed"
                    )
                rem = None if deadline is None else deadline - now()
                if rem is not None and rem <= 0:
                    raise ShedError(
                        f"queue full ({self.maxsize}); admission timed "
                        f"out after {timeout}s"
                    )
                self._cond.wait(rem if rem is not None else 0.1)

    def next_group(self, max_n: int, max_delay: float,
                   poll: float = 0.05) -> list[Request]:
        """Pop the next dispatchable micro-batch (see class docstring).
        Returns [] only when the queue is closed AND drained — the driver's
        exit condition.  A closed-but-nonempty queue drains without waiting
        out deadlines (shutdown flushes, it does not stall)."""
        with self._cond:
            while not self._q:
                if self._closed:
                    return []
                self._cond.wait(poll)
            head = self._q[0]
            deadline = head.t_submit + max_delay
            while not self._closed:
                n_match = sum(1 for r in self._q if r.group == head.group)
                rem = deadline - now()
                if n_match >= max_n or rem <= 0:
                    break
                self._cond.wait(min(rem, poll))
            out: list[Request] = []
            i = 0
            while i < len(self._q) and len(out) < max_n:
                if self._q[i].group == head.group:
                    out.append(self._q.pop(i))
                else:
                    i += 1
            self._cond.notify_all()  # space freed: wake blocked producers
            return out

    def close(self) -> None:
        """Stop admitting; wake everyone.  Producers blocked in ``put``
        raise ``ShedError``; the driver drains what is queued and exits."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

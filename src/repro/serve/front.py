"""Async serving front: deadline micro-batching + shape-bucketed dispatch
over the fused engines.

The fused paths (``bss_query_batched`` / ``bss_knn_batched`` / the forest
walkers — and the sharded engine, automatically, when the index was built
with a mesh) only earn their keep on BATCHES; a stream of single queries
each paying a full engine dispatch wastes them.  This front is the piece
that assembles those batches from live traffic:

* ``submit(query, kind="range"|"knn", ...)`` admits one request and
  returns a ``concurrent.futures.Future`` immediately (driver-threaded —
  no asyncio anywhere near the engine path);
* a single driver thread collects compatible requests into micro-batches
  under a deadline / max-batch policy: the batch dispatches when the
  OLDEST queued request has waited ``max_delay_s``, or earlier the moment
  the batch is full;
* every batch is padded up to a fixed ladder of shape buckets
  (``repro.core.backends.DEFAULT_BUCKETS``), so the jitted engines see at
  most ``len(buckets)`` distinct batch shapes per (kind, metric) — jit
  recompiles are bounded by the ladder, not by the traffic's batch-size
  distribution;
* results demux back to the per-request futures, each carrying its own
  engine accounting (``ServeResult``).

Exactness is inherited, not re-proven: the front never post-processes
engine output beyond row demuxing.  BSS range batches mix PER-REQUEST
thresholds through the engine's per-query radii; padding rows ride with
radius -1, which the planar bound (>= 0) can never meet — padded rows
survive no block, evaluate no distance and hit nothing (asserted by the
compile-guard tests).  kNN and forest-range batches group on their scalar
engine parameters (k / r0 / max_rounds; the walker's single t), and their
padding rows duplicate the batch's first query — per-query rows of those
engines are independent, so real rows are untouched and the duplicate's
cost is bounded by the bucket rounding (reported as ``padding_waste``).

Admission is a bounded queue with a load-shed policy (block until space,
or fail fast with ``ShedError``), plus an optional exact-hit LRU result
cache keyed on the request's quantized (float32) query bytes and its
dispatch parameters.  Input hygiene happens ONCE at admission: the query
is canonicalised to float32 there (the engines and the cache key both see
the same bytes) and non-finite queries — including float64 values that
overflow the float32 cast — are rejected with ``ValueError`` before they
can poison a micro-batch or become an unmatchable NaN cache entry.  The
cache key is a canonical fixed-order typed tuple (kind, engine, precision,
t, k, r0, max_rounds, dim) — never ``repr`` of whatever params happened to
be around, whose concatenation with raw query bytes is not injective.

``submit(..., precision="bf16")`` routes the request through the engines'
bf16 exact phase (bit-identical results, roughly half the corpus HBM
traffic; see ``bss_query_batched``).  Precision is part of the dispatch
group — fp32 and bf16 requests never share a micro-batch — and of the
cache key, and the re-check volume rides the telemetry (``bf16_rows``,
``recheck_points`` counters, per-request ``ServeResult.n_recheck``).

``stats()`` snapshots the whole pipeline: queue wait, batch sizes, padding
waste, engine time, shed/cache counters.  It is total: an empty telemetry
window (fresh front, no completions yet) yields zeros, never a raise.

Living corpus: a BSS front serves a MUTABLE corpus through the functional
maintenance ops (``repro.index.maintain``).  ``front.append(rows)`` /
``front.delete(ids)`` / ``front.compact()`` build a NEW index snapshot and
swap ``self.index`` between micro-batches — ``_dispatch`` captures the
index reference once per batch, so queries in flight finish on the old
mirror (no torn reads; the swap is a single reference assignment).  Every
mutation bumps the index ``generation``, which is a typed field of the
exact-hit cache key — entries from older generations simply stop matching
(invalidation by key, no flush) — and rides every ``ServeResult``.  The
mutation itself is folded into the metrics registry
(``index/generation`` / ``index/tombstone_frac`` gauges, per-op
``index/mutation_s`` latency; see ``repro.obs.fold.fold_mutation``).

Engine knobs ride one frozen :class:`~repro.core.backends.EngineOpts`
(``opts=``); the per-request ``precision`` is overlaid per dispatch via
``dataclasses.replace``.  The legacy ``backend=`` / ``interpret=`` /
``realisation=`` kwargs still work (deprecation warning under
``REPRO_STRICT_API=1``); the front's realisation DEFAULT stays "dense"
(bucket-ladder recompile contract) unless an explicit ``opts=`` or
``realisation=`` says otherwise.

Host-side by design (and recorded as such in the ROADMAP): the queue, the
driver thread, the cache and the demux all run in numpy/threading; only
the engine call inside ``_dispatch`` touches jax.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from collections import OrderedDict, deque
from concurrent.futures import Future

import numpy as np

from repro.core import flat_index
from repro.core.backends import (
    DEFAULT_BUCKETS,
    EngineOpts,
    bucket_for,
    resolve_engine_opts,
)
from repro.core.exclusion import HILBERT
from repro.forest import (
    EncodedForest,
    EncodedMonotone,
    forest_range_search,
    monotone_range_search,
)
from repro.forest import walk as forest_walk
from repro.index import maintain as index_maintain
from repro.obs.fold import (
    fold_engine_stats,
    fold_mutation,
    poll_compile,
    shard_imbalance as _shard_imbalance,
)
from repro.obs.registry import MetricsRegistry
from repro.obs.spans import Span
from repro.obs.trace import (
    TraceBuffer,
    complete_event,
    metadata_event,
    span_events,
    write_trace,
)
from repro.serve.queue import (
    BoundedRequestQueue,
    Request,
    ShedError,
    nearest_rank,
    now,
)

__all__ = ["ServingFront", "ServeResult", "ShedError"]


@dataclasses.dataclass
class ServeResult:
    """What a request's future resolves to: the engine result rows for this
    request plus its slice of the batch telemetry."""

    hits: list[int] | None = None        # range: original corpus indices
    indices: np.ndarray | None = None    # knn: (k,) original ids, -1 padded
    distances: np.ndarray | None = None  # knn: (k,) ascending
    n_dists: int = 0                     # this query's own distance charge
    n_recheck: int = 0                   # bf16 band points re-run in fp32
    queue_wait_s: float = 0.0            # admission -> dispatch
    engine_s: float = 0.0                # the batch's engine wall time
    batch_size: int = 0                  # real requests in the batch
    padded_to: int = 0                   # bucket the batch dispatched at
    cache_hit: bool = False
    generation: int = 0                  # index snapshot this was served on
    trace_id: str = ""                   # obs trace id (front.explain(...))
    spans: dict | None = None            # per-stage durations (obs spans)


def _copy_result(res: ServeResult) -> ServeResult:
    """Fresh hits list / result arrays: cache entries and client results
    must never alias (a client sorting its hit list in place must not
    corrupt what the next cache hit is served)."""
    return dataclasses.replace(
        res,
        hits=None if res.hits is None else list(res.hits),
        indices=None if res.indices is None else res.indices.copy(),
        distances=None if res.distances is None else res.distances.copy(),
    )


def _cache_key(
    kind: str,
    engine: str,
    precision: str,
    generation: int,
    t: float | None,
    k: int | None,
    r0: float | None,
    max_rounds: int | None,
    q: np.ndarray,
) -> bytes:
    """Canonical cache key: a FIXED-ORDER, explicitly-typed header tuple
    followed by the float32 query bytes.

    Properties the old ``repr(params) + q.tobytes()`` scheme lacked:

    * injective — the header is NUL-free ASCII and the key splits at the
      first NUL, so a (header, query) pair can never masquerade as a
      different one by shifting bytes across the boundary (query bytes are
      arbitrary and routinely contain printable ASCII);
    * typed — every field is coerced (float/int/None) before formatting,
      so ``t=1`` and ``t=1.0`` are one entry, not two;
    * total — every dispatch parameter of BOTH kinds appears in its fixed
      slot (None where the kind doesn't use it), so a stray parameter of
      the other kind can neither split nor merge entries.

    ``generation`` (v3) keys the entry to ONE index snapshot: a mutation
    bumps the live generation, so every pre-mutation entry stops matching
    — the cache needs no flush hook, stale results are unreachable by
    construction (generations are monotonic, an old value never returns).
    """
    head = (
        "v3", kind, engine, precision, int(generation),
        None if t is None else float(t),
        None if k is None else int(k),
        None if r0 is None else float(r0),
        None if max_rounds is None else int(max_rounds),
        int(q.shape[0]),
    )
    return repr(head).encode("ascii") + b"\x00" + q.tobytes()


class _LRU:
    """Exact-hit result cache: quantized query bytes + dispatch params ->
    finished ServeResult.  Plain OrderedDict LRU under the front's lock;
    entries are defensively copied on both sides (see ``_copy_result``)."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._d: OrderedDict[bytes, ServeResult] = OrderedDict()

    def get(self, key: bytes) -> ServeResult | None:
        res = self._d.get(key)
        if res is None:
            return None
        self._d.move_to_end(key)
        return _copy_result(res)

    def put(self, key: bytes, res: ServeResult) -> None:
        self._d[key] = _copy_result(res)
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)


class ServingFront:
    """Deadline-based micro-batching front over a built index.

    ``index`` is a :class:`~repro.core.flat_index.BSSIndex` (range + kNN;
    a mesh-built index serves through the sharded engine automatically) or
    an encoded forest (range only — kNN on trees is ROADMAP work, exactly
    as on :class:`~repro.serve.retrieval.RetrievalServer`).

    ``prep`` optionally maps raw query batches into the index's engine
    space (e.g. a cosine forest's unit-sphere normalisation); the BSS
    engines do their own prep, so BSS fronts leave it None and feed the
    engines exactly what a direct call would — bit-identity preserved.

    ``realisation`` (default "dense") pins the jnp backend's exact phase
    to the dense realisation: the adaptive sparse path pads its alive-cell
    count to a data-dependent power of two, and a fresh shape class means
    an unpredictable mid-stream recompile — exactly what the bucket ladder
    exists to prevent.  "adaptive" restores the engine default (better
    arithmetic at very low survivor density, unbounded shape classes).
    """

    def __init__(
        self,
        index,
        *,
        buckets: tuple = DEFAULT_BUCKETS,
        max_delay_s: float = 0.002,
        max_queue: int = 1024,
        admission: str = "block",
        cache_size: int = 0,
        opts: EngineOpts | None = None,
        backend: str | None = None,
        interpret: bool | None = None,
        realisation: str | None = None,
        mechanism: str = HILBERT,
        prep=None,
        start: bool = True,
        metrics: bool = True,
        profile_dir: str | None = None,
    ):
        if isinstance(index, flat_index.BSSIndex):
            self._engine = "bss"
        elif isinstance(index, (EncodedForest, EncodedMonotone)):
            self._engine = "forest"
        else:
            raise TypeError(
                f"index must be a BSSIndex or an encoded forest, got "
                f"{type(index).__name__}"
            )
        if not buckets or list(buckets) != sorted(set(int(b) for b in buckets)):
            raise ValueError(
                f"buckets must be a strictly ascending ladder, got {buckets!r}"
            )
        if admission not in ("block", "shed"):
            raise ValueError(
                f"admission must be block|shed, got {admission!r}"
            )
        eopts = resolve_engine_opts(
            opts, backend=backend, interpret=interpret,
            realisation=realisation,
        )
        if opts is None and realisation is None:
            # the front's realisation DEFAULT is "dense", not the engine's
            # "adaptive": the sparse path's data-dependent padding class
            # defeats the bucket-ladder recompile contract (see class doc)
            eopts = dataclasses.replace(eopts, realisation="dense")
        self.index = index
        self.buckets = tuple(int(b) for b in buckets)
        self.max_delay_s = float(max_delay_s)
        self.admission = admission
        self.opts = eopts
        # legacy attribute views (older callers/tests read these)
        self.backend = eopts.backend
        self.interpret = eopts.interpret
        self.realisation = eopts.realisation
        self.mechanism = mechanism
        self.prep = prep
        self._queue = BoundedRequestQueue(max_queue)
        self._cache = _LRU(cache_size) if cache_size > 0 else None
        self._lock = threading.Lock()  # telemetry + cache
        self._mutate_lock = threading.Lock()  # serialises index mutations
        # telemetry: scalar tallies + a bounded window for percentiles
        self._n = dict(
            submitted=0, completed=0, shed=0, cache_hits=0, errors=0,
            batches=0, rows=0, padded_rows=0, dispatches=0,
            bf16_rows=0, recheck_points=0,
        )
        self._per_bucket: dict[int, int] = {}
        self._waits: deque[float] = deque(maxlen=4096)
        self._engine_s_total = 0.0
        # observability: registry folding + explain ring are gated on
        # `metrics`; trace ids and span timestamps always ride the requests
        # (they are part of ServeResult).  `profile_dir` opts into a
        # jax.profiler.trace around each engine dispatch.
        self.metrics_enabled = bool(metrics)
        self.profile_dir = profile_dir
        self._metrics = MetricsRegistry()
        self._trace = TraceBuffer()
        self._explain: deque[dict] = deque(maxlen=256)
        self._compile_last: dict[str, int] = {}
        if self._engine == "bss":
            self._compile_watch = {
                "range/lb": flat_index._lower_bounds_jit,
                "range/dense": flat_index._dense_hit_mask_jit,
                "range/fused": flat_index._query_batched_jit,
                "range/bf16": flat_index._query_batched_bf16_jit,
                "knn/lb": flat_index._knn_lb_jit,
                "knn/round": flat_index._knn_round_jit,
                "knn/round_bf16": flat_index._knn_round_bf16_jit,
            }
        elif isinstance(index, EncodedMonotone):
            self._compile_watch = {
                "forest/monotone_walk": forest_walk._monotone_walk_jit,
            }
        else:
            self._compile_watch = {
                "forest/walk": forest_walk._forest_walk_jit,
            }
        if self.metrics_enabled:
            # the bucket-ladder recompile contract, visible at runtime:
            # compile/recompiles growth should stay within this ladder
            self._metrics.gauge("compile/ladder_buckets").set(
                len(self.buckets)
            )
            if self._engine == "bss":
                # the living-corpus gauges exist from birth (a fresh front
                # reports its snapshot, not an absent series)
                self._metrics.gauge("index/generation").set(
                    int(index.generation)
                )
                self._metrics.gauge("index/tombstone_frac").set(
                    float(index.tombstone_frac)
                )
        self._thread: threading.Thread | None = None
        if start:
            self.start()

    # ------------------------------------------------------------ lifecycle

    def start(self) -> None:
        if self._thread is not None:
            return
        self._thread = threading.Thread(
            target=self._drive, name="serving-front-driver", daemon=True
        )
        self._thread.start()

    def close(self) -> None:
        """Stop admitting, drain the queue (every pending future resolves),
        and join the driver.  Idempotent."""
        self._queue.close()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "ServingFront":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- admission

    def submit(
        self,
        query: np.ndarray,
        kind: str = "range",
        *,
        t: float | None = None,
        k: int | None = None,
        r0: float | None = None,
        max_rounds: int = 8,
        timeout: float | None = None,
        precision: str = "fp32",
    ) -> Future:
        """Admit one query; returns a Future resolving to ``ServeResult``.

        ``kind="range"`` needs ``t`` (a metric distance; per-request — BSS
        batches mix thresholds); ``kind="knn"`` needs ``k`` (requests
        sharing (k, r0, max_rounds) batch together).  ``precision`` selects
        the engine exact phase ("fp32" | "bf16" — same results either way;
        part of the dispatch group, so precisions never share a batch).
        Admission follows the front's policy: "block" waits for queue space
        (up to ``timeout``), "shed" fails fast — either way a rejected
        request raises :class:`ShedError` out of ``submit`` itself, never a
        half-admitted future.

        The query is canonicalised to float32 HERE, once — engines, padding
        rows and the cache key all see the same bytes — and must be finite
        after that cast: NaN/Inf inputs (or float64 values overflowing
        float32) raise ``ValueError`` at admission instead of riding into a
        shared micro-batch."""
        # out-of-range float64 inputs overflow to Inf here ON PURPOSE — the
        # finiteness check below turns them into a clean admission error,
        # so the cast itself must not warn
        with np.errstate(over="ignore"):
            q = np.asarray(query, np.float32)
        if q.ndim != 1:
            raise ValueError(
                f"submit takes ONE query vector (the front does the "
                f"batching), got shape {q.shape}"
            )
        if not np.all(np.isfinite(q)):
            raise ValueError(
                "query must be finite after the float32 cast (no NaN/Inf; "
                "float64 values beyond float32 range overflow to Inf)"
            )
        # canonicalise -0.0 -> +0.0: distances cannot tell them apart, so
        # the cache key must not either
        q = q + np.float32(0.0)
        if precision not in ("fp32", "bf16"):
            raise ValueError(f"precision must be fp32|bf16, got {precision!r}")
        if kind == "range":
            if t is None:
                raise ValueError("range requests need t=")
            t = float(t)
            if t < 0:
                raise ValueError(
                    f"t must be >= 0 (negative radii are the engine's "
                    f"padding sentinel), got {t}"
                )
            group = (
                ("range", t, precision)
                if self._engine == "forest"
                else ("range", precision)
            )
        elif kind == "knn":
            if self._engine == "forest":
                from repro.serve.retrieval import FOREST_KNN_ERROR

                raise NotImplementedError(FOREST_KNN_ERROR)
            if k is None or int(k) <= 0:
                raise ValueError(f"knn requests need a positive k, got {k}")
            k = int(k)
            group = ("knn", k, None if r0 is None else float(r0),
                     int(max_rounds), precision)
        else:
            raise ValueError(f"kind must be range|knn, got {kind!r}")

        fut: Future = Future()
        span = Span()
        span.mark("admit")
        key = None
        if self._cache is not None:
            # the kind's FULL dispatch signature in fixed typed slots (None
            # where the kind doesn't use a slot): the BSS range group key
            # omits t (mixed-threshold batching), so t joins the key here;
            # a stray parameter of the OTHER kind can neither split nor
            # merge logically identical requests
            # generation is read HERE, at admission: a hit must reflect the
            # index the caller can observe right now.  If a mutation lands
            # between admission and dispatch, the computed result is stored
            # under this (now unreachable) key — generations are monotonic,
            # so a mislabelled entry can never be served, only evicted.
            key = _cache_key(
                kind, self._engine, precision,
                int(getattr(self.index, "generation", 0)),
                t if kind == "range" else None,
                k if kind == "knn" else None,
                (None if r0 is None else float(r0)) if kind == "knn" else None,
                int(max_rounds) if kind == "knn" else None,
                q,
            )
            with self._lock:
                hit = self._cache.get(key)
            if hit is not None:
                with self._lock:
                    self._n["submitted"] += 1
                    self._n["cache_hits"] += 1
                    self._n["completed"] += 1
                if self.metrics_enabled:
                    self._metrics.counter("serve/cache_hits").inc()
                fut.set_result(dataclasses.replace(
                    hit, cache_hit=True, trace_id=span.trace_id,
                    spans=span.durations(),
                ))
                return fut
        req = Request(
            query=q, kind=kind, group=group, future=fut, t_submit=now(),
            t=t, k=k, cache_key=key, precision=precision,
            trace_id=span.trace_id, span=span,
        )
        try:
            self._queue.put(req, policy=self.admission, timeout=timeout)
        except ShedError:
            with self._lock:
                self._n["submitted"] += 1
                self._n["shed"] += 1
            raise
        with self._lock:
            self._n["submitted"] += 1
        return fut

    def submit_many(self, queries: np.ndarray, kind: str = "range",
                    **kw) -> list[Future]:
        """Convenience fan-in: one ``submit`` per row (shared params)."""
        return [self.submit(q, kind, **kw) for q in np.asarray(queries)]

    # -------------------------------------------------------------- driver

    def _drive(self) -> None:
        while True:
            group = self._queue.next_group(self.buckets[-1], self.max_delay_s)
            if not group:
                return  # closed and drained
            try:
                self._dispatch(group)
            except Exception as e:  # noqa: BLE001 — resolve, never wedge
                with self._lock:
                    self._n["errors"] += 1
                for r in group:
                    try:
                        # a client cancel can race the done() check; an
                        # InvalidStateError here must not kill the driver
                        if not r.future.done():
                            r.future.set_exception(e)
                    except Exception:  # noqa: BLE001
                        pass

    @staticmethod
    def _resolve(fut: Future, res: ServeResult) -> bool:
        """Set a result, tolerating client-side cancellation (a cancelled
        future must never poison the rest of its micro-batch)."""
        if fut.cancelled():
            return False
        try:
            fut.set_result(res)
            return True
        except Exception:  # noqa: BLE001 — cancel racing the set
            return False

    def _profiler(self):
        """Opt-in ``jax.profiler.trace`` context around one dispatch (a
        no-op unless the front was built with ``profile_dir=``).  Host-side
        only — it wraps the engine call, it never reaches into the jit."""
        if self.profile_dir is None:
            return contextlib.nullcontext()
        import jax

        return jax.profiler.trace(self.profile_dir)

    def _annotate(self, name: str):
        """Opt-in ``jax.profiler.TraceAnnotation`` around the engine call.

        The annotation name carries the dispatch's span timestamp on the
        serving clock, so the device-side profile and the host trace
        (``export_trace``) can be lined up on one timeline even though the
        profiler keeps its own epoch."""
        if self.profile_dir is None:
            return contextlib.nullcontext()
        import jax

        return jax.profiler.TraceAnnotation(name)

    def _dispatch(self, group: list[Request]) -> None:
        """One engine call for one compatible micro-batch: pad to the
        bucket, run the fused path, demux rows to futures."""
        # ONE index snapshot per batch, captured before any engine work: a
        # concurrent mutation swaps self.index between batches, and this
        # whole batch finishes on whichever snapshot it started with — no
        # torn reads, and every row's ServeResult.generation names it
        index = self.index
        generation = int(getattr(index, "generation", 0))
        # clients may have cancelled queued futures (the standard timeout
        # move); drop them before spending engine time
        group = [r for r in group if not r.future.cancelled()]
        if not group:
            return
        t_batch = now()
        for r in group:
            if r.span is not None:
                r.span.mark("batch", t_batch)
        n = len(group)
        bucket = bucket_for(n, self.buckets)
        pad = bucket - n
        qs = np.stack([r.query for r in group])
        if pad:
            # duplicate the first row: always a valid engine input (zeros
            # are not, for the probability-space metrics); BSS range pads
            # are additionally killed by their -1 radius below
            qs = np.concatenate([qs, np.repeat(qs[:1], pad, axis=0)])
        if self.prep is not None:
            qs = self.prep(qs)
        head = group[0]
        t_wait = now()
        for r in group:
            if r.span is not None:
                r.span.mark("dispatch", t_wait)
        # one EngineOpts per dispatch: the front's base knobs with this
        # group's precision overlaid (precisions never share a batch)
        eng_opts = dataclasses.replace(self.opts, precision=head.precision)
        ann = (
            f"serve/engine kind={head.kind} bucket={bucket} "
            f"gen={generation} t_dispatch={t_wait:.6f}"
        )
        with self._profiler(), self._annotate(ann):
            if head.kind == "range" and self._engine == "bss":
                t_vec = np.array(
                    [r.t for r in group] + [-1.0] * pad, np.float32
                )
                hits, stats = flat_index.bss_query_batched(
                    index, qs, t_vec, opts=eng_opts,
                )
            elif head.kind == "range":  # forest: scalar-t walker
                search = (
                    monotone_range_search
                    if isinstance(index, EncodedMonotone)
                    else forest_range_search
                )
                hits, stats = search(
                    index, qs, head.t, self.mechanism, opts=eng_opts,
                )
            else:  # knn
                _, k, r0, max_rounds, _ = head.group
                idx, dist, stats = flat_index.bss_knn_batched(
                    index, qs, k, r0=r0, max_rounds=max_rounds,
                    opts=eng_opts,
                )
        t_engine = now()
        engine_s = t_engine - t_wait
        for r in group:
            if r.span is not None:
                r.span.mark("engine", t_engine)
        per_q = np.asarray(stats["per_query_dists"])
        excluded = {
            m: np.asarray(v) for m, v in stats.get("excluded", {}).items()
        }
        recheck = None
        if head.precision == "bf16":
            recheck = np.asarray(
                stats.get("per_query_recheck", np.zeros(bucket, np.int64))
            )

        if self.metrics_enabled:
            reg = self._metrics
            # fold REAL rows only — padding rows are a bucket artefact,
            # not query traffic (same convention as the bf16 accounting)
            folded = dict(stats)
            folded["n_queries"] = n
            folded["per_query_dists"] = per_q[:n]
            folded["excluded"] = {m: v[:n] for m, v in excluded.items()}
            if recheck is not None:
                folded["per_query_recheck"] = recheck[:n]
            fold_engine_stats(reg, folded)
            reg.histogram("serve/batch_size", kind=head.kind).observe(n)
            reg.histogram("serve/engine_s", kind=head.kind).observe(engine_s)
            if pad:
                reg.counter("serve/padded_rows").inc(pad)
            with self._lock:
                poll_compile(reg, self._compile_watch, self._compile_last)

        with self._lock:
            self._n["batches"] += 1
            self._n["rows"] += bucket
            self._n["padded_rows"] += pad
            self._per_bucket[bucket] = self._per_bucket.get(bucket, 0) + 1
            self._engine_s_total += engine_s
            if recheck is not None:
                # re-check volume over REAL rows only — padding rows are a
                # bucket artefact, not precision cost
                self._n["bf16_rows"] += n
                self._n["recheck_points"] += int(recheck[:n].sum())
        trace_evs: list[dict] = []
        for i, r in enumerate(group):
            wait = t_wait - r.t_submit
            durs = None
            if r.span is not None:
                r.span.mark("demux")
                durs = r.span.durations()
                if self.metrics_enabled:
                    trace_evs.extend(span_events(
                        r.span, tid=int(r.trace_id[1:]),
                        args={"kind": r.kind, "generation": generation},
                    ))
            res = ServeResult(
                n_dists=int(per_q[i]),
                n_recheck=0 if recheck is None else int(recheck[i]),
                queue_wait_s=wait,
                engine_s=engine_s, batch_size=n, padded_to=bucket,
                generation=generation, trace_id=r.trace_id, spans=durs,
            )
            if r.kind == "range":
                res.hits = hits[i]
            else:
                res.indices = idx[i]
                res.distances = dist[i]
            if self.metrics_enabled:
                if durs:
                    for stage, v in durs.items():
                        self._metrics.histogram(
                            "serve/span_s", stage=stage
                        ).observe(v)
                # per-request "explain" record: this row's slice of the
                # batch accounting + attribution, dumpable via explain()
                rec = {
                    "trace_id": r.trace_id,
                    "kind": r.kind,
                    "precision": head.precision,
                    "engine": stats.get("engine", self._engine),
                    "backend": stats.get("backend", self.backend),
                    "generation": generation,
                    "batch_size": n,
                    "padded_to": bucket,
                    "n_dists": int(per_q[i]),
                    "n_recheck": 0 if recheck is None else int(recheck[i]),
                    "excluded": {m: int(v[i]) for m, v in excluded.items()},
                    "spans": durs,
                }
                if "shard_dists" in stats:
                    # the sharded engine's per-shard split of the batch's
                    # exact-phase work — batch-level, same for every row
                    sd = np.asarray(stats["shard_dists"], np.int64)
                    rec["shard_dists"] = sd.tolist()
                    rec["shard_blocks"] = np.asarray(
                        stats["shard_blocks"], np.int64
                    ).tolist()
                    rec["shard_imbalance"] = _shard_imbalance(sd)
                with self._lock:
                    self._explain.append(rec)
            if not self._resolve(r.future, res):
                continue
            with self._lock:
                self._n["completed"] += 1
                self._waits.append(wait)
                if self._cache is not None and r.cache_key is not None:
                    self._cache.put(r.cache_key, res)
        if self.metrics_enabled:
            # one clock for everything: the dispatch's engine-phase slices
            # land on the driver track (tid 0), each request's stage slices
            # on its own per-request track — all stamped by `now()`
            args = {
                "kind": head.kind, "batch_size": n, "padded_to": bucket,
                "generation": generation,
                "engine": str(stats.get("engine", self._engine)),
                "n_dists": int(per_q[:n].sum()),
            }
            trace_evs.extend([
                complete_event("dispatch/assemble", t_batch,
                               t_wait - t_batch, tid=0, cat="dispatch",
                               args=args),
                complete_event("dispatch/engine", t_wait, engine_s, tid=0,
                               cat="dispatch", args=args),
                complete_event("dispatch/demux", t_engine, now() - t_engine,
                               tid=0, cat="dispatch", args=args),
            ])
            self._trace.extend(trace_evs)

    # ------------------------------------------------------------ mutations

    def _mutate(self, fn):
        """Run one functional mutation and swap the live index.

        The mutation builds a NEW index (``repro.index.maintain`` never
        touches the old one), then the swap is a single reference
        assignment — atomic to the driver thread, so a micro-batch either
        dispatches wholly on the old snapshot or wholly on the new one.
        ``_mutate_lock`` only serialises concurrent MUTATORS (so two
        appends compose instead of one clobbering the other); it is never
        held by the query path.
        """
        if self._engine != "bss":
            raise NotImplementedError(
                "living-corpus mutations run on the BSS engine; the encoded "
                "forest is immutable — rebuild it (incremental tree "
                "maintenance is ROADMAP work)"
            )
        t0 = now()
        with self._mutate_lock:
            new_index, mstats = fn(self.index)
            self.index = new_index
        if mstats is not None and self.metrics_enabled:
            t1 = now()
            fold_mutation(self._metrics, mstats, seconds=t1 - t0)
            # mutations share the driver track (tid 0): index maintenance
            # shows up inline with the dispatches it interleaves with
            self._trace.add(complete_event(
                f"mutation/{mstats.op}", t0, t1 - t0, tid=0, cat="mutation",
                args={
                    "op": str(mstats.op),
                    "rows": int(mstats.rows),
                    "generation": int(mstats.generation),
                    "n_blocks": int(mstats.n_blocks),
                    "tombstone_frac": float(mstats.tombstone_frac),
                },
            ))
        return mstats

    def append(self, rows):
        """Add ``rows`` (raw metric space, same dim) to the served corpus:
        fresh blocks against the existing pivot tables, generation bumped,
        cache entries of the old generation orphaned by key.  Returns the
        :class:`~repro.index.maintain.MutationStats`; queries admitted
        after this call see the new rows."""
        return self._mutate(lambda idx: index_maintain.append(idx, rows))

    def delete(self, ids):
        """Tombstone live corpus ids: they stop matching range/kNN from
        the next micro-batch on (in-flight batches finish on the old
        snapshot).  Returns the mutation's ``MutationStats``."""
        return self._mutate(lambda idx: index_maintain.delete(idx, ids))

    def compact(self, *, refresh_pivots: bool = True):
        """Re-permute the live rows into dense blocks (drops tombstones;
        ``refresh_pivots=True`` also rebuilds the pivot tables from the
        surviving corpus — bit-identical to a fresh ``build_bss`` over the
        live rows).  Returns the mutation's ``MutationStats``."""
        return self._mutate(
            lambda idx: index_maintain.compact(
                idx, refresh_pivots=refresh_pivots
            )
        )

    def maybe_compact(self, *, max_tombstone_frac: float = 0.25,
                      max_block_growth: float = 2.0,
                      refresh_pivots: bool | None = None):
        """Compact only when degraded (tombstone fraction / block growth
        thresholds — see :func:`repro.index.maintain.maybe_compact`).
        With metrics on, the front feeds its own OBSERVED
        ``engine/block_exclusion_rate`` gauge into the pivot-refresh
        decision: measured exclusion decay is what triggers a pivot
        refresh, exactly as the maintenance doc prescribes.  Returns the
        ``MutationStats`` when a compaction ran, else None."""
        rate = None
        if self.metrics_enabled and refresh_pivots is None:
            vals = [
                s.value for s in self._metrics.series()
                if s.kind == "gauge"
                and s.name == "engine/block_exclusion_rate"
            ]
            if vals:
                rate = min(vals)
        return self._mutate(
            lambda idx: index_maintain.maybe_compact(
                idx, max_tombstone_frac=max_tombstone_frac,
                max_block_growth=max_block_growth,
                block_exclusion_rate=rate, refresh_pivots=refresh_pivots,
            )
        )

    # ------------------------------------------------------------ telemetry

    def metrics(self) -> MetricsRegistry:
        """The front's metrics registry (always constructed; populated only
        while ``metrics=True``).  ``front.metrics().render()`` is the
        one-screen dashboard; ``.snapshot()`` / ``.to_prometheus()`` export
        it."""
        return self._metrics

    def explain(self, trace_id: str | None = None) -> dict | None:
        """The per-request explain record for ``trace_id`` (most recent
        request when None): span durations, batch shape, this row's
        distance charge, per-mechanism exclusion attribution and — on the
        sharded engine — the batch's per-shard work split.

        Records live in a bounded ring of the last 256 dispatched
        requests.  Asking for a specific ``trace_id`` that is not in the
        ring raises ``KeyError`` naming the capacity — the id either aged
        out, was served from the exact-hit cache (cache hits never
        dispatch), or the front runs with metrics off.  ``explain()``
        with no id returns the most recent record, or None when the ring
        is empty."""
        with self._lock:
            recs = list(self._explain)
        if trace_id is None:
            return recs[-1] if recs else None
        for rec in reversed(recs):
            if rec["trace_id"] == trace_id:
                return rec
        raise KeyError(
            f"no explain record for trace id {trace_id!r}: the ring keeps "
            f"the last {self._explain.maxlen} dispatched requests, and "
            f"cache hits / metrics-off requests never enter it"
        )

    def export_trace(self, path, *, extra: dict | None = None):
        """Write everything the trace buffer holds (request stage slices,
        per-dispatch engine phases, mutation slices — one monotonic clock)
        as Chrome trace-event JSON to ``path``; returns the path.  Load it
        in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``."""
        meta = [
            metadata_event("process_name", "repro-serving"),
            metadata_event("thread_name", "driver", tid=0),
        ]
        other = {
            "engine": self._engine,
            "backend": self.backend,
            "clock": "repro.serve.queue.now (monotonic, seconds*1e6)",
        }
        if extra:
            other.update(extra)
        return write_trace(path, meta + self._trace.events(), extra=other)

    def stats(self) -> dict:
        """Snapshot of the pipeline telemetry (host-side counters only —
        never blocks on the engine).  Total on an empty window: a fresh
        front with zero completions reports zeros everywhere, it never
        raises (regression-tested — percentiles, means and ratios all
        guard their denominators)."""
        with self._lock:
            waits = list(self._waits)
            n = dict(self._n)
            per_bucket = dict(self._per_bucket)
            engine_s = self._engine_s_total

        def pct(p: float) -> float:
            # nearest_rank is 0.0 on an empty window by contract; the guard
            # here keeps stats() total even if that contract ever changes
            return nearest_rank(waits, p) if waits else 0.0

        rows = n["rows"]
        return {
            **n,
            "queue_depth": len(self._queue),
            "per_bucket_batches": per_bucket,
            "batch_size_mean": (
                (rows - n["padded_rows"]) / n["batches"] if n["batches"] else 0.0
            ),
            "padding_waste": n["padded_rows"] / rows if rows else 0.0,
            "queue_wait_s": {
                "mean": sum(waits) / len(waits) if waits else 0.0,
                "p50": pct(0.50), "p95": pct(0.95), "max": pct(1.0),
            },
            "engine_s_total": engine_s,
            "engine_s_per_batch": engine_s / n["batches"] if n["batches"] else 0.0,
        }

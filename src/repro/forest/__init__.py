"""Device forest: array-encoded, jitted batched walks for every hyperplane
partition tree in the repo (paper §4 12-variant family + §5 monotone/LRT
family).

``encode`` flattens a built host tree into structure-of-arrays level tables;
``walk`` runs the batched frontier-per-level range search on accelerator,
returning result sets AND per-query distance counts identical to the numpy
walks in ``core/tree.py`` / ``core/lrt.py``.
"""

from repro.forest.encode import (
    EncodedForest,
    EncodedMonotone,
    encode_monotone,
    encode_tree,
)
from repro.forest.walk import forest_range_search, monotone_range_search

__all__ = [
    "EncodedForest",
    "EncodedMonotone",
    "encode_tree",
    "encode_monotone",
    "forest_range_search",
    "monotone_range_search",
]

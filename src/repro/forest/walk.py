"""Jitted frontier-per-level batched range search over encoded forests.

The host walks in ``core/tree.py`` / ``core/lrt.py`` pop one (node, active
query subset) at a time.  This walker processes a whole LEVEL at once: the
frontier is a dense (query x node-at-level) survival matrix, each level is

    one metric-dispatched distance evaluation for every active
    (query, frontier node) pair            -> reference/pivot hits
    masked exclusion predicates            -> per-child survival
    one gather                             -> the next level's frontier

and surviving leaf buckets accumulate into a (query x leaf) candidate
matrix checked by one masked exact phase at the end.  Every shape is static
per tree, so the whole query path is ONE jitted call — no per-node host
callbacks anywhere.

Backends mirror the BSS engine: ``pallas`` routes the level distance
evaluations and the leaf exact phase through the masked Pallas kernel family
(``masked_pairwise_kernel_call`` — dead (query-tile x block) cells are
skipped on the hardware), ``jnp`` computes the same dense shapes through
XLA; ``auto`` picks per ``jax.default_backend()``.  Exclusion geometry is
the SAME numpy/jnp-generic predicates of ``core/exclusion.py`` that the
host walks consume — the forest walker is their third consumer, not a
fourth copy.

Distance accounting is analytic and exact: a query is charged ``k`` at
every (query, node) frontier cell it keeps alive and ``len(bucket)`` per
surviving leaf — precisely what ``DistanceCounter`` tallies in the host
walk.  The *hardware* may evaluate more (a survived tile computes all its
cells; that is the point of the dense engine), but the paper's figure of
merit counts the walk's own decisions, identically to the oracle.  Result
sets and per-query counts therefore match the host walks bit-for-bit
whenever float32 and float64 agree on every predicate — the same contract
``bss_query_batched`` has with its oracle.

``precision="bf16"`` streams the bfloat16 leaf mirror through the exact
phase instead (halving its HBM traffic — leaf buckets dominate the walk's
bytes) with every threshold comparison widened by the measured margin of
``repro.core.precision``; points in the boundary band are re-checked
against the fp32 leaf table through the same masked kernels, so hit sets
are bit-identical to the fp32 walk.  The walk's exclusion predicates and
their reference tables stay fp32 — pruning decisions, and with them the
analytic per-query counts, never depend on the precision choice.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exclusion, projection
from repro.core.distances import get_metric
from repro.core.flat_index import _bf16_stats
from repro.core.exclusion import HILBERT, HYPERBOLIC
from repro.core.backends import (
    EngineOpts,
    resolve_backend,
    resolve_engine_opts,
    tile_survival,
)
from repro.forest.encode import (
    EncodedForest,
    EncodedMonotone,
    ForestDev,
    LeafDev,
    MonotoneDev,
)
from repro.kernels.pairwise_dist import (
    KERNEL_METRICS,
    masked_pairwise_kernel_call,
)
from repro.kernels.tiles import TILE_BLOCK, TILE_BQ
from repro.obs import schema as obs_schema

__all__ = ["forest_range_search", "monotone_range_search"]


# ---------------------------------------------------------------------------
# shared masked distance plumbing
# ---------------------------------------------------------------------------


def _owner_alive(alive: jnp.ndarray, owner_of_row: jnp.ndarray) -> jnp.ndarray:
    """(Q, n_owners) survival -> (Q, rows) per-row survival through an
    owner-of-row map (-1 rows, i.e. padding, are never alive)."""
    n_owners = alive.shape[1]
    safe = jnp.clip(owner_of_row, 0, max(n_owners - 1, 0))
    return jnp.where(owner_of_row[None, :] >= 0, alive[:, safe], False)


def _masked_dists(
    metric_name: str,
    queries: jnp.ndarray,
    rows_data: jnp.ndarray,
    row_alive: jnp.ndarray,
    *,
    backend: str,
    interpret: bool | None,
) -> jnp.ndarray:
    """(Q, rows) metric distances; on the pallas backend dead
    (query-tile x block) cells are skipped by the masked kernel (and come
    back +inf), on jnp the dense pass runs through XLA.  Callers must mask
    out rows they did not ask for — values there are garbage-or-inf."""
    if backend == "pallas" and metric_name in KERNEL_METRICS:
        block_alive = row_alive.reshape(
            row_alive.shape[0], -1, TILE_BLOCK
        ).any(axis=2)
        tile_mask = tile_survival(block_alive, TILE_BQ)
        return masked_pairwise_kernel_call(
            metric_name, queries, rows_data, tile_mask,
            bm=TILE_BQ, bn=TILE_BLOCK, interpret=interpret,
        )
    return get_metric(metric_name).pairwise(queries, rows_data)


def _leaf_exact(
    metric_name: str,
    queries: jnp.ndarray,
    leaves: LeafDev,
    leaf_alive: jnp.ndarray,
    t: jnp.ndarray,
    leaf16: jnp.ndarray | None,
    eps: jnp.ndarray,
    *,
    backend: str,
    interpret: bool | None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Final exact-check phase: (hit bitmask, per-query re-checked points,
    re-checked tiles).  With ``leaf16`` the distances come from the bf16
    leaf mirror and only the band ``t - eps < d16 <= t + eps`` is re-run
    against the fp32 table.  The re-check reuses the same masked-kernel
    machinery over the same fp32 rows, and a computed tile's values do not
    depend on the mask — so band cells see the exact fp32 values the fp32
    walk computes, and the hit bitmask is bit-identical to it."""
    nq = queries.shape[0]
    if leaf_alive.shape[1] == 0:
        return (
            jnp.zeros((nq, leaves.leaf_data.shape[0]), bool),
            jnp.zeros((nq,), jnp.int32),
            jnp.int32(0),
        )
    row_alive = _owner_alive(leaf_alive, leaves.leaf_of_row)
    ok = leaves.leaf_valid[None, :] & row_alive
    if leaf16 is None:
        d = _masked_dists(
            metric_name, queries, leaves.leaf_data, row_alive,
            backend=backend, interpret=interpret,
        )
        return (d <= t) & ok, jnp.zeros((nq,), jnp.int32), jnp.int32(0)
    d16 = _masked_dists(
        metric_name, queries, leaf16, row_alive,
        backend=backend, interpret=interpret,
    )
    sure = (d16 <= t - eps) & ok  # final by the margin guarantee
    band = (d16 <= t + eps) & ok & ~sure
    d32 = _masked_dists(
        metric_name, queries, leaves.leaf_data, band,
        backend=backend, interpret=interpret,
    )
    hit = sure | (band & (d32 <= t))
    band_blocks = band.reshape(nq, -1, TILE_BLOCK).any(axis=2)
    rtiles = jnp.sum(tile_survival(band_blocks, TILE_BQ))
    return hit, jnp.sum(band, axis=1, dtype=jnp.int32), rtiles


def _count_alive(alive: jnp.ndarray, weight: jnp.ndarray) -> jnp.ndarray:
    """Per-query distance-evaluation charge: sum of ``weight`` over the
    query's alive cells (int32 — the host counter's integers exactly)."""
    return jnp.sum(
        jnp.where(alive, weight[None, :].astype(jnp.int32), 0), axis=1
    )


def _n_root_leaves(dev) -> int:
    """Leaf buckets hanging directly off the root (always alive for every
    query).  Encode assigns leaf ids root-attached first, then level by
    level — so they are exactly the ids no per-level edge table claims."""
    return dev.leaves.leaf_len.shape[0] - sum(
        lv.leaf_parent_pos.shape[0] for lv in dev.levels
    )


# ---------------------------------------------------------------------------
# n-ary partition-tree walker (all 12 variants)
# ---------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=("metric_name", "mechanism", "backend", "interpret"),
)
def _forest_walk_jit(
    metric_name: str,
    queries: jnp.ndarray,
    t: jnp.ndarray,
    dev: ForestDev,
    leaf16: jnp.ndarray | None,
    eps: jnp.ndarray,
    *,
    mechanism: str,
    backend: str,
    interpret: bool | None,
):
    """Returns (per-level ref-hit bitmasks, leaf-row hit bitmask, counts,
    per-query band sizes, re-checked tiles, obs dict).  ``leaf16``/``eps``
    select the bf16 leaf exact phase (None => plain fp32; the None-vs-array
    pytree difference keys the retrace).

    The obs dict is the walker's device-side observability — per-query
    exclusion attribution (cover / hyperplane / centre, disjoint by the
    priority order below) and per-level frontier occupancy — computed as
    ordinary traced reductions over masks the walk already materialises
    and returned functionally, never via callbacks (see ``repro.obs``)."""
    nq = queries.shape[0]
    counts = jnp.zeros((nq,), jnp.int32)
    obs_cover = jnp.zeros((nq,), jnp.int32)
    obs_hyper = jnp.zeros((nq,), jnp.int32)
    obs_centre = jnp.zeros((nq,), jnp.int32)
    frontier = []
    ref_hits = []
    leaf_alive_parts = [jnp.ones((nq, _n_root_leaves(dev)), bool)]

    alive = None  # (nq, Na_l) frontier; level 0 is fully active
    dcent = None  # (nq, Na_l) inherited centre distance (NaN at the root)
    for li, lv in enumerate(dev.levels):
        na, kmax = lv.ref_valid.shape
        if li == 0:
            alive = jnp.ones((nq, na), bool)
            dcent = jnp.full((nq, na), jnp.nan, jnp.float32)
        counts = counts + _count_alive(alive, lv.n_refs)
        row_alive = _owner_alive(alive, lv.node_of_row)
        d = _masked_dists(
            metric_name, queries, lv.ref_data, row_alive,
            backend=backend, interpret=interpret,
        )
        dq = d[:, : na * kmax].reshape(nq, na, kmax)
        dq = jnp.where(lv.ref_valid[None], dq, jnp.inf)  # pad slots inert
        ref_hits.append(alive[:, :, None] & lv.ref_valid[None] & (dq <= t))
        e_cov = exclusion.cover_radius_exclusion_mask(
            dq, lv.cover_r[None], t, xp=jnp
        )
        e_hyp = exclusion.hyperplane_exclusion_mask(
            dq, lv.ref_dists, t, mechanism, xp=jnp
        )
        # SAT centre witness where the node has one AND the walk carried the
        # centre distance down (NaN dcent at the root compares False)
        e_cen = (
            exclusion.centre_witness_exclusion_mask(
                dq, dcent, lv.centre_dists, t, mechanism, xp=jnp
            )
            & lv.centre_on[None, :, None]
        )
        excl = e_cov | e_hyp | e_cen
        # per-query mechanism attribution over the LIVE valid child slots,
        # made disjoint by priority (cover -> hyperplane -> centre) so the
        # three counts sum to the total excluded slots; pure reductions
        # over masks the walk computes anyway
        live = alive[:, :, None] & lv.ref_valid[None]
        obs_cover += jnp.sum(live & e_cov, axis=(1, 2), dtype=jnp.int32)
        obs_hyper += jnp.sum(
            live & ~e_cov & e_hyp, axis=(1, 2), dtype=jnp.int32
        )
        obs_centre += jnp.sum(
            live & ~e_cov & ~e_hyp & e_cen, axis=(1, 2), dtype=jnp.int32
        )
        frontier.append(jnp.sum(alive, dtype=jnp.int32))
        keep = live & ~excl
        if lv.leaf_parent_pos.shape[0]:
            leaf_alive_parts.append(
                keep[:, lv.leaf_parent_pos, lv.leaf_parent_slot]
            )
        if li + 1 < len(dev.levels):
            nxt = dev.levels[li + 1]
            alive = keep[:, nxt.parent_pos, nxt.parent_slot]
            dcent = dq[:, nxt.parent_pos, nxt.parent_slot]

    leaf_alive = jnp.concatenate(leaf_alive_parts, axis=1)
    counts = counts + _count_alive(leaf_alive, dev.leaves.leaf_len)
    leaf_hit, band_counts, rtiles = _leaf_exact(
        metric_name, queries, dev.leaves, leaf_alive, t, leaf16, eps,
        backend=backend, interpret=interpret,
    )
    obs = {
        "excluded_cover": obs_cover,
        "excluded_hyperplane": obs_hyper,
        "excluded_centre": obs_centre,
        "frontier": (
            jnp.stack(frontier) if frontier else jnp.zeros((0,), jnp.int32)
        ),
    }
    return tuple(ref_hits), leaf_hit, counts, band_counts, rtiles, obs


def forest_range_search(
    forest: EncodedForest,
    queries: np.ndarray,
    t: float,
    mechanism: str = HILBERT,
    *,
    opts: EngineOpts | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
    precision: str | None = None,
) -> tuple[list[list[int]], dict]:
    """Batched exact range search over an encoded partition tree.

    Engine options travel as ``opts=EngineOpts(...)`` (legacy per-knob
    kwargs shimmed via ``resolve_engine_opts``); the walker tiles by the
    tree's own level/leaf shapes and has no adaptive split, so ``opts.bq``
    and ``opts.realisation`` are ignored — only backend / interpret /
    precision apply.

    Returns (per-query hit lists of original dataset indices, stats).
    ``stats["per_query_dists"]`` is the paper's figure of merit — identical
    to ``DistanceCounter.per_query`` of the host ``tree.range_search``
    whenever float32/float64 agree on every predicate.

    ``precision="bf16"`` runs the leaf exact phase against the bfloat16
    leaf mirror with fp32 boundary re-check: hit lists and counts are
    bit-identical to the fp32 walk, the re-check volume is reported under
    the bf16 stats keys (see ``bss_query_batched``)."""
    if mechanism not in (HILBERT, HYPERBOLIC):
        raise ValueError(mechanism)
    opts = resolve_engine_opts(
        opts, backend=backend, interpret=interpret, precision=precision,
    )
    interpret = opts.interpret
    precision = opts.precision
    backend = resolve_backend(opts.backend)
    queries = np.asarray(queries, np.float32)
    nq = queries.shape[0]
    if nq == 0:
        stats = _stats(
            forest, np.zeros(0, np.int64), backend, precision,
            engine="forest",
            excluded={m: np.zeros(0, np.int64)
                      for m in ("cover", mechanism, "centre")},
        )
        if precision == "bf16":
            _bf16_stats(stats, forest.bf16_eps(), 0, np.zeros(0, np.int64))
        return [], stats
    bf16 = precision == "bf16"
    eps = forest.bf16_eps() if bf16 else 0.0
    ref_hits, leaf_hit, counts, band_counts, rtiles, obs = _forest_walk_jit(
        forest.metric,
        jnp.asarray(queries),
        jnp.float32(t),
        forest.device,
        forest.leaf_bf16 if bf16 else None,
        jnp.float32(eps),
        mechanism=mechanism,
        backend=backend,
        interpret=interpret,
    )
    results: list[list[int]] = [[] for _ in range(nq)]
    for lv, hit in zip(forest.levels, ref_hits):
        q, n, s = np.nonzero(np.asarray(hit))
        ids = lv.ref_idx[n, s]
        for qi, rid in zip(q, ids):
            results[qi].append(int(rid))
    q, r = np.nonzero(np.asarray(leaf_hit))
    ids = forest.leaf.member_of_row[r]
    for qi, rid in zip(q, ids):
        results[qi].append(int(rid))
    stats = _stats(
        forest, np.asarray(counts).astype(np.int64), backend, precision,
        engine="forest",
        # the walker reports hyperplane exclusions mechanism-neutrally;
        # the label is whichever hyperplane rule this walk actually ran
        excluded={
            "cover": np.asarray(obs["excluded_cover"], np.int64),
            mechanism: np.asarray(obs["excluded_hyperplane"], np.int64),
            "centre": np.asarray(obs["excluded_centre"], np.int64),
        },
        frontier=obs["frontier"],
    )
    if bf16:
        _bf16_stats(stats, eps, int(rtiles), np.asarray(band_counts))
    return results, stats


def _stats(enc, per_query: np.ndarray, backend: str, precision: str, *,
           engine: str, excluded: dict | None = None,
           frontier=None) -> dict:
    stats = {
        "per_query_dists": per_query,
        "dists_per_query": float(per_query.mean()) if per_query.size else 0.0,
        "n_levels": len(enc.levels),
        "n_nodes": enc.n_nodes,
        "n_leaves": enc.leaf.n_leaves,
        "backend": backend,
        "precision": precision,
        # nodes alive across all queries, per level (device-side reduction)
        "frontier_occupancy": (
            np.zeros(len(enc.levels), np.int64) if frontier is None
            else np.asarray(frontier, np.int64)
        ),
    }
    return obs_schema.normalise_stats(
        stats, engine=engine, kind="range", backend=backend,
        n_queries=int(per_query.shape[0]), excluded=excluded,
    )


# ---------------------------------------------------------------------------
# monotone binary walker (closer / median_x / median_y / pca / lrt)
# ---------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=("metric_name", "mechanism", "backend", "interpret"),
)
def _monotone_walk_jit(
    metric_name: str,
    queries: jnp.ndarray,
    t: jnp.ndarray,
    dev: MonotoneDev,
    leaf16: jnp.ndarray | None,
    eps: jnp.ndarray,
    *,
    mechanism: str,
    backend: str,
    interpret: bool | None,
):
    """Returns (root hit, per-level p2-hit bitmasks, leaf-row hits, counts,
    per-query band sizes, re-checked tiles, obs dict — per-query hyperplane
    exclusions + per-level frontier occupancy, as functional outputs).

    One NEW distance per (query, visited node) — the inherited pivot's
    distance rides the frontier, exactly the Monotonous-Bisector-Tree
    invariant the host walk exploits."""
    nq = queries.shape[0]
    metric = get_metric(metric_name)
    d_root = metric.pairwise(queries, dev.root_p1_data)[:, 0]  # (nq,)
    counts = jnp.ones((nq,), jnp.int32)  # everyone pays the root distance
    obs_hyper = jnp.zeros((nq,), jnp.int32)
    frontier = []
    root_hit = d_root <= t
    p2_hits = []
    leaf_alive_parts = [jnp.ones((nq, _n_root_leaves(dev)), bool)]

    alive = None
    dinh = None  # (nq, Na_l) inherited-pivot distance
    for li, lv in enumerate(dev.levels):
        na = lv.delta.shape[0]
        if li == 0:
            alive = jnp.ones((nq, na), bool)
            dinh = jnp.broadcast_to(d_root[:, None], (nq, na))
        counts = counts + jnp.sum(alive, axis=1, dtype=jnp.int32)
        row_alive = _owner_alive(
            alive,
            jnp.where(
                lv.p2_valid, jnp.arange(lv.p2_valid.shape[0], dtype=jnp.int32),
                -1,
            ),
        )
        d = _masked_dists(
            metric_name, queries, lv.p2_data, row_alive,
            backend=backend, interpret=interpret,
        )
        d2 = d[:, :na]
        d1 = dinh
        p2_hits.append(alive & (d2 <= t))
        if mechanism == HYPERBOLIC:
            margin = exclusion.hyperbolic_margin(d1, d2, xp=jnp)
        else:
            x, y = projection.project(d1, d2, lv.delta[None, :], xp=jnp)
            margin = exclusion.planar_margin(
                x, y, lv.theta[None, :], lv.h[None, :],
                lv.nx[None, :], lv.ny[None, :], lv.split[None, :], xp=jnp,
            )
        keep_l = alive & (margin < t)    # cannot exclude left unless m >= t
        keep_r = alive & (margin > -t)
        # each alive node has two semispaces; count the ones the margin
        # test excluded (left when m >= t, right when m <= -t)
        obs_hyper += jnp.sum(
            jnp.where(alive & ~keep_l, 1, 0)
            + jnp.where(alive & ~keep_r, 1, 0),
            axis=1, dtype=jnp.int32,
        )
        frontier.append(jnp.sum(alive, dtype=jnp.int32))
        if lv.leaf_parent_pos.shape[0]:
            pos, right = lv.leaf_parent_pos, lv.leaf_parent_right
            leaf_alive_parts.append(
                jnp.where(right[None, :], keep_r[:, pos], keep_l[:, pos])
            )
        if li + 1 < len(dev.levels):
            nxt = dev.levels[li + 1]
            pos, right = nxt.parent_pos, nxt.parent_right
            alive = jnp.where(right[None, :], keep_r[:, pos], keep_l[:, pos])
            dinh = jnp.where(right[None, :], d2[:, pos], d1[:, pos])

    leaf_alive = jnp.concatenate(leaf_alive_parts, axis=1)
    counts = counts + _count_alive(leaf_alive, dev.leaves.leaf_len)
    leaf_hit, band_counts, rtiles = _leaf_exact(
        metric_name, queries, dev.leaves, leaf_alive, t, leaf16, eps,
        backend=backend, interpret=interpret,
    )
    obs = {
        "excluded_hyperplane": obs_hyper,
        "frontier": (
            jnp.stack(frontier) if frontier else jnp.zeros((0,), jnp.int32)
        ),
    }
    return root_hit, tuple(p2_hits), leaf_hit, counts, band_counts, rtiles, obs


def monotone_range_search(
    forest: EncodedMonotone,
    queries: np.ndarray,
    t: float,
    mechanism: str = HILBERT,
    *,
    opts: EngineOpts | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
    precision: str | None = None,
) -> tuple[list[list[int]], dict]:
    """Batched exact range search over an encoded monotone tree; counterpart
    of ``lrt.range_search_monotone`` with the same mechanism restriction
    (Hyperbolic is only sound for the 'closer' split).  ``opts`` /
    ``precision`` as in ``forest_range_search``."""
    if mechanism == HYPERBOLIC and forest.partition != "closer":
        raise ValueError(
            "hyperbolic exclusion is only sound for the 'closer' split"
        )
    if mechanism not in (HILBERT, HYPERBOLIC):
        raise ValueError(mechanism)
    opts = resolve_engine_opts(
        opts, backend=backend, interpret=interpret, precision=precision,
    )
    interpret = opts.interpret
    precision = opts.precision
    backend = resolve_backend(opts.backend)
    queries = np.asarray(queries, np.float32)
    nq = queries.shape[0]
    if nq == 0:
        stats = _stats(
            forest, np.zeros(0, np.int64), backend, precision,
            engine="monotone",
            excluded={mechanism: np.zeros(0, np.int64)},
        )
        if precision == "bf16":
            _bf16_stats(stats, forest.bf16_eps(), 0, np.zeros(0, np.int64))
        return [], stats
    bf16 = precision == "bf16"
    eps = forest.bf16_eps() if bf16 else 0.0
    (root_hit, p2_hits, leaf_hit, counts, band_counts, rtiles,
     obs) = _monotone_walk_jit(
        forest.metric,
        jnp.asarray(queries),
        jnp.float32(t),
        forest.device,
        forest.leaf_bf16 if bf16 else None,
        jnp.float32(eps),
        mechanism=mechanism,
        backend=backend,
        interpret=interpret,
    )
    results: list[list[int]] = [[] for _ in range(nq)]
    for qi in np.nonzero(np.asarray(root_hit))[0]:
        results[qi].append(forest.root_p1)
    for lv, hit in zip(forest.levels, p2_hits):
        q, n = np.nonzero(np.asarray(hit))
        ids = lv.p2_idx[n]
        for qi, rid in zip(q, ids):
            results[qi].append(int(rid))
    q, r = np.nonzero(np.asarray(leaf_hit))
    ids = forest.leaf.member_of_row[r]
    for qi, rid in zip(q, ids):
        results[qi].append(int(rid))
    stats = _stats(
        forest, np.asarray(counts).astype(np.int64), backend, precision,
        engine="monotone",
        excluded={
            mechanism: np.asarray(obs["excluded_hyperplane"], np.int64),
        },
        frontier=obs["frontier"],
    )
    if bf16:
        _bf16_stats(stats, eps, int(rtiles), np.asarray(band_counts))
    return results, stats

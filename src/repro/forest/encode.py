"""Flatten host-built partition trees into device-resident node tables.

The host trees (``core/tree.py``'s 12-variant n-ary family, ``core/lrt.py``'s
monotone/LRT family) are pointer-chasing python structures — hostile to
accelerators.  This module re-encodes a BUILT tree as structure-of-arrays
**level tables**: all nodes of one depth side by side, padded to the level's
max arity with validity masks, children expressed as gather indices into the
next level's table.  The encoding is lossless w.r.t. the query geometry:

  * per-node reference dataset indices + the build-time ref–ref distance
    matrices, centre distances and cover radii (everything the exclusion
    predicates in ``core/exclusion.py`` consume),
  * child pointers: each level-``l+1`` node knows its (parent position,
    parent slot) in level ``l`` — propagation is a pure gather, because a
    tree child has exactly one parent,
  * leaf buckets: one global member table padded to the max bucket size,
    each leaf knowing the (level, position, slot) edge it hangs from; the
    flattened leaf points double as a blocked corpus for the masked
    pairwise kernels (rows padded to the kernel block size).

The walk in ``forest/walk.py`` then runs level by level with static shapes —
the whole query path jits.  Host numpy tables stay on the dataclass (cheap
to pickle, feed the result assembly); the ``.device`` property mirrors them
into jnp arrays once per encoding, exactly like ``BSSIndex.device``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.lrt import MonotoneTree, _MNode
from repro.core.precision import bf16_margin as _bf16_margin
from repro.core.tree import PartitionTree, _Node
from repro.kernels.tiles import TILE_BLOCK

__all__ = [
    "EncodedForest",
    "EncodedMonotone",
    "encode_tree",
    "encode_monotone",
]


# ---------------------------------------------------------------------------
# device mirrors (pytrees of jnp arrays; all shape information is static)
# ---------------------------------------------------------------------------


class LevelDev(NamedTuple):
    """One depth of an n-ary partition tree, padded to the level's max arity."""

    ref_valid: jnp.ndarray     # (Na, kmax) bool — False at padded ref slots
    n_refs: jnp.ndarray        # (Na,) int32 true arity (the distance count)
    ref_dists: jnp.ndarray     # (Na, kmax, kmax) f32, 0 at padded slots
    centre_dists: jnp.ndarray  # (Na, kmax) f32, NaN where absent
    centre_on: jnp.ndarray     # (Na,) bool — centre witness usable at node
    cover_r: jnp.ndarray       # (Na, kmax) f32
    parent_pos: jnp.ndarray    # (Na,) int32 position in PREVIOUS level
    parent_slot: jnp.ndarray   # (Na,) int32 ref slot in the parent
    ref_data: jnp.ndarray      # (rows_pad, dim) f32 node-major gathered refs
    node_of_row: jnp.ndarray   # (rows_pad,) int32 owning node, -1 in the tail
    leaf_parent_pos: jnp.ndarray   # (n_leaves_l,) int32
    leaf_parent_slot: jnp.ndarray  # (n_leaves_l,) int32


class LeafDev(NamedTuple):
    """Global leaf-bucket table shared by both walkers (ids grouped
    root-attached first, then level by level — the walk relies on it)."""

    leaf_len: jnp.ndarray    # (n_leaves,) int32 true bucket size
    leaf_data: jnp.ndarray   # (rows_pad, dim) f32 leaf-major member vectors
    leaf_valid: jnp.ndarray  # (rows_pad,) bool — False at pad slots/tail
    leaf_of_row: jnp.ndarray  # (rows_pad,) int32 owning leaf, -1 in the tail


class ForestDev(NamedTuple):
    levels: tuple  # tuple[LevelDev, ...]
    leaves: LeafDev


class MLevelDev(NamedTuple):
    """One depth of a monotone binary tree (one fresh pivot per node)."""

    delta: jnp.ndarray        # (Na,) f32 d(p1, p2)
    theta: jnp.ndarray        # (Na,) f32
    h: jnp.ndarray            # (Na,) f32
    nx: jnp.ndarray           # (Na,) f32
    ny: jnp.ndarray           # (Na,) f32
    split: jnp.ndarray        # (Na,) f32
    parent_pos: jnp.ndarray   # (Na,) int32
    parent_right: jnp.ndarray  # (Na,) bool — True if right child of parent
    p2_data: jnp.ndarray      # (rows_pad, dim) f32 fresh-pivot vectors
    p2_valid: jnp.ndarray     # (rows_pad,) bool — False in the padded tail
    leaf_parent_pos: jnp.ndarray    # (n_leaves_l,) int32
    leaf_parent_right: jnp.ndarray  # (n_leaves_l,) bool


class MonotoneDev(NamedTuple):
    root_p1_data: jnp.ndarray  # (1, dim) f32
    levels: tuple  # tuple[MLevelDev, ...]
    leaves: LeafDev


# ---------------------------------------------------------------------------
# host-side tables
# ---------------------------------------------------------------------------


def _pad_rows(a: np.ndarray, mult: int) -> np.ndarray:
    rem = a.shape[0] % mult
    if rem == 0:
        return a
    return np.concatenate(
        [a, np.zeros((mult - rem,) + a.shape[1:], a.dtype)], axis=0
    )


def _leaf_pad_width(max_len: int) -> int:
    """Bucket slot width: next power of two (lane-friendly) up to the kernel
    block, then whole blocks — so a kernel block never straddles a partial
    leaf in a way the row->leaf map can't express (the map is per-row, so
    ANY width is correct; powers of two just keep the padding waste low)."""
    if max_len <= 0:
        return 1
    width = 1 << (max_len - 1).bit_length()
    if width > TILE_BLOCK:
        width = -(-max_len // TILE_BLOCK) * TILE_BLOCK
    return width


@dataclasses.dataclass
class _LeafTable:
    """Host leaf tables + the flat member map used for result assembly."""

    members: np.ndarray       # (n_leaves, leaf_pad) int64, -1 pad
    lens: np.ndarray          # (n_leaves,) int32
    member_of_row: np.ndarray  # (rows_pad,) int64 original id, -1 pad/tail
    data: np.ndarray          # (rows_pad, dim) f32
    valid: np.ndarray         # (rows_pad,) bool
    leaf_of_row: np.ndarray   # (rows_pad,) int32

    @property
    def n_leaves(self) -> int:
        return self.members.shape[0]


def _build_leaf_table(leaves: list[np.ndarray], data32: np.ndarray) -> _LeafTable:
    dim = data32.shape[1]
    if leaves:
        pad = _leaf_pad_width(max(len(lf) for lf in leaves))
        members = np.full((len(leaves), pad), -1, dtype=np.int64)
        for i, lf in enumerate(leaves):
            members[i, : len(lf)] = lf
    else:
        members = np.zeros((0, 1), dtype=np.int64)
    lens = (members >= 0).sum(axis=1).astype(np.int32)
    flat = members.reshape(-1)
    n_rows = flat.shape[0]
    rows_pad = max(-(-max(n_rows, 1) // TILE_BLOCK) * TILE_BLOCK, TILE_BLOCK)
    member_of_row = np.full(rows_pad, -1, dtype=np.int64)
    member_of_row[:n_rows] = flat
    leaf_of_row = np.full(rows_pad, -1, dtype=np.int32)
    if members.shape[0]:
        leaf_of_row[:n_rows] = np.repeat(
            np.arange(members.shape[0], dtype=np.int32), members.shape[1]
        )
    valid = member_of_row >= 0
    ldata = np.zeros((rows_pad, dim), np.float32)
    ldata[valid] = data32[member_of_row[valid]]
    return _LeafTable(members, lens, member_of_row, ldata, valid, leaf_of_row)


@dataclasses.dataclass
class _Level:
    ref_idx: np.ndarray       # (Na, kmax) int64, -1 pad
    ref_valid: np.ndarray
    n_refs: np.ndarray
    ref_dists: np.ndarray
    centre_dists: np.ndarray
    centre_on: np.ndarray
    cover_r: np.ndarray
    parent_pos: np.ndarray
    parent_slot: np.ndarray
    ref_data: np.ndarray
    node_of_row: np.ndarray
    leaf_parent_pos: np.ndarray
    leaf_parent_slot: np.ndarray


class _LeafBf16Mixin:
    """Lazy bf16 mirror of the leaf-bucket table + its comparison margin.

    Only the LEAF data gets a bf16 twin: the walk's exclusion predicates
    (reference/pivot distances, cover radii, hyperplane margins) stay fp32 so
    pruning decisions — and with them the analytic distance counts, the
    paper's figure of merit — are bit-identical across precisions.  The
    margin is measured over valid leaf rows only (padding must not inflate
    the re-check band)."""

    @property
    def leaf_bf16(self) -> jnp.ndarray:
        if self._leaf16 is None:
            self._leaf16 = jnp.asarray(self.leaf.data, jnp.bfloat16)
        return self._leaf16

    def bf16_eps(self) -> float:
        if self._bf16_eps is None:
            self._bf16_eps = _bf16_margin(
                self.metric, self.leaf.data, self.leaf.valid
            )
        return self._bf16_eps


@dataclasses.dataclass
class EncodedForest(_LeafBf16Mixin):
    """Array encoding of a ``PartitionTree`` (any of the 12 variants)."""

    variant: str
    metric: str
    n_points: int
    levels: list[_Level]
    leaf: _LeafTable
    _device: ForestDev | None = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _leaf16: jnp.ndarray | None = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _bf16_eps: float | None = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def n_nodes(self) -> int:
        return sum(lv.n_refs.shape[0] for lv in self.levels)

    @property
    def device(self) -> ForestDev:
        if self._device is None:
            self._device = ForestDev(
                levels=tuple(
                    LevelDev(
                        ref_valid=jnp.asarray(lv.ref_valid),
                        n_refs=jnp.asarray(lv.n_refs, jnp.int32),
                        ref_dists=jnp.asarray(lv.ref_dists, jnp.float32),
                        centre_dists=jnp.asarray(lv.centre_dists, jnp.float32),
                        centre_on=jnp.asarray(lv.centre_on),
                        cover_r=jnp.asarray(lv.cover_r, jnp.float32),
                        parent_pos=jnp.asarray(lv.parent_pos, jnp.int32),
                        parent_slot=jnp.asarray(lv.parent_slot, jnp.int32),
                        ref_data=jnp.asarray(lv.ref_data, jnp.float32),
                        node_of_row=jnp.asarray(lv.node_of_row, jnp.int32),
                        leaf_parent_pos=jnp.asarray(lv.leaf_parent_pos, jnp.int32),
                        leaf_parent_slot=jnp.asarray(
                            lv.leaf_parent_slot, jnp.int32
                        ),
                    )
                    for lv in self.levels
                ),
                leaves=_leaf_dev(self.leaf),
            )
        return self._device


def _leaf_dev(leaf: _LeafTable) -> LeafDev:
    return LeafDev(
        leaf_len=jnp.asarray(leaf.lens, jnp.int32),
        leaf_data=jnp.asarray(leaf.data, jnp.float32),
        leaf_valid=jnp.asarray(leaf.valid),
        leaf_of_row=jnp.asarray(leaf.leaf_of_row, jnp.int32),
    )


def encode_tree(tree: PartitionTree) -> EncodedForest:
    """Breadth-first flatten of a built ``PartitionTree``.

    Leaf ids are assigned root-attached first, then level by level in node
    order — the walk concatenates its per-level leaf-survival gathers in
    exactly that order."""
    data32 = np.asarray(tree.data, np.float32)

    # the degenerate k==0 wrapper (tiny-dataset root) evaluates no distances
    # in the host walk — hoist its children so the table has no 0-ref rows
    leaves: list[np.ndarray] = []
    frontier: list[tuple[_Node, int, int]] = []  # (node, parent_pos, slot)

    def _intake(child, parent_pos: int, slot: int, nxt, leaf_edges):
        if child is None:
            return
        if isinstance(child, np.ndarray):
            if len(child):
                leaves.append(np.asarray(child, np.int64))
                leaf_edges.append((parent_pos, slot))
            return
        nxt.append((child, parent_pos, slot))

    root = tree.root
    if len(root.ref_idx) == 0:
        root_edges: list = []  # root-attached leaves are always alive
        for ch in root.children:
            _intake(ch, -1, -1, frontier, root_edges)
    else:
        frontier = [(root, -1, -1)]

    levels: list[_Level] = []
    while frontier:
        nodes = [n for n, _, _ in frontier]
        na = len(nodes)
        kmax = max(len(n.ref_idx) for n in nodes)
        ref_idx = np.full((na, kmax), -1, dtype=np.int64)
        ref_dists = np.zeros((na, kmax, kmax), np.float32)
        centre_dists = np.full((na, kmax), np.nan, np.float32)
        cover_r = np.zeros((na, kmax), np.float32)
        parent_pos = np.array([p for _, p, _ in frontier], dtype=np.int32)
        parent_slot = np.array([s for _, _, s in frontier], dtype=np.int32)
        centre_on = np.zeros(na, bool)
        nxt: list[tuple[_Node, int, int]] = []
        leaf_edges: list[tuple[int, int]] = []
        for i, node in enumerate(nodes):
            k = len(node.ref_idx)
            ref_idx[i, :k] = node.ref_idx
            ref_dists[i, :k, :k] = node.ref_dists
            centre_dists[i, :k] = node.centre_dists
            cover_r[i, :k] = node.cover_r
            centre_on[i] = not np.any(np.isnan(node.centre_dists))
            for j, child in enumerate(node.children):
                _intake(child, i, j, nxt, leaf_edges)
        ref_valid = ref_idx >= 0
        rows = np.where(ref_valid, ref_idx, 0).reshape(-1)
        ref_data = _pad_rows(
            np.where(
                ref_valid.reshape(-1, 1), data32[rows], np.float32(0.0)
            ).astype(np.float32),
            TILE_BLOCK,
        )
        node_of_row = np.full(ref_data.shape[0], -1, dtype=np.int32)
        node_of_row[: na * kmax] = np.repeat(
            np.arange(na, dtype=np.int32), kmax
        )
        levels.append(
            _Level(
                ref_idx=ref_idx,
                ref_valid=ref_valid,
                n_refs=ref_valid.sum(axis=1).astype(np.int32),
                ref_dists=ref_dists,
                centre_dists=centre_dists,
                centre_on=centre_on,
                cover_r=cover_r,
                parent_pos=parent_pos,
                parent_slot=parent_slot,
                ref_data=ref_data,
                node_of_row=node_of_row,
                leaf_parent_pos=np.array(
                    [p for p, _ in leaf_edges], dtype=np.int32
                ),
                leaf_parent_slot=np.array(
                    [s for _, s in leaf_edges], dtype=np.int32
                ),
            )
        )
        frontier = nxt

    return EncodedForest(
        variant=tree.variant,
        metric=tree.metric,
        n_points=int(tree.data.shape[0]),
        levels=levels,
        leaf=_build_leaf_table(leaves, data32),
    )


# ---------------------------------------------------------------------------
# monotone family
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _MLevel:
    p2_idx: np.ndarray        # (Na,) int64
    delta: np.ndarray
    theta: np.ndarray
    h: np.ndarray
    nx: np.ndarray
    ny: np.ndarray
    split: np.ndarray
    parent_pos: np.ndarray
    parent_right: np.ndarray
    p2_data: np.ndarray
    p2_valid: np.ndarray
    leaf_parent_pos: np.ndarray
    leaf_parent_right: np.ndarray


@dataclasses.dataclass
class EncodedMonotone(_LeafBf16Mixin):
    """Array encoding of a ``MonotoneTree`` (closer/median/pca/lrt splits)."""

    partition: str
    select: str
    metric: str
    n_points: int
    root_p1: int
    root_p1_data: np.ndarray  # (1, dim) f32
    levels: list[_MLevel]
    leaf: _LeafTable
    _device: MonotoneDev | None = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _leaf16: jnp.ndarray | None = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _bf16_eps: float | None = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def n_nodes(self) -> int:
        return sum(lv.delta.shape[0] for lv in self.levels)

    @property
    def device(self) -> MonotoneDev:
        if self._device is None:
            self._device = MonotoneDev(
                root_p1_data=jnp.asarray(self.root_p1_data, jnp.float32),
                levels=tuple(
                    MLevelDev(
                        delta=jnp.asarray(lv.delta, jnp.float32),
                        theta=jnp.asarray(lv.theta, jnp.float32),
                        h=jnp.asarray(lv.h, jnp.float32),
                        nx=jnp.asarray(lv.nx, jnp.float32),
                        ny=jnp.asarray(lv.ny, jnp.float32),
                        split=jnp.asarray(lv.split, jnp.float32),
                        parent_pos=jnp.asarray(lv.parent_pos, jnp.int32),
                        parent_right=jnp.asarray(lv.parent_right),
                        p2_data=jnp.asarray(lv.p2_data, jnp.float32),
                        p2_valid=jnp.asarray(lv.p2_valid),
                        leaf_parent_pos=jnp.asarray(lv.leaf_parent_pos, jnp.int32),
                        leaf_parent_right=jnp.asarray(lv.leaf_parent_right),
                    )
                    for lv in self.levels
                ),
                leaves=_leaf_dev(self.leaf),
            )
        return self._device


def encode_monotone(tree: MonotoneTree) -> EncodedMonotone:
    """Breadth-first flatten of a built ``MonotoneTree``.  Each node carries
    one fresh pivot; the inherited pivot's identity is implicit in the
    parent edge (left inherits the parent's p1-side distance, right the
    fresh p2's), which is all the walk needs."""
    data32 = np.asarray(tree.data, np.float32)

    leaves: list[np.ndarray] = []
    frontier: list[tuple[_MNode, int, bool]] = []

    def _intake(child, parent_pos: int, right: bool, nxt, leaf_edges):
        if child is None:
            return
        if isinstance(child, np.ndarray):
            if len(child):
                leaves.append(np.asarray(child, np.int64))
                leaf_edges.append((parent_pos, right))
            return
        nxt.append((child, parent_pos, right))

    root_edges: list = []
    _intake(tree.root, -1, False, frontier, root_edges)

    levels: list[_MLevel] = []
    while frontier:
        nodes = [n for n, _, _ in frontier]
        na = len(nodes)
        p2_idx = np.array([n.p2 for n in nodes], dtype=np.int64)
        p2_data = _pad_rows(data32[p2_idx], TILE_BLOCK)
        p2_valid = np.zeros(p2_data.shape[0], bool)
        p2_valid[:na] = True
        nxt: list[tuple[_MNode, int, bool]] = []
        leaf_edges: list[tuple[int, bool]] = []
        for i, node in enumerate(nodes):
            _intake(node.left, i, False, nxt, leaf_edges)
            _intake(node.right, i, True, nxt, leaf_edges)
        levels.append(
            _MLevel(
                p2_idx=p2_idx,
                delta=np.array([n.delta for n in nodes], np.float32),
                theta=np.array([n.theta for n in nodes], np.float32),
                h=np.array([n.h for n in nodes], np.float32),
                nx=np.array([n.nx for n in nodes], np.float32),
                ny=np.array([n.ny for n in nodes], np.float32),
                split=np.array([n.split for n in nodes], np.float32),
                parent_pos=np.array([p for _, p, _ in frontier], np.int32),
                parent_right=np.array([r for _, _, r in frontier], bool),
                p2_data=p2_data,
                p2_valid=p2_valid,
                leaf_parent_pos=np.array(
                    [p for p, _ in leaf_edges], dtype=np.int32
                ),
                leaf_parent_right=np.array(
                    [r for _, r in leaf_edges], dtype=bool
                ),
            )
        )
        frontier = nxt

    return EncodedMonotone(
        partition=tree.partition,
        select=tree.select,
        metric=tree.metric,
        n_points=int(tree.data.shape[0]),
        root_p1=int(tree.root_p1),
        root_p1_data=data32[tree.root_p1][None, :],
        levels=levels,
        leaf=_build_leaf_table(leaves, data32),
    )

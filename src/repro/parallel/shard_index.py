"""Sharded BSS: the fused engine partitioned over a device mesh.

``ShardedBSSIndex`` takes a built :class:`~repro.core.flat_index.BSSIndex`
and partitions its corpus BLOCKS across the mesh's data axes — block-granular,
so every shard is itself a valid blocked kernel corpus (block-aligned data
rows, per-block boxes, per-slot validity).  Queries and the reference-point
tables (pivots, pairs, deltas) are replicated; the shard-local arrays are
born with a ``NamedSharding`` once, so repeated queries pay no per-call
re-layout.

Query paths (both run the EXISTING fused single-device code shard-local
under ``shard_map`` — same kernels, same bound math, same masking):

* ``sharded_query_batched`` — range search.  Each shard runs the fused pass
  (planar lower bound -> tile survival -> masked exact phase) over its own
  blocks and emits a per-shard hit BITMASK; the out-spec concatenates the
  bitmasks back in corpus order, so host-side hit extraction is identical to
  the single-device engine's.

* ``sharded_knn_batched`` — radius-deepening kNN.  Every round each shard
  computes its masked exact distances and a per-shard ``lax.top_k``; the
  cross-device merge all-gathers the (distance, global position) candidate
  lists and runs a second ``top_k`` over the concatenation.  The shrinking
  radius stays GLOBAL (driven by the merged kth-nearest-so-far), so each
  shard's planar exclusion remains sound — a shard never prunes a block some
  other shard's candidates couldn't already beat.  The host driver mirrors
  ``bss_knn_batched``'s radius schedule step for step, which is what makes
  the per-query distance accounting identical to the single-device engine.

Shard telemetry: every query path also returns per-shard exact-phase
distance counts and surviving-block counts (``stats["shard_dists"]`` /
``stats["shard_blocks"]``, one slot per shard) as FUNCTIONAL jit outputs
— tiny shard-local reductions concatenated by the out-spec, never a
callback, so the jaxpr audit's no-callback and bit-identity contracts
hold unchanged.  The serving layer folds them into ``shard/imbalance``
gauges (``repro.obs.fold.shard_imbalance``).

Block-count padding: when ``n_blocks`` is not a multiple of the shard
count, empty padding blocks are appended — zero data rows marked invalid,
and boxes carrying the same (min=+big, max=-big) empty-box sentinel a
fully-padded block would get in ``build_bss``, so their planar bound is
+inf and they are excluded at any finite radius.  All stats are reported
over the REAL blocks only; results and per-query distance counts are
asserted (tests, benchmarks) to be identical to the single-device fused
engine and the numpy oracle.

Tie-breaking note: ``lax.top_k`` prefers the earliest index on equal
values.  The merge concatenates candidate lists shard-major (shard 0's
candidates first, each list in ascending-position order for ties), so on
equal distances the merged ``top_k`` selects the smallest global position —
exactly the single-device ``top_k``'s choice.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.backends import (
    EngineOpts,
    resolve_backend,
    resolve_engine_opts,
    tile_survival,
)
from repro.core.flat_index import (
    _DEFAULT_BQ,
    _batched_stats,
    _bf16_stats,
    _engine_metric,
    _engine_queries,
    _finish_stats,
    _fused_lower_bounds,
    _knn_empty_stats,
    _masked_exact_dists,
    _per_query_t,
    _valid_per_block,
    BSSDeviceArrays,
    BSSIndex,
)
from repro.parallel.sharding import dp_axes, named

__all__ = [
    "ShardedBSSIndex",
    "shard_bss",
    "sharded_query_batched",
    "sharded_knn_batched",
]

# the empty-box sentinel build_bss uses for all-invalid slots: point_to_box
# against (min=+big, max=-big) overflows to +inf in float32, so a padding
# block is excluded by ANY finite radius
_BIG = np.float32(3.4e38)


def _shard_work(alive, valid_l, block):
    """Shard-local work summary, as functional jit outputs (shape (1,)
    each, concatenated to (n_shards,) by the out-spec — never a callback).

    ``sdist`` is this shard's exact-phase distance-evaluation count: for
    every (query, surviving block) pair, the block's valid-row count —
    the shard-local slice of the very sum ``_batched_stats`` charges per
    query, so the shard vector totals to the batch's exact-phase work.
    ``sblk`` counts surviving NON-EMPTY blocks (a padding or fully
    tombstoned block admitted by an infinite radius does no work and is
    not this gauge's business).  int32 like the engines' other traced
    tallies (x64 stays off).
    """
    valid_pb = jnp.sum(
        valid_l.reshape(-1, block), axis=1, dtype=jnp.int32
    )
    sdist = jnp.sum(alive * valid_pb[None, :], dtype=jnp.int32)
    sblk = jnp.sum(alive & (valid_pb > 0)[None, :], dtype=jnp.int32)
    return sdist.reshape(1), sblk.reshape(1)


class ShardedBSSIndex:
    """Block-granular partition of a built ``BSSIndex`` over a device mesh.

    The mesh must expose at least one data axis (``("data",)`` — or
    ``("pod", "data")``, over whose product the blocks are partitioned).
    Construction pads the block count up to a multiple of the shard count,
    places the padded arrays with their ``NamedSharding`` once, and caches
    the jitted ``shard_map`` callables per (path, metric, backend) key.
    """

    def __init__(self, index: BSSIndex, mesh: Mesh):
        axes = dp_axes(mesh)
        if not axes:
            raise ValueError(
                f"mesh {mesh.axis_names} has no data axis; the sharded BSS "
                f"engine partitions corpus blocks over ('data',) (optionally "
                f"('pod', 'data'))"
            )
        self.index = index
        self.mesh = mesh
        self.axes = axes
        self.n_shards = int(np.prod([mesh.shape[a] for a in axes]))

        block = index.block
        n_blocks = index.n_blocks
        self.n_blocks_pad = -(-n_blocks // self.n_shards) * self.n_shards
        pad_b = self.n_blocks_pad - n_blocks
        dim = index.data.shape[1]
        m = index.pairs.shape[0]
        data = index.data
        valid = index.valid
        boxes = index.boxes
        perm = index.perm
        if pad_b:
            data = np.concatenate(
                [data, np.zeros((pad_b * block, dim), np.float32)]
            )
            valid = np.concatenate([valid, np.zeros(pad_b * block, bool)])
            empty = np.tile(
                np.array([_BIG, -_BIG, _BIG, -_BIG], np.float32),
                (pad_b, m, 1),
            )
            boxes = np.concatenate([boxes, empty])
            perm = np.concatenate(
                [perm, np.full(pad_b * block, -1, np.int64)]
            )
        # original ids for the padded layout (padding slots are -1, exactly
        # like the partial-block padding of the single-device layout)
        self.perm = perm
        self.n_pad = self.n_blocks_pad * block
        self.rows_per_shard = self.n_pad // self.n_shards

        put = lambda a, spec: jax.device_put(a, named(mesh, spec))  # noqa: E731
        self.dev = BSSDeviceArrays(
            data=put(jnp.asarray(data, jnp.float32), P(axes, None)),
            pivots=put(jnp.asarray(index.pivots, jnp.float32), P()),
            pairs=put(jnp.asarray(index.pairs, jnp.int32), P()),
            deltas=put(jnp.asarray(index.deltas, jnp.float32), P()),
            boxes=put(jnp.asarray(boxes, jnp.float32), P(axes, None, None)),
            valid=put(jnp.asarray(valid), P(axes)),
        )
        self._host_data = data  # padded layout, for the lazy bf16 mirror
        self._data16: jnp.ndarray | None = None
        self._fns: dict = {}

    @property
    def dev_data16(self) -> jnp.ndarray:
        """Sharded bfloat16 corpus mirror (lazy — only bf16 queries pay for
        it), partitioned exactly like ``dev.data``.  The comparison margin
        comes from ``self.index.bf16_margin()``: it is measured over the
        VALID rows only, which the block-count padding never adds to."""
        if self._data16 is None:
            self._data16 = jax.device_put(
                jnp.asarray(self._host_data, jnp.bfloat16),
                named(self.mesh, P(self.axes, None)),
            )
        return self._data16

    # ------------------------------------------------------------- callables

    def _range_fn(self, metric: str, backend: str, bq: int, interpret):
        key = ("range", metric, backend, bq, interpret)
        if key not in self._fns:
            axes, block = self.axes, self.index.block

            def local(q, t, data_l, valid_l, boxes_l, pivots, pairs, deltas):
                # t is the replicated (Q,) per-query radius vector — each
                # query's survival and hit test use only its own radius,
                # exactly like the single-device engine
                lb = _fused_lower_bounds(
                    metric, q, pivots, pairs, deltas, boxes_l,
                    backend=backend, bq=bq, interpret=interpret,
                )
                alive = lb <= t[:, None]
                tmask = tile_survival(alive, bq)
                dist = _masked_exact_dists(
                    metric, q, data_l, valid_l, tmask,
                    backend=backend, block=block, bq=bq, interpret=interpret,
                )
                sdist, sblk = _shard_work(alive, valid_l, block)
                return dist <= t[:, None], alive, tmask, sdist, sblk

            self._fns[key] = jax.jit(shard_map(
                local, self.mesh,
                in_specs=(
                    P(), P(), P(axes, None), P(axes), P(axes, None, None),
                    P(), P(), P(),
                ),
                out_specs=(
                    P(None, axes), P(None, axes), P(None, axes),
                    P(axes), P(axes),
                ),
                check_rep=False,
            ))
        return self._fns[key]

    def _range_bf16_fn(self, metric: str, backend: str, bq: int, interpret):
        key = ("range16", metric, backend, bq, interpret)
        if key not in self._fns:
            axes, block = self.axes, self.index.block

            def local(q, t, eps, data_l, valid_l, boxes_l, pivots, pairs,
                      deltas, data16_l):
                # shard-local bf16 scan + fp32 boundary re-check: the same
                # sure/band/re-check scheme as _query_batched_bf16_jit, run
                # over this shard's blocks.  The re-check is purely local
                # (a band cell's fp32 value lives on the shard that owns
                # the block), so no extra collectives appear — the bitmask
                # concatenation below IS the global fp32 merge.
                lb = _fused_lower_bounds(
                    metric, q, pivots, pairs, deltas, boxes_l,
                    backend=backend, bq=bq, interpret=interpret,
                )
                alive = lb <= t[:, None]
                tmask = tile_survival(alive, bq)
                d16 = _masked_exact_dists(
                    metric, q, data16_l, valid_l, tmask,
                    backend=backend, block=block, bq=bq, interpret=interpret,
                )
                t_col = t[:, None]
                sure = d16 <= t_col - eps
                band = (d16 <= t_col + eps) & ~sure
                band_blocks = band.reshape(q.shape[0], -1, block).any(axis=2)
                rmask = tile_survival(band_blocks, bq) & tmask
                d32 = _masked_exact_dists(
                    metric, q, data_l, valid_l, rmask,
                    backend=backend, block=block, bq=bq, interpret=interpret,
                )
                hit = sure | (band & (d32 <= t_col))
                sdist, sblk = _shard_work(alive, valid_l, block)
                return (
                    hit, alive, tmask, rmask,
                    jnp.sum(band, axis=1, dtype=jnp.int32)[:, None],
                    sdist, sblk,
                )

            self._fns[key] = jax.jit(shard_map(
                local, self.mesh,
                in_specs=(
                    P(), P(), P(), P(axes, None), P(axes),
                    P(axes, None, None), P(), P(), P(), P(axes, None),
                ),
                out_specs=(
                    P(None, axes), P(None, axes), P(None, axes),
                    P(None, axes), P(None, axes), P(axes), P(axes),
                ),
                check_rep=False,
            ))
        return self._fns[key]

    def _lb_fn(self, metric: str, backend: str, bq: int, interpret):
        key = ("lb", metric, backend, bq, interpret)
        if key not in self._fns:
            axes = self.axes

            def local(q, boxes_l, pivots, pairs, deltas):
                return _fused_lower_bounds(
                    metric, q, pivots, pairs, deltas, boxes_l,
                    backend=backend, bq=bq, interpret=interpret,
                )

            self._fns[key] = jax.jit(shard_map(
                local, self.mesh,
                in_specs=(P(), P(axes, None, None), P(), P(), P()),
                out_specs=P(None, axes),
                check_rep=False,
            ))
        return self._fns[key]

    def _knn_round_fn(self, metric: str, backend: str, bq: int, interpret,
                      k: int):
        key = ("knn", metric, backend, bq, interpret, k)
        if key not in self._fns:
            axes, block = self.axes, self.index.block
            mesh, rows = self.mesh, self.rows_per_shard
            # a shard can contribute at most min(k, rows) candidates of the
            # true global top-k, so the per-shard top_k (and the all-gather)
            # can stay that narrow even when k exceeds a shard's row count
            k_local = min(k, rows)

            def local(q, radii, lb_l, data_l, valid_l):
                alive = lb_l <= radii[:, None]
                tmask = tile_survival(alive, bq)
                dist = _masked_exact_dists(
                    metric, q, data_l, valid_l, tmask,
                    backend=backend, block=block, bq=bq, interpret=interpret,
                )  # (Q, rows), +inf where pruned/padding
                neg, li = jax.lax.top_k(-dist, k_local)
                # local -> global positions in the padded permuted layout
                off = jnp.int32(0)
                for a in axes:
                    off = off * mesh.shape[a] + jax.lax.axis_index(a)
                gi = li + off * rows
                allneg = jax.lax.all_gather(neg, axes)  # (S, Q, k_local)
                allidx = jax.lax.all_gather(gi, axes)
                nq = q.shape[0]
                allneg = jnp.moveaxis(allneg, 0, 1).reshape(nq, -1)
                allidx = jnp.moveaxis(allidx, 0, 1).reshape(nq, -1)
                neg2, sel = jax.lax.top_k(allneg, k)  # global k smallest
                cand_idx = jnp.take_along_axis(allidx, sel, axis=1)
                sdist, sblk = _shard_work(alive, valid_l, block)
                return cand_idx, -neg2, alive, tmask, sdist, sblk

            self._fns[key] = jax.jit(shard_map(
                local, self.mesh,
                in_specs=(
                    P(), P(), P(None, axes), P(axes, None), P(axes),
                ),
                out_specs=(
                    P(None, None), P(None, None), P(None, axes),
                    P(None, axes), P(axes), P(axes),
                ),
                check_rep=False,
            ))
        return self._fns[key]

    def _knn_round_bf16_fn(self, metric: str, backend: str, bq: int,
                           interpret, k: int):
        key = ("knn16", metric, backend, bq, interpret, k)
        if key not in self._fns:
            axes, block = self.axes, self.index.block
            mesh, rows = self.mesh, self.rows_per_shard
            k_local = min(k, rows)

            def local(q, radii, eps, lb_l, data_l, valid_l, data16_l):
                # bf16 scan, then a FIRST all-gather to form the GLOBAL bf16
                # kth — the re-check band must be global or a shard whose
                # own kth16 is loose would re-check too little.  Band cells
                # are re-checked locally against the fp32 shard, and the
                # per-shard top_k over the band-restricted fp32 values feeds
                # the STANDARD merge: every cell at or under the global fp32
                # kth is in the band (margin containment), cells outside are
                # strictly beyond it, and shard-major concatenation keeps
                # the fp32 engine's tie order — outputs are bit-identical
                # to _knn_round_fn.
                alive = lb_l <= radii[:, None]
                tmask = tile_survival(alive, bq)
                d16 = _masked_exact_dists(
                    metric, q, data16_l, valid_l, tmask,
                    backend=backend, block=block, bq=bq, interpret=interpret,
                )
                nq = q.shape[0]
                neg16, _ = jax.lax.top_k(-d16, k_local)
                allneg16 = jax.lax.all_gather(neg16, axes)  # (S, Q, k_local)
                allneg16 = jnp.moveaxis(allneg16, 0, 1).reshape(nq, -1)
                merged16, _ = jax.lax.top_k(allneg16, k)
                kth16 = -merged16[:, -1]
                bthr = jnp.where(
                    jnp.isfinite(kth16), kth16 + 2.0 * eps, jnp.inf
                )
                band = (d16 <= bthr[:, None]) & jnp.isfinite(d16)
                band_blocks = band.reshape(nq, -1, block).any(axis=2)
                rmask = tile_survival(band_blocks, bq) & tmask
                d32 = _masked_exact_dists(
                    metric, q, data_l, valid_l, rmask,
                    backend=backend, block=block, bq=bq, interpret=interpret,
                )
                dist = jnp.where(band, d32, jnp.inf)
                neg, li = jax.lax.top_k(-dist, k_local)
                off = jnp.int32(0)
                for a in axes:
                    off = off * mesh.shape[a] + jax.lax.axis_index(a)
                gi = li + off * rows
                allneg = jax.lax.all_gather(neg, axes)
                allidx = jax.lax.all_gather(gi, axes)
                allneg = jnp.moveaxis(allneg, 0, 1).reshape(nq, -1)
                allidx = jnp.moveaxis(allidx, 0, 1).reshape(nq, -1)
                neg2, sel = jax.lax.top_k(allneg, k)
                cand_idx = jnp.take_along_axis(allidx, sel, axis=1)
                sdist, sblk = _shard_work(alive, valid_l, block)
                return (
                    cand_idx, -neg2, alive, tmask, rmask,
                    jnp.sum(band, axis=1, dtype=jnp.int32)[:, None],
                    sdist, sblk,
                )

            self._fns[key] = jax.jit(shard_map(
                local, self.mesh,
                in_specs=(
                    P(), P(), P(), P(None, axes), P(axes, None), P(axes),
                    P(axes, None),
                ),
                out_specs=(
                    P(None, None), P(None, None), P(None, axes),
                    P(None, axes), P(None, axes), P(None, axes),
                    P(axes), P(axes),
                ),
                check_rep=False,
            ))
        return self._fns[key]


    # --------------------------------------------------- living-corpus hooks

    def _clone_for(self, new_index: BSSIndex) -> "ShardedBSSIndex":
        """Shallow clone bound to a mutated index.  The jitted shard_map
        cache (``_fns``) is SHARED — its closures capture only mesh
        geometry and static knobs, and take the device arrays as call
        arguments, so a mutation that preserves array shapes keeps serving
        with zero recompiles."""
        clone = object.__new__(ShardedBSSIndex)
        clone.__dict__.update(self.__dict__)
        clone.index = new_index
        return clone

    def _spliced(self, arr: jnp.ndarray, tail: np.ndarray, start: int,
                 dtype) -> jnp.ndarray:
        """Device-side in-place-style update of a sharded array (a fresh
        buffer, but updated ON the devices) with the output pinned to the
        array's own NamedSharding — the splice never gathers the corpus to
        one device and never re-lands the unchanged blocks."""
        fn = jax.jit(
            lambda a, b: jax.lax.dynamic_update_slice_in_dim(
                a, b, start, 0
            ),
            out_shardings=arr.sharding,
        )
        return fn(arr, jnp.asarray(tail, dtype))

    def extended(
        self,
        new_index: BSSIndex,
        tail_data: np.ndarray,
        tail_valid: np.ndarray,
        tail_boxes: np.ndarray,
        tail_perm: np.ndarray,
    ) -> "ShardedBSSIndex | None":
        """Consume empty PADDING blocks for an append's fresh blocks.

        The padded layout parks its empty blocks at the absolute end of
        the block axis — on the least-loaded shard(s), since the partition
        is contiguous-chunk.  When the new blocks fit in that free space
        they are spliced into those slots device-side: no array changes
        shape, the contiguous partition (and ``rows_per_shard``) is
        untouched, and the shared ``_fns`` cache keeps every compiled
        engine hot.  Returns ``None`` when they do NOT fit — the caller
        falls back to a lazy full re-layout (the block count must grow,
        which moves every chunk boundary)."""
        nb_new = tail_boxes.shape[0]
        free = self.n_blocks_pad - self.index.n_blocks
        if nb_new > free:
            return None
        block = self.index.block
        start_blk = self.index.n_blocks
        start_row = start_blk * block
        nrows = nb_new * block
        clone = self._clone_for(new_index)
        perm = self.perm.copy()
        perm[start_row : start_row + nrows] = tail_perm
        clone.perm = perm
        host = self._host_data.copy()
        host[start_row : start_row + nrows] = tail_data
        clone._host_data = host
        clone.dev = BSSDeviceArrays(
            data=self._spliced(
                self.dev.data, tail_data, start_row, jnp.float32
            ),
            pivots=self.dev.pivots,
            pairs=self.dev.pairs,
            deltas=self.dev.deltas,
            boxes=self._spliced(
                self.dev.boxes, tail_boxes, start_blk, jnp.float32
            ),
            valid=self._spliced(
                self.dev.valid, tail_valid, start_row, jnp.bool_
            ),
        )
        if self._data16 is not None:
            clone._data16 = self._spliced(
                self._data16, tail_data, start_row, jnp.bfloat16
            )
        return clone

    def with_tombstones(
        self, new_index: BSSIndex, positions: np.ndarray
    ) -> "ShardedBSSIndex":
        """Clear the valid bits of deleted slot positions on-device (data,
        boxes and the bf16 mirror are untouched — the engines mask by
        validity) and mirror the -1 perm sentinel on the host side."""
        clone = self._clone_for(new_index)
        perm = self.perm.copy()
        perm[positions] = -1
        clone.perm = perm
        fn = jax.jit(
            lambda v, p: v.at[p].set(False),
            out_shardings=self.dev.valid.sharding,
        )
        clone.dev = self.dev._replace(
            valid=fn(self.dev.valid, jnp.asarray(positions))
        )
        return clone


def shard_bss(index: BSSIndex, mesh: Mesh) -> ShardedBSSIndex:
    """Partition a built index's blocks over the mesh (see class docs)."""
    return ShardedBSSIndex(index, mesh)


# ---------------------------------------------------------------------------
# Range search
# ---------------------------------------------------------------------------


def sharded_query_batched(
    sidx: ShardedBSSIndex,
    queries: np.ndarray,
    t,
    *,
    opts: EngineOpts | None = None,
    bq: int | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
    precision: str | None = None,
) -> tuple[list[list[int]], dict]:
    """Exact range search, one fused shard-local pass per device.

    Engine options travel as ``opts=EngineOpts(...)`` (legacy per-knob
    kwargs shimmed via ``resolve_engine_opts``); ``opts.realisation`` is
    ignored — the shard-local body is always the dense masked pass, whose
    fixed shapes are what keep per-device compiles bounded.

    ``t`` is a scalar threshold or a (Q,) vector of per-query radii (the
    serving front's mixed-threshold micro-batches; a negative radius —
    padding — excludes its row everywhere), replicated across shards.

    Hit lists (indices AND per-query order) and the distance accounting are
    identical to ``bss_query_batched`` / the numpy oracle: the per-shard
    planar bounds are the same elementwise math over a block slice, and the
    concatenated hit bitmask is extracted exactly like the single-device
    dense path's.  ``precision="bf16"`` runs the shard-local bf16 scan with
    fp32 boundary re-check (``_range_bf16_fn``) — same results, same
    counts, with the re-check telemetry added to stats."""
    opts = resolve_engine_opts(
        opts, bq=bq, backend=backend, interpret=interpret,
        precision=precision,
    )
    bq = opts.bq if opts.bq is not None else _DEFAULT_BQ
    interpret = opts.interpret
    precision = opts.precision
    backend = resolve_backend(opts.backend)
    index = sidx.index
    metric_eng = _engine_metric(index.metric_name)
    queries = _engine_queries(index.metric_name, np.asarray(queries, np.float32))
    nq = queries.shape[0]
    if nq == 0:
        empty = np.zeros((0, index.n_blocks), bool)
        stats = _batched_stats(index, empty, empty)
        stats["n_shards"] = sidx.n_shards
        stats["shard_dists"] = np.zeros(sidx.n_shards, np.int64)
        stats["shard_blocks"] = np.zeros(sidx.n_shards, np.int64)
        stats["precision"] = precision
        if precision == "bf16":
            _bf16_stats(stats, index.bf16_margin(), 0, np.zeros(0, np.int64))
        return [], _finish_stats(
            stats, kind="range", backend=backend, engine="sharded"
        )
    t_vec = _per_query_t(t, nq)
    if precision == "bf16":
        eps = index.bf16_margin()
        fn = sidx._range_bf16_fn(metric_eng, backend, bq, interpret)
        hit, alive, tmask, rmask, band_counts, sdist, sblk = fn(
            jnp.asarray(queries), jnp.asarray(t_vec), jnp.float32(eps),
            sidx.dev.data, sidx.dev.valid, sidx.dev.boxes,
            sidx.dev.pivots, sidx.dev.pairs, sidx.dev.deltas,
            sidx.dev_data16,
        )
    else:
        fn = sidx._range_fn(metric_eng, backend, bq, interpret)
        hit, alive, tmask, sdist, sblk = fn(
            jnp.asarray(queries), jnp.asarray(t_vec),
            sidx.dev.data, sidx.dev.valid, sidx.dev.boxes,
            sidx.dev.pivots, sidx.dev.pairs, sidx.dev.deltas,
        )
    hit = np.asarray(hit)
    qidx, pidx = np.nonzero(hit)  # row-major: ascending position per query
    orig = sidx.perm[pidx]
    counts = hit.sum(axis=1)
    per_query = np.split(orig, np.cumsum(counts)[:-1])
    results = [r.tolist() for r in per_query]
    # padding-block columns are never alive (their bound is +inf); stats are
    # reported over the REAL blocks so they compare 1:1 with the
    # single-device engine and the oracle
    alive = np.asarray(alive)[:, : index.n_blocks]
    tmask = np.asarray(tmask)[:, : index.n_blocks]
    stats = _batched_stats(index, alive, tmask)
    stats["n_shards"] = sidx.n_shards
    # per-shard exact-phase work split (functional jit outputs, one slot
    # per shard): the shard totals partition the batch's exact-phase
    # distance sum, so imbalance is read straight off this vector
    stats["shard_dists"] = np.asarray(sdist, dtype=np.int64)
    stats["shard_blocks"] = np.asarray(sblk, dtype=np.int64)
    stats["precision"] = precision
    if precision == "bf16":
        _bf16_stats(
            stats, eps, int(np.asarray(rmask).sum()),
            np.asarray(band_counts).sum(axis=1),
        )
    return results, _finish_stats(
        stats, kind="range", backend=backend, engine="sharded"
    )


# ---------------------------------------------------------------------------
# kNN
# ---------------------------------------------------------------------------


def sharded_knn_batched(
    sidx: ShardedBSSIndex,
    queries: np.ndarray,
    k: int,
    *,
    r0: float | None = None,
    growth: float = 2.0,
    max_rounds: int = 8,
    opts: EngineOpts | None = None,
    bq: int | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
    precision: str | None = None,
) -> tuple[np.ndarray, np.ndarray, dict]:
    """Exact batched kNN over the sharded index.

    Engine options travel as ``opts=EngineOpts(...)`` (legacy kwargs
    shimmed; ``opts.realisation`` ignored — rounds are dense-pinned, see
    ``sharded_query_batched``); ``r0`` / ``growth`` / ``max_rounds`` are
    the radius schedule and stay explicit.

    ``precision="bf16"`` swaps each round for the bf16-scan +
    global-band + fp32-re-check round (``_knn_round_bf16_fn``); candidates,
    distances, the radius schedule and the per-query counts stay
    bit-identical to the fp32 sharded engine.

    The host driver mirrors ``bss_knn_batched`` step for step — same initial
    per-query radius (read off the sorted REAL-block bounds), same
    tighten-and-widen schedule, same exhaustive fallback — so the per-round
    alive sets over real blocks (and therefore the per-query distance
    counts) are identical to the single-device engine's.  Only the round
    body differs: each shard evaluates its own masked exact phase and a
    per-shard ``top_k``, merged across the mesh by all-gather + global
    ``top_k`` (see module docstring for the tie-break argument); the
    shrinking radius is driven by the MERGED kth-nearest-so-far, keeping
    per-shard exclusion globally sound."""
    opts = resolve_engine_opts(
        opts, bq=bq, backend=backend, interpret=interpret,
        precision=precision,
    )
    bq = opts.bq if opts.bq is not None else _DEFAULT_BQ
    interpret = opts.interpret
    precision = opts.precision
    backend = resolve_backend(opts.backend)
    index = sidx.index
    metric_eng = _engine_metric(index.metric_name)
    queries = _engine_queries(index.metric_name, np.asarray(queries, np.float32))
    nq = queries.shape[0]
    k = int(k)
    if k <= 0:
        raise ValueError(f"k must be positive, got {k}")
    if nq == 0:
        stats = _knn_empty_stats(index, 0, precision, backend,
                                 engine="sharded")
        stats["n_shards"] = sidx.n_shards
        stats["shard_dists"] = np.zeros(sidx.n_shards, np.int64)
        stats["shard_blocks"] = np.zeros(sidx.n_shards, np.int64)
        return (
            np.zeros((0, k), np.int64), np.zeros((0, k), np.float32), stats,
        )
    k_run = min(k, index.n_valid)
    if k_run == 0:
        stats = _knn_empty_stats(index, nq, precision, backend,
                                 engine="sharded")
        stats["n_shards"] = sidx.n_shards
        stats["shard_dists"] = np.zeros(sidx.n_shards, np.int64)
        stats["shard_blocks"] = np.zeros(sidx.n_shards, np.int64)
        return (
            np.full((nq, k), -1, np.int64),
            np.full((nq, k), np.inf, np.float32),
            stats,
        )
    qj = jnp.asarray(queries)
    n_blocks = index.n_blocks

    # radius-independent planar bounds, computed once shard-local and kept
    # device-sharded for the rounds; the host copy (REAL columns only —
    # padding bounds are +inf) drives the same initial-radius and widening
    # schedule as the single-device engine
    lb_dev = sidx._lb_fn(metric_eng, backend, bq, interpret)(
        qj, sidx.dev.boxes, sidx.dev.pivots, sidx.dev.pairs, sidx.dev.deltas,
    )
    lb_np = np.asarray(lb_dev)[:, :n_blocks]
    lb_sorted = np.sort(lb_np, axis=1)
    if r0 is None:
        j0 = min(n_blocks - 1, max(0, math.ceil(2 * k / index.block) - 1))
        radii = lb_sorted[:, j0].astype(np.float32)
    else:
        radii = np.full(nq, float(r0), np.float32)

    bf16 = precision == "bf16"
    eps = index.bf16_margin() if bf16 else 0.0
    if bf16:
        round_fn = sidx._knn_round_bf16_fn(
            metric_eng, backend, bq, interpret, k_run
        )
        data16 = sidx.dev_data16
    else:
        round_fn = sidx._knn_round_fn(metric_eng, backend, bq, interpret, k_run)
    valid_pb = _valid_per_block(index)
    total_exact = np.zeros(nq, np.int64)
    excl_pq = np.zeros(nq, np.int64)
    # per-shard accumulation across rounds: a finished query's radius is
    # -1 from the round after it completes, so its rows survive no block
    # and the in-jit shard sums agree with the `upd`-masked host tallies
    shard_dists = np.zeros(sidx.n_shards, np.int64)
    shard_blocks = np.zeros(sidx.n_shards, np.int64)
    tiles_total = 0
    recheck_pq = np.zeros(nq, np.int64)
    recheck_tiles_total = 0
    done = np.zeros(nq, bool)
    cand_idx = np.full((nq, k_run), 0, np.int64)
    cand_dist = np.full((nq, k_run), np.inf, np.float32)
    rounds = 0
    for rounds in range(1, max_rounds + 2):
        if rounds == max_rounds + 1:
            radii = np.where(done, radii, np.inf).astype(np.float32)
        if bf16:
            ci, cd, alive, tmask, rmask, band_counts, sdist, sblk = round_fn(
                qj, jnp.asarray(radii), jnp.float32(eps), lb_dev,
                sidx.dev.data, sidx.dev.valid, data16,
            )
            recheck_tiles_total += int(
                np.asarray(rmask)[:, : n_blocks].sum()
            )
            recheck_pq += np.where(
                ~done, np.asarray(band_counts).sum(axis=1), 0
            )
        else:
            ci, cd, alive, tmask, sdist, sblk = round_fn(
                qj, jnp.asarray(radii), lb_dev, sidx.dev.data, sidx.dev.valid,
            )
        shard_dists += np.asarray(sdist, dtype=np.int64)
        shard_blocks += np.asarray(sblk, dtype=np.int64)
        ci, cd = np.asarray(ci), np.asarray(cd)
        # real-block columns only: identical to the single-device alive set
        # (padding is only ever admitted by the radius=inf fallback round,
        # where its zero valid points still contribute no distances)
        alive = np.asarray(alive)[:, :n_blocks]
        tiles_round = int(np.asarray(tmask)[:, :n_blocks].sum())
        kth = cd[:, -1]
        dn = np.isfinite(kth) & ((kth <= radii) | alive.all(axis=1))
        upd = ~done  # freeze finished queries (their results are final)
        cand_idx[upd] = ci[upd]
        cand_dist[upd] = cd[upd]
        total_exact[upd] += alive[upd].astype(np.int64) @ valid_pb
        excl_pq[upd] += n_blocks - alive[upd].sum(axis=1)
        tiles_total += tiles_round
        done = done | dn
        if done.all():
            break
        # identical tighten-and-widen schedule to bss_knn_batched
        n_alive = alive.sum(axis=1)
        j_next = np.minimum(
            n_blocks - 1,
            np.maximum(np.maximum(2 * n_alive, n_alive + 1), 1),
        )
        widened = np.maximum(lb_sorted[np.arange(nq), j_next], radii * growth)
        radii = np.where(
            done, np.float32(-1.0),
            np.where(np.isfinite(kth), np.minimum(kth, widened), widened),
        ).astype(np.float32)
        radii = np.where(
            ~done & (n_alive > n_blocks // 2), np.float32(np.inf), radii
        )

    n_pivots = index.pivots.shape[0]
    stats = {
        "rounds": rounds,
        "pivot_dists_per_query": float(n_pivots),
        "exact_dists_per_query": float(total_exact.mean()),
        "dists_per_query": float(n_pivots + total_exact.mean()),
        "per_query_dists": n_pivots + total_exact,
        "tiles_computed": tiles_total,
        "n_blocks": int(n_blocks),
        "n_shards": sidx.n_shards,
        "shard_dists": shard_dists,
        "shard_blocks": shard_blocks,
        "generation": int(index.generation),
        "precision": precision,
        "excluded": {"hilbert": excl_pq},
    }
    if bf16:
        _bf16_stats(stats, eps, recheck_tiles_total, recheck_pq)
    _finish_stats(stats, kind="knn", backend=backend, engine="sharded")
    orig = np.where(np.isfinite(cand_dist), sidx.perm[cand_idx], -1)
    if k_run < k:
        orig = np.pad(orig, ((0, 0), (0, k - k_run)), constant_values=-1)
        cand_dist = np.pad(
            cand_dist, ((0, 0), (0, k - k_run)), constant_values=np.inf
        )
    return orig, cand_dist, stats

"""Mesh-aware sharding helpers.

Mesh axes:
  single-pod:  ("data", "model")            = (16, 16)  -> 256 chips
  multi-pod:   ("pod", "data", "model")     = (2, 16, 16) -> 512 chips

Conventions used across every model family:
  * batch-like dims shard over all data axes (pod+data),
  * tensor-parallel dims shard over "model",
  * FSDP ("zero-3") weight sharding uses the data axes on a weight's input
    dim — all-gathered per layer inside lax.scan so XLA's latency-hiding
    scheduler overlaps the gather with the previous layer's compute.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "P",
    "dp_axes",
    "fsdp_axes",
    "named",
    "shard_tree",
    "batch_spec",
    "abstract_with_sharding",
]


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """All data-parallel axes present in the mesh (pod first)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes used for fully-sharded parameter storage."""
    return dp_axes(mesh)


def batch_spec(mesh: Mesh, *rest: Any) -> P:
    """PartitionSpec with the batch dim sharded over all data axes."""
    return P(dp_axes(mesh), *rest)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def shard_tree(mesh: Mesh, spec_tree: Any) -> Any:
    """Map a pytree of PartitionSpec -> pytree of NamedSharding."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def abstract_with_sharding(shape_tree: Any, sharding_tree: Any) -> Any:
    """Attach shardings to ShapeDtypeStructs (for .lower() without arrays)."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shape_tree,
        sharding_tree,
    )


def _no_active_mesh() -> bool:
    try:
        from jax.sharding import get_abstract_mesh
    except ImportError:
        # jax <= 0.4.37: the `with mesh:` context lives in thread_resources
        from jax.interpreters import pxla

        return pxla.thread_resources.env.physical_mesh.empty
    m = get_abstract_mesh()
    return m is None or m.empty


def maybe_constrain(x, spec: P):
    """with_sharding_constraint that is a no-op when no mesh is active
    (lets the same model code run in single-device smoke tests and in
    pjit-partitioned production graphs)."""
    if _no_active_mesh():
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def divisible_or_none(dim: int, mesh: Mesh, axes: tuple[str, ...] | str):
    """Return the axes if ``dim`` divides their product, else None (replicate).

    GSPMD can pad uneven shardings, but padding on a *weight* dim wastes HBM
    and produces ragged collectives; we prefer explicit replication and call
    it out in the roofline notes.
    """
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return axes if dim % size == 0 else None

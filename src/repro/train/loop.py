"""Fault-tolerant training loop.

Features (all test-covered on CPU; all mesh-shape-agnostic so they hold on
the 512-chip production mesh):
  * checkpoint/restart: periodic async checkpoints of (state, data-iterator
    state); on start, auto-resume from the latest checkpoint,
  * elastic re-mesh: the mesh is built from the LIVE device list each run;
    checkpoints are sharding-agnostic (host-side leaves) so a restart on a
    different device count reshards transparently,
  * straggler watchdog: per-step wall-time EMA; steps slower than
    ``straggler_factor`` x EMA are logged and counted (on real multi-host
    deployments this triggers the drop-and-reshard protocol; on a single
    process it is telemetry),
  * optional int8 error-feedback gradient compression (repro.optim).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.optim import Optimizer
from repro.serve.queue import now
from repro.train.step import init_state, make_train_step

__all__ = ["TrainLoop", "TrainLoopConfig"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "checkpoints"
    keep_last: int = 3
    log_every: int = 10
    microbatches: int = 1
    compression: bool = False
    straggler_factor: float = 3.0


class TrainLoop:
    def __init__(
        self,
        loss_fn: Callable,
        optimizer: Optimizer,
        stream,  # data pipeline with next()/state()/restore()
        cfg: TrainLoopConfig,
        state_shardings: Any = None,
    ):
        self.cfg = cfg
        self.optimizer = optimizer
        self.stream = stream
        self.ckpt = CheckpointManager(cfg.checkpoint_dir, cfg.keep_last)
        self.step_fn = jax.jit(
            make_train_step(
                loss_fn, optimizer, cfg.microbatches, cfg.compression
            ),
            donate_argnums=(0,),
        )
        self.state_shardings = state_shardings
        self.stragglers = 0
        self.losses: list[float] = []

    def init_or_restore(self, init_params_fn: Callable) -> Any:
        latest = self.ckpt.latest_step()
        if latest is None:
            params = init_params_fn()
            return init_state(params, self.optimizer, self.cfg.compression)
        template = init_state(
            init_params_fn(), self.optimizer, self.cfg.compression
        )
        state, extra = self.ckpt.restore(
            template, step=latest, shardings=self.state_shardings
        )
        self.stream.restore(extra["stream"])
        print(f"[restore] resumed from step {latest}")
        return state

    def run(self, state: Any, crash_at: int | None = None) -> Any:
        ema = None
        start = int(state["step"])
        for step in range(start, self.cfg.total_steps):
            batch = self.stream.next()
            t0 = now()
            state, metrics = self.step_fn(state, batch)
            loss = float(metrics["loss"])  # blocks; = per-step sync point
            dt = now() - t0
            self.losses.append(loss)
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if dt > self.cfg.straggler_factor * ema and step > start + 3:
                self.stragglers += 1
                print(f"[straggler] step {step} took {dt:.3f}s (ema {ema:.3f}s)")
            if (step + 1) % self.cfg.log_every == 0:
                print(f"step {step + 1}: loss={loss:.4f} ({dt * 1e3:.0f} ms)")
            if (step + 1) % self.cfg.checkpoint_every == 0:
                self.ckpt.save(
                    step + 1, state, extra={"stream": self.stream.state()},
                    blocking=False,
                )
            if crash_at is not None and step + 1 >= crash_at:
                self.ckpt.wait()
                raise RuntimeError(f"simulated crash at step {step + 1}")
        self.ckpt.wait()
        return state

"""Train-step factory: microbatch gradient accumulation + optimizer apply.

``make_train_step`` returns a pure function
    (state, batch) -> (state, metrics)
suitable for ``jax.jit`` with in/out shardings and donation.  Microbatching
runs as a ``lax.scan`` over leading-dim splits of the batch — activation
memory scales with the microbatch, gradients accumulate in fp32.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim import Optimizer, int8_error_feedback

__all__ = ["make_train_step", "init_state"]


def init_state(params, optimizer: Optimizer, compression: bool = False) -> dict:
    state = {"params": params, "opt": optimizer.init(params), "step": jnp.zeros((), jnp.int32)}
    if compression:
        state["residual"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    return state


def make_train_step(
    loss_fn: Callable[[Any, Any], jnp.ndarray],
    optimizer: Optimizer,
    microbatches: int = 1,
    compression: bool = False,
    accum_dtype=jnp.float32,
):
    """``accum_dtype``: gradient-accumulation precision.  fp32 is the safe
    default; bf16 halves the accumulator HBM (8 GB/chip for a 1T model on
    512 chips) at ~3 effective mantissa bits over 8 microbatches — the
    Adafactor update clip absorbs the noise (kimi-k2 recipe)."""
    def split_mb(batch):
        def r(x):
            b = x.shape[0]
            if b % microbatches != 0:
                raise ValueError(
                    f"batch size {b} not divisible by microbatches "
                    f"{microbatches}"
                )
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        return jax.tree.map(r, batch)

    def train_step(state, batch):
        params = state["params"]

        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = split_mb(batch)

            def body(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc_l, acc_g = acc
                acc_g = jax.tree.map(
                    lambda a, x: a + x.astype(accum_dtype), acc_g, g
                )
                return (acc_l + l, acc_g), None

            zero = (
                jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params),
            )
            (loss_sum, grads), _ = jax.lax.scan(body, zero, mbs)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        new_state = dict(state)
        if compression:
            grads, new_state["residual"] = int8_error_feedback(
                grads, state["residual"]
            )
        new_params, new_opt = optimizer.update(grads, state["opt"], params)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        new_state["step"] = state["step"] + 1
        metrics = {"loss": loss}
        return new_state, metrics

    return train_step

"""Incremental maintenance of the blocked BSS index: a living corpus.

``build_bss`` is a batch build; this module keeps a built index serving
while the corpus changes, without rebuilding it:

* :func:`append` packs new rows into FRESH blocks against the EXISTING
  pivot / pivot-pair reference tables.  The paper's blocked layout (§6:
  per-block reference tables over fixed-size blocks) is naturally
  append-friendly — a new block needs only its OWN planar tables, so the
  host-side table work is exactly ``m × P`` pivot distances for ``m`` new
  rows (recorded in the mutation stats; never the ``n × P`` of a rebuild).
  New rows get their own locality permutation (the same recursive
  median-split ``build_bss`` uses, run over the new rows only), existing
  blocks' data and boxes are untouched, and the device mirrors are
  EXTENDED: a live single-device mirror grows by suffix-concatenation (only
  the new blocks cross host→device); a live sharded mirror consumes its
  empty PADDING blocks first — those sit at the end of the padded layout,
  i.e. on the least-loaded shard — via a sharding-preserving device-side
  splice that changes no array shape, so the cached shard_map callables
  keep serving with ZERO recompiles (``ShardedBSSIndex.extended``).  Only
  when the new blocks outgrow the padding does the sharded mirror fall back
  to a lazy re-layout.

* :func:`delete` tombstones rows through the per-block valid counts every
  engine already honours: the slot's ``valid`` bit clears (the masked exact
  phases and the distance accounting read it) and its ``perm`` entry
  becomes -1 (the padding sentinel the oracle and hit extraction already
  skip).  Block boxes are left alone — a box over a superset of the live
  points only ever LOOSENS the lower bound, which is sound (never excludes
  a true hit); compaction re-tightens.

* :func:`compact` re-permutes the live rows into a fresh layout when
  tombstones or append-growth have degraded it: with ``refresh_pivots=True``
  it reruns the full build (FFT pivot selection included) over the live
  rows in ascending-original-id order with the index's own seed — the
  result is field-for-field the index a fresh ``build_bss`` over the same
  live rows would produce (ids preserved through a permutation remap), the
  anchor of the bit-identity contract below; with ``refresh_pivots=False``
  it keeps the reference tables and only re-permutes / re-packs (cheaper:
  no pivot selection pass, ``m × P`` projection distances).
  :func:`maybe_compact` is the threshold policy.

Every mutation is FUNCTIONAL: it returns a NEW ``BSSIndex`` (plus a
:class:`MutationStats`) sharing the unchanged arrays, and bumps the
monotonic ``index.generation``.  A generation is therefore a consistent
snapshot — the serving front mutates by swapping whole index references
between micro-batches (queries in flight finish on the old mirror; no
torn reads) and keys its exact-hit cache on the generation.

Exactness contract: at EVERY generation, the fused / oracle / sharded /
bf16 engines agree bit-for-bit on hits, kNN results and per-query distance
counts (engine parity is layout-independent: they share one layout and one
bound definition).  After :func:`compact` with refreshed pivots, the index
is additionally bit-identical — layout, hits, counts — to a fresh
``build_bss`` over the same live rows.  An un-compacted append keeps old
blocks verbatim instead of re-permuting (that is what makes it O(m)), so
its BLOCK LAYOUT legitimately differs from a fresh build until compaction;
``tests/test_maintain.py`` pins all three statements.

Everything here is host-side numpy orchestration (never jit-reachable);
the only device work is mirror extension.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import jax.numpy as jnp
import numpy as np

from repro.core.flat_index import (
    BSSDeviceArrays,
    BSSIndex,
    _build_engine_index,
    _engine_metric,
    _pack_blocks,
    _project_all,
    _split_perm,
    _MIN_NORM,
)
from repro.core.npdist import pairwise_np

__all__ = [
    "MutationStats",
    "append",
    "delete",
    "compact",
    "maybe_compact",
]


@dataclasses.dataclass(frozen=True)
class MutationStats:
    """What one mutation did and what it cost — the accounting the
    no-full-rebuild contract is verified by, and the record the serving
    front folds into its metrics registry.

    ``table_dists`` counts the host-side reference-table distance
    evaluations the mutation performed: ``rows × n_pivots`` for append
    (new rows only — the proof the append path never re-derives the
    existing corpus), 0 for delete, the live-corpus projection cost for
    compact."""

    op: str                    # "append" | "delete" | "compact"
    generation: int            # the NEW index's generation
    rows: int                  # rows appended / deleted / re-packed
    table_dists: int           # host reference-table distance evaluations
    n_blocks: int              # the NEW index's block count
    tombstone_frac: float      # the NEW index's tombstone fraction
    new_blocks: int = 0        # append: blocks added
    sharded_in_place: bool = False  # append: mirror spliced, no re-layout
    refreshed_pivots: bool = False  # compact: pivot tables re-derived


def _engine_rows(index: BSSIndex, rows: np.ndarray) -> np.ndarray:
    """Map raw input rows into the index's engine space — the same ops (and
    therefore the same bits) as ``build_bss``'s corpus-side mapping."""
    rows = np.asarray(rows, np.float32)
    if rows.ndim != 2 or rows.shape[1] != index.data.shape[1]:
        raise ValueError(
            f"rows must have shape (m, {index.data.shape[1]}), got "
            f"{rows.shape}"
        )
    if index.metric_name == "cosine":
        norms = np.linalg.norm(rows, axis=1, keepdims=True)
        rows = rows / np.maximum(norms, _MIN_NORM)
    return rows


def _layout_rows(
    index: BSSIndex, rows: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, int]:
    """Lay engine-space rows out against the index's EXISTING reference
    tables: project onto the pivot-pair planes, median-split for locality,
    pack into fresh padded blocks with their boxes — the exact helpers
    ``build_bss`` itself runs, over the new rows only.  Returns
    ``(perm, data_pad, valid, boxes, table_dists)`` where ``perm`` orders
    the INPUT rows and ``table_dists`` is the pivot-distance count."""
    build_metric = _engine_metric(index.metric_name)
    dp = pairwise_np(build_metric, rows, index.pivots).astype(np.float32)
    x, y = _project_all(dp, index.pairs, index.deltas)
    feats = np.concatenate([x, y], axis=1)
    perm = _split_perm(feats, index.block)
    data_pad, valid, boxes = _pack_blocks(
        rows[perm], x[perm], y[perm], index.block
    )
    return perm, data_pad, valid, boxes, int(dp.size)


def append(
    index: BSSIndex, rows: np.ndarray
) -> tuple[BSSIndex, MutationStats]:
    """Append ``rows`` as fresh blocks; returns ``(new_index, stats)``.

    The new rows are assigned original ids ``[index.next_id,
    index.next_id + m)`` (stable across later compactions), laid out
    against the EXISTING pivots (module docstring), and appended after the
    current blocks.  Existing blocks — data, boxes, validity — are shared
    untouched; live device mirrors are extended, not rebuilt."""
    rows = _engine_rows(index, rows)
    m = rows.shape[0]
    if m == 0:
        raise ValueError("append needs at least one row")
    perm_new, tail_data, tail_valid, tail_boxes, table_dists = _layout_rows(
        index, rows
    )
    ids = index.next_id + np.arange(m, dtype=np.int64)
    pad = tail_valid.shape[0] - m
    tail_perm = np.concatenate(
        [ids[perm_new], np.full(pad, -1, dtype=np.int64)]
    )

    new = dataclasses.replace(
        index,
        data=np.concatenate([index.data, tail_data]),
        perm=np.concatenate([index.perm, tail_perm]),
        valid=np.concatenate([index.valid, tail_valid]),
        boxes=np.concatenate([index.boxes, tail_boxes]),
        generation=index.generation + 1,
        next_id=index.next_id + m,
        _device=None,
        _sharded=None,
        _bf16=None,
        # the margin is a corpus max — new rows can raise it; recompute
        # lazily on first bf16 query of the new generation
        _bf16_eps=None,
    )

    # device-mirror extension: only the new blocks cross host→device
    if index._device is not None:
        old = index._device
        new._device = BSSDeviceArrays(
            data=jnp.concatenate(
                [old.data, jnp.asarray(tail_data, jnp.float32)]
            ),
            pivots=old.pivots,
            pairs=old.pairs,
            deltas=old.deltas,
            boxes=jnp.concatenate(
                [old.boxes, jnp.asarray(tail_boxes, jnp.float32)]
            ),
            valid=jnp.concatenate([old.valid, jnp.asarray(tail_valid)]),
        )
    if index._bf16 is not None:
        new._bf16 = jnp.concatenate(
            [index._bf16, jnp.asarray(tail_data, jnp.bfloat16)]
        )
    sharded_in_place = False
    if index._sharded is not None:
        ext = index._sharded.extended(
            new, tail_data, tail_valid, tail_boxes, tail_perm
        )
        if ext is not None:
            new._sharded = ext
            sharded_in_place = True

    return new, MutationStats(
        op="append",
        generation=new.generation,
        rows=m,
        table_dists=table_dists,
        n_blocks=new.n_blocks,
        tombstone_frac=new.tombstone_frac,
        new_blocks=tail_boxes.shape[0],
        sharded_in_place=sharded_in_place,
    )


def delete(
    index: BSSIndex, ids: Iterable[int]
) -> tuple[BSSIndex, MutationStats]:
    """Tombstone rows by ORIGINAL id; returns ``(new_index, stats)``.

    A deleted slot clears its ``valid`` bit (every engine's masked exact
    phase, hit test and per-block distance accounting honour it already)
    and its ``perm`` entry becomes the -1 padding sentinel.  Unknown or
    already-deleted ids raise ``ValueError`` — a delete is an assertion
    about a live row, and silently ignoring a stale id would hide a
    double-delete race in the caller."""
    want = np.asarray(list(ids), dtype=np.int64)
    if want.size == 0:
        raise ValueError("delete needs at least one id")
    if np.unique(want).size != want.size:
        raise ValueError("duplicate ids in one delete")
    # original id -> slot position (live rows only)
    live_pos = np.nonzero(index.valid)[0]
    live_ids = index.perm[live_pos]
    id2pos = np.full(index.next_id, -1, dtype=np.int64)
    id2pos[live_ids] = live_pos
    bad = (want < 0) | (want >= index.next_id)
    if bad.any():
        raise ValueError(f"unknown ids: {want[bad].tolist()}")
    pos = id2pos[want]
    dead = pos < 0
    if dead.any():
        raise ValueError(
            f"ids not live (unknown or already deleted): "
            f"{want[dead].tolist()}"
        )

    valid = index.valid.copy()
    valid[pos] = False
    perm = index.perm.copy()
    perm[pos] = -1
    new = dataclasses.replace(
        index,
        perm=perm,
        valid=valid,
        generation=index.generation + 1,
        tombstones=index.tombstones + int(want.size),
        _device=None,
        _sharded=None,
        # data is untouched: the bf16 mirror stays valid, and the old
        # margin (a max over a SUPERSET of the live rows) remains sound —
        # a larger eps only widens the fp32 re-check band
        _bf16=index._bf16,
        _bf16_eps=index._bf16_eps,
    )
    if index._device is not None:
        new._device = index._device._replace(
            valid=index._device.valid.at[jnp.asarray(pos)].set(False)
        )
    if index._sharded is not None:
        new._sharded = index._sharded.with_tombstones(new, pos)

    return new, MutationStats(
        op="delete",
        generation=new.generation,
        rows=int(want.size),
        table_dists=0,
        n_blocks=new.n_blocks,
        tombstone_frac=new.tombstone_frac,
    )


def compact(
    index: BSSIndex, *, refresh_pivots: bool = True
) -> tuple[BSSIndex, MutationStats]:
    """Re-permute the live rows into a fresh tight layout; returns
    ``(new_index, stats)``.  Original ids survive (``next_id`` too, so
    id assignment never collides with resurrected slots); tombstones
    reset.

    ``refresh_pivots=True`` reruns the FULL build over the live rows in
    ascending-original-id order with the index's own seed — field-for-field
    the fresh ``build_bss`` over the same live rows (the bit-identity
    anchor; see module docstring).  ``refresh_pivots=False`` keeps the
    existing reference tables and only re-permutes / re-packs — the cheap
    variant for when exclusion power is still healthy."""
    live_pos = np.nonzero(index.valid)[0]
    m = live_pos.size
    if m == 0:
        raise ValueError("compact needs at least one live row")
    live_ids = index.perm[live_pos]
    order = np.argsort(live_ids)
    ids_sorted = live_ids[order]
    rows = index.data[live_pos[order]]  # engine space, ascending id

    if refresh_pivots:
        built = _build_engine_index(
            index.metric_name, rows,
            n_pivots=index.pivots.shape[0],
            n_pairs=index.pairs.shape[0],
            block=index.block, seed=index.seed, mesh=index.mesh,
        )
        perm = built.perm
        data_pad, valid, boxes = built.data, built.valid, built.boxes
        pivots, pairs, deltas = built.pivots, built.pairs, built.deltas
        # FFT selection evaluates O(m·P) candidate distances plus the m·P
        # projection table — charge both halves
        table_dists = 2 * m * index.pivots.shape[0]
    else:
        perm_rows, data_pad, valid, boxes, table_dists = _layout_rows(
            index, rows
        )
        pad = valid.shape[0] - m
        perm = np.concatenate(
            [perm_rows, np.full(pad, -1, dtype=np.int64)]
        )
        pivots, pairs, deltas = index.pivots, index.pairs, index.deltas

    # row positions -> original ids (fresh-build comparisons map through
    # the same ids_sorted table)
    perm_ids = np.where(perm >= 0, ids_sorted[np.clip(perm, 0, m - 1)], -1)
    new = dataclasses.replace(
        index,
        data=data_pad,
        perm=perm_ids,
        valid=valid,
        pivots=pivots,
        pairs=pairs,
        deltas=deltas,
        boxes=boxes,
        generation=index.generation + 1,
        tombstones=0,
        _device=None,
        _sharded=None,
        _bf16=None,
        _bf16_eps=None,
    )
    return new, MutationStats(
        op="compact",
        generation=new.generation,
        rows=m,
        table_dists=int(table_dists),
        n_blocks=new.n_blocks,
        tombstone_frac=0.0,
        refreshed_pivots=refresh_pivots,
    )


def maybe_compact(
    index: BSSIndex,
    *,
    max_tombstone_frac: float = 0.25,
    max_block_growth: float = 2.0,
    block_exclusion_rate: float | None = None,
    min_block_exclusion_rate: float = 0.5,
    refresh_pivots: bool | None = None,
) -> tuple[BSSIndex, MutationStats | None]:
    """Compact when the layout has degraded; returns ``(index, stats)``
    with ``stats=None`` (and the index unchanged) when it has not.

    Triggers: tombstone fraction above ``max_tombstone_frac``, or block
    count above ``max_block_growth ×`` the minimum the live rows need
    (append always opens fresh blocks, so growth measures fragmentation).

    Pivot refresh: pass the measured ``block_exclusion_rate`` from the
    engines' stats (PR 8's attribution metrics export it) and the pivots
    are re-derived when it has sunk below ``min_block_exclusion_rate`` —
    appended data drifting away from the original pivots is exactly what
    that shows up as.  ``refresh_pivots`` forces the choice either way."""
    n_live = index.n_valid
    min_blocks = max(1, -(-n_live // index.block))
    degraded = (
        index.tombstone_frac > max_tombstone_frac
        or index.n_blocks > max_block_growth * min_blocks
    )
    if not degraded:
        return index, None
    if refresh_pivots is None:
        refresh_pivots = (
            block_exclusion_rate is not None
            and block_exclusion_rate < min_block_exclusion_rate
        )
    return compact(index, refresh_pivots=refresh_pivots)

"""Living-corpus index maintenance: functional append / delete / compact
over a built :class:`~repro.core.flat_index.BSSIndex` (see ``maintain``)."""

from repro.index.maintain import (
    MutationStats,
    append,
    compact,
    delete,
    maybe_compact,
)

__all__ = [
    "MutationStats",
    "append",
    "compact",
    "delete",
    "maybe_compact",
]

"""Transformer building blocks: RMSNorm, RoPE, GQA attention (full / sliding
window / softcapped), SwiGLU MLP, and a sort-based (dropless-style) MoE with
capacity bound — all pure jnp, pjit-shardable, scan-friendly.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "rope",
    "gqa_attention",
    "swiglu",
    "moe_block",
    "softcap",
]

NEG_INF = -2.0e38


def _axprod(axes) -> int:
    """Product of mesh-axis sizes for the current abstract mesh (1 if none)."""
    from jax.sharding import get_abstract_mesh

    m = get_abstract_mesh()
    if m is None or m.empty:
        return 1
    out = 1
    for a in axes:
        out *= dict(zip(m.axis_names, m.axis_sizes))[a]
    return out


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return y.astype(dtype)


def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0
) -> jnp.ndarray:
    """Rotary embedding.  x: (..., S, n, d_head), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def gqa_attention(
    q: jnp.ndarray,  # (B, S, H, Dh)
    k: jnp.ndarray,  # (B, T, KV, Dh)
    v: jnp.ndarray,  # (B, T, KV, Dh)
    q_positions: jnp.ndarray,  # (B, S) int32
    kv_positions: jnp.ndarray,  # (B, T) int32
    kv_valid: jnp.ndarray | None = None,  # (B, T) bool — cache occupancy
    window: int | None = None,  # sliding window (local attention)
    attn_softcap: float | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """Causal grouped-query attention; supports decode (S=1, long T) and
    train/prefill (S == T).  Softmax in fp32; outputs in q.dtype."""
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else dh**-0.5
    qg = q.reshape(b, s, kvh, g, dh)
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32
    ) * scale
    scores = softcap(scores, attn_softcap)
    mask = q_positions[:, :, None] >= kv_positions[:, None, :]
    if window is not None:
        mask &= (q_positions[:, :, None] - kv_positions[:, None, :]) < window
    if kv_valid is not None:
        mask &= kv_valid[:, None, :]
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum(
        "bkgst,btkd->bskgd", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, s, h, dh).astype(q.dtype)


def gqa_attention_quantized(
    q: jnp.ndarray,          # (B, S, H, Dh)
    k_q: jnp.ndarray,        # (B, T, KV, Dh) int8
    k_scale: jnp.ndarray,    # (B, T, KV) fp32, absmax/127 per (pos, head)
    v_q: jnp.ndarray,
    v_scale: jnp.ndarray,
    q_positions: jnp.ndarray,
    kv_positions: jnp.ndarray,
    kv_valid: jnp.ndarray | None = None,
    window=None,
    attn_softcap: float | None = None,
) -> jnp.ndarray:
    """Attention against an int8-quantised KV cache (KIVI-style per-token,
    per-head scales).  The scales factor OUT of the dh contraction, so they
    are applied to the score matrix / folded into the probabilities — the
    dequantised cache is never materialised:

        scores = (q . k_q) * k_scale[t]
        out    = (probs * v_scale[t]) . v_q
    """
    b, s, h, dh = q.shape
    kvh = k_q.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, dh)
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", qg, k_q.astype(q.dtype),
        preferred_element_type=jnp.float32,
    ) * (dh**-0.5)
    scores = scores * k_scale.transpose(0, 2, 1)[:, :, None, None, :]
    scores = softcap(scores, attn_softcap)
    mask = q_positions[:, :, None] >= kv_positions[:, None, :]
    if window is not None:
        mask &= (q_positions[:, :, None] - kv_positions[:, None, :]) < window
    if kv_valid is not None:
        mask &= kv_valid[:, None, :]
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    probs = probs * v_scale.transpose(0, 2, 1)[:, :, None, None, :]
    out = jnp.einsum(
        "bkgst,btkd->bskgd", probs.astype(q.dtype), v_q.astype(q.dtype),
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, s, h, dh).astype(q.dtype)


def quantize_kv(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(B, S, KV, Dh) -> int8 values + (B, S, KV) fp32 scales."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gqa_attention_qchunked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_positions: jnp.ndarray,
    kv_positions: jnp.ndarray,
    kv_valid: jnp.ndarray | None = None,
    window=None,
    attn_softcap: float | None = None,
    chunk: int = 2048,
) -> jnp.ndarray:
    """Query-chunked attention for long prefill: lax.scan over query chunks
    bounds the live score tensor to (B, H, chunk, T) — the flash-attention
    memory fix restated at the XLA level (each chunk's softmax is complete
    because keys are fully resident; no online rescaling needed)."""
    b, s, h, dh = q.shape
    if s % chunk or s <= chunk:
        return gqa_attention(
            q, k, v, q_positions, kv_positions, kv_valid, window, attn_softcap
        )
    n = s // chunk
    qc = q.reshape(b, n, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    pc = q_positions.reshape(b, n, chunk).transpose(1, 0, 2)

    def body(_, xs):
        qi, pi = xs
        o = gqa_attention(
            qi, k, v, pi, kv_positions, kv_valid, window, attn_softcap
        )
        return None, o

    _, outs = jax.lax.scan(body, None, (qc, pc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)


@dataclasses.dataclass(frozen=True)
class MoEDims:
    n_experts: int
    top_k: int
    capacity: int  # per-expert token capacity (global, per microbatch)
    expert_axis: str | None = None  # mesh axis for expert parallelism
    token_axes: tuple | None = None  # mesh axes of the flattened token dim


def moe_block(
    x: jnp.ndarray,  # (B, S, D)
    router_w: jnp.ndarray,  # (D, E)
    w_gate: jnp.ndarray,  # (E, D, F)
    w_up: jnp.ndarray,  # (E, D, F)
    w_down: jnp.ndarray,  # (E, F, D)
    dims: MoEDims,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based token->expert dispatch (MaxText/MegaBlocks-style permute):

      route -> top-k -> flatten (token, expert) pairs -> sort by expert ->
      rank-in-expert -> scatter into an (E, C, D) buffer (drop beyond C) ->
      batched expert GEMMs -> gather back -> weighted combine.

    Avoids the O(T*E*C) one-hot dispatch tensor entirely: all intermediates
    are O(T*k) or O(E*C*D).  Capacity C bounds worst-case skew; with
    C = 1.25 * T*k/E drops are rare and training-neutral.

    Returns (output (B,S,D), aux_load_balance_loss scalar).
    """
    b, s, d = x.shape
    e, k, cap = dims.n_experts, dims.top_k, dims.capacity
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]

    logits = (tokens.astype(jnp.float32) @ router_w.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(axis=-1, keepdims=True), 1e-9)

    # aux loss (Switch-style load balancing)
    density = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0
    )
    density_prob = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_prob) * e

    from jax.sharding import PartitionSpec as _P

    from repro.parallel.sharding import maybe_constrain

    flat_e = top_e.reshape(-1)  # (T*k,)
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e)  # stable
    se = flat_e[order]
    first = jnp.searchsorted(se, jnp.arange(e), side="left")  # (E,)
    pos = jnp.arange(t * k) - first[se]
    tok_of = order // k
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)  # cap row == drop bin

    def _dispatch_spec():
        # Measured on kimi-k2 (EXPERIMENTS.md §Perf): expert-axis rows is
        # the only layout GSPMD partitions sanely.  Rows over token axes or
        # (expert+token) axes both collapse into full all-gathers
        # (159-171 GB/layer/device vs 31 GB here).
        return _P(dims.expert_axis or tuple(dims.token_axes), None)

    gathered = tokens[tok_of]  # (T*k, D)
    if dims.token_axes is not None or dims.expert_axis is not None:
        # Without a constraint GSPMD REPLICATES this (T*k, D) gather output
        # on every device — at kimi-k2 prefill scale ~120 GB/chip.
        gathered = maybe_constrain(gathered, _dispatch_spec())
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[se, slot].add(
        jnp.where(keep[:, None], gathered, 0).astype(x.dtype),
        mode="drop",
    )
    if dims.expert_axis is not None:
        # expert parallelism: the scatter above is the token all-to-all
        buf = jax.lax.with_sharding_constraint(
            buf, _P(dims.expert_axis, None, None)
        )
    # expert GEMMs emit x.dtype (bf16): the MXU still accumulates fp32
    # internally, but cross-shard PARTIAL sums (the d_model contraction is
    # FSDP-sharded -> XLA all-reduces activation partials) travel at half
    # the bytes.  Measured on kimi-k2: 17.8 -> ~9 GiB/layer/mb/device.
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", buf, w_gate, preferred_element_type=x.dtype)
    ) * jnp.einsum("ecd,edf->ecf", buf, w_up, preferred_element_type=x.dtype)
    out_buf = jnp.einsum(
        "ecf,efd->ecd", h.astype(x.dtype), w_down,
        preferred_element_type=x.dtype,
    ).astype(x.dtype)

    back = out_buf[se, slot]  # (T*k, D) gather from expert space
    if dims.token_axes is not None or dims.expert_axis is not None:
        back = maybe_constrain(back, _dispatch_spec())
    vals = back * jnp.where(keep, flat_p[order], 0.0)[:, None].astype(x.dtype)
    combined = jnp.zeros((t, d), x.dtype).at[tok_of].add(vals)
    if dims.token_axes is not None:
        combined = maybe_constrain(combined, _P(tuple(dims.token_axes), None))
    return combined.reshape(b, s, d), aux

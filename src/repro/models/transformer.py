"""Decoder-only LM: dense (llama-style) and MoE variants, GQA, RoPE, optional
local/global alternating attention with logit soft-capping (gemma2-style).

Layers are *stacked* (leading L dim) and executed with ``lax.scan`` — one
compiled layer body regardless of depth (critical for 61-layer × 512-device
dry-run compiles) — with optional remat.

The same parameter pytree serves train (teacher-forced step) and serve
(single-token decode against a KV cache).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import layers
from repro.parallel.sharding import (
    divisible_or_none,
    dp_axes,
    fsdp_axes,
    maybe_constrain,
)

__all__ = ["LMConfig", "LMModel"]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    n_shared_experts: int = 0
    # gemma2-style features
    sliding_window: int | None = None
    local_global_alternate: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    scale_embed: bool = False
    post_norms: bool = False
    rope_theta: float = 10000.0
    dtype: Any = jnp.bfloat16
    remat: bool = True
    # distribution
    optimizer: str = "adamw"  # "adamw" | "adafactor"
    grad_accum_dtype: str = "float32"  # "bfloat16" halves accumulator HBM
    microbatches: int = 1
    seq_shard_activations: bool = False
    # FSDP execution mode: True = all-gather each layer's weights at use
    # (weight-stationary, ZeRO-3 style: 2.1 GB/layer gather for kimi);
    # False lets XLA contract against the sharded d_model dim, which
    # ALL-REDUCES the (E, C, d_ff) activation partials instead — measured
    # 17.8 GiB/layer/microbatch/device on kimi-k2.  See EXPERIMENTS.md §Perf.
    unshard_weights_at_use: bool = False
    expert_axis: str | None = None  # mesh axis for MoE expert parallelism
    attn_q_chunk: int | None = None  # query chunking for long prefill
    # KV-cache precision for decode: "bf16" | "int8" (KIVI-style per-token
    # per-head scales; halves long-context cache HBM, scales factor out of
    # the attention contraction so the cache is never dequantised in full).
    kv_cache_dtype: str = "bf16"
    # Unroll layers into straight-line HLO instead of lax.scan.  Used by the
    # dry-run cost probes: XLA's HloCostAnalysis counts while-loop bodies
    # ONCE (no trip-count multiply), so FLOP/collective extraction needs
    # loop-free probes (see launch/dryrun.py).
    unroll_layers: bool = False
    # Mesh axes over which the batch dim of activations is pinned.  GSPMD's
    # gather partitioning replicates the embedding-lookup output (and thus
    # the whole residual stream) without this constraint.  None = no mesh.
    batch_axes: tuple | None = None

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    def n_params(self) -> int:
        d, dh = self.d_model, self.dh
        attn = d * self.n_heads * dh * 2 + d * self.n_kv_heads * dh * 2
        if self.is_moe:
            mlp = self.moe_experts * 3 * d * self.d_ff + d * self.moe_experts
            mlp += self.n_shared_experts * 3 * d * self.d_ff
        else:
            mlp = 3 * d * self.d_ff
        per_layer = attn + mlp + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab * d

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: top-k + shared experts only)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        attn = d * self.n_heads * self.dh * 2 + d * self.n_kv_heads * self.dh * 2
        mlp = (self.moe_top_k + self.n_shared_experts) * 3 * d * self.d_ff
        mlp += d * self.moe_experts  # router
        per_layer = attn + mlp + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab * d


def _scaled(key, shape, dtype, fan_in):
    return (jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)).astype(dtype)


class LMModel:
    def __init__(self, cfg: LMConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ params

    def param_shapes(self) -> dict:
        c = self.cfg
        d, dh, L = c.d_model, c.dh, c.n_layers
        f32, dt = jnp.float32, c.dtype
        sh = {
            "embed": ((c.vocab, d), dt),
            "unembed": ((c.vocab, d), dt),
            "final_norm": ((d,), f32),
            "attn_norm": ((L, d), f32),
            "mlp_norm": ((L, d), f32),
            "wq": ((L, d, c.n_heads * dh), dt),
            "wk": ((L, d, c.n_kv_heads * dh), dt),
            "wv": ((L, d, c.n_kv_heads * dh), dt),
            "wo": ((L, c.n_heads * dh, d), dt),
        }
        if c.post_norms:
            sh["attn_post_norm"] = ((L, d), f32)
            sh["mlp_post_norm"] = ((L, d), f32)
        if c.is_moe:
            sh["router"] = ((L, d, c.moe_experts), f32)
            sh["moe_gate"] = ((L, c.moe_experts, d, c.d_ff), dt)
            sh["moe_up"] = ((L, c.moe_experts, d, c.d_ff), dt)
            sh["moe_down"] = ((L, c.moe_experts, c.d_ff, d), dt)
            if c.n_shared_experts:
                fs = c.n_shared_experts * c.d_ff
                sh["shared_gate"] = ((L, d, fs), dt)
                sh["shared_up"] = ((L, d, fs), dt)
                sh["shared_down"] = ((L, fs, d), dt)
        else:
            sh["w_gate"] = ((L, d, c.d_ff), dt)
            sh["w_up"] = ((L, d, c.d_ff), dt)
            sh["w_down"] = ((L, c.d_ff, d), dt)
        return sh

    def abstract_params(self) -> dict:
        return {
            k: jax.ShapeDtypeStruct(s, dt) for k, (s, dt) in self.param_shapes().items()
        }

    def init_params(self, rng) -> dict:
        c = self.cfg
        out = {}
        keys = jax.random.split(rng, len(self.param_shapes()))
        for k_rng, (name, (shape, dt)) in zip(keys, self.param_shapes().items()):
            if "norm" in name:
                out[name] = jnp.zeros(shape, dt)
            else:
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                out[name] = _scaled(k_rng, shape, dt, fan_in)
        return out

    def param_specs(self, mesh: Mesh) -> dict:
        """FSDP over data axes (input dim) + TP over model axis (output dim)."""
        c = self.cfg
        fs = fsdp_axes(mesh)
        d_ok = lambda dim: divisible_or_none(dim, mesh, fs)  # noqa: E731
        m_ok = lambda dim: ("model" if dim % mesh.shape["model"] == 0 else None)  # noqa: E731
        dh = c.dh
        specs = {
            # embed is GATHERED (not matmul'd): vocab-sharded only — sharding
            # d_model too makes GSPMD's gather partitioning fall back to
            # replication of the output.
            "embed": P(m_ok(c.vocab), None),
            "unembed": P(m_ok(c.vocab), d_ok(c.d_model)),
            "final_norm": P(None),
            "attn_norm": P(None, None),
            "mlp_norm": P(None, None),
            "wq": P(None, d_ok(c.d_model), m_ok(c.n_heads * dh)),
            "wk": P(None, d_ok(c.d_model), m_ok(c.n_kv_heads * dh)),
            "wv": P(None, d_ok(c.d_model), m_ok(c.n_kv_heads * dh)),
            "wo": P(None, m_ok(c.n_heads * dh), d_ok(c.d_model)),
        }
        if c.post_norms:
            specs["attn_post_norm"] = P(None, None)
            specs["mlp_post_norm"] = P(None, None)
        if c.is_moe:
            e_ax = "model" if c.moe_experts % mesh.shape["model"] == 0 else None
            specs["router"] = P(None, d_ok(c.d_model), None)
            specs["moe_gate"] = P(None, e_ax, d_ok(c.d_model), None)
            specs["moe_up"] = P(None, e_ax, d_ok(c.d_model), None)
            specs["moe_down"] = P(None, e_ax, None, d_ok(c.d_model))
            if c.n_shared_experts:
                fs_dim = c.n_shared_experts * c.d_ff
                specs["shared_gate"] = P(None, d_ok(c.d_model), m_ok(fs_dim))
                specs["shared_up"] = P(None, d_ok(c.d_model), m_ok(fs_dim))
                specs["shared_down"] = P(None, m_ok(fs_dim), d_ok(c.d_model))
        else:
            specs["w_gate"] = P(None, d_ok(c.d_model), m_ok(c.d_ff))
            specs["w_up"] = P(None, d_ok(c.d_model), m_ok(c.d_ff))
            specs["w_down"] = P(None, m_ok(c.d_ff), d_ok(c.d_model))
        return specs

    # ------------------------------------------------------------------ layers

    def _layer_params(self, params: dict) -> tuple[dict, list[str]]:
        keys = [k for k in params if params[k].ndim >= 2 and k not in (
            "embed", "unembed") and k != "final_norm"]
        return {k: params[k] for k in keys}, keys

    def _is_local_flags(self) -> jnp.ndarray:
        c = self.cfg
        if c.local_global_alternate:
            return jnp.arange(c.n_layers) % 2 == 0  # even layers local
        return jnp.zeros(c.n_layers, dtype=bool)

    def _block(self, x, lp, is_local, q_pos, kv_pos, k_cache=None, v_cache=None,
               kv_valid=None, cache_slot=None, k_scale=None, v_scale=None):
        """One transformer layer.  Returns (x, new_k, new_v) — where new_k /
        new_v are (values, scales) tuples when the cache is int8-quantised.

        Train/prefill: caches are None — K/V come from this segment.
        Decode: k_cache/v_cache hold the past; new K/V are written at
        ``cache_slot`` (and returned for the scan to re-stack).
        """
        c = self.cfg
        b = x.shape[0]
        dh = c.dh

        if c.unshard_weights_at_use and c.batch_axes is not None:
            unshard = {
                "wq": P(None, "model"), "wk": P(None, "model"),
                "wv": P(None, "model"), "wo": P("model", None),
                "w_gate": P(None, "model"), "w_up": P(None, "model"),
                "w_down": P("model", None),
                "moe_gate": P("model", None, None),
                "moe_up": P("model", None, None),
                "moe_down": P("model", None, None),
                "shared_gate": P(None, "model"),
                "shared_up": P(None, "model"),
                "shared_down": P("model", None),
                "router": P(None, None),
            }
            lp = {
                k: (maybe_constrain(v, unshard[k]) if k in unshard else v)
                for k, v in lp.items()
            }

        h = layers.rms_norm(x, lp["attn_norm"])
        q = (h @ lp["wq"]).reshape(b, -1, c.n_heads, dh)
        k = (h @ lp["wk"]).reshape(b, -1, c.n_kv_heads, dh)
        v = (h @ lp["wv"]).reshape(b, -1, c.n_kv_heads, dh)
        q = layers.rope(q, q_pos, c.rope_theta)
        k = layers.rope(k, q_pos, c.rope_theta)

        quantized = k_cache is not None and k_cache.dtype == jnp.int8
        if quantized:
            kq_new, ks_new = layers.quantize_kv(k)
            vq_new, vs_new = layers.quantize_kv(v)
            dus = jax.lax.dynamic_update_slice_in_dim
            nk = dus(k_cache, kq_new, cache_slot, axis=1)
            nks = dus(k_scale, ks_new, cache_slot, axis=1)
            nv = dus(v_cache, vq_new, cache_slot, axis=1)
            nvs = dus(v_scale, vs_new, cache_slot, axis=1)
            new_k, new_v = (nk, nks), (nv, nvs)
            att_k, att_v, att_kv_pos, att_valid = nk, nv, kv_pos, kv_valid
        elif k_cache is not None:
            new_k = jax.lax.dynamic_update_slice_in_dim(
                k_cache, k.astype(k_cache.dtype), cache_slot, axis=1
            )
            new_v = jax.lax.dynamic_update_slice_in_dim(
                v_cache, v.astype(v_cache.dtype), cache_slot, axis=1
            )
            att_k, att_v, att_kv_pos, att_valid = new_k, new_v, kv_pos, kv_valid
        else:
            new_k, new_v = k, v  # prefill: the segment IS the cache content
            att_k, att_v, att_kv_pos, att_valid = k, v, kv_pos, None

        if c.local_global_alternate and c.sliding_window:
            # per-layer traced window: local layers use the sliding window,
            # global layers an effectively-infinite one (single attention
            # call — the mask comparison broadcasts the traced scalar).
            eff_window = jnp.where(
                is_local, jnp.int32(c.sliding_window), jnp.int32(2**30)
            )
        else:
            eff_window = c.sliding_window
        if quantized:
            o = layers.gqa_attention_quantized(
                q, new_k[0], new_k[1], new_v[0], new_v[1],
                q_pos, att_kv_pos, att_valid,
                window=eff_window, attn_softcap=c.attn_softcap,
            )
        elif c.attn_q_chunk:
            o = layers.gqa_attention_qchunked(
                q, att_k, att_v, q_pos, att_kv_pos, att_valid,
                window=eff_window, attn_softcap=c.attn_softcap,
                chunk=c.attn_q_chunk,
            )
        else:
            o = layers.gqa_attention(
                q, att_k, att_v, q_pos, att_kv_pos, att_valid,
                window=eff_window, attn_softcap=c.attn_softcap,
            )
        o = o.reshape(b, -1, c.n_heads * dh) @ lp["wo"]
        if c.post_norms:
            o = layers.rms_norm(o, lp["attn_post_norm"])
        x = x + o

        h = layers.rms_norm(x, lp["mlp_norm"])
        if c.is_moe:
            cap = self._capacity(h.shape[0] * h.shape[1])
            mo, _aux = layers.moe_block(
                h, lp["router"], lp["moe_gate"], lp["moe_up"], lp["moe_down"],
                layers.MoEDims(
                    c.moe_experts, c.moe_top_k, cap, c.expert_axis,
                    token_axes=c.batch_axes,
                ),
            )
            if c.n_shared_experts:
                mo = mo + layers.swiglu(
                    h, lp["shared_gate"], lp["shared_up"], lp["shared_down"]
                )
        else:
            mo = layers.swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
        if c.post_norms:
            mo = layers.rms_norm(mo, lp["mlp_post_norm"])
        return x + mo, new_k, new_v

    def _capacity(self, n_tokens: int) -> int:
        c = self.cfg
        per = n_tokens * c.moe_top_k / c.moe_experts
        cap = int(math.ceil(per * c.moe_capacity_factor))
        cap = max(8, min(cap, n_tokens))
        if cap >= 64:
            cap = -(-cap // 64) * 64  # data-axis-shardable capacity dim
        return cap

    # ----------------------------------------------------------------- forward

    def _constrain_resid(self, x):
        """Pin the residual stream's sharding.  Without this, GSPMD's gather
        partitioning of the embedding lookup replicates the whole stream.
        seq_shard_activations additionally spreads the sequence dim over the
        model axis (sequence parallelism: stash memory / norm work /16)."""
        c = self.cfg
        if c.batch_axes is None:
            return x
        if c.seq_shard_activations:
            return maybe_constrain(x, P(tuple(c.batch_axes), "model", None))
        return maybe_constrain(x, P(tuple(c.batch_axes), None, None))

    def forward(self, params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
        """Teacher-forced logits: tokens (B, S) -> (B, S, vocab)."""
        c = self.cfg
        b, s = tokens.shape
        x = self._constrain_resid(params["embed"][tokens].astype(c.dtype))
        if c.scale_embed:
            x = x * math.sqrt(c.d_model)
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        lp_all, keys = self._layer_params(params)
        is_local = self._is_local_flags()

        def body(x, scanned):
            lp, loc = scanned
            y, _, _ = self._block(x, lp, loc, pos, pos)
            return self._constrain_resid(y), None

        if c.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        if c.unroll_layers:
            for i in range(c.n_layers):
                x, _ = body(x, (jax.tree.map(lambda a: a[i], lp_all), is_local[i]))
        else:
            x, _ = jax.lax.scan(body, x, (lp_all, is_local))
        x = layers.rms_norm(x, params["final_norm"])
        logits = x.astype(jnp.float32) @ params["unembed"].T.astype(jnp.float32)
        return layers.softcap(logits, c.final_softcap)

    def prefill(self, params: dict, tokens: jnp.ndarray,
                chunk: int | None = None) -> tuple[jnp.ndarray, dict]:
        """Prefill: run the full prompt, return (last-token logits (B, vocab),
        KV cache (L, B, S, KV, Dh)).  Only the final position's logits are
        computed — materialising (B, S, vocab) at 32K context is pure waste.

        ``chunk``: Sarathi-style chunked prefill — an outer scan feeds
        ``chunk``-token segments through the whole stack, growing the cache
        as the carry.  Bounds live activations (and the MoE dispatch buffer)
        to one segment; mandatory at MoE-trillion scale.
        """
        c = self.cfg
        b, s = tokens.shape
        x = self._constrain_resid(params["embed"][tokens].astype(c.dtype))
        if c.scale_embed:
            x = x * math.sqrt(c.d_model)
        lp_all, _ = self._layer_params(params)
        is_local = self._is_local_flags()

        if chunk and s > chunk and s % chunk == 0:
            nseg = s // chunk
            xs = x.reshape(b, nseg, chunk, c.d_model).transpose(1, 0, 2, 3)
            kv_pos = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None, :], (b, s)
            )
            cache0 = {
                "k": jnp.zeros((c.n_layers, b, s, c.n_kv_heads, self.dh_pad()),
                               c.dtype),
                "v": jnp.zeros((c.n_layers, b, s, c.n_kv_heads, self.dh_pad()),
                               c.dtype),
            }
            cache0 = jax.tree.map(self._constrain_cache, cache0)

            def seg_body(cache, seg):
                xi, seg_idx = seg
                offset = seg_idx * chunk
                q_pos = offset + jnp.broadcast_to(
                    jnp.arange(chunk, dtype=jnp.int32)[None, :], (b, chunk)
                )

                def layer_body(xc, scanned):
                    lp, loc, kc, vc = scanned
                    y, nk, nv = self._block(
                        xc, lp, loc, q_pos, kv_pos,
                        k_cache=kc, v_cache=vc, kv_valid=None,
                        cache_slot=offset,
                    )
                    return self._constrain_resid(y), (nk, nv)

                xi, (nk, nv) = jax.lax.scan(
                    layer_body, xi, (lp_all, is_local, cache["k"], cache["v"])
                )
                nk = self._constrain_cache(nk)
                nv = self._constrain_cache(nv)
                return {"k": nk, "v": nv}, xi[:, -1:]

            cache, last_h = jax.lax.scan(
                seg_body, cache0, (xs, jnp.arange(nseg, dtype=jnp.int32))
            )
            x_last = last_h[-1]
        else:
            pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

            def body(x, scanned):
                lp, loc = scanned
                y, k, v = self._block(x, lp, loc, pos, pos)
                return self._constrain_resid(y), (k, v)

            if c.remat:
                body = jax.checkpoint(body, prevent_cse=False)
            if c.unroll_layers:
                kvs = []
                for i in range(c.n_layers):
                    x, kv = body(
                        x, (jax.tree.map(lambda a: a[i], lp_all), is_local[i])
                    )
                    kvs.append(kv)
                ks = jnp.stack([k for k, _ in kvs])
                vs = jnp.stack([v for _, v in kvs])
            else:
                x, (ks, vs) = jax.lax.scan(body, x, (lp_all, is_local))
            cache = {"k": ks, "v": vs}
            x_last = x[:, -1:]

        x_last = layers.rms_norm(x_last, params["final_norm"])
        logits = x_last[:, 0].astype(jnp.float32) @ params["unembed"].T.astype(
            jnp.float32
        )
        return layers.softcap(logits, c.final_softcap), cache

    def dh_pad(self) -> int:
        return self.cfg.dh

    def _constrain_cache(self, kv):
        c = self.cfg
        if c.batch_axes is None:
            return kv
        # (L, B, S, KV, Dh) or (B, S, KV, Dh): seq-shard over model
        lead = (None,) if kv.ndim == 5 else ()
        return maybe_constrain(
            kv, P(*lead, tuple(c.batch_axes), "model", None, None)
        )

    def loss_fn(self, params: dict, batch: dict) -> jnp.ndarray:
        """batch: tokens (B, S+1) int32.  Mean next-token cross-entropy."""
        tokens = batch["tokens"]
        inp, tgt = tokens[:, :-1], tokens[:, 1:]
        logits = self.forward(params, inp)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - gold)

    # ------------------------------------------------------------------ decode

    def init_cache_shapes(self, batch: int, max_seq: int) -> dict:
        c = self.cfg
        shape = (c.n_layers, batch, max_seq, c.n_kv_heads, c.dh)
        if c.kv_cache_dtype == "int8":
            sshape = (c.n_layers, batch, max_seq, c.n_kv_heads)
            return {
                "k": jax.ShapeDtypeStruct(shape, jnp.int8),
                "v": jax.ShapeDtypeStruct(shape, jnp.int8),
                "k_scale": jax.ShapeDtypeStruct(sshape, jnp.float32),
                "v_scale": jax.ShapeDtypeStruct(sshape, jnp.float32),
            }
        return {
            "k": jax.ShapeDtypeStruct(shape, c.dtype),
            "v": jax.ShapeDtypeStruct(shape, c.dtype),
        }

    def cache_specs(self, mesh: Mesh, batch: int) -> dict:
        dp = dp_axes(mesh)
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        bdim = dp if batch % dp_size == 0 else None  # batch=1: replicate
        spec = P(None, bdim, "model", None, None)  # seq-sharded KV
        out = {"k": spec, "v": spec}
        if self.cfg.kv_cache_dtype == "int8":
            out["k_scale"] = P(None, bdim, "model", None)
            out["v_scale"] = P(None, bdim, "model", None)
        return out

    def decode_step(self, params: dict, cache: dict, token: jnp.ndarray,
                    pos: jnp.ndarray) -> tuple[jnp.ndarray, dict]:
        """One-token decode: token (B, 1) int32, pos scalar int32 (current
        length).  Returns (logits (B, vocab), new cache)."""
        c = self.cfg
        b = token.shape[0]
        max_seq = cache["k"].shape[2]
        x = params["embed"][token].astype(c.dtype)
        if c.batch_axes is not None:
            x = maybe_constrain(x, P(tuple(c.batch_axes), None, None))
        if c.scale_embed:
            x = x * math.sqrt(c.d_model)
        q_pos = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
        kv_pos = jnp.broadcast_to(
            jnp.arange(max_seq, dtype=jnp.int32)[None, :], (b, max_seq)
        )
        kv_valid = kv_pos <= pos  # includes the slot being written
        lp_all, _ = self._layer_params(params)
        is_local = self._is_local_flags()

        quantized = cache["k"].dtype == jnp.int8

        def body(x, scanned):
            lp, loc, kc, vc, ks, vs = scanned
            y, nk, nv = self._block(
                x, lp, loc, q_pos, kv_pos,
                k_cache=kc, v_cache=vc, kv_valid=kv_valid, cache_slot=pos,
                k_scale=ks, v_scale=vs,
            )
            return y, (nk, nv)

        dummy = (
            jnp.zeros((c.n_layers, b, 0), jnp.float32)
            if not quantized else None
        )
        scales = (
            (cache["k_scale"], cache["v_scale"]) if quantized
            else (dummy, dummy)
        )
        if c.unroll_layers:
            nks, nvs = [], []
            for i in range(c.n_layers):
                x, (k_i, v_i) = body(
                    x,
                    (jax.tree.map(lambda a: a[i], lp_all), is_local[i],
                     cache["k"][i], cache["v"][i],
                     scales[0][i], scales[1][i]),
                )
                nks.append(k_i)
                nvs.append(v_i)
            nk = jax.tree.map(lambda *xs: jnp.stack(xs), *nks)
            nv = jax.tree.map(lambda *xs: jnp.stack(xs), *nvs)
        else:
            x, (nk, nv) = jax.lax.scan(
                body, x,
                (lp_all, is_local, cache["k"], cache["v"], scales[0], scales[1]),
            )
        x = layers.rms_norm(x, params["final_norm"])
        logits = x[:, 0].astype(jnp.float32) @ params["unembed"].T.astype(jnp.float32)
        logits = layers.softcap(logits, c.final_softcap)
        if quantized:
            new_cache = {"k": nk[0], "k_scale": nk[1],
                         "v": nv[0], "v_scale": nv[1]}
        else:
            new_cache = {"k": nk, "v": nv}
        return logits, new_cache



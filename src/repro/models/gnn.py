"""PNA (Principal Neighbourhood Aggregation, arXiv:2004.05718) in pure JAX.

Message passing is built on ``jax.ops.segment_sum``-family ops over an
edge-index (src, dst) representation — JAX has no sparse SpMM worth using
here (BCOO only), so the scatter/gather machinery IS part of the system.

Aggregators: mean / max / min / std; scalers: identity / amplification /
attenuation (log-degree, normalised by the train-set average log-degree).
Update: h' = U([h || concat(scaled aggregations)]).

Supports: full-graph node classification, sampled-subgraph training (the
neighbour sampler lives in repro.data.graphs), and batched small graphs with
graph-level readout (``graph_id`` segment mean + classifier).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["PNAConfig", "PNAModel"]

_AGGS = ("mean", "max", "min", "std")
_SCALERS = ("identity", "amplification", "attenuation")


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str
    n_layers: int
    d_hidden: int
    d_feat: int
    n_classes: int
    avg_log_deg: float = 3.0
    graph_level: bool = False
    dtype: Any = jnp.float32
    optimizer: str = "adamw"
    microbatches: int = 1
    batch_axes: tuple | None = None  # mesh axes for node/edge row sharding


class PNAModel:
    def __init__(self, cfg: PNAConfig):
        self.cfg = cfg

    def param_shapes(self) -> dict:
        c = self.cfg
        d = c.d_hidden
        n_mix = len(_AGGS) * len(_SCALERS)  # 12
        sh = {
            "w_in": ((c.d_feat, d), c.dtype),
            "w_msg": ((c.n_layers, 2 * d, d), c.dtype),
            "b_msg": ((c.n_layers, d), c.dtype),
            "w_upd": ((c.n_layers, (1 + n_mix) * d, d), c.dtype),
            "b_upd": ((c.n_layers, d), c.dtype),
            "w_out": ((d, c.n_classes), c.dtype),
        }
        return sh

    def abstract_params(self) -> dict:
        return {
            k: jax.ShapeDtypeStruct(s, dt) for k, (s, dt) in self.param_shapes().items()
        }

    def init_params(self, rng) -> dict:
        out = {}
        for key, (name, (shape, dt)) in zip(
            jax.random.split(rng, len(self.param_shapes())),
            self.param_shapes().items(),
        ):
            if name.startswith("b_"):
                out[name] = jnp.zeros(shape, dt)
            else:
                out[name] = (
                    jax.random.normal(key, shape, jnp.float32)
                    / math.sqrt(shape[-2] if len(shape) > 1 else shape[-1])
                ).astype(dt)
        return out

    def param_specs(self, mesh: Mesh) -> dict:
        # GNN weights are tiny (75-wide): replicate; the sharded objects are
        # the node/edge arrays (see input specs in configs/).
        return {k: P(*([None] * len(s))) for k, (s, _) in self.param_shapes().items()}

    # ----------------------------------------------------------------- forward

    def forward(self, params: dict, batch: dict) -> jnp.ndarray:
        """Two edge layouts:

        FLAT (CPU/smoke): edge_src (E,), edge_dst (E,) global ids.

        DST-PARTITIONED (production, DistDGL-style): edges presorted by
        destination and packed per node-block — edge_src (S, E_loc) global
        ids, edge_dst_local (S, E_loc) ids local to block s, edge_valid
        (S, E_loc).  The segment reduction becomes a vmap over the S
        (sharded) block dim, so GSPMD partitions the scatter trivially —
        without this, scatter output is REPLICATED per device (2.4M x 75
        fp32 x ~10 live tensors at ogb_products scale).

        Returns node logits (N, C) or graph logits (G, C)."""
        if "edge_valid" in batch:
            return self._forward_partitioned(params, batch)
        return self._forward_flat(params, batch)

    def _forward_flat(self, params: dict, batch: dict) -> jnp.ndarray:
        c = self.cfg
        x = batch["x"].astype(c.dtype)
        src, dst = batch["edge_src"], batch["edge_dst"]
        n = x.shape[0]

        def _rows_early(t):
            if c.batch_axes is None:
                return t
            from repro.parallel.sharding import maybe_constrain

            return maybe_constrain(
                t, P(tuple(c.batch_axes), *([None] * (t.ndim - 1)))
            )

        deg = _rows_early(
            jax.ops.segment_sum(jnp.ones_like(dst, c.dtype), dst, num_segments=n)
        )
        logd = jnp.log1p(deg)[:, None]  # (N, 1)
        s_amp = logd / c.avg_log_deg
        s_att = c.avg_log_deg / jnp.maximum(logd, 1e-2)

        def _rows(t):
            # pin row sharding: GSPMD gather partitioning otherwise
            # replicates h[src]/h[dst] on every device (61M x 75 floats)
            if c.batch_axes is None:
                return t
            from repro.parallel.sharding import maybe_constrain

            return maybe_constrain(
                t, P(tuple(c.batch_axes), *([None] * (t.ndim - 1)))
            )

        h = _rows(x @ params["w_in"])
        for layer in range(c.n_layers):
            m_in = jnp.concatenate([_rows(h[src]), _rows(h[dst])], axis=-1)
            m = jax.nn.relu(
                m_in @ params["w_msg"][layer] + params["b_msg"][layer]
            )  # (E, d)
            # every segment-op OUTPUT is row-constrained: GSPMD's scatter
            # partitioning otherwise REPLICATES the (N, d) aggregation on
            # every device (2.4M x 75 fp32 x ~10 live tensors at products
            # scale — the dominant memory term before this constraint)
            s_sum = _rows(jax.ops.segment_sum(m, dst, num_segments=n))
            s_cnt = jnp.maximum(deg[:, None], 1.0)
            a_mean = s_sum / s_cnt
            a_max = _rows(jax.ops.segment_max(m, dst, num_segments=n))
            a_min = _rows(jax.ops.segment_min(m, dst, num_segments=n))
            # empty segments: segment_max/min return -inf/+inf fillers
            a_max = jnp.where(jnp.isfinite(a_max), a_max, 0.0)
            a_min = jnp.where(jnp.isfinite(a_min), a_min, 0.0)
            sq = _rows(jax.ops.segment_sum(m * m, dst, num_segments=n))
            # +eps inside sqrt: d/dx sqrt(x) -> inf at x=0 (deg<=1 nodes have
            # exactly zero variance, which NaNs the backward pass otherwise)
            a_std = jnp.sqrt(jnp.maximum(sq / s_cnt - a_mean**2, 0.0) + 1e-6)
            aggs = [a_mean, a_max, a_min, a_std]
            mixed = [h] + [a * s for a in aggs for s in (1.0, s_amp, s_att)]
            z = jnp.concatenate(mixed, axis=-1)  # (N, 13d)
            h = _rows(
                jax.nn.relu(z @ params["w_upd"][layer] + params["b_upd"][layer]) + h
            )

        if c.graph_level:
            gid = batch["graph_id"]
            g = batch["labels"].shape[0]
            pooled = jax.ops.segment_sum(h, gid, num_segments=g)
            cnt = jax.ops.segment_sum(jnp.ones((n, 1), c.dtype), gid, num_segments=g)
            h = pooled / jnp.maximum(cnt, 1.0)
        return (h @ params["w_out"]).astype(jnp.float32)

    def _forward_partitioned(self, params: dict, batch: dict) -> jnp.ndarray:
        c = self.cfg
        x = batch["x"].astype(c.dtype)          # (N, F) row-sharded
        src = batch["edge_src"]                  # (S, E_loc) global ids
        dstl = batch["edge_dst_local"]           # (S, E_loc) block-local ids
        valid = batch["edge_valid"]              # (S, E_loc)
        n = x.shape[0]
        s_blocks, e_loc = src.shape
        n_loc = n // s_blocks
        vmask = valid.astype(c.dtype)[..., None]  # (S, E_loc, 1)

        def _rows(t):
            if c.batch_axes is None:
                return t
            from repro.parallel.sharding import maybe_constrain

            return maybe_constrain(
                t, P(tuple(c.batch_axes), *([None] * (t.ndim - 1)))
            )

        ones = (valid.astype(c.dtype)).reshape(s_blocks, e_loc)
        deg = jax.vmap(
            lambda w, d: jax.ops.segment_sum(w, d, num_segments=n_loc)
        )(ones, dstl).reshape(n)
        deg = _rows(deg)
        logd = jnp.log1p(deg)[:, None]
        s_amp = logd / c.avg_log_deg
        s_att = c.avg_log_deg / jnp.maximum(logd, 1e-2)

        def seg(op, vals):
            out = jax.vmap(
                lambda v, d: op(v, d, num_segments=n_loc)
            )(vals, dstl)
            return _rows(out.reshape(n, -1))

        h = _rows(x @ params["w_in"])
        for layer in range(c.n_layers):
            hs = _rows(h[src])                   # (S, E_loc, d) halo gather
            hd = _rows(h[dstl + (jnp.arange(s_blocks) * n_loc)[:, None]])
            m_in = jnp.concatenate([hs, hd], axis=-1)
            m = jax.nn.relu(
                m_in @ params["w_msg"][layer] + params["b_msg"][layer]
            ) * vmask                            # padded edges contribute 0
            s_cnt = jnp.maximum(deg[:, None], 1.0)
            s_sum = seg(jax.ops.segment_sum, m)
            a_mean = s_sum / s_cnt
            a_max = seg(jax.ops.segment_max, jnp.where(vmask > 0, m, -jnp.inf))
            a_min = seg(jax.ops.segment_min, jnp.where(vmask > 0, m, jnp.inf))
            a_max = jnp.where(jnp.isfinite(a_max), a_max, 0.0)
            a_min = jnp.where(jnp.isfinite(a_min), a_min, 0.0)
            sq = seg(jax.ops.segment_sum, m * m)
            a_std = jnp.sqrt(jnp.maximum(sq / s_cnt - a_mean**2, 0.0) + 1e-6)
            mixed = [h] + [
                a * s for a in (a_mean, a_max, a_min, a_std)
                for s in (1.0, s_amp, s_att)
            ]
            z = jnp.concatenate(mixed, axis=-1)
            h = _rows(
                jax.nn.relu(z @ params["w_upd"][layer] + params["b_upd"][layer]) + h
            )

        if c.graph_level:
            gid = batch["graph_id"]
            g = batch["labels"].shape[0]
            pooled = jax.ops.segment_sum(h, gid, num_segments=g)
            cnt = jax.ops.segment_sum(
                jnp.ones((n, 1), c.dtype), gid, num_segments=g
            )
            h = pooled / jnp.maximum(cnt, 1.0)
        return (h @ params["w_out"]).astype(jnp.float32)

    @staticmethod
    def partition_edges(src, dst, n_pad: int, s_blocks: int = 512,
                        e_loc: int | None = None):
        """Host-side converter: flat edge list -> dst-partitioned layout.

        Sorts edges by destination block, packs each block's edges into a
        fixed-width row (padding with invalid edges).  Production graph
        loaders emit this directly (one block per node shard)."""
        import numpy as np

        src = np.asarray(src)
        dst = np.asarray(dst)
        n_loc = n_pad // s_blocks
        block = dst // n_loc
        order = np.argsort(block, kind="stable")
        src, dst, block = src[order], dst[order], block[order]
        counts = np.bincount(block, minlength=s_blocks)
        if e_loc is None:
            e_loc = max(1, int(counts.max()))
        out_src = np.zeros((s_blocks, e_loc), np.int32)
        out_dstl = np.zeros((s_blocks, e_loc), np.int32)
        out_valid = np.zeros((s_blocks, e_loc), bool)
        starts = np.concatenate([[0], np.cumsum(counts)])
        for b in range(s_blocks):
            take = min(int(counts[b]), e_loc)  # overflow edges dropped
            sl = slice(starts[b], starts[b] + take)
            out_src[b, :take] = src[sl]
            out_dstl[b, :take] = dst[sl] - b * n_loc
            out_valid[b, :take] = True
        return out_src, out_dstl, out_valid

    def loss_fn(self, params: dict, batch: dict) -> jnp.ndarray:
        logits = self.forward(params, batch)
        labels = batch["labels"]
        mask = batch.get("label_mask")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        nll = logz - gold
        if mask is not None:
            return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.mean(nll)

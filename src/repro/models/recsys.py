"""RecSys architectures: Wide&Deep, DIN, two-tower retrieval, DLRM-RM2.

The hot path is the sparse embedding lookup.  JAX has no native EmbeddingBag
— we build it from gather (+ ``segment_sum`` for multi-hot bags) as a
first-class substrate.  Tables are stacked (T, V, D) and row-sharded over the
"model" mesh axis; lookups against sharded tables become partial-gather +
cross-shard combine under GSPMD.

The two-tower model is the paper-integration point: its item tower fills the
corpus that the supermetric BSS index (repro.core.flat_index) serves exactly
(`retrieval_cand` cell = 1M-candidate scoring).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = [
    "RecsysConfig",
    "WideDeepModel",
    "DINModel",
    "TwoTowerModel",
    "DLRMModel",
]


def embedding_lookup(tables: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """tables (T, V, D), idx (B, T) one id per field -> (B, T, D)."""
    t = tables.shape[0]
    return tables[jnp.arange(t)[None, :], idx]


def embedding_bag(
    table: jnp.ndarray, idx: jnp.ndarray, valid: jnp.ndarray | None = None,
    combine: str = "mean",
) -> jnp.ndarray:
    """table (V, D), idx (B, L) multi-hot bag -> (B, D).  Manual EmbeddingBag:
    gather + masked reduce (the JAX-native formulation of nn.EmbeddingBag)."""
    e = table[idx]  # (B, L, D)
    if valid is not None:
        e = e * valid[..., None].astype(e.dtype)
        denom = jnp.maximum(valid.sum(axis=-1, keepdims=True), 1.0).astype(e.dtype)
    else:
        denom = jnp.asarray(idx.shape[1], e.dtype)
    s = e.sum(axis=1)
    return s / denom if combine == "mean" else s


def _mlp_shapes(dims: Sequence[int], dtype) -> list:
    return [((dims[i], dims[i + 1]), dtype) for i in range(len(dims) - 1)]


def _mlp_apply(x, ws, bs, final_act=False):
    for i, (w, b) in enumerate(zip(ws, bs)):
        x = x @ w + b
        if i < len(ws) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def _constrain_rows(x, batch_axes):
    """Pin the batch/row sharding of an embedding-lookup output; GSPMD's
    gather partitioning otherwise replicates it (see transformer.py note)."""
    if batch_axes is None:
        return x
    from repro.parallel.sharding import maybe_constrain

    return maybe_constrain(
        x, P(tuple(batch_axes), *([None] * (x.ndim - 1)))
    )


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                      # wide_deep | din | two_tower | dlrm
    n_sparse: int = 26
    n_dense: int = 0
    embed_dim: int = 64
    vocab: int = 1_000_000         # rows per table (assignment leaves this
                                   # open; kernel-taxonomy D.6 regime 10^6)
    mlp: tuple = (1024, 512, 256)
    bot_mlp: tuple = ()
    attn_mlp: tuple = (80, 40)
    hist_len: int = 100
    tower_mlp: tuple = (1024, 512, 256)
    n_user_fields: int = 8
    n_item_fields: int = 4
    dtype: Any = jnp.bfloat16
    optimizer: str = "adamw"
    microbatches: int = 1
    batch_axes: tuple | None = None


class _RecsysBase:
    def __init__(self, cfg: RecsysConfig):
        self.cfg = cfg

    def abstract_params(self) -> dict:
        def to_sds(x):
            return jax.ShapeDtypeStruct(x[0], x[1])

        return jax.tree.map(
            to_sds, self.param_shapes(), is_leaf=lambda v: isinstance(v, tuple)
            and len(v) == 2 and isinstance(v[0], tuple)
        )

    def init_params(self, rng) -> dict:
        flat = jax.tree.leaves(
            self.param_shapes(),
            is_leaf=lambda v: isinstance(v, tuple) and len(v) == 2
            and isinstance(v[0], tuple),
        )
        treedef = jax.tree.structure(
            self.param_shapes(),
            is_leaf=lambda v: isinstance(v, tuple) and len(v) == 2
            and isinstance(v[0], tuple),
        )
        keys = jax.random.split(rng, len(flat))
        leaves = []
        for k, (shape, dt) in zip(keys, flat):
            fan = shape[-2] if len(shape) > 1 else shape[-1]
            leaves.append(
                (jax.random.normal(k, shape, jnp.float32) / math.sqrt(fan)).astype(dt)
            )
        return jax.tree.unflatten(treedef, leaves)

    def param_specs(self, mesh: Mesh) -> dict:
        def spec(v):
            shape, _ = v
            if len(shape) == 3:  # stacked tables (T, V, D): rows over model
                return P(None, "model", None)
            if len(shape) == 2 and shape[0] >= 65536:  # big single table
                return P("model", None)
            return P(*([None] * len(shape)))

        return jax.tree.map(
            spec, self.param_shapes(), is_leaf=lambda v: isinstance(v, tuple)
            and len(v) == 2 and isinstance(v[0], tuple)
        )


class WideDeepModel(_RecsysBase):
    """Wide&Deep (arXiv:1606.07792): wide hashed-linear + deep MLP on field
    embeddings, summed logits."""

    def param_shapes(self) -> dict:
        c = self.cfg
        deep_in = c.n_sparse * c.embed_dim
        dims = (deep_in,) + tuple(c.mlp) + (1,)
        return {
            "tables": ((c.n_sparse, c.vocab, c.embed_dim), c.dtype),
            "wide": ((c.vocab, 1), c.dtype),
            "mlp_w": _mlp_shapes(dims, c.dtype),
            "mlp_b": [((d,), c.dtype) for d in dims[1:]],
        }

    def forward(self, params: dict, batch: dict) -> jnp.ndarray:
        c = self.cfg
        idx = batch["sparse_ids"]  # (B, T)
        emb = _constrain_rows(embedding_lookup(params["tables"], idx), c.batch_axes)
        deep = _mlp_apply(
            emb.reshape(emb.shape[0], -1), params["mlp_w"], params["mlp_b"]
        )
        wide = params["wide"][idx % c.vocab][..., 0].sum(axis=-1, keepdims=True)
        return (deep + wide).astype(jnp.float32)[:, 0]


class DINModel(_RecsysBase):
    """Deep Interest Network (arXiv:1706.06978): target attention over the
    user behaviour sequence."""

    def param_shapes(self) -> dict:
        c = self.cfg
        d = c.embed_dim
        attn_dims = (4 * d,) + tuple(c.attn_mlp) + (1,)
        mlp_dims = (2 * d,) + tuple(c.mlp) + (1,)
        return {
            "item_table": ((c.vocab, d), c.dtype),
            "attn_w": _mlp_shapes(attn_dims, c.dtype),
            "attn_b": [((x,), c.dtype) for x in attn_dims[1:]],
            "mlp_w": _mlp_shapes(mlp_dims, c.dtype),
            "mlp_b": [((x,), c.dtype) for x in mlp_dims[1:]],
        }

    def forward(self, params: dict, batch: dict) -> jnp.ndarray:
        hist = batch["hist_ids"]        # (B, L)
        target = batch["target_id"]     # (B,)
        valid = batch.get("hist_valid")  # (B, L) bool
        eh = _constrain_rows(params["item_table"][hist], self.cfg.batch_axes)
        et = _constrain_rows(
            params["item_table"][target], self.cfg.batch_axes
        )[:, None, :]
        etb = jnp.broadcast_to(et, eh.shape)
        a_in = jnp.concatenate([eh, etb, eh - etb, eh * etb], axis=-1)
        w = _mlp_apply(a_in, params["attn_w"], params["attn_b"])[..., 0]  # (B, L)
        if valid is not None:
            w = jnp.where(valid, w, -1e9)
        w = jax.nn.softmax(w.astype(jnp.float32), axis=-1).astype(eh.dtype)
        user = jnp.einsum("bl,bld->bd", w, eh)
        z = jnp.concatenate([user, et[:, 0]], axis=-1)
        return _mlp_apply(z, params["mlp_w"], params["mlp_b"]).astype(jnp.float32)[:, 0]


class TwoTowerModel(_RecsysBase):
    """Two-tower retrieval (Yi et al., RecSys'19): user/item towers -> dot;
    trained with in-batch sampled softmax (logQ-free synthetic variant)."""

    def param_shapes(self) -> dict:
        c = self.cfg
        d_emb = 64  # per-field embedding feeding the towers
        u_in = c.n_user_fields * d_emb
        i_in = c.n_item_fields * d_emb
        u_dims = (u_in,) + tuple(c.tower_mlp) + (c.embed_dim,)
        i_dims = (i_in,) + tuple(c.tower_mlp) + (c.embed_dim,)
        return {
            "user_tables": ((c.n_user_fields, c.vocab, d_emb), c.dtype),
            "item_tables": ((c.n_item_fields, c.vocab, d_emb), c.dtype),
            "user_w": _mlp_shapes(u_dims, c.dtype),
            "user_b": [((x,), c.dtype) for x in u_dims[1:]],
            "item_w": _mlp_shapes(i_dims, c.dtype),
            "item_b": [((x,), c.dtype) for x in i_dims[1:]],
        }

    def user_embed(self, params, user_ids):
        e = _constrain_rows(
            embedding_lookup(params["user_tables"], user_ids), self.cfg.batch_axes
        )
        z = _mlp_apply(e.reshape(e.shape[0], -1), params["user_w"], params["user_b"])
        return z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-6)

    def item_embed(self, params, item_ids):
        e = _constrain_rows(
            embedding_lookup(params["item_tables"], item_ids), self.cfg.batch_axes
        )
        z = _mlp_apply(e.reshape(e.shape[0], -1), params["item_w"], params["item_b"])
        return z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-6)

    def forward(self, params: dict, batch: dict) -> jnp.ndarray:
        if "candidates" in batch:  # retrieval scoring: precomputed item matrix
            u = self.user_embed(params, batch["user_ids"])  # (B, E)
            return (u.astype(jnp.float32) @ batch["candidates"].astype(jnp.float32).T)
        u = self.user_embed(params, batch["user_ids"])
        i = self.item_embed(params, batch["item_ids"])
        return (u.astype(jnp.float32) @ i.astype(jnp.float32).T) * 20.0  # temp

    def loss_fn(self, params: dict, batch: dict) -> jnp.ndarray:
        logits = self.forward(params, batch)  # (B, B) in-batch softmax
        labels = jnp.arange(logits.shape[0])
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    def forward_retrieval_pruned(
        self, params: dict, batch: dict, *, block: int = 128,
        budget_blocks: int = 3136,
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Supermetric-pruned candidate scoring (the paper's technique in the
        serving graph).  batch adds the BSS index arrays:
            pivots (P, E) fp32, pair_idx (M, 2) i32, deltas (M,) fp32,
            boxes (B_blocks, M, 4) fp32.
        Only the ``budget_blocks`` blocks with the smallest planar lower
        bound are gathered and scored — candidate HBM reads drop by
        B/budget (2.5x at the default).  Exact for any top-k whose k-th
        distance exceeds the (budget+1)-th block bound (serving layer
        verifies and widens, see serve/retrieval.py).

        Returns (scores (Q, budget*block), candidate row indices)."""
        u = self.user_embed(params, batch["user_ids"])  # (Q, E) normalised
        cand = batch["candidates"]
        piv = batch["pivots"]
        pairs = batch["pair_idx"]
        deltas = batch["deltas"]
        boxes = batch["boxes"]  # (B_blocks, M, 4)
        n, e_dim = cand.shape
        b_blocks = boxes.shape[0]
        n_pad = b_blocks * block

        uf = u.astype(jnp.float32)
        dq = jnp.sqrt(jnp.maximum(
            jnp.sum(uf * uf, -1)[:, None]
            + jnp.sum(piv * piv, -1)[None, :]
            - 2.0 * uf @ piv.T, 0.0,
        ))  # (Q, P)
        d1 = dq[:, pairs[:, 0]]
        d2 = dq[:, pairs[:, 1]]
        delta = jnp.maximum(deltas[None, :], 1e-12)
        qx = (d1 * d1 - d2 * d2) / (2.0 * delta)
        qy = jnp.sqrt(jnp.maximum(d1 * d1 - (qx + delta / 2.0) ** 2, 0.0))
        dx = jnp.maximum(jnp.maximum(boxes[None, :, :, 0] - qx[:, None, :],
                                     qx[:, None, :] - boxes[None, :, :, 1]), 0.0)
        dy = jnp.maximum(jnp.maximum(boxes[None, :, :, 2] - qy[:, None, :],
                                     qy[:, None, :] - boxes[None, :, :, 3]), 0.0)
        lb = jnp.max(jnp.sqrt(dx * dx + dy * dy), axis=-1)  # (Q, B_blocks)

        # Rank blocks by (lower bound, distance-to-box-centres): overlapping
        # boxes give lb == 0 ties for most blocks, where the bound alone
        # degenerates to block-id order (an arbitrary subset).  The centre
        # proximity is a pure ordering heuristic — soundness/exactness only
        # ever depend on WHICH blocks are inside the budget being verified
        # downstream, never on this tie-break.
        cx = 0.5 * (boxes[None, :, :, 0] + boxes[None, :, :, 1])
        cy = 0.5 * (boxes[None, :, :, 2] + boxes[None, :, :, 3])
        cdist = jnp.mean(
            jnp.sqrt((qx[:, None, :] - cx) ** 2 + (qy[:, None, :] - cy) ** 2),
            axis=-1,
        )  # (Q, B_blocks)
        order = jnp.lexsort((cdist, lb), axis=1)  # lb primary, cdist ties
        top = order[:, :budget_blocks]  # (Q, budget)
        cand_pad = jnp.pad(cand, ((0, n_pad - n), (0, 0)))
        blocks = cand_pad.reshape(b_blocks, block, e_dim)
        picked = blocks[top]  # (Q, budget, block, E) — the pruned gather
        scores = jnp.einsum(
            "qe,qkbe->qkb", u.astype(jnp.float32), picked.astype(jnp.float32)
        ).reshape(u.shape[0], -1)
        rows = (top[..., None] * block
                + jnp.arange(block)[None, None, :]).reshape(u.shape[0], -1)
        return scores, rows


class DLRMModel(_RecsysBase):
    """DLRM-RM2 (arXiv:1906.00091): bottom MLP on dense feats, dot-product
    feature interaction, top MLP."""

    def param_shapes(self) -> dict:
        c = self.cfg
        d = c.embed_dim
        bot = (c.n_dense,) + tuple(c.bot_mlp)
        n_f = c.n_sparse + 1
        n_inter = n_f * (n_f - 1) // 2
        top = (n_inter + d,) + tuple(c.mlp) + (1,)
        return {
            "tables": ((c.n_sparse, c.vocab, d), c.dtype),
            "bot_w": _mlp_shapes(bot, c.dtype),
            "bot_b": [((x,), c.dtype) for x in bot[1:]],
            "top_w": _mlp_shapes(top, c.dtype),
            "top_b": [((x,), c.dtype) for x in top[1:]],
        }

    def forward(self, params: dict, batch: dict) -> jnp.ndarray:
        dense = batch["dense"].astype(self.cfg.dtype)  # (B, 13)
        idx = batch["sparse_ids"]  # (B, 26)
        z0 = _mlp_apply(dense, params["bot_w"], params["bot_b"], final_act=True)
        emb = _constrain_rows(
            embedding_lookup(params["tables"], idx), self.cfg.batch_axes
        )  # (B, 26, D)
        z = jnp.concatenate([z0[:, None, :], emb], axis=1)  # (B, 27, D)
        inter = jnp.einsum("bnd,bmd->bnm", z, z)  # (B, 27, 27)
        iu, ju = jnp.triu_indices(z.shape[1], k=1)
        feat = jnp.concatenate([inter[:, iu, ju], z0], axis=-1)
        return _mlp_apply(feat, params["top_w"], params["top_b"]).astype(jnp.float32)[:, 0]


def bce_loss(model, params: dict, batch: dict) -> jnp.ndarray:
    logit = model.forward(params, batch)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )

"""Shared plumbing of the batched query engines (BSS scan + device forest):
the engine option record, backend selection, query-tile survival, and the
serving front's shape buckets.

``EngineOpts`` is the ONE definition of the cross-cutting engine option
space.  The five knobs (query-tile size, compute backend, Pallas interpret
mode, jnp exact-phase realisation, exact-phase precision) used to be
copy-pasted across every batched entry point — six signatures that had to
agree, and did only by review.  Every entry point now accepts
``opts=EngineOpts(...)``; the legacy per-knob kwargs still work through
:func:`resolve_engine_opts` (and warn when ``REPRO_STRICT_API=1``), so the
option space is defined, validated and documented exactly once.

Both engines tile their work as (query-tile x corpus-block) cells fed to the
masked Pallas kernels on TPU (``backend="pallas"``) or an equivalent fused
jnp graph elsewhere (``"jnp"``); ``"auto"`` picks per the jax default
backend.  These helpers are the contract between an engine's per-query
survival logic and the kernels' tile granularity — one copy, two engines.

The bucket ladder is the serving-side half of the same contract: the async
front (``repro.serve.front``) pads every micro-batch up to one of a fixed
ladder of query counts, so the jitted engines see at most ``len(buckets)``
distinct batch shapes per (kind, metric) — recompiles are bounded by the
ladder, not the traffic.  ``jit_cache_size`` is the observability hook the
compile-guard tests (and the front's telemetry) count those lowerings with.
"""

from __future__ import annotations

import dataclasses
import os
import warnings

import jax
import jax.numpy as jnp

__all__ = [
    "EngineOpts",
    "resolve_engine_opts",
    "resolve_backend",
    "tile_survival",
    "DEFAULT_BUCKETS",
    "bucket_for",
    "jit_cache_size",
]

# set REPRO_STRICT_API=1 to make the legacy per-knob engine kwargs warn
# (DeprecationWarning) — the migration ratchet for out-of-repo callers;
# in-repo callers all pass opts= already
STRICT_API_ENV = "REPRO_STRICT_API"

_BACKENDS = ("auto", "pallas", "jnp")
_REALISATIONS = ("adaptive", "dense")
_PRECISIONS = ("fp32", "bf16")


@dataclasses.dataclass(frozen=True)
class EngineOpts:
    """The cross-cutting options of every batched query engine, as one
    frozen (hashable, reusable) record.

    * ``bq`` — query-tile row count fed to the masked kernels; ``None``
      means the kernel default (``repro.kernels.tiles.TILE_BQ``, itself
      env-overridable).  Engines that tile differently (the forest
      walkers) ignore it.
    * ``backend`` — ``"auto"`` (pallas on TPU, jnp elsewhere) | ``"pallas"``
      | ``"jnp"``.
    * ``interpret`` — Pallas interpret mode (tests run the kernel wiring
      off-TPU with ``backend="pallas", interpret=True``); ``None`` leaves
      the kernel default.
    * ``realisation`` — jnp exact-phase realisation: ``"adaptive"`` picks
      cell-gather vs dense by survivor density, ``"dense"`` pins the
      fixed-shape pass (the serving front's choice — bounded recompiles).
      Engines without the adaptive split (sharded, forest) ignore it.
    * ``precision`` — exact-phase corpus precision, ``"fp32"`` | ``"bf16"``
      (bf16 streams the half-width mirror with an fp32 boundary re-check;
      results and counts bit-identical either way).

    Validation lives here, once, instead of per entry point."""

    bq: int | None = None
    backend: str = "auto"
    interpret: bool | None = None
    realisation: str = "adaptive"
    precision: str = "fp32"

    def __post_init__(self):
        if self.bq is not None and int(self.bq) <= 0:
            raise ValueError(f"bq must be positive, got {self.bq}")
        if self.backend not in _BACKENDS:
            raise ValueError(
                f"backend must be auto|pallas|jnp, got {self.backend!r}"
            )
        if self.realisation not in _REALISATIONS:
            raise ValueError(
                f"realisation must be adaptive|dense, got "
                f"{self.realisation!r}"
            )
        if self.precision not in _PRECISIONS:
            raise ValueError(
                f"precision must be fp32|bf16, got {self.precision!r}"
            )


def resolve_engine_opts(opts: EngineOpts | None = None, **legacy) -> EngineOpts:
    """The legacy-kwarg shim every engine entry point funnels through.

    ``opts`` given -> returned as-is (mixing it with a legacy kwarg is an
    error: two sources of truth for one knob).  ``opts`` absent -> an
    ``EngineOpts`` is assembled from whichever legacy kwargs the caller
    passed (``None`` = not passed = the field default), with a
    ``DeprecationWarning`` when ``REPRO_STRICT_API=1`` — the in-repo
    callers all pass ``opts=``; the env var is the ratchet for the rest."""
    given = {k: v for k, v in legacy.items() if v is not None}
    if opts is not None:
        if not isinstance(opts, EngineOpts):
            raise TypeError(
                f"opts must be an EngineOpts, got {type(opts).__name__}"
            )
        if given:
            raise ValueError(
                f"pass opts= OR the legacy kwargs, not both (got opts= and "
                f"{sorted(given)})"
            )
        return opts
    if given and os.environ.get(STRICT_API_ENV) == "1":
        warnings.warn(
            f"legacy engine kwargs {sorted(given)} are deprecated; pass "
            f"opts=EngineOpts(...) (repro.core.backends)",
            DeprecationWarning,
            stacklevel=3,
        )
    return EngineOpts(**given)

# default micro-batch shape ladder of the serving front: 8 covers trickle
# traffic, 512 is past the point where the fused engines are
# throughput-bound; ladders are always sorted ascending
DEFAULT_BUCKETS = (8, 32, 128, 512)


def bucket_for(n: int, buckets=DEFAULT_BUCKETS) -> int:
    """Smallest bucket that fits ``n`` queries (``buckets`` ascending).
    The caller pads its batch up to the returned size, so every jit sees
    only ladder shapes."""
    if n <= 0:
        raise ValueError(f"need at least one query, got {n}")
    for b in buckets:
        if n <= b:
            return int(b)
    raise ValueError(
        f"batch of {n} exceeds the largest bucket {buckets[-1]}; "
        f"split it before dispatch"
    )


def jit_cache_size(fn) -> int:
    """Number of distinct lowerings a ``jax.jit``-wrapped callable holds —
    the compile count the shape-bucket guard bounds.  Returns -1 when the
    jax version exposes no cache hook (callers should skip, not fail)."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return -1
    return int(probe())


def resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("pallas", "jnp"):
        raise ValueError(f"backend must be auto|pallas|jnp, got {backend!r}")
    return backend


def tile_survival(alive: jnp.ndarray, bq: int) -> jnp.ndarray:
    """(Q, B) per-query survival -> (ceil(Q/bq), B) tile survival: a tile
    lives when ANY of its queries does (jnp ops — usable in and out of jit;
    host callers wrap the result in np.asarray)."""
    qtiles = -(-alive.shape[0] // bq)
    alive_pad = jnp.pad(
        alive, ((0, qtiles * bq - alive.shape[0]), (0, 0)),
        constant_values=False,
    )
    return alive_pad.reshape(qtiles, bq, -1).any(axis=1)

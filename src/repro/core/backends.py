"""Shared plumbing of the batched query engines (BSS scan + device forest):
backend selection and query-tile survival.

Both engines tile their work as (query-tile x corpus-block) cells fed to the
masked Pallas kernels on TPU (``backend="pallas"``) or an equivalent fused
jnp graph elsewhere (``"jnp"``); ``"auto"`` picks per the jax default
backend.  These two helpers are the contract between an engine's per-query
survival logic and the kernels' tile granularity — one copy, two engines.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["resolve_backend", "tile_survival"]


def resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("pallas", "jnp"):
        raise ValueError(f"backend must be auto|pallas|jnp, got {backend!r}")
    return backend


def tile_survival(alive: jnp.ndarray, bq: int) -> jnp.ndarray:
    """(Q, B) per-query survival -> (ceil(Q/bq), B) tile survival: a tile
    lives when ANY of its queries does (jnp ops — usable in and out of jit;
    host callers wrap the result in np.asarray)."""
    qtiles = -(-alive.shape[0] // bq)
    alive_pad = jnp.pad(
        alive, ((0, qtiles * bq - alive.shape[0]), (0, 0)),
        constant_values=False,
    )
    return alive_pad.reshape(qtiles, bq, -1).any(axis=1)

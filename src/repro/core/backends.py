"""Shared plumbing of the batched query engines (BSS scan + device forest):
backend selection, query-tile survival, and the serving front's shape
buckets.

Both engines tile their work as (query-tile x corpus-block) cells fed to the
masked Pallas kernels on TPU (``backend="pallas"``) or an equivalent fused
jnp graph elsewhere (``"jnp"``); ``"auto"`` picks per the jax default
backend.  These helpers are the contract between an engine's per-query
survival logic and the kernels' tile granularity — one copy, two engines.

The bucket ladder is the serving-side half of the same contract: the async
front (``repro.serve.front``) pads every micro-batch up to one of a fixed
ladder of query counts, so the jitted engines see at most ``len(buckets)``
distinct batch shapes per (kind, metric) — recompiles are bounded by the
ladder, not the traffic.  ``jit_cache_size`` is the observability hook the
compile-guard tests (and the front's telemetry) count those lowerings with.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "resolve_backend",
    "tile_survival",
    "DEFAULT_BUCKETS",
    "bucket_for",
    "jit_cache_size",
]

# default micro-batch shape ladder of the serving front: 8 covers trickle
# traffic, 512 is past the point where the fused engines are
# throughput-bound; ladders are always sorted ascending
DEFAULT_BUCKETS = (8, 32, 128, 512)


def bucket_for(n: int, buckets=DEFAULT_BUCKETS) -> int:
    """Smallest bucket that fits ``n`` queries (``buckets`` ascending).
    The caller pads its batch up to the returned size, so every jit sees
    only ladder shapes."""
    if n <= 0:
        raise ValueError(f"need at least one query, got {n}")
    for b in buckets:
        if n <= b:
            return int(b)
    raise ValueError(
        f"batch of {n} exceeds the largest bucket {buckets[-1]}; "
        f"split it before dispatch"
    )


def jit_cache_size(fn) -> int:
    """Number of distinct lowerings a ``jax.jit``-wrapped callable holds —
    the compile count the shape-bucket guard bounds.  Returns -1 when the
    jax version exposes no cache hook (callers should skip, not fail)."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return -1
    return int(probe())


def resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("pallas", "jnp"):
        raise ValueError(f"backend must be auto|pallas|jnp, got {backend!r}")
    return backend


def tile_survival(alive: jnp.ndarray, bq: int) -> jnp.ndarray:
    """(Q, B) per-query survival -> (ceil(Q/bq), B) tile survival: a tile
    lives when ANY of its queries does (jnp ops — usable in and out of jit;
    host callers wrap the result in np.asarray)."""
    qtiles = -(-alive.shape[0] // bq)
    alive_pad = jnp.pad(
        alive, ((0, qtiles * bq - alive.shape[0]), (0, 0)),
        constant_values=False,
    )
    return alive_pad.reshape(qtiles, bq, -1).any(axis=1)

"""Monotone binary hyperplane trees over the projected plane, including the
paper's novel Linear Regression Tree (§5) and the arbitrary-planar-partition
family (§3.4).

All trees here are *monotone* (each child shares one pivot with its parent,
as in the Monotonous Bisector Tree): at query time only ONE new distance is
evaluated per visited node — the inherited pivot's distance is passed down.

Partition strategies (all are 1-Lipschitz functionals of the projected plane,
so |margin(q) - split| > t soundly excludes the far side under the four-point
property):

    closer     sign of planar x  == classic closer-pivot split (unbalanced;
               also admits the Hyperbolic mechanism for non-supermetric use)
    median_x   balanced split at median planar x   (Fig. 8 left)
    median_y   balanced split at median height y   (Fig. 8 right)
    pca        balanced split along the 1st principal axis of the node's
               projected cloud (Fig. 9)
    lrt        LRT: least-squares line fit, rotate about X-intercept so the
               line becomes the X-axis, split at median rotated x (Alg. 3)

Selection strategies for the fresh pivot: "rand" and "far" (farthest from the
inherited pivot — free, since inherited distances are already known).
"""

from __future__ import annotations

import dataclasses
import sys

import numpy as np

from repro.core import exclusion, projection
from repro.core.constants import DEGENERATE_DELTA
from repro.core.exclusion import HILBERT, HYPERBOLIC
from repro.core.npdist import DistanceCounter, pairwise_np

__all__ = ["PARTITIONS", "MonotoneTree", "build_monotone_tree", "range_search_monotone"]

PARTITIONS = ("closer", "median_x", "median_y", "pca", "lrt")


@dataclasses.dataclass
class _MNode:
    p1: int              # inherited pivot (dataset index)
    p2: int              # fresh pivot
    delta: float         # d(p1, p2)
    theta: float         # rotation angle (lrt) or pca axis angle
    h: float             # rotation X-intercept (lrt only)
    ny: float            # margin = nx*r_x + ny*r_y; (nx,ny) unit
    nx: float
    split: float
    left: object         # _MNode | np.ndarray leaf | None
    right: object


@dataclasses.dataclass
class MonotoneTree:
    partition: str
    select: str
    metric: str
    data: np.ndarray
    root: object
    root_p1: int
    build_distances: int
    n_nodes: int
    max_depth: int


# Planar geometry comes from core/projection.py (numpy namespace, float64)
# — the SAME bodies the jitted engines run in float32, so build, host walk
# and device forest walk agree on the degenerate-plane (duplicate-pivot)
# handling by construction.  Build refuses nodes with delta below
# DEGENERATE_DELTA (leaf-bucket fallback), so the walk-side ring collapse
# inside ``projection.project`` never fires for an encoded node.


def _fit_partition(partition: str, x: np.ndarray, y: np.ndarray,
                   q: float = 0.5):
    """Returns (theta, h, nx, ny, split).

    ``q``: split quantile.  0.5 = the paper's balanced median split; other
    values implement the *controlled unbalancing* the paper proposes as
    future work (§3.5/§6: "the effect of controlling the balance ... will
    increase the probability of exclusion at cost of excluding smaller
    subsets").
    """
    if partition == "closer":
        return 0.0, 0.0, 1.0, 0.0, 0.0
    if partition == "median_x":
        return 0.0, 0.0, 1.0, 0.0, float(np.quantile(x, q))
    if partition == "median_y":
        return 0.0, 0.0, 0.0, 1.0, float(np.quantile(y, q))
    if partition == "pca":
        xc, yc = x - x.mean(), y - y.mean()
        cov = np.array(
            [[np.mean(xc * xc), np.mean(xc * yc)], [np.mean(xc * yc), np.mean(yc * yc)]]
        )
        w, v = np.linalg.eigh(cov)
        pc1 = v[:, int(np.argmax(w))]  # split ALONG pc1 (max spread direction)
        nx, ny = float(pc1[0]), float(pc1[1])
        m = nx * x + ny * y
        return 0.0, 0.0, nx, ny, float(np.quantile(m, q))
    if partition == "lrt":
        xb, yb = float(x.mean()), float(y.mean())
        den = float(np.sum((x - xb) ** 2))
        num = float(np.sum((x - xb) * (y - yb)))
        if den < 1e-12 or abs(num) < 1e-12 * max(den, 1.0):
            theta, h = 0.0, 0.0
        else:
            m = num / den
            theta = float(np.arctan(m))
            h = xb - yb / m if abs(m) > 1e-9 else 0.0
        rx, _ = projection.rotate(x, y, theta, h, xp=np)
        return theta, h, 1.0, 0.0, float(np.quantile(rx, q))
    raise ValueError(partition)


def build_monotone_tree(
    partition: str,
    select: str,
    metric: str,
    data: np.ndarray,
    seed: int = 0,
    leaf_cap: int = 8,
    split_quantile: float = 0.5,
) -> MonotoneTree:
    """``split_quantile`` != 0.5 gives the paper's proposed *controlled
    unbalancing* (§6 future work): deterministic skew instead of the
    serendipitous skew of the 'closer' split."""
    if partition not in PARTITIONS:
        raise ValueError(partition)
    if select not in ("rand", "far"):
        raise ValueError(select)
    if sys.getrecursionlimit() < 100_000:
        sys.setrecursionlimit(100_000)
    rng = np.random.default_rng(seed)
    data = np.asarray(data, np.float64)
    n = data.shape[0]
    build_count = [0]
    stats = {"nodes": 0, "depth": 0}

    def pick_p2(subset: np.ndarray, d1: np.ndarray) -> int:
        if select == "far":
            return int(subset[int(np.argmax(d1))])
        return int(subset[int(rng.integers(len(subset)))])

    def make(subset: np.ndarray, p1: int, d1: np.ndarray, depth: int):
        stats["depth"] = max(stats["depth"], depth)
        if len(subset) <= leaf_cap:
            return subset
        stats["nodes"] += 1
        p2 = pick_p2(subset, d1)
        delta = float(pairwise_np(metric, data[p1], data[p2][None, :])[0, 0])
        build_count[0] += 1
        keep = subset != p2
        subset, d1 = subset[keep], d1[keep]
        d2 = pairwise_np(metric, data[subset], data[p2][None, :])[:, 0]
        build_count[0] += len(subset)
        if delta < DEGENERATE_DELTA:
            # degenerate (duplicate or near-duplicate) pivots: the plane
            # cannot be trusted — projection would collapse it to the ring
            # bound at query time (PR 2 fix), so no linear split of it can
            # separate anything.  Fall back to a leaf bucket.
            return np.concatenate([subset, np.array([p2], dtype=np.int64)])
        x, y = projection.project(d1, d2, delta, xp=np)
        theta, h, nx, ny, split = _fit_partition(partition, x, y, split_quantile)
        margin = exclusion.planar_margin(x, y, theta, h, nx, ny, split, xp=np)
        lmask = margin < 0.0
        # One-sided splits are legitimate for the unbalanced 'closer' tree
        # (paper §5: "the unbalanced tree is always the best performer"); for
        # balanced partitions they mean the median is tied — nudge the split
        # to a strict separator, or give up on a degenerate cloud.  The split
        # stored in the node is ALWAYS the true boundary, so the |margin|>t
        # exclusion stays sound.
        if partition != "closer" and (lmask.all() or (~lmask).all()):
            uniq = np.unique(margin)
            if len(uniq) < 2:
                return np.concatenate([subset, np.array([p2], dtype=np.int64)])
            cut = float(uniq[max(1, len(uniq) // 2)])
            split += cut
            margin = margin - cut
            lmask = margin < 0.0
        left = make(subset[lmask], p1, d1[lmask], depth + 1)
        right = make(subset[~lmask], p2, d2[~lmask], depth + 1)
        return _MNode(p1, p2, delta, theta, h, ny, nx, split, left, right)

    all_idx = np.arange(n, dtype=np.int64)
    p1 = int(rng.integers(n))
    subset = all_idx[all_idx != p1]
    d1 = pairwise_np(metric, data[subset], data[p1][None, :])[:, 0]
    build_count[0] += len(subset)
    root = make(subset, p1, d1, 1)
    return MonotoneTree(
        partition=partition,
        select=select,
        metric=metric,
        data=data,
        root=root,
        root_p1=p1,
        build_distances=build_count[0],
        n_nodes=stats["nodes"],
        max_depth=stats["depth"],
    )


def range_search_monotone(
    tree: MonotoneTree,
    queries: np.ndarray,
    t: float,
    mechanism: str = HILBERT,
) -> tuple[list[list[int]], DistanceCounter]:
    """Batched counting range search (paper Alg. 5, generalised partitions).

    Only ``partition='closer'`` admits the Hyperbolic mechanism; every other
    partition is planar-geometric and requires the four-point property.
    """
    if mechanism == HYPERBOLIC and tree.partition != "closer":
        raise ValueError("hyperbolic exclusion is only sound for the 'closer' split")
    queries = np.asarray(queries, np.float64)
    nq = queries.shape[0]
    counter = DistanceCounter(tree.metric, nq)
    results: list[list[int]] = [[] for _ in range(nq)]
    data = tree.data

    d_root = counter.pairwise(
        np.arange(nq, dtype=np.int64), queries, data[tree.root_p1][None, :]
    )[:, 0]
    for qi in np.nonzero(d_root <= t)[0]:
        results[qi].append(tree.root_p1)

    stack = [(tree.root, np.arange(nq, dtype=np.int64), d_root)]
    while stack:
        node, qidx, dq1 = stack.pop()
        if node is None or len(qidx) == 0:
            continue
        if isinstance(node, np.ndarray):
            if len(node) == 0:
                continue
            d = counter.pairwise(qidx, queries[qidx], data[node])
            hit = d <= t
            for row in np.nonzero(hit.any(axis=1))[0]:
                results[qidx[row]].extend(int(i) for i in node[hit[row]])
            continue
        dq2 = counter.pairwise(qidx, queries[qidx], data[node.p2][None, :])[:, 0]
        for row in np.nonzero(dq2 <= t)[0]:
            results[qidx[row]].append(node.p2)
        if mechanism == HYPERBOLIC:
            # <0 closer to p1; exclude iff |.| > t
            margin = exclusion.hyperbolic_margin(dq1, dq2, xp=np)
        else:
            x, y = projection.project(dq1, dq2, node.delta, xp=np)
            margin = exclusion.planar_margin(
                x, y, node.theta, node.h, node.nx, node.ny, node.split, xp=np
            )
        go_left = margin < t       # cannot exclude left unless margin >= t
        go_right = margin > -t
        if np.any(go_left):
            stack.append((node.left, qidx[go_left], dq1[go_left]))
        if np.any(go_right):
            stack.append((node.right, qidx[go_right], dq2[go_right]))
    return results, counter

"""Shared numeric floors for the geometric machinery.

``MIN_DELTA`` is THE zero-baseline floor for every Hilbert / planar
computation that divides by an inter-pivot distance: the planar projection
``x = (d1^2 - d2^2) / (2 delta)``, the Hilbert exclusion criterion
``(d1^2 - d2^2) / delta > 2t``, and the kernels' in-VMEM copies of the same
math.  Before this constant existed the floors disagreed (``1e-300`` in
``core/tree.py`` vs ``1e-12`` everywhere else), so a duplicate pivot pair
(delta == 0) was clamped differently depending on which engine evaluated it
— same geometry, different exclusion decisions.

Soundness at the floor: with exact duplicates, ``d(q,p1) == d(q,p2)``
numerically (identical rows give identical float results), the numerator is
exactly 0 and ``0 / MIN_DELTA == 0`` — nothing is ever excluded through a
degenerate plane, which is the conservative (sound) behaviour.  A tiny
positive floor also keeps float32 arithmetic finite (``1e-300`` underflows
to 0 in float32 and produced inf/nan planar coordinates on device).
"""

from __future__ import annotations

# Minimum inter-pivot distance used as a divisor in planar / Hilbert math.
# float32-representable (unlike 1e-300) and far below any real distance.
MIN_DELTA = 1e-12

# Below this inter-pivot distance a plane is DEGENERATE (duplicate or
# near-duplicate pivots) and the apex x-coordinate is neutralised to 0 —
# the projection degrades to the sound triangle-inequality ring bound
# (x=0, y=d1) instead of dividing rounding noise by a tiny delta.  The
# hazard is real under jit: XLA fusion can evaluate d1^2 and d2^2 through
# different rewrites, so ``d1*d1 - d2*d2`` is ~1e-7 even when d1 == d2
# bitwise, and ``1e-7 / (2 * MIN_DELTA)`` is a catastrophically wrong
# planar coordinate.  1e-6 sits far above float32 noise and far below any
# meaningful pivot separation.
DEGENERATE_DELTA = 1e-6

__all__ = ["MIN_DELTA", "DEGENERATE_DELTA"]

"""Mixed-precision margins for the bf16 exact phase.

The engines can stream a bfloat16 mirror of the corpus through the masked
tile kernels (halving corpus HBM traffic; accumulation stays fp32 — every
kernel upcasts on entry) WITHOUT giving up exactness, because every
threshold comparison against a bf16-phase distance is widened by a
conservative margin ``eps`` and the resulting boundary band is re-checked
against the fp32 corpus.  This module derives that margin.

Derivation (recorded in ROADMAP.md):  write ``p~`` for the bf16 rounding of
corpus point ``p``.  All supermetrics here are genuine metrics, so the
triangle inequality gives ``|d(q, p~) - d(q, p)| <= d(p, p~)`` for every
query ``q``.  At mirror time we compute ``r_max = max_p d(p, p~)`` EXACTLY,
in float64, over the real (valid) corpus rows — no modelling of bf16's
2^-9 relative step is needed; the realised rounding displacement is
measured per point in the metric itself.  The engine evaluates
``d16 ~= d(q, p~)`` in fp32 arithmetic, so a second (much smaller) term
bounds fp32 accumulation noise: ``ARITH_ULPS * eps_f32 * sqrt(dim) *
scale`` with a per-metric magnitude ``scale``.  The margin

    eps = 2 * r_max + ARITH_ULPS * eps_f32 * sqrt(dim) * scale

then guarantees, with the factor-2 headroom on the provable term:

* range:  every true hit (``d(q,p) <= t``) has ``d16 <= t + eps`` — the
  bf16 phase can never falsely exclude; and every sure hit
  (``d16 <= t - eps``) satisfies ``d(q,p) <= t`` — no fp32 re-check needed
  outside the band ``t - eps < d16 <= t + eps``.
* kNN:  ``|kth16 - kth32| <= eps`` (sorted order statistics of two
  pointwise-eps-close vectors), so the true top-k all lie inside the band
  ``d16 <= kth16 + 2*eps``.

bf16 rounding dominates: its relative step (2^-9) exceeds fp32's (2^-24)
by ~3e4, so the measured ``2*r_max`` term is the margin for any realistic
corpus and the arithmetic term is a positivity floor.
"""

from __future__ import annotations

import math

import numpy as np

__all__ = ["ARITH_ULPS", "bf16_round_np", "bf16_margin"]

# headroom multiplier on fp32 accumulation noise (heuristic floor; the
# property tests in tests/test_bf16_precision.py exercise it across random
# corpora on all four supermetrics)
ARITH_ULPS = 64.0

_F32_EPS = float(np.finfo(np.float32).eps)
_EPS = 1e-12  # probability-simplex guard, mirrors npdist._EPS


def bf16_round_np(a: np.ndarray) -> np.ndarray:
    """Round-to-nearest-even through bfloat16, returned as float32 — the
    exact values the engine's bf16 corpus mirror holds."""
    a32 = np.asarray(a, np.float32)
    try:
        import ml_dtypes  # bundled with jax

        return a32.astype(ml_dtypes.bfloat16).astype(np.float32)
    except (ImportError, TypeError):  # pragma: no cover - defensive
        import jax.numpy as jnp

        return np.asarray(
            jnp.asarray(a32).astype(jnp.bfloat16).astype(jnp.float32)
        )


def _xlogx(v: np.ndarray) -> np.ndarray:
    return np.where(v > _EPS, v * np.log(np.maximum(v, _EPS)), 0.0)


def _rowwise(metric_name: str, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """d(a[i], b[i]) per row, float64, matching ``npdist.pairwise_np``'s
    guards exactly (these ARE the diagonal of the oracle)."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    if metric_name == "l2":
        return np.linalg.norm(a - b, axis=1)
    if metric_name == "cosine":
        an = a / np.maximum(np.linalg.norm(a, axis=1, keepdims=True), _EPS)
        bn = b / np.maximum(np.linalg.norm(b, axis=1, keepdims=True), _EPS)
        cos = np.clip(np.sum(an * bn, axis=1), -1.0, 1.0)
        return np.sqrt(np.maximum(2.0 - 2.0 * cos, 0.0))
    if metric_name == "jsd":
        m = 0.5 * (a + b)
        js = np.sum(0.5 * _xlogx(a) + 0.5 * _xlogx(b) - _xlogx(m), axis=1)
        return np.sqrt(np.maximum(js, 0.0) / np.log(2.0))
    if metric_name == "triangular":
        s = np.maximum(a + b, _EPS)
        return np.sqrt(np.maximum(0.5 * np.sum((a - b) ** 2 / s, axis=1), 0.0))
    # power transforms and anything else: chunked diagonal of the oracle
    from repro.core.npdist import pairwise_np

    out = np.empty(a.shape[0], np.float64)
    chunk = 64
    for lo in range(0, a.shape[0], chunk):
        hi = min(lo + chunk, a.shape[0])
        out[lo:hi] = np.diagonal(pairwise_np(metric_name, a[lo:hi], b[lo:hi]))
    return out


def _arith_scale(metric_name: str, data64: np.ndarray) -> float:
    """Magnitude scale for the fp32-accumulation noise term."""
    if metric_name in ("jsd", "triangular"):
        return 1.0  # distances live in [0, 1]
    if metric_name == "cosine":
        return 2.0  # distances live in [0, 2]
    norms = np.linalg.norm(data64, axis=1)
    return 1.0 + (float(norms.max()) if norms.size else 0.0)


def bf16_margin(
    metric_name: str, data: np.ndarray, valid: np.ndarray | None = None
) -> float:
    """Conservative comparison margin for bf16-phase distances against the
    corpus ``data`` (engine space: already normalised for cosine-as-l2),
    restricted to ``valid`` rows (padding rows are never hits and must not
    inflate the band)."""
    data = np.asarray(data, np.float32)
    if valid is not None:
        data = data[np.asarray(valid, bool)]
    dim = int(data.shape[1]) if data.ndim == 2 else 1
    if data.size == 0:
        return float(_F32_EPS)
    data64 = np.asarray(data, np.float64)
    r = _rowwise(metric_name, data64, bf16_round_np(data).astype(np.float64))
    eps = 2.0 * float(r.max()) + ARITH_ULPS * _F32_EPS * math.sqrt(dim) * (
        _arith_scale(metric_name, data64)
    )
    # round UP into fp32 so the jitted comparisons inherit the guarantee
    return float(np.nextafter(np.float32(eps), np.float32(np.inf)))

"""Reference-point (pivot) selection strategies.

A central empirical finding of the paper (§3.3, §4.3): with four-point
(Hilbert) exclusion, search performance is nearly *invariant* to pivot
choice, so cheap strategies (random / FFT) suffice — "putting huge
computational resources into building expensive data structures may be far
less worthwhile in this context".  We implement the paper's set: random, FFT
(farthest-first traversal), max-separation sampling, plus outlier selection
for SAT roots.
"""

from __future__ import annotations

import numpy as np

from repro.core.npdist import pairwise_np

__all__ = ["select_random", "select_fft", "select_maxsep_pair", "select_outlier"]


def select_random(rng: np.random.Generator, n_pts: int, k: int) -> np.ndarray:
    """k distinct indices uniformly at random."""
    return rng.choice(n_pts, size=min(k, n_pts), replace=False)


def select_fft(
    metric: str,
    data: np.ndarray,
    k: int,
    rng: np.random.Generator,
    sample_cap: int = 4096,
) -> np.ndarray:
    """Farthest-first traversal (greedy k-center, Gonzalez).

    Seeded from a random point; each next pivot maximises the min-distance to
    pivots chosen so far.  For large nodes we FFT over a random subsample
    (standard practice; the paper's point is precisely that pivot quality
    barely matters under Hilbert exclusion).
    """
    n = data.shape[0]
    k = min(k, n)
    if n > sample_cap:
        cand = rng.choice(n, size=sample_cap, replace=False)
    else:
        cand = np.arange(n)
    sub = data[cand]
    first = int(rng.integers(len(cand)))
    chosen = [first]
    min_d = pairwise_np(metric, sub[first], sub)[0]
    for _ in range(k - 1):
        nxt = int(np.argmax(min_d))
        chosen.append(nxt)
        d_new = pairwise_np(metric, sub[nxt], sub)[0]
        min_d = np.minimum(min_d, d_new)
    return cand[np.array(chosen, dtype=np.int64)]


def select_maxsep_pair(
    metric: str, data: np.ndarray, rng: np.random.Generator, n_pairs: int = 1000
) -> tuple[int, int]:
    """Most-separated pair out of ``n_pairs`` random samples (paper §3.3)."""
    n = data.shape[0]
    a = rng.integers(0, n, size=n_pairs)
    b = rng.integers(0, n, size=n_pairs)
    dd = np.array(
        [pairwise_np(metric, data[a[i]], data[b[i]][None, :])[0, 0] for i in range(n_pairs)]
    )
    i = int(np.argmax(dd))
    return int(a[i]), int(b[i])


def select_outlier(
    metric: str, data: np.ndarray, rng: np.random.Generator, sample_cap: int = 4096
) -> int:
    """SAT_out root selection: an outlier — farthest point from a random
    seed (one FFT step), per DiSAT practice [3]."""
    n = data.shape[0]
    if n > sample_cap:
        cand = rng.choice(n, size=sample_cap, replace=False)
    else:
        cand = np.arange(n)
    seed = data[int(rng.integers(n))]
    d = pairwise_np(metric, seed, data[cand])[0]
    return int(cand[int(np.argmax(d))])

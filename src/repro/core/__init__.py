"""Supermetric search core — the paper's contribution as a composable library.

Layers:
  distances   metrics + four-point classification (jnp, batched)
  npdist      host-side twins used by tree build / distance-counted replay
  projection  tetrahedral planar projection + lower bound (paper §3)
  exclusion   Hyperbolic vs Hilbert rules; general planar partitions
  refpoints   pivot selection (random / FFT / maxsep / outlier)
  tree        12 hyperplane partition-tree variants (paper §4)
  lrt         monotone binary trees incl. the Linear Regression Tree (§5)
  flat_index  Blocked Supermetric Scan — TPU-native engine (DESIGN.md §2)
"""

from repro.core import distances, exclusion, lrt, projection, refpoints, tree  # noqa: F401


def __getattr__(name: str):
    # flat_index pulls in repro.kernels, whose modules import
    # repro.core.constants — importing it eagerly here closes an import
    # cycle whenever a kernels module is the interpreter's entry point.
    # Lazy attribute access keeps `repro.core.flat_index` working while
    # leaving the kernels layer importable on its own.
    if name == "flat_index":
        import importlib

        return importlib.import_module("repro.core.flat_index")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

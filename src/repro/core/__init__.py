"""Supermetric search core — the paper's contribution as a composable library.

Layers:
  distances   metrics + four-point classification (jnp, batched)
  npdist      host-side twins used by tree build / distance-counted replay
  projection  tetrahedral planar projection + lower bound (paper §3)
  exclusion   Hyperbolic vs Hilbert rules; general planar partitions
  refpoints   pivot selection (random / FFT / maxsep / outlier)
  tree        12 hyperplane partition-tree variants (paper §4)
  lrt         monotone binary trees incl. the Linear Regression Tree (§5)
  flat_index  Blocked Supermetric Scan — TPU-native engine (DESIGN.md §2)
"""

from repro.core import distances, exclusion, flat_index, lrt, projection, refpoints, tree  # noqa: F401

"""Host-side (numpy) distance evaluation for tree build & distance-counted
query replay.

The paper's experiments measure *number of distance evaluations per query*;
that bookkeeping runs on the host over array-encoded trees (pointer-chasing
is a CPU-side concern).  The TPU engines (`flat_index`, `kernels/`) use the
jnp/Pallas implementations in `distances.py`; these numpy twins are
cross-validated against them in tests.
"""

from __future__ import annotations

import numpy as np

__all__ = ["pairwise_np", "register_power", "DistanceCounter"]

_EPS = 1e-12


def _l2(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    sq = (
        np.sum(x * x, axis=-1)[:, None]
        + np.sum(y * y, axis=-1)[None, :]
        - 2.0 * (x @ y.T)
    )
    return np.sqrt(np.maximum(sq, 0.0))


def _cosine(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    xn = x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), _EPS)
    yn = y / np.maximum(np.linalg.norm(y, axis=-1, keepdims=True), _EPS)
    cos = np.clip(xn @ yn.T, -1.0, 1.0)
    return np.sqrt(np.maximum(2.0 - 2.0 * cos, 0.0))


def _xlogx(v: np.ndarray) -> np.ndarray:
    return np.where(v > _EPS, v * np.log(np.maximum(v, _EPS)), 0.0)


def _jsd(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    x = x[:, None, :]
    y = y[None, :, :]
    m = 0.5 * (x + y)
    js = np.sum(0.5 * _xlogx(x) + 0.5 * _xlogx(y) - _xlogx(m), axis=-1)
    return np.sqrt(np.maximum(js, 0.0) / np.log(2.0))


def _triangular(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    x = x[:, None, :]
    y = y[None, :, :]
    return np.sqrt(
        np.maximum(0.5 * np.sum((x - y) ** 2 / np.maximum(x + y, _EPS), axis=-1), 0.0)
    )


def _l1(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    return np.sum(np.abs(x[:, None, :] - y[None, :, :]), axis=-1)


def _linf(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    return np.max(np.abs(x[:, None, :] - y[None, :, :]), axis=-1)


_FNS = {
    "l2": _l2,
    "cosine": _cosine,
    "jsd": _jsd,
    "triangular": _triangular,
    "l1": _l1,
    "linf": _linf,
}


def register_power(base: str, alpha: float) -> str:
    """Register the numpy twin of ``distances.power_transform(base, alpha)``
    under the canonical ``"{base}^{alpha}"`` name; returns the name."""
    name = f"{base}^{alpha}"
    if name not in _FNS:
        base_fn = _FNS[base]

        def pw(x, y, _b=base_fn, _a=alpha):
            # host-side numpy twin: runs on numpy arrays only, never traced
            return np.power(np.maximum(_b(x, y), 0.0), _a)  # lint: disable=R2

        _FNS[name] = pw
    return name


def _resolve(name: str):
    fn = _FNS.get(name)
    if fn is None and "^" in name:
        # power-transform names ("l1^0.5") parse + register on first use,
        # mirroring distances.get_metric
        base, _, exp = name.partition("^")
        if base in _FNS:
            try:
                alpha = float(exp)
            except ValueError:
                alpha = None
            # same bound as distances.power_transform: only 0 < a <= 1/2
            # guarantees the four-point property the engines rely on
            if (
                alpha is not None
                and 0.0 < alpha <= 0.5
                and f"{base}^{alpha}" == name
            ):
                fn = _FNS[register_power(base, alpha)]
    if fn is None:
        raise KeyError(f"unknown metric {name!r}; have {sorted(_FNS)}")
    return fn


def pairwise_np(name: str, x: np.ndarray, y: np.ndarray) -> np.ndarray:
    fn = _resolve(name)
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    if x.ndim == 1:
        x = x[None, :]
    if y.ndim == 1:
        y = y[None, :]
    return fn(x, y)


class DistanceCounter:
    """Wraps a metric; every evaluated (query, point) pair is tallied.

    The tally IS the paper's figure of merit.  ``per_query`` holds one counter
    per query row so means/medians can be reported exactly as the paper does.
    """

    def __init__(self, metric_name: str, n_queries: int):
        self.name = metric_name
        self.per_query = np.zeros(n_queries, dtype=np.int64)

    def pairwise(self, qidx: np.ndarray, queries: np.ndarray, pts: np.ndarray):
        d = pairwise_np(self.name, queries, pts)
        self.per_query[qidx] += pts.shape[0] if pts.ndim > 1 else 1
        return d

    @property
    def mean(self) -> float:
        return float(self.per_query.mean())

"""Blocked Supermetric Scan (BSS) — the TPU-native realisation of the paper.

The paper's trees prune *semispaces* one node at a time with data-dependent
branching — hostile to TPUs.  BSS keeps the paper's geometry (the planar
lower bound of §3) but restructures the computation for the MXU:

  build:  choose P pivots (FFT — pivot quality barely matters under the
          four-point property, §3.3); project every point onto the M
          pivot-pair planes; recursively median-split the *margin space* to
          find a locality-preserving permutation; group points into
          MXU-tile-aligned blocks of 128; store per (block × plane) bounding
          boxes of the projected coordinates.

  query:  dist(q, pivots)  ->  project q onto all planes  ->  per block,
          lower-bound = max over planes of planar distance-to-box  ->
          blocks with bound > t are EXCLUDED (sound by the four-point
          property); exact distances run only for surviving blocks through
          the pairwise kernel.

Every step is dense, batched and masked: pruning whole 128-point blocks is
exactly the granularity at which a TPU can actually skip work.  Exactness is
preserved (no approximation anywhere) — this is still the paper's *exact*
search, reorganised.

Query engine architecture
-------------------------

Two query paths share one index:

* **Fused batched path** (``bss_query_batched`` / ``bss_knn_batched``) — the
  production engine.  The whole query runs inside a single jitted function:
  query→pivot distances, the planar lower bound over every (query, block)
  pair, a (query-tile × block) survival mask, and exact distances for the
  surviving cells only.  On TPU the lower bound and the masked exact phase
  are the Pallas kernels (``planar_lower_bound_kernel_call`` and the
  metric-dispatched ``masked_pairwise_kernel_call`` family); off-TPU the
  same jitted graph routes through pure-jnp math so XLA still fuses it
  (``backend="auto"`` picks per ``jax.default_backend()``; tests force
  ``"pallas"`` + ``interpret=True`` to exercise the kernel wiring
  everywhere).  The jnp exact phase is adaptive in survivor density: sparse
  survivors gather only the alive (query, block) cells — for range search
  AND for kNN rounds — while dense survivors run one pairwise pass (for l2
  the range hit test runs in the squared domain with no distance matrix
  materialised).  Compact hits / top-k candidates cross back to the host,
  never an O(Q·N) matrix.  kNN is the range reduction run as *batched
  radius deepening*: one jitted round over all queries per iteration, with
  each query's kth-nearest-so-far distance tightening its radius (and
  therefore the survival mask) for the next round, and ``jax.lax.top_k``
  extracting candidates.

* **Numpy oracle path** (``bss_query``) — the original per-block host loop,
  kept verbatim as the correctness oracle: it shares the index build and the
  lower-bound definition but evaluates the exact phase in float64 numpy.
  The test suite asserts the fused path reproduces its hit lists exactly;
  it is also the baseline the benchmarks measure the fused path against.

Metric support
--------------

Every registered four-point metric is served end to end; the engine maps
each to its *kernel space* at the boundary:

* **l2** — the native MXU path (squared-domain matmul identity).
* **cosine** — served EXACTLY as l2: the proper supermetric cosine distance
  ``sqrt(2 - 2 cos)`` *is* the Euclidean distance between unit vectors, so
  the corpus is normalised once at build and queries once per batch, and
  every downstream stage (bounds, kernels, exact phase) runs the l2 code.
* **jsd / triangular** — probability-space metrics with their own VPU tile
  kernels wired into the masked exact phase and the pivot-distance stage.
* **power transforms** (``"l1^0.5"`` …, paper §2.2) — four-point by
  construction; served through the jnp pairwise path (no tile kernel).

Distance accounting: ``exact_dists_per_query`` counts only VALID corpus
points in surviving blocks (per-block valid counts, excluding the padded
slots of partial blocks), so the paper's figure of merit matches a
``DistanceCounter`` replay exactly even when n is not a multiple of the
block size.

``BSSIndex`` stores the build products as host numpy arrays (cheap to
pickle, friendly to the oracle) and mirrors them into device arrays on
first use (``index.device``) so repeated queries pay no host→device copies.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import projection
from repro.core.backends import EngineOpts, resolve_backend, resolve_engine_opts, tile_survival
from repro.core.distances import Metric, get_metric
from repro.core.npdist import pairwise_np
from repro.core.refpoints import select_fft
from repro.kernels.pairwise_dist import (
    KERNEL_METRICS,
    masked_pairwise_kernel_call,
    pairwise_kernel_call,
)
from repro.kernels.planar_exclusion import planar_lower_bound_kernel_call
from repro.kernels.tiles import TILE_BQ
from repro.obs import schema as obs_schema

__all__ = [
    "BSSIndex",
    "build_bss",
    "bss_query",
    "bss_query_batched",
    "bss_knn_batched",
    "bss_lower_bounds",
]

# query-tile size: matches the Pallas kernels' row tiling (REPRO_TILE_BQ)
_DEFAULT_BQ = TILE_BQ

# Normalisation floor for the cosine→l2 mapping; matches the cosine metric's
# own floor in distances._cosine_pairwise so both paths agree bit-for-bit on
# which vectors count as zero.
_MIN_NORM = 1e-12


def _engine_metric(metric_name: str) -> str:
    """The metric the fused engine actually computes with.  Supermetric
    cosine IS l2 on the unit sphere, so cosine rides the l2 kernels; every
    other metric is served natively."""
    return "l2" if metric_name == "cosine" else metric_name


def _engine_queries(metric_name: str, queries: np.ndarray) -> np.ndarray:
    """Map queries into the engine's kernel space (unit sphere for cosine;
    identity otherwise).  The corpus side happens once, in ``build_bss``."""
    if metric_name == "cosine":
        norms = np.linalg.norm(queries, axis=-1, keepdims=True)
        queries = queries / np.maximum(norms, _MIN_NORM)
    return np.asarray(queries, np.float32)


class BSSDeviceArrays(NamedTuple):
    """Device-resident mirror of the index, built once per index."""

    data: jnp.ndarray    # (n_pad, dim)
    pivots: jnp.ndarray  # (P, dim)
    pairs: jnp.ndarray   # (M, 2)
    deltas: jnp.ndarray  # (M,)
    boxes: jnp.ndarray   # (n_blocks, M, 4)
    valid: jnp.ndarray   # (n_pad,) bool


@dataclasses.dataclass
class BSSIndex:
    metric_name: str
    data: np.ndarray          # (n_pad, dim) permuted + padded
    perm: np.ndarray          # (n_pad,) original index, -1 for padding
    valid: np.ndarray         # (n_pad,) bool
    pivots: np.ndarray        # (P, dim)
    pairs: np.ndarray         # (M, 2) pivot indices per plane
    deltas: np.ndarray        # (M,)
    boxes: np.ndarray         # (n_blocks, M, 4) = x_lo, x_hi, y_lo, y_hi
    block: int
    # build provenance + living-corpus bookkeeping (repro.index.maintain):
    # mutations are FUNCTIONAL — append/delete/compact return a new index
    # sharing unchanged arrays — so a generation is a consistent snapshot
    # (the serving front swaps whole generations between micro-batches).
    seed: int = 0        # build seed; compact reuses it for layout parity
    generation: int = 0  # bumped by every append/delete/compact
    next_id: int = 0     # next original id an append will assign
    tombstones: int = 0  # rows deleted since build/last compact
    # when set, device arrays are born with a NamedSharding over the mesh's
    # data axes (corpus blocks partitioned, reference tables replicated) and
    # the batched query paths route through the sharded engine
    mesh: Mesh | None = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _device: BSSDeviceArrays | None = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _sharded: object | None = dataclasses.field(
        default=None, repr=False, compare=False
    )
    # bf16 exact-phase mirror (lazy): the corpus rounded to bfloat16 for the
    # halved-HBM scan, plus the derived comparison margin.  Reference tables
    # (pivots / deltas / boxes) deliberately stay fp32: rounding them would
    # perturb the survival sets and break the bit-identical-counts contract,
    # and they are a rounding-error of the corpus traffic anyway.
    _bf16: jnp.ndarray | None = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _bf16_eps: float | None = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def n_blocks(self) -> int:
        return self.boxes.shape[0]

    @property
    def n_valid(self) -> int:
        return int(self.valid.sum())

    @property
    def tombstone_frac(self) -> float:
        """Deleted fraction of the rows the layout still carries — the
        compaction trigger (``repro.index.maintain.maybe_compact``)."""
        return self.tombstones / max(self.tombstones + self.n_valid, 1)

    @property
    def metric(self) -> Metric:
        return get_metric(self.metric_name)

    @property
    def device(self) -> BSSDeviceArrays:
        """Device-resident mirror, built once.  With a mesh attached this is
        the SHARDED mirror (block count padded to the shard count, arrays
        placed with their NamedSharding at birth — never re-laid-out per
        query); without one, plain single-device arrays."""
        if self.mesh is not None:
            return self.sharded().dev
        if self._device is None:
            self._device = BSSDeviceArrays(
                data=jnp.asarray(self.data, jnp.float32),
                pivots=jnp.asarray(self.pivots, jnp.float32),
                pairs=jnp.asarray(self.pairs, jnp.int32),
                deltas=jnp.asarray(self.deltas, jnp.float32),
                boxes=jnp.asarray(self.boxes, jnp.float32),
                valid=jnp.asarray(self.valid),
            )
        return self._device

    @property
    def device_bf16(self) -> jnp.ndarray:
        """(n_pad, dim) bfloat16 corpus mirror, built once.  The tile
        kernels upcast to fp32 on entry, so streaming this halves corpus
        HBM traffic with fp32 accumulation unchanged."""
        if self._bf16 is None:
            self._bf16 = jnp.asarray(self.data, jnp.bfloat16)
        return self._bf16

    def bf16_margin(self) -> float:
        """Conservative threshold margin for the bf16 phase (derivation in
        ``repro.core.precision``): measured in the ENGINE metric over the
        engine-space corpus (already unit-normalised for cosine), computed
        once per index."""
        if self._bf16_eps is None:
            from repro.core.precision import bf16_margin

            self._bf16_eps = bf16_margin(
                _engine_metric(self.metric_name), self.data, self.valid
            )
        return self._bf16_eps

    def sharded(self, mesh: Mesh | None = None):
        """The :class:`~repro.parallel.shard_index.ShardedBSSIndex` view of
        this index over ``mesh`` (default: the mesh given at build time),
        cached per mesh."""
        mesh = mesh if mesh is not None else self.mesh
        if mesh is None:
            raise ValueError(
                "no mesh: pass one here or build with build_bss(mesh=...)"
            )
        if self._sharded is None or self._sharded.mesh is not mesh:
            from repro.parallel.shard_index import ShardedBSSIndex

            self._sharded = ShardedBSSIndex(self, mesh)
        return self._sharded


def _project_all(dp: np.ndarray, pairs: np.ndarray, deltas: np.ndarray):
    """dp: (n, P) pivot distances -> (n, M) x and (n, M) y planar coords.

    SAME implementation as the query side (``projection.project``, numpy
    namespace) — in particular degenerate planes (duplicate pivots) collapse
    to the ring (0, d1) on BOTH sides, or the box/query geometries would
    diverge unsoundly."""
    return projection.project(
        dp[:, pairs[:, 0]], dp[:, pairs[:, 1]], deltas[None, :], xp=np
    )


def _split_perm(feats: np.ndarray, block: int) -> np.ndarray:
    """Locality-preserving permutation of ``len(feats)`` rows: recursive
    max-variance median split of the margin space down to block-sized
    leaves.  Shared by ``build_bss`` and the append path
    (``repro.index.maintain``) so both lay rows out identically."""
    out: list[np.ndarray] = []

    def split(idx: np.ndarray):
        if len(idx) <= block:
            out.append(idx)
            return
        sub = feats[idx]
        dimm = int(np.argmax(sub.var(axis=0)))
        order = np.argsort(sub[:, dimm], kind="stable")
        half = len(idx) // 2
        split(idx[order[:half]])
        split(idx[order[half:]])

    split(np.arange(len(feats), dtype=np.int64))
    return np.concatenate(out)


def _pack_blocks(
    data_rows: np.ndarray, x: np.ndarray, y: np.ndarray, block: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad already-permuted engine-space rows to whole blocks and compute
    the per (block × plane) bounding boxes — the packing half of
    ``build_bss``, shared with the append path so appended blocks are
    bit-identical to built ones.  Returns ``(data_pad, valid, boxes)``."""
    n, m = x.shape
    n_blocks = math.ceil(n / block)
    pad = n_blocks * block - n
    valid = np.concatenate(
        [np.ones(n, dtype=bool), np.zeros(pad, dtype=bool)]
    )
    data_pad = np.concatenate(
        [data_rows, np.zeros((pad, data_rows.shape[1]), np.float32)]
    )
    xs = np.concatenate([x, np.zeros((pad, m), np.float32)])
    ys = np.concatenate([y, np.zeros((pad, m), np.float32)])
    xs = xs.reshape(n_blocks, block, m)
    ys = ys.reshape(n_blocks, block, m)
    vmask = valid.reshape(n_blocks, block, 1)
    big = np.float32(3.4e38)
    boxes = np.stack(
        [
            np.where(vmask, xs, big).min(axis=1),
            np.where(vmask, xs, -big).max(axis=1),
            np.where(vmask, ys, big).min(axis=1),
            np.where(vmask, ys, -big).max(axis=1),
        ],
        axis=-1,
    ).astype(np.float32)  # (n_blocks, M, 4)
    return data_pad, valid, boxes


def build_bss(
    metric_name: str,
    data: np.ndarray,
    n_pivots: int = 16,
    n_pairs: int = 24,
    block: int = 128,
    seed: int = 0,
    mesh: Mesh | None = None,
) -> BSSIndex:
    """Build the blocked index (module docstring).  With ``mesh`` the device
    mirror is born sharded over the mesh's data axes and the batched query
    paths serve through the sharded engine (``repro.parallel.shard_index``);
    the host arrays and the numpy oracle are unaffected."""
    metric = get_metric(metric_name)  # validates; registers power names
    if not metric.four_point:
        raise ValueError(
            f"{metric_name!r} lacks the four-point property — planar "
            f"exclusion would be unsound.  Use a supermetric, or its "
            f"power transform (e.g. {metric_name}^0.5, paper §2.2)."
        )
    data = np.asarray(data, np.float32)
    if metric_name == "cosine":
        # Corpus onto the unit sphere once: supermetric cosine distance IS
        # l2 there, so the whole engine (projection, kernels, exact phase)
        # runs the l2 path with zero approximation.
        norms = np.linalg.norm(data, axis=1, keepdims=True)
        data = data / np.maximum(norms, _MIN_NORM)
    return _build_engine_index(
        metric_name, data, n_pivots=n_pivots, n_pairs=n_pairs, block=block,
        seed=seed, mesh=mesh,
    )


def _build_engine_index(
    metric_name: str,
    data: np.ndarray,
    *,
    n_pivots: int,
    n_pairs: int,
    block: int,
    seed: int,
    mesh: Mesh | None,
) -> BSSIndex:
    """``build_bss`` body over ENGINE-SPACE rows (already f32, already on
    the unit sphere for cosine).  Split out so ``repro.index.maintain``'s
    compact can rebuild from an index's stored rows with the EXACT ops of a
    fresh build — stored cosine rows are normalised once at original build,
    and renormalising them is not bit-stable."""
    rng = np.random.default_rng(seed)
    build_metric = _engine_metric(metric_name)
    n = data.shape[0]
    piv_idx = select_fft(build_metric, data, n_pivots, rng)
    pivots = data[piv_idx]

    # All pivot pairs, keep the M most separated (wide baselines give the
    # best-conditioned planes; beyond that the paper shows insensitivity).
    pd = pairwise_np(build_metric, pivots, pivots)
    cand = [(pd[i, j], i, j) for i in range(n_pivots) for j in range(i + 1, n_pivots)]
    cand.sort(reverse=True)
    m = min(n_pairs, len(cand))
    pairs = np.array([[i, j] for _, i, j in cand[:m]], dtype=np.int32)
    deltas = np.array([d for d, _, _ in cand[:m]], dtype=np.float32)

    dp = pairwise_np(build_metric, data, pivots).astype(np.float32)  # (n, P)
    x, y = _project_all(dp, pairs, deltas)  # (n, M) each
    feats = np.concatenate([x, y], axis=1)  # (n, 2M) margin space

    # locality-preserving permutation + MXU-aligned packing (helpers shared
    # with the append path, which runs them over new rows only)
    perm = _split_perm(feats, block)
    dsorted, valid, boxes = _pack_blocks(data[perm], x[perm], y[perm], block)
    pad = valid.shape[0] - n
    perm_pad = np.concatenate([perm, np.full(pad, -1, dtype=np.int64)])

    return BSSIndex(
        metric_name=metric_name,
        data=dsorted,
        perm=perm_pad,
        valid=valid,
        pivots=np.asarray(pivots, np.float32),
        pairs=pairs,
        deltas=deltas,
        boxes=boxes,
        block=block,
        seed=seed,
        next_id=n,
        mesh=mesh,
    )


@partial(jax.jit, static_argnames=("metric_name",))
def _lower_bounds_jit(
    metric_name: str,
    queries: jnp.ndarray,
    pivots: jnp.ndarray,
    pairs: jnp.ndarray,
    deltas: jnp.ndarray,
    boxes: jnp.ndarray,
) -> jnp.ndarray:
    """(Q, n_blocks) sound lower bound on d(q, any point in block).

    Thin jit wrapper over the shared bound math in ``_fused_lower_bounds``
    (jnp branch) — one definition serves the oracle, the stats helpers and
    the fused engine alike."""
    return _fused_lower_bounds(
        metric_name, queries, pivots, pairs, deltas, boxes,
        backend="jnp", bq=_DEFAULT_BQ, interpret=None,
    )


def bss_lower_bounds(index: BSSIndex, queries: np.ndarray) -> np.ndarray:
    queries = _engine_queries(index.metric_name, np.asarray(queries, np.float32))
    return np.asarray(
        _lower_bounds_jit(
            _engine_metric(index.metric_name),
            jnp.asarray(queries),
            jnp.asarray(index.pivots),
            jnp.asarray(index.pairs),
            jnp.asarray(index.deltas),
            jnp.asarray(index.boxes),
        )
    )


def _valid_per_block(index: BSSIndex) -> np.ndarray:
    """(n_blocks,) number of REAL corpus points per block.  The final block
    of a corpus whose size is not a multiple of ``block`` is partially
    padding; distance accounting must count only the valid slots."""
    return index.valid.reshape(index.n_blocks, index.block).sum(axis=1)


def _exact_counts(index: BSSIndex, alive: np.ndarray) -> np.ndarray:
    """(Q,) exact distance evaluations implied by a (Q, n_blocks) survival
    matrix — per-block VALID counts, not ``survived * block`` (which would
    count padded slots as distance evaluations and inflate the paper's
    figure of merit)."""
    return alive.astype(np.int64) @ _valid_per_block(index)


def _per_query_t(t, nq: int) -> np.ndarray:
    """Range thresholds as a (Q,) float32 vector: a scalar ``t`` broadcasts
    to every query; a vector carries PER-QUERY radii (the serving front
    mixes thresholds inside one micro-batch this way, and marks its padding
    rows with a negative radius — the planar bound is >= 0, so such a row
    survives no block, evaluates no distances and hits nothing)."""
    t_arr = np.asarray(t, np.float32)
    if t_arr.ndim == 0:
        return np.full(nq, float(t_arr), np.float32)
    if t_arr.shape != (nq,):
        raise ValueError(
            f"per-query t must have shape ({nq},), got {t_arr.shape}"
        )
    return t_arr


def bss_query(
    index: BSSIndex, queries: np.ndarray, t
) -> tuple[list[list[int]], dict]:
    """Exact range search — the NUMPY ORACLE path (see module docstring).

    ``t`` is a scalar threshold or a (Q,) vector of per-query radii.
    Returns per-query hit lists (original indices) and stats including the
    paper's figure of merit (distances/query: P pivot distances + the VALID
    points of each surviving block)."""
    queries = np.asarray(queries, np.float32)
    nq = queries.shape[0]
    t_vec = _per_query_t(t, nq)
    lb = bss_lower_bounds(index, queries)  # (Q, B)
    alive = lb <= t_vec[:, None]
    results: list[list[int]] = [[] for _ in range(nq)]
    bsz = index.block
    data = index.data
    # exact phase: per block, evaluate only the surviving queries
    for b in np.nonzero(alive.any(axis=0))[0]:
        qrows = np.nonzero(alive[:, b])[0]
        blk = data[b * bsz : (b + 1) * bsz]
        d = pairwise_np(index.metric_name, queries[qrows], blk)
        hits = d <= t_vec[qrows][:, None]
        for r, qi in enumerate(qrows):
            for off in np.nonzero(hits[r])[0]:
                orig = index.perm[b * bsz + off]
                if orig >= 0:
                    results[int(qi)].append(int(orig))
    n_pivots = index.pivots.shape[0]
    exact = _exact_counts(index, alive)  # padding-free, per query
    stats = {
        "pivot_dists_per_query": float(n_pivots),
        "exact_dists_per_query": float(exact.mean()),
        "dists_per_query": float(n_pivots + exact.mean()),
        "per_query_dists": n_pivots + exact,
        "block_exclusion_rate": float(1.0 - alive.mean()),
        "n_blocks": int(index.n_blocks),
        "generation": int(index.generation),
    }
    return results, stats


# ---------------------------------------------------------------------------
# Fused batched engine
# ---------------------------------------------------------------------------


# shared with the device forest walker — see repro.core.backends
_tile_survival = tile_survival
_resolve_backend = resolve_backend


def _fused_lower_bounds(
    metric_name: str,
    queries: jnp.ndarray,
    dev_pivots: jnp.ndarray,
    dev_pairs: jnp.ndarray,
    dev_deltas: jnp.ndarray,
    dev_boxes: jnp.ndarray,
    *,
    backend: str,
    bq: int,
    interpret: bool | None,
) -> jnp.ndarray:
    """(Q, B) planar lower bounds, through the Pallas kernels or pure jnp.

    ``metric_name`` is the ENGINE metric (cosine arrives here as l2 over
    pre-normalised queries).  Metrics with a registered tile kernel compute
    the query→pivot distances through it on the pallas backend; the rest
    (power transforms) use their jnp pairwise."""
    if backend == "pallas" and metric_name in KERNEL_METRICS:
        dqp = pairwise_kernel_call(
            metric_name, queries, dev_pivots, interpret=interpret
        )
    else:
        dqp = get_metric(metric_name).pairwise(queries, dev_pivots)  # (Q, P)
    d1 = dqp[:, dev_pairs[:, 0]]
    d2 = dqp[:, dev_pairs[:, 1]]
    if backend == "pallas":
        return planar_lower_bound_kernel_call(
            d1, d2, dev_deltas, dev_boxes, bq=bq, interpret=interpret
        )
    qx, qy = projection.project(d1, d2, dev_deltas[None, :])  # (Q, M)
    # (Q, 1, M) vs boxes (1, B, M, 4) -> per-plane bound, max over planes.
    lb = projection.point_to_box(qx[:, None, :], qy[:, None, :], dev_boxes[None])
    return jnp.max(lb, axis=-1)  # (Q, B)


def _masked_exact_dists(
    metric_name: str,
    queries: jnp.ndarray,
    dev_data: jnp.ndarray,
    dev_valid: jnp.ndarray,
    tile_mask: jnp.ndarray,
    *,
    backend: str,
    block: int,
    bq: int,
    interpret: bool | None,
) -> jnp.ndarray:
    """(Q, n_pad) exact distances for surviving (query-tile × block) cells;
    +inf everywhere the mask (or padding) excluded.

    On the pallas backend every metric with a registered tile kernel
    (l2 / jsd / triangular; cosine arrives as l2) runs the masked kernel —
    excluded tiles are skipped on the hardware, not computed-then-masked.
    The dense jnp fallback below serves only kernel-less metrics (power
    transforms) and the dense-survivor regime of the jnp backend; the
    sparse-survivor regime uses the cell-gather realisations
    (``_cells_exact_jit`` for range, ``_knn_round_cells_jit`` for kNN)."""
    if backend == "pallas" and metric_name in KERNEL_METRICS:
        dist = masked_pairwise_kernel_call(
            metric_name, queries, dev_data, tile_mask,
            bm=bq, bn=block, interpret=interpret,
        )
    else:
        # Same masked semantics through XLA: dense metric distances with the
        # survival mask applied.
        dense = get_metric(metric_name).pairwise(queries, dev_data)  # (Q, n_pad)
        mrep = jnp.repeat(
            jnp.repeat(tile_mask, bq, axis=0)[: queries.shape[0]],
            block,
            axis=1,
        )[:, : dev_data.shape[0]]
        dist = jnp.where(mrep, dense, jnp.inf)
    return jnp.where(dev_valid[None, :], dist, jnp.inf)


def _gather_cell_dists(
    metric_name: str,
    queries: jnp.ndarray,
    data: jnp.ndarray,
    valid: jnp.ndarray,
    qidx: jnp.ndarray,
    bidx: jnp.ndarray,
    block: int,
):
    """Shared cell-gather distance block for the sparse range AND kNN
    realisations: evaluate the metric only for the C gathered (query, block)
    cells.  Returns (d (C, block), pvalid (C, block))."""
    dim = data.shape[-1]
    blocks = data.reshape(-1, block, dim)
    gathered = blocks[bidx]  # (C, block, dim)
    qs = queries[qidx]  # (C, dim)
    metric = get_metric(metric_name)
    d = jax.vmap(lambda a, b: metric.pairwise(a[None], b)[0])(qs, gathered)
    pvalid = valid.reshape(-1, block)[bidx]  # (C, block)
    return d, pvalid


@partial(jax.jit, static_argnames=("metric_name", "block", "cap"))
def _cells_exact_jit(
    metric_name: str,
    queries: jnp.ndarray,
    data: jnp.ndarray,
    valid: jnp.ndarray,
    qidx: jnp.ndarray,
    bidx: jnp.ndarray,
    cell_valid: jnp.ndarray,
    t: jnp.ndarray,
    *,
    block: int,
    cap: int,
):
    """Exact phase over an explicit alive-cell list — the XLA realisation of
    the masked Pallas kernel's tile skipping: only the C surviving
    (query, block) cells are gathered and evaluated, and hits leave the
    device as a fixed-capacity compact list instead of a dense (Q, N)
    matrix.  ``t`` is the (Q,) per-query radius vector (each cell tests
    against its own query's radius).  Returns (hit_q (cap,), hit_pos (cap,),
    n_hits); entries past n_hits are -1.  Row-major over (cell, offset) with
    cells sorted by (query, block), so per-query hits come out in ascending
    position order — the oracle's order."""
    d, pvalid = _gather_cell_dists(
        metric_name, queries, data, valid, qidx, bidx, block
    )
    hit = (d <= t[qidx][:, None]) & pvalid & cell_valid[:, None]
    flat = hit.reshape(-1)
    n_hits = jnp.sum(flat)
    (pos,) = jnp.nonzero(flat, size=cap, fill_value=-1)
    cell = pos // block
    off = pos % block
    hit_q = jnp.where(pos >= 0, qidx[cell], -1)
    hit_pos = jnp.where(pos >= 0, bidx[cell] * block + off, -1)
    return hit_q, hit_pos, n_hits


def _next_pow2(x: int, lo: int = 16) -> int:
    return max(lo, 1 << (max(x, 1) - 1).bit_length())


# Above this alive-cell fraction the jnp backend computes the dense distance
# matrix (one big GEMM beats ragged gathers); below it, only the surviving
# cells are gathered.  Empirically ~0.08 on CPU; either branch is exact.
_DENSE_ALIVE_FRAC = 0.08


@partial(jax.jit, static_argnames=("metric_name", "block"))
def _dense_hit_mask_jit(
    metric_name: str,
    queries: jnp.ndarray,
    data: jnp.ndarray,
    valid: jnp.ndarray,
    alive: jnp.ndarray,
    t: jnp.ndarray,
    *,
    block: int,
):
    """Dense exact pass returning the (Q, N) hit BITMASK.

    One big GEMM with the hit test fused into its output traversal — for l2
    the test runs in the squared domain rearranged as
    ``|p|^2 - 2 q.p <= t^2 - |q|^2`` (no sqrt, and the f32 distance matrix
    itself is never materialised as an output) — masked by the per-query
    block survival.  ``t`` is the (Q,) per-query radius vector (a negative
    entry, e.g. a serving-front padding row, hits nothing).  Bools are 4x
    cheaper than the distances to move, and position extraction is a single
    host ``np.nonzero`` over the mask (XLA's sized ``nonzero`` costs seconds
    at this size; numpy's scan is milliseconds)."""
    nq = queries.shape[0]
    if metric_name == "l2":
        qf = queries.astype(jnp.float32)
        df = data.astype(jnp.float32)
        s = -2.0 * (qf @ df.T) + jnp.sum(df * df, axis=-1)[None, :]
        # t < 0 must hit nothing even though t*t > 0: send its threshold
        # to -inf (the squared-domain rearrangement is sign-blind).
        thresh = jnp.where(
            t >= 0, t * t - jnp.sum(qf * qf, axis=-1), -jnp.inf
        )  # (Q,)
        raw_hit = s <= thresh[:, None]
    else:
        raw_hit = get_metric(metric_name).pairwise(queries, data) <= t[:, None]
    hit = (
        raw_hit.reshape(nq, -1, block)
        & alive[:, :, None]
        & valid.reshape(1, -1, block)
    )
    return hit.reshape(nq, -1)


@partial(
    jax.jit,
    static_argnames=("metric_name", "block", "bq", "backend", "interpret"),
)
def _query_batched_jit(
    metric_name: str,
    queries: jnp.ndarray,
    t: jnp.ndarray,
    dev: BSSDeviceArrays,
    *,
    block: int,
    bq: int,
    backend: str,
    interpret: bool | None,
):
    """One fused range-search pass.  Returns (dist (Q, n_pad), alive (Q, B),
    tile_mask (Qtiles, B)).

    ``t`` is the (Q,) per-query radius vector.  dist is +inf wherever the
    planar bound excluded the cell (or padding); every finite entry is an
    exact metric distance.  Exactness: a tile survives when ANY of its
    queries has lb <= its own t, so no true hit of any query is ever pruned
    (per-query hits are re-filtered by d <= t on the host)."""
    lb = _fused_lower_bounds(
        metric_name, queries, dev.pivots, dev.pairs, dev.deltas, dev.boxes,
        backend=backend, bq=bq, interpret=interpret,
    )  # (Q, B)
    alive = lb <= t[:, None]
    tile_mask = _tile_survival(alive, bq)  # (Qtiles, B)
    dist = _masked_exact_dists(
        metric_name, queries, dev.data, dev.valid, tile_mask,
        backend=backend, block=block, bq=bq, interpret=interpret,
    )
    return dist, alive, tile_mask


@partial(
    jax.jit,
    static_argnames=("metric_name", "block", "bq", "backend", "interpret"),
)
def _query_batched_bf16_jit(
    metric_name: str,
    queries: jnp.ndarray,
    t: jnp.ndarray,
    dev: BSSDeviceArrays,
    data16: jnp.ndarray,
    eps: jnp.ndarray,
    *,
    block: int,
    bq: int,
    backend: str,
    interpret: bool | None,
):
    """One fused bf16 range pass with fp32 boundary re-check.

    The bound phase is UNTOUCHED (fp32 reference tables), so ``alive`` /
    ``tile_mask`` — and with them the paper's distance counts — are
    bit-identical to the fp32 engine's.  The scan streams the bf16 corpus
    (fp32 accumulation inside the kernels); with ``eps`` the derived margin
    (``repro.core.precision``):

      * ``d16 <= t - eps``  — SURE hit, no fp32 needed (margin soundness);
      * ``t - eps < d16 <= t + eps`` — boundary band: the fp32 corpus is
        re-scanned ONLY for tiles containing a band cell, through the same
        masked-kernel machinery, so every consulted fp32 value is the very
        value the fp32 engine computes — the final hit set is bit-identical;
      * everything else — sure miss (no true hit can have d16 > t + eps).

    Returns (hit (Q, n_pad) bool, alive (Q, B), tile_mask, recheck_tiles
    scalar, band_counts (Q,) int32)."""
    lb = _fused_lower_bounds(
        metric_name, queries, dev.pivots, dev.pairs, dev.deltas, dev.boxes,
        backend=backend, bq=bq, interpret=interpret,
    )
    alive = lb <= t[:, None]
    tile_mask = _tile_survival(alive, bq)
    d16 = _masked_exact_dists(
        metric_name, queries, data16, dev.valid, tile_mask,
        backend=backend, block=block, bq=bq, interpret=interpret,
    )
    t_col = t[:, None]
    sure = d16 <= t_col - eps
    band = (d16 <= t_col + eps) & ~sure
    band_blocks = band.reshape(queries.shape[0], -1, block).any(axis=2)
    recheck_mask = _tile_survival(band_blocks, bq) & tile_mask
    d32 = _masked_exact_dists(
        metric_name, queries, dev.data, dev.valid, recheck_mask,
        backend=backend, block=block, bq=bq, interpret=interpret,
    )
    hit = sure | (band & (d32 <= t_col))
    return (
        hit, alive, tile_mask, jnp.sum(recheck_mask),
        jnp.sum(band, axis=1, dtype=jnp.int32),
    )


@partial(jax.jit, static_argnames=("metric_name", "block", "cap"))
def _cells_exact_bf16_jit(
    metric_name: str,
    queries: jnp.ndarray,
    data16: jnp.ndarray,
    valid: jnp.ndarray,
    qidx: jnp.ndarray,
    bidx: jnp.ndarray,
    cell_valid: jnp.ndarray,
    t: jnp.ndarray,
    eps: jnp.ndarray,
    *,
    block: int,
    cap: int,
):
    """Sparse (cell-gather) realisation of the bf16 range phase: like
    ``_cells_exact_jit`` but over the bf16 corpus.  Emits a compact list of
    the hits from cells with NO boundary-band point (``d16 <= t - eps``
    everywhere it fires — final by margin soundness) plus per-cell band
    flags: cells holding any ``t - eps < d16 <= t + eps`` point go back
    through the fp32 ``_cells_exact_jit`` (same gather shapes as the fp32
    engine, so every re-checked value is bit-identical to what that engine
    computes, and within a band cell its hit mask IS the fp32 engine's).
    Returns (hit_q, hit_pos, n_hits, band_cell (C,), band_counts (Q,))."""
    d, pvalid = _gather_cell_dists(
        metric_name, queries, data16, valid, qidx, bidx, block
    )
    ok = pvalid & cell_valid[:, None]
    tq = t[qidx][:, None]
    sure = (d <= tq - eps) & ok
    band = (d <= tq + eps) & ok & ~sure
    band_cell = band.any(axis=1)  # (C,)
    flat = (sure & ~band_cell[:, None]).reshape(-1)
    n_hits = jnp.sum(flat)
    (pos,) = jnp.nonzero(flat, size=cap, fill_value=-1)
    cell = pos // block
    off = pos % block
    hit_q = jnp.where(pos >= 0, qidx[cell], -1)
    hit_pos = jnp.where(pos >= 0, bidx[cell] * block + off, -1)
    nq = queries.shape[0]
    band_counts = jnp.zeros(nq, jnp.int32).at[
        jnp.clip(qidx, 0, nq - 1)
    ].add(jnp.sum(band, axis=1, dtype=jnp.int32))
    return hit_q, hit_pos, n_hits, band_cell, band_counts


def _batched_stats(index: BSSIndex, alive: np.ndarray, tile_mask: np.ndarray) -> dict:
    """The paper's figure of merit for a fused pass.  ``alive`` counts each
    query's own surviving blocks (the oracle's accounting, comparable across
    engines) weighted by per-block VALID point counts — padded slots are
    never counted as distance evaluations; ``tiles_computed`` counts what
    the hardware actually ran (tile-level OR over the query tile)."""
    n_pivots = index.pivots.shape[0]
    exact = _exact_counts(index, alive)
    mean_exact = float(exact.mean()) if exact.size else 0.0
    return {
        "pivot_dists_per_query": float(n_pivots),
        "exact_dists_per_query": mean_exact,
        "dists_per_query": float(n_pivots) + mean_exact,
        # per-request accounting for the serving front: each query's OWN
        # charge (pivot distances + its surviving blocks' valid points)
        "per_query_dists": n_pivots + exact,
        "block_exclusion_rate": float(1.0 - alive.mean()) if alive.size else 1.0,
        "tiles_computed": int(tile_mask.sum()),
        "tile_exclusion_rate": (
            float(1.0 - tile_mask.mean()) if tile_mask.size else 1.0
        ),
        "n_blocks": int(index.n_blocks),
        "generation": int(index.generation),
        # per-mechanism attribution (repro.obs.schema): every block BSS
        # excludes is excluded by the planar four-point bound — the Hilbert
        # mechanism — read off the engine's functional `alive` output
        "excluded": {
            "hilbert": (
                index.n_blocks - alive.sum(axis=1)
            ).astype(np.int64),
        },
    }


def _finish_stats(stats: dict, *, kind: str, backend: str,
                  engine: str = "bss") -> dict:
    """Stamp the shared observability schema onto an engine stats dict at
    the jit boundary (see ``repro.obs.schema`` for the contract)."""
    return obs_schema.normalise_stats(
        stats, engine=engine, kind=kind, backend=backend,
        n_queries=int(np.asarray(stats["per_query_dists"]).shape[0]),
        excluded=stats.get("excluded"),
    )


def bss_query_batched(
    index: BSSIndex,
    queries: np.ndarray,
    t,
    *,
    opts: EngineOpts | None = None,
    bq: int | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
    realisation: str | None = None,
    precision: str | None = None,
) -> tuple[list[list[int]], dict]:
    """Exact range search through the fused jitted engine.

    Engine options travel as one ``opts=EngineOpts(...)`` record
    (``repro.core.backends``); the per-knob kwargs are the legacy spelling,
    kept working through :func:`resolve_engine_opts` (they warn under
    ``REPRO_STRICT_API=1``).

    ``precision="bf16"`` streams the bfloat16 corpus mirror through the
    exact phase (half the corpus HBM traffic; fp32 accumulation unchanged)
    and re-checks the boundary band ``|d16 - t| <= eps`` against the fp32
    corpus — hits AND per-query distance counts stay bit-identical to the
    fp32 engine (margin derivation: ``repro.core.precision``).  Stats gain
    ``band_eps`` / ``recheck_tiles`` / ``per_query_recheck`` telemetry;
    the paper's figure of merit (``per_query_dists``) is charged exactly as
    in fp32 — re-checked points are reported separately, never double
    counted.

    ``t`` is a scalar threshold or a (Q,) vector of PER-QUERY radii — the
    serving front mixes thresholds inside one micro-batch this way; each
    query's survival, hits and distance accounting use only its own radius,
    so every row is exactly the single-threshold engine's row (a negative
    radius excludes its row from everything — the front's padding).

    ``realisation="dense"`` pins the jnp backend to the dense exact phase:
    the sparse cell-gather realisation pads its alive-cell count to a
    DATA-DEPENDENT power of two, so a latency-sensitive caller (the async
    serving front) would pay an unpredictable mid-stream recompile whenever
    traffic produces a fresh shape class — the dense pass's shapes are
    fixed by (Q, N) alone, keeping compile count bounded by the front's
    bucket ladder.  Either realisation is exact; "adaptive" (default)
    picks by survivor density as before.

    Bit-equal to the ``bss_query`` oracle's hit lists (same indices, same
    per-query order) whenever float32 and float64 agree on ``d <= t`` —
    which the test suite enforces at safe thresholds.

    The ``pallas`` backend runs the dense masked kernel (tile skipping on
    TPU, interpret mode in tests).  The ``jnp`` backend picks its exact
    phase by survivor density: below ``_DENSE_ALIVE_FRAC`` only the alive
    (query, block) cells are gathered (``_cells_exact_jit``); above it one
    dense per-query-masked pass wins (``_dense_hit_mask_jit``).  Either
    way only compact hits / a bitmask cross back to the host — never the
    distance matrix.

    A mesh-built index (``build_bss(mesh=...)``) serves through the sharded
    engine — one shard-local fused pass per device, hit bitmasks
    concatenated back in corpus order; results and stats are identical."""
    opts = resolve_engine_opts(
        opts, bq=bq, backend=backend, interpret=interpret,
        realisation=realisation, precision=precision,
    )
    if index.mesh is not None:
        from repro.parallel.shard_index import sharded_query_batched

        return sharded_query_batched(index.sharded(), queries, t, opts=opts)
    bq = opts.bq if opts.bq is not None else _DEFAULT_BQ
    interpret = opts.interpret
    realisation = opts.realisation
    precision = opts.precision
    backend = _resolve_backend(opts.backend)
    metric_eng = _engine_metric(index.metric_name)
    queries = _engine_queries(index.metric_name, np.asarray(queries, np.float32))
    nq = queries.shape[0]
    if nq == 0:
        stats = _batched_stats(
            index,
            np.zeros((0, index.n_blocks), bool),
            np.zeros((0, index.n_blocks), bool),
        )
        stats["precision"] = precision
        if precision == "bf16":
            _bf16_stats(stats, index.bf16_margin(), 0, np.zeros(0, np.int64))
        return [], _finish_stats(stats, kind="range", backend=backend)
    t_vec = _per_query_t(t, nq)
    dev = index.device
    if precision == "bf16":
        return _query_batched_bf16(
            index, metric_eng, queries, t_vec, dev,
            bq=bq, backend=backend, interpret=interpret,
            realisation=realisation,
        )
    if backend == "jnp":
        qj = jnp.asarray(queries)
        lb = np.asarray(
            _lower_bounds_jit(
                metric_eng, qj, dev.pivots, dev.pairs, dev.deltas,
                dev.boxes,
            )
        )
        alive = lb <= t_vec[:, None]
        if realisation == "dense" or alive.mean() > _DENSE_ALIVE_FRAC:
            mask = np.asarray(
                _dense_hit_mask_jit(
                    metric_eng, qj, dev.data, dev.valid,
                    jnp.asarray(alive), jnp.asarray(t_vec), block=index.block,
                )
            )
            hit_q, hit_pos = np.nonzero(mask)  # (query, position) ascending
        else:
            qidx, bidx = np.nonzero(alive)  # sorted by (query, block)
            c = len(qidx)
            c_pad = _next_pow2(c)
            cell_valid = jnp.asarray(np.arange(c_pad) < c)
            qidx_p = jnp.asarray(np.pad(qidx, (0, c_pad - c)), jnp.int32)
            bidx_p = jnp.asarray(np.pad(bidx, (0, c_pad - c)), jnp.int32)
            cap = _next_pow2(8 * max(nq, 1), lo=1024)
            while True:
                hit_q, hit_pos, n_hits = _cells_exact_jit(
                    metric_eng, qj, dev.data, dev.valid,
                    qidx_p, bidx_p, cell_valid, jnp.asarray(t_vec),
                    block=index.block, cap=cap,
                )
                n_hits = int(n_hits)
                if n_hits <= cap:
                    break
                cap = _next_pow2(n_hits)  # rare: recompile, bigger bucket
            hit_q = np.asarray(hit_q)[:n_hits]
            hit_pos = np.asarray(hit_pos)[:n_hits]
        orig = index.perm[hit_pos]
        counts = np.bincount(hit_q, minlength=nq)
        per_query = np.split(orig, np.cumsum(counts)[:-1])
        results = [r.tolist() for r in per_query]
        tile_mask = np.asarray(_tile_survival(jnp.asarray(alive), bq))
        stats = _batched_stats(index, alive, tile_mask)
        stats["precision"] = "fp32"
        return results, _finish_stats(stats, kind="range", backend=backend)
    dist, alive, tile_mask = _query_batched_jit(
        metric_eng,
        jnp.asarray(queries),
        jnp.asarray(t_vec),
        dev,
        block=index.block,
        bq=bq,
        backend=backend,
        interpret=interpret,
    )
    dist = np.asarray(dist)
    hit = dist <= t_vec[:, None]
    qidx, pidx = np.nonzero(hit)  # row-major: pidx ascending within a query
    orig = index.perm[pidx]
    counts = hit.sum(axis=1)
    per_query = np.split(orig, np.cumsum(counts)[:-1])
    results = [r.tolist() for r in per_query]
    stats = _batched_stats(index, np.asarray(alive), np.asarray(tile_mask))
    stats["precision"] = "fp32"
    return results, _finish_stats(stats, kind="range", backend=backend)


def _bf16_stats(stats: dict, eps: float, recheck_tiles: int,
                per_query_recheck: np.ndarray) -> dict:
    """Augment an engine stats dict with the bf16 re-check telemetry.  The
    existing keys (the paper's figure of merit included) are bit-identical
    to the fp32 engine's; the re-check volume is reported SEPARATELY so the
    count-parity contract survives."""
    stats["precision"] = "bf16"
    stats["band_eps"] = float(eps)
    stats["recheck_tiles"] = int(recheck_tiles)
    stats["per_query_recheck"] = np.asarray(per_query_recheck, np.int64)
    stats["recheck_points_per_query"] = (
        float(stats["per_query_recheck"].mean())
        if stats["per_query_recheck"].size else 0.0
    )
    return stats


def _query_batched_bf16(
    index: BSSIndex,
    metric_eng: str,
    queries: np.ndarray,
    t_vec: np.ndarray,
    dev: BSSDeviceArrays,
    *,
    bq: int,
    backend: str,
    interpret: bool | None,
    realisation: str,
) -> tuple[list[list[int]], dict]:
    """Host driver for the bf16 range phase (both realisations); see
    ``_query_batched_bf16_jit`` for the dense scheme and
    ``_cells_exact_bf16_jit`` for the sparse one."""
    nq = queries.shape[0]
    eps = index.bf16_margin()
    data16 = index.device_bf16
    qj = jnp.asarray(queries)
    eps_j = jnp.float32(eps)
    if backend == "jnp" and realisation != "dense":
        lb = np.asarray(
            _lower_bounds_jit(
                metric_eng, qj, dev.pivots, dev.pairs, dev.deltas, dev.boxes,
            )
        )
        alive = lb <= t_vec[:, None]
        # Same adaptive branch condition as fp32 (it reads only the fp32
        # bound phase), so both precisions pick the same realisation.
        if alive.mean() <= _DENSE_ALIVE_FRAC:
            qidx, bidx = np.nonzero(alive)  # sorted by (query, block)
            c = len(qidx)
            c_pad = _next_pow2(c)
            qidx_p = np.pad(qidx, (0, c_pad - c)).astype(np.int32)
            bidx_p = np.pad(bidx, (0, c_pad - c)).astype(np.int32)
            cell_valid = jnp.asarray(np.arange(c_pad) < c)
            tj = jnp.asarray(t_vec)
            cap = _next_pow2(8 * max(nq, 1), lo=1024)
            while True:
                hit_q, hit_pos, n_hits, band_cell, band_counts = (
                    _cells_exact_bf16_jit(
                        metric_eng, qj, data16, dev.valid,
                        jnp.asarray(qidx_p), jnp.asarray(bidx_p),
                        cell_valid, tj, eps_j,
                        block=index.block, cap=cap,
                    )
                )
                n_hits = int(n_hits)
                if n_hits <= cap:
                    break
                cap = _next_pow2(n_hits)
            hit_q = np.asarray(hit_q)[:n_hits]
            hit_pos = np.asarray(hit_pos)[:n_hits]
            band_counts = np.asarray(band_counts)
            # fp32 re-check of every band CELL through the fp32 engine's own
            # sparse realisation — values and hit masks bit-identical to it.
            band_cells = np.nonzero(np.asarray(band_cell))[0]
            if band_cells.size:
                q2 = qidx_p[band_cells]
                b2 = bidx_p[band_cells]
                c2 = len(band_cells)
                c2_pad = _next_pow2(c2)
                cap2 = _next_pow2(8 * max(nq, 1), lo=1024)
                while True:
                    rq, rp, n_r = _cells_exact_jit(
                        metric_eng, qj, dev.data, dev.valid,
                        jnp.asarray(np.pad(q2, (0, c2_pad - c2)), jnp.int32),
                        jnp.asarray(np.pad(b2, (0, c2_pad - c2)), jnp.int32),
                        jnp.asarray(np.arange(c2_pad) < c2), tj,
                        block=index.block, cap=cap2,
                    )
                    n_r = int(n_r)
                    if n_r <= cap2:
                        break
                    cap2 = _next_pow2(n_r)
                hit_q = np.concatenate([hit_q, np.asarray(rq)[:n_r]])
                hit_pos = np.concatenate([hit_pos, np.asarray(rp)[:n_r]])
                order = np.lexsort((hit_pos, hit_q))
                hit_q = hit_q[order]
                hit_pos = hit_pos[order]
            orig = index.perm[hit_pos]
            counts = np.bincount(hit_q, minlength=nq)
            per_query = np.split(orig, np.cumsum(counts)[:-1])
            results = [r.tolist() for r in per_query]
            tile_mask = np.asarray(_tile_survival(jnp.asarray(alive), bq))
            stats = _batched_stats(index, alive, tile_mask)
            _bf16_stats(stats, eps, 0, band_counts)
            return results, _finish_stats(
                stats, kind="range", backend=backend
            )
    hit, alive, tile_mask, recheck_tiles, band_counts = (
        _query_batched_bf16_jit(
            metric_eng, qj, jnp.asarray(t_vec), dev, data16, eps_j,
            block=index.block, bq=bq, backend=backend, interpret=interpret,
        )
    )
    hit = np.asarray(hit)
    hit_q, hit_pos = np.nonzero(hit)  # row-major: positions ascending
    orig = index.perm[hit_pos]
    counts = hit.sum(axis=1)
    per_query = np.split(orig, np.cumsum(counts)[:-1])
    results = [r.tolist() for r in per_query]
    stats = _batched_stats(index, np.asarray(alive), np.asarray(tile_mask))
    _bf16_stats(stats, eps, int(recheck_tiles), np.asarray(band_counts))
    return results, _finish_stats(stats, kind="range", backend=backend)


@partial(
    jax.jit,
    static_argnames=("metric_name", "block", "bq", "k", "backend", "interpret"),
)
def _knn_round_jit(
    metric_name: str,
    queries: jnp.ndarray,
    radii: jnp.ndarray,
    lb: jnp.ndarray,
    dev: BSSDeviceArrays,
    *,
    k: int,
    block: int,
    bq: int,
    backend: str,
    interpret: bool | None,
):
    """One batched radius-deepening round over ALL queries.

    ``lb`` is the radius-independent (Q, B) planar bound matrix, computed
    once by the caller and reused across rounds.  Returns (cand_idx (Q, k)
    positions in the permuted layout, cand_dist (Q, k) ascending, kth (Q,),
    done (Q,), alive (Q, B), tile_mask).

    ``done`` is sound: if the kth-smallest computed distance is <= the
    query's radius, every unevaluated point sits in a block whose planar
    lower bound exceeds the radius, hence is farther than the kth candidate
    — the top-k is final."""
    alive = lb <= radii[:, None]
    tile_mask = _tile_survival(alive, bq)
    dist = _masked_exact_dists(
        metric_name, queries, dev.data, dev.valid, tile_mask,
        backend=backend, block=block, bq=bq, interpret=interpret,
    )  # (Q, n_pad), +inf where pruned/padding
    neg, cand_idx = jax.lax.top_k(-dist, k)  # k smallest distances
    cand_dist = -neg  # ascending
    kth = cand_dist[:, -1]
    # done when nothing unevaluated can beat the kth candidate: either the
    # radius covers it, or every block was computed anyway.
    done = jnp.isfinite(kth) & ((kth <= radii) | jnp.all(alive, axis=1))
    return cand_idx, cand_dist, kth, done, alive, tile_mask


@partial(
    jax.jit,
    static_argnames=("metric_name", "block", "bq", "k", "backend", "interpret"),
)
def _knn_round_bf16_jit(
    metric_name: str,
    queries: jnp.ndarray,
    radii: jnp.ndarray,
    lb: jnp.ndarray,
    dev: BSSDeviceArrays,
    data16: jnp.ndarray,
    eps: jnp.ndarray,
    *,
    k: int,
    block: int,
    bq: int,
    backend: str,
    interpret: bool | None,
):
    """One bf16 radius-deepening round with fp32 boundary re-check.

    The bf16 scan's own kth-smallest distance ``kth16`` bounds the fp32
    kth within ``eps`` (sorted order statistics of pointwise-eps-close
    vectors), so every member of the fp32 top-k satisfies
    ``d16 <= kth16 + 2*eps`` — that band is re-checked against the fp32
    corpus and the top-k re-taken over the fp32 values (+inf outside the
    band; everything excluded is strictly beyond the fp32 kth, ties at the
    kth included, so selection AND tie order match the fp32 round exactly).
    The ``isfinite`` guard keeps the band inside the computed tile set:
    when fewer than k cells are computed, ``kth16`` is +inf and the band is
    exactly the computed cells — again the fp32 round's pool.  Outputs are
    bit-identical to ``_knn_round_jit``, so the radius schedule (and with
    it the per-query distance counts) never diverges."""
    alive = lb <= radii[:, None]
    tile_mask = _tile_survival(alive, bq)
    d16 = _masked_exact_dists(
        metric_name, queries, data16, dev.valid, tile_mask,
        backend=backend, block=block, bq=bq, interpret=interpret,
    )
    neg16, _ = jax.lax.top_k(-d16, k)
    kth16 = -neg16[:, -1]
    bthr = jnp.where(jnp.isfinite(kth16), kth16 + 2.0 * eps, jnp.inf)
    band = (d16 <= bthr[:, None]) & jnp.isfinite(d16)
    band_blocks = band.reshape(queries.shape[0], -1, block).any(axis=2)
    recheck_mask = _tile_survival(band_blocks, bq) & tile_mask
    d32 = _masked_exact_dists(
        metric_name, queries, dev.data, dev.valid, recheck_mask,
        backend=backend, block=block, bq=bq, interpret=interpret,
    )
    dist = jnp.where(band, d32, jnp.inf)
    neg, cand_idx = jax.lax.top_k(-dist, k)
    cand_dist = -neg
    kth = cand_dist[:, -1]
    done = jnp.isfinite(kth) & ((kth <= radii) | jnp.all(alive, axis=1))
    return (
        cand_idx, cand_dist, kth, done, alive, tile_mask,
        jnp.sum(recheck_mask), jnp.sum(band, axis=1, dtype=jnp.int32),
    )


@partial(jax.jit, static_argnames=("metric_name", "k", "block"))
def _knn_round_cells_jit(
    metric_name: str,
    queries: jnp.ndarray,
    data: jnp.ndarray,
    valid: jnp.ndarray,
    qidx: jnp.ndarray,
    bidx: jnp.ndarray,
    cell_valid: jnp.ndarray,
    *,
    k: int,
    block: int,
):
    """Sparse kNN round: the cell-gather realisation of the masked kernel's
    tile skipping for the jnp backend.  Exact distances are evaluated ONLY
    for the C host-gathered alive (query, block) cells — O(C·block·dim)
    arithmetic instead of the dense O(Q·N·dim) — then scatter-min'd into a
    (Q, n_pad) +inf matrix for ``top_k``.  Padded cells carry +inf, so the
    min-scatter is a no-op for them regardless of scatter order.  Returns
    (cand_idx (Q, k) permuted positions, cand_dist (Q, k) ascending).

    The scatter target is still O(Q·n_pad) floats — same memory as the
    dense round, but 4-byte writes instead of dim-wide metric arithmetic
    (the win is ~dim× on compute, which is what dominates for jsd /
    triangular).  A survivor-proportional top-k (per-query capped gather)
    is the follow-up when kNN serving memory becomes the binding
    constraint — see ROADMAP."""
    d, pvalid = _gather_cell_dists(
        metric_name, queries, data, valid, qidx, bidx, block
    )
    d = jnp.where(pvalid & cell_valid[:, None], d, jnp.inf)
    nq = queries.shape[0]
    n_blocks = data.shape[0] // block
    dense = jnp.full((nq, n_blocks, block), jnp.inf, jnp.float32)
    dense = dense.at[qidx, bidx].min(d)
    neg, cand_idx = jax.lax.top_k(-dense.reshape(nq, -1), k)
    return cand_idx, -neg


@partial(jax.jit, static_argnames=("metric_name", "k", "block"))
def _knn_round_cells_bf16_jit(
    metric_name: str,
    queries: jnp.ndarray,
    data16: jnp.ndarray,
    valid: jnp.ndarray,
    qidx: jnp.ndarray,
    bidx: jnp.ndarray,
    cell_valid: jnp.ndarray,
    eps: jnp.ndarray,
    *,
    k: int,
    block: int,
):
    """bf16 half of a sparse kNN round: gather the alive cells from the
    bf16 corpus, find each query's bf16 kth, and flag the (query, block)
    cells holding any point inside the re-check band
    ``d16 <= kth16 + 2*eps`` (containment argument in
    ``_knn_round_bf16_jit``).  The caller then runs the UNCHANGED fp32
    ``_knn_round_cells_jit`` over just those cells — identical gather
    shapes, so its candidate values, indices and tie order are exactly the
    fp32 round's.  Returns (band_cell (C,) bool, band_counts (Q,) int32)."""
    d, pvalid = _gather_cell_dists(
        metric_name, queries, data16, valid, qidx, bidx, block
    )
    d = jnp.where(pvalid & cell_valid[:, None], d, jnp.inf)
    nq = queries.shape[0]
    n_blocks = data16.shape[0] // block
    dense16 = jnp.full((nq, n_blocks, block), jnp.inf, jnp.float32)
    dense16 = dense16.at[qidx, bidx].min(d)
    neg16, _ = jax.lax.top_k(-dense16.reshape(nq, -1), k)
    kth16 = -neg16[:, -1]
    bthr = jnp.where(jnp.isfinite(kth16), kth16 + 2.0 * eps, jnp.inf)
    qi = jnp.clip(qidx, 0, nq - 1)
    band = (d <= bthr[qi][:, None]) & jnp.isfinite(d)  # (C, block)
    band_cell = band.any(axis=1)
    band_counts = jnp.zeros(nq, jnp.int32).at[qi].add(
        jnp.sum(band, axis=1, dtype=jnp.int32)
    )
    return band_cell, band_counts


@partial(jax.jit, static_argnames=("metric_name", "bq", "backend", "interpret"))
def _knn_lb_jit(
    metric_name: str,
    queries: jnp.ndarray,
    dev: BSSDeviceArrays,
    *,
    bq: int,
    backend: str,
    interpret: bool | None,
) -> jnp.ndarray:
    return _fused_lower_bounds(
        metric_name, queries, dev.pivots, dev.pairs, dev.deltas, dev.boxes,
        backend=backend, bq=bq, interpret=interpret,
    )


def _knn_empty_stats(index: BSSIndex, nq: int, precision: str,
                     backend: str, engine: str = "bss") -> dict:
    """Schema-conformant stats for the kNN early returns (no queries, or
    an empty valid corpus): zero rounds, zero work."""
    stats = {
        "rounds": 0, "pivot_dists_per_query": 0.0,
        "exact_dists_per_query": 0.0, "dists_per_query": 0.0,
        "per_query_dists": np.zeros(nq, np.int64),
        "tiles_computed": 0, "n_blocks": int(index.n_blocks),
        "generation": int(index.generation),
        "precision": precision,
        "excluded": {"hilbert": np.zeros(nq, np.int64)},
    }
    if precision == "bf16":
        _bf16_stats(stats, index.bf16_margin(), 0, np.zeros(nq, np.int64))
    return _finish_stats(stats, kind="knn", backend=backend, engine=engine)


def bss_knn_batched(
    index: BSSIndex,
    queries: np.ndarray,
    k: int,
    *,
    r0: float | None = None,
    growth: float = 2.0,
    max_rounds: int = 8,
    opts: EngineOpts | None = None,
    bq: int | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
    realisation: str | None = None,
    precision: str | None = None,
) -> tuple[np.ndarray, np.ndarray, dict]:
    """Exact batched kNN: the range-search reduction run as jitted
    radius-deepening rounds over all queries at once.

    Engine options travel as ``opts=EngineOpts(...)`` exactly as in
    ``bss_query_batched`` (legacy per-knob kwargs shimmed the same way);
    ``r0`` / ``growth`` / ``max_rounds`` are the radius SCHEDULE — kNN
    semantics, not engine plumbing — and stay explicit kwargs.

    ``precision="bf16"`` runs every round's scan over the bfloat16 corpus
    mirror and re-checks the per-round radius band
    ``d16 <= kth16 + 2*eps`` against the fp32 corpus
    (``_knn_round_bf16_jit``) — candidates, distances, the radius schedule
    and the per-query distance counts are bit-identical to the fp32 engine;
    stats gain the re-check telemetry (``band_eps`` / ``recheck_tiles`` /
    ``per_query_recheck``).

    ``realisation="dense"`` pins every jnp round to the dense masked pass
    (no sparse cell-gather): shapes depend only on (Q, N, k), so a serving
    front's compile count stays bounded by its bucket ladder — see
    ``bss_query_batched``.  Both realisations are exact; they may disagree
    in the last ulp of a distance, which can shift the radius schedule (and
    so the per-query distance COUNTS, never the results) — count-parity
    contracts should pin one realisation (the sharded engine and its tests
    pin dense).

    Round scheme (each round is ONE jitted call, fixed shapes, no recompiles):
      * every query carries its own radius; blocks with planar bound above it
        are excluded from the masked exact phase;
      * ``jax.lax.top_k`` extracts the k nearest computed candidates;
      * a query is finished when its kth candidate distance <= its radius
        (soundness argument in ``_knn_round_jit``);
      * unfinished queries tighten AND widen: the kth-nearest-so-far
        distance is an upper bound on the true kth distance, so the next
        radius is ``min(kth_so_far, widened)`` where ``widened`` is the
        per-query radius that doubles the number of surviving blocks (read
        off the query's sorted block bounds — scale-free, so convergence
        takes at most ~log2(n_blocks) rounds).  One extra round at radius
        ``kth_so_far`` is always sufficient; the min keeps the mask as
        tight as the current evidence allows.  After ``max_rounds`` any
        stragglers run one exhaustive round (radius = inf), so the result
        is always exact.

    The initial radius (when ``r0`` is None) is per-query and scale-free:
    the ceil(2k/block)-th smallest block bound — the smallest radius that
    could possibly admit 2k candidate points, by the bound's own ordering.

    On the jnp backend each round is adaptive in survivor density (mirroring
    the range path): sparse rounds gather only the alive (query, block)
    cells (``_knn_round_cells_jit``), dense rounds run the masked dense pass
    — either way the round's arithmetic is exact and the result identical.

    Returns (indices (Q, k) original ids sorted by ascending distance — -1
    when the corpus holds fewer than k valid points, distances (Q, k), stats).

    A mesh-built index (``build_bss(mesh=...)``) serves through the sharded
    engine: per-shard rounds merged by all-gather + global top-k under the
    same radius schedule — results and distance counts are identical.
    """
    opts = resolve_engine_opts(
        opts, bq=bq, backend=backend, interpret=interpret,
        realisation=realisation, precision=precision,
    )
    if index.mesh is not None:
        from repro.parallel.shard_index import sharded_knn_batched

        return sharded_knn_batched(
            index.sharded(), queries, k, r0=r0, growth=growth,
            max_rounds=max_rounds, opts=opts,
        )
    bq = opts.bq if opts.bq is not None else _DEFAULT_BQ
    interpret = opts.interpret
    realisation = opts.realisation
    precision = opts.precision
    backend = _resolve_backend(opts.backend)
    metric_eng = _engine_metric(index.metric_name)
    queries = _engine_queries(index.metric_name, np.asarray(queries, np.float32))
    nq = queries.shape[0]
    k = int(k)
    if k <= 0:
        raise ValueError(f"k must be positive, got {k}")
    if nq == 0:
        return (
            np.zeros((0, k), np.int64),
            np.zeros((0, k), np.float32),
            _knn_empty_stats(index, 0, precision, backend),
        )
    # clamp to the VALID corpus size: with k_run > n_valid the kth distance
    # would stay inf and no round could ever finish early
    k_run = min(k, index.n_valid)
    if k_run == 0:
        return (
            np.full((nq, k), -1, np.int64),
            np.full((nq, k), np.inf, np.float32),
            _knn_empty_stats(index, nq, precision, backend),
        )
    dev = index.device
    qj = jnp.asarray(queries)
    bf16 = precision == "bf16"
    eps = index.bf16_margin() if bf16 else 0.0
    eps_j = jnp.float32(eps)
    data16 = index.device_bf16 if bf16 else None
    recheck_pq = np.zeros(nq, np.int64)
    recheck_tiles_total = 0

    # The (Q, B) planar bounds are radius-independent: compute them once
    # (through the selected backend) and reuse across every round — the
    # device copy feeds the rounds, the sorted host copy drives the initial
    # radius and the per-round widening schedule.
    lb_dev = _knn_lb_jit(
        metric_eng, qj, dev, bq=bq, backend=backend, interpret=interpret
    )
    lb_np = np.asarray(lb_dev)
    lb_sorted = np.sort(lb_np, axis=1)
    n_blocks = index.n_blocks
    if r0 is None:
        j0 = min(n_blocks - 1, max(0, math.ceil(2 * k / index.block) - 1))
        radii = lb_sorted[:, j0].astype(np.float32)
    else:
        radii = np.full(nq, float(r0), np.float32)

    valid_pb = _valid_per_block(index)
    total_exact = np.zeros(nq, np.int64)
    excl_pq = np.zeros(nq, np.int64)
    tiles_total = 0
    done = np.zeros(nq, bool)
    cand_idx = np.full((nq, k_run), 0, np.int64)
    cand_dist = np.full((nq, k_run), np.inf, np.float32)
    rounds = 0
    for rounds in range(1, max_rounds + 2):
        if rounds == max_rounds + 1:
            # exhaustive fallback for stragglers: radius inf computes every
            # block, so the round below is guaranteed final for them.
            radii = np.where(done, radii, np.inf).astype(np.float32)
        alive_host = lb_np <= radii[:, None]  # identical to the device test
        if (backend == "jnp" and realisation != "dense"
                and alive_host.mean() <= _DENSE_ALIVE_FRAC):
            # sparse round: gather only the alive cells (adaptive, like the
            # range path; the branch condition reads only the fp32 bound
            # phase, so both precisions take it identically);
            # done/alive/tiles derived on host
            qidx, bidx = np.nonzero(alive_host)
            c = len(qidx)
            c_pad = _next_pow2(c)
            qidx_p = np.pad(qidx, (0, c_pad - c)).astype(np.int32)
            bidx_p = np.pad(bidx, (0, c_pad - c)).astype(np.int32)
            if bf16:
                # bf16 scan picks the band cells; the UNCHANGED fp32 round
                # below then runs over just those cells — its values, tie
                # order and outputs are exactly the fp32 round's.
                band_cell, band_counts = _knn_round_cells_bf16_jit(
                    metric_eng, qj, data16, dev.valid,
                    jnp.asarray(qidx_p), jnp.asarray(bidx_p),
                    jnp.asarray(np.arange(c_pad) < c), eps_j,
                    k=k_run, block=index.block,
                )
                sel = np.nonzero(np.asarray(band_cell))[0]
                recheck_pq += np.where(~done, np.asarray(band_counts), 0)
                qidx_p, bidx_p = qidx_p[sel], bidx_p[sel]
                c = len(sel)
                c_pad = _next_pow2(c)
                qidx_p = np.pad(qidx_p, (0, c_pad - c)).astype(np.int32)
                bidx_p = np.pad(bidx_p, (0, c_pad - c)).astype(np.int32)
            ci, cd = _knn_round_cells_jit(
                metric_eng, qj, dev.data, dev.valid,
                jnp.asarray(qidx_p), jnp.asarray(bidx_p),
                jnp.asarray(np.arange(c_pad) < c),
                k=k_run, block=index.block,
            )
            ci, cd = np.asarray(ci), np.asarray(cd)
            kth = cd[:, -1]
            dn = np.isfinite(kth) & (
                (kth <= radii) | alive_host.all(axis=1)
            )
            alive = alive_host
            tiles_round = int(
                np.asarray(_tile_survival(jnp.asarray(alive_host), bq)).sum()
            )
        elif bf16:
            (ci, cd, kth, dn, alive, tile_mask, rtiles, band_counts) = (
                _knn_round_bf16_jit(
                    metric_eng, qj, jnp.asarray(radii), lb_dev, dev,
                    data16, eps_j,
                    k=k_run, block=index.block, bq=bq, backend=backend,
                    interpret=interpret,
                )
            )
            ci, cd, kth, dn, alive = (
                np.asarray(ci), np.asarray(cd), np.asarray(kth),
                np.asarray(dn), np.asarray(alive),
            )
            tiles_round = int(np.asarray(tile_mask).sum())
            recheck_tiles_total += int(rtiles)
            recheck_pq += np.where(~done, np.asarray(band_counts), 0)
        else:
            ci, cd, kth, dn, alive, tile_mask = _knn_round_jit(
                metric_eng, qj, jnp.asarray(radii), lb_dev, dev,
                k=k_run, block=index.block, bq=bq, backend=backend,
                interpret=interpret,
            )
            ci, cd, kth, dn, alive = (
                np.asarray(ci), np.asarray(cd), np.asarray(kth),
                np.asarray(dn), np.asarray(alive),
            )
            tiles_round = int(np.asarray(tile_mask).sum())
        upd = ~done  # freeze finished queries (their results are final)
        cand_idx[upd] = ci[upd]
        cand_dist[upd] = cd[upd]
        total_exact[upd] += alive[upd].astype(np.int64) @ valid_pb
        excl_pq[upd] += n_blocks - alive[upd].sum(axis=1)
        tiles_total += tiles_round
        done = done | dn
        if done.all():
            break
        # widen to the radius that (at least) doubles the surviving blocks,
        # tighten by the kth-nearest-so-far where we already hold k
        # candidates — min() keeps the next mask as small as evidence allows.
        n_alive = alive.sum(axis=1)
        j_next = np.minimum(
            n_blocks - 1,
            np.maximum(np.maximum(2 * n_alive, n_alive + 1), 1),
        )
        widened = np.maximum(lb_sorted[np.arange(nq), j_next], radii * growth)
        # finished queries get a negative radius: lb >= 0, so their alive
        # rows empty out and they stop contributing blocks/tiles to the
        # remaining rounds (their results are already frozen above)
        radii = np.where(
            done, np.float32(-1.0),
            np.where(np.isfinite(kth), np.minimum(kth, widened), widened),
        ).astype(np.float32)
        # unprunable query (most blocks already alive): grinding more
        # rounds just re-evaluates them — finish exhaustively instead
        radii = np.where(
            ~done & (n_alive > n_blocks // 2), np.float32(np.inf), radii
        )

    n_pivots = index.pivots.shape[0]
    stats = {
        "rounds": rounds,
        "pivot_dists_per_query": float(n_pivots),
        "exact_dists_per_query": float(total_exact.mean()),
        "dists_per_query": float(n_pivots + total_exact.mean()),
        "per_query_dists": n_pivots + total_exact,
        "tiles_computed": tiles_total,
        "n_blocks": int(index.n_blocks),
        "generation": int(index.generation),
        "precision": precision,
        # rounds x blocks the Hilbert bound pruned from the exact phase,
        # accumulated per query over its unfinished rounds only
        "excluded": {"hilbert": excl_pq},
    }
    if bf16:
        _bf16_stats(stats, eps, recheck_tiles_total, recheck_pq)
    _finish_stats(stats, kind="knn", backend=backend)
    orig = np.where(np.isfinite(cand_dist), index.perm[cand_idx], -1)
    if k_run < k:  # corpus smaller than k: pad out to the requested width
        orig = np.pad(orig, ((0, 0), (0, k - k_run)), constant_values=-1)
        cand_dist = np.pad(
            cand_dist, ((0, 0), (0, k - k_run)), constant_values=np.inf
        )
    return orig, cand_dist, stats

"""Blocked Supermetric Scan (BSS) — the TPU-native realisation of the paper.

The paper's trees prune *semispaces* one node at a time with data-dependent
branching — hostile to TPUs.  BSS keeps the paper's geometry (the planar
lower bound of §3) but restructures the computation for the MXU:

  build:  choose P pivots (FFT — pivot quality barely matters under the
          four-point property, §3.3); project every point onto the M
          pivot-pair planes; recursively median-split the *margin space* to
          find a locality-preserving permutation; group points into
          MXU-tile-aligned blocks of 128; store per (block × plane) bounding
          boxes of the projected coordinates.

  query:  dist(q, pivots)  ->  project q onto all planes  ->  per block,
          lower-bound = max over planes of planar distance-to-box  ->
          blocks with bound > t are EXCLUDED (sound by the four-point
          property); exact distances run only for surviving blocks through
          the pairwise kernel.

Every step is dense, batched and masked: pruning whole 128-point blocks is
exactly the granularity at which a TPU can actually skip work.  Exactness is
preserved (no approximation anywhere) — this is still the paper's *exact*
search, reorganised.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import projection
from repro.core.distances import METRICS, Metric
from repro.core.npdist import pairwise_np
from repro.core.refpoints import select_fft

__all__ = ["BSSIndex", "build_bss", "bss_query", "bss_lower_bounds"]


@dataclasses.dataclass
class BSSIndex:
    metric_name: str
    data: np.ndarray          # (n_pad, dim) permuted + padded
    perm: np.ndarray          # (n_pad,) original index, -1 for padding
    valid: np.ndarray         # (n_pad,) bool
    pivots: np.ndarray        # (P, dim)
    pairs: np.ndarray         # (M, 2) pivot indices per plane
    deltas: np.ndarray        # (M,)
    boxes: np.ndarray         # (n_blocks, M, 4) = x_lo, x_hi, y_lo, y_hi
    block: int

    @property
    def n_blocks(self) -> int:
        return self.boxes.shape[0]

    @property
    def metric(self) -> Metric:
        return METRICS[self.metric_name]


def _project_all(dp: np.ndarray, pairs: np.ndarray, deltas: np.ndarray):
    """dp: (n, P) pivot distances -> (n, M) x and (n, M) y planar coords."""
    d1 = dp[:, pairs[:, 0]]
    d2 = dp[:, pairs[:, 1]]
    delta = np.maximum(deltas[None, :], 1e-12)
    x = (d1 * d1 - d2 * d2) / (2.0 * delta)
    y = np.sqrt(np.maximum(d1 * d1 - (x + delta / 2.0) ** 2, 0.0))
    return x, y


def build_bss(
    metric_name: str,
    data: np.ndarray,
    n_pivots: int = 16,
    n_pairs: int = 24,
    block: int = 128,
    seed: int = 0,
) -> BSSIndex:
    rng = np.random.default_rng(seed)
    data = np.asarray(data, np.float32)
    n = data.shape[0]
    piv_idx = select_fft(metric_name, data, n_pivots, rng)
    pivots = data[piv_idx]

    # All pivot pairs, keep the M most separated (wide baselines give the
    # best-conditioned planes; beyond that the paper shows insensitivity).
    pd = pairwise_np(metric_name, pivots, pivots)
    cand = [(pd[i, j], i, j) for i in range(n_pivots) for j in range(i + 1, n_pivots)]
    cand.sort(reverse=True)
    m = min(n_pairs, len(cand))
    pairs = np.array([[i, j] for _, i, j in cand[:m]], dtype=np.int32)
    deltas = np.array([d for d, _, _ in cand[:m]], dtype=np.float32)

    dp = pairwise_np(metric_name, data, pivots).astype(np.float32)  # (n, P)
    x, y = _project_all(dp, pairs, deltas)  # (n, M) each
    feats = np.concatenate([x, y], axis=1)  # (n, 2M) margin space

    # locality-preserving permutation: recursive max-variance median split
    out: list[np.ndarray] = []

    def split(idx: np.ndarray):
        if len(idx) <= block:
            out.append(idx)
            return
        sub = feats[idx]
        dimm = int(np.argmax(sub.var(axis=0)))
        order = np.argsort(sub[:, dimm], kind="stable")
        half = len(idx) // 2
        split(idx[order[:half]])
        split(idx[order[half:]])

    split(np.arange(n, dtype=np.int64))
    perm = np.concatenate(out)

    n_blocks = math.ceil(n / block)
    n_pad = n_blocks * block
    pad = n_pad - n
    perm_pad = np.concatenate([perm, np.full(pad, -1, dtype=np.int64)])
    valid = perm_pad >= 0
    dsorted = np.concatenate([data[perm], np.zeros((pad, data.shape[1]), np.float32)])

    xs = np.concatenate([x[perm], np.zeros((pad, m), np.float32)])
    ys = np.concatenate([y[perm], np.zeros((pad, m), np.float32)])
    xs = xs.reshape(n_blocks, block, m)
    ys = ys.reshape(n_blocks, block, m)
    vmask = valid.reshape(n_blocks, block, 1)
    big = np.float32(3.4e38)
    boxes = np.stack(
        [
            np.where(vmask, xs, big).min(axis=1),
            np.where(vmask, xs, -big).max(axis=1),
            np.where(vmask, ys, big).min(axis=1),
            np.where(vmask, ys, -big).max(axis=1),
        ],
        axis=-1,
    ).astype(np.float32)  # (n_blocks, M, 4)

    return BSSIndex(
        metric_name=metric_name,
        data=dsorted,
        perm=perm_pad,
        valid=valid,
        pivots=np.asarray(pivots, np.float32),
        pairs=pairs,
        deltas=deltas,
        boxes=boxes,
        block=block,
    )


@partial(jax.jit, static_argnames=("metric_name",))
def _lower_bounds_jit(
    metric_name: str,
    queries: jnp.ndarray,
    pivots: jnp.ndarray,
    pairs: jnp.ndarray,
    deltas: jnp.ndarray,
    boxes: jnp.ndarray,
) -> jnp.ndarray:
    """(Q, n_blocks) sound lower bound on d(q, any point in block)."""
    metric = METRICS[metric_name]
    dqp = metric.pairwise(queries, pivots)  # (Q, P)
    d1 = dqp[:, pairs[:, 0]]
    d2 = dqp[:, pairs[:, 1]]
    qx, qy = projection.project(d1, d2, deltas[None, :])  # (Q, M)
    # (Q, 1, M) vs boxes (1, B, M, 4) -> per-plane bound, max over planes.
    lb = projection.point_to_box(qx[:, None, :], qy[:, None, :], boxes[None])
    return jnp.max(lb, axis=-1)  # (Q, B)


def bss_lower_bounds(index: BSSIndex, queries: np.ndarray) -> np.ndarray:
    return np.asarray(
        _lower_bounds_jit(
            index.metric_name,
            jnp.asarray(queries, jnp.float32),
            jnp.asarray(index.pivots),
            jnp.asarray(index.pairs),
            jnp.asarray(index.deltas),
            jnp.asarray(index.boxes),
        )
    )


def bss_query(
    index: BSSIndex, queries: np.ndarray, t: float
) -> tuple[list[list[int]], dict]:
    """Exact range search.  Returns per-query hit lists (original indices)
    and stats including the paper's figure of merit (distances/query:
    P pivot distances + 128 per surviving block)."""
    queries = np.asarray(queries, np.float32)
    nq = queries.shape[0]
    lb = bss_lower_bounds(index, queries)  # (Q, B)
    alive = lb <= t
    results: list[list[int]] = [[] for _ in range(nq)]
    bsz = index.block
    data = index.data
    # exact phase: per block, evaluate only the surviving queries
    for b in np.nonzero(alive.any(axis=0))[0]:
        qrows = np.nonzero(alive[:, b])[0]
        blk = data[b * bsz : (b + 1) * bsz]
        d = pairwise_np(index.metric_name, queries[qrows], blk)
        hits = d <= t
        for r, qi in enumerate(qrows):
            for off in np.nonzero(hits[r])[0]:
                orig = index.perm[b * bsz + off]
                if orig >= 0:
                    results[int(qi)].append(int(orig))
    n_pivots = index.pivots.shape[0]
    survived = alive.sum(axis=1)  # blocks per query
    stats = {
        "pivot_dists_per_query": float(n_pivots),
        "exact_dists_per_query": float((survived * bsz).mean()),
        "dists_per_query": float(n_pivots + (survived * bsz).mean()),
        "block_exclusion_rate": float(1.0 - alive.mean()),
        "n_blocks": int(index.n_blocks),
    }
    return results, stats

"""Hyperplane partition trees (paper §4): 12 structural variants × 2
exclusion mechanisms (Hyperbolic / Hilbert).

Variants (paper §4.2), differentiated ONLY by reference-point selection —
query code is shared, exactly mirroring the paper's "same Java classes,
specialised only by selection strategy" methodology:

    sat_pure            SAT neighbour set, ascending-distance scan
    sat_distal_pure     SAT neighbour set, descending-distance scan
    sat_distal_fixed    distal scan, capped at arity 4
    sat_distal_log      distal scan, capped at ln|S|
    sat_global_fixed    distal scan ordered by distance from GLOBAL root centre, arity 4
    sat_global_log      ... capped at ln|S|
    hpt_fft_binary      FFT (farthest-first) pivots, arity 2
    hpt_fft_fixed       FFT pivots, arity 4
    hpt_fft_log         FFT pivots, arity ln|S|      <-- paper's best
    hpt_random_binary   random pivots, arity 2
    hpt_random_fixed    random pivots, arity 4
    hpt_random_log      random pivots, arity ln|S|

Exclusion at query time (paper Alg. 2 + §2.2):
  * cover radius:   d(q, p_x) > cr_x + t
  * hyperbolic:     exists y:  d(q,p_x) - d(q,p_y) > 2t
  * Hilbert:        exists y: (d(q,p_x)^2 - d(q,p_y)^2) / d(p_x,p_y) > 2t
  * SAT-family trees additionally use the parent *centre* as a free witness
    (its query distance is passed down; d(p_x, centre) stored at build).

Queries run batched: the engine walks the array-encoded tree with an explicit
stack of (node, active-query-subset), evaluating distances for all active
queries at once (vectorised numpy) while tallying per-query distance counts —
bitwise identical counts to a one-query-at-a-time walk.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from repro.core import exclusion, refpoints
from repro.core.exclusion import HILBERT, HYPERBOLIC
from repro.core.npdist import DistanceCounter, pairwise_np

__all__ = ["TREE_VARIANTS", "PartitionTree", "build_tree", "range_search"]


@dataclasses.dataclass
class _Node:
    ref_idx: np.ndarray          # (k,) dataset indices of reference points
    ref_dists: np.ndarray        # (k, k) pairwise ref distances (build-time)
    centre_dists: np.ndarray     # (k,) d(ref_i, parent centre); NaN if none
    cover_r: np.ndarray          # (k,) cover radius of child subtree i
    children: list               # k entries: _Node | np.ndarray(leaf idx) | None


@dataclasses.dataclass
class PartitionTree:
    variant: str
    metric: str
    data: np.ndarray
    root: _Node
    build_distances: int
    n_nodes: int
    max_depth: int


# --------------------------------------------------------------------------
# arity policies
# --------------------------------------------------------------------------


def _arity_binary(n: int, depth: int) -> int:
    return 2


def _arity_fixed(n: int, depth: int) -> int:
    return 4


def _arity_log(n: int, depth: int) -> int:
    return max(2, int(math.log(max(n, 3))))


# --------------------------------------------------------------------------
# reference selection
# --------------------------------------------------------------------------


def _sat_neighbours(
    metric: str,
    data: np.ndarray,
    subset: np.ndarray,
    d_c: np.ndarray,
    order: np.ndarray,
    cap: int | None,
    build_count: list,
) -> np.ndarray:
    """Serial SAT neighbour selection: scan ``subset`` in ``order``; s joins N
    iff it is closer to the centre than to every current member of N.

    Only the running min-distance-to-refs is kept (the membership criterion
    needs nothing more), so wide distal nodes stay O(n) memory."""
    refs: list[int] = []
    min_d = np.full(len(subset), np.inf)
    for pos in order:
        if cap is not None and len(refs) >= cap:
            break
        if len(refs) == 0 or d_c[pos] < min_d[pos]:
            new_ref = subset[pos]
            d_new = pairwise_np(metric, data[subset], data[new_ref][None, :])[:, 0]
            build_count[0] += len(subset)
            min_d = np.minimum(min_d, d_new)
            refs.append(int(new_ref))
    return np.asarray(refs, dtype=np.int64)


def _make_selector(variant: str):
    """Returns (select_fn, arity_fn, is_sat).  select_fn(data, subset, centre,
    global_order_rank, rng, build_count) -> ref indices (into dataset)."""
    if variant.startswith("sat"):
        if variant == "sat_pure":
            cap, order_kind = None, "asc"
        elif variant == "sat_distal_pure":
            cap, order_kind = None, "desc"
        elif variant == "sat_distal_fixed":
            cap, order_kind = 4, "desc"
        elif variant == "sat_distal_log":
            cap, order_kind = "log", "desc"
        elif variant == "sat_global_fixed":
            cap, order_kind = 4, "global"
        elif variant == "sat_global_log":
            cap, order_kind = "log", "global"
        else:
            raise ValueError(variant)

        def select(metric, data, subset, centre_idx, global_rank, rng, build_count):
            n = len(subset)
            k_cap = cap if not isinstance(cap, str) else _arity_log(n, 0)
            c = data[centre_idx][None, :]
            d_c = pairwise_np(metric, data[subset], c)[:, 0]
            build_count[0] += n
            if order_kind == "global":
                order = np.argsort(global_rank[subset])[::-1]
            else:
                order = np.argsort(d_c)
                if order_kind == "desc":
                    order = order[::-1]
            return _sat_neighbours(
                metric, data, subset, d_c, order, k_cap, build_count
            )

        return select, None, True

    kind, strategy, arity_name = variant.split("_")
    if kind != "hpt":
        raise ValueError(f"unknown tree variant family {kind!r} in {variant!r}")
    arity_fn = {
        "binary": _arity_binary,
        "fixed": _arity_fixed,
        "log": _arity_log,
    }[arity_name]

    def select(metric, data, subset, centre_idx, global_rank, rng, build_count):
        k = min(arity_fn(len(subset), 0), len(subset))
        if strategy == "random":
            loc = refpoints.select_random(rng, len(subset), k)
        else:  # fft
            loc = refpoints.select_fft(metric, data[subset], k, rng)
            build_count[0] += k * min(len(subset), 4096)  # FFT scan cost
        return subset[loc]

    return select, arity_fn, False


TREE_VARIANTS = (
    "sat_pure",
    "sat_distal_pure",
    "sat_distal_fixed",
    "sat_distal_log",
    "sat_global_fixed",
    "sat_global_log",
    "hpt_fft_binary",
    "hpt_fft_fixed",
    "hpt_fft_log",
    "hpt_random_binary",
    "hpt_random_fixed",
    "hpt_random_log",
)


# --------------------------------------------------------------------------
# build
# --------------------------------------------------------------------------


def build_tree(
    variant: str,
    metric: str,
    data: np.ndarray,
    seed: int = 0,
    leaf_cap: int = 8,
) -> PartitionTree:
    if variant not in TREE_VARIANTS:
        raise ValueError(f"unknown variant {variant!r}")
    import sys

    if sys.getrecursionlimit() < 100_000:
        sys.setrecursionlimit(100_000)
    rng = np.random.default_rng(seed)
    data = np.asarray(data, np.float64)
    n = data.shape[0]
    select, _, is_sat = _make_selector(variant)
    # Centre-witness hyperplane exclusion is only SOUND for uncapped ("pure")
    # SAT construction: capping breaks the every-point-closer-to-some-ref-
    # than-to-centre invariant (paper §4.1 "SAT construction").
    centre_witness = variant in ("sat_pure", "sat_distal_pure")
    build_count = [0]
    stats = {"nodes": 0, "max_depth": 0}

    # SAT-family trees need a centre; root centre is an outlier (SAT_out).
    root_centre = refpoints.select_outlier(metric, data, rng) if is_sat else -1
    global_rank = None
    if variant.startswith("sat_global"):
        d_root = pairwise_np(metric, data, data[root_centre][None, :])[:, 0]
        build_count[0] += n
        global_rank = d_root

    def make_node(subset: np.ndarray, centre_idx: int, depth: int):
        stats["max_depth"] = max(stats["max_depth"], depth)
        if len(subset) == 0:
            return None
        if len(subset) <= leaf_cap:
            return subset  # leaf bucket
        stats["nodes"] += 1
        ref_idx = select(metric, data, subset, centre_idx, global_rank, rng, build_count)
        k = len(ref_idx)
        refs = data[ref_idx]
        ref_dists = pairwise_np(metric, refs, refs)
        if centre_witness and centre_idx >= 0:
            centre_dists = pairwise_np(metric, refs, data[centre_idx][None, :])[:, 0]
        else:
            centre_dists = np.full(k, np.nan)
        rest_mask = ~np.isin(subset, ref_idx)
        rest = subset[rest_mask]
        children: list = [None] * k
        cover_r = np.zeros(k)
        if len(rest) > 0:
            d_assign = pairwise_np(metric, data[rest], refs)  # (m, k)
            build_count[0] += len(rest) * k
            owner = np.argmin(d_assign, axis=1)
            for j in range(k):
                sub_j = rest[owner == j]
                if len(sub_j) > 0:
                    cover_r[j] = float(d_assign[owner == j, j].max())
                children[j] = make_node(sub_j, int(ref_idx[j]), depth + 1)
        return _Node(ref_idx, ref_dists, centre_dists, cover_r, children)

    subset0 = np.arange(n, dtype=np.int64)
    if is_sat:
        # the root centre itself is stored at the root as a 1-ref super-node
        subset0 = subset0[subset0 != root_centre]
        inner = make_node(subset0, root_centre, 1)
        stats["nodes"] += 1
        root = _Node(
            ref_idx=np.array([root_centre], dtype=np.int64),
            ref_dists=np.zeros((1, 1)),
            centre_dists=np.full(1, np.nan),
            cover_r=np.array(
                [float(pairwise_np(metric, data[subset0], data[root_centre][None, :]).max())]
                if len(subset0)
                else [0.0]
            ),
            children=[inner],
        )
        build_count[0] += len(subset0)
    else:
        root = make_node(subset0, -1, 0)
        if not isinstance(root, _Node):  # degenerate tiny dataset
            root = _Node(
                ref_idx=np.array([], dtype=np.int64),
                ref_dists=np.zeros((0, 0)),
                centre_dists=np.zeros(0),
                cover_r=np.zeros(0),
                children=[root],
            )
    return PartitionTree(
        variant=variant,
        metric=metric,
        data=data,
        root=root,
        build_distances=build_count[0],
        n_nodes=stats["nodes"],
        max_depth=stats["max_depth"],
    )


# --------------------------------------------------------------------------
# batched counting range query
# --------------------------------------------------------------------------


def _exclusion_masks(
    dq: np.ndarray,
    node: _Node,
    t: float,
    mechanism: str,
    d_centre: np.ndarray | None,
) -> np.ndarray:
    """(nq, k) True where child x is excluded for that query.

    All three predicates come from ``core/exclusion.py`` (numpy namespace,
    float64) — the same bodies the device forest walker runs under jit, so
    the host walk IS the oracle for the accelerated one."""
    excl = exclusion.cover_radius_exclusion_mask(
        dq, node.cover_r[None, :], t, xp=np
    )
    excl |= exclusion.hyperplane_exclusion_mask(
        dq, node.ref_dists, t, mechanism, xp=np
    )
    # SAT-family bonus witness: the parent centre (free query distance).
    if d_centre is not None and not np.any(np.isnan(node.centre_dists)):
        excl |= exclusion.centre_witness_exclusion_mask(
            dq, d_centre, node.centre_dists[None, :], t, mechanism, xp=np
        )
    return excl


def range_search(
    tree: PartitionTree,
    queries: np.ndarray,
    t: float,
    mechanism: str = HILBERT,
) -> tuple[list[list[int]], DistanceCounter]:
    """Batched exact range search; returns per-query hit lists + counter."""
    if mechanism not in (HILBERT, HYPERBOLIC):
        raise ValueError(mechanism)
    queries = np.asarray(queries, np.float64)
    nq = queries.shape[0]
    counter = DistanceCounter(tree.metric, nq)
    results: list[list[int]] = [[] for _ in range(nq)]
    data = tree.data

    # stack entries: (node_or_leaf, active query idx array, centre dists | None)
    stack: list = [(tree.root, np.arange(nq, dtype=np.int64), None)]
    while stack:
        node, qidx, d_centre = stack.pop()
        if node is None or len(qidx) == 0:
            continue
        if isinstance(node, np.ndarray):  # leaf bucket
            d = counter.pairwise(qidx, queries[qidx], data[node])
            hit_mask = d <= t
            for row in np.nonzero(hit_mask.any(axis=1))[0]:
                qi = qidx[row]
                results[qi].extend(int(h) for h in node[hit_mask[row]])
            continue
        k = len(node.ref_idx)
        if k == 0:
            for ch in node.children:
                stack.append((ch, qidx, None))
            continue
        dq = counter.pairwise(qidx, queries[qidx], data[node.ref_idx])
        hit_mask = dq <= t
        for row in np.nonzero(hit_mask.any(axis=1))[0]:
            qi = qidx[row]
            results[qi].extend(int(r) for r in node.ref_idx[hit_mask[row]])
        excl = _exclusion_masks(dq, node, t, mechanism, d_centre)
        for j, child in enumerate(node.children):
            if child is None:
                continue
            keep = ~excl[:, j]
            if np.any(keep):
                stack.append((child, qidx[keep], dq[keep, j]))
    return results, counter


def exhaustive_search(
    metric: str, data: np.ndarray, queries: np.ndarray, t: float
) -> list[list[int]]:
    """Ground truth (chunked to bound memory)."""
    data = np.asarray(data, np.float64)
    queries = np.asarray(queries, np.float64)
    out: list[list[int]] = []
    for q0 in range(0, len(queries), 256):
        qs = queries[q0 : q0 + 256]
        d = pairwise_np(metric, qs, data)
        for row in range(len(qs)):
            out.append([int(i) for i in np.nonzero(d[row] <= t)[0]])
    return out

"""Exclusion rules: Hyperbolic (triangle-inequality) vs Hilbert (four-point),
plus the fully general *linear planar partition* family (paper §3.2-3.4).

Conventions
-----------
A binary partition at a tree node is described by a **signed margin**
function ``m(point) -> R``: points with ``m < split`` go left, ``m >= split``
go right.  At query time the engine computes ``m(q)`` and uses a *sound
separation bound* ``sep(q)`` such that

    sep(q) > t   and  q on the right  ==>  no solution on the left
    (symmetrically for the other side)

For **Hilbert** rules the margin is a geometric coordinate in the projected
plane, and ``sep = |m(q) - split|`` is sound because planar distances lower-
bound true distances (four-point property).  Any unit-direction linear
functional of the plane works — x-split, y-split, PCA axis, regression axis.

For **Hyperbolic** rules (no four-point property assumed) the only sound
bound for the closer-of-two-pivots partition is
``sep = |d(q,p1) - d(q,p2)| / 2`` (condition ``|d1-d2| > 2t``).

Cover-radius ("ball") exclusion is independent of both and always sound.

One implementation, three consumers
-----------------------------------
Every predicate takes an ``xp`` array namespace (``numpy`` or
``jax.numpy``).  The host tree walks (``core/tree.py``, ``core/lrt.py``)
call with ``xp=numpy`` in float64; the device forest walker
(``forest/walk.py``) calls the same bodies with ``xp=jax.numpy`` in float32
under jit.  Exclusion GEOMETRY lives here and nowhere else — a divergent
re-derivation is exactly how the pre-PR-2 delta-floor bug happened.

NaN discipline: every criterion is written so a NaN operand (missing centre
witness, padded slot) makes the comparison False — i.e. *no exclusion*,
the conservative direction.  Padded reference slots should carry ``+inf``
query distances, which the criteria likewise treat as "excludes nothing".
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import projection
from repro.core.constants import DEGENERATE_DELTA, MIN_DELTA

__all__ = [
    "HYPERBOLIC",
    "HILBERT",
    "PlanarPartition",
    "hyperbolic_margin",
    "hilbert_margin",
    "planar_margin",
    "cover_radius_exclusion_mask",
    "hyperplane_exclusion_mask",
    "centre_witness_exclusion_mask",
]

HYPERBOLIC = "hyperbolic"
HILBERT = "hilbert"


# one dtype policy for ALL xp-generic geometry: float32 on device, the
# host's dtype (float64 walks) on numpy — shared with projection.py so the
# exclusion predicates and the planar coordinates they compare against can
# never drift apart in precision
_coerce = projection._coerce


def hyperbolic_margin(d1, d2, *, xp=jnp):
    """Signed triangle-inequality margin for the closer-pivot partition.

    ``(d1 - d2)/2``: negative => closer to p1 (left).  A query may exclude
    the opposite side iff |margin| > t.  (paper: |d(q,p1)-d(q,p2)| > 2t)
    """
    d1, d2 = _coerce(xp, d1, d2)
    return 0.5 * (d1 - d2)


def hilbert_margin(d1, d2, delta, *, xp=jnp):
    """Signed four-point margin: the planar X coordinate
    ``(d1^2 - d2^2) / (2 d(p1,p2))``.  Same sign convention; exclusion of the
    opposite side iff |margin| > t (paper: (d1^2-d2^2)/delta > 2t)."""
    return projection.project_x(d1, d2, delta, xp=xp)


@dataclasses.dataclass(frozen=True)
class PlanarPartition:
    """A linear partition of the projected plane (general Hilbert-style rule).

    margin(point) = nx * r_x + ny * r_y   where (r_x, r_y) = rotate(proj(s))

    ``(nx, ny)`` must be a unit vector (so the margin is a true planar
    coordinate and |margin(q) - split| lower-bounds the planar — hence true —
    distance from q to the partition boundary).

    Instances cover the paper's menagerie:
      * x-split (Hilbert/GHT):      nx=1, ny=0, theta=0, h=0
      * y-split ("height"):         nx=0, ny=1
      * LRT:  rotate by theta around (h, 0), then x-split at median
      * PCA axis split: theta = principal direction angle
    """

    theta: float = 0.0
    h: float = 0.0
    nx: float = 1.0
    ny: float = 0.0
    split: float = 0.0

    def margin(self, x, y, *, xp=jnp):
        rx, ry = projection.rotate(x, y, self.theta, self.h, xp=xp)
        return self.nx * rx + self.ny * ry - self.split

    def separation(self, x, y, *, xp=jnp):
        return xp.abs(self.margin(x, y, xp=xp))


def planar_margin(x, y, theta, h, nx, ny, split, *, xp=jnp):
    """Array form of ``PlanarPartition.margin`` for batched node tables:
    all parameters broadcast (per-node vectors against (..., node) planar
    coordinates).  Same geometry, same soundness argument."""
    rx, ry = projection.rotate(x, y, theta, h, xp=xp)
    return nx * rx + ny * ry - split


def cover_radius_exclusion_mask(dq, cover_r, t, *, xp=jnp):
    """Ball exclusion: child x is excluded when ``d(q, p_x) > cr_x + t``
    (no solution can sit inside a cover ball the query clears by > t).
    Shapes broadcast; +inf dq excludes (a padded slot has no child)."""
    dq, cover_r = _coerce(xp, dq, cover_r)
    return dq > cover_r + t


def hyperplane_exclusion_mask(dq, ref_dists, t, mechanism, *, xp=jnp):
    """Pairwise hyperplane exclusion over an n-ary node (paper Alg. 2).

    Args:
      dq:        (..., k) distances from query/queries to the k reference
                 points of a node.  Padded slots must carry +inf (an inf
                 witness or candidate never triggers a criterion).
      ref_dists: (k, k) pairwise distances among the reference points —
                 or any broadcastable batch of them, e.g. (nodes, k, k)
                 against dq (queries, nodes, k).
      t:         query threshold.
      mechanism: HYPERBOLIC or HILBERT.

    Returns:
      (..., k) boolean mask, True where child x can be EXCLUDED: exists y
      with  d(q,px) - d(q,py) > 2t          (hyperbolic)
      or    (d(q,px)^2 - d(q,py)^2)/d(px,py) > 2t   (Hilbert).
    """
    dq, ref_dists = _coerce(xp, dq, ref_dists)
    dx = dq[..., :, None]  # (..., k, 1) candidate-to-exclude x
    dy = dq[..., None, :]  # (..., 1, k) witness y
    if mechanism == HYPERBOLIC:
        crit = dx - dy > 2.0 * t
    elif mechanism == HILBERT:
        delta = xp.maximum(ref_dists, MIN_DELTA)  # (..., k, k)
        # degenerate witness pairs (duplicate refs) separate nothing: under
        # jit the numerator carries float noise that a tiny delta would
        # amplify into spurious exclusion — neutralise those pairs instead
        crit = ((dx * dx - dy * dy) / delta > 2.0 * t) & (
            ref_dists >= DEGENERATE_DELTA
        )
    else:
        raise ValueError(f"unknown mechanism {mechanism!r}")
    k = dq.shape[-1]
    off_diag = ~xp.eye(k, dtype=bool)
    return xp.any(crit & off_diag, axis=-1)


def centre_witness_exclusion_mask(dq, d_centre, centre_dists, t, mechanism, *, xp=jnp):
    """SAT-family bonus witness: the parent *centre*, whose query distance
    was already paid one level up (passed down for free).

    Args:
      dq:           (..., k) query→reference distances at the node.
      d_centre:     (...,) query→parent-centre distance (NaN when the walk
                    has no centre in hand — NaN comparisons are False, so
                    nothing is excluded: the sound default).
      centre_dists: (k,) build-time d(ref_i, centre) — or a broadcastable
                    batch; NaN entries (witness disabled at build, see
                    ``build_tree``'s centre_witness flag) exclude nothing.
      t, mechanism: as in ``hyperplane_exclusion_mask``.

    Returns (..., k) True where child x is excluded via the centre witness.
    """
    dq, d_centre, centre_dists = _coerce(xp, dq, d_centre, centre_dists)
    dc = d_centre[..., None]  # (..., 1)
    if mechanism == HYPERBOLIC:
        return dq - dc > 2.0 * t
    if mechanism == HILBERT:
        delta = xp.maximum(centre_dists, MIN_DELTA)
        # same degenerate-pair neutralisation as the pairwise criterion: a
        # ref sitting on the centre separates nothing (and a tiny delta
        # would amplify jit float noise into unsound exclusion)
        return ((dq * dq - dc * dc) / delta > 2.0 * t) & (
            centre_dists >= DEGENERATE_DELTA
        )
    raise ValueError(f"unknown mechanism {mechanism!r}")

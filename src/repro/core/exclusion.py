"""Exclusion rules: Hyperbolic (triangle-inequality) vs Hilbert (four-point),
plus the fully general *linear planar partition* family (paper §3.2-3.4).

Conventions
-----------
A binary partition at a tree node is described by a **signed margin**
function ``m(point) -> R``: points with ``m < split`` go left, ``m >= split``
go right.  At query time the engine computes ``m(q)`` and uses a *sound
separation bound* ``sep(q)`` such that

    sep(q) > t   and  q on the right  ==>  no solution on the left
    (symmetrically for the other side)

For **Hilbert** rules the margin is a geometric coordinate in the projected
plane, and ``sep = |m(q) - split|`` is sound because planar distances lower-
bound true distances (four-point property).  Any unit-direction linear
functional of the plane works — x-split, y-split, PCA axis, regression axis.

For **Hyperbolic** rules (no four-point property assumed) the only sound
bound for the closer-of-two-pivots partition is
``sep = |d(q,p1) - d(q,p2)| / 2`` (condition ``|d1-d2| > 2t``).

Cover-radius ("ball") exclusion is independent of both and always sound.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import projection
from repro.core.constants import DEGENERATE_DELTA, MIN_DELTA

__all__ = [
    "HYPERBOLIC",
    "HILBERT",
    "PlanarPartition",
    "hyperbolic_margin",
    "hilbert_margin",
    "hyperplane_exclusion_mask",
]

HYPERBOLIC = "hyperbolic"
HILBERT = "hilbert"


def hyperbolic_margin(d1: jnp.ndarray, d2: jnp.ndarray) -> jnp.ndarray:
    """Signed triangle-inequality margin for the closer-pivot partition.

    ``(d1 - d2)/2``: negative => closer to p1 (left).  A query may exclude
    the opposite side iff |margin| > t.  (paper: |d(q,p1)-d(q,p2)| > 2t)
    """
    return 0.5 * (jnp.asarray(d1, jnp.float32) - jnp.asarray(d2, jnp.float32))


def hilbert_margin(d1: jnp.ndarray, d2: jnp.ndarray, delta) -> jnp.ndarray:
    """Signed four-point margin: the planar X coordinate
    ``(d1^2 - d2^2) / (2 d(p1,p2))``.  Same sign convention; exclusion of the
    opposite side iff |margin| > t (paper: (d1^2-d2^2)/delta > 2t)."""
    return projection.project_x(d1, d2, delta)


@dataclasses.dataclass(frozen=True)
class PlanarPartition:
    """A linear partition of the projected plane (general Hilbert-style rule).

    margin(point) = nx * r_x + ny * r_y   where (r_x, r_y) = rotate(proj(s))

    ``(nx, ny)`` must be a unit vector (so the margin is a true planar
    coordinate and |margin(q) - split| lower-bounds the planar — hence true —
    distance from q to the partition boundary).

    Instances cover the paper's menagerie:
      * x-split (Hilbert/GHT):      nx=1, ny=0, theta=0, h=0
      * y-split ("height"):         nx=0, ny=1
      * LRT:  rotate by theta around (h, 0), then x-split at median
      * PCA axis split: theta = principal direction angle
    """

    theta: float = 0.0
    h: float = 0.0
    nx: float = 1.0
    ny: float = 0.0
    split: float = 0.0

    def margin(self, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
        rx, ry = projection.rotate(x, y, self.theta, self.h)
        return self.nx * rx + self.ny * ry - self.split

    def separation(self, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
        return jnp.abs(self.margin(x, y))


def hyperplane_exclusion_mask(
    dq: jnp.ndarray,
    ref_dists: jnp.ndarray,
    t: float,
    mechanism: str,
) -> jnp.ndarray:
    """Pairwise hyperplane exclusion over an n-ary node (paper Alg. 2).

    Args:
      dq:        (..., k) distances from query/queries to the k reference
                 points of a node.
      ref_dists: (k, k) pairwise distances among the reference points
                 (only used by Hilbert; computed at build time).
      t:         query threshold.
      mechanism: HYPERBOLIC or HILBERT.

    Returns:
      (..., k) boolean mask, True where child x can be EXCLUDED: exists y
      with  d(q,px) - d(q,py) > 2t          (hyperbolic)
      or    (d(q,px)^2 - d(q,py)^2)/d(px,py) > 2t   (Hilbert).
    """
    dx = dq[..., :, None]  # (..., k, 1) candidate-to-exclude x
    dy = dq[..., None, :]  # (..., 1, k) witness y
    if mechanism == HYPERBOLIC:
        crit = dx - dy > 2.0 * t
    elif mechanism == HILBERT:
        delta = jnp.maximum(ref_dists, MIN_DELTA)  # (k, k)
        # degenerate witness pairs (duplicate refs) separate nothing: under
        # jit the numerator carries float noise that a tiny delta would
        # amplify into spurious exclusion — neutralise those pairs instead
        crit = ((dx * dx - dy * dy) / delta > 2.0 * t) & (
            ref_dists >= DEGENERATE_DELTA
        )
    else:
        raise ValueError(f"unknown mechanism {mechanism!r}")
    k = dq.shape[-1]
    off_diag = ~jnp.eye(k, dtype=bool)
    return jnp.any(crit & off_diag, axis=-1)

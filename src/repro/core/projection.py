"""Tetrahedral projection onto a plane (paper §3) — the core geometric tool.

Given two pivots ``p1, p2`` with inter-pivot distance ``delta`` and a point
``s`` with measured distances ``d1 = d(s, p1)``, ``d2 = d(s, p2)``, the point
projects to the apex of the triangle with base
``p1 = (-delta/2, 0), p2 = (+delta/2, 0)``:

    x = (d1^2 - d2^2) / (2 * delta)
    y = sqrt(max(d1^2 - (x + delta/2)^2, 0))          (upper half-plane)

**Lower-bound theorem (paper §3, Fig. 3/4).**  If the space has the
four-point property then for any two points ``s, u``

    l2( proj(s), proj(u) ) <= d(s, u)

so any partition of the plane yields a sound exclusion rule: a query farther
than ``t`` (in the plane) from a region cannot have solutions inside it.
Hilbert exclusion is the special case of the vertical line ``x = 0``.

All functions take an ``xp`` array namespace: ``jax.numpy`` (default —
float32, jit/vmap-friendly, shapes broadcast over leading dims) or ``numpy``
(host dtype preserved, i.e. the float64 tree walks).  The host twins used to
be re-derived in ``core/lrt.py`` and ``core/flat_index.py``; they now share
THIS body, so the degenerate-plane handling (the PR 2 duplicate-pivot fix)
cannot drift between engines.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.constants import DEGENERATE_DELTA, MIN_DELTA

__all__ = [
    "project",
    "project_x",
    "rotate",
    "planar_lower_bound",
    "point_to_interval",
    "point_to_box",
]


def _coerce(xp, *arrays):
    """THE dtype policy for every xp-generic geometry function (here and in
    ``core/exclusion.py``): jnp computes in float32 (the engines' dtype);
    numpy keeps the host dtype (float64 walks, float32 index build)."""
    if xp is jnp:
        return tuple(jnp.asarray(a, jnp.float32) for a in arrays)
    return tuple(xp.asarray(a) for a in arrays)


def project(d1, d2, delta, *, xp=jnp):
    """Planar apex coordinates for distances (d1, d2) w.r.t. pivot gap delta.

    Broadcasts over any leading shape.  Degenerate triangles (numerical noise
    making d1 + d2 < delta) are clamped onto the X-axis, which keeps the
    lower-bound property (clamping can only *reduce* planar distances).

    Degenerate PLANES (delta below ``DEGENERATE_DELTA``: duplicate or
    near-duplicate pivots) project to the ring (x=0, y=d1) — the sound
    triangle-inequality bound — instead of dividing float noise by a tiny
    baseline (see ``repro.core.constants``).
    """
    d1, d2, raw = _coerce(xp, d1, d2, delta)
    delta = xp.maximum(raw, MIN_DELTA)
    x = xp.where(
        raw < DEGENERATE_DELTA, 0.0, (d1 * d1 - d2 * d2) / (2.0 * delta)
    )
    y_sq = d1 * d1 - (x + delta / 2.0) ** 2
    y = xp.sqrt(xp.maximum(y_sq, 0.0))
    return x, y


def project_x(d1, d2, delta, *, xp=jnp):
    """X coordinate only — this is the Hilbert-exclusion quantity
    ``(d1^2 - d2^2) / (2 delta)`` (signed distance to the separating
    hyperplane's planar image).  Degenerate planes yield 0 (no exclusion —
    coincident pivots separate nothing)."""
    d1, d2, raw = _coerce(xp, d1, d2, delta)
    delta = xp.maximum(raw, MIN_DELTA)
    return xp.where(
        raw < DEGENERATE_DELTA, 0.0, (d1 * d1 - d2 * d2) / (2.0 * delta)
    )


def rotate(x, y, theta, h, *, xp=jnp):
    """Rotate planar points by ``-theta``-style LRT transform around the
    X-intercept ``(h, 0)`` (paper Eq. 2-3):

        r_x = (x - h) cos(theta) + y sin(theta)
        r_y = -(x - h) sin(theta) + y cos(theta)

    Note: the paper prints the rotation with the signs producing a rotation
    by ``-theta``; what matters for correctness is that it is a *rigid*
    transform (distance-preserving), so the lower-bound property survives.
    """
    x, y, theta, h = _coerce(xp, x, y, theta, h)
    c = xp.cos(theta)
    s = xp.sin(theta)
    xs = x - h
    return xs * c + y * s, -xs * s + y * c


def planar_lower_bound(x1, y1, x2, y2, *, xp=jnp):
    """l2 distance in the plane == lower bound on true distance (supermetric)."""
    return xp.sqrt((x1 - x2) ** 2 + (y1 - y2) ** 2)


def point_to_interval(v, lo, hi, *, xp=jnp):
    """Distance from scalar coordinate(s) to interval(s) [lo, hi] (0 inside)."""
    return xp.maximum(xp.maximum(lo - v, v - hi), 0.0)


def point_to_box(x, y, box, *, xp=jnp):
    """Planar distance from point(s) to axis-aligned box(es).

    ``box[..., :] = (x_lo, x_hi, y_lo, y_hi)``.  Broadcasts.  Because the
    planar metric lower-bounds the true metric, this is a sound lower bound
    on the distance from the query to EVERY point whose projection lies in
    the box — the Blocked Supermetric Scan's pruning primitive.
    """
    dx = point_to_interval(x, box[..., 0], box[..., 1], xp=xp)
    dy = point_to_interval(y, box[..., 2], box[..., 3], xp=xp)
    return xp.sqrt(dx * dx + dy * dy)

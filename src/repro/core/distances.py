"""Distance metrics with explicit supermetric (four-point) classification.

The paper's taxonomy (Connor et al., Supermetric Search, 2017, §2.2):

* four-point property (isometrically 4-embeddable in l2^3, i.e. *supermetric*):
  Euclidean, Jensen-Shannon, Triangular, and the properly-formulated Cosine
  distance; also ``d^alpha`` for any metric ``d`` and ``0 < alpha <= 1/2``.
* NOT four-point: Manhattan (l1), Chebyshev (linf), Levenshtein.

Every metric exposes

* ``pairwise(X, Y) -> (n, m)`` distance matrix — the batched form every
  engine in this framework consumes (TPU-first design),
* ``point(x, y) -> scalar`` convenience wrapper,
* ``four_point`` — whether Hilbert exclusion / planar lower-bounding is sound.

All functions are pure jnp and jit/vmap/pjit-compatible. ``pairwise`` for
Euclidean/Cosine routes through a single matmul (MXU-friendly); the Pallas
kernel in ``repro.kernels.pairwise_dist`` implements the same contraction
with explicit VMEM tiling and is validated against these references.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "Metric",
    "METRICS",
    "get_metric",
    "l2",
    "cosine",
    "jsd",
    "triangular",
    "l1",
    "linf",
    "power_transform",
]

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class Metric:
    """A distance metric with batched evaluation and supermetric metadata."""

    name: str
    pairwise: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    four_point: bool
    # True when inputs must be probability vectors (non-negative, sum to 1).
    probability_space: bool = False

    def point(self, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
        return self.pairwise(x[None, :], y[None, :])[0, 0]

    def to_query(self, q: jnp.ndarray, xs: jnp.ndarray) -> jnp.ndarray:
        """Distances from one query to a set of points, shape (n,)."""
        return self.pairwise(q[None, :], xs)[0]


# ---------------------------------------------------------------------------
# Supermetric distances (four-point property holds)
# ---------------------------------------------------------------------------


def _sq_norms(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(x * x, axis=-1)


def _l2_pairwise(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """||x-y|| via the matmul identity; fp32 accumulation; clamped at 0."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    sq = _sq_norms(x)[:, None] + _sq_norms(y)[None, :] - 2.0 * (x @ y.T)
    return jnp.sqrt(jnp.maximum(sq, 0.0))


def _cosine_pairwise(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Proper (supermetric) Cosine distance, per Connor et al. [1]:

    the Euclidean distance between l2-normalised vectors,
    ``d(x, y) = sqrt(2 - 2 cos(x, y))``.  (The common ``1 - cos`` form is not
    even a metric; this form inherits the n-point property from l2.)
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), _EPS)
    yn = y / jnp.maximum(jnp.linalg.norm(y, axis=-1, keepdims=True), _EPS)
    cos = jnp.clip(xn @ yn.T, -1.0, 1.0)
    return jnp.sqrt(jnp.maximum(2.0 - 2.0 * cos, 0.0))


def _xlogx(v: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(v > _EPS, v * jnp.log(jnp.maximum(v, _EPS)), 0.0)


def _jsd_pairwise(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Jensen-Shannon *distance* (sqrt of base-2 JS divergence).

    Defined over probability vectors; value in [0, 1].  Has the n-point
    property (Connor et al. [1], via isometric Hilbert-space embedding).
    Quadratic-memory formulation (broadcast over pairs) — the Pallas/blocked
    path tiles this; the reference keeps it simple.
    """
    x = x.astype(jnp.float32)[:, None, :]
    y = y.astype(jnp.float32)[None, :, :]
    m = 0.5 * (x + y)
    # JS = H(m) - (H(x)+H(y))/2, computed as sum of xlogx terms (natural log).
    js = jnp.sum(0.5 * _xlogx(x) + 0.5 * _xlogx(y) - _xlogx(m), axis=-1)
    js = jnp.maximum(js, 0.0) / jnp.log(2.0)  # base-2, in [0, 1]
    return jnp.sqrt(js)


def _triangular_pairwise(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Triangular distance: sqrt of (half the) triangular discrimination,

    ``d(x, y) = sqrt( 0.5 * sum_i (x_i - y_i)^2 / (x_i + y_i) )``

    over probability vectors; supermetric per Connor et al. [1].
    """
    x = x.astype(jnp.float32)[:, None, :]
    y = y.astype(jnp.float32)[None, :, :]
    num = (x - y) ** 2
    den = jnp.maximum(x + y, _EPS)
    return jnp.sqrt(jnp.maximum(0.5 * jnp.sum(num / den, axis=-1), 0.0))


# ---------------------------------------------------------------------------
# Plain-metric distances (four-point property FAILS — kept as controls)
# ---------------------------------------------------------------------------


def _l1_pairwise(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.float32)[:, None, :]
    y = y.astype(jnp.float32)[None, :, :]
    return jnp.sum(jnp.abs(x - y), axis=-1)


def _linf_pairwise(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.float32)[:, None, :]
    y = y.astype(jnp.float32)[None, :, :]
    return jnp.max(jnp.abs(x - y), axis=-1)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

l2 = Metric("l2", _l2_pairwise, four_point=True)
cosine = Metric("cosine", _cosine_pairwise, four_point=True)
jsd = Metric("jsd", _jsd_pairwise, four_point=True, probability_space=True)
triangular = Metric(
    "triangular", _triangular_pairwise, four_point=True, probability_space=True
)
l1 = Metric("l1", _l1_pairwise, four_point=False)
linf = Metric("linf", _linf_pairwise, four_point=False)

METRICS: dict[str, Metric] = {
    m.name: m for m in (l2, cosine, jsd, triangular, l1, linf)
}


def power_transform(base: Metric, alpha: float = 0.5) -> Metric:
    """``d^alpha`` for ``0 < alpha <= 1/2`` has the four-point property for
    ANY metric ``d`` (paper §2.2 item 4) — this upgrades e.g. l1 into a
    supermetric at the cost of distorting the distance distribution.

    The metric is REGISTERED: it lands in ``METRICS`` under
    ``"{base}^{alpha}"`` and gets a numpy twin in ``npdist``, so every
    engine (``build_bss``, ``build_tree``, ``pairwise_np``, benchmarks)
    accepts the name like any built-in metric."""
    if not (0.0 < alpha <= 0.5):
        raise ValueError("four-point property only guaranteed for 0 < alpha <= 1/2")

    def pw(x, y, _base=base.pairwise, _a=alpha):
        return jnp.power(jnp.maximum(_base(x, y), 0.0), _a)

    m = Metric(
        f"{base.name}^{alpha}",
        pw,
        four_point=True,
        probability_space=base.probability_space,
    )
    METRICS[m.name] = m
    # numpy twin, so the host-side engines accept the name too (late import:
    # npdist is numpy-only and must not depend on this jnp module)
    from repro.core import npdist

    npdist.register_power(base.name, alpha)
    return m


def get_metric(name: str) -> Metric:
    """Registry lookup; ``"{base}^{alpha}"`` power-transform names (e.g.
    ``"l1^0.5"``) are parsed and registered on first use."""
    if name not in METRICS and "^" in name:
        base, _, exp = name.partition("^")
        if base in METRICS:
            try:
                # parses a STATIC metric-name string at trace time
                alpha = float(exp)  # lint: disable=R2
            except ValueError:
                alpha = None
            # only canonical names register ("l1^0.5", not "l1^0.50") — a
            # failed lookup must not mutate the registry as a side effect
            if alpha is not None and f"{base}^{alpha}" == name:
                power_transform(METRICS[base], alpha)
    if name not in METRICS:
        raise KeyError(f"unknown metric {name!r}; have {sorted(METRICS)}")
    return METRICS[name]

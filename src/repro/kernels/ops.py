"""Public jit'd entry points for the Pallas kernels, with automatic
interpret-mode fallback off-TPU and shape-padding handled inside.

``bss_lower_bounds_fused`` wires the kernels into the BSS index: one fused
projection+bounding kernel, then (optionally) the masked pairwise kernel over
survivors — the full TPU query path of DESIGN.md §2.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.pairwise_dist import (
    KERNEL_METRICS,
    masked_pairwise_kernel_call,
    masked_pairwise_l2_kernel_call,
    pairwise_kernel_call,
    pairwise_l2_kernel_call,
)
from repro.kernels.planar_exclusion import planar_lower_bound_kernel_call
from repro.kernels.tiles import TILE_BLOCK, TILE_BQ

__all__ = [
    "pairwise_l2",
    "masked_pairwise_l2",
    "pairwise_metric",
    "masked_pairwise_metric",
    "KERNEL_METRICS",
    "planar_lower_bound",
    "bss_query_fused",
]

pairwise_l2 = pairwise_l2_kernel_call
masked_pairwise_l2 = masked_pairwise_l2_kernel_call
pairwise_metric = pairwise_kernel_call
masked_pairwise_metric = masked_pairwise_kernel_call
planar_lower_bound = planar_lower_bound_kernel_call


def bss_query_fused(
    queries: jnp.ndarray,
    pivots: jnp.ndarray,
    pair_idx: jnp.ndarray,
    deltas: jnp.ndarray,
    boxes: jnp.ndarray,
    data: jnp.ndarray,
    t: float,
    *,
    block: int = TILE_BLOCK,
    bq: int = TILE_BQ,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full TPU-native BSS range query (dense masked form).

    Returns (dist, tile_mask): dist (Q, N) with +inf where tiles were pruned,
    tile_mask (Qtiles, B) the per-tile survival matrix.  Exact: every true
    hit (d <= t) is guaranteed live by the four-point lower bound.
    """
    dqp = pairwise_l2_kernel_call(queries, pivots, interpret=interpret)  # (Q, P)
    d1 = dqp[:, pair_idx[:, 0]]
    d2 = dqp[:, pair_idx[:, 1]]
    lb = planar_lower_bound_kernel_call(
        d1, d2, deltas, boxes, bq=bq, interpret=interpret
    )  # (Q, B)
    qtiles = -(-queries.shape[0] // bq)
    lb_pad = jnp.pad(lb, ((0, qtiles * bq - lb.shape[0]), (0, 0)), constant_values=jnp.inf)
    tile_mask = (
        lb_pad.reshape(qtiles, bq, -1).min(axis=1) <= t
    )  # a tile survives if ANY of its queries does
    dist = masked_pairwise_l2_kernel_call(
        queries, data, tile_mask, bm=bq, bn=block, interpret=interpret
    )
    return dist, tile_mask

import functools  # noqa: E402

from repro.kernels.jsd_dist import pairwise_jsd_kernel_call  # noqa: E402

pairwise_jsd = pairwise_jsd_kernel_call
# triangular has no standalone call module — it shares the dispatched
# plumbing in pairwise_dist (one copy of the grid/padding machinery)
pairwise_tri = functools.partial(pairwise_kernel_call, "triangular")
__all__ += ["pairwise_jsd", "pairwise_tri"]

"""Pallas TPU kernel: blocked pairwise distance matrix.

This is the framework's compute hot-spot — the paper's cost model is
*distance evaluations*, and on TPU those are batched into an MXU contraction:

    d2(X, Y) = |x|^2 + |y|^2 - 2 X Y^T

Tiling: grid over (M/bm, N/bn) output tiles.  Each grid cell streams an
(bm, K) X tile and an (bn, K) Y tile from HBM into VMEM, contracts on the MXU
with fp32 accumulation, adds the squared norms (computed in-kernel on the
VPU — cheaper than two extra HBM-resident operands), and writes one output
tile.  Metric-space dims (K = 10..512) fit VMEM whole, so K is NOT tiled;
bm = bn = 128 matches the MXU systolic array and the BSS block size, making
"block pruned" == "grid cell skipped" (see masked variant).

VMEM budget per cell @ bm=bn=128, K=512, fp32:
    X tile 256 KiB + Y tile 256 KiB + out 64 KiB + norms ~1 KiB  << 16 MiB.

The masked variant consumes the BSS exclusion mask (one flag per output
tile) and skips the MXU work of excluded tiles via ``pl.when`` — the planar
lower bound of the paper materialised as *actually skipped* compute.

Metric-dispatched family
------------------------
``pairwise_kernel_call`` / ``masked_pairwise_kernel_call`` dispatch one tile
kernel per supermetric: l2 (MXU contraction, this module), JSD and
Triangular (VPU broadcast reductions, ``jsd_dist`` / ``tri_dist``).  The
masked wrapper is metric-agnostic — the ``pl.when`` tile skip is applied
around whichever tile kernel the metric resolves to, so every supermetric
gets the same "block pruned == grid cell skipped" guarantee.  Cosine never
appears here: the engine serves it as l2 over unit-normalised vectors
(exact, per the supermetric cosine definition).

Mixed precision
---------------
The family is dtype-parametrised through jit: operands keep their storage
dtype across the HBM->VMEM stream and every tile kernel upcasts to fp32 ON
ENTRY (``.astype`` + ``preferred_element_type``), so accumulation is always
fp32 and the output is always an fp32 distance tile.  The bf16 exact phase
(``precision="bf16"`` in the engines) exploits exactly this: ``y`` is the
bfloat16 corpus mirror (half the streamed bytes — the dominant traffic),
``x`` stays fp32 (queries are a rounding error of the traffic, and keeping
them exact halves the comparison margin).  bf16 operands meet the TPU
minimum tile (16, 128) trivially at bn = 128; the comparison-margin
machinery that makes the halved precision EXACT lives in
``repro.core.precision`` and the engine drivers, not here — these kernels
compute the same function regardless of the storage dtype, just at the
storage dtype's rounding of ``y``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.jsd_dist import _jsd_tile_kernel
from repro.kernels.tiles import TILE_BLOCK, TILE_BQ
from repro.kernels.tri_dist import _tri_tile_kernel

__all__ = [
    "pairwise_l2_kernel_call",
    "masked_pairwise_l2_kernel_call",
    "pairwise_kernel_call",
    "masked_pairwise_kernel_call",
    "KERNEL_METRICS",
]

# overridable without a rebuild via REPRO_TILE_BQ / REPRO_TILE_BLOCK
# (see repro.kernels.tiles) — the TPU-autotuning knob.
DEFAULT_BM = TILE_BQ
DEFAULT_BN = TILE_BLOCK


def _interpret_default() -> bool:
    # Kernels TARGET TPU; everywhere else they run in interpret mode.
    return jax.default_backend() != "tpu"


def _l2_tile_kernel(x_ref, y_ref, o_ref, *, squared: bool):
    x = x_ref[...].astype(jnp.float32)  # (bm, K)
    y = y_ref[...].astype(jnp.float32)  # (bn, K)
    # MXU contraction with explicit fp32 accumulation.
    xy = jax.lax.dot_general(
        x,
        y,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    xx = jnp.sum(x * x, axis=1, keepdims=True)  # (bm, 1)  VPU
    yy = jnp.sum(y * y, axis=1, keepdims=True).T  # (1, bn)
    sq = jnp.maximum(xx + yy - 2.0 * xy, 0.0)
    o_ref[...] = sq if squared else jnp.sqrt(sq)


def _masked_tile_kernel(mask_ref, x_ref, y_ref, o_ref, *, tile_kernel):
    """Metric-agnostic mask wrapper: the whole compute tile is skipped when
    the BSS planar lower bound already excluded this (query-tile, block)
    cell — excluded tiles are filled with +inf without touching MXU/VPU."""
    o_ref[...] = jnp.full_like(o_ref, jnp.inf)

    @pl.when(mask_ref[0, 0] != 0)
    def _do():
        tile_kernel(x_ref, y_ref, o_ref)


# metric name -> unmasked tile kernel (x_ref, y_ref, o_ref); the masked
# variant is derived by wrapping with _masked_tile_kernel
_TILE_KERNELS = {
    "l2": functools.partial(_l2_tile_kernel, squared=False),
    "jsd": _jsd_tile_kernel,
    "triangular": _tri_tile_kernel,
}
KERNEL_METRICS = tuple(_TILE_KERNELS)


def _pad_to(a: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    rem = a.shape[axis] % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(a, pad)


def _pairwise_call(tile_kernel, x, y, *, bm, bn, interpret):
    """Shared (grid, padding, pallas_call) plumbing for unmasked tiles."""
    m, k = x.shape
    n, k2 = y.shape
    if k != k2:
        raise ValueError(
            f"x and y must share the feature dimension: {x.shape} vs {y.shape}"
        )
    xp = _pad_to(x, bm, 0)
    yp = _pad_to(y, bn, 0)
    mp, np_ = xp.shape[0], yp.shape[0]
    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n]


def _masked_call(tile_kernel, x, y, tile_mask, *, bm, bn, interpret):
    """Shared plumbing for masked tiles: one mask flag per output tile,
    excluded tiles short-circuit to +inf via ``pl.when``."""
    m, k = x.shape
    n, _ = y.shape
    xp = _pad_to(x, bm, 0)
    yp = _pad_to(y, bn, 0)
    mp, np_ = xp.shape[0], yp.shape[0]
    grid = (mp // bm, np_ // bn)
    if tile_mask.shape != grid:
        raise ValueError(
            f"tile_mask shape {tile_mask.shape} does not match the "
            f"(m_tiles, n_tiles) grid {grid}"
        )
    out = pl.pallas_call(
        functools.partial(_masked_tile_kernel, tile_kernel=tile_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(tile_mask.astype(jnp.int32), xp, yp)
    return out[:m, :n]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "squared", "interpret")
)
def pairwise_l2_kernel_call(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    squared: bool = False,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(m, K), (n, K) -> (m, n) Euclidean distance matrix."""
    if interpret is None:
        interpret = _interpret_default()
    return _pairwise_call(
        functools.partial(_l2_tile_kernel, squared=squared),
        x, y, bm=bm, bn=bn, interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "squared", "interpret")
)
def masked_pairwise_l2_kernel_call(
    x: jnp.ndarray,
    y: jnp.ndarray,
    tile_mask: jnp.ndarray,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    squared: bool = False,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Masked variant: ``tile_mask[i, j] != 0`` marks live output tiles;
    excluded tiles are filled with +inf without touching the MXU.

    ``tile_mask`` has shape (ceil(m/bm), ceil(n/bn)) — for BSS use bn = the
    index block size so mask == block-survival matrix.
    """
    if interpret is None:
        interpret = _interpret_default()
    return _masked_call(
        functools.partial(_l2_tile_kernel, squared=squared),
        x, y, tile_mask, bm=bm, bn=bn, interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("metric_name", "bm", "bn", "interpret")
)
def pairwise_kernel_call(
    metric_name: str,
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Metric-dispatched (m, K), (n, K) -> (m, n) distance matrix for every
    metric in ``KERNEL_METRICS``."""
    if interpret is None:
        interpret = _interpret_default()
    return _pairwise_call(
        _TILE_KERNELS[metric_name], x, y, bm=bm, bn=bn, interpret=interpret
    )


@functools.partial(
    jax.jit, static_argnames=("metric_name", "bm", "bn", "interpret")
)
def masked_pairwise_kernel_call(
    metric_name: str,
    x: jnp.ndarray,
    y: jnp.ndarray,
    tile_mask: jnp.ndarray,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Metric-dispatched masked pairwise: the BSS exact phase for every
    metric in ``KERNEL_METRICS``, with the same tile-skipping contract as
    ``masked_pairwise_l2_kernel_call``."""
    if interpret is None:
        interpret = _interpret_default()
    return _masked_call(
        _TILE_KERNELS[metric_name], x, y, tile_mask,
        bm=bm, bn=bn, interpret=interpret,
    )

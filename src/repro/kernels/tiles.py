"""Tile-size knobs for the masked Pallas kernel family.

The fused engines tile their work as (query-tile x corpus-block) cells with
a K-lane chunk bounding the VPU broadcast transient.  The defaults
(128 / 128 / 64) match the MXU systolic array and the BSS block size, but
real-TPU autotuning (see ROADMAP "Pallas masked-kernel autotuning") needs a
way to try other shapes WITHOUT a rebuild — so each constant reads an
environment variable at import time:

    REPRO_TILE_BQ      query-tile rows   (kernel bm / bq)      default 128
    REPRO_TILE_BLOCK   corpus-block cols (kernel bn / bb)      default 128
    REPRO_TILE_KCHUNK  K lanes reduced per VPU pass            default 64
    REPRO_TILE_VPU     standalone VPU-kernel tile (bm = bn)    default 64

This module is import-light on purpose (no jax): it must be readable by
tooling/subprocesses without paying the jax import.  Consumers:
``kernels/pairwise_dist.py`` (bm/bn), ``kernels/planar_exclusion.py``
(bq/bb), ``kernels/jsd_dist.py`` / ``kernels/tri_dist.py`` (K-chunk),
``core/flat_index.py`` and ``forest/walk.py`` (query-tile default).
"""

from __future__ import annotations

import os

__all__ = ["TILE_BQ", "TILE_BLOCK", "TILE_KCHUNK", "TILE_VPU"]


def _env_int(name: str, default: int) -> int:
    raw = os.environ.get(name)
    if raw is None or not raw.strip():
        return default
    try:
        val = int(raw)
    except ValueError as e:
        raise ValueError(f"{name} must be an integer, got {raw!r}") from e
    if val <= 0:
        raise ValueError(f"{name} must be positive, got {val}")
    return val


# query-tile rows of every masked/unmasked pairwise kernel (bm / bq)
TILE_BQ = _env_int("REPRO_TILE_BQ", 128)

# corpus-block columns (bn / bb); the BSS index build keeps its own `block`
# parameter — for "block pruned == grid cell skipped" they should agree
TILE_BLOCK = _env_int("REPRO_TILE_BLOCK", 128)

# K lanes reduced per VPU pass in the broadcast-reduction tile kernels
# (jsd / triangular); bounds the (bm, bn, Kc) VMEM transient
TILE_KCHUNK = _env_int("REPRO_TILE_KCHUNK", 64)

# default square tile of the STANDALONE VPU kernels (the unmasked
# jsd/triangular entry points, where the transcendental cost dominates and
# a smaller tile keeps the broadcast transient cheap); the BSS masked
# exact phase always overrides with bm=TILE_BQ / bn=TILE_BLOCK
TILE_VPU = _env_int("REPRO_TILE_VPU", 64)

"""Pallas TPU tile kernel: blocked pairwise Triangular distance.

Triangular discrimination has no MXU contraction form — like JSD it is a
pure-VPU broadcast reduction over probability vectors:

    d(x, y) = sqrt( 0.5 * sum_i (x_i - y_i)^2 / (x_i + y_i) )

The (bm, bn, Kc) broadcast is reduced in K-chunks of ``_K_CHUNK`` lanes, so
the VMEM transient never exceeds bm*bn*_K_CHUNK*4 bytes (4 MiB at 128x128
tiles) regardless of the metric-space dimension.

Padding rows are all-zero: (0-0)^2 / max(0+0, eps) = 0, a valid input —
padded cells are sliced away by the caller / masked by the BSS valid mask.

This module holds only the tile kernel; the grid/padding plumbing and the
jitted entry points live in ``pairwise_dist`` (``pairwise_kernel_call`` /
``masked_pairwise_kernel_call`` dispatch on ``"triangular"``) so every
metric shares one copy of the call machinery.

Dtype-parametrised like the rest of the family (``pairwise_dist``, "Mixed
precision"): operands stream at their storage dtype and the tile kernel
upcasts to fp32 on entry, so a bfloat16 Y (the engines' bf16 corpus
mirror) halves the streamed bytes while the division and accumulation
stay fp32.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.tiles import TILE_KCHUNK

__all__ = ["_tri_tile_kernel"]

_EPS = 1e-12
# lanes reduced per VPU pass; bounds the (bm, bn, Kc) transient.
# Overridable via REPRO_TILE_KCHUNK (repro.kernels.tiles).
_K_CHUNK = TILE_KCHUNK


def _tri_tile_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (bm, K)
    y = y_ref[...].astype(jnp.float32)  # (bn, K)
    k = x.shape[1]
    acc = jnp.zeros((x.shape[0], y.shape[0]), jnp.float32)
    for k0 in range(0, k, _K_CHUNK):  # static K => unrolled at trace time
        xs = x[:, None, k0 : k0 + _K_CHUNK]
        ys = y[None, :, k0 : k0 + _K_CHUNK]
        num = (xs - ys) ** 2
        den = jnp.maximum(xs + ys, _EPS)
        acc = acc + jnp.sum(num / den, axis=-1)
    o_ref[...] = jnp.sqrt(jnp.maximum(0.5 * acc, 0.0))

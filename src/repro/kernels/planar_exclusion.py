"""Pallas TPU kernel: fused tetrahedral projection + block lower-bounding.

Computes, for a tile of queries and a tile of BSS blocks, the planar
lower-bound matrix

    lb[q, b] = max_m  dist2d( proj_m(q), box[b, m] )

fusing (i) apex projection of the query onto every pivot-pair plane
(paper §3, Eq. in Fig. 4), (ii) point-to-rectangle distance, (iii) the
max-reduction over planes — one HBM read of the query-pivot distances and
the box table, one write of the bound.  Pure VPU work (no MXU), so the tile
shape is chosen lane-friendly: (bq, bb) = (128, 128) output with the M-plane
axis unrolled in VMEM.

VMEM @ bq=bb=128, M=32: d1/d2 2*16 KiB + boxes 128*32*4*4 = 64 KiB +
intermediate (128,128,32) fp32 = 2 MiB < 16 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.constants import DEGENERATE_DELTA, MIN_DELTA
from repro.kernels.tiles import TILE_BLOCK, TILE_BQ

__all__ = ["planar_lower_bound_kernel_call"]

# overridable via REPRO_TILE_BQ / REPRO_TILE_BLOCK (repro.kernels.tiles)
DEFAULT_BQ = TILE_BQ
DEFAULT_BB = TILE_BLOCK


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _lb_tile_kernel(d1_ref, d2_ref, delta_ref, boxes_ref, o_ref):
    d1 = d1_ref[...].astype(jnp.float32)  # (bq, M)
    d2 = d2_ref[...].astype(jnp.float32)  # (bq, M)
    raw = delta_ref[...].astype(jnp.float32)  # (1, M)
    delta = jnp.maximum(raw, MIN_DELTA)
    boxes = boxes_ref[...].astype(jnp.float32)  # (bb, M, 4)

    # apex projection (fused; never leaves VMEM); degenerate planes use the
    # ring bound x=0 — must match projection.project / ref exactly
    qx = jnp.where(
        raw < DEGENERATE_DELTA, 0.0, (d1 * d1 - d2 * d2) / (2.0 * delta)
    )  # (bq, M)
    qy = jnp.sqrt(jnp.maximum(d1 * d1 - (qx + delta / 2.0) ** 2, 0.0))

    qxe = qx[:, None, :]  # (bq, 1, M)
    qye = qy[:, None, :]
    dx = jnp.maximum(jnp.maximum(boxes[None, :, :, 0] - qxe, qxe - boxes[None, :, :, 1]), 0.0)
    dy = jnp.maximum(jnp.maximum(boxes[None, :, :, 2] - qye, qye - boxes[None, :, :, 3]), 0.0)
    lb = jnp.sqrt(dx * dx + dy * dy)  # (bq, bb, M)
    o_ref[...] = jnp.max(lb, axis=-1)


def _pad_to(a: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    rem = a.shape[axis] % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(a, pad)


@functools.partial(jax.jit, static_argnames=("bq", "bb", "interpret"))
def planar_lower_bound_kernel_call(
    d1: jnp.ndarray,
    d2: jnp.ndarray,
    deltas: jnp.ndarray,
    boxes: jnp.ndarray,
    *,
    bq: int = DEFAULT_BQ,
    bb: int = DEFAULT_BB,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """d1, d2: (Q, M) query distances to each plane's two pivots;
    deltas: (M,); boxes: (B, M, 4).  Returns (Q, B) lower bounds.

    Padding blocks get boxes at +inf distance (empty box ⇒ bound inf), so
    padded cells never survive.
    """
    if interpret is None:
        interpret = _interpret_default()
    q, m = d1.shape
    b = boxes.shape[0]
    d1p = _pad_to(d1, bq, 0)
    d2p = _pad_to(d2, bq, 0)
    if b % bb:
        padb = bb - b % bb
        fill = jnp.tile(
            jnp.asarray([3.0e38, 3.1e38, 3.0e38, 3.1e38], jnp.float32), (padb, m, 1)
        )
        boxesp = jnp.concatenate([boxes, fill], axis=0)
    else:
        boxesp = boxes
    qp, bp = d1p.shape[0], boxesp.shape[0]
    grid = (qp // bq, bp // bb)
    out = pl.pallas_call(
        _lb_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, m), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, m), lambda i, j: (i, 0)),
            pl.BlockSpec((1, m), lambda i, j: (0, 0)),
            pl.BlockSpec((bb, m, 4), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp, bp), jnp.float32),
        interpret=interpret,
    )(d1p, d2p, deltas[None, :], boxesp)
    return out[:q, :b]

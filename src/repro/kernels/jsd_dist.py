"""Pallas TPU kernel: blocked pairwise Jensen-Shannon distance.

Unlike l2 (MXU matmul identity), JSD has no contraction form — it is a
transcendental-heavy VPU workload:

    JS(x, y) = sum_i [ x_i/2 log x_i + y_i/2 log y_i - m_i log m_i ],
    m = (x+y)/2;   d = sqrt(JS / ln 2)

Tiling: grid over (M/bm, N/bn) output tiles; each cell streams an (bm, K)
X tile and (bn, K) Y tile into VMEM and loops the pair reduction on the VPU.
The x-entropy term depends only on x (resp. y) — precomputed per tile to
avoid recomputing it bn (resp. bm) times.  The mixture-entropy broadcast is
reduced in K-chunks of ``_K_CHUNK`` lanes so the (bm, bn, Kc) transient is
bounded at 4 MiB even for 128x128 tiles (the BSS masked exact phase ties
bm/bn to the query-tile / block sizes) and large metric-space dims.

Dtype-parametrised like the rest of the family (``pairwise_dist``, "Mixed
precision"): operands stream at their storage dtype and the tile kernel
upcasts to fp32 on entry, so a bfloat16 Y (the engines' bf16 corpus
mirror) halves the streamed bytes while the log/entropy arithmetic and
accumulation stay fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiles import TILE_KCHUNK, TILE_VPU

__all__ = ["pairwise_jsd_kernel_call"]

_EPS = 1e-12
# lanes reduced per VPU pass; bounds the (bm, bn, Kc) transient.
# Overridable via REPRO_TILE_KCHUNK (repro.kernels.tiles).
_K_CHUNK = TILE_KCHUNK


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _xlogx(v):
    return jnp.where(v > _EPS, v * jnp.log(jnp.maximum(v, _EPS)), 0.0)


def _jsd_tile_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (bm, K)
    y = y_ref[...].astype(jnp.float32)  # (bn, K)
    k = x.shape[1]
    hx = jnp.sum(_xlogx(x), axis=1)  # (bm,) entropy terms, computed once
    hy = jnp.sum(_xlogx(y), axis=1)  # (bn,)
    hm = jnp.zeros((x.shape[0], y.shape[0]), jnp.float32)  # (bm, bn)
    for k0 in range(0, k, _K_CHUNK):  # static K => unrolled at trace time
        m = 0.5 * (x[:, None, k0 : k0 + _K_CHUNK] + y[None, :, k0 : k0 + _K_CHUNK])
        hm = hm + jnp.sum(_xlogx(m), axis=-1)
    js = 0.5 * hx[:, None] + 0.5 * hy[None, :] - hm
    o_ref[...] = jnp.sqrt(jnp.maximum(js, 0.0) / jnp.log(2.0))


def _pad_to(a, mult, axis):
    rem = a.shape[axis] % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(a, pad)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def pairwise_jsd_kernel_call(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    bm: int = TILE_VPU,
    bn: int = TILE_VPU,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(m, K), (n, K) probability vectors -> (m, n) JS distance matrix."""
    if interpret is None:
        interpret = _interpret_default()
    m, k = x.shape
    n, _ = y.shape
    # padding rows are all-zero -> valid inputs for the xlogx guard
    xp = _pad_to(x, bm, 0)
    yp = _pad_to(y, bn, 0)
    grid = (xp.shape[0] // bm, yp.shape[0] // bn)
    out = pl.pallas_call(
        _jsd_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], yp.shape[0]), jnp.float32),
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n]

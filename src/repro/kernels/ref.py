"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle.
"""

from __future__ import annotations

import jax.numpy as jnp


def pairwise_l2_ref(x: jnp.ndarray, y: jnp.ndarray, squared: bool = False) -> jnp.ndarray:
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    sq = (
        jnp.sum(x * x, axis=1)[:, None]
        + jnp.sum(y * y, axis=1)[None, :]
        - 2.0 * (x @ y.T)
    )
    sq = jnp.maximum(sq, 0.0)
    return sq if squared else jnp.sqrt(sq)


def masked_pairwise_l2_ref(
    x: jnp.ndarray, y: jnp.ndarray, tile_mask: jnp.ndarray, bm: int, bn: int,
    squared: bool = False,
) -> jnp.ndarray:
    d = pairwise_l2_ref(x, y, squared=squared)
    mrep = jnp.repeat(jnp.repeat(tile_mask != 0, bm, axis=0), bn, axis=1)
    mrep = mrep[: d.shape[0], : d.shape[1]]
    return jnp.where(mrep, d, jnp.inf)


def planar_lower_bound_ref(
    d1: jnp.ndarray, d2: jnp.ndarray, deltas: jnp.ndarray, boxes: jnp.ndarray
) -> jnp.ndarray:
    d1 = d1.astype(jnp.float32)
    d2 = d2.astype(jnp.float32)
    delta = jnp.maximum(deltas.astype(jnp.float32)[None, :], 1e-12)
    qx = (d1 * d1 - d2 * d2) / (2.0 * delta)
    qy = jnp.sqrt(jnp.maximum(d1 * d1 - (qx + delta / 2.0) ** 2, 0.0))
    qxe = qx[:, None, :]
    qye = qy[:, None, :]
    bx = boxes[None]
    dx = jnp.maximum(jnp.maximum(bx[..., 0] - qxe, qxe - bx[..., 1]), 0.0)
    dy = jnp.maximum(jnp.maximum(bx[..., 2] - qye, qye - bx[..., 3]), 0.0)
    return jnp.max(jnp.sqrt(dx * dx + dy * dy), axis=-1)


def pairwise_jsd_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    _EPS = 1e-12

    def xlogx(v):
        return jnp.where(v > _EPS, v * jnp.log(jnp.maximum(v, _EPS)), 0.0)

    x = x.astype(jnp.float32)[:, None, :]
    y = y.astype(jnp.float32)[None, :, :]
    m = 0.5 * (x + y)
    js = jnp.sum(0.5 * xlogx(x) + 0.5 * xlogx(y) - xlogx(m), axis=-1)
    return jnp.sqrt(jnp.maximum(js, 0.0) / jnp.log(2.0))

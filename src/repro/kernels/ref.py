"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle.
"""

from __future__ import annotations

import jax.numpy as jnp


def pairwise_l2_ref(x: jnp.ndarray, y: jnp.ndarray, squared: bool = False) -> jnp.ndarray:
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    sq = (
        jnp.sum(x * x, axis=1)[:, None]
        + jnp.sum(y * y, axis=1)[None, :]
        - 2.0 * (x @ y.T)
    )
    sq = jnp.maximum(sq, 0.0)
    return sq if squared else jnp.sqrt(sq)


def masked_pairwise_l2_ref(
    x: jnp.ndarray, y: jnp.ndarray, tile_mask: jnp.ndarray, bm: int, bn: int,
    squared: bool = False,
) -> jnp.ndarray:
    d = pairwise_l2_ref(x, y, squared=squared)
    mrep = jnp.repeat(jnp.repeat(tile_mask != 0, bm, axis=0), bn, axis=1)
    mrep = mrep[: d.shape[0], : d.shape[1]]
    return jnp.where(mrep, d, jnp.inf)


def planar_lower_bound_ref(
    d1: jnp.ndarray, d2: jnp.ndarray, deltas: jnp.ndarray, boxes: jnp.ndarray
) -> jnp.ndarray:
    from repro.core.constants import DEGENERATE_DELTA, MIN_DELTA

    d1 = d1.astype(jnp.float32)
    d2 = d2.astype(jnp.float32)
    raw = deltas.astype(jnp.float32)[None, :]
    delta = jnp.maximum(raw, MIN_DELTA)
    qx = jnp.where(
        raw < DEGENERATE_DELTA, 0.0, (d1 * d1 - d2 * d2) / (2.0 * delta)
    )
    qy = jnp.sqrt(jnp.maximum(d1 * d1 - (qx + delta / 2.0) ** 2, 0.0))
    qxe = qx[:, None, :]
    qye = qy[:, None, :]
    bx = boxes[None]
    dx = jnp.maximum(jnp.maximum(bx[..., 0] - qxe, qxe - bx[..., 1]), 0.0)
    dy = jnp.maximum(jnp.maximum(bx[..., 2] - qye, qye - bx[..., 3]), 0.0)
    return jnp.max(jnp.sqrt(dx * dx + dy * dy), axis=-1)


def pairwise_jsd_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    _EPS = 1e-12

    def xlogx(v):
        return jnp.where(v > _EPS, v * jnp.log(jnp.maximum(v, _EPS)), 0.0)

    x = x.astype(jnp.float32)[:, None, :]
    y = y.astype(jnp.float32)[None, :, :]
    m = 0.5 * (x + y)
    js = jnp.sum(0.5 * xlogx(x) + 0.5 * xlogx(y) - xlogx(m), axis=-1)
    return jnp.sqrt(jnp.maximum(js, 0.0) / jnp.log(2.0))


def pairwise_tri_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    _EPS = 1e-12
    x = x.astype(jnp.float32)[:, None, :]
    y = y.astype(jnp.float32)[None, :, :]
    num = (x - y) ** 2
    den = jnp.maximum(x + y, _EPS)
    return jnp.sqrt(jnp.maximum(0.5 * jnp.sum(num / den, axis=-1), 0.0))


def masked_pairwise_metric_ref(
    dense: jnp.ndarray, tile_mask: jnp.ndarray, bm: int, bn: int
) -> jnp.ndarray:
    """Apply the tile mask to a dense (m, n) distance matrix from any of the
    ``*_ref`` pairwise oracles — the reference for the masked family."""
    mrep = jnp.repeat(jnp.repeat(tile_mask != 0, bm, axis=0), bn, axis=1)
    mrep = mrep[: dense.shape[0], : dense.shape[1]]
    return jnp.where(mrep, dense, jnp.inf)

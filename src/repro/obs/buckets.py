"""Bucket ladders for cumulative-bucket histograms.

A histogram's Prometheus exposition is only as useful as its bucket
boundaries: a scrape-side ``histogram_quantile`` interpolates inside the
bucket an observation landed in, so the ladder has to straddle the
metric's dynamic range.  Latencies span microseconds to seconds and
distance counts span 1 to millions, so the *default* ladder is
log-spaced; metrics with a known, narrower range (batch sizes, kNN round
counts) override it with a hand-picked ladder in :data:`LADDERS`.

Everything here is host-side and numpy-free — ladders are plain tuples of
floats consumed by :class:`repro.obs.registry.Histogram`, which keeps one
cumulative count per boundary (plus the implicit ``+Inf`` overflow) and
exposes them as ``_bucket{le="..."}`` series.
"""

from __future__ import annotations

import math

__all__ = ["DEFAULT_LADDER", "LADDERS", "ladder_for", "log_ladder",
           "validate_ladder"]


def log_ladder(lo: float, hi: float, per_decade: int = 1) -> tuple:
    """Log-spaced bucket boundaries from ``lo`` to ``hi`` inclusive, with
    ``per_decade`` boundaries per factor of 10.  Boundaries are rounded to
    9 significant digits so the exposition's ``le`` strings round-trip
    exactly through ``float()``."""
    if not (lo > 0 and hi > lo):
        raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
    if per_decade < 1:
        raise ValueError(f"per_decade must be >= 1, got {per_decade}")
    lo_e = round(math.log10(lo) * per_decade)
    hi_e = round(math.log10(hi) * per_decade)
    out = tuple(
        float(f"{10 ** (e / per_decade):.9g}") for e in range(lo_e, hi_e + 1)
    )
    return validate_ladder(out)


def validate_ladder(bounds) -> tuple:
    """Check a ladder is a strictly-increasing tuple of finite floats and
    return it as such (raises ``ValueError`` otherwise)."""
    out = tuple(float(b) for b in bounds)
    if not out:
        raise ValueError("ladder must have at least one boundary")
    for b in out:
        if not math.isfinite(b):
            raise ValueError(f"non-finite boundary {b} (+Inf is implicit)")
    if any(a >= b for a, b in zip(out, out[1:])):
        raise ValueError(f"boundaries must strictly increase, got {out}")
    return out


# seconds: 10us .. 10s, 2 boundaries/decade — host-side serving latencies
_SECONDS = log_ladder(1e-5, 10.0, 2)
# counts: 1 .. 1e6, 1 boundary/decade with a 3x midpoint — distance tallies
_COUNTS = validate_ladder(
    [b for e in range(0, 7) for b in (10.0 ** e, 3.0 * 10.0 ** e)][:-1]
)

DEFAULT_LADDER = log_ladder(1e-6, 1e3, 1)

# per-metric overrides; anything not listed gets DEFAULT_LADDER.  Keys are
# repo-side metric names (slash-namespaced, pre-`prom_name`).
LADDERS: dict = {
    "serve/span_s": _SECONDS,
    "serve/engine_s": _SECONDS,
    "serve/call_s": _SECONDS,
    "index/mutation_s": _SECONDS,
    "serve/batch_size": tuple(float(2 ** e) for e in range(0, 9)),
    "engine/dists_per_query": _COUNTS,
    "engine/knn_rounds": tuple(float(r) for r in (1, 2, 3, 4, 6, 8, 12, 16)),
}


def ladder_for(name: str) -> tuple:
    """Bucket boundaries for a metric name: its :data:`LADDERS` override,
    else :data:`DEFAULT_LADDER`."""
    return LADDERS.get(name, DEFAULT_LADDER)

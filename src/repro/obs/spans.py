"""Per-request serving spans.

A request through the ServingFront passes admit -> queue -> batch ->
dispatch -> engine -> demux; a :class:`Span` carries one monotonic
timestamp per stage (the serving stack's clock, ``repro.serve.queue.now``
— R1 forbids ``time.time`` anywhere in src).  ``durations()`` turns the
marks into per-stage intervals, which the front records into
``serve/span_s{stage=...}`` histograms and returns on each
``ServeResult`` for the per-request "explain" trace.

Trace ids are process-unique monotonically increasing ints (cheap,
lock-free via ``itertools.count``) rendered as ``t000042`` strings so
they sort lexicographically in logs.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from repro.serve.queue import now

__all__ = ["STAGES", "Span", "new_trace_id"]

# stage marks in causal order: `admit` is stamped on submit(); the rest
# are stamped by the driver thread as the batch moves through dispatch
STAGES = ("admit", "batch", "dispatch", "engine", "demux")

_ids = itertools.count(1)


def new_trace_id() -> str:
    return f"t{next(_ids):06d}"


@dataclass
class Span:
    """Monotonic stage timestamps for one request."""

    trace_id: str = field(default_factory=new_trace_id)
    marks: dict = field(default_factory=dict)

    def mark(self, stage: str, t: float | None = None) -> float:
        """Stamp ``stage`` at monotonic time ``t`` (default: now)."""
        if stage not in STAGES:
            raise ValueError(f"unknown stage {stage!r}, expected {STAGES}")
        t = now() if t is None else float(t)
        self.marks[stage] = t
        return t

    def durations(self) -> dict:
        """Intervals between consecutive *recorded* marks, in seconds.

        Keys are named for what the request was doing during the
        interval: ``queue`` (admit->batch), ``batch`` (batch->dispatch,
        padding/assembly), ``engine`` (dispatch->engine, the jitted
        call), ``demux`` (engine->demux, per-request slicing), plus
        ``total`` (first mark -> last mark).  Stages never marked are
        simply absent.
        """
        names = {
            ("admit", "batch"): "queue",
            ("batch", "dispatch"): "batch",
            ("dispatch", "engine"): "engine",
            ("engine", "demux"): "demux",
        }
        seen = [s for s in STAGES if s in self.marks]
        out: dict = {}
        for a, b in zip(seen, seen[1:]):
            out[names.get((a, b), f"{a}_to_{b}")] = (
                self.marks[b] - self.marks[a]
            )
        if len(seen) >= 2:
            out["total"] = self.marks[seen[-1]] - self.marks[seen[0]]
        return out

"""Metrics registry: counters, gauges and bounded-ring histograms keyed
``name{label=value}``, with a JSON snapshot, a Prometheus-style text
exposition and a one-screen ``render()`` dashboard.

Everything here is host-side and thread-safe (one lock per registry — the
serving front's driver thread and its clients fold concurrently).  The
histogram keeps a bounded ring of recent observations (percentiles are a
*window* statistic, like the front's ``queue_wait_s`` deque) next to
cumulative ``count`` / ``sum`` / per-bucket tallies (*lifetime*
statistics, which is what the Prometheus histogram convention exports) —
so a long-running front reports recent latency percentiles without
unbounded memory while the exposition carries real ``_bucket`` series.
Bucket boundaries come from ``repro.obs.buckets`` (log-spaced default,
per-metric overrides) unless the caller passes an explicit ladder.

Metric names are slash-namespaced repo-side (``serve/span_s``,
``engine/dists``); :func:`prom_name` maps them to the exposition's
``[a-zA-Z0-9_:]`` charset (``serve_span_s``).  Label values are rendered
with the standard escapes, so a snapshot scraped from the text form parses
back losslessly (``repro.obs.export.parse_prometheus``).
"""

from __future__ import annotations

import json
import re
import threading
from bisect import bisect_left
from collections import deque
from itertools import accumulate

from repro.obs.buckets import ladder_for, validate_ladder
from repro.serve.queue import nearest_rank

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "fmt_le",
    "metric_key",
    "prom_name",
]

_DEFAULT_WINDOW = 2048
_PROM_BAD = re.compile(r"[^a-zA-Z0-9_:]")


def fmt_le(bound: float) -> str:
    """Render a bucket boundary as its ``le`` label value (``+Inf`` for
    the overflow bucket) — shared with the parser so round-trips are
    exact."""
    return "+Inf" if bound == float("inf") else f"{bound:.9g}"


def metric_key(name: str, labels: dict) -> str:
    """Canonical series key: ``name{k=v,...}`` with labels sorted by key —
    the same (name, labels) pair always lands on the same series."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


def prom_name(name: str) -> str:
    """Repo-side metric name -> Prometheus metric name (the exposition
    charset is ``[a-zA-Z0-9_:]``; ``/`` and ``.`` become ``_``)."""
    return _PROM_BAD.sub("_", name)


def _prom_label_str(labels: dict) -> str:
    if not labels:
        return ""
    # text-format 0.0.4 label-value escapes: backslash, double-quote and
    # newline (an unescaped newline would split the sample line in two)
    esc = lambda v: (  # noqa: E731
        str(v).replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")
    )
    inner = ",".join(f'{k}="{esc(labels[k])}"' for k in sorted(labels))
    return f"{{{inner}}}"


class Counter:
    """Monotonically increasing tally (float-valued; negative increments
    are rejected — a counter that can go down is a gauge)."""

    kind = "counter"

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = dict(labels)
        self.value = 0.0

    def inc(self, value: float = 1.0) -> None:
        v = float(value)
        if v < 0:
            raise ValueError(
                f"counter {self.name!r} cannot decrease (got {v}); use a "
                f"gauge"
            )
        self.value += v


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = dict(labels)
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Bounded-ring + cumulative-bucket histogram.

    A deque of the last ``window`` observations backs the dashboard
    percentiles (p50/p95/p99/max via the serving stack's nearest-rank
    percentile); cumulative ``count`` / ``sum`` and one per-bucket tally
    per boundary (``le`` semantics: observation counted in the first
    bucket whose bound is >= the value, overflow in the implicit ``+Inf``
    bucket) never forget — they are what the Prometheus exposition
    exports as ``_bucket`` / ``_sum`` / ``_count`` series.
    """

    kind = "histogram"

    def __init__(self, name: str, labels: dict, window: int = _DEFAULT_WINDOW,
                 buckets=None):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.name = name
        self.labels = dict(labels)
        self.window = int(window)
        self.ring: deque[float] = deque(maxlen=self.window)
        self.count = 0
        self.sum = 0.0
        self.buckets = (
            ladder_for(name) if buckets is None else validate_ladder(buckets)
        )
        # raw (non-cumulative) per-bucket tallies; index len(buckets) is
        # the +Inf overflow bucket
        self._bucket_raw = [0] * (len(self.buckets) + 1)

    def observe(self, value: float) -> None:
        v = float(value)
        self.ring.append(v)
        self.count += 1
        self.sum += v
        self._bucket_raw[bisect_left(self.buckets, v)] += 1

    def percentile(self, p: float) -> float:
        return nearest_rank(self.ring, p)

    def bucket_counts(self) -> list:
        """Cumulative ``(le, count)`` pairs ending with ``(+Inf, count)``
        — exactly the ``_bucket`` series the exposition emits."""
        bounds = list(self.buckets) + [float("inf")]
        return list(zip(bounds, accumulate(self._bucket_raw)))

    def summary(self) -> dict:
        vals = list(self.ring)
        return {
            "count": self.count,
            "sum": self.sum,
            "window": len(vals),
            "p50": nearest_rank(vals, 0.50),
            "p95": nearest_rank(vals, 0.95),
            "p99": nearest_rank(vals, 0.99),
            "max": nearest_rank(vals, 1.0),
            "buckets": {
                fmt_le(le): c for le, c in self.bucket_counts()
            },
        }


class MetricsRegistry:
    """Get-or-create registry of metric series.

    ``counter`` / ``gauge`` / ``histogram`` return the live series for
    (name, labels) — callers mutate it directly (``inc``/``set``/
    ``observe``); creation and snapshotting are serialized under the
    registry lock, and the mutators touch only their own series (CPython
    float/deque ops — safe under the GIL from multiple threads).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._series: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, cls, name: str, labels: dict, **kw):
        key = metric_key(name, labels)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = cls(name, labels, **kw)
                self._series[key] = s
            elif not isinstance(s, cls):
                raise ValueError(
                    f"metric {key!r} already registered as {s.kind}"
                )
            return s

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, window: int = _DEFAULT_WINDOW,
                  buckets=None, **labels) -> Histogram:
        h = self._get(Histogram, name, labels, window=window,
                      buckets=buckets)
        if h.window != int(window):
            raise ValueError(
                f"histogram {metric_key(name, labels)!r} already registered "
                f"with window={h.window}, got {window}"
            )
        if buckets is not None and h.buckets != validate_ladder(buckets):
            raise ValueError(
                f"histogram {metric_key(name, labels)!r} already registered "
                f"with buckets={h.buckets}, got {tuple(buckets)}"
            )
        return h

    def series(self) -> list:
        with self._lock:
            return [self._series[k] for k in sorted(self._series)]

    # ------------------------------------------------------------- export

    def snapshot(self) -> dict:
        """JSON-serialisable snapshot: one entry per series, keyed by the
        canonical ``name{label=value}`` series key."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for s in self.series():
            key = metric_key(s.name, s.labels)
            if s.kind == "counter":
                out["counters"][key] = s.value
            elif s.kind == "gauge":
                out["gauges"][key] = s.value
            else:
                out["histograms"][key] = s.summary()
        return out

    def to_json(self, **kw) -> str:
        return json.dumps(self.snapshot(), sort_keys=True, **kw)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4): counters and gauges
        as plain samples, histograms as cumulative ``_bucket{le="..."}``
        series (``+Inf`` bucket included) plus ``_sum`` / ``_count``."""
        lines: list[str] = []
        typed: set[str] = set()
        for s in self.series():
            pname = prom_name(s.name)
            if s.kind == "histogram":
                if pname not in typed:
                    typed.add(pname)
                    lines.append(f"# TYPE {pname} histogram")
                for le, cum in s.bucket_counts():
                    lbl = _prom_label_str({**s.labels, "le": fmt_le(le)})
                    lines.append(f"{pname}_bucket{lbl} {cum}")
                base = _prom_label_str(s.labels)
                lines.append(f"{pname}_sum{base} {s.sum:.9g}")
                lines.append(f"{pname}_count{base} {s.count}")
            else:
                if pname not in typed:
                    typed.add(pname)
                    lines.append(f"# TYPE {pname} {s.kind}")
                lbl = _prom_label_str(s.labels)
                lines.append(f"{pname}{lbl} {s.value:.9g}")
        return "\n".join(lines) + ("\n" if lines else "")

    # ------------------------------------------------------------- render

    def render(self, width: int = 78) -> str:
        """One-screen text dashboard: series grouped by name prefix (the
        part before the first ``/``), counters/gauges one per line,
        histograms as ``p50/p95/p99/max`` over the ring window."""
        groups: dict[str, list] = {}
        for s in self.series():
            groups.setdefault(s.name.partition("/")[0], []).append(s)
        if not groups:
            return "(no metrics recorded)"
        lines: list[str] = []
        for g in sorted(groups):
            lines.append(f"== {g} ".ljust(width, "="))
            for s in groups[g]:
                key = metric_key(s.name, s.labels)
                if s.kind == "histogram":
                    m = s.summary()
                    lines.append(
                        f"  {key:<44s} n={m['count']:<8d} "
                        f"p50={m['p50']:.4g} p95={m['p95']:.4g} "
                        f"p99={m['p99']:.4g} max={m['max']:.4g}"
                    )
                else:
                    v = s.value
                    val = f"{v:.6g}" if isinstance(v, float) else str(v)
                    lines.append(f"  {key:<44s} {val} ({s.kind})")
        return "\n".join(lines)

"""Chrome trace-event export: one Perfetto-loadable timeline per front.

The serving front already stamps every request's span marks
(admit→batch→dispatch→engine→demux, ``repro.obs.spans``) and times every
dispatched batch and mutation — all on the serving stack's single
monotonic clock (``repro.serve.queue.now``).  This module turns those
timestamps into Chrome trace-event JSON (the ``{"traceEvents": [...]}``
format Perfetto and ``chrome://tracing`` load directly): per-request
stage slices on one track per request, per-dispatch engine phase slices
on the driver track, and mutation slices on the same track so index
maintenance shows up inline with the traffic it stalls.

Timestamps are microseconds on the monotonic clock, so host spans line
up with each other exactly; when the front also runs under
``profile_dir=`` it wraps each engine call in a
``jax.profiler.TraceAnnotation`` named after the dispatch, so the
device-side profile carries the same dispatch names and the two
timelines can be read side by side.

``validate_trace`` is the schema check CI and tests use — no Perfetto
binary needed.
"""

from __future__ import annotations

import json
import math
import threading
from collections import deque
from pathlib import Path

from repro.obs.spans import STAGES, Span

__all__ = [
    "TraceBuffer",
    "complete_event",
    "instant_event",
    "load_trace",
    "metadata_event",
    "span_events",
    "validate_trace",
    "write_trace",
]

_DEFAULT_CAPACITY = 65536
_US = 1e6  # trace-event timestamps are microseconds

# what the request was doing during each consecutive stage interval —
# same naming as Span.durations()
_STAGE_NAMES = {
    ("admit", "batch"): "queue",
    ("batch", "dispatch"): "batch",
    ("dispatch", "engine"): "engine",
    ("engine", "demux"): "demux",
}


def complete_event(name: str, start_s: float, dur_s: float, *, tid: int,
                   pid: int = 1, cat: str = "serving",
                   args: dict | None = None) -> dict:
    """A ``ph="X"`` complete event (a slice with a duration)."""
    ev = {
        "name": str(name),
        "ph": "X",
        "cat": cat,
        "ts": float(start_s) * _US,
        "dur": max(float(dur_s), 0.0) * _US,
        "pid": int(pid),
        "tid": int(tid),
    }
    if args:
        ev["args"] = dict(args)
    return ev


def instant_event(name: str, t_s: float, *, tid: int, pid: int = 1,
                  cat: str = "serving", args: dict | None = None) -> dict:
    """A ``ph="i"`` instant event (a point-in-time marker)."""
    ev = {
        "name": str(name),
        "ph": "i",
        "s": "t",  # thread-scoped marker
        "cat": cat,
        "ts": float(t_s) * _US,
        "pid": int(pid),
        "tid": int(tid),
    }
    if args:
        ev["args"] = dict(args)
    return ev


def metadata_event(kind: str, value: str, *, tid: int = 0,
                   pid: int = 1) -> dict:
    """A ``ph="M"`` metadata event naming a process or thread track."""
    if kind not in ("process_name", "thread_name"):
        raise ValueError(f"unknown metadata kind {kind!r}")
    return {
        "name": kind,
        "ph": "M",
        "pid": int(pid),
        "tid": int(tid),
        "args": {"name": str(value)},
    }


def span_events(span: Span, *, tid: int, pid: int = 1,
                args: dict | None = None) -> list:
    """One complete event per consecutive recorded stage interval of
    ``span`` (queue/batch/engine/demux), plus a thread-name metadata
    event so the request's track is labelled with its trace id."""
    seen = [s for s in STAGES if s in span.marks]
    out = [metadata_event("thread_name", span.trace_id, tid=tid, pid=pid)]
    base = dict(args or {})
    base["trace_id"] = span.trace_id
    for a, b in zip(seen, seen[1:]):
        name = _STAGE_NAMES.get((a, b), f"{a}_to_{b}")
        out.append(complete_event(
            name, span.marks[a], span.marks[b] - span.marks[a],
            tid=tid, pid=pid, cat="request", args=base,
        ))
    return out


class TraceBuffer:
    """Bounded, thread-safe ring of trace events.

    The front appends from its driver thread and from mutating callers;
    ``export_trace`` snapshots under the same lock.  Capacity bounds
    memory on a long-running front the same way the explain ring does —
    oldest events fall off first.
    """

    def __init__(self, capacity: int = _DEFAULT_CAPACITY):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._events: deque[dict] = deque(maxlen=self.capacity)

    def add(self, event: dict) -> None:
        with self._lock:
            self._events.append(event)

    def extend(self, events) -> None:
        with self._lock:
            self._events.extend(events)

    def events(self) -> list:
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)


def write_trace(path, events, *, extra: dict | None = None) -> Path:
    """Write ``events`` as Chrome trace-event JSON to ``path``.

    Metadata events sort first (Perfetto applies track names on first
    sight); everything else keeps buffer order, which is already
    chronological per track.
    """
    path = Path(path)
    events = sorted(events, key=lambda e: e.get("ph") != "M")
    payload: dict = {"traceEvents": events, "displayTimeUnit": "ms"}
    if extra:
        payload["otherData"] = dict(extra)
    path.write_text(json.dumps(payload) + "\n")
    return path


def load_trace(path) -> dict:
    return json.loads(Path(path).read_text())


def validate_trace(payload) -> list:
    """Schema-check a trace-event payload; returns problem strings
    (empty = valid).  Covers the subset of the trace-event format we
    emit: ``X`` (must have finite ``ts``/``dur`` >= 0), ``i`` and ``M``
    phases, every event carrying ``name``/``pid``/``tid``."""
    problems: list[str] = []
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        return ["payload is not a dict with a 'traceEvents' key"]
    events = payload["traceEvents"]
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not a dict")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M"):
            problems.append(f"{where}: unknown phase {ph!r}")
            continue
        for k in ("name", "pid", "tid"):
            if k not in ev:
                problems.append(f"{where}: missing {k!r}")
        if ph in ("X", "i"):
            ts = ev.get("ts")
            if (not isinstance(ts, (int, float))
                    or not math.isfinite(ts) or ts < 0):
                problems.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if (not isinstance(dur, (int, float))
                    or not math.isfinite(dur) or dur < 0):
                problems.append(f"{where}: bad dur {dur!r}")
        if ph == "M":
            if ev.get("name") not in ("process_name", "thread_name"):
                problems.append(
                    f"{where}: unknown metadata {ev.get('name')!r}"
                )
            elif not isinstance(ev.get("args", {}).get("name"), str):
                problems.append(f"{where}: metadata missing args.name")
        if "args" in ev and not isinstance(ev["args"], dict):
            problems.append(f"{where}: args is not a dict")
    return problems

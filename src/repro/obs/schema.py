"""One documented schema for every engine's ``stats`` dict.

Before this module each engine grew its own ad-hoc key set (the BSS scan
reported ``block_exclusion_rate``, the forest ``n_levels``, the sharded
engine ``n_shards``).  The shared contract is now:

======================  =====================================================
key                     meaning
======================  =====================================================
``schema``              int — schema version (``SCHEMA_VERSION``)
``engine``              ``bss`` | ``sharded`` | ``forest`` | ``monotone``
``kind``                ``range`` | ``knn``
``backend``             resolved compute backend string (``jnp``, ``pallas``,
                        ``pallas-interpret``, ...)
``precision``           ``fp32`` | ``bf16``
``n_queries``           int — number of queries in the batch
``per_query_dists``     int64 ndarray ``(n_queries,)`` — exact distance
                        evaluations per query (the paper's figure of merit)
``dists_per_query``     float — mean of ``per_query_dists``
``excluded``            dict mechanism -> int64 ndarray ``(n_queries,)`` —
                        per-query exclusion attribution.  Mechanisms are a
                        subset of ``MECHANISMS``; units are engine-native
                        (128-point blocks for bss/sharded, tree nodes for
                        the walkers)
======================  =====================================================

Engine-specific keys (``n_blocks``, ``tiles_computed``, ``n_levels``,
``frontier_occupancy``, ``rounds``, the bf16 band keys, the sharded
engine's ``shard_dists`` / ``shard_blocks`` per-shard work vectors, ...)
ride along unchanged — the schema fixes the shared core, it does not
forbid extras.

This module is also the one home of the RUNTIME METRIC NAMESPACE:
:data:`METRIC_NAMES` lists every metric name the codebase may register
on a :class:`~repro.obs.registry.MetricsRegistry`.  Lint rule R6
(``repro.analysis``) fails CI on any ``counter(...)`` / ``gauge(...)`` /
``histogram(...)`` call in ``src/`` whose name literal is not listed
here — dashboards and the regression sentinel key on these names, so an
unregistered name is a silent observability hole.

Host-side and numpy-only: validation runs at the jit boundary on
materialised stats, never inside a traced function.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "SCHEMA_VERSION",
    "ENGINES",
    "KINDS",
    "PRECISIONS",
    "MECHANISMS",
    "METRIC_NAMES",
    "normalise_stats",
    "validate_stats",
    "check_stats",
]

SCHEMA_VERSION = 1

ENGINES = ("bss", "sharded", "forest", "monotone")
KINDS = ("range", "knn")
PRECISIONS = ("fp32", "bf16")
# exclusion mechanisms: the two hyperplane bounds (paper §3), the
# cover-radius ball test, and the centre-witness test
MECHANISMS = ("hilbert", "hyperbolic", "cover", "centre")

# every metric name the codebase registers at runtime (lint rule R6: a
# name used in src/ but absent here fails CI).  Kept as a plain set
# literal so the import-free AST lint can read it with ast.literal_eval.
METRIC_NAMES = {
    # engine-call folding (repro.obs.fold.fold_engine_stats)
    "engine/queries",
    "engine/dists",
    "engine/dists_per_query",
    "engine/excluded",
    "engine/tiles_computed",
    "engine/tile_exclusion_rate",
    "engine/block_exclusion_rate",
    "engine/frontier_nodes",
    "engine/recheck_points",
    "engine/recheck_tiles",
    "engine/knn_rounds",
    # sharded-engine work split (fold_engine_stats on sharded stats)
    "shard/dists",
    "shard/blocks",
    "shard/imbalance",
    # living-corpus mutations (fold_mutation)
    "index/mutations",
    "index/mutated_rows",
    "index/table_dists",
    "index/generation",
    "index/tombstone_frac",
    "index/n_blocks",
    "index/new_blocks",
    "index/sharded_in_place",
    "index/pivot_refreshes",
    "index/mutation_s",
    # compile-cache polling (poll_compile) + the bucket-ladder contract
    "compile/cache_size",
    "compile/recompiles",
    "compile/ladder_buckets",
    # serving front / retrieval server
    "serve/cache_hits",
    "serve/batch_size",
    "serve/engine_s",
    "serve/padded_rows",
    "serve/span_s",
    "serve/call_s",
}

_CORE_KEYS = (
    "schema", "engine", "kind", "backend", "precision",
    "n_queries", "per_query_dists", "dists_per_query", "excluded",
)


def normalise_stats(stats, *, engine, kind, backend, n_queries,
                    excluded=None):
    """Stamp the shared-schema keys onto an engine's ``stats`` dict.

    Mutates and returns ``stats``.  ``excluded`` maps mechanism name to a
    per-query count array; omitted (or ``None``) means the engine recorded
    no attribution — an empty dict, which still validates.  Existing
    engine-specific keys are preserved.
    """
    stats["schema"] = SCHEMA_VERSION
    stats["engine"] = engine
    stats["kind"] = kind
    stats["backend"] = backend
    stats["n_queries"] = int(n_queries)
    stats.setdefault("precision", "fp32")
    excl = {} if excluded is None else dict(excluded)
    stats["excluded"] = {
        m: np.asarray(v, dtype=np.int64) for m, v in excl.items()
    }
    return stats


def _is_count_array(v, n):
    a = np.asarray(v)
    return (
        a.shape == (n,)
        and np.issubdtype(a.dtype, np.integer)
        and (n == 0 or int(a.min()) >= 0)
    )


def validate_stats(stats) -> list:
    """Validate a stats dict against the shared schema.

    Returns a list of human-readable problem strings — empty means valid.
    Never raises on malformed input (use :func:`check_stats` to raise).
    """
    problems: list[str] = []
    if not isinstance(stats, dict):
        return [f"stats is {type(stats).__name__}, expected dict"]
    for k in _CORE_KEYS:
        if k not in stats:
            problems.append(f"missing core key {k!r}")
    if problems:
        return problems

    if stats["schema"] != SCHEMA_VERSION:
        problems.append(
            f"schema={stats['schema']!r}, expected {SCHEMA_VERSION}"
        )
    if stats["engine"] not in ENGINES:
        problems.append(f"engine={stats['engine']!r} not in {ENGINES}")
    if stats["kind"] not in KINDS:
        problems.append(f"kind={stats['kind']!r} not in {KINDS}")
    if stats["precision"] not in PRECISIONS:
        problems.append(
            f"precision={stats['precision']!r} not in {PRECISIONS}"
        )
    if not isinstance(stats["backend"], str) or not stats["backend"]:
        problems.append(f"backend={stats['backend']!r} is not a string")

    n = stats["n_queries"]
    if not isinstance(n, int) or n < 0:
        problems.append(f"n_queries={n!r} is not a non-negative int")
        return problems

    if not _is_count_array(stats["per_query_dists"], n):
        problems.append(
            f"per_query_dists is not a non-negative int array of shape "
            f"({n},)"
        )
    elif n:  # the mean is convention-defined on an empty batch
        mean = float(np.asarray(stats["per_query_dists"]).mean())
        if abs(float(stats["dists_per_query"]) - mean) > 1e-6 * max(mean, 1.0):
            problems.append(
                f"dists_per_query={stats['dists_per_query']} != "
                f"mean(per_query_dists)={mean}"
            )

    excl = stats["excluded"]
    if not isinstance(excl, dict):
        problems.append(f"excluded is {type(excl).__name__}, expected dict")
    else:
        for m, v in excl.items():
            if m not in MECHANISMS:
                problems.append(
                    f"excluded mechanism {m!r} not in {MECHANISMS}"
                )
            elif not _is_count_array(v, n):
                problems.append(
                    f"excluded[{m!r}] is not a non-negative int array of "
                    f"shape ({n},)"
                )

    if stats["precision"] == "bf16":
        for k in ("band_eps", "recheck_points_per_query"):
            if k not in stats:
                problems.append(f"precision=bf16 but missing {k!r}")
    if stats["kind"] == "knn" and "rounds" not in stats:
        problems.append("kind=knn but missing 'rounds'")
    return problems


def check_stats(stats) -> dict:
    """Raise ``ValueError`` listing every problem if ``stats`` does not
    conform; return ``stats`` unchanged if it does."""
    problems = validate_stats(stats)
    if problems:
        raise ValueError(
            "stats schema violation:\n  " + "\n  ".join(problems)
        )
    return stats

"""Runtime observability: registry, schema, spans, folding, export, trace.

Why engine metrics are *functional jit outputs*
-----------------------------------------------
The obvious way to instrument a jitted engine — ``jax.debug_callback`` or
host-side counters poked from inside the traced function — is exactly
what this repo's invariants forbid: lint rule R2 rejects host syncs in
jit-reachable code, and the jaxpr audit (``python -m repro.analysis``)
fails on *any* callback primitive in an engine jaxpr, because callbacks
serialise the device stream and make performance measurements lie.

So every device-side metric here is an ordinary traced array returned in
the engine's ``stats`` pytree, next to the results: per-mechanism
exclusion attribution, frontier occupancy, tile counts, bf16 re-check
volume, and the sharded engine's per-shard exact-phase work split.  The
device computes them as part of the same fused program (a few masked
reductions over masks the engine already materialises), and the host
folds them into the :class:`~repro.obs.registry.MetricsRegistry` at the
jit boundary (``repro.obs.fold``) — where the results are being
materialised anyway, so observability adds no synchronisation points and
cannot change results (the bit-identity test in ``tests/test_obs.py``
proves it).

Layout
------
- ``registry`` — counters / gauges / bounded-ring histograms with real
  cumulative buckets, JSON snapshot, Prometheus text exposition,
  ``render()`` dashboard
- ``buckets`` — the log-spaced default bucket ladder + per-metric
  overrides used by every histogram
- ``schema`` — the shared engine-stats schema + validator, and
  ``METRIC_NAMES``, the one registry of runtime metric names (lint R6)
- ``spans`` — per-request trace ids and monotonic stage timestamps
- ``trace`` — Chrome trace-event JSON (Perfetto) export of spans, engine
  phases, and mutation events, all on the serving clock
- ``fold`` — stats -> registry at the jit boundary; compile-cache polling
- ``export`` — snapshot files + exposition round-trip checks
"""

from repro.obs.buckets import DEFAULT_LADDER, LADDERS, ladder_for, log_ladder
from repro.obs.export import parse_prometheus, validate_exposition, write_snapshot
from repro.obs.fold import fold_engine_stats, poll_compile, shard_imbalance
from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    fmt_le,
    metric_key,
    prom_name,
)
from repro.obs.schema import (
    MECHANISMS,
    METRIC_NAMES,
    SCHEMA_VERSION,
    check_stats,
    normalise_stats,
    validate_stats,
)
from repro.obs.spans import STAGES, Span, new_trace_id
from repro.obs.trace import (
    TraceBuffer,
    complete_event,
    instant_event,
    load_trace,
    metadata_event,
    span_events,
    validate_trace,
    write_trace,
)

__all__ = [
    "Counter",
    "DEFAULT_LADDER",
    "Gauge",
    "Histogram",
    "LADDERS",
    "MetricsRegistry",
    "MECHANISMS",
    "METRIC_NAMES",
    "SCHEMA_VERSION",
    "STAGES",
    "Span",
    "TraceBuffer",
    "check_stats",
    "complete_event",
    "fmt_le",
    "fold_engine_stats",
    "instant_event",
    "ladder_for",
    "load_trace",
    "log_ladder",
    "metadata_event",
    "metric_key",
    "new_trace_id",
    "normalise_stats",
    "parse_prometheus",
    "poll_compile",
    "prom_name",
    "shard_imbalance",
    "span_events",
    "validate_exposition",
    "validate_stats",
    "validate_trace",
    "write_snapshot",
    "write_trace",
]

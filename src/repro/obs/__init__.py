"""Runtime observability: registry, schema, spans, folding, export.

Why engine metrics are *functional jit outputs*
-----------------------------------------------
The obvious way to instrument a jitted engine — ``jax.debug_callback`` or
host-side counters poked from inside the traced function — is exactly
what this repo's invariants forbid: lint rule R2 rejects host syncs in
jit-reachable code, and the jaxpr audit (``python -m repro.analysis``)
fails on *any* callback primitive in an engine jaxpr, because callbacks
serialise the device stream and make performance measurements lie.

So every device-side metric here is an ordinary traced array returned in
the engine's ``stats`` pytree, next to the results: per-mechanism
exclusion attribution, frontier occupancy, tile counts, bf16 re-check
volume.  The device computes them as part of the same fused program (a
few masked reductions over masks the engine already materialises), and
the host folds them into the :class:`~repro.obs.registry.MetricsRegistry`
at the jit boundary (``repro.obs.fold``) — where the results are being
materialised anyway, so observability adds no synchronisation points and
cannot change results (the bit-identity test in ``tests/test_obs.py``
proves it).

Layout
------
- ``registry`` — counters / gauges / bounded-ring histograms, JSON
  snapshot, Prometheus text exposition, ``render()`` dashboard
- ``schema`` — the shared engine-stats schema + validator
- ``spans`` — per-request trace ids and monotonic stage timestamps
- ``fold`` — stats -> registry at the jit boundary; compile-cache polling
- ``export`` — snapshot files + exposition round-trip checks
"""

from repro.obs.export import parse_prometheus, validate_exposition, write_snapshot
from repro.obs.fold import fold_engine_stats, poll_compile
from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    metric_key,
    prom_name,
)
from repro.obs.schema import (
    MECHANISMS,
    SCHEMA_VERSION,
    check_stats,
    normalise_stats,
    validate_stats,
)
from repro.obs.spans import STAGES, Span, new_trace_id

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MECHANISMS",
    "SCHEMA_VERSION",
    "STAGES",
    "Span",
    "check_stats",
    "fold_engine_stats",
    "metric_key",
    "new_trace_id",
    "normalise_stats",
    "parse_prometheus",
    "poll_compile",
    "prom_name",
    "validate_exposition",
    "validate_stats",
    "write_snapshot",
]

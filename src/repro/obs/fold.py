"""Fold engine stats into a :class:`~repro.obs.registry.MetricsRegistry`.

This is the host side of the observability split: the engines report
everything worth counting as *functional jit outputs* (arrays in their
stats pytrees — see ``repro.obs.__doc__`` for why), and the serving layer
calls :func:`fold_engine_stats` once per dispatched batch, at the jit
boundary, where the arrays have already been materialised for the
caller's results.  Folding therefore adds zero device work and zero extra
host syncs.

:func:`poll_compile` is the runtime face of the bucket-ladder recompile
contract (PR 5/7): it reads each engine jit's compile-cache size through
``repro.core.backends.jit_cache_size`` and turns growth into a
``compile/recompiles`` counter — the CI-time ``audit_compile_cache``
equality becomes a live metric.
"""

from __future__ import annotations

import numpy as np

from repro.core.backends import jit_cache_size
from repro.obs.registry import MetricsRegistry

__all__ = ["fold_engine_stats", "fold_mutation", "poll_compile",
           "shard_imbalance"]


def shard_imbalance(per_shard) -> float:
    """Max/mean ratio of a per-shard work vector: 1.0 is perfectly
    balanced, S is everything-on-one-shard (for S shards).  Defined as
    1.0 on an all-zero vector (no work is trivially balanced)."""
    vals = [int(v) for v in np.asarray(per_shard).reshape(-1).tolist()]
    if not vals or sum(vals) == 0:
        return 1.0
    return max(vals) * len(vals) / sum(vals)


def fold_engine_stats(reg: MetricsRegistry, stats: dict) -> None:
    """Fold one engine-call stats dict (shared schema, see
    ``repro.obs.schema``) into ``reg``.  Tolerates pre-schema dicts —
    missing keys simply contribute nothing."""
    engine = str(stats.get("engine", "unknown"))
    kind = str(stats.get("kind", "unknown"))
    lbl = dict(engine=engine, kind=kind)

    pq = np.asarray(stats.get("per_query_dists", ()), dtype=np.int64)
    nq = int(stats.get("n_queries", pq.shape[0] if pq.ndim == 1 else 0))
    reg.counter("engine/queries", **lbl).inc(nq)
    if pq.ndim == 1 and pq.size:
        reg.counter("engine/dists", **lbl).inc(int(pq.sum()))
        h = reg.histogram("engine/dists_per_query", **lbl)
        for v in pq.tolist():
            h.observe(v)

    for mech, counts in dict(stats.get("excluded", {})).items():
        c = np.asarray(counts, dtype=np.int64)
        if c.size:
            reg.counter(
                "engine/excluded", mechanism=mech, **lbl
            ).inc(int(c.sum()))

    if "tiles_computed" in stats:
        reg.counter("engine/tiles_computed", **lbl).inc(
            int(stats["tiles_computed"])
        )
    if "tile_exclusion_rate" in stats:
        reg.gauge("engine/tile_exclusion_rate", **lbl).set(
            float(stats["tile_exclusion_rate"])
        )
    if "block_exclusion_rate" in stats:
        reg.gauge("engine/block_exclusion_rate", **lbl).set(
            float(stats["block_exclusion_rate"])
        )

    fo = stats.get("frontier_occupancy")
    if fo is not None:
        for lv, occ in enumerate(np.asarray(fo, dtype=np.int64).tolist()):
            reg.counter(
                "engine/frontier_nodes", level=lv, **lbl
            ).inc(int(occ))

    if stats.get("precision") == "bf16":
        prc = np.asarray(
            stats.get("per_query_recheck", ()), dtype=np.int64
        )
        if prc.size:
            reg.counter("engine/recheck_points", **lbl).inc(int(prc.sum()))
        if "recheck_tiles" in stats:
            reg.counter("engine/recheck_tiles", **lbl).inc(
                int(stats["recheck_tiles"])
            )

    if kind == "knn" and "rounds" in stats:
        reg.histogram("engine/knn_rounds", **lbl).observe(
            int(stats["rounds"])
        )

    if "shard_dists" in stats:
        # the sharded engine's per-shard split of the exact-phase work
        # (functional jit outputs, one slot per mesh device): per-shard
        # traffic counters plus a max/mean imbalance gauge — the number a
        # rebalancing policy would watch
        sd = np.asarray(stats["shard_dists"], dtype=np.int64)
        sb = np.asarray(
            stats.get("shard_blocks", np.zeros_like(sd)), dtype=np.int64
        )
        for i, (d, b) in enumerate(zip(sd.tolist(), sb.tolist())):
            reg.counter("shard/dists", shard=i, **lbl).inc(int(d))
            reg.counter("shard/blocks", shard=i, **lbl).inc(int(b))
        reg.gauge("shard/imbalance", **lbl).set(shard_imbalance(sd))


def fold_mutation(reg: MetricsRegistry, mstats,
                  seconds: float | None = None) -> None:
    """Fold one living-corpus mutation (a
    :class:`~repro.index.maintain.MutationStats`) into ``reg``.

    Gauges track the index's CURRENT shape (``index/generation``,
    ``index/tombstone_frac``, ``index/n_blocks`` — last write wins, so the
    newest mutation's view is the live one); counters accumulate mutation
    traffic per op; ``seconds`` (the host wall time of the mutation,
    including any device-mirror splice) lands in ``index/mutation_s{op=}``.
    """
    lbl = dict(op=str(mstats.op))
    reg.counter("index/mutations", **lbl).inc()
    reg.counter("index/mutated_rows", **lbl).inc(int(mstats.rows))
    reg.counter("index/table_dists", **lbl).inc(int(mstats.table_dists))
    reg.gauge("index/generation").set(int(mstats.generation))
    reg.gauge("index/tombstone_frac").set(float(mstats.tombstone_frac))
    reg.gauge("index/n_blocks").set(int(mstats.n_blocks))
    if mstats.op == "append":
        reg.counter("index/new_blocks").inc(int(mstats.new_blocks))
        if mstats.sharded_in_place:
            reg.counter("index/sharded_in_place").inc()
    if mstats.op == "compact" and mstats.refreshed_pivots:
        reg.counter("index/pivot_refreshes").inc()
    if seconds is not None:
        reg.histogram("index/mutation_s", **lbl).observe(float(seconds))


def poll_compile(reg: MetricsRegistry, watched: dict,
                 last: dict | None = None) -> dict:
    """Sample compile-cache sizes for ``watched`` (name -> jitted fn).

    Sets ``compile/cache_size{fn=name}`` gauges and increments
    ``compile/recompiles{fn=name}`` by any growth since the previous
    sample (carried in ``last``, which is returned updated for the next
    call).  Functions whose cache size is unreadable
    (``jit_cache_size`` < 0, e.g. a monkeypatched jit) are skipped.
    """
    last = {} if last is None else last
    for name, fn in watched.items():
        size = jit_cache_size(fn)
        if size < 0:
            continue
        reg.gauge("compile/cache_size", fn=name).set(size)
        prev = last.get(name)
        if prev is not None and size > prev:
            reg.counter("compile/recompiles", fn=name).inc(size - prev)
        last[name] = size
    return last

"""Snapshot export + exposition round-trip checks.

``write_snapshot`` dumps a registry to a JSON file (numpy scalars and
arrays coerced to plain JSON).  ``parse_prometheus`` is a minimal parser
for the text exposition our registry emits — CI uses it to prove the
scrape from a live serving run is well-formed (every sample line parses,
every histogram has its ``_sum``/``_count`` pair) without needing a
Prometheus binary in the container.
"""

from __future__ import annotations

import json
import re
from pathlib import Path

import numpy as np

from repro.obs.registry import MetricsRegistry

__all__ = ["write_snapshot", "parse_prometheus", "validate_exposition"]

_SAMPLE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?"
    r"\s+(?P<value>[^\s]+)$"
)
_LABEL = re.compile(r'(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>(?:\\.|[^"\\])*)"')


def _jsonable(obj):
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


def write_snapshot(reg: MetricsRegistry, path, extra: dict | None = None
                   ) -> Path:
    """Write ``reg.snapshot()`` (plus optional ``extra`` payload keys) as
    JSON to ``path``; returns the path written."""
    path = Path(path)
    payload = {"metrics": _jsonable(reg.snapshot())}
    if extra:
        payload.update(_jsonable(extra))
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return path


def parse_prometheus(text: str) -> list:
    """Parse exposition text into ``(name, labels, value)`` tuples.

    Raises ``ValueError`` on any line that is neither a comment nor a
    well-formed sample.
    """
    out = []
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: malformed sample {line!r}")
        labels = {}
        if m.group("labels"):
            for lm in _LABEL.finditer(m.group("labels")):
                labels[lm.group("k")] = (
                    lm.group("v").replace(r"\"", '"').replace(r"\\", "\\")
                )
        try:
            value = float(m.group("value"))
        except ValueError as e:
            raise ValueError(
                f"line {lineno}: non-numeric value {m.group('value')!r}"
            ) from e
        out.append((m.group("name"), labels, value))
    return out


def validate_exposition(text: str) -> list:
    """Structural checks on exposition text; returns problem strings
    (empty = valid).  Checks: parseable, finite values, and every
    summary quantile series has matching ``_sum`` and ``_count``."""
    try:
        samples = parse_prometheus(text)
    except ValueError as e:
        return [str(e)]
    problems = []
    names = {n for n, _, _ in samples}
    for name, labels, value in samples:
        if not np.isfinite(value):
            problems.append(f"{name}{labels}: non-finite value {value}")
        if "quantile" in labels:
            for suffix in ("_sum", "_count"):
                if name + suffix not in names:
                    problems.append(
                        f"summary {name} missing {name + suffix}"
                    )
    return problems

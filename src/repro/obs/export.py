"""Snapshot export + exposition round-trip checks.

``write_snapshot`` dumps a registry to a JSON file (numpy scalars and
arrays coerced to plain JSON).  ``parse_prometheus`` is a minimal parser
for the text exposition our registry emits — CI uses it to prove the
scrape from a live serving run is well-formed (every sample line parses,
every histogram family carries a cumulative ``_bucket`` ladder ending in
``+Inf`` that agrees with its ``_sum``/``_count`` pair) without needing a
Prometheus binary in the container.
"""

from __future__ import annotations

import json
import re
from pathlib import Path

import numpy as np

from repro.obs.registry import MetricsRegistry

__all__ = ["write_snapshot", "parse_prometheus", "validate_exposition"]

_SAMPLE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?"
    r"\s+(?P<value>[^\s]+)$"
)
_LABEL = re.compile(r'(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>(?:\\.|[^"\\])*)"')
_ESCAPE = re.compile(r"\\(.)")


def _unescape_label(v: str) -> str:
    # text-format 0.0.4: `\\` -> backslash, `\"` -> quote, `\n` -> newline
    # (a single left-to-right pass — sequential str.replace would corrupt
    # values like `\\n`, turning an escaped backslash + n into a newline)
    return _ESCAPE.sub(lambda m: "\n" if m.group(1) == "n" else m.group(1), v)


def _jsonable(obj):
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


def write_snapshot(reg: MetricsRegistry, path, extra: dict | None = None
                   ) -> Path:
    """Write ``reg.snapshot()`` (plus optional ``extra`` payload keys) as
    JSON to ``path``; returns the path written."""
    path = Path(path)
    payload = {"metrics": _jsonable(reg.snapshot())}
    if extra:
        payload.update(_jsonable(extra))
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return path


def parse_prometheus(text: str) -> list:
    """Parse exposition text into ``(name, labels, value)`` tuples.

    Raises ``ValueError`` on any line that is neither a comment nor a
    well-formed sample.
    """
    out = []
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: malformed sample {line!r}")
        labels = {}
        if m.group("labels"):
            for lm in _LABEL.finditer(m.group("labels")):
                labels[lm.group("k")] = _unescape_label(lm.group("v"))
        try:
            value = float(m.group("value"))
        except ValueError as e:
            raise ValueError(
                f"line {lineno}: non-numeric value {m.group('value')!r}"
            ) from e
        out.append((m.group("name"), labels, value))
    return out


def _label_sig(labels: dict, drop: str) -> tuple:
    return tuple(sorted((k, v) for k, v in labels.items() if k != drop))


def validate_exposition(text: str) -> list:
    """Structural checks on exposition text; returns problem strings
    (empty = valid).

    Checks: every line parses, every value is finite, and every histogram
    family is a *real* cumulative-bucket histogram — each ``_bucket``
    series (grouped by base name + non-``le`` labels) carries a valid
    ``le`` ladder ending in ``+Inf``, its counts are non-decreasing in
    ``le`` order, the ``+Inf`` count equals the family's ``_count``, and
    the ``_sum`` / ``_count`` samples exist.
    """
    try:
        samples = parse_prometheus(text)
    except ValueError as e:
        return [str(e)]
    problems = []
    values = {}
    families: dict = {}
    for name, labels, value in samples:
        if not np.isfinite(value):
            problems.append(f"{name}{labels}: non-finite value {value}")
        values[(name, _label_sig(labels, drop=""))] = value
        if name.endswith("_bucket") and "le" in labels:
            base = name[: -len("_bucket")]
            fam = families.setdefault((base, _label_sig(labels, "le")), [])
            le = float("inf") if labels["le"] == "+Inf" else float(labels["le"])
            fam.append((le, value))

    for (base, sig), fam in families.items():
        where = f"{base}{{{','.join(f'{k}={v}' for k, v in sig)}}}"
        les = [le for le, _ in fam]
        if les != sorted(les) or len(set(les)) != len(les):
            problems.append(f"{where}: le ladder not strictly increasing")
        if not les or les[-1] != float("inf"):
            problems.append(f"{where}: missing +Inf bucket")
        counts = [c for _, c in sorted(fam)]
        if any(a > b for a, b in zip(counts, counts[1:])):
            problems.append(f"{where}: bucket counts not cumulative")
        for suffix in ("_sum", "_count"):
            if (base + suffix, sig) not in values:
                problems.append(f"{where}: missing {base + suffix}")
        total = values.get((base + "_count", sig))
        if fam and total is not None and sorted(fam)[-1][1] != total:
            problems.append(
                f"{where}: +Inf bucket {sorted(fam)[-1][1]} != _count "
                f"{total}"
            )
    return problems

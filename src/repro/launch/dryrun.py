import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, and extract the roofline raw material.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

Per cell this emits JSON with:
    memory_analysis   (per-device bytes: args/outputs/temps/peak)
    cost_analysis     (HLO flops / bytes accessed)
    collective_bytes  (per-device bytes through all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       parsed from the SPMD-partitioned HLO)
    model_flops       (6*N*D dense / 6*N_active*D MoE analytic reference)

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system — the run aborts loudly.
"""

import argparse
import dataclasses
import json
import re
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.serve.queue import now

from repro.configs import common
from repro.configs.registry import all_cells, get_arch, registry
from repro.launch.mesh import make_production_mesh
from repro.optim import make_optimizer
from repro.parallel.sharding import dp_axes, shard_tree
from repro.train.step import make_train_step

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]))[^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    per_kind: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        per_kind[kind] = per_kind.get(kind, 0) + _shape_bytes(shape_str)
    per_kind["total"] = sum(per_kind.values())
    return per_kind


def _sds_with_sharding(sds_tree, spec_tree, mesh):
    shardings = shard_tree(mesh, spec_tree)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        sds_tree,
        shardings,
    )


def _model_flops(bundle, model, cell, batch_sds) -> float:
    """Analytic useful-FLOPs reference (6*N*D rule and analogues)."""
    cfg = model.cfg
    if bundle.family == "lm":
        n_act = cfg.n_active_params()
        if cell.kind == "train":
            toks = batch_sds["tokens"].shape[0] * (batch_sds["tokens"].shape[1] - 1)
            return 6.0 * n_act * toks
        if cell.kind == "prefill":
            toks = batch_sds["tokens"].shape[0] * batch_sds["tokens"].shape[1]
            return 2.0 * n_act * toks
        toks = batch_sds["token"].shape[0]
        return 2.0 * n_act * toks
    if bundle.family == "gnn":
        # dominant: per-edge message MLP + per-node update MLP, fwd+bwd (x3)
        x = batch_sds["x"]
        e = batch_sds["edge_src"].shape[0]
        n = x.shape[0]
        d = cfg.d_hidden
        per_layer = e * (2 * d) * d * 2 + n * (13 * d) * d * 2
        fwd = cfg.n_layers * per_layer + n * x.shape[1] * d * 2
        return 3.0 * fwd
    # recsys: embedding gathers dominate bytes, MLPs dominate flops
    model_params = sum(
        int(jnp.prod(jnp.array(s[0])))
        for s in jax.tree.leaves(
            model.param_shapes(),
            is_leaf=lambda v: isinstance(v, tuple) and len(v) == 2
            and isinstance(v[0], tuple),
        )
        if len(s[0]) == 2  # MLP mats only (tables are gathered, not matmul'd)
    )
    if "candidates" in batch_sds:
        # two-tower candidate scoring: ONE user-tower pass + a dot per
        # candidate (the MLP does NOT run per candidate row)
        n = batch_sds["candidates"].shape[0]
        e_dim = batch_sds["candidates"].shape[1]
        user_rows = batch_sds["user_ids"].shape[0]
        return 2.0 * model_params * user_rows + 2.0 * n * e_dim * user_rows
    rows = jax.tree.leaves(batch_sds)[0].shape[0]
    mult = 6.0 if cell.kind == "train" else 2.0
    return mult * model_params * rows


def _measure(compiled) -> dict:
    """Per-device flops / bytes / collective bytes from a compiled artifact."""
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": {k: float(v) for k, v in coll.items()},
    }


def _combine(a: dict, b: dict, ca: float, cb: float) -> dict:
    """ca*a + cb*b, fieldwise (coll dict keys unioned)."""
    keys = set(a["coll"]) | set(b["coll"])
    return {
        "flops": ca * a["flops"] + cb * b["flops"],
        "bytes": ca * a["bytes"] + cb * b["bytes"],
        "coll": {
            k: ca * a["coll"].get(k, 0.0) + cb * b["coll"].get(k, 0.0) for k in keys
        },
    }


_ZERO = {"flops": 0.0, "bytes": 0.0, "coll": {}}


def _dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out


def _with_batch_axes(model, mesh, rows: int, family: str = "lm"):
    """Rebuild a model with activation batch-sharding pinned to the data
    axes (when the row count divides them).  GNN node/edge rows shard over
    ALL axes (data + model) — cells pad to multiples of 512."""
    if not hasattr(model.cfg, "batch_axes"):
        return model
    if family == "gnn":
        axes = dp_axes(mesh) + ("model",)
    else:
        axes = dp_axes(mesh) if rows % _dp_size(mesh) == 0 else None
    return type(model)(dataclasses.replace(model.cfg, batch_axes=axes))


def _cell_rows(cell, batch_sds) -> int:
    if "tokens" in batch_sds:
        return batch_sds["tokens"].shape[0]
    if "token" in batch_sds:
        return batch_sds["token"].shape[0]
    if "candidates" in batch_sds:
        return batch_sds["candidates"].shape[0]
    return jax.tree.leaves(batch_sds)[0].shape[0]


def probe_lm_cell(model, family, cell, mesh, batch_sds) -> dict:
    """Loop-corrected per-device cost totals for an LM cell.

    XLA's HloCostAnalysis counts while-loop bodies once, so the scan-based
    production graph undercounts FLOPs/collectives by ~n_layers (and
    ~microbatches).  We compile small UNROLLED probes at L=2 and L=4 (the
    L=4/L=2 delta isolates exactly two layers, covering gemma2's local/global
    alternation), plus a standalone optimizer probe, and extrapolate:

        per_layer  = (P4 - P2) / 2
        fixed      = P2 - 2 * per_layer          (embed/logits/loss[/opt])
        train      = mb * (fixed - opt + L * per_layer) + opt
        prefill/decode =       fixed + L * per_layer
    """
    import dataclasses as dc

    cfg = model.cfg
    kind = cell.kind
    mb = getattr(cfg, "microbatches", 1) if kind == "train" else 1

    probes = {}
    for L in (2, 4):
        pcfg = dc.replace(
            cfg, n_layers=L, unroll_layers=True, attn_q_chunk=None,
            microbatches=1,
        )
        pmodel = type(model)(pcfg)
        params_sds = _sds_with_sharding(
            pmodel.abstract_params(), pmodel.param_specs(mesh), mesh
        )
        if kind == "train":
            toks = batch_sds["tokens"]
            pb = toks.shape[0] // mb
            ptoks = jax.ShapeDtypeStruct((pb, toks.shape[1]), toks.dtype,
                                         sharding=toks.sharding)
            opt = make_optimizer(cfg.optimizer)
            opt_sds = _sds_with_sharding(
                jax.eval_shape(opt.init, params_sds),
                opt.state_specs(pmodel.param_specs(mesh)), mesh,
            )
            state_sds = {"params": params_sds, "opt": opt_sds,
                         "step": jax.ShapeDtypeStruct((), jnp.int32)}
            loss_fn = common.loss_for(family, pmodel)
            step = make_train_step(loss_fn, opt, microbatches=1)
            compiled = jax.jit(step, donate_argnums=(0,)).lower(
                state_sds, {"tokens": ptoks}).compile()
        elif kind == "prefill":
            compiled = jax.jit(pmodel.prefill).lower(
                params_sds, batch_sds["tokens"]).compile()
        else:  # decode
            b = batch_sds["token"].shape[0]
            seq = common.LM_SHAPES[cell.shape_name]["seq"]
            cache_sds = _sds_with_sharding(
                pmodel.init_cache_shapes(b, seq), pmodel.cache_specs(mesh, b),
                mesh,
            )
            compiled = jax.jit(pmodel.decode_step, donate_argnums=(1,)).lower(
                params_sds, cache_sds, batch_sds["token"], batch_sds["pos"]
            ).compile()
        probes[L] = _measure(compiled)

    per_layer = _combine(probes[4], probes[2], 0.5, -0.5)
    fixed = _combine(probes[2], per_layer, 1.0, -2.0)

    if kind == "train":
        # The L-probes ran FULL train steps, so `per_layer`/`fixed` each mix
        # per-microbatch fwd+bwd cost with once-per-step optimizer cost.
        # Probe the optimizer alone at L=2 and L=4, split both components,
        # then: total = mb * body(L) + opt(L).
        opt = make_optimizer(cfg.optimizer)

        def _opt_probe(L: int) -> dict:
            pcfg = dataclasses.replace(
                cfg, n_layers=L, unroll_layers=True, microbatches=1
            )
            pm = type(model)(pcfg)
            psds = _sds_with_sharding(
                pm.abstract_params(), pm.param_specs(mesh), mesh
            )
            osds = _sds_with_sharding(
                jax.eval_shape(opt.init, psds),
                opt.state_specs(pm.param_specs(mesh)), mesh,
            )
            return _measure(
                jax.jit(opt.update).lower(psds, osds, psds).compile()
            )

        opt2, opt4 = _opt_probe(2), _opt_probe(4)
        per_layer_opt = _combine(opt4, opt2, 0.5, -0.5)
        opt_fixed = _combine(opt2, per_layer_opt, 1.0, -2.0)
        opt_full = _combine(opt_fixed, per_layer_opt, 1.0, float(cfg.n_layers))
        per_layer_body = _combine(per_layer, per_layer_opt, 1.0, -1.0)
        body_fixed = _combine(fixed, opt_fixed, 1.0, -1.0)
        per_mb = _combine(body_fixed, per_layer_body, 1.0, float(cfg.n_layers))
        total = _combine(per_mb, opt_full, float(mb), 1.0)
    else:
        total = _combine(fixed, per_layer, 1.0, float(cfg.n_layers))

    # numerical floor: extrapolation can go slightly negative on tiny terms
    total["flops"] = max(total["flops"], 0.0)
    total["bytes"] = max(total["bytes"], 0.0)
    total["coll"] = {k: max(v, 0.0) for k, v in total["coll"].items()}
    return {
        "method": "unrolled L2/L4 probe extrapolation (per-device)",
        "per_layer": per_layer,
        "fixed": fixed,
        "total": total,
        "microbatches": mb,
    }


def lower_cell(arch: str, shape_name: str, mesh, *, compile_: bool = True) -> dict:
    bundle = get_arch(arch)
    cell = bundle.cells[shape_name]
    if cell.skip:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": cell.skip}
    model = bundle.model_for(shape_name)
    t0 = now()

    with jax.set_mesh(mesh):
        batch_sds = _sds_with_sharding(cell.inputs(), cell.input_partition(mesh), mesh)
        model = _with_batch_axes(
            model, mesh, _cell_rows(cell, batch_sds), bundle.family
        )
        cfg = model.cfg

        if cell.kind == "train":
            params_sds = _sds_with_sharding(
                model.abstract_params(), model.param_specs(mesh), mesh
            )
            opt = make_optimizer(cfg.optimizer)
            opt_sds_raw = jax.eval_shape(opt.init, params_sds)
            opt_specs = opt.state_specs(model.param_specs(mesh))
            opt_sds = _sds_with_sharding(opt_sds_raw, opt_specs, mesh)
            state_sds = {
                "params": params_sds,
                "opt": opt_sds,
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }
            loss_fn = common.loss_for(bundle.family, model)
            import jax.numpy as _jnp

            step = make_train_step(
                loss_fn, opt, microbatches=getattr(cfg, "microbatches", 1),
                accum_dtype=getattr(_jnp, getattr(cfg, "grad_accum_dtype", "float32")),
            )
            jitted = jax.jit(step, donate_argnums=(0,))
            lowered = jitted.lower(state_sds, batch_sds)
        elif cell.kind == "prefill":
            params_sds = _sds_with_sharding(
                model.abstract_params(), model.param_specs(mesh), mesh
            )
            pre_cfg = dataclasses.replace(cfg, remat=False)
            pre_model = type(model)(pre_cfg)
            # Sarathi-style chunked prefill: bounds live activations + the
            # MoE dispatch buffer to one 2048-token segment.
            jitted = jax.jit(lambda p, t: pre_model.prefill(p, t, chunk=2048))
            lowered = jitted.lower(params_sds, batch_sds["tokens"])
        elif cell.kind == "decode":
            params_sds = _sds_with_sharding(
                model.abstract_params(), model.param_specs(mesh), mesh
            )
            b = batch_sds["token"].shape[0]
            seq = common.LM_SHAPES[shape_name]["seq"]
            cache_sds = _sds_with_sharding(
                model.init_cache_shapes(b, seq), model.cache_specs(mesh, b), mesh
            )
            from jax.sharding import NamedSharding

            logit_shard = NamedSharding(
                mesh,
                jax.sharding.PartitionSpec(
                    dp_axes(mesh) if b % _dp_size(mesh) == 0 else None, "model"
                ),
            )
            cache_shard = shard_tree(mesh, model.cache_specs(mesh, b))
            jitted = jax.jit(
                model.decode_step,
                donate_argnums=(1,),
                out_shardings=(logit_shard, cache_shard),
            )
            lowered = jitted.lower(
                params_sds, cache_sds, batch_sds["token"], batch_sds["pos"]
            )
        else:  # serve (recsys forward)
            params_sds = _sds_with_sharding(
                model.abstract_params(), model.param_specs(mesh), mesh
            )
            jitted = jax.jit(model.forward)
            lowered = jitted.lower(params_sds, batch_sds)
            # beyond-paper variant: supermetric-pruned candidate scoring
            # (the paper's technique in the serving graph) — lowered and
            # measured alongside the dense baseline.
            if (arch == "two-tower-retrieval"
                    and shape_name == "retrieval_cand"):
                n_cand = batch_sds["candidates"].shape[0]
                block, n_piv, n_pairs = 128, 16, 24
                b_blocks = -(-n_cand // block)
                e_dim = batch_sds["candidates"].shape[1]
                idx_sds = dict(batch_sds)
                idx_sds["pivots"] = jax.ShapeDtypeStruct(
                    (n_piv, e_dim), jnp.float32)
                idx_sds["pair_idx"] = jax.ShapeDtypeStruct(
                    (n_pairs, 2), jnp.int32)
                idx_sds["deltas"] = jax.ShapeDtypeStruct(
                    (n_pairs,), jnp.float32)
                idx_sds["boxes"] = jax.ShapeDtypeStruct(
                    (b_blocks, n_pairs, 4), jnp.float32)
                fwd = lambda p, b: model.forward_retrieval_pruned(  # noqa: E731
                    p, b, block=block, budget_blocks=3136)
                opt_compiled = jax.jit(fwd).lower(params_sds, idx_sds).compile()

        lower_s = now() - t0
        rec = {
            "arch": arch,
            "shape": shape_name,
            "kind": cell.kind,
            "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
            "n_devices": int(mesh.devices.size),
            "lower_seconds": round(lower_s, 2),
            "status": "lowered",
            "note": cell.note,
        }
        if not compile_:
            return rec

        t1 = now()
        compiled = lowered.compile()
        rec["compile_seconds"] = round(now() - t1, 2)
        rec["status"] = "compiled"

        mem = compiled.memory_analysis()
        if mem is not None:
            for attr in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "alias_size_in_bytes",
                "generated_code_size_in_bytes",
            ):
                if hasattr(mem, attr):
                    rec.setdefault("memory", {})[attr] = int(getattr(mem, attr))
        cost = compiled.cost_analysis()
        if cost:
            rec["cost"] = {
                k: float(v)
                for k, v in cost.items()
                if isinstance(v, (int, float)) and k in (
                    "flops", "bytes accessed", "bytes accessed output",
                    "optimal_seconds", "utilization operand 0",
                )
            }
            # keep all numeric keys too (backend-dependent naming)
            rec["cost_all"] = {
                k: float(v) for k, v in cost.items() if isinstance(v, (int, float))
            }
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes_from_hlo(hlo)
        rec["model_flops"] = _model_flops(bundle, model, cell, cell.inputs())

        # loop-corrected totals: LM graphs wrap layers (and microbatches) in
        # lax.scan, which HloCostAnalysis counts once — probe & extrapolate.
        if (bundle.family == "recsys" and arch == "two-tower-retrieval"
                and shape_name == "retrieval_cand"):
            om = opt_compiled.memory_analysis()
            rec["supermetric_variant"] = {
                "budget_blocks": 3136,
                "of_blocks": -(-cell.inputs()["candidates"].shape[0] // 128),
                **_measure(opt_compiled),
                "memory": {
                    a: int(getattr(om, a)) for a in (
                        "argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "alias_size_in_bytes")
                    if hasattr(om, a)
                },
            }
        if bundle.family == "lm":
            rec["corrected"] = probe_lm_cell(model, bundle.family, cell, mesh, batch_sds)
        else:
            rec["corrected"] = {
                "method": "loop-free graph: measured == true (per-device)",
                "total": {
                    "flops": rec.get("cost_all", {}).get("flops", 0.0),
                    "bytes": rec.get("cost_all", {}).get("bytes accessed", 0.0),
                    "coll": {k: float(v) for k, v in rec["collectives"].items()},
                },
            }
        return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    meshes = []
    if args.both_meshes:
        meshes = [False, True]
    else:
        meshes = [args.multi_pod]

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    failures = []
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        tag = "multipod" if multi_pod else "singlepod"
        for arch, shape in cells:
            fname = outdir / f"{arch.replace('/', '_')}__{shape}__{tag}.json"
            if args.skip_existing and fname.exists():
                ok = json.loads(fname.read_text()).get("status") in (
                    "compiled", "skipped")
                if ok:
                    print(f"[skip existing] {fname.name}")
                    continue
            print(f"=== {arch} x {shape} [{tag}] ===", flush=True)
            try:
                rec = lower_cell(arch, shape, mesh, compile_=not args.lower_only)
                fname.write_text(json.dumps(rec, indent=2))
                mem = rec.get("memory", {})
                print(
                    f"  status={rec['status']} "
                    f"args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                    f"temps={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                    f"flops={rec.get('cost', {}).get('flops', 0):.3e} "
                    f"coll={rec.get('collectives', {}).get('total', 0)/2**30:.3f}GiB",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape, tag, repr(e)))
                fname.write_text(json.dumps({
                    "arch": arch, "shape": shape, "status": "failed",
                    "error": traceback.format_exc(),
                }, indent=2))
                print(f"  FAILED: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nALL CELLS OK")


if __name__ == "__main__":
    main()

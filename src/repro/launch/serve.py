"""Serving launcher: embed a corpus with the two-tower model, build the
supermetric index, serve batched retrieval queries.

    PYTHONPATH=src python -m repro.launch.serve --corpus 20000 --queries 256 --k 10
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.serve.queue import now
from repro.serve.retrieval import RetrievalServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--corpus", type=int, default=20_000)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--min-score", type=float, default=None)
    args = ap.parse_args()

    bundle = get_arch("two-tower-retrieval")
    model, cfg, _ = bundle.make_reduced()
    params = model.init_params(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    item_ids = rng.integers(0, cfg.vocab, size=(args.corpus, cfg.n_item_fields))
    user_ids = rng.integers(0, cfg.vocab, size=(args.queries, cfg.n_user_fields))

    print(f"embedding corpus of {args.corpus} items ...")
    corpus = np.asarray(model.item_embed(params, item_ids))
    users = np.asarray(model.user_embed(params, user_ids))

    t0 = now()
    server = RetrievalServer(corpus)
    print(f"built supermetric index in {now() - t0:.2f}s "
          f"({server.index.n_blocks} blocks)")

    if args.min_score is not None:
        hits = server.range_query(users, args.min_score)
        sizes = [len(h) for h in hits]
        print(f"range query >= {args.min_score}: mean {np.mean(sizes):.1f} hits")
    else:
        t0 = now()
        top = server.top_k(users, args.k)
        dt = now() - t0
        print(f"top-{args.k} for {args.queries} queries in {dt:.2f}s")
    s = server.stats
    print(f"distances/query: {s.dists_per_query:.0f} "
          f"(exhaustive would be {args.corpus}) -> {100 * s.saving:.1f}% pruned")


if __name__ == "__main__":
    main()

"""Simulated multi-device host environments (one CPU process as a mesh).

``--xla_force_host_platform_device_count`` must be set before jax first
initialises, so anything that needs a simulated mesh spawns a subprocess
with the flag in ``XLA_FLAGS``.  The env assembly lives HERE — one copy
shared by the test shim (``tests/multidevice_shim.py``) and the sharded
benchmark (``benchmarks/bss_sharded.py``): XLA rejects duplicate flags, so
any forcing flag inherited from the caller's environment (e.g. the
sharded-matrix CI job's own 8-device setting) must be replaced, not
appended to.
"""

from __future__ import annotations

import os
import re

FORCE_FLAG = "--xla_force_host_platform_device_count"

__all__ = ["FORCE_FLAG", "simulated_device_env"]


def simulated_device_env(n_devices: int, base: dict | None = None) -> dict:
    """A copy of ``base`` (default: ``os.environ``) whose ``XLA_FLAGS``
    force ``n_devices`` simulated host devices, replacing any forcing flag
    already present."""
    env = dict(os.environ if base is None else base)
    flags = re.sub(rf"{FORCE_FLAG}=\d+", "", env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = " ".join([*flags.split(), f"{FORCE_FLAG}={n_devices}"])
    return env

"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (device count is locked on first backend init, and only
launch/dryrun.py is allowed to fake 512 host devices).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod leading axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / single-host runs / elastic
    restarts: the mesh is derived from the LIVE device list)."""
    n = jax.device_count()
    if n % model_parallel != 0:
        raise ValueError(
            f"device count {n} not divisible by model_parallel "
            f"{model_parallel}"
        )
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))

"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 50 --reduced   # CPU-runnable demo (reduced config)

On hardware, drop ``--reduced`` and the full assignment config trains on the
mesh built from the live device list (elastic: device count is discovered,
never assumed).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import common
from repro.configs.registry import get_arch
from repro.data.pipeline import ClickStream, TokenStream, batched_molecules
from repro.optim import make_optimizer
from repro.train.loop import TrainLoop, TrainLoopConfig


def make_stream(family, model, cfg, reduced: bool):
    if family == "lm":
        if reduced:
            return TokenStream(vocab=model.cfg.vocab, batch=8, seq=32)
        return TokenStream(vocab=cfg.vocab, batch=256, seq=4096)
    if family == "recsys":
        return ClickStream(model.cfg, batch=16 if reduced else 65536)
    # gnn: repeated molecule batches

    class _G:
        def __init__(self):
            self.step = 0

        def next(self):
            rng = np.random.default_rng(self.step)
            self.step += 1
            return batched_molecules(rng, 8, 10, 20, model.cfg.d_feat,
                                     model.cfg.n_classes)

        def state(self):
            return {"step": self.step}

        def restore(self, s):
            self.step = s["step"]

    return _G()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--checkpoint-dir", default="checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--compression", action="store_true")
    args = ap.parse_args()

    bundle = get_arch(args.arch)
    if args.reduced:
        model, cfg, _ = bundle.make_reduced()
    else:
        model, cfg = bundle.model, bundle.cfg
    loss_fn = common.loss_for(bundle.family, model)
    opt = make_optimizer(getattr(cfg, "optimizer", "adamw"),
                         total_steps=args.steps)
    stream = make_stream(bundle.family, model, cfg, args.reduced)

    loop = TrainLoop(
        loss_fn, opt, stream,
        TrainLoopConfig(
            total_steps=args.steps,
            checkpoint_every=args.checkpoint_every,
            checkpoint_dir=args.checkpoint_dir,
            compression=args.compression,
            microbatches=1 if args.reduced else getattr(cfg, "microbatches", 1),
        ),
    )
    state = loop.init_or_restore(
        lambda: model.init_params(jax.random.PRNGKey(0))
    )
    state = loop.run(state)
    print(f"done: final loss {loop.losses[-1]:.4f} over {len(loop.losses)} steps "
          f"({loop.stragglers} straggler events)")


if __name__ == "__main__":
    main()

"""The paper's own workload configuration — metric-search corpora, index
parameters and serving knobs, as a first-class config (the `--arch`-style
entry point for the search side of the framework).

    from repro.configs.supermetric import SISAP_COLORS, build_index
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import flat_index, tree
from repro.data import metricsets


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    name: str
    metric: str = "l2"
    # corpus
    dataset: str = "colors"           # key into data.metricsets.DATASETS
    n_points: int | None = None       # None = dataset default (paper size)
    # paper thresholds (l2); index-time calibration overrides when None
    thresholds: tuple = ()
    selectivities: tuple = (1e-5, 1e-4, 1e-3)
    # tree engine (paper §4 winner)
    tree_variant: str = "hpt_fft_log"
    exclusion: str = "hilbert"
    # BSS engine (TPU-native)
    n_pivots: int = 16
    n_pairs: int = 24
    block: int = 128
    # LRT engine (§5 + §6 controlled unbalancing)
    lrt_partition: str = "lrt"
    lrt_select: str = "far"
    split_quantile: float = 0.5


SISAP_COLORS = SearchConfig(
    name="sisap-colors", dataset="colors",
    thresholds=(0.052, 0.083, 0.131),  # paper Table 3
)
SISAP_NASA = SearchConfig(
    name="sisap-nasa", dataset="nasa",
    thresholds=(0.120, 0.285, 0.530),
)
EUC10 = SearchConfig(
    name="euc10", dataset="euc10",
    thresholds=(0.229, 0.245, 0.263),
    selectivities=(1e-6, 2e-6, 4e-6),
)

CONFIGS = {c.name: c for c in (SISAP_COLORS, SISAP_NASA, EUC10)}


def load_corpus(cfg: SearchConfig, seed: int = 0):
    gen = metricsets.DATASETS[cfg.dataset][0]
    data = gen(seed=seed) if cfg.n_points is None else gen(cfg.n_points, seed=seed)
    return metricsets.split_queries(data, 0.10, seed=seed + 1)


def build_index(cfg: SearchConfig, corpus: np.ndarray, engine: str = "bss",
                seed: int = 0):
    """engine: 'bss' (TPU-native) | 'tree' (paper §4) | 'lrt' (paper §5)."""
    if engine == "bss":
        return flat_index.build_bss(
            cfg.metric, corpus, n_pivots=cfg.n_pivots, n_pairs=cfg.n_pairs,
            block=cfg.block, seed=seed,
        )
    if engine == "tree":
        return tree.build_tree(cfg.tree_variant, cfg.metric, corpus, seed=seed)
    if engine == "lrt":
        from repro.core import lrt as lrt_mod

        return lrt_mod.build_monotone_tree(
            cfg.lrt_partition, cfg.lrt_select, cfg.metric, corpus,
            seed=seed, split_quantile=cfg.split_quantile,
        )
    raise ValueError(engine)

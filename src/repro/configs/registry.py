"""Architecture registry: ``--arch <id>`` resolution for all launchers.

Per-arch modules (one file per assigned architecture, exact configs inside):
    configs/phi3_5_moe.py  configs/kimi_k2.py  configs/gemma2_9b.py
    configs/deepseek_coder_33b.py  configs/llama3_2_1b.py
    configs/pna.py
    configs/wide_deep.py  configs/din.py  configs/two_tower.py  configs/dlrm_rm2.py
plus the paper's own workload: configs/supermetric.py (metric-search corpus).
"""

from __future__ import annotations

from repro.configs import lm_archs, pna, recsys_archs
from repro.configs.common import ArchBundle

_REGISTRY: dict[str, ArchBundle] | None = None


def registry() -> dict[str, ArchBundle]:
    global _REGISTRY
    if _REGISTRY is None:
        _REGISTRY = {}
        _REGISTRY.update(lm_archs.bundles())
        _REGISTRY.update(pna.bundles())
        _REGISTRY.update(recsys_archs.bundles())
    return _REGISTRY


def get_arch(name: str) -> ArchBundle:
    reg = registry()
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; have: {sorted(reg)}")
    return reg[name]


def all_cells() -> list[tuple[str, str]]:
    """Every (arch, shape) pair — the 40-cell dry-run matrix."""
    out = []
    for name, b in registry().items():
        for cell in b.cells:
            out.append((name, cell))
    return out

"""--arch kimi-k2-1t-a32b (exact assignment config; implementation in lm_archs.py)."""
from repro.configs.lm_archs import bundles as _b

ARCH_ID = "kimi-k2-1t-a32b"
BUNDLE = _b()["kimi-k2-1t-a32b"]
CONFIG = BUNDLE.cfg

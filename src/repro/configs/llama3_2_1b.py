"""--arch llama3.2-1b (exact assignment config; implementation in lm_archs.py)."""
from repro.configs.lm_archs import bundles as _b

ARCH_ID = "llama3.2-1b"
BUNDLE = _b()["llama3.2-1b"]
CONFIG = BUNDLE.cfg

"""pna [gnn] — exact assignment config:

    n_layers=4 d_hidden=75 aggregators=mean-max-min-std
    scalers=id-amp-atten            [arXiv:2004.05718; paper]

Shapes (per assignment; see configs/common.GNN_SHAPES for the padded forms):
    full_graph_sm   n=2,708  e=10,556   d_feat=1,433   (full-batch, cora)
    minibatch_lg    n=232,965 e=114,615,892 batch_nodes=1,024 fanout=15-10
    ogb_products    n=2,449,029 e=61,859,140 d_feat=100 (full-batch-large)
    molecule        n=30 e=64 batch=128                 (batched-small-graphs)

The input feature width / label space differ per dataset, so each cell gets
its own (w_in, w_out) head around the shared 4×75 PNA trunk.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import common
from repro.models.gnn import PNAConfig, PNAModel

BASE = PNAConfig(
    name="pna", n_layers=4, d_hidden=75, d_feat=1433, n_classes=7,
)

_CELL_CFG = {
    "full_graph_sm": dict(d_feat=1433, n_classes=7),
    "minibatch_lg": dict(d_feat=602, n_classes=41),
    "ogb_products": dict(d_feat=100, n_classes=47),
    "molecule": dict(d_feat=16, n_classes=2, graph_level=True),
}


def _cell_model(cell_name: str) -> PNAModel:
    return PNAModel(dataclasses.replace(BASE, **_CELL_CFG[cell_name]))


def _make_reduced():
    cfg = dataclasses.replace(
        BASE, name="pna-smoke", n_layers=2, d_hidden=16, d_feat=8, n_classes=3
    )
    model = PNAModel(cfg)

    def batch_fn(rng):
        n, e = 64, 256
        rngs = jax.random.split(rng, 4)
        return {
            "x": jax.random.normal(rngs[0], (n, cfg.d_feat), jnp.float32),
            "edge_src": jax.random.randint(rngs[1], (e,), 0, n),
            "edge_dst": jax.random.randint(rngs[2], (e,), 0, n),
            "labels": jax.random.randint(rngs[3], (n,), 0, cfg.n_classes),
            "label_mask": jnp.ones((n,), jnp.float32),
        }

    return model, cfg, batch_fn


def bundles() -> dict:
    b = common.ArchBundle(
        name="pna",
        family="gnn",
        cfg=BASE,
        model=PNAModel(BASE),
        cells=common.gnn_cells(BASE),
        make_reduced=_make_reduced,
        cell_model=_cell_model,
    )
    return {"pna": b}

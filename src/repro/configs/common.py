"""Config substrate: architecture bundles and dry-run cells.

An ArchBundle knows how to produce, for every assigned input shape:
  * ShapeDtypeStruct input trees (no allocation — dry-run contract),
  * input PartitionSpecs for a given mesh,
  * the step function to lower (train_step / prefill / decode / serve),
and how to build a REDUCED version of itself for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.sharding import dp_axes

SKIP_PURE_FULL_ATTENTION = (
    "long_500k requires sub-quadratic attention; this arch is pure full "
    "attention (assignment rule: skip + note in DESIGN.md)"
)


@dataclasses.dataclass
class Cell:
    shape_name: str
    kind: str  # train | prefill | decode | serve
    # inputs() -> pytree of ShapeDtypeStruct (the *batch*, not params)
    inputs: Callable[[], Any]
    # input_partition(mesh) -> matching pytree of PartitionSpec
    input_partition: Callable[[Mesh], Any]
    skip: str | None = None
    note: str = ""


@dataclasses.dataclass
class ArchBundle:
    name: str
    family: str  # lm | gnn | recsys
    cfg: Any
    model: Any
    cells: dict[str, Cell]
    # reduced-config smoke artifacts
    make_reduced: Callable[[], tuple[Any, Any, Callable]]
    # (model, cfg, batch_fn(rng) -> concrete reduced batch)
    # per-cell model override (GNN heads differ per dataset shape)
    cell_model: Callable[[str], Any] | None = None

    def model_for(self, cell_name: str):
        if self.cell_model is not None:
            return self.cell_model(cell_name)
        return self.model

    def loss_fn(self, model=None):
        return loss_for(self.family, model if model is not None else self.model)


def loss_for(family: str, model) -> Callable:
    if family == "recsys":
        from repro.models import recsys as R

        if model.cfg.kind == "two_tower":
            return model.loss_fn
        return lambda p, b: R.bce_loss(model, p, b)
    return model.loss_fn


# --------------------------------------------------------------------- LM

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def lm_cells(cfg, model, *, pure_full_attention: bool) -> dict[str, Cell]:
    cells = {}
    for shape_name, s in LM_SHAPES.items():
        kind, seq, batch = s["kind"], s["seq"], s["batch"]
        skip = None
        if shape_name == "long_500k" and pure_full_attention:
            skip = SKIP_PURE_FULL_ATTENTION

        if kind == "train":

            def inputs(seq=seq, batch=batch):
                return {"tokens": jax.ShapeDtypeStruct((batch, seq + 1), jnp.int32)}

            def ipart(mesh):
                return {"tokens": P(dp_axes(mesh), None)}

        elif kind == "prefill":

            def inputs(seq=seq, batch=batch):
                return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}

            def ipart(mesh):
                return {"tokens": P(dp_axes(mesh), None)}

        else:  # decode: batch + cache handled by the launcher

            def inputs(seq=seq, batch=batch):
                return {
                    "token": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
                    "pos": jax.ShapeDtypeStruct((), jnp.int32),
                }

            def ipart(mesh, batch=batch):
                tok = P(dp_axes(mesh), None) if batch > 1 else P(None, None)
                return {"token": tok, "pos": P()}

        cells[shape_name] = Cell(shape_name, kind, inputs, ipart, skip=skip)
    return cells


def lm_reduced(cfg_cls, model_cls, **overrides):
    """Tiny same-family config + synthetic batch for CPU smoke."""

    def make():
        cfg = cfg_cls(**overrides)
        model = model_cls(cfg)

        def batch_fn(rng):
            return {
                "tokens": jax.random.randint(rng, (2, 33), 0, cfg.vocab)
            }

        return model, cfg, batch_fn

    return make


# --------------------------------------------------------------------- GNN

def _pad512(x: int) -> int:
    return -(-x // 512) * 512


GNN_SHAPES = {
    # exact assignment numbers, padded to a multiple of 512 so node/edge
    # arrays shard evenly on both production meshes (padding is masked out
    # via label_mask / degree-0 nodes — standard pipeline practice).
    "full_graph_sm": dict(n=2708, e=10556, f=1433, classes=7),
    "minibatch_lg": dict(
        n=1024 + 1024 * 15 + 1024 * 15 * 10, e=1024 * 15 + 1024 * 15 * 10,
        f=602, classes=41,
        note="reddit-scale sampled subgraph: 1,024 seeds, fanout 15-10 "
             "(232,965 nodes / 114,615,892 edges in the full graph)",
    ),
    "ogb_products": dict(n=2_449_029, e=61_859_140, f=100, classes=47),
    "molecule": dict(
        n=30 * 128, e=64 * 128, f=16, classes=2,
        graphs=128, note="128 small graphs batched block-diagonally",
    ),
}


GNN_EDGE_BLOCKS = 512  # dst-partitioned edge layout: one row per node block


def gnn_cells(cfg) -> dict[str, Cell]:
    """PNA shape set (see configs/pna.py for the exact numbers).

    Edges use the dst-partitioned layout (S=512 blocks x E_loc, 5% skew
    slack) — see PNAModel._forward_partitioned for why."""
    cells = {}
    for name, s in GNN_SHAPES.items():
        e_loc = -(-int(s["e"] * 1.05 // GNN_EDGE_BLOCKS) // 8) * 8 + 8

        def inputs(s=s, e_loc=e_loc):
            n = _pad512(s["n"])
            eshape = (GNN_EDGE_BLOCKS, e_loc)
            d = {
                "x": jax.ShapeDtypeStruct((n, s["f"]), jnp.float32),
                "edge_src": jax.ShapeDtypeStruct(eshape, jnp.int32),
                "edge_dst_local": jax.ShapeDtypeStruct(eshape, jnp.int32),
                "edge_valid": jax.ShapeDtypeStruct(eshape, jnp.bool_),
            }
            if "graphs" in s:
                d["graph_id"] = jax.ShapeDtypeStruct((n,), jnp.int32)
                d["labels"] = jax.ShapeDtypeStruct((s["graphs"],), jnp.int32)
            else:
                d["labels"] = jax.ShapeDtypeStruct((n,), jnp.int32)
                d["label_mask"] = jax.ShapeDtypeStruct((n,), jnp.float32)
            return d

        def ipart(mesh, s=s):
            all_ax = dp_axes(mesh) + ("model",)
            nodes = P(all_ax)
            edges = P(all_ax, None)
            d = {
                "x": P(all_ax, None),
                "edge_src": edges,
                "edge_dst_local": edges,
                "edge_valid": edges,
            }
            if "graphs" in s:
                d["graph_id"] = nodes
                d["labels"] = P(None)
            else:
                d["labels"] = nodes
                d["label_mask"] = nodes
            return d

        cells[name] = Cell(name, "train", inputs, ipart, note=s.get("note", ""))
    return cells


def _axsize(mesh: Mesh, axes) -> int:
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


# ------------------------------------------------------------------ recsys

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="serve", batch=1, candidates=1_000_000),
}


def recsys_batch_sds(cfg, batch: int, candidates: int | None = None, train=False):
    """ShapeDtypeStruct batch for each recsys model kind."""
    k = cfg.kind
    d = {}
    if k == "wide_deep":
        b = candidates or batch
        d["sparse_ids"] = jax.ShapeDtypeStruct((b, cfg.n_sparse), jnp.int32)
    elif k == "din":
        b = candidates or batch
        d["hist_ids"] = jax.ShapeDtypeStruct((b, cfg.hist_len), jnp.int32)
        d["hist_valid"] = jax.ShapeDtypeStruct((b, cfg.hist_len), jnp.bool_)
        d["target_id"] = jax.ShapeDtypeStruct((b,), jnp.int32)
    elif k == "two_tower":
        d["user_ids"] = jax.ShapeDtypeStruct((batch, cfg.n_user_fields), jnp.int32)
        if candidates:
            d["candidates"] = jax.ShapeDtypeStruct(
                (candidates, cfg.embed_dim), jnp.float32
            )
        else:
            d["item_ids"] = jax.ShapeDtypeStruct((batch, cfg.n_item_fields), jnp.int32)
    elif k == "dlrm":
        b = candidates or batch
        d["dense"] = jax.ShapeDtypeStruct((b, cfg.n_dense), jnp.float32)
        d["sparse_ids"] = jax.ShapeDtypeStruct((b, cfg.n_sparse), jnp.int32)
    else:
        raise ValueError(k)
    if train and k != "two_tower":
        b = candidates or batch
        d["label"] = jax.ShapeDtypeStruct((b,), jnp.float32)
    return d


def recsys_cells(cfg) -> dict[str, Cell]:
    cells = {}
    for name, s in RECSYS_SHAPES.items():
        cand = s.get("candidates")

        def inputs(s=s, cand=cand):
            return recsys_batch_sds(cfg, s["batch"], cand, train=s["kind"] == "train")

        def ipart(mesh, s=s, cand=cand):
            dp = dp_axes(mesh)
            eff = cand or s["batch"]
            row = P(dp) if eff % _axsize(mesh, dp) == 0 else P(None)
            sds = recsys_batch_sds(cfg, s["batch"], cand, train=s["kind"] == "train")
            out = {}
            for key, sd in sds.items():
                if key == "candidates":
                    # FEATURE-dim sharding: row gathers (incl. the pruned
                    # variant's dynamic block gather) stay local; the dot
                    # becomes a partial contraction + tiny all-reduce.
                    # Row sharding instead makes GSPMD all-gather the whole
                    # 1 GB table for the dynamic gather (measured).
                    out[key] = P(None, "model")
                elif key == "user_ids" and cand:
                    out[key] = P(None, None)  # batch=1
                else:
                    out[key] = P(*(tuple(row) + (None,) * (len(sd.shape) - 1)))
            return out

        note = ""
        if cand and cfg.kind != "two_tower":
            note = (
                "retrieval_cand for a CTR model = bulk-score 1M candidate rows "
                "for one user (user features broadcast into each row)"
            )
        cells[name] = Cell(name, s["kind"], inputs, ipart, note=note)
    return cells

"""The four assigned recsys architectures — exact assignment configs.

    wide-deep           n_sparse=40 embed_dim=32 mlp=1024-512-256
                        interaction=concat            [arXiv:1606.07792]
    din                 embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80
                        interaction=target-attn       [arXiv:1706.06978]
    two-tower-retrieval embed_dim=256 tower_mlp=1024-512-256 interaction=dot
                        sampled-softmax               [RecSys'19 (YouTube)]
    dlrm-rm2            n_dense=13 n_sparse=26 embed_dim=64
                        bot_mlp=13-512-256-64 top_mlp=512-512-256-1
                        interaction=dot               [arXiv:1906.00091]

Vocabulary sizes are not pinned by the assignment; we use the 10^6-row
regime from the public DLRM/Criteo literature (kernel_taxonomy §D.6) —
documented here so the roofline numbers are reproducible.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import common
from repro.models import recsys as R

WIDE_DEEP = R.RecsysConfig(
    name="wide-deep", kind="wide_deep", n_sparse=40, embed_dim=32,
    mlp=(1024, 512, 256), vocab=1_000_000,
)
DIN = R.RecsysConfig(
    name="din", kind="din", embed_dim=18, hist_len=100,
    attn_mlp=(80, 40), mlp=(200, 80), vocab=1_000_000,
)
TWO_TOWER = R.RecsysConfig(
    name="two-tower-retrieval", kind="two_tower", embed_dim=256,
    tower_mlp=(1024, 512, 256), n_user_fields=8, n_item_fields=4,
    vocab=1_000_000,
)
DLRM = R.RecsysConfig(
    name="dlrm-rm2", kind="dlrm", n_dense=13, n_sparse=26, embed_dim=64,
    bot_mlp=(512, 256, 64), mlp=(512, 512, 256), vocab=1_000_000,
)

_MODEL_CLS = {
    "wide_deep": R.WideDeepModel,
    "din": R.DINModel,
    "two_tower": R.TwoTowerModel,
    "dlrm": R.DLRMModel,
}


def _make_reduced_fn(cfg):
    def make():
        small = dataclasses.replace(
            cfg, name=cfg.name + "-smoke", vocab=997, dtype=jnp.float32
        )
        model = _MODEL_CLS[cfg.kind](small)

        def batch_fn(rng):
            sds = common.recsys_batch_sds(small, batch=16, train=True)
            rngs = jax.random.split(rng, len(sds))
            out = {}
            for k_rng, (key, sd) in zip(rngs, sds.items()):
                if sd.dtype == jnp.int32:
                    out[key] = jax.random.randint(k_rng, sd.shape, 0, small.vocab)
                elif sd.dtype == jnp.bool_:
                    out[key] = jnp.ones(sd.shape, jnp.bool_)
                else:
                    out[key] = jax.random.uniform(k_rng, sd.shape, jnp.float32)
            return out

        return model, small, batch_fn

    return make


def bundles() -> dict:
    out = {}
    for cfg in (WIDE_DEEP, DIN, TWO_TOWER, DLRM):
        model = _MODEL_CLS[cfg.kind](cfg)
        out[cfg.name] = common.ArchBundle(
            name=cfg.name,
            family="recsys",
            cfg=cfg,
            model=model,
            cells=common.recsys_cells(cfg),
            make_reduced=_make_reduced_fn(cfg),
        )
    return out

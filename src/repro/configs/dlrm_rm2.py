"""--arch dlrm-rm2 (exact assignment config; implementation in recsys_archs.py)."""
from repro.configs.recsys_archs import bundles as _b

ARCH_ID = "dlrm-rm2"
BUNDLE = _b()["dlrm-rm2"]
CONFIG = BUNDLE.cfg

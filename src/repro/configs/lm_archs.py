"""The five assigned LM architectures — exact configs from the assignment.

    phi3.5-moe-42b-a6.6b  [moe]   32L d4096 32H (GQA kv=8) dff6400 v32064, 16e top-2
                          [hf:microsoft/Phi-3.5-MoE-instruct]
    kimi-k2-1t-a32b       [moe]   61L d7168 64H (GQA kv=8) dff2048 v163840, 384e top-8
                          [arXiv:2501.kimi2] (+1 shared expert; head_dim 128
                          chosen for MXU alignment — assignment leaves it open)
    gemma2-9b             [dense] 42L d3584 16H (GQA kv=8) dff14336 v256000
                          local(4096)+global alternating, softcaps [arXiv:2408.00118]
    deepseek-coder-33b    [dense] 62L d7168 56H (GQA kv=8) dff19200 v32256
                          llama-arch [arXiv:2401.14196]
    llama3.2-1b           [dense] 16L d2048 32H (GQA kv=8) dff8192 v128256
                          [hf:meta-llama/Llama-3.2-1B]

Optimizer note: kimi-k2 (1T params) uses Adafactor — AdamW fp32 states are
20 bytes/param = 20 TB, unfittable on 512 v5e chips; Adafactor's factored
second moment brings state+param+grad to ~8 GB/chip (PaLM/T5 precedent).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs import common
from repro.models.transformer import LMConfig, LMModel


def _bundle(cfg: LMConfig, *, pure_full_attention: bool, reduced_kw: dict):
    model = LMModel(cfg)
    reduced_defaults = dict(
        name=cfg.name + "-smoke", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=211, dtype=jnp.float32, remat=False,
    )
    reduced_defaults.update(reduced_kw)
    return common.ArchBundle(
        name=cfg.name,
        family="lm",
        cfg=cfg,
        model=model,
        cells=common.lm_cells(cfg, model, pure_full_attention=pure_full_attention),
        make_reduced=common.lm_reduced(LMConfig, LMModel, **reduced_defaults),
    )


PHI35_MOE = LMConfig(
    name="phi3.5-moe-42b-a6.6b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab=32064, moe_experts=16, moe_top_k=2,
    optimizer="adamw", microbatches=4, expert_axis="model",
    seq_shard_activations=True,
)

KIMI_K2 = LMConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab=163840, head_dim=128, moe_experts=384, moe_top_k=8,
    n_shared_experts=1,
    optimizer="adafactor", microbatches=8, expert_axis="model",
    seq_shard_activations=True, grad_accum_dtype="bfloat16",
)

GEMMA2_9B = LMConfig(
    name="gemma2-9b",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_ff=14336,
    vocab=256000, head_dim=256,
    sliding_window=4096, local_global_alternate=True,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True, scale_embed=True,
    optimizer="adamw", microbatches=4, seq_shard_activations=True,
    kv_cache_dtype="int8",
)

DEEPSEEK_CODER_33B = LMConfig(
    name="deepseek-coder-33b",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19200,
    vocab=32256,
    optimizer="adamw", microbatches=4, seq_shard_activations=True,
)

LLAMA32_1B = LMConfig(
    name="llama3.2-1b",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab=128256,
    optimizer="adamw", microbatches=1, seq_shard_activations=True,
)


def bundles() -> dict:
    return {
        "phi3.5-moe-42b-a6.6b": _bundle(
            PHI35_MOE, pure_full_attention=True,
            reduced_kw=dict(moe_experts=4, moe_top_k=2, expert_axis=None),
        ),
        "kimi-k2-1t-a32b": _bundle(
            KIMI_K2, pure_full_attention=True,
            reduced_kw=dict(moe_experts=4, moe_top_k=2, n_shared_experts=1,
                            optimizer="adafactor", expert_axis=None,
                            seq_shard_activations=False),
        ),
        "gemma2-9b": _bundle(
            GEMMA2_9B, pure_full_attention=False,
            reduced_kw=dict(sliding_window=8, local_global_alternate=True,
                            attn_softcap=50.0, final_softcap=30.0,
                            post_norms=True, scale_embed=True),
        ),
        "deepseek-coder-33b": _bundle(
            DEEPSEEK_CODER_33B, pure_full_attention=True, reduced_kw={}
        ),
        "llama3.2-1b": _bundle(
            LLAMA32_1B, pure_full_attention=True, reduced_kw={}
        ),
    }

"""--arch gemma2-9b (exact assignment config; implementation in lm_archs.py)."""
from repro.configs.lm_archs import bundles as _b

ARCH_ID = "gemma2-9b"
BUNDLE = _b()["gemma2-9b"]
CONFIG = BUNDLE.cfg

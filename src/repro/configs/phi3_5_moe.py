"""--arch phi3.5-moe-42b-a6.6b (exact assignment config; implementation in lm_archs.py)."""
from repro.configs.lm_archs import bundles as _b

ARCH_ID = "phi3.5-moe-42b-a6.6b"
BUNDLE = _b()["phi3.5-moe-42b-a6.6b"]
CONFIG = BUNDLE.cfg

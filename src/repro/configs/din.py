"""--arch din (exact assignment config; implementation in recsys_archs.py)."""
from repro.configs.recsys_archs import bundles as _b

ARCH_ID = "din"
BUNDLE = _b()["din"]
CONFIG = BUNDLE.cfg

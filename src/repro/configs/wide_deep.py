"""--arch wide-deep (exact assignment config; implementation in recsys_archs.py)."""
from repro.configs.recsys_archs import bundles as _b

ARCH_ID = "wide-deep"
BUNDLE = _b()["wide-deep"]
CONFIG = BUNDLE.cfg

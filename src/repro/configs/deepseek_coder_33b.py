"""--arch deepseek-coder-33b (exact assignment config; implementation in lm_archs.py)."""
from repro.configs.lm_archs import bundles as _b

ARCH_ID = "deepseek-coder-33b"
BUNDLE = _b()["deepseek-coder-33b"]
CONFIG = BUNDLE.cfg

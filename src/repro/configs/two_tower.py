"""--arch two-tower-retrieval (exact assignment config; implementation in recsys_archs.py)."""
from repro.configs.recsys_archs import bundles as _b

ARCH_ID = "two-tower-retrieval"
BUNDLE = _b()["two-tower-retrieval"]
CONFIG = BUNDLE.cfg

"""Sharded optimizers: AdamW (fp32 states) and Adafactor (factored second
moment, no momentum — the only optimizer whose state fits a 1T-param model on
512 chips; same trade-off PaLM/T5 made).

Optimizer states inherit the parameter PartitionSpecs (ZeRO-style: since
params are already FSDP-sharded over the data axes, the states are too —
there is no replicated optimizer memory anywhere).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer",
    "adamw",
    "adafactor",
    "cosine_schedule",
    "clip_by_global_norm",
    "make_optimizer",
]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], tuple[Any, Any]]
    # state_specs(param_specs) -> spec pytree matching init(params) structure
    state_specs: Callable[[Any], Any]


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def adamw(
    lr: Callable | float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, _unused_step=None):
        step = state["step"] + 1
        if grad_clip:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr_t = lr_fn(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh, vh = m / bc1, v / bc2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}

    def state_specs(param_specs):
        from jax.sharding import PartitionSpec as P

        return {
            "m": param_specs,
            "v": param_specs,
            "step": P(),
        }

    return Optimizer(init, update, state_specs)


def adafactor(
    lr: Callable | float = 1e-2,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    scan_leading_dim: bool = True,
) -> Optimizer:
    """Factored Adafactor (Shazeer & Stern, 2018), momentum-free.

    For params with ndim >= 2 the second moment is factored over the last two
    dims (row/col running means) — O(n+m) state instead of O(n*m); smaller
    params keep a full second moment.

    ``scan_leading_dim``: apply the (purely elementwise-per-slice) update as
    a lax.scan over stacked-layer leaves (ndim>=3, leading dim>=8), bounding
    the fp32 update transients to ONE layer slice instead of the whole
    stacked tensor (a 61-layer MoE leaf is ~2.2 GB/chip in fp32 — x4 live
    copies without this).
    """
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def one(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "f": jax.tree.map(one, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, _unused=None):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd_slice(p, g, f):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in f:
                vr = beta * f["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * f["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                )
                cfac = jax.lax.rsqrt(vc)
                u = g * rfac[..., None] * cfac[..., None, :]
                newf = {"vr": vr, "vc": vc}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v)
                newf = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            newp = p.astype(jnp.float32) - lr_t * (
                u + weight_decay * p.astype(jnp.float32)
            )
            return newp.astype(p.dtype), newf

        def upd(p, g, f):
            if scan_leading_dim and p.ndim >= 3 and p.shape[0] >= 8:
                def body(_, xs):
                    return None, upd_slice(*xs)

                _, (newp, newf) = jax.lax.scan(body, None, (p, g, f))
                return newp, newf
            return upd_slice(p, g, f)

        # tree.map flattens grads/state up to params' treedef, so the per-leaf
        # factored-state dicts arrive intact at ``upd``.
        out = jax.tree.map(upd, params, grads, state["f"])
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2  # noqa: E731
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
        new_f = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
        return new_params, {"f": new_f, "step": step}

    def state_specs(param_specs):
        from jax.sharding import PartitionSpec as P

        def one(spec):
            # vr drops the last dim's sharding, vc the second-to-last's.
            parts = tuple(spec)
            if len(parts) >= 2:
                return {
                    "vr": P(*parts[:-1]),
                    "vc": P(*(parts[:-2] + parts[-1:])),
                }
            return {"v": P(*parts) if parts else P()}

        return {
            "f": jax.tree.map(one, param_specs),
            "step": P(),
        }

    return Optimizer(init, update, state_specs)


def make_optimizer(kind: str, total_steps: int = 10_000) -> Optimizer:
    if kind == "adamw":
        return adamw(lr=cosine_schedule(3e-4, 200, total_steps))
    if kind == "adafactor":
        return adafactor(lr=cosine_schedule(1e-2, 200, total_steps))
    raise ValueError(kind)

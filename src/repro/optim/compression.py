"""Gradient compression: int8 quantisation with error feedback.

At 1000+-node scale the data-parallel all-reduce is the dominant inter-pod
collective; 4x compression (fp32 grads -> int8 + per-tensor scale) cuts it
proportionally.  Error feedback (Seide et al., 2014; Karimireddy et al.,
2019) accumulates the quantisation residual locally and re-injects it the
next step, which preserves convergence to first order.

Usage: wrap the gradients between accumulation and the optimizer in the
train step.  On a real multi-pod mesh the int8 tensors are what cross the
inter-pod links (the quantise happens before the pjit-inserted reduce when
``shard_map``-scoped; here we keep the pjit formulation and document the
wire-format intent — the arithmetic and convergence behaviour are identical
and test-covered).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["int8_error_feedback"]


def _quantize(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def int8_error_feedback(grads, residuals):
    """Returns (compressed-then-decompressed grads, new residuals).

    residuals pytree matches grads (fp32); pass zeros initially.
    """

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = _quantize(g32)
        deq = _dequantize(q, s)
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(one, grads, residuals)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2  # noqa: E731
    newg = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
    newr = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
    return newg, newr

from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adamw,
    adafactor,
    cosine_schedule,
    clip_by_global_norm,
    make_optimizer,
)
from repro.optim.compression import int8_error_feedback  # noqa: F401

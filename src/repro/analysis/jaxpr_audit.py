"""Layer 2: jaxpr audit of the six public engine entry points.

Where the AST lint (layer 1) reasons about SOURCE, this layer reasons
about the TRACED program: it drives `bss_query_batched`,
`bss_knn_batched`, `sharded_query_batched`, `sharded_knn_batched`,
`forest_range_search` and `monotone_range_search` over tiny synthetic
indexes across the {metric x backend(jnp, pallas-interpret) x
realisation x precision(fp32, bf16)} matrix, captures the jaxpr of every
jitted engine function that fires (module-level jits are wrapped; the
sharded engine's dynamically created ``jax.jit(shard_map(...))`` closures
are caught by patching ``jax.jit`` itself), and statically walks the
jaxprs to assert:

* **no float64** — no var, const or ``convert_element_type`` target is
  f64 anywhere, including sub-jaxprs (pjit / scan / while / pallas_call);
* **no host callbacks** — no ``pure_callback`` / ``io_callback`` /
  ``debug_callback`` primitive (a callback inside an engine jit is a
  hidden host sync);
* **bf16 confinement** — bfloat16 appears in a cell's jaxprs iff
  ``precision="bf16"``, and a dataflow taint walk from the bf16 inputs
  proves the bound-phase outputs (``alive`` / ``tile_mask`` / frontier
  hits / distance counts) are UNTAINTED: PR 6's bit-identity proof rests
  on the pruning tables never depending on the reduced-precision corpus,
  and this check makes that mechanical.

Plus the **compile-cache audit** (:func:`audit_compile_cache`): replay a
mixed-shape query stream through ``ServingFront`` and assert each engine
jit's distinct-lowering count equals the bucket-ladder prediction — PR
5's bounded-recompile guarantee as an equality, not a hope.

Pure trace-time analysis plus tiny real calls; no TPU needed (pallas runs
in interpret mode).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Callable

from repro.core.backends import EngineOpts

__all__ = [
    "AuditProblem",
    "run_audit",
    "audit_compile_cache",
    "AUDIT_METRICS",
]

AUDIT_METRICS = ("l2", "cosine", "jsd", "triangular")
_CALLBACK_PRIMS = {"pure_callback", "io_callback", "debug_callback"}


@dataclasses.dataclass(frozen=True)
class AuditProblem:
    cell: str      # matrix-cell description, e.g. "bss/jsd/pallas/bf16"
    fn: str        # jitted function name
    check: str     # f64 | callback | bf16-absent | bf16-present | taint
    detail: str

    def format(self) -> str:
        return f"[{self.cell}] {self.fn}: {self.check}: {self.detail}"


@dataclasses.dataclass
class _Capture:
    fn: str
    cell: str
    closed: Any        # jax.core.ClosedJaxpr
    out_shape: Any     # pytree of ShapeDtypeStruct


# ---------------------------------------------------------------------------
# capture machinery
# ---------------------------------------------------------------------------


def _is_array_like(x) -> bool:
    import jax
    import numpy as np

    return isinstance(x, (np.ndarray, jax.Array, np.generic))


def _is_traced_arg(x) -> bool:
    """Pytrees containing any array are traced; bare scalars/strings/None
    are closed over as statics (matching how the engines pass them)."""
    import jax

    leaves = jax.tree_util.tree_leaves(x)
    return any(_is_array_like(l) for l in leaves)


class _Recorder:
    """Collects one jaxpr per distinct (fn, arg-signature) call, tagged
    with the matrix cell active at call time."""

    def __init__(self):
        self.captures: list[_Capture] = []
        self.cell = "?"
        self._seen: dict[str, _Capture] = {}

    def _signature(self, name, args, kwargs):
        import jax

        parts = [name]
        for a in args:
            if _is_traced_arg(a):
                for l in jax.tree_util.tree_leaves(a):
                    parts.append(f"{getattr(l, 'shape', ())}"
                                 f"{getattr(l, 'dtype', type(l).__name__)}")
            else:
                parts.append(repr(a))
        parts.append(repr(sorted(kwargs.items(), key=lambda kv: kv[0])))
        return "|".join(parts)

    def record(self, name: str, inner: Callable, args, kwargs) -> None:
        import jax

        sig = self._signature(name, args, kwargs)
        prior = self._seen.get(sig)
        if prior is not None:
            # identical trace already captured: register it under this
            # cell too (checks are per cell) without paying a re-trace
            if prior.cell != self.cell and not any(
                c.fn == name and c.cell == self.cell
                for c in self.captures
            ):
                self.captures.append(
                    _Capture(name, self.cell, prior.closed, prior.out_shape)
                )
            return
        spec: list = []
        arrays: list = []
        for a in args:
            if _is_traced_arg(a):
                spec.append(len(arrays))
                arrays.append(a)
            else:
                spec.append(("static", a))

        def closure(*arrs):
            rebuilt = [
                arrs[s] if isinstance(s, int) else s[1] for s in spec
            ]
            return inner(*rebuilt, **kwargs)

        closed, out_shape = jax.make_jaxpr(closure, return_shape=True)(
            *arrays
        )
        cap = _Capture(name, self.cell, closed, out_shape)
        self._seen[sig] = cap
        self.captures.append(cap)

    def for_cell(self, cell: str) -> list[_Capture]:
        return [c for c in self.captures if c.cell == cell]


def _wrap_module_jit(rec: _Recorder, name: str, jitted):
    inner = jitted.__wrapped__

    @functools.wraps(jitted)
    def wrapper(*args, **kwargs):
        rec.record(name, inner, args, kwargs)
        return jitted(*args, **kwargs)

    wrapper.__audit_original__ = jitted
    return wrapper


@contextlib.contextmanager
def _patched_engines(rec: _Recorder):
    """Wrap every module-level engine jit AND ``jax.jit`` itself (the
    sharded engine creates its shard_map jits lazily per dispatch key)."""
    import jax

    from repro.core import flat_index
    from repro.forest import walk

    targets = [
        (flat_index, n)
        for n in (
            "_lower_bounds_jit",
            "_cells_exact_jit",
            "_cells_exact_bf16_jit",
            "_dense_hit_mask_jit",
            "_query_batched_jit",
            "_query_batched_bf16_jit",
            "_knn_round_jit",
            "_knn_round_bf16_jit",
            "_knn_round_cells_jit",
            "_knn_round_cells_bf16_jit",
            "_knn_lb_jit",
        )
    ] + [(walk, n) for n in ("_forest_walk_jit", "_monotone_walk_jit")]

    saved = [(m, n, getattr(m, n)) for m, n in targets]
    real_jit = jax.jit

    def recording_jit(fun, *a, **kw):
        jitted = real_jit(fun, *a, **kw)

        @functools.wraps(jitted)
        def wrapper(*args, **kwargs):
            rec.record(
                getattr(fun, "__name__", "dynamic_jit"), fun, args, kwargs
            )
            return jitted(*args, **kwargs)

        return wrapper

    try:
        for m, n, fn in saved:
            setattr(m, n, _wrap_module_jit(rec, n, fn))
        jax.jit = recording_jit
        yield
    finally:
        jax.jit = real_jit
        for m, n, fn in saved:
            setattr(m, n, fn)


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _sub_jaxprs(params: dict):
    """Yield every (Closed)Jaxpr buried in an eqn's params."""
    import jax.core as jcore

    def visit(v):
        if isinstance(v, jcore.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jcore.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                yield from visit(x)
        elif isinstance(v, dict):
            for x in v.values():
                yield from visit(x)

    for v in params.values():
        yield from visit(v)


def _all_jaxprs(jaxpr):
    """The jaxpr and every sub-jaxpr, recursively."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        for sub in _sub_jaxprs(eqn.params):
            yield from _all_jaxprs(sub)


def _dtype_of(v):
    aval = getattr(v, "aval", None)
    return getattr(aval, "dtype", None)


def _check_no_f64(cap: _Capture) -> list[str]:
    import numpy as np

    problems = []
    for j in _all_jaxprs(cap.closed.jaxpr):
        for eqn in j.eqns:
            nd = eqn.params.get("new_dtype")
            if nd is not None and np.dtype(nd) == np.float64:
                problems.append(
                    f"{eqn.primitive.name} converts to float64"
                )
            for v in list(eqn.invars) + list(eqn.outvars):
                if _dtype_of(v) == np.float64:
                    problems.append(
                        f"float64 value at {eqn.primitive.name}"
                    )
        for v in list(j.invars) + list(j.constvars) + list(j.outvars):
            if _dtype_of(v) == np.float64:
                problems.append("float64 jaxpr binder")
    return sorted(set(problems))


def _check_no_callbacks(cap: _Capture) -> list[str]:
    problems = []
    for j in _all_jaxprs(cap.closed.jaxpr):
        for eqn in j.eqns:
            if eqn.primitive.name in _CALLBACK_PRIMS:
                problems.append(f"{eqn.primitive.name} primitive present")
    return sorted(set(problems))


def _has_bf16(cap: _Capture) -> bool:
    import jax.numpy as jnp

    bf16 = jnp.bfloat16
    for j in _all_jaxprs(cap.closed.jaxpr):
        for v in list(j.invars) + list(j.constvars) + list(j.outvars):
            if _dtype_of(v) == bf16:
                return True
        for eqn in j.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                if _dtype_of(v) == bf16:
                    return True
    return False


# -- taint ------------------------------------------------------------------


def _is_bf16_var(v) -> bool:
    import jax.numpy as jnp

    return _dtype_of(v) == jnp.bfloat16


def _taint_jaxpr(jaxpr, in_taint: list[bool], consts=None) -> list[bool]:
    """Propagate bf16 taint through one jaxpr: returns per-outvar taint.

    Precise through pjit-style call eqns (sub-jaxpr arity matches the
    eqn's) and through scan/while carry loops (iterated to fixpoint);
    conservative (any tainted input taints all outputs) elsewhere —
    pallas_call included, which is sound because the only pallas kernels
    fed bf16 are the exact-phase distance scans whose outputs are
    legitimately tainted."""
    import jax.core as jcore

    tainted: set = set()

    def var_tainted(x) -> bool:
        if isinstance(x, jcore.Literal):
            return _is_bf16_var(x)
        return x in tainted or _is_bf16_var(x)

    for v, t in zip(jaxpr.invars, in_taint):
        if t:
            tainted.add(v)
    if consts is not None:
        for v, c in zip(jaxpr.constvars, consts):
            if getattr(c, "dtype", None) is not None and str(c.dtype) == (
                "bfloat16"
            ):
                tainted.add(v)

    changed = True
    while changed:
        changed = False
        for eqn in eqns_of(jaxpr):
            in_t = [var_tainted(x) for x in eqn.invars]
            out_t = _eqn_out_taint(eqn, in_t)
            for o, t in zip(eqn.outvars, out_t):
                if t and o not in tainted:
                    tainted.add(o)
                    changed = True
    return [var_tainted(o) for o in jaxpr.outvars]


def eqns_of(jaxpr):
    return jaxpr.eqns


def _eqn_out_taint(eqn, in_t: list[bool]) -> list[bool]:
    import jax.core as jcore

    name = eqn.primitive.name
    params = eqn.params
    if name == "scan" and "jaxpr" in params:
        sub = params["jaxpr"]
        sub_j = sub.jaxpr if isinstance(sub, jcore.ClosedJaxpr) else sub
        nc = params.get("num_consts", 0)
        ncar = params.get("num_carry", 0)
        cur = list(in_t)
        while True:
            out_t = _taint_jaxpr(sub_j, cur)
            nxt = list(cur)
            for i in range(ncar):
                if out_t[i]:
                    nxt[nc + i] = True
            if nxt == cur:
                return out_t
            cur = nxt
    if name == "while" and "body_jaxpr" in params:
        body = params["body_jaxpr"]
        body_j = body.jaxpr if isinstance(body, jcore.ClosedJaxpr) else body
        cn = params.get("cond_nconsts", 0)
        bn = params.get("body_nconsts", 0)
        carry_t = list(in_t[cn + bn:])
        body_consts_t = list(in_t[cn:cn + bn])
        while True:
            out_t = _taint_jaxpr(body_j, body_consts_t + carry_t)
            nxt = [a or b for a, b in zip(carry_t, out_t)]
            if nxt == carry_t:
                return carry_t
            carry_t = nxt
    sub = params.get("jaxpr", params.get("call_jaxpr"))
    if sub is not None:
        sub_j = sub.jaxpr if isinstance(sub, jcore.ClosedJaxpr) else sub
        consts = (
            sub.consts if isinstance(sub, jcore.ClosedJaxpr) else None
        )
        if len(sub_j.invars) == len(eqn.invars) and len(
            sub_j.outvars
        ) == len(eqn.outvars):
            return _taint_jaxpr(sub_j, in_t, consts)
    # conservative fallback (pallas_call, cond, collectives, ...)
    any_t = any(in_t)
    return [any_t] * len(eqn.outvars)


def _output_taint(cap: _Capture) -> list[bool]:
    """Per-flat-output bf16 taint of a captured jaxpr (bf16 invars AND
    bf16 closed-over consts seed the walk)."""
    closed = cap.closed
    in_taint = [_is_bf16_var(v) for v in closed.jaxpr.invars]
    return _taint_jaxpr(closed.jaxpr, in_taint, closed.consts)


# which flat outputs of each bf16-bearing engine jit must stay UNTAINTED.
# Specs are functions of the output pytree (from make_jaxpr(...,
# return_shape=True)) returning a same-structure pytree of bools — True
# means "this output is part of the bound/pruning phase and must not
# depend on the bf16 corpus".
def _mask(tree, flag: bool):
    import jax

    return jax.tree_util.tree_map(lambda _: flag, tree)


def _spec_query_bf16(out):
    hit, alive, tile_mask, rtiles, band = out
    return (
        _mask(hit, False), _mask(alive, True), _mask(tile_mask, True),
        _mask(rtiles, False), _mask(band, False),
    )


def _spec_knn_round_bf16(out):
    cand_idx, cand_dist, kth, done, alive, tile_mask, rtiles, band = out
    return (
        _mask(cand_idx, False), _mask(cand_dist, False),
        _mask(kth, False), _mask(done, False), _mask(alive, True),
        _mask(tile_mask, True), _mask(rtiles, False), _mask(band, False),
    )


def _spec_forest_walk(out):
    # obs (exclusion attribution + frontier occupancy) is derived from the
    # fp32 bound phase only, so it must stay untainted — instrumenting the
    # walker STRENGTHENED this audit rather than weakening it
    ref_hits, leaf_hit, counts, band, rtiles, obs = out
    return (
        _mask(ref_hits, True), _mask(leaf_hit, False),
        _mask(counts, True), _mask(band, False), _mask(rtiles, False),
        _mask(obs, True),
    )


def _spec_monotone_walk(out):
    root_hit, p2_hits, leaf_hit, counts, band, rtiles, obs = out
    return (
        _mask(root_hit, True), _mask(p2_hits, True),
        _mask(leaf_hit, False), _mask(counts, True), _mask(band, False),
        _mask(rtiles, False), _mask(obs, True),
    )


_UNTAINTED_SPECS: dict[str, Callable] = {
    "_query_batched_bf16_jit": _spec_query_bf16,
    "_knn_round_bf16_jit": _spec_knn_round_bf16,
    "_forest_walk_jit": _spec_forest_walk,
    "_monotone_walk_jit": _spec_monotone_walk,
}


def _check_taint(cap: _Capture) -> list[str]:
    import jax

    spec_fn = _UNTAINTED_SPECS.get(cap.fn)
    if spec_fn is None or not _has_bf16(cap):
        return []
    must_be_clean, _ = jax.tree_util.tree_flatten(spec_fn(cap.out_shape))
    taint = _output_taint(cap)
    if len(taint) != len(must_be_clean):  # pragma: no cover - spec bug
        return [
            f"output arity mismatch: {len(taint)} outvars vs "
            f"{len(must_be_clean)} spec entries"
        ]
    problems = []
    for i, (clean, t) in enumerate(zip(must_be_clean, taint)):
        if clean and t:
            problems.append(
                f"bound-phase output #{i} is tainted by the bf16 corpus "
                "(pruning must be precision-independent)"
            )
    return problems


# ---------------------------------------------------------------------------
# matrix driver
# ---------------------------------------------------------------------------


def _synth(metric: str, n: int, dim: int, seed: int):
    """Tiny CLUSTERED corpus+queries (isotropic gaussians defeat the
    planar bounds entirely, so the adaptive path would never go sparse);
    simplex-normalised for the probability-space metrics."""
    import numpy as np

    rng = np.random.default_rng(seed)
    n_clusters = 16
    centers = rng.normal(size=(n_clusters, dim)) * 8.0
    lab = np.repeat(np.arange(n_clusters), -(-n // n_clusters))[:n]
    db = (centers[lab] + rng.normal(size=(n, dim)) * 0.15).astype(
        np.float32
    )
    q = (centers[:8] + rng.normal(size=(8, dim)) * 0.15).astype(np.float32)
    if metric in ("jsd", "triangular"):
        db = np.abs(db) + 0.05
        db /= db.sum(axis=1, keepdims=True)
        q = np.abs(q) + 0.05
        q /= q.sum(axis=1, keepdims=True)
    return db, q


def _range_radii(metric: str, db, q) -> tuple[float, float]:
    """(narrow, wide) radii from the oracle distance distribution: narrow
    leaves a thin alive set (the adaptive jnp path goes cell-gather),
    wide floods it (dense) — both exact-phase realisations trace."""
    import numpy as np

    from repro.core.npdist import pairwise_np

    d = pairwise_np(metric, q, db)
    return float(np.quantile(d, 0.02)), float(np.quantile(d, 0.6))


def _audit_captures(rec: _Recorder, cell: str, bf16: bool) -> list[
    AuditProblem
]:
    problems: list[AuditProblem] = []
    caps = rec.for_cell(cell)
    if not caps:
        problems.append(
            AuditProblem(cell, "-", "coverage", "no jaxpr captured")
        )
    any_bf16 = False
    for cap in caps:
        for d in _check_no_f64(cap):
            problems.append(AuditProblem(cell, cap.fn, "f64", d))
        for d in _check_no_callbacks(cap):
            problems.append(AuditProblem(cell, cap.fn, "callback", d))
        has16 = _has_bf16(cap)
        any_bf16 = any_bf16 or has16
        if has16 and not bf16:
            problems.append(
                AuditProblem(
                    cell, cap.fn, "bf16-present",
                    "bfloat16 in a fp32-precision cell",
                )
            )
        for d in _check_taint(cap):
            problems.append(AuditProblem(cell, cap.fn, "taint", d))
    if bf16 and caps and not any_bf16:
        problems.append(
            AuditProblem(
                cell, "-", "bf16-absent",
                "precision=bf16 but no bfloat16 in any captured jaxpr",
            )
        )
    return problems


def run_audit(
    full: bool = False, log: Callable[[str], None] | None = None
) -> list[AuditProblem]:
    """Drive the engine matrix and check every captured jaxpr.

    ``full=False`` (the default / self-check mode) audits the l2 column
    of the matrix — every entry point, backend, realisation and precision
    still fires.  ``full=True`` (CI) runs all four supermetrics."""
    import numpy as np

    from repro.core import flat_index, lrt, tree
    from repro.forest import encode_monotone, encode_tree
    from repro.forest.walk import forest_range_search, monotone_range_search

    log = log or (lambda s: None)
    metrics = AUDIT_METRICS if full else ("l2",)
    rec = _Recorder()
    problems: list[AuditProblem] = []

    with _patched_engines(rec):
        for metric in metrics:
            db, q = _synth(metric, 512, 8, seed=3)
            t_narrow, t_wide = _range_radii(metric, db, q)
            idx = flat_index.build_bss(
                metric, db, n_pivots=6, n_pairs=8, block=32, seed=5
            )
            # backend x realisation legs as EngineOpts — the audit drives
            # the engines through the SAME frozen-options surface the
            # serving stack uses; the adaptive jnp path is run at both a
            # pruning and a flooding radius so BOTH its exact-phase
            # realisations (cell-gather and dense) trace.
            legs = [
                EngineOpts(backend="jnp", realisation="adaptive"),
                EngineOpts(backend="jnp", realisation="dense"),
                EngineOpts(backend="pallas", realisation="dense",
                           interpret=True),
            ]
            for leg in legs:
                for precision in ("fp32", "bf16"):
                    opts = dataclasses.replace(leg, precision=precision)
                    cell = (
                        f"bss/{metric}/{opts.backend}-{opts.realisation}"
                        f"/{precision}"
                    )
                    rec.cell = cell
                    log(f"audit {cell}")
                    for t in (t_narrow, t_wide):
                        flat_index.bss_query_batched(idx, q, t, opts=opts)
                    flat_index.bss_knn_batched(
                        idx, q, 3, r0=t_narrow, opts=opts,
                    )
                    problems += _audit_captures(
                        rec, cell, bf16=precision == "bf16"
                    )

            # sharded engine (1-device mesh: shard_map traces the same
            # collective program as the real pod, minus cross-chip hops)
            import jax
            from jax.sharding import Mesh

            from repro.parallel import shard_index

            mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
            sidx = shard_index.shard_bss(idx, mesh)
            for precision in ("fp32", "bf16"):
                opts = EngineOpts(backend="jnp", precision=precision)
                cell = f"sharded/{metric}/jnp/{precision}"
                rec.cell = cell
                log(f"audit {cell}")
                shard_index.sharded_query_batched(
                    sidx, q, t_narrow, opts=opts,
                )
                shard_index.sharded_knn_batched(sidx, q, 3, opts=opts)
                problems += _audit_captures(
                    rec, cell, bf16=precision == "bf16"
                )

            # forest + monotone walkers
            tr = tree.build_tree("hpt_random_fixed", metric, db, seed=7)
            enc = encode_tree(tr)
            mtr = lrt.build_monotone_tree("closer", "far", metric, db, seed=7)
            menc = encode_monotone(mtr)
            for backend, interpret in (("jnp", None), ("pallas", True)):
                for precision in ("fp32", "bf16"):
                    opts = EngineOpts(
                        backend=backend, interpret=interpret,
                        precision=precision,
                    )
                    cell = f"forest/{metric}/{backend}/{precision}"
                    rec.cell = cell
                    log(f"audit {cell}")
                    forest_range_search(enc, q, t_narrow, opts=opts)
                    monotone_range_search(menc, q, t_narrow, opts=opts)
                    problems += _audit_captures(
                        rec, cell, bf16=precision == "bf16"
                    )

    return problems


# ---------------------------------------------------------------------------
# compile-cache audit (PR 5's recompile bound, as an equality)
# ---------------------------------------------------------------------------


def audit_compile_cache(
    sizes=tuple(range(1, 11)), buckets=(4, 8)
) -> tuple[list[AuditProblem], dict]:
    """Replay a mixed-size range+knn stream through ``ServingFront`` and
    assert each engine jit's distinct-lowering growth EQUALS the ladder
    prediction: one lowering per bucket the stream touches, per entry
    point.  Returns (problems, info); info["skipped"] is True when this
    jax exposes no jit cache hook (growth then unobservable)."""
    import numpy as np

    from repro.core import flat_index
    from repro.core.backends import bucket_for, jit_cache_size
    from repro.serve.front import ServingFront

    db, q = _synth("l2", 320, 8, seed=11)
    idx = flat_index.build_bss("l2", db, n_pivots=6, n_pairs=8, block=64,
                               seed=13)
    fns = {
        "range/lb": flat_index._lower_bounds_jit,
        "range/dense": flat_index._dense_hit_mask_jit,
        "knn/lb": flat_index._knn_lb_jit,
        "knn/round": flat_index._knn_round_jit,
    }
    before = {name: jit_cache_size(fn) for name, fn in fns.items()}
    info: dict = {"buckets": list(buckets), "sizes": list(sizes)}
    if any(v < 0 for v in before.values()):
        info["skipped"] = True
        return [], info
    info["skipped"] = False

    # buckets the stream touches; waves larger than the top bucket are
    # split by the front into top-bucket chunks plus a remainder
    touched: set[int] = set()
    for n in sizes:
        while n > 0:
            chunk = min(n, buckets[-1])
            touched.add(bucket_for(chunk, buckets))
            n -= chunk
    predicted = len(touched)
    info["predicted_lowerings"] = predicted

    qbig = np.concatenate([q] * ((max(sizes) // len(q)) + 1))
    with ServingFront(idx, buckets=buckets, max_delay_s=0.02,
                      backend="jnp") as front:
        for n in sizes:
            futs = [
                front.submit(qv, "range", t=0.5 + 0.01 * i)
                for i, qv in enumerate(qbig[:n])
            ]
            futs += [front.submit(qv, "knn", k=3) for qv in qbig[:n]]
            for f in futs:
                f.result(timeout=60)

    problems: list[AuditProblem] = []
    growth = {}
    for name, fn in fns.items():
        grew = jit_cache_size(fn) - before[name]
        growth[name] = grew
        if grew != predicted:
            problems.append(
                AuditProblem(
                    "serving/compile-cache", name, "lowerings",
                    f"{grew} distinct lowerings, ladder predicts "
                    f"{predicted} (buckets {buckets}, sizes "
                    f"{min(sizes)}..{max(sizes)})",
                )
            )
    info["growth"] = growth
    return problems, info

"""``python -m repro.analysis`` — run the lint and/or the jaxpr audit,
print diagnostics, exit non-zero on any violation.

Modes:

* default: AST lint + the smoke audit column (l2 across every entry
  point / backend / realisation / precision + the compile-cache replay)
  — fast enough for the pre-push habit and the self-check test;
* ``--ci``: lint + the FULL {metric x backend x realisation x precision}
  matrix, writing the machine-readable report to ``--json`` (default
  ``ANALYSIS_report.json``) for the CI artifact;
* ``--lint-only``: just the AST layer (milliseconds, no jax import).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.lint import lint_repo
from repro.analysis.rules import RULES, load_allowlist


def _find_root(start: Path) -> Path:
    """Nearest ancestor holding the linted tree (src/repro)."""
    p = start.resolve()
    for cand in (p, *p.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    raise SystemExit(f"no src/repro found above {start}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jit/precision invariant checker (AST lint + jaxpr "
        "audit)",
    )
    ap.add_argument("--ci", action="store_true",
                    help="full audit matrix + JSON report (the CI gate)")
    ap.add_argument("--lint-only", action="store_true",
                    help="AST lint only (no jax import)")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: auto-detect from cwd)")
    ap.add_argument("--json", type=Path, default=None,
                    help="write the JSON report here (default "
                    "ANALYSIS_report.json under --ci)")
    args = ap.parse_args(argv)

    root = args.root or _find_root(Path.cwd())
    report: dict = {"root": str(root), "rules": {
        rid: r.summary for rid, r in RULES.items()
    }}

    violations = lint_repo(root, load_allowlist())
    report["lint"] = [v.as_dict() for v in violations]
    for v in violations:
        print(v.format())
    print(f"lint: {len(violations)} violation(s)")

    audit_problems = []
    if not args.lint_only:
        from repro.analysis.jaxpr_audit import audit_compile_cache, run_audit

        def log(msg: str) -> None:
            print(f"  {msg}", flush=True)

        audit_problems = run_audit(full=args.ci, log=log)
        cache_problems, cache_info = audit_compile_cache()
        audit_problems += cache_problems
        report["jaxpr_audit"] = [p.__dict__ for p in audit_problems]
        report["compile_cache"] = cache_info
        for p in audit_problems:
            print(p.format())
        print(
            f"jaxpr audit ({'full' if args.ci else 'smoke'}): "
            f"{len(audit_problems)} problem(s); compile-cache "
            f"{'skipped (no cache hook)' if cache_info.get('skipped') else cache_info.get('growth')}"
        )

    json_path = args.json or (
        root / "ANALYSIS_report.json" if args.ci else None
    )
    if json_path is not None:
        report["ok"] = not violations and not audit_problems
        json_path.write_text(json.dumps(report, indent=2, sort_keys=True))
        print(f"report: {json_path}")

    return 1 if (violations or audit_problems) else 0


if __name__ == "__main__":
    sys.exit(main())

"""Layer 1: AST lint over ``src/``, ``benchmarks/``, ``examples/``,
``tests/`` enforcing the repo's jit/precision/timing invariants (rule ids
and rationale in :mod:`repro.analysis.rules`).

The interesting rule is R2 (host-sync-in-jit): it builds a call graph of
the ``repro`` package — roots are every function wrapped by ``jax.jit`` /
``shard_map`` (as a decorator, a ``functools.partial(jax.jit, ...)``
decorator, or a direct ``jax.jit(f)`` / ``jax.jit(shard_map(f, ...))``
call, including nested defs like the sharded engine's ``local`` closures)
— and flags host-sync primitives (``np.*`` calls, ``.item()``,
``float()``/``int()`` on non-constant operands) in any function reachable
from a root.  Edges resolve same-module calls, ``from repro.x import f``
names, ``repro.x.f`` module-alias attribute calls, and module-level
aliasing TRANSITIVELY: chained ``a = f; b = a`` assignments, re-exported
``from repro.x import f`` names followed into their defining module, and
attribute-chained re-exports (``use = helper.np_user``) — all bounded by a
resolution depth and a cycle guard, so pathological alias graphs cannot
hang the lint.

Everything is pure ``ast`` — no imports of the linted code, so the lint
runs in milliseconds and never pays (or is confused by) jax import
side effects.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path

from repro.analysis.rules import (
    Allowlist,
    Violation,
    load_allowlist,
    parse_disables,
)

__all__ = ["lint_repo", "lint_paths", "LINT_DIRS"]

LINT_DIRS = ("src", "benchmarks", "examples", "tests")

# parameter / keyword names that carry kernel tile shapes (R4)
_TILE_PARAMS = {"bm", "bn", "bq", "bb", "block", "kchunk", "k_chunk"}
# module-level constant names that carry tile shapes (R4)
_TILE_CONST_RE = re.compile(r"^(_?K_CHUNK|TILE_|DEFAULT_B)")
# builtins whose call on a traced array forces a host sync (R2)
_SYNC_BUILTINS = {"float", "int", "bool"}


def _attr_chain(node: ast.AST) -> str | None:
    """``a.b.c`` -> ``"a.b.c"``; None for anything not a pure Name/Attribute
    chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _FileInfo:
    """Per-file AST plus the import/alias tables the rules resolve against."""

    def __init__(self, path: Path, relpath: str, source: str):
        self.path = path
        self.relpath = relpath
        self.source = source
        self.tree = ast.parse(source, filename=str(path))
        self.disables = parse_disables(source)
        # alias -> full module name ("np" -> "numpy", "jax" -> "jax")
        self.module_aliases: dict[str, str] = {}
        # local name -> (module, original name) for `from m import x [as y]`
        self.imports_from: dict[str, tuple[str, str]] = {}
        # module-level `alias = other_name`
        self.assigns: dict[str, str] = {}
        # module-level `alias = a.b.c` (attribute-chained re-export)
        self.attr_assigns: dict[str, str] = {}
        # every def in the file (module-level AND nested), by name
        self.functions: dict[str, list[ast.FunctionDef]] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.module_aliases[a.asname or a.name.split(".")[0]] = (
                        a.name
                    )
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.imports_from[a.asname or a.name] = (
                        node.module,
                        a.name,
                    )
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions.setdefault(node.name, []).append(node)
        for stmt in self.tree.body:
            if (
                isinstance(stmt, ast.Assign)
                and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)
            ):
                if isinstance(stmt.value, ast.Name):
                    self.assigns[stmt.targets[0].id] = stmt.value.id
                elif isinstance(stmt.value, ast.Attribute):
                    chain = _attr_chain(stmt.value)
                    if chain is not None:
                        self.attr_assigns[stmt.targets[0].id] = chain

    # -- resolution helpers -------------------------------------------------

    def resolve_assign(self, name: str) -> str:
        """Follow module-level ``a = b`` chains to their terminal name
        (cycle-guarded; a self-referential chain returns where it stopped)."""
        seen = {name}
        while name in self.assigns:
            nxt = self.assigns[name]
            if nxt in seen:
                break
            seen.add(nxt)
            name = nxt
        return name

    def resolves_to(self, node: ast.AST, module: str, name: str) -> bool:
        """Does ``node`` reference ``module.name`` in this file's namespace?"""
        chain = _attr_chain(node)
        if chain is not None and "." in chain:
            head, _, rest = chain.partition(".")
            full = self.module_aliases.get(head)
            if full is not None and f"{full}.{rest}" == f"{module}.{name}":
                return True
            # `from jax import numpy as jnp` style: imports_from maps the
            # head to (module, orig)
            imp = self.imports_from.get(head)
            if imp is not None and (f"{imp[0]}.{imp[1]}.{rest}").endswith(
                f"{module}.{name}"
            ):
                return True
            return False
        if isinstance(node, ast.Name):
            imp = self.imports_from.get(node.id)
            return imp is not None and imp == (module, name)
        return False

    def is_jit_ref(self, node: ast.AST) -> bool:
        return self.resolves_to(node, "jax", "jit")

    def is_shard_map_ref(self, node: ast.AST) -> bool:
        chain = _attr_chain(node)
        if chain is not None and chain.split(".")[-1] == "shard_map":
            return True
        imp = self.imports_from.get(chain) if chain else None
        return imp is not None and imp[1] == "shard_map"

    def is_partial_ref(self, node: ast.AST) -> bool:
        return self.resolves_to(node, "functools", "partial")

    def numpy_aliases(self) -> set[str]:
        return {a for a, m in self.module_aliases.items() if m == "numpy"}

    def numpy_names(self) -> set[str]:
        """Names bound by ``from numpy import x [as y]``."""
        return {
            a for a, (m, _) in self.imports_from.items() if m == "numpy"
        }

    def is_time_time(self, node: ast.AST) -> bool:
        """A reference to stdlib ``time.time``."""
        if self.resolves_to(node, "time", "time"):
            return True
        chain = _attr_chain(node)
        if chain is None:
            return False
        head, _, rest = chain.partition(".")
        return rest == "time" and self.module_aliases.get(head) == "time"


def _iter_py(root: Path, dirs=LINT_DIRS):
    for d in dirs:
        base = root / d
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            if "__pycache__" in p.parts:
                continue
            yield p


class _Linter:
    def __init__(self, root: Path, allowlist: Allowlist):
        self.root = root
        self.allowlist = allowlist
        self.violations: list[Violation] = []
        self.files: dict[str, _FileInfo] = {}

    def load(self, dirs=LINT_DIRS) -> None:
        for p in _iter_py(self.root, dirs):
            rel = p.relative_to(self.root).as_posix()
            try:
                self.files[rel] = _FileInfo(p, rel, p.read_text())
            except SyntaxError as e:  # pragma: no cover - repo parses
                self.emit("R1", rel, e.lineno or 1, 0, f"syntax error: {e}")

    def emit(self, rule: str, relpath: str, line: int, col: int,
             message: str) -> None:
        if self.allowlist.allows(rule, relpath):
            return
        fi = self.files.get(relpath)
        if fi is not None:
            disabled = fi.disables.get(line, set())
            if rule in disabled or "all" in disabled:
                return
        self.violations.append(Violation(rule, relpath, line, col, message))

    # -- R1: wall-clock timing ---------------------------------------------

    def check_r1(self, fi: _FileInfo) -> None:
        for node in ast.walk(fi.tree):
            if isinstance(node, ast.Call) and fi.is_time_time(node.func):
                self.emit(
                    "R1", fi.relpath, node.lineno, node.col_offset,
                    "time.time() call; use the monotonic `now` from "
                    "repro.serve.queue",
                )

    # -- R3: float64 leaks --------------------------------------------------

    def check_r3(self, fi: _FileInfo) -> None:
        for node in ast.walk(fi.tree):
            if isinstance(node, ast.Attribute) and node.attr in (
                "float64", "enable_x64",
            ):
                self.emit(
                    "R3", fi.relpath, node.lineno, node.col_offset,
                    f"reference to {node.attr} (engines are fp32/bf16 "
                    "by contract)",
                )
            elif isinstance(node, ast.Name) and node.id == "float64":
                self.emit(
                    "R3", fi.relpath, node.lineno, node.col_offset,
                    "reference to float64 (engines are fp32/bf16 by "
                    "contract)",
                )
            elif isinstance(node, ast.Call):
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    if isinstance(arg, ast.Constant) and arg.value in (
                        "float64", "jax_enable_x64",
                    ):
                        self.emit(
                            "R3", fi.relpath, arg.lineno, arg.col_offset,
                            f"dtype/flag string {arg.value!r} passed to a "
                            "call",
                        )

    # -- R4: raw tile literals in kernels/ -----------------------------------

    def check_r4(self, fi: _FileInfo) -> None:
        if not fi.relpath.startswith("src/repro/kernels/"):
            return
        if fi.relpath.endswith("/tiles.py"):
            return
        for node in ast.walk(fi.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                a = node.args
                pairs = list(
                    zip(a.args[len(a.args) - len(a.defaults):], a.defaults)
                ) + [
                    (arg, d)
                    for arg, d in zip(a.kwonlyargs, a.kw_defaults)
                    if d is not None
                ]
                for arg, default in pairs:
                    if arg.arg.lower() in _TILE_PARAMS and isinstance(
                        default, ast.Constant
                    ) and isinstance(default.value, int):
                        self.emit(
                            "R4", fi.relpath, default.lineno,
                            default.col_offset,
                            f"tile parameter {arg.arg!r} defaults to raw "
                            f"literal {default.value}; use repro.kernels."
                            "tiles",
                        )
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg and kw.arg.lower() in _TILE_PARAMS and (
                        isinstance(kw.value, ast.Constant)
                        and isinstance(kw.value.value, int)
                    ):
                        self.emit(
                            "R4", fi.relpath, kw.value.lineno,
                            kw.value.col_offset,
                            f"tile keyword {kw.arg}={kw.value.value} is a "
                            "raw literal; use repro.kernels.tiles",
                        )
        for stmt in fi.tree.body:
            if (
                isinstance(stmt, ast.Assign)
                and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)
                and _TILE_CONST_RE.match(stmt.targets[0].id)
                and isinstance(stmt.value, ast.Constant)
                and isinstance(stmt.value.value, int)
            ):
                self.emit(
                    "R4", fi.relpath, stmt.lineno, stmt.col_offset,
                    f"tile constant {stmt.targets[0].id} bound to raw "
                    f"literal {stmt.value.value}; import from repro."
                    "kernels.tiles",
                )

    # -- R5: assert-as-validation in library code ----------------------------

    def check_r5(self, fi: _FileInfo) -> None:
        if not fi.relpath.startswith("src/"):
            return
        for node in ast.walk(fi.tree):
            if isinstance(node, ast.Assert):
                self.emit(
                    "R5", fi.relpath, node.lineno, node.col_offset,
                    "assert in library code is stripped under -O; raise "
                    "ValueError/TypeError",
                )

    # -- R6: unregistered runtime metric names -------------------------------

    _SCHEMA_RELPATH = "src/repro/obs/schema.py"
    _METRIC_METHODS = ("counter", "gauge", "histogram")

    def _metric_names(self):
        """The schema's METRIC_NAMES set, read from the AST of
        ``src/repro/obs/schema.py`` (never imported — the lint stays
        import-free).  ``None`` when the file or the literal is absent,
        which disables R6 (fixture repos without a schema lint clean)."""
        if not hasattr(self, "_metric_names_cache"):
            names = None
            fi = self.files.get(self._SCHEMA_RELPATH)
            if fi is not None:
                for stmt in fi.tree.body:
                    if (
                        isinstance(stmt, ast.Assign)
                        and len(stmt.targets) == 1
                        and isinstance(stmt.targets[0], ast.Name)
                        and stmt.targets[0].id == "METRIC_NAMES"
                    ):
                        try:
                            names = frozenset(ast.literal_eval(stmt.value))
                        except (ValueError, TypeError):
                            names = None
            self._metric_names_cache = names
        return self._metric_names_cache

    def check_r6(self, fi: _FileInfo) -> None:
        if not fi.relpath.startswith("src/"):
            return
        if fi.relpath == self._SCHEMA_RELPATH:
            return
        names = self._metric_names()
        if names is None:
            return
        for node in ast.walk(fi.tree):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in self._METRIC_METHODS
                and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)
            ):
                continue
            name = node.args[0].value
            if name not in names:
                self.emit(
                    "R6", fi.relpath, node.lineno, node.col_offset,
                    f"metric name {name!r} is not listed in "
                    "repro.obs.schema.METRIC_NAMES; register it there",
                )

    # -- R2: host sync inside jit-reachable functions ------------------------

    def _resolve_callable(self, mods: dict, mod: str, fi: _FileInfo,
                          name: str, depth: int = 8) -> list:
        """Resolve a bare ``name`` in ``fi``'s module namespace to every
        function def it can denote — ``[(modname, ast.FunctionDef), ...]``.

        Follows, transitively up to ``depth`` hops: module-level ``a = b``
        chains (``resolve_assign``), ``from repro.x import f`` re-exports
        into their defining module, and attribute-chained re-exports
        (``use = helper.np_user`` where ``helper`` is an imported module).
        Closes the old one-hop gap where ``b = a; jax.jit(b)`` with
        ``a = np_user`` escaped the call graph."""
        if depth <= 0:
            return []
        name = fi.resolve_assign(name)
        if name in fi.functions:
            return [(mod, fdef) for fdef in fi.functions[name]]
        if name in fi.imports_from:
            m, orig = fi.imports_from[name]
            tfi = mods.get(m)
            if tfi is not None:
                return self._resolve_callable(mods, m, tfi, orig, depth - 1)
            return []
        chain = fi.attr_assigns.get(name)
        if chain is not None:
            head, _, rest = chain.partition(".")
            head = fi.resolve_assign(head)
            base = fi.module_aliases.get(head)
            if base is None:
                imp = fi.imports_from.get(head)
                if imp is not None:
                    base = f"{imp[0]}.{imp[1]}"
            if base is not None and rest:
                parts = rest.split(".")
                m = ".".join([base] + parts[:-1])
                tfi = mods.get(m)
                if tfi is not None:
                    return self._resolve_callable(
                        mods, m, tfi, parts[-1], depth - 1
                    )
        return []

    def _src_modname(self, relpath: str) -> str | None:
        if not relpath.startswith("src/") or not relpath.endswith(".py"):
            return None
        mod = relpath[len("src/"):-len(".py")].replace("/", ".")
        if mod.endswith(".__init__"):
            mod = mod[: -len(".__init__")]
        return mod

    def check_r2(self) -> None:
        # module name -> file info, for the src/ package only
        mods: dict[str, _FileInfo] = {}
        for rel, fi in self.files.items():
            mod = self._src_modname(rel)
            if mod is not None:
                mods[mod] = fi

        Node = tuple  # (modname, ast.FunctionDef)
        roots: list[Node] = []

        def add_root_callable(fi: _FileInfo, mod: str, node: ast.AST) -> None:
            """args[0] of a jax.jit(...)/shard_map(...) call."""
            if isinstance(node, ast.Name):
                roots.extend(self._resolve_callable(mods, mod, fi, node.id))
            elif isinstance(node, ast.Call):
                # jax.jit(shard_map(local, ...)) and friends
                if node.args:
                    add_root_callable(fi, mod, node.args[0])
            elif isinstance(node, ast.Lambda):
                roots.append((mod, node))

        for mod, fi in mods.items():
            for node in ast.walk(fi.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    for dec in node.decorator_list:
                        if fi.is_jit_ref(dec) or fi.is_shard_map_ref(dec):
                            roots.append((mod, node))
                        elif isinstance(dec, ast.Call):
                            if fi.is_jit_ref(dec.func) or fi.is_shard_map_ref(
                                dec.func
                            ):
                                roots.append((mod, node))
                            elif (
                                fi.is_partial_ref(dec.func)
                                and dec.args
                                and (
                                    fi.is_jit_ref(dec.args[0])
                                    or fi.is_shard_map_ref(dec.args[0])
                                )
                            ):
                                roots.append((mod, node))
                elif isinstance(node, ast.Call) and (
                    fi.is_jit_ref(node.func) or fi.is_shard_map_ref(node.func)
                ):
                    if node.args:
                        add_root_callable(fi, mod, node.args[0])

        # BFS over the package call graph
        seen: set[tuple[str, int]] = set()
        work = list(roots)
        reachable: list[Node] = []
        while work:
            mod, fdef = work.pop()
            key = (mod, id(fdef))
            if key in seen:
                continue
            seen.add(key)
            reachable.append((mod, fdef))
            fi = mods[mod]
            for node in ast.walk(fdef):
                if not isinstance(node, ast.Call):
                    continue
                f = node.func
                if isinstance(f, ast.Name):
                    work.extend(self._resolve_callable(mods, mod, fi, f.id))
                elif isinstance(f, ast.Attribute) and isinstance(
                    f.value, ast.Name
                ):
                    base = fi.resolve_assign(f.value.id)
                    m = fi.module_aliases.get(base)
                    if m is None:
                        imp = fi.imports_from.get(base)
                        if imp is not None:
                            m = f"{imp[0]}.{imp[1]}"
                    tfi = mods.get(m) if m else None
                    if tfi is not None:
                        work.extend(
                            self._resolve_callable(mods, m, tfi, f.attr)
                        )

        # scan every reachable function body for host-sync primitives
        flagged: set[tuple[str, int, str]] = set()
        for mod, fdef in reachable:
            fi = mods[mod]
            np_aliases = fi.numpy_aliases()
            np_names = fi.numpy_names()
            fname = getattr(fdef, "name", "<lambda>")
            for node in ast.walk(fdef):
                if not isinstance(node, ast.Call):
                    continue
                f = node.func
                msg = None
                if (
                    isinstance(f, ast.Attribute)
                    and isinstance(f.value, ast.Name)
                    and f.value.id in np_aliases
                ):
                    msg = (
                        f"numpy call {f.value.id}.{f.attr}() in "
                        f"jit-reachable function {fname!r} (host sync / "
                        "trace break)"
                    )
                elif isinstance(f, ast.Attribute) and f.attr == "item":
                    msg = (
                        f".item() in jit-reachable function {fname!r} "
                        "(forces a device sync)"
                    )
                elif isinstance(f, ast.Name) and f.id in np_names:
                    msg = (
                        f"numpy call {f.id}() in jit-reachable function "
                        f"{fname!r} (host sync / trace break)"
                    )
                elif (
                    isinstance(f, ast.Name)
                    and f.id in _SYNC_BUILTINS
                    and node.args
                    and not all(
                        isinstance(a, ast.Constant) for a in node.args
                    )
                ):
                    msg = (
                        f"{f.id}() on a non-constant operand in "
                        f"jit-reachable function {fname!r} (host sync on "
                        "traced values)"
                    )
                if msg is not None:
                    key = (fi.relpath, node.lineno, msg)
                    if key not in flagged:
                        flagged.add(key)
                        self.emit(
                            "R2", fi.relpath, node.lineno,
                            node.col_offset, msg,
                        )


def lint_repo(
    root: Path, allowlist: Allowlist | None = None, dirs=LINT_DIRS
) -> list[Violation]:
    """Run every rule over ``dirs`` under ``root``; returns sorted
    violations."""
    if allowlist is None:
        allowlist = load_allowlist()
    linter = _Linter(root, allowlist)
    linter.load(dirs)
    for fi in linter.files.values():
        linter.check_r1(fi)
        linter.check_r3(fi)
        linter.check_r4(fi)
        linter.check_r5(fi)
        linter.check_r6(fi)
    linter.check_r2()
    return sorted(
        linter.violations, key=lambda v: (v.path, v.line, v.col, v.rule)
    )


def lint_paths(
    root: Path, relpaths: list[str], allowlist: Allowlist | None = None
) -> list[Violation]:
    """Lint specific files (repo-relative) — the unit the fixture tests
    drive.  R2's call graph still spans all of ``src/`` so reachability is
    computed against the real package."""
    all_v = lint_repo(root, allowlist)
    keep = set(relpaths)
    return [v for v in all_v if v.path in keep]

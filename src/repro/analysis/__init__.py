"""Static-analysis layer enforcing the repo's jit/precision/timing
invariants: an AST lint (:mod:`repro.analysis.lint`, rules in
:mod:`repro.analysis.rules`) and a jaxpr audit of every public engine
entry point (:mod:`repro.analysis.jaxpr_audit`).  ``python -m
repro.analysis`` runs both and exits non-zero on violations; CI gates on
``--ci`` (full matrix + JSON report).

This package intentionally does NOT import jax at package level — the
lint layer must stay usable (and fast) without touching the engines; the
audit imports jax lazily.
"""

from repro.analysis.rules import RULES, Violation  # noqa: F401

__all__ = ["RULES", "Violation"]

"""Rule registry, diagnostics, and suppression machinery for the repo lint.

Each rule protects a load-bearing invariant that earlier PRs established
but nothing previously enforced (see ROADMAP "Invariants & static
analysis").  A rule fires as a :class:`Violation` carrying the rule id,
repo-relative path, and 1-based line/column — the unit every consumer
(CLI text output, the JSON report, the test fixtures) works in.

Two suppression channels, both reviewable in-repo:

* inline ``# lint: disable=R3`` (comma-separated ids, or ``all``) on the
  offending line — for one-off intentional exceptions next to the code;
* ``allowlist.txt`` next to this module — ``<RULE> <glob>`` per line,
  fnmatch'd against repo-relative paths — for whole-file exemptions like
  the float64 numpy oracles.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import re
from pathlib import Path

__all__ = [
    "Rule",
    "RULES",
    "Violation",
    "Allowlist",
    "load_allowlist",
    "parse_disables",
]


@dataclasses.dataclass(frozen=True)
class Rule:
    """One lint rule: stable id, short name, and what it protects."""

    id: str
    name: str
    summary: str


RULES: dict[str, Rule] = {
    r.id: r
    for r in (
        Rule(
            "R1",
            "wall-clock-timing",
            "time.time() for durations — use the shared monotonic `now` "
            "from repro.serve.queue (wall clock steps under NTP)",
        ),
        Rule(
            "R2",
            "host-sync-in-jit",
            "host-sync primitive (np.* call, .item(), float()/int() on "
            "arrays) inside a function reachable from jax.jit/shard_map — "
            "breaks tracing or forces a device sync",
        ),
        Rule(
            "R3",
            "float64-leak",
            "float64 / enable_x64 outside the allowlisted numpy oracles — "
            "the engines are fp32/bf16 by contract (PR 6 margin proof)",
        ),
        Rule(
            "R4",
            "raw-tile-literal",
            "raw tile-size literal in kernels/ — tile shapes must come "
            "from repro.kernels.tiles so REPRO_TILE_* overrides reach "
            "every kernel",
        ),
        Rule(
            "R5",
            "assert-validation",
            "bare `assert` used for input validation in library code — "
            "stripped under python -O; raise ValueError/TypeError",
        ),
        Rule(
            "R6",
            "unregistered-metric-name",
            "metric name registered at runtime (counter/gauge/histogram "
            "call) that is absent from repro.obs.schema.METRIC_NAMES — "
            "dashboards and the regression sentinel key on the schema "
            "namespace, so an unlisted name is a silent observability hole",
        ),
    )
}

# inline escape hatch: `# lint: disable=R1` / `disable=R1,R5` / `disable=all`
_DISABLE_RE = re.compile(r"#\s*lint:\s*disable=([A-Za-z0-9,\s]+)")


def parse_disables(source: str) -> dict[int, set[str]]:
    """Map 1-based line number -> set of rule ids disabled on that line
    (the literal string ``"all"`` disables every rule)."""
    out: dict[int, set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _DISABLE_RE.search(line)
        if m:
            ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
            out[i] = ids
    return out


@dataclasses.dataclass(frozen=True)
class Violation:
    """One diagnostic: rule id + repo-relative location + message."""

    rule: str
    path: str  # repo-relative, posix separators
    line: int  # 1-based
    col: int  # 0-based (ast convention)
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class Allowlist:
    """``<RULE> <glob>`` entries fnmatch'd against repo-relative paths.

    Lines starting with ``#`` and blank lines are ignored; an inline
    ``# reason`` after the glob is stripped.  Unknown rule ids are an
    error at load time — a typo'd allowlist entry must not silently
    suppress nothing."""

    def __init__(self, entries: list[tuple[str, str]]):
        for rule, _ in entries:
            if rule not in RULES:
                raise ValueError(f"allowlist names unknown rule {rule!r}")
        self.entries = entries

    def allows(self, rule: str, relpath: str) -> bool:
        return any(
            r == rule and fnmatch.fnmatch(relpath, pat)
            for r, pat in self.entries
        )


def load_allowlist(path: Path | None = None) -> Allowlist:
    """Load the checked-in allowlist (``allowlist.txt`` beside this module
    by default)."""
    if path is None:
        path = Path(__file__).parent / "allowlist.txt"
    entries: list[tuple[str, str]] = []
    if path.exists():
        for raw in path.read_text().splitlines():
            line = raw.split("#", 1)[0].strip()
            if not line:
                continue
            parts = line.split(None, 1)
            if len(parts) != 2:
                raise ValueError(f"bad allowlist line: {raw!r}")
            entries.append((parts[0], parts[1].strip()))
    return Allowlist(entries)

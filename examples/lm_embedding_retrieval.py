"""LM-embedding retrieval: any of the five assigned LM architectures can
feed the supermetric index — embed token windows with the (reduced) LM's
final hidden state, index, and search exactly.

This is the §Arch-applicability story from DESIGN.md made concrete: the
paper's technique does not accelerate the transformer itself; it serves the
similarity structure the transformer PRODUCES.

    PYTHONPATH=src python examples/lm_embedding_retrieval.py --arch llama3.2-1b
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core import flat_index, tree
from repro.data.pipeline import TokenStream


def embed_windows(model, params, tokens):
    """Mean-pooled final hidden state per window (B, d_model)."""
    c = model.cfg
    x = params["embed"][tokens].astype(c.dtype)
    pos = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    lp_all, _ = model._layer_params(params)
    is_local = model._is_local_flags()

    def body(xc, scanned):
        lp, loc = scanned
        y, _, _ = model._block(xc, lp, loc, pos, pos)
        return y, None

    x, _ = jax.lax.scan(body, x, (lp_all, is_local))
    return np.asarray(x.mean(axis=1), np.float32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--windows", type=int, default=4096)
    args = ap.parse_args()

    bundle = get_arch(args.arch)
    assert bundle.family == "lm"
    model, cfg, _ = bundle.make_reduced()
    params = model.init_params(jax.random.PRNGKey(0))
    stream = TokenStream(vocab=cfg.vocab, batch=256, seq=32, seed=0)

    embs = []
    for _ in range(args.windows // 256):
        embs.append(embed_windows(model, params, jnp.asarray(stream.next()["tokens"][:, :-1])))
    corpus = np.concatenate(embs)
    queries, corpus = corpus[:64], corpus[64:]
    print(f"embedded {len(corpus)} windows with {args.arch} (reduced) "
          f"-> {corpus.shape[1]}-d")

    from repro.data.metricsets import calibrate_threshold

    t = calibrate_threshold("l2", corpus, 2e-3)
    idx = flat_index.build_bss("l2", corpus, n_pivots=12, n_pairs=16, block=128)
    hits, stats = flat_index.bss_query(idx, queries, t)
    truth = tree.exhaustive_search("l2", corpus, queries, t)
    exact = all(sorted(a) == sorted(b) for a, b in zip(hits, truth))
    print(f"range search t={t:.4f}: exact={exact}, "
          f"{stats['dists_per_query']:.0f} dists/query "
          f"({100 * stats['block_exclusion_rate']:.1f}% blocks pruned)")


if __name__ == "__main__":
    main()

"""Quickstart: supermetric search in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's best tree (hpt_fft_log) and the TPU-native Blocked
Supermetric Scan over a clustered dataset, runs the same range queries with
Hyperbolic vs Hilbert exclusion, and prints the paper's figure of merit.
"""

import os

# Sharded-serving demo (step 8): simulate a 4-device host mesh when running
# on a single-CPU machine.  Must precede the first jax import; a real
# accelerator platform ignores the host-platform flag (and XLA_FLAGS set by
# the environment wins).
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=4"
)

import numpy as np  # noqa: E402

from repro.core import flat_index, tree  # noqa: E402
from repro.core.backends import EngineOpts  # noqa: E402
from repro.data import metricsets  # noqa: E402

# 1. a clustered "real-world-like" metric space (colors surrogate)
data = metricsets.colors_surrogate(10_000, dim=64, seed=0)
db, queries = metricsets.split_queries(data, frac=0.05, seed=1, max_queries=100)
t = metricsets.calibrate_threshold("l2", db, selectivity=2e-4)
print(f"corpus={len(db)}  queries={len(queries)}  threshold t={t:.4f}")

# 2. the paper's winning structure, both exclusion mechanisms
tr = tree.build_tree("hpt_fft_log", "l2", db, seed=2)
for mech in ("hyperbolic", "hilbert"):
    results, counter = tree.range_search(tr, queries, t, mech)
    print(f"hpt_fft_log + {mech:10s}: {counter.mean:8.1f} distances/query")

# 3. exactness against brute force
truth = tree.exhaustive_search("l2", db, queries, t)
assert all(sorted(a) == sorted(b) for a, b in zip(results, truth))
print("exactness: verified against exhaustive search")

# 4. the TPU-native engine (MXU-tile-aligned block pruning): fused batched
#    path (one jitted pass) checked against its numpy oracle
idx = flat_index.build_bss("l2", db, n_pivots=16, n_pairs=24, block=128)
hits, stats = flat_index.bss_query_batched(idx, queries, t)
oracle_hits, _ = flat_index.bss_query(idx, queries, t)
assert hits == oracle_hits
assert all(sorted(a) == sorted(b) for a, b in zip(hits, truth))
print(
    f"BSS engine (fused): {stats['dists_per_query']:.0f} distances/query, "
    f"{100 * stats['block_exclusion_rate']:.1f}% of 128-point blocks pruned "
    f"(exact results, == numpy oracle)"
)

# 5. batched exact kNN on the same index (radius-deepening rounds)
knn_idx, knn_dist, kstats = flat_index.bss_knn_batched(idx, queries, k=5)
print(
    f"BSS kNN: top-5 for {len(queries)} queries in {kstats['rounds']} "
    f"jitted rounds, {kstats['dists_per_query']:.0f} distances/query"
)

# 6. the same engine under the OTHER supermetrics (paper §2.2): the colors
#    surrogate rows are probability vectors, valid for JSD / Triangular —
#    and cosine rides the l2 kernels on the unit sphere.
from repro.core.npdist import pairwise_np  # noqa: E402

for metric in ("cosine", "jsd", "triangular"):
    t_m = metricsets.calibrate_threshold(metric, db, selectivity=2e-4)
    idx_m = flat_index.build_bss(metric, db, n_pivots=16, n_pairs=24, block=128)
    hits_m, stats_m = flat_index.bss_query_batched(idx_m, queries, t_m)
    oracle_m, _ = flat_index.bss_query(idx_m, queries, t_m)
    # the float32 engine and float64 oracle may only disagree on points
    # whose distance is within float rounding of the raw quantile threshold
    for a, b, qv in zip(hits_m, oracle_m, queries):
        for j in set(a) ^ set(b):
            dj = float(pairwise_np(metric, qv, db[j])[0, 0])
            assert abs(dj - t_m) <= 1e-5 * t_m, (metric, j, dj, t_m)
    print(
        f"BSS engine [{metric:10s}]: {stats_m['dists_per_query']:.0f} "
        f"distances/query (exact, == numpy oracle)"
    )

# 7. the device forest: array-encode the tree from step 2 and run the SAME
#    range search as a single jitted batched walk (frontier-per-level) —
#    identical result sets AND identical per-query distance counts.
from repro.forest import encode_tree, forest_range_search  # noqa: E402

enc = encode_tree(tr)
f_hits, f_stats = forest_range_search(enc, queries, t, "hilbert")
assert all(sorted(a) == sorted(b) for a, b in zip(f_hits, results))
assert (f_stats["per_query_dists"] == counter.per_query).all()
print(
    f"device forest (hpt_fft_log): {f_stats['dists_per_query']:8.1f} "
    f"distances/query over {f_stats['n_levels']} jitted levels "
    f"(results AND per-query counts == host walk)"
)

# 8. sharded serving: partition the BSS corpus blocks over a ("data",)
#    device mesh — build_bss(mesh=...) bears the device arrays with their
#    NamedSharding, and the SAME fused engine then runs one shard-local
#    pass per device under shard_map (range: hit bitmasks concatenated in
#    corpus order; kNN: per-shard top-k merged by all-gather + global
#    top-k under a global shrinking radius).  Hits AND distance counts are
#    identical to the single-device engine of steps 4-5.
import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

mesh = Mesh(np.array(jax.devices()), ("data",))
idx_sh = flat_index.build_bss(
    "l2", db, n_pivots=16, n_pairs=24, block=128, mesh=mesh
)
sh_hits, sh_stats = flat_index.bss_query_batched(idx_sh, queries, t)
assert sh_hits == hits  # identical to the single-device fused engine
sh_knn, sh_kd, sh_kstats = flat_index.bss_knn_batched(idx_sh, queries, k=5)
assert all(
    set(a.tolist()) == set(b.tolist()) for a, b in zip(sh_knn, knn_idx)
)
print(
    f"sharded BSS over {sh_stats['n_shards']} devices: "
    f"{sh_stats['dists_per_query']:.0f} distances/query — hits and counts "
    f"== single-device engine"
)

# 9. async serving: the engines above take pre-assembled batches, but live
#    traffic arrives one query at a time.  ServingFront assembles the
#    batches itself — submit() returns a Future immediately, a driver
#    thread collects requests under a deadline, pads each micro-batch to a
#    fixed bucket ladder (so jit recompiles are bounded by the ladder, not
#    the traffic), and dispatches through the SAME fused engines: results
#    are bit-identical to direct engine calls.  Range requests may each
#    carry their own threshold (served via per-query radii in one batch);
#    stats() snapshots queue wait / batch sizes / padding waste.
from repro.serve.front import ServingFront  # noqa: E402

with ServingFront(idx, max_delay_s=0.005) as front:
    futures = [front.submit(qv, "range", t=t * (1 + 0.2 * (i % 2)))
               for i, qv in enumerate(queries[:20])]
    futures += [front.submit(qv, "knn", k=5) for qv in queries[:10]]
    answers = [f.result(timeout=120) for f in futures]
assert answers[0].hits == hits[0]  # == the direct fused call of step 4
fstats = front.stats()
print(
    f"async front: {fstats['completed']} requests in {fstats['batches']} "
    f"micro-batches (mean batch {fstats['batch_size_mean']:.1f}, "
    f"p95 queue wait {1e3 * fstats['queue_wait_s']['p95']:.1f}ms) — "
    f"results == direct engine calls"
)

# 10. bf16 exact phase: precision="bf16" streams a bfloat16 mirror of the
#     corpus through the exact phase (half the HBM bytes per evaluated
#     point) and re-checks only the comparison-margin boundary band
#     |d - t| <= eps in fp32 — so hits, kNN results AND per-query distance
#     counts stay bit-identical to the fp32 engine.  eps comes from the
#     measured rounding displacement: eps = 2*max_p d(p, p~) + a small
#     fp32-arithmetic term (see repro/core/precision.py).
h16, s16 = flat_index.bss_query_batched(
    idx, queries, t, opts=EngineOpts(precision="bf16"))
assert h16 == hits  # bit-identical to the fp32 engine of step 4
assert (s16["per_query_dists"] == stats["per_query_dists"]).all()
print(
    f"bf16 exact phase: hits + counts == fp32 engine, band eps="
    f"{s16['band_eps']:.2e}, {s16['recheck_points_per_query']:.1f} "
    f"fp32 re-checked points/query"
)

# 11. the invariant checker: everything above leans on conventions (no
#     host syncs inside the jitted engines, fp32/bf16 only, monotonic
#     timing, tile sizes routed through repro.kernels.tiles).  The AST
#     lint enforces them in milliseconds; `python -m repro.analysis`
#     additionally traces every engine entry point and audits the jaxprs
#     (no f64, no callbacks, bf16 confinement, bounded recompiles).
from pathlib import Path  # noqa: E402

from repro.analysis.lint import lint_repo  # noqa: E402
from repro.analysis.rules import load_allowlist  # noqa: E402

repo_root = Path(__file__).resolve().parents[1]
violations = lint_repo(repo_root, load_allowlist())
for v in violations:
    print(v.format())
assert not violations
print("invariant lint: clean (run `python -m repro.analysis` for the "
      "full jaxpr audit)")

# 12. observability: the front (and every engine) reports what it pruned
#     and why.  Device-side counts (per-mechanism exclusion attribution,
#     tile counts, bf16 re-check volume) are FUNCTIONAL jit outputs in the
#     stats dicts — no callbacks, nothing the invariant checker of step 11
#     would reject, and provably zero effect on results — folded into a
#     metrics registry at the jit boundary.  front.metrics().render() is
#     the one-screen dashboard (.to_prometheus() the scrape endpoint), and
#     front.explain(trace_id) replays one request: stage-by-stage span
#     timings plus that row's share of the batch accounting.
with ServingFront(idx, max_delay_s=0.005) as front:
    answers = [front.submit(qv, "range", t=t).result(timeout=120)
               for qv in queries[:8]]
    print(front.metrics().render())
    trace = front.explain(answers[0].trace_id)
assert answers[0].hits == hits[0]  # metrics on: results still identical
print(
    f"explain {trace['trace_id']}: {trace['n_dists']} exact distances, "
    f"excluded {trace['excluded']} blocks, span total "
    f"{1e3 * trace['spans']['total']:.1f}ms "
    f"(engine {1e3 * trace['spans']['engine']:.1f}ms)"
)

# 13. living corpus: the index of step 4 is not frozen.  append() packs new
#     rows into fresh blocks against the EXISTING pivots (m x P distances,
#     no rebuild), delete() tombstones, compact() re-permutes the layout —
#     and every mutation bumps a monotonic generation the front swaps
#     between micro-batches (in-flight queries finish on their snapshot,
#     the answer cache keys on the generation, so nothing stale is ever
#     served).  Results after any mutation are bit-identical to a fresh
#     build_bss over the same live rows.
new_rows = metricsets.colors_surrogate(512, dim=64, seed=7)
with ServingFront(idx, max_delay_s=0.005, metrics=True) as front:
    g0 = front.metrics().series()
    gen0 = int(next(s.value for s in g0 if s.name == "index/generation"))
    ms_a = front.append(new_rows)
    grown = [front.submit(qv, "range", t=t).result(timeout=120)
             for qv in queries[:8]]
    ms_d = front.delete(np.arange(64))
    ms_c = front.compact()
    g1 = front.metrics().series()
    gen1 = int(next(s.value for s in g1 if s.name == "index/generation"))
    final = [front.submit(qv, "range", t=t).result(timeout=120)
             for qv in queries[:8]]
    live_index = front.index
assert gen1 == gen0 + 3  # append, delete, compact: one generation each
assert all(r.generation == gen1 for r in final)
new_ids = len(db) + np.arange(len(new_rows))  # appended rows: ids next_id..
live_ids = np.concatenate([np.arange(64, len(db)), new_ids])
fresh = flat_index.build_bss(
    "l2", np.concatenate([db[64:], new_rows]), n_pivots=16, n_pairs=24,
    block=128, seed=idx.seed,
)
fresh_hits, _ = flat_index.bss_query_batched(fresh, queries[:8], t)
remap = [sorted(live_ids[j] for j in h) for h in fresh_hits]
assert [sorted(r.hits) for r in final] == remap  # == fresh rebuild
print(
    f"living corpus: +{ms_a.rows} rows ({ms_a.new_blocks} new blocks, "
    f"{ms_a.table_dists} table distances), -{ms_d.rows} tombstoned, "
    f"compacted to {ms_c.n_blocks} blocks — generation {gen0} -> {gen1}, "
    f"results == fresh rebuild over the live rows"
)

# 14. performance tracing: everything the front does — each request's
#     queue/batch/engine/demux span slices, the driver's per-dispatch
#     phases, every index mutation — lands in one trace buffer on one
#     monotonic clock.  export_trace() writes Chrome trace-event JSON:
#     open it at https://ui.perfetto.dev (or chrome://tracing) and each
#     request is its own track, with mutations inline on the driver
#     track.  Building the front with profile_dir="..." additionally
#     wraps each engine dispatch in a jax.profiler trace so device-level
#     profiles line up with these host-side spans.  On a sharded index
#     the same stats carry per-shard work splits — the shard/imbalance
#     gauge in render() (max/mean, 1.0 = perfectly balanced) is the row a
#     rebalancing policy would watch.
from repro.obs import load_trace, validate_trace  # noqa: E402

with ServingFront(idx, max_delay_s=0.005) as front:
    for qv in queries[:8]:
        front.submit(qv, "range", t=t).result(timeout=120)
    front.append(metricsets.colors_surrogate(256, dim=64, seed=8))
    front.submit(queries[0], "knn", k=5).result(timeout=120)
    trace_path = front.export_trace("TRACE_quickstart.json")
payload = load_trace(trace_path)
assert validate_trace(payload) == []
kinds = {e["name"] for e in payload["traceEvents"]}
assert {"queue", "engine", "demux", "dispatch/engine",
        "mutation/append"} <= kinds
print(
    f"trace: {len(payload['traceEvents'])} events -> {trace_path} "
    "(load in https://ui.perfetto.dev; benchmarks/regress.py watches "
    "the matching BENCH_* numbers for regressions in CI)"
)
